"""Device-resident evaluation arena — round-robin matches as one XLA program.

A match is a T-step autoreset rollout of N envs where agent rows [0, L) act
under side A's params and rows [L, A) under side B's, counting completed
episodes as wins/draws/losses from the env's side-A-centric ``score``
(> 0.5 ⇒ A won — the ``check_selfplay_env`` score convention). The match is
a pure function of ``(params_a, params_b, key)``, so a K-opponent pool
evaluates as ONE vmapped/jitted launch over stacked param sets — no
per-match Python dispatch — and an all-pairs round-robin is a single
vmapped call over the gathered pair axes. ``benchmarks/bench_league.py``
holds the vmapped-vs-sequential speedup this buys.

Match records ``(a, b, outcome)`` feed ``ranker.Ranker`` directly;
``outcome`` is the standard match score (wins + draws/2) / episodes.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.emulation import Emulated
from repro.core.vector import VecEnv
from repro.telemetry import span as _span

_EPS = 1e-6                           # score == 0.5 within eps ⇒ draw


class Arena:
    """Evaluation arena for one competitive env + policy architecture.

    ``env`` is a raw Ocean-protocol env (wrapped in ``Emulated`` here) or an
    already-wrapped one; ``policy``/``dist`` must match the stored params
    (both sides share the learner's architecture). ``learner_agents`` is the
    agent-row split L (default A // 2). A ``random`` side samples from
    zero logits — uniform over discrete actions, a unit Gaussian for
    continuous ones — the league's fixed skill floor."""

    def __init__(self, env, policy, dist, *, num_envs: int = 16,
                 steps: Optional[int] = None, learner_agents: int = 0):
        self.em = env if isinstance(env, Emulated) else Emulated(env)
        self.policy, self.dist = policy, dist
        A = self.em.num_agents
        if A < 2:
            raise ValueError(f"arena needs a multi-agent env "
                             f"(num_agents={A}); matches split agent rows "
                             f"between two param sets")
        self.A = A
        self.L = learner_agents or A // 2
        if not 0 < self.L < A:
            raise ValueError(f"learner_agents={self.L} must split "
                             f"num_agents={A} into two non-empty sides")
        self.vec = VecEnv(self.em, num_envs)
        self.N = num_envs
        h = int(getattr(self.em.env, "horizon", 32))
        self.steps = steps or 2 * h
        self._play = jax.jit(self._make_play(random_b=False))
        self._play_random = jax.jit(self._make_play(random_b=True))
        self._vs_pool = jax.jit(jax.vmap(self._make_play(random_b=False),
                                         in_axes=(None, 0, 0)))
        self._pairs = jax.jit(jax.vmap(self._make_play(random_b=False),
                                       in_axes=(0, 0, 0)))

    # -- the single-match program ---------------------------------------------
    def _make_play(self, random_b: bool):
        policy, dist, vec = self.policy, self.dist, self.vec
        N, A, L, T = self.N, self.A, self.L, self.steps
        step_fn = vec.step_fn()

        def split_rows(x, lo, hi):
            e = x.reshape((N, A) + x.shape[1:])[:, lo:hi]
            return e.reshape((N * (hi - lo),) + x.shape[1:])

        def act(params, obs, carry, reset, key, random):
            logits, _, pc = policy.step(params, obs, carry, reset=reset)
            if random:  # repro: noqa[TRACER-BRANCH] — random is a Python bool bound per program (random_b closure / literal False)
                logits = jnp.zeros_like(logits)
            return dist.sample(key, logits), pc

        def play(params_a, params_b, key):
            k_init, key = jax.random.split(key)
            env_state, obs = vec.init(k_init)
            ca = policy.initial_carry(N * L)
            cb = policy.initial_carry(N * (A - L))
            zero = jnp.zeros((), jnp.float32)
            carry0 = (env_state, obs, ca, cb,
                      jnp.zeros((N * A,), jnp.bool_), zero, zero, zero)

            def one(c, k):
                env_state, obs, ca, cb, done_prev, wa, wb, dr = c
                ka, kb, ke = jax.random.split(k, 3)
                d_e = done_prev.reshape(N, A)
                act_a, ca = act(params_a, split_rows(obs, 0, L), ca,
                                d_e[:, :L].reshape(-1), ka, False)
                act_b, cb = act(params_b, split_rows(obs, L, A), cb,
                                d_e[:, L:].reshape(-1), kb, random_b)
                action = jnp.concatenate(
                    [act_a.reshape((N, L) + act_a.shape[1:]),
                     act_b.reshape((N, A - L) + act_b.shape[1:])],
                    axis=1).reshape((N * A,) + act_a.shape[1:])
                env_state, obs, _rew, done, info = step_fn(env_state, action,
                                                           ke)
                v = info["valid"].astype(jnp.float32)
                s = info["score"]
                wa = wa + jnp.sum(v * (s > 0.5 + _EPS))
                wb = wb + jnp.sum(v * (s < 0.5 - _EPS))
                dr = dr + jnp.sum(v * (jnp.abs(s - 0.5) <= _EPS))
                return (env_state, obs, ca, cb, done, wa, wb, dr), None

            (_, _, _, _, _, wa, wb, dr), _ = jax.lax.scan(
                one, carry0, jax.random.split(key, T))
            ep = wa + wb + dr
            return {"wins_a": wa, "wins_b": wb, "draws": dr, "episodes": ep,
                    "outcome": (wa + 0.5 * dr) / jnp.maximum(ep, 1.0)}

        return play

    # -- public API ------------------------------------------------------------
    def play(self, params_a, params_b, key) -> dict:
        """One match; returns host floats."""
        with _span("arena.play"):
            return {k: float(v) for k, v in
                    self._play(params_a, params_b, key).items()}

    def play_random(self, params_a, key) -> dict:
        """Side A vs the random-policy baseline (zero logits)."""
        return {k: float(v) for k, v in
                self._play_random(params_a, params_a, key).items()}

    def vs_pool(self, params_a, stacked_b, key) -> list:
        """Side A vs a K-stacked opponent pool in one vmapped launch;
        returns K per-opponent result dicts."""
        with _span("arena.vs_pool"):
            K = jax.tree.leaves(stacked_b)[0].shape[0]
            out = self._vs_pool(params_a, stacked_b, jax.random.split(key, K))
            rows = jax.device_get(out)
            return [{k: float(rows[k][i]) for k in rows} for i in range(K)]

    def round_robin(self, stacked, versions, key) -> list:
        """All ordered pairs i < j of a K-stacked param set as ONE vmapped
        launch. Returns ``(versions[i], versions[j], outcome_ij)`` match
        records ready for ``Ranker.record``."""
        K = jax.tree.leaves(stacked)[0].shape[0]
        if K != len(versions):
            raise ValueError(f"stacked leading axis {K} != "
                             f"len(versions) {len(versions)}")
        ii, jj = np.triu_indices(K, k=1)
        if len(ii) == 0:
            return []
        with _span("arena.round_robin"):
            side_a = jax.tree.map(lambda x: jnp.asarray(x)[ii], stacked)
            side_b = jax.tree.map(lambda x: jnp.asarray(x)[jj], stacked)
            out = self._pairs(side_a, side_b, jax.random.split(key, len(ii)))
            outcomes = np.asarray(jax.device_get(out["outcome"]))
        return [(versions[i], versions[j], float(o))
                for i, j, o in zip(ii, jj, outcomes)]

    def vs_pool_sequential(self, params_a, stacked_b, key) -> list:
        """Per-opponent jitted dispatches — the baseline the vmapped pool is
        benchmarked against (bench_league.py); identical math, K launches."""
        K = jax.tree.leaves(stacked_b)[0].shape[0]
        keys = jax.random.split(key, K)
        out = []
        for i in range(K):
            one = jax.tree.map(lambda x: jnp.asarray(x)[i], stacked_b)
            out.append({k: float(v) for k, v in
                        self._play(params_a, one, keys[i]).items()})
        return out
