"""Self-play training — the league's engine integration (layer 4).

A multi-agent env's agent rows split into *learner* rows [0, L) acting
under the live ``TrainState`` params and *opponent* rows [L, A) acting
under frozen params sampled from the ``PolicyStore`` once per engine
launch. The rollout records only learner rows — opponent behavior is part
of the environment from the learner's perspective — and feeds the exact
same ``make_ocean_learn`` PPO math as ordinary training, so self-play
works wherever the fused launch does (jit and shard_map tiers; randomness
stays keyed by global row index, so an S-device run is seed-matched with
single-device).

``run_selfplay`` is the batteries-included driver behind
``launch.train --selfplay`` and the Duel acceptance test: snapshot the
learner into the store on a cadence, rate each snapshot against the pool in
the vmapped arena, and sample opponents by rating.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import TrainConfig
from repro.rl.learner import _shard_index, make_ocean_learn
from repro.rl.rollout import Trajectory


class SelfPlayCarry(NamedTuple):
    """RolloutCarry with a second policy carry for the frozen opponent rows
    (recurrent opponents replay their snapshot's architecture)."""
    env_state: object
    obs: jax.Array              # (N*A, obs) — all rows, agent-major
    policy_carry: object        # learner rows (N*L)
    opp_carry: object           # opponent rows (N*(A-L))
    done_prev: jax.Array        # (N*A,)


@dataclasses.dataclass
class SelfPlay:
    """Engine-facing self-play spec: ``next_opponent()`` is called host-side
    once per launch (an ``OpponentSampler.next_params``, or any callable
    returning a param tree); ``learner_agents`` is the agent-row split L
    (0 → num_agents // 2)."""
    next_opponent: Callable[[], object]
    learner_agents: int = 0


def selfplay_rollout(policy, params, opp_params, step_fn, carry, key,
                     unroll, dist, num_envs, env_offset, num_agents,
                     learner_agents):
    """T-step fused rollout with split agent rows. Returns
    ``(carry', Trajectory-over-learner-rows, last_value (N*L,))``.

    Randomness is keyed by global row index (learner and opponent streams
    fold separate subkeys), and env keys by global env index — the same
    shard-invariance contract as ``rollout.rollout(keyed=...)``, so the
    shard_map tier passes ``env_offset = shard * local_envs``."""
    N, A, L = num_envs, num_agents, learner_agents
    O = A - L

    def rows(x, lo, hi):
        e = x.reshape((N, A) + x.shape[1:])[:, lo:hi]
        return e.reshape((N * (hi - lo),) + x.shape[1:])

    def one(c: SelfPlayCarry, k):
        k_act, k_opp, k_env = jax.random.split(k, 3)
        d_e = c.done_prev
        obs_l, obs_o = rows(c.obs, 0, L), rows(c.obs, L, A)
        reset_l, reset_o = rows(d_e, 0, L), rows(d_e, L, A)
        logits_l, value_l, pc_l = policy.step(params, obs_l, c.policy_carry,
                                              reset=reset_l)
        logits_o, _, pc_o = policy.step(opp_params, obs_o, c.opp_carry,
                                        reset=reset_o)
        # per-row keys from GLOBAL row indices (shard-invariant)
        kl = jax.vmap(lambda i: jax.random.fold_in(k_act, i))(
            env_offset * L + jnp.arange(N * L))
        ko = jax.vmap(lambda i: jax.random.fold_in(k_opp, i))(
            env_offset * O + jnp.arange(N * O))
        act_l = jax.vmap(dist.sample)(kl, logits_l)
        act_o = jax.vmap(dist.sample)(ko, logits_o)
        logp_l = dist.log_prob(logits_l, act_l)
        action = jnp.concatenate(
            [act_l.reshape((N, L) + act_l.shape[1:]),
             act_o.reshape((N, O) + act_o.shape[1:])],
            axis=1).reshape((N * A,) + act_l.shape[1:])
        env_keys = jax.vmap(lambda i: jax.random.fold_in(k_env, i))(
            env_offset + jnp.arange(N))
        env_state, obs, rew, done, info = step_fn(c.env_state, action,
                                                  env_keys)
        out = Trajectory(obs_l, act_l, logp_l, value_l, rows(rew, 0, L),
                         rows(done, 0, L), reset_l, info)
        return SelfPlayCarry(env_state, obs, pc_l, pc_o, done), out

    keys = jax.random.split(key, unroll)
    carry, traj = jax.lax.scan(one, carry, keys)
    _, last_value, _ = policy.step(params, rows(carry.obs, 0, L),
                                   carry.policy_carry,
                                   reset=rows(carry.done_prev, 0, L))
    return carry, traj, last_value


def make_selfplay_update(policy, step_fn, tcfg: TrainConfig, dist,
                         num_envs: int, num_agents: int, learner_agents: int,
                         kernel_mode: str = None, axis_name=None,
                         num_shards: int = 1):
    """Returns jit-able ``update(ts, rc, opp_params, key)`` — the self-play
    twin of ``learner.make_ocean_update``: split-row rollout, then the
    shared PPO learn over the learner rows only."""
    T = tcfg.unroll_length
    learn = make_ocean_learn(policy, tcfg, dist, kernel_mode=kernel_mode,
                             axis_name=axis_name, num_shards=num_shards)

    def update(ts, rc: SelfPlayCarry, opp_params, key):
        k_roll, k_perm = jax.random.split(key)
        carry0 = rc.policy_carry
        off = (_shard_index(axis_name) * num_envs
               if axis_name is not None else jnp.zeros((), jnp.int32))
        rc, traj, last_value = selfplay_rollout(
            policy, ts.params, opp_params, step_fn, rc, k_roll, T, dist,
            num_envs, off, num_agents, learner_agents)
        ts, metrics = learn(ts, carry0, traj, last_value, k_perm)
        return ts, rc, metrics

    return update


# -- high-level driver --------------------------------------------------------

def build_league(env, tcfg: TrainConfig, *, league_dir: str,
                 hidden: int = 64, recurrent: bool = False,
                 conv: bool = None, strategy: str = "prioritized",
                 seed: int = 0, learner_agents: int = 0,
                 arena_envs: int = 16, backend: str = None, mesh=None,
                 kernel_mode: str = None):
    """Wire a complete league around ``env``: (engine, store, ranker,
    sampler, arena). The store is seeded with the engine's init params as
    version 0 if empty, so sampling always has an opponent."""
    from repro.rl.engine import TrainEngine
    from repro.rl.trainer import ocean_policy_stack
    from repro.league.arena import Arena
    from repro.league.ranker import OpponentSampler, Ranker
    from repro.league.store import PolicyStore

    em, dist, policy = ocean_policy_stack(env, hidden=hidden,
                                          recurrent=recurrent, conv=conv)
    store = PolicyStore(league_dir)
    ranker = Ranker(store.ratings())
    sampler = OpponentSampler(store, ranker, policy.abstract(),
                              strategy=strategy, seed=seed)
    engine = TrainEngine(
        em, policy, tcfg, dist, key=jax.random.PRNGKey(seed),
        backend=backend, mesh=mesh, kernel_mode=kernel_mode,
        selfplay=SelfPlay(sampler.next_params, learner_agents))
    if len(store) == 0:
        store.add(jax.device_get(engine.ts.params), step=0)
    arena = Arena(em, policy, dist, num_envs=arena_envs,
                  learner_agents=learner_agents or em.num_agents // 2)
    return engine, store, ranker, sampler, arena


class LeagueResult(NamedTuple):
    history: list               # per-update metric dicts (engine history)
    store: object               # the PolicyStore (latest version = final)
    ranker: object              # Ranker with post-run ratings
    winrate_random: float       # final params vs the random baseline


def run_selfplay(env, tcfg: TrainConfig, *, league_dir: str,
                 total_steps: int, snapshot_every: int = 10,
                 rate_matches: int = 4, hidden: int = 64,
                 recurrent: bool = False, conv: bool = None,
                 strategy: str = "prioritized",
                 seed: int = 0, learner_agents: int = 0,
                 backend: str = None, mesh=None, kernel_mode: str = None,
                 log_every: int = 0) -> LeagueResult:
    """Self-play training loop: every ``snapshot_every`` updates the learner
    is snapshotted into the store, rated against up to ``rate_matches``
    pool members in one vmapped arena launch, and the ratings persist to
    ``league_dir/league.json``. The returned ``winrate_random`` is the
    final learner's match outcome vs the random-policy skill floor — the
    league's solved criterion (self-play score hovers near 0.5 by
    construction, so score can't be one)."""
    engine, store, ranker, sampler, arena = build_league(
        env, tcfg, league_dir=league_dir, hidden=hidden, recurrent=recurrent,
        conv=conv, strategy=strategy, seed=seed,
        learner_agents=learner_agents, backend=backend, mesh=mesh,
        kernel_mode=kernel_mode)
    rate_key = jax.random.PRNGKey(seed + 1)
    last = {"score": None}

    def on_update(u, m):
        last["score"] = m["score"]
        if log_every and (u % log_every == 0):
            print(f"  upd {u:4d} steps {m['env_steps']:7d} "
                  f"score {m['score']:.3f} opp v{sampler.history[-1]} "
                  f"sps {m['sps']:.0f}")

    snap = {"through": 0}

    def on_launch(u):
        nonlocal rate_key
        if u // snapshot_every <= snap["through"] // snapshot_every:
            return
        snap["through"] = u
        params = jax.device_get(engine.ts.params)
        v = store.add(params, step=u * engine.steps_per_update,
                      score=last["score"])
        pool = [x for x in store.versions() if x != v][-rate_matches:]
        if pool:
            stacked = store.load_stacked(pool, sampler.like)
            rate_key, sub = jax.random.split(rate_key)
            for opp, res in zip(pool, arena.vs_pool(params, stacked, sub)):
                ranker.update(v, opp, res["outcome"])
            store.set_ratings(ranker.ratings)

    history, solved = engine.run(total_steps, on_update=on_update,
                                 on_launch=on_launch)
    final = jax.device_get(engine.ts.params)
    if snap["through"] != len(history):    # last launch wasn't snapshotted
        store.add(final, step=len(history) * engine.steps_per_update,
                  score=last["score"])
    for v in store.versions():          # unrated versions get the default
        ranker.ratings.setdefault(v, ranker.rating(v))
    store.set_ratings(ranker.ratings)
    wr = arena.play_random(final, jax.random.PRNGKey(seed + 2))["outcome"]
    return LeagueResult(history, store, ranker, wr)
