"""Policy League: versioned policy store, rating-ranked opponent pool, and
device-resident self-play arena (see README §Policy League).

    store.PolicyStore      — versioned frozen-policy archive over ckpt
    ranker.Ranker          — Elo over match records + opponent samplers
    arena.Arena            — vmapped round-robin match evaluation
    selfplay               — TrainEngine integration + run_selfplay driver

CLI: ``python -m repro.league arena --league-dir DIR --env duel``.
"""
from repro.league.arena import Arena
from repro.league.ranker import OpponentSampler, Ranker, SAMPLER_STRATEGIES
from repro.league.selfplay import (LeagueResult, SelfPlay, SelfPlayCarry,
                                   build_league, make_selfplay_update,
                                   run_selfplay, selfplay_rollout)
from repro.league.store import INITIAL_RATING, PolicyStore

__all__ = [
    "Arena", "INITIAL_RATING", "LeagueResult", "OpponentSampler",
    "PolicyStore", "Ranker", "SAMPLER_STRATEGIES", "SelfPlay",
    "SelfPlayCarry", "build_league", "make_selfplay_update", "run_selfplay",
    "selfplay_rollout",
]
