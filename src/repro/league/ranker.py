"""Ranker + opponent samplers — the league's skill model.

``Ranker`` is a standard Elo update over match records ``(a, b, outcome)``
where ``outcome`` is side a's score in [0, 1] (1 win, 0 loss, 0.5 draw).
Elo is what the paper's policy-ranker machinery uses for Neural MMO: it
needs only pairwise outcomes, tolerates noisy matches, and recovers a total
order after enough records — the planted-skill-tier recovery test pins that
property down.

Samplers turn ratings into an opponent curriculum:

  latest       — always the newest snapshot (classic mirror self-play).
  uniform      — every stored version equally likely (league play; prevents
                 strategy collapse / cycling).
  prioritized  — probability decays with rating distance from the learner's
                 current rating, so training time concentrates on peers
                 (the policy-pool analogue of prioritized fictitious
                 self-play).

All samplers are deterministic functions of their seed: the same seed and
the same store state replay the same opponent schedule.
"""
from __future__ import annotations

import math
from typing import Optional

import numpy as np


class Ranker:
    """Elo ratings over policy versions, updated from match outcomes."""

    def __init__(self, ratings: Optional[dict] = None, k: float = 32.0,
                 initial: float = 1000.0):
        self.k, self.initial = float(k), float(initial)
        self.ratings = {int(v): float(r) for v, r in (ratings or {}).items()}

    def rating(self, version) -> float:
        return self.ratings.get(int(version), self.initial)

    def expected(self, a, b) -> float:
        """P(a beats b) under the Elo model."""
        return 1.0 / (1.0 + 10.0 ** ((self.rating(b) - self.rating(a))
                                     / 400.0))

    def update(self, a, b, outcome: float):
        """One match: ``outcome`` is a's score in [0, 1]."""
        ea = self.expected(a, b)
        delta = self.k * (float(outcome) - ea)
        self.ratings[int(a)] = self.rating(a) + delta
        self.ratings[int(b)] = self.rating(b) - delta

    def record(self, records):
        """Apply an iterable of ``(a, b, outcome)`` match records."""
        for a, b, outcome in records:
            self.update(a, b, outcome)

    def rank(self) -> list:
        """Versions sorted best-first (ties broken by newest)."""
        return sorted(self.ratings, key=lambda v: (-self.ratings[v], -v))

    def leaderboard(self) -> str:
        lines = [f"{'rank':>4}  {'version':>7}  {'rating':>8}"]
        for i, v in enumerate(self.rank()):
            lines.append(f"{i + 1:>4}  v{v:<6}  {self.ratings[v]:>8.1f}")
        return "\n".join(lines)


SAMPLER_STRATEGIES = ("latest", "uniform", "prioritized")


class OpponentSampler:
    """Draws opponent versions from a ``PolicyStore`` under a strategy,
    deterministically from ``seed``. ``next_params()`` is the callable the
    TrainEngine's selfplay mode invokes once per launch; loaded params are
    cached per version so re-sampling a version costs no I/O."""

    def __init__(self, store, ranker: Ranker, like, *,
                 strategy: str = "prioritized", seed: int = 0,
                 temperature: float = 200.0):
        if strategy not in SAMPLER_STRATEGIES:
            raise ValueError(f"unknown sampler strategy {strategy!r}; "
                             f"expected one of {SAMPLER_STRATEGIES}")
        self.store, self.ranker, self.like = store, ranker, like
        self.strategy, self.temperature = strategy, float(temperature)
        self._rng = np.random.default_rng(seed)
        self._cache = {}
        self.history = []                # sampled versions, in order

    def sample(self) -> int:
        versions = self.store.versions()
        if not versions:
            raise ValueError(f"policy store {self.store.directory!r} is "
                             f"empty; add a snapshot before sampling")
        if self.strategy == "latest":
            v = versions[-1]
        elif self.strategy == "uniform":
            v = int(self._rng.choice(versions))
        else:                            # prioritized by rating proximity
            anchor = self.ranker.rating(versions[-1])
            gaps = np.asarray([abs(self.ranker.rating(v) - anchor)
                               for v in versions])
            w = np.exp(-gaps / self.temperature)
            v = int(self._rng.choice(versions, p=w / w.sum()))
        self.history.append(v)
        return v

    def next_params(self):
        """Sample a version and return its (cached) param tree."""
        v = self.sample()
        if v not in self._cache:
            self._cache[v] = self.store.load(v, self.like)
        return self._cache[v]

    def invalidate(self, version: Optional[int] = None):
        """Drop cached params (all, or one version) — call after external
        writes to the store directory."""
        if version is None:
            self._cache.clear()
        else:
            self._cache.pop(int(version), None)
