"""PolicyStore — versioned frozen-policy snapshots over ``checkpoint/ckpt``.

The league's archive layer: every snapshot of the learner becomes an
immutable, monotonically numbered *version* with metadata (env step, score
at snapshot time, current rating). Storage reuses the elastic checkpoint
format — one ``step_<v>`` directory per version under ``<dir>/policies`` —
so a policy saved from one mesh restores under any other (``load`` accepts
a ``shardings`` tree exactly like ``ckpt.restore``), and a crash mid-save
never corrupts the archive (the ckpt commit protocol).

Metadata lives in ``<dir>/league.json``, written atomically (tmp + rename)
so the store survives concurrent readers. Ratings are stored here too:
the store is the single durable artifact of a league — point the arena CLI
or a fresh training run at the directory and everything resumes.
"""
from __future__ import annotations

import json
import os
from typing import Optional

import jax
import numpy as np

from repro.checkpoint import ckpt

INITIAL_RATING = 1000.0


class PolicyStore:
    """Append-only versioned policy archive rooted at ``directory``."""

    def __init__(self, directory: str):
        self.directory = directory
        self.policy_dir = os.path.join(directory, "policies")
        self.index_path = os.path.join(directory, "league.json")
        self._meta = self._read_index()

    # -- index I/O -------------------------------------------------------------
    def _read_index(self) -> dict:
        if os.path.exists(self.index_path):
            with open(self.index_path) as f:
                return {int(k): v for k, v in json.load(f)["versions"].items()}
        return {}

    def _write_index(self):
        os.makedirs(self.directory, exist_ok=True)
        tmp = self.index_path + ".tmp"
        with open(tmp, "w") as f:
            json.dump({"versions": {str(k): v
                                    for k, v in sorted(self._meta.items())}},
                      f, indent=1)
        os.replace(tmp, self.index_path)       # atomic

    # -- write path ------------------------------------------------------------
    def add(self, params, *, step: int = 0, score: Optional[float] = None,
            rating: Optional[float] = None) -> int:
        """Snapshot ``params`` as the next version; returns its number.
        ``rating`` defaults to the current latest version's rating (a new
        snapshot starts where its parent left off), or INITIAL_RATING for
        the first."""
        v = max(self._meta) + 1 if self._meta else 0
        if rating is None:
            rating = (self._meta[max(self._meta)]["rating"] if self._meta
                      else INITIAL_RATING)
        ckpt.save(self.policy_dir, params, step=v, keep=None)
        self._meta[v] = {"step": int(step),
                         "score": None if score is None else float(score),
                         "rating": float(rating)}
        self._write_index()
        return v

    # -- read path -------------------------------------------------------------
    def versions(self) -> list:
        return sorted(self._meta)

    def __len__(self) -> int:
        return len(self._meta)

    def latest(self) -> Optional[int]:
        return max(self._meta) if self._meta else None

    def meta(self, version: int) -> dict:
        return dict(self._meta[int(version)])

    def load(self, version: int, like, shardings=None):
        """Restore one version's params into the structure of ``like``
        (arrays or ShapeDtypeStructs; e.g. ``policy.abstract()``), optionally
        assembled straight onto a target mesh via ``shardings``."""
        path = os.path.join(self.policy_dir, f"step_{int(version)}")
        return ckpt.restore(path, like, shardings=shardings)

    def load_stacked(self, versions, like):
        """Restore K versions stacked along a new leading axis — the arena's
        opponent-pool layout (one vmapped match program over axis 0)."""
        trees = [self.load(v, like) for v in versions]
        return jax.tree.map(lambda *xs: np.stack(
            [np.asarray(x) for x in xs]), *trees)

    # -- ratings ---------------------------------------------------------------
    def ratings(self) -> dict:
        return {v: m["rating"] for v, m in self._meta.items()}

    def set_rating(self, version: int, rating: float):
        self._meta[int(version)]["rating"] = float(rating)
        self._write_index()

    def set_ratings(self, ratings: dict):
        for v, r in ratings.items():
            self._meta[int(v)]["rating"] = float(r)
        self._write_index()
