"""League CLI.

  # rank every stored version with a vmapped all-pairs arena, persist Elo
  PYTHONPATH=src python -m repro.league arena --league-dir /tmp/duel_league

  # leaderboard without playing
  PYTHONPATH=src python -m repro.league ls --league-dir /tmp/duel_league
"""
import argparse

from repro.league.ranker import Ranker
from repro.league.store import PolicyStore


def _leaderboard(store: PolicyStore) -> str:
    ranker = Ranker(store.ratings())
    lines = [f"{'rank':>4}  {'version':>7}  {'rating':>8}  {'step':>10}  "
             f"{'score':>6}"]
    for i, v in enumerate(ranker.rank()):
        m = store.meta(v)
        sc = "-" if m["score"] is None else f"{m['score']:.3f}"
        lines.append(f"{i + 1:>4}  v{v:<6}  {m['rating']:>8.1f}  "
                     f"{m['step']:>10}  {sc:>6}")
    return "\n".join(lines)


def main(argv=None):
    ap = argparse.ArgumentParser(prog="python -m repro.league")
    sub = ap.add_subparsers(dest="cmd", required=True)

    pa = sub.add_parser("arena", help="round-robin rate all stored versions")
    pa.add_argument("--league-dir", required=True)
    pa.add_argument("--env", default="duel",
                    help="competitive OCEAN env the policies play")
    pa.add_argument("--num-envs", type=int, default=16)
    pa.add_argument("--hidden", type=int, default=64,
                    help="policy width the snapshots were trained with")
    pa.add_argument("--max-versions", type=int, default=8,
                    help="rate only the newest K versions")
    pa.add_argument("--seed", type=int, default=0)

    pl = sub.add_parser("ls", help="print the leaderboard")
    pl.add_argument("--league-dir", required=True)

    args = ap.parse_args(argv)
    store = PolicyStore(args.league_dir)
    if args.cmd == "ls":
        print(_leaderboard(store))
        return 0

    import jax
    from repro.configs.ocean import preset
    from repro.envs.ocean import OCEAN
    from repro.league.arena import Arena
    from repro.rl.trainer import ocean_policy_stack

    if len(store) < 2:
        print(f"need >= 2 stored versions to play matches "
              f"(store has {len(store)})")
        return 1
    em, dist, policy = ocean_policy_stack(
        OCEAN[args.env](), hidden=args.hidden,
        recurrent=preset(args.env).recurrent)
    arena = Arena(em, policy, dist, num_envs=args.num_envs)
    versions = store.versions()[-args.max_versions:]
    stacked = store.load_stacked(versions, policy.abstract())
    records = arena.round_robin(stacked, versions,
                                jax.random.PRNGKey(args.seed))
    ranker = Ranker(store.ratings())
    ranker.record(records)
    store.set_ratings(ranker.ratings)
    print(f"played {len(records)} matches over versions "
          f"{versions[0]}..{versions[-1]}")
    print(_leaderboard(store))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
