"""Layer 1 driver: run the AST rules over files, honoring ``# repro:
noqa[RULE]`` suppressions and a committed baseline of grandfathered
findings.

Library API::

    from repro import analysis
    findings = analysis.check_file("my_env.py")
    findings = analysis.check_paths(["src/"], baseline="baseline.json")

Suppression is per-line: a ``# repro: noqa[HOST-SYNC]`` comment on the
flagged line silences that rule there (bare ``# repro: noqa`` silences all
rules on the line). The baseline file is a JSON multiset of finding keys
``path::RULE::normalized-snippet`` with counts — keyed on content, not
line numbers, so unrelated edits above a grandfathered finding don't
resurrect it.
"""
from __future__ import annotations

import ast
import json
import re
from collections import Counter
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Sequence, Union

from repro.analysis.rules import RULES, Finding, build_context

_NOQA = re.compile(r"#\s*repro:\s*noqa(?:\[([A-Za-z0-9_\-, ]+)\])?")


def _noqa_rules_for_line(line: str) -> Optional[set]:
    """None → no noqa; empty set → all rules suppressed; else rule IDs."""
    m = _NOQA.search(line)
    if not m:
        return None
    if m.group(1) is None:
        return set()
    return {r.strip().upper() for r in m.group(1).split(",") if r.strip()}


def check_source(source: str, path: str = "<string>",
                 rules: Optional[Iterable[str]] = None) -> List[Finding]:
    """Lint python source text. ``rules`` limits to a subset of rule IDs."""
    try:
        tree = ast.parse(source, filename=path)
    except SyntaxError as e:
        return [Finding("SYNTAX", path, e.lineno or 1, e.offset or 0,
                        f"cannot parse: {e.msg}", "")]
    ctx = build_context(tree, source, path)
    wanted = set(rules) if rules is not None else set(RULES)
    findings: List[Finding] = []
    for rule_id, rule in RULES.items():
        if rule_id not in wanted:
            continue
        findings.extend(rule.fn(ctx))
    # apply noqa
    lines = ctx.lines
    kept = []
    for f in findings:
        if 1 <= f.line <= len(lines):
            suppressed = _noqa_rules_for_line(lines[f.line - 1])
            if suppressed is not None and \
                    (not suppressed or f.rule in suppressed):
                continue
        kept.append(f)
    kept.sort(key=lambda f: (f.path, f.line, f.col, f.rule))
    return kept


def check_file(path: Union[str, Path],
               rules: Optional[Iterable[str]] = None) -> List[Finding]:
    p = Path(path)
    return check_source(p.read_text(), str(p), rules=rules)


def iter_python_files(paths: Sequence[Union[str, Path]]) -> List[Path]:
    out: List[Path] = []
    for p in paths:
        p = Path(p)
        if p.is_dir():
            out.extend(sorted(q for q in p.rglob("*.py")
                              if "__pycache__" not in q.parts))
        elif p.suffix == ".py":
            out.append(p)
    return out


# ---------------------------------------------------------------------------
# baseline

def _key_str(f: Finding) -> str:
    path, rule, snippet = f.key()
    return f"{path}::{rule}::{snippet}"


def load_baseline(path: Union[str, Path, None]) -> Counter:
    if path is None or not Path(path).exists():
        return Counter()
    data = json.loads(Path(path).read_text())
    return Counter({k: int(v) for k, v in data.get("findings", {}).items()})


def save_baseline(findings: Sequence[Finding], path: Union[str, Path]
                  ) -> None:
    counts = Counter(_key_str(f) for f in findings)
    payload = {"comment": "grandfathered repro.analysis findings — "
                          "regenerate with `python -m repro.analysis "
                          "--self --update-baseline`",
               "findings": dict(sorted(counts.items()))}
    Path(path).write_text(json.dumps(payload, indent=2) + "\n")


def apply_baseline(findings: Sequence[Finding],
                   baseline: Counter) -> List[Finding]:
    """Drop findings covered by the baseline multiset (count-aware)."""
    budget = Counter(baseline)
    fresh: List[Finding] = []
    for f in findings:
        k = _key_str(f)
        if budget.get(k, 0) > 0:
            budget[k] -= 1
        else:
            fresh.append(f)
    return fresh


def check_paths(paths: Sequence[Union[str, Path]],
                baseline: Union[str, Path, None] = None,
                rules: Optional[Iterable[str]] = None) -> List[Finding]:
    """Lint all python files under ``paths``; subtract the baseline."""
    findings: List[Finding] = []
    for p in iter_python_files(paths):
        findings.extend(check_file(p, rules=rules))
    return apply_baseline(findings, load_baseline(baseline))
