"""Layer 2: trace-and-inspect audit of real jaxprs and compiled HLO.

Where the AST lint (layer 1) over-approximates from source text, this layer
under-approximates from the actual program: it traces a function under
canonical arguments and asserts the four properties that make a JAX stack
"play nice" at speed —

  * **no host callbacks** in the jaxpr (a ``pure_callback``/``io_callback``
    anywhere under jit reintroduces the per-step host round-trip the paper
    eliminates),
  * **retrace count ≤ 1 per distinct arg signature** across a shape/dtype
    sweep (a function that retraces on every call recompiles in the hot
    loop),
  * **donation consumed**: if the caller passes ``donate_argnums``, the
    compiled HLO must actually alias those input buffers into the output
    (``input_output_alias`` in the module header, parsed by
    ``launch.hlo_analysis``) — otherwise train-state double-buffers,
  * **no silent f32→f64 promotion**: no float64 intermediate appears unless
    a float64 input was given.

Entry point: :func:`audit_fn`. Target enumeration for the repo's own
kernels/engines/envs lives in ``analysis.targets``.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np

# primitive names that smuggle a host round-trip into a jitted program
CALLBACK_PRIMS = ("pure_callback", "io_callback", "python_callback",
                  "callback", "debug_callback")


@dataclass(frozen=True)
class AuditViolation:
    check: str       # host-callback | retrace | donation | f64-promotion
    target: str
    message: str

    def render(self) -> str:
        return f"[{self.check}] {self.target}: {self.message}"

    def to_dict(self) -> dict:
        return {"check": self.check, "target": self.target,
                "message": self.message}


@dataclass
class AuditResult:
    target: str
    checks: List[str] = field(default_factory=list)
    violations: List[AuditViolation] = field(default_factory=list)

    @property
    def ok(self) -> bool:
        return not self.violations


# ---------------------------------------------------------------------------
# jaxpr walking

def _subjaxprs(params: dict):
    from jax.core import Jaxpr
    from jax.extend.core import ClosedJaxpr  # jax >= 0.4.x

    def leaves(v):
        if isinstance(v, (ClosedJaxpr,)):
            yield v.jaxpr
        elif isinstance(v, Jaxpr):
            yield v
        elif isinstance(v, (tuple, list)):
            for x in v:
                yield from leaves(x)
    for v in params.values():
        yield from leaves(v)


def callback_eqns(jaxpr, found: Optional[list] = None) -> list:
    """All (primitive_name, eqn) pairs for host-callback primitives,
    recursing through scan/cond/pjit sub-jaxprs."""
    if found is None:
        found = []
    for eqn in jaxpr.eqns:
        name = eqn.primitive.name
        if name in CALLBACK_PRIMS or "callback" in name:
            found.append((name, eqn))
        for sub in _subjaxprs(eqn.params):
            callback_eqns(sub, found)
    return found


def _is_f64(dt) -> bool:
    try:
        return np.dtype(dt) == np.float64
    except TypeError:                    # extended dtypes (PRNG keys)
        return False


def _f64_outvars(jaxpr, found: Optional[list] = None) -> list:
    for eqn in jaxpr.eqns:
        for var in eqn.outvars:
            aval = getattr(var, "aval", None)
            if aval is not None and getattr(aval, "dtype", None) is not None \
                    and _is_f64(aval.dtype):
                found = found if found is not None else []
                found.append((eqn.primitive.name, aval))
                break
        for sub in _subjaxprs(eqn.params):
            out = _f64_outvars(sub, found)
            found = out if found is None else found
    return found if found is not None else []


# ---------------------------------------------------------------------------
# argument plumbing

def _is_static(arg: Any) -> bool:
    return isinstance(arg, (bool, int, float, str, bytes, type(None)))


def _static_argnums(args: Sequence[Any]) -> Tuple[int, ...]:
    return tuple(i for i, a in enumerate(args) if _is_static(a))


def _array_leaves(args: Sequence[Any]) -> list:
    return [l for l in jax.tree_util.tree_leaves(list(args))
            if hasattr(l, "shape") and hasattr(l, "dtype")]


def _aval_signature(args: Sequence[Any]) -> tuple:
    """Shape/dtype fingerprint of the array leaves — statics excluded on
    purpose: the contract is one trace per distinct *aval* signature, so a
    function retraced because a supposedly-fixed static flipped is a bug."""
    return tuple((tuple(l.shape), str(l.dtype))
                 for l in _array_leaves(args))


# ---------------------------------------------------------------------------
# the audit

def audit_fn(fn: Callable, args: Sequence[Any], *,
             name: Optional[str] = None,
             variants: Sequence[Sequence[Any]] = (),
             donate_argnums: Optional[Tuple[int, ...]] = None,
             check_callbacks: bool = True,
             check_retrace: bool = True,
             check_f64: bool = True,
             allow_callbacks: Sequence[str] = ()) -> AuditResult:
    """Audit ``fn`` under canonical ``args`` (plus optional sweep
    ``variants`` — alternative arg tuples, typically other batch sizes).

    Non-array scalars in ``args`` are treated as static arguments (matching
    how the repo passes flags like ``causal=True`` through jit).
    ``allow_callbacks`` whitelists primitive names (e.g. a deliberate
    ``io_callback`` in a host-bridge op).
    """
    target = name or getattr(fn, "__name__", repr(fn))
    res = AuditResult(target=target)
    statics = _static_argnums(args)

    # -- jaxpr checks: callbacks + f64 --------------------------------------
    jaxpr = None
    if check_callbacks or check_f64:
        try:
            jaxpr = jax.make_jaxpr(fn, static_argnums=statics)(*args).jaxpr
        except Exception as e:          # tracing itself failed
            res.checks.append("trace")
            res.violations.append(AuditViolation(
                "trace", target, f"tracing failed: {type(e).__name__}: {e}"))
            return res

    if check_callbacks:
        res.checks.append("host-callback")
        for prim, _eqn in callback_eqns(jaxpr):
            if prim in allow_callbacks:
                continue
            res.violations.append(AuditViolation(
                "host-callback", target,
                f"jaxpr contains host callback primitive '{prim}' — every "
                f"call round-trips to python, serializing the device"))

    if check_f64:
        res.checks.append("f64-promotion")
        has_f64_input = any(_is_f64(l.dtype) for l in _array_leaves(args))
        if not has_f64_input:
            hits = _f64_outvars(jaxpr)
            if hits:
                prim, aval = hits[0]
                res.violations.append(AuditViolation(
                    "f64-promotion", target,
                    f"float64 intermediate produced by '{prim}' "
                    f"({aval.dtype}{list(getattr(aval, 'shape', ()))}) with "
                    f"no float64 input — doubles memory traffic and falls "
                    f"off the fast path silently"))

    # -- retrace across the sweep -------------------------------------------
    if check_retrace:
        res.checks.append("retrace")
        traces = 0

        def counting(*a, **kw):
            nonlocal traces
            traces += 1
            return fn(*a, **kw)

        jitted = jax.jit(counting, static_argnums=statics)
        sweep = [tuple(args)] + [tuple(v) for v in variants]
        try:
            for v in sweep:
                jax.block_until_ready(jitted(*v))  # repro: noqa[HOST-SYNC] — the audit must force compilation to count traces
                jax.block_until_ready(jitted(*v))  # repro: noqa[HOST-SYNC] — second call must hit the jit cache
        except Exception as e:
            res.violations.append(AuditViolation(
                "retrace", target,
                f"execution failed during sweep: {type(e).__name__}: {e}"))
        else:
            distinct = len({_aval_signature(v) for v in sweep})
            if traces > distinct:
                res.violations.append(AuditViolation(
                    "retrace", target,
                    f"traced {traces}× for {distinct} distinct arg "
                    f"signature(s) — something non-aval (a static flag, a "
                    f"fresh closure, weak types) is busting the jit cache"))

    # -- donation consumed --------------------------------------------------
    if donate_argnums:
        res.checks.append("donation")
        try:
            jitted = jax.jit(fn, static_argnums=statics,
                             donate_argnums=donate_argnums,
                             keep_unused=True)
            hlo = jitted.lower(*args).compile().as_text()
        except Exception as e:
            res.violations.append(AuditViolation(
                "donation", target,
                f"compile failed: {type(e).__name__}: {e}"))
        else:
            from repro.launch.hlo_analysis import donated_params
            consumed = donated_params(hlo)
            # flat param numbering: dynamic args flattened in order
            flat_idx, expected = 0, {}
            for i, a in enumerate(args):
                if i in statics:
                    continue
                n = len(jax.tree_util.tree_leaves(a))
                if i in donate_argnums:
                    expected[i] = set(range(flat_idx, flat_idx + n))
                flat_idx += n
            for i, want in expected.items():
                if want and not (want & consumed):
                    res.violations.append(AuditViolation(
                        "donation", target,
                        f"arg {i} was donated but none of its "
                        f"{len(want)} buffer(s) are aliased into the "
                        f"output (no input_output_alias in compiled "
                        f"HLO) — the donation silently double-buffers"))
    return res
