"""The JAX-aware AST lint rules — the pluggable half of ``repro.analysis``.

Each rule is a function ``fn(ctx: ModuleContext) -> Iterator[Finding]``
registered under a stable ID (``TRACER-BRANCH``, ``HOST-SYNC``, …). The
heavy lifting — which functions run under a JAX trace, which local names
hold tracers — is done once per module by :func:`build_context` and shared
by every rule, so adding a rule is ~20 lines.

What "traced" means statically (the approximation every rule builds on):

  * a function decorated with ``jax.jit`` / ``jax.vmap`` / … (including
    ``functools.partial(jax.jit, …)`` decorators),
  * a function (or lambda) passed by name to a trace entry point —
    ``jax.jit``, ``jax.grad``, ``jax.lax.scan`` / ``while_loop`` /
    ``cond`` / ``switch`` / ``fori_loop``, ``shard_map``, ``pallas_call``,
    ``jax.make_jaxpr`` — anywhere in the module,
  * any function lexically nested inside a traced function (its body runs
    at trace time), and
  * any local function a traced function calls by bare name (transitively):
    this is the reachability that makes ``NONDET-IN-PURE`` catch a
    ``time.time()`` two helper calls below the jitted entry point.

Within a traced function, the *parameters* are assumed to be tracers
(``self``/``cls`` excluded) and taint propagates through simple
assignments. Uses that are static even on tracers — ``x.shape``,
``x.dtype``, ``x.ndim``, ``len(x)``, ``isinstance(x, …)`` — never count,
which is what keeps shape-driven Python control flow (the dominant legal
pattern) out of the findings.

Cross-module tracing (an env ``step`` method jitted by a *caller* in
another file) is invisible to this layer by design — that is exactly what
the runtime half, ``analysis.jaxpr_audit``, covers.
"""
from __future__ import annotations

import ast
from dataclasses import dataclass, field
from typing import Callable, Dict, Iterator, List, Optional, Set, Tuple

# ---------------------------------------------------------------------------
# findings + registry

@dataclass(frozen=True)
class Finding:
    rule: str
    path: str
    line: int
    col: int
    message: str
    snippet: str = ""

    def key(self) -> tuple:
        """Line-number-insensitive identity used by the baseline file: a
        finding survives unrelated edits above it."""
        return (self.path, self.rule, " ".join(self.snippet.split()))

    def to_dict(self) -> dict:
        return {"rule": self.rule, "path": self.path, "line": self.line,
                "col": self.col, "message": self.message,
                "snippet": self.snippet}

    def render(self) -> str:
        return (f"{self.path}:{self.line}:{self.col}: {self.rule} "
                f"{self.message}")


@dataclass(frozen=True)
class Rule:
    id: str
    summary: str
    fn: Callable


RULES: Dict[str, Rule] = {}


def rule(rule_id: str, summary: str):
    def deco(fn):
        RULES[rule_id] = Rule(rule_id, summary, fn)
        return fn
    return deco


# ---------------------------------------------------------------------------
# module context

# attribute reads that are static even on a tracer — never taint evidence
# (num_agents/horizon are static env class attributes throughout this stack)
STATIC_ATTRS = {"shape", "dtype", "ndim", "size", "aval", "weak_type",
                "sharding", "itemsize", "nbytes", "num_agents", "horizon"}
# calls whose result is static/hashable regardless of tracer args
STATIC_CALLS = {"len", "isinstance", "hasattr", "getattr", "type", "id",
                "repr", "str"}

# trace entry points: callables whose function-valued arguments run under
# trace. Bare names on the left may appear un-prefixed (common imports);
# names on the right are only recognized with a jax/lax/pl prefix (too
# generic to match bare).
_ENTRY_BARE = {"jit", "vmap", "pmap", "grad", "value_and_grad", "shard_map",
               "pallas_call", "checkpoint", "remat", "make_jaxpr",
               "while_loop", "fori_loop", "associative_scan"}
_ENTRY_DOTTED = _ENTRY_BARE | {"scan", "cond", "switch", "map", "eval_shape"}
_JAX_ROOTS = {"jax", "lax", "pl", "pltpu", "plgpu"}

_NONDET_ROOTS = {"time", "random", "datetime", "secrets", "uuid"}

# numpy attributes that are legal under trace (dtypes, scalars, constants —
# used as annotations/arguments, not as array ops)
_NUMPY_OK = {"float16", "float32", "float64", "int4", "int8", "int16",
             "int32", "int64", "uint4", "uint8", "uint16", "uint32",
             "uint64", "bool_", "complex64", "complex128", "bfloat16",
             "dtype", "ndarray", "generic", "number", "integer", "floating",
             "signedinteger", "unsignedinteger", "inexact", "pi", "e",
             "inf", "nan", "newaxis", "issubdtype", "promote_types",
             "result_type", "iinfo", "finfo"}

_BLOCKING_GATE_IMPORTS = {"threading", "queue", "multiprocessing", "socket",
                          "concurrent", "concurrent.futures"}


@dataclass
class FuncInfo:
    node: ast.AST                      # FunctionDef | AsyncFunctionDef | Lambda
    name: str
    qualname: str
    parent: Optional[ast.AST]          # enclosing function node or None
    traced: bool = False
    trace_reason: str = ""
    # params declared static via the jit decorator's static_argnames /
    # static_argnums — excluded from taint (they are Python values at trace
    # time, so branching on them is legal)
    static_params: Set[str] = field(default_factory=set)


@dataclass
class ModuleContext:
    path: str
    source: str
    lines: List[str]
    tree: ast.Module
    funcs: Dict[int, FuncInfo] = field(default_factory=dict)  # id(node) -> info
    parents: Dict[int, ast.AST] = field(default_factory=dict)  # id(node) -> parent
    module_aliases: Dict[str, str] = field(default_factory=dict)  # alias->module
    from_imports: Dict[str, str] = field(default_factory=dict)  # name->module
    has_threading_imports: bool = False

    # -- helpers shared by rules --------------------------------------------

    def finding(self, rule_id: str, node: ast.AST, message: str) -> Finding:
        line = getattr(node, "lineno", 1)
        col = getattr(node, "col_offset", 0)
        snippet = self.lines[line - 1].strip() if line <= len(self.lines) \
            else ""
        return Finding(rule_id, self.path, line, col, message, snippet)

    def traced_funcs(self) -> List[FuncInfo]:
        return [fi for fi in self.funcs.values() if fi.traced]

    def func_of(self, node: ast.AST) -> Optional[FuncInfo]:
        """The innermost function containing ``node`` (by parent chain)."""
        cur = self.parents.get(id(node))
        while cur is not None:
            if id(cur) in self.funcs:
                return self.funcs[id(cur)]
            cur = self.parents.get(id(cur))
        return None


def dotted_chain(node: ast.AST) -> Tuple[str, ...]:
    """``jax.lax.scan`` → ("jax", "lax", "scan"); () if not a name chain."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return tuple(reversed(parts))
    return ()


def body_stmts(fn_node: ast.AST) -> Iterator[ast.AST]:
    """All nodes of a function body, NOT descending into nested function
    definitions (those are separate traced contexts, checked on their own).
    """
    if isinstance(fn_node, ast.Lambda):
        yield from ast.walk(fn_node.body)
        return
    stack = list(fn_node.body)
    while stack:
        node = stack.pop()
        yield node
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.Lambda)):
            continue
        stack.extend(ast.iter_child_nodes(node))


# annotations that declare a parameter to be a host value, not a tracer
_HOST_ANNOTATIONS = {"int", "bool", "str", "bytes"}


def _annotated_host(p: ast.arg) -> bool:
    ann = p.annotation
    ch = dotted_chain(ann) if ann is not None else ()
    if not ch and isinstance(ann, ast.Constant) and \
            isinstance(ann.value, str):           # string annotation
        ch = tuple(ann.value.split("."))
    return bool(ch) and (ch[-1] in _HOST_ANNOTATIONS
                         or ch[-1].endswith("Config"))


def _param_names(fn_node: ast.AST) -> Set[str]:
    a = fn_node.args
    params = list(getattr(a, "posonlyargs", [])) + a.args + a.kwonlyargs
    names = [p.arg for p in params if not _annotated_host(p)]
    if a.vararg:
        names.append(a.vararg.arg)
    if a.kwarg:
        names.append(a.kwarg.arg)
    return {n for n in names if n not in ("self", "cls")}


def _is_entry_call(ctx: ModuleContext, call: ast.Call) -> bool:
    chain = dotted_chain(call.func)
    if not chain or "tree" in chain:   # jax.tree.map is a host-side map
        return False
    last = chain[-1]
    if len(chain) == 1:
        return last in _ENTRY_BARE
    return last in _ENTRY_DOTTED and (chain[0] in _JAX_ROOTS
                                      or "jax" in chain or "lax" in chain)


def _candidate_fn_exprs(call: ast.Call) -> Iterator[ast.AST]:
    """Function-valued argument expressions of a trace-entry call."""
    for arg in list(call.args) + [kw.value for kw in call.keywords]:
        if isinstance(arg, (ast.Name, ast.Lambda)):
            yield arg
        elif isinstance(arg, ast.Call):
            ch = dotted_chain(arg.func)
            if ch and ch[-1] == "partial":
                for inner in arg.args[:1]:
                    if isinstance(inner, (ast.Name, ast.Lambda)):
                        yield inner
        elif isinstance(arg, (ast.List, ast.Tuple)):   # lax.switch branches
            for el in arg.elts:
                if isinstance(el, (ast.Name, ast.Lambda)):
                    yield el


def build_context(tree: ast.Module, source: str, path: str) -> ModuleContext:
    ctx = ModuleContext(path=path, source=source,
                        lines=source.splitlines(), tree=tree)

    # parent map + function table
    func_stack: List[Tuple[ast.AST, str]] = []

    def visit(node, parent, qual):
        ctx.parents[id(node)] = parent
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.Lambda)):
            name = getattr(node, "name", "<lambda>")
            qn = f"{qual}.{name}" if qual else name
            fn_parent = None
            for anc, _ in reversed(func_stack):
                fn_parent = anc
                break
            ctx.funcs[id(node)] = FuncInfo(node, name, qn, fn_parent)
            func_stack.append((node, qn))
            for child in ast.iter_child_nodes(node):
                visit(child, node, qn)
            func_stack.pop()
        else:
            for child in ast.iter_child_nodes(node):
                visit(child, node, qual)

    for top in tree.body:
        visit(top, tree, "")

    # imports
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for al in node.names:
                ctx.module_aliases[al.asname or al.name.split(".")[0]] = \
                    al.name
        elif isinstance(node, ast.ImportFrom) and node.module:
            for al in node.names:
                ctx.from_imports[al.asname or al.name] = node.module
    # root-normalize both alias targets and from-sources: `import
    # multiprocessing.shared_memory` stores the full dotted name as the
    # alias value, which would otherwise slip past the root-level gate set
    mods = ({m.split(".")[0] for m in ctx.module_aliases.values()}
            | set(ctx.module_aliases.values())
            | {m.split(".")[0] for m in ctx.from_imports.values()})
    ctx.has_threading_imports = bool(mods & _BLOCKING_GATE_IMPORTS)

    defs_by_name: Dict[str, List[FuncInfo]] = {}
    for fi in ctx.funcs.values():
        defs_by_name.setdefault(fi.name, []).append(fi)

    # seed traced set: decorators + trace-entry call sites
    def mark(fi: FuncInfo, reason: str):
        if not fi.traced:
            fi.traced = True
            fi.trace_reason = reason

    def grab_statics(fi: FuncInfo, call: ast.Call):
        """static_argnames/static_argnums of a jit decorator → param names."""
        if isinstance(fi.node, ast.Lambda):
            return
        a = fi.node.args
        pos = [p.arg for p in list(getattr(a, "posonlyargs", [])) + a.args]
        names = set(pos) | {p.arg for p in a.kwonlyargs}

        def consts(v):
            if isinstance(v, ast.Constant):
                return [v.value]
            return [e.value for e in getattr(v, "elts", [])
                    if isinstance(e, ast.Constant)]

        for kw in call.keywords:
            if kw.arg == "static_argnames":
                fi.static_params |= {c for c in consts(kw.value)
                                     if isinstance(c, str) and c in names}
            elif kw.arg == "static_argnums":
                fi.static_params |= {pos[n] for n in consts(kw.value)
                                     if isinstance(n, int) and n < len(pos)}

    for fi in ctx.funcs.values():
        for dec in getattr(fi.node, "decorator_list", []):
            target = dec.func if isinstance(dec, ast.Call) else dec
            ch = dotted_chain(target)
            if ch and ch[-1] == "partial" and isinstance(dec, ast.Call):
                for inner in dec.args[:1]:
                    ich = dotted_chain(inner)
                    if ich and ich[-1] in _ENTRY_DOTTED:
                        mark(fi, f"decorated with {'.'.join(ich)}")
                        grab_statics(fi, dec)
            elif ch and (ch[-1] in _ENTRY_BARE
                         or (len(ch) > 1 and ch[-1] in _ENTRY_DOTTED
                             and ch[0] in _JAX_ROOTS)):
                mark(fi, f"decorated with {'.'.join(ch)}")
                if isinstance(dec, ast.Call):
                    grab_statics(fi, dec)

    for node in ast.walk(tree):
        if isinstance(node, ast.Call) and _is_entry_call(ctx, node):
            entry = ".".join(dotted_chain(node.func))
            for expr in _candidate_fn_exprs(node):
                if isinstance(expr, ast.Lambda):
                    fi = ctx.funcs.get(id(expr))
                    if fi:
                        mark(fi, f"passed to {entry}")
                elif isinstance(expr, ast.Name):
                    for fi in defs_by_name.get(expr.id, []):
                        mark(fi, f"passed to {entry}")

    # propagate: lexical nesting + bare-name local calls, to fixpoint
    changed = True
    while changed:
        changed = False
        for fi in ctx.funcs.values():
            if fi.traced:
                continue
            par = fi.parent
            if par is not None and ctx.funcs[id(par)].traced:
                mark(fi, f"nested in traced "
                         f"{ctx.funcs[id(par)].qualname}")
                changed = True
        for fi in ctx.funcs.values():
            if not fi.traced:
                continue
            for node in body_stmts(fi.node):
                if isinstance(node, ast.Call) and \
                        isinstance(node.func, ast.Name):
                    for callee in defs_by_name.get(node.func.id, []):
                        if not callee.traced:
                            mark(callee, f"called from traced {fi.qualname}")
                            changed = True
    return ctx


# ---------------------------------------------------------------------------
# taint: which local names hold tracers inside a traced function

def _assign_targets(node) -> List[str]:
    out = []

    def grab(t):
        if isinstance(t, ast.Name):
            out.append(t.id)
        elif isinstance(t, (ast.Tuple, ast.List)):
            for el in t.elts:
                grab(el)
        elif isinstance(t, ast.Starred):
            grab(t.value)
    if isinstance(node, ast.Assign):
        for t in node.targets:
            grab(t)
    elif isinstance(node, (ast.AugAssign, ast.AnnAssign)):
        grab(node.target)
    elif isinstance(node, ast.For):
        grab(node.target)
    elif isinstance(node, ast.withitem) and node.optional_vars is not None:
        grab(node.optional_vars)
    return out


def hot_names(expr: ast.AST, tainted: Set[str]) -> Set[str]:
    """Tainted names used *non-statically* in ``expr``: a name only read
    through ``.shape``/``.dtype``/``len()``/``isinstance()`` does not count.
    """
    found: Set[str] = set()

    def walk(node):
        if isinstance(node, ast.Attribute) and node.attr in STATIC_ATTRS:
            return                      # x.shape, x.dtype, ... — static
        if isinstance(node, ast.Compare) and node.ops and \
                all(isinstance(op, (ast.In, ast.NotIn)) for op in node.ops) \
                and isinstance(node.left, ast.Constant) \
                and isinstance(node.left.value, str):
            return                      # '"key" in batch' — structural, the
                                        # pytree's key set is static
        if isinstance(node, ast.Call):
            ch = dotted_chain(node.func)
            if ch and ch[-1] in STATIC_CALLS:
                return                  # len(x), isinstance(x, T), ...
        if isinstance(node, ast.Name) and isinstance(node.ctx, ast.Load) \
                and node.id in tainted:
            found.add(node.id)
        for child in ast.iter_child_nodes(node):
            walk(child)

    walk(expr)
    return found


def taint_of(fn_node: ast.AST, tainted0: Optional[Set[str]] = None,
             exclude: Set[str] = frozenset()) -> Set[str]:
    """Names holding (things derived from) the function's parameters.
    ``exclude``: params that are static at trace time (static_argnames)."""
    tainted = (set(tainted0 or ()) | _param_names(fn_node)) - set(exclude)
    changed = True
    while changed:
        changed = False
        for node in body_stmts(fn_node):
            value = None
            if isinstance(node, (ast.Assign, ast.AnnAssign, ast.AugAssign)):
                value = node.value
            elif isinstance(node, ast.For):
                value = node.iter
            if value is None:
                continue
            if hot_names(value, tainted):
                for name in _assign_targets(node):
                    if name not in tainted:
                        tainted.add(name)
                        changed = True
    return tainted


# ---------------------------------------------------------------------------
# the rules

@rule("TRACER-BRANCH",
      "Python if/while/assert on a traced value inside a jit/scan context")
def _tracer_branch(ctx: ModuleContext) -> Iterator[Finding]:
    for fi in ctx.traced_funcs():
        tainted = taint_of(fi.node, exclude=fi.static_params)
        for node in body_stmts(fi.node):
            if isinstance(node, (ast.If, ast.While, ast.Assert)):
                test, what = node.test, type(node).__name__.lower()
            elif isinstance(node, ast.IfExp):
                test, what = node.test, "conditional expression"
            else:
                continue
            hot = hot_names(test, tainted)
            if hot:
                yield ctx.finding(
                    "TRACER-BRANCH", node,
                    f"Python {what} on traced value(s) "
                    f"{sorted(hot)} inside traced function "
                    f"'{fi.qualname}' — this raises a "
                    f"ConcretizationTypeError under jit (or silently "
                    f"freezes the branch at trace time); use jnp.where / "
                    f"lax.cond / lax.while_loop")


_SYNC_ATTRS = {"item", "tolist", "block_until_ready"}
_SYNC_LOOP_CALLS = {"block_until_ready", "device_get", "item"}


@rule("HOST-SYNC",
      "host synchronization (float()/.item()/np.asarray/device_get) on "
      "device values in a traced function or a hot host loop")
def _host_sync(ctx: ModuleContext) -> Iterator[Finding]:
    # pattern A: concretizing calls on tainted values inside traced functions
    for fi in ctx.traced_funcs():
        tainted = taint_of(fi.node, exclude=fi.static_params)
        for node in body_stmts(fi.node):
            if not isinstance(node, ast.Call):
                continue
            ch = dotted_chain(node.func)
            hot: Set[str] = set()
            kind = None
            if ch and len(ch) == 1 and ch[0] in ("float", "int", "bool",
                                                 "complex"):
                for a in node.args:
                    hot |= hot_names(a, tainted)
                kind = f"{ch[0]}()"
            elif isinstance(node.func, ast.Attribute) and \
                    node.func.attr in _SYNC_ATTRS:
                hot = hot_names(node.func.value, tainted)
                kind = f".{node.func.attr}()"
            elif ch and len(ch) >= 2 and ch[-1] in ("asarray", "array") \
                    and ctx.module_aliases.get(ch[0]) == "numpy":
                for a in node.args:
                    hot |= hot_names(a, tainted)
                kind = f"{'.'.join(ch)}()"
            if hot and kind:
                yield ctx.finding(
                    "HOST-SYNC", node,
                    f"{kind} on traced value(s) {sorted(hot)} inside "
                    f"traced function '{fi.qualname}' — forces a device→"
                    f"host sync (or a trace-time concretization error); "
                    f"keep the value on device or move it out of the "
                    f"traced region")
    # pattern B: explicit syncs lexically inside host-side loops
    loop_of: Dict[int, ast.AST] = {}
    for node in ast.walk(ctx.tree):
        if isinstance(node, (ast.For, ast.While)):
            for sub in ast.walk(node):
                loop_of.setdefault(id(sub), node)
    for node in ast.walk(ctx.tree):
        if id(node) not in loop_of or not isinstance(node, ast.Call):
            continue
        fi = ctx.func_of(node)
        if fi is not None and fi.traced:
            continue                     # pattern A's jurisdiction
        ch = dotted_chain(node.func)
        name = ch[-1] if ch else (node.func.attr
                                  if isinstance(node.func, ast.Attribute)
                                  else None)
        if name in _SYNC_LOOP_CALLS:
            yield ctx.finding(
                "HOST-SYNC", node,
                f"{name}() inside a host-side loop — a per-iteration "
                f"device sync serializes dispatch (the per-update float(v) "
                f"bug class); batch the fetch outside the loop")


_REPRO_BLOCKING_CALLS = {"spin_until", "wait_fragments"}


@rule("BLOCKING-NO-TIMEOUT",
      "blocking queue/thread call without a timeout in threaded code")
def _blocking_no_timeout(ctx: ModuleContext) -> Iterator[Finding]:
    for node in ast.walk(ctx.tree):
        if not isinstance(node, ast.Call):
            continue
        kwnames = {kw.arg for kw in node.keywords}
        if "timeout" in kwnames:
            continue
        # this repo's own cross-process waits (shm.spin_until, the async
        # tier's AsyncRollouts.wait_fragments) declare timeout kw-only for
        # exactly this reason — a call without it spins forever on a dead
        # peer. Checked regardless of the import gate: these names only
        # exist in the shared-memory layer, where the hazard is inherent.
        fname = (node.func.id if isinstance(node.func, ast.Name)
                 else node.func.attr if isinstance(node.func, ast.Attribute)
                 else None)
        if fname in _REPRO_BLOCKING_CALLS:
            yield ctx.finding(
                "BLOCKING-NO-TIMEOUT", node,
                f"{fname}() without timeout= — this wait spins on another "
                f"process's progress (actor/learner slab handshake); a "
                f"dead peer turns it into a livelock. The timeout turns "
                f"that into a diagnosable error")
            continue
        if not ctx.has_threading_imports:
            continue
        # bare `wait(object_list)` from-imported from
        # multiprocessing.connection — blocks until a connection is ready
        if (isinstance(node.func, ast.Name) and node.func.id == "wait"
                and node.args
                and ctx.from_imports.get("wait", "").endswith("connection")):
            yield ctx.finding(
                "BLOCKING-NO-TIMEOUT", node,
                "connection.wait(objects) without a timeout — a dead or "
                "wedged peer turns this into a silent deadlock; pass "
                "timeout= (poll in a loop if cancellation must be honored)")
            continue
        if not isinstance(node.func, ast.Attribute):
            continue
        attr = node.func.attr
        blocking = False
        if attr == "get" and not node.args:
            # Queue.get() — dict.get always takes >= 1 positional arg
            blocking = not any(kw.arg == "block" and
                               isinstance(kw.value, ast.Constant) and
                               kw.value.value is False
                               for kw in node.keywords)
        elif attr == "join" and not node.args:
            # Thread/Process.join() — str.join always takes an argument
            blocking = True
        elif attr in ("recv", "result") and not node.args:
            blocking = True
        elif attr in ("acquire", "wait") and not node.args:
            blocking = not any(kw.arg == "blocking" and
                               isinstance(kw.value, ast.Constant) and
                               kw.value.value is False
                               for kw in node.keywords)
        elif attr == "wait" and node.args:
            # connection.wait(object_list): the positional arg is the
            # object list, not a timeout (unlike Event.wait(t))
            ch = dotted_chain(node.func)
            blocking = len(ch) >= 2 and ch[-2] == "connection"
        elif attr == "accept" and not node.args:
            # socket.accept() / HTTPServer accept path — parks the thread
            # until a client connects; unbounded unless settimeout was set,
            # which this AST pass can't prove. Serve loops should poll
            # under a server timeout (handle_request with a class-level
            # timeout) or select() with a deadline.
            blocking = True
        elif attr == "serve_forever":
            # serve_forever blocks until shutdown() from another thread —
            # a wedged handler or a lost shutdown() call leaves it parked
            # with no way to observe a stop flag. Run handle_request()
            # in a loop under a server timeout instead.
            blocking = True
        if blocking:
            yield ctx.finding(
                "BLOCKING-NO-TIMEOUT", node,
                f".{attr}() without a timeout in a module that uses "
                f"threads/queues — a dead or wedged peer turns this into "
                f"a silent deadlock; pass timeout= (poll in a loop if "
                f"cancellation must be honored)")


@rule("NONDET-IN-PURE",
      "nondeterministic host call (time/random/np.random) reachable from a "
      "traced function")
def _nondet_in_pure(ctx: ModuleContext) -> Iterator[Finding]:
    for fi in ctx.traced_funcs():
        for node in body_stmts(fi.node):
            if not isinstance(node, ast.Call):
                continue
            ch = dotted_chain(node.func)
            if len(ch) < 2:
                continue
            root_mod = ctx.module_aliases.get(ch[0])
            bad = None
            if root_mod in _NONDET_ROOTS:
                bad = f"{root_mod}.{'.'.join(ch[1:])}"
            elif root_mod == "numpy" and ch[1] == "random":
                bad = f"numpy.{'.'.join(ch[1:])}"
            elif ch[0] in _NONDET_ROOTS and root_mod is None and \
                    ctx.from_imports.get(ch[0], "").startswith(tuple(
                        _NONDET_ROOTS)):
                bad = ".".join(ch)
            if bad:
                yield ctx.finding(
                    "NONDET-IN-PURE", node,
                    f"{bad}() inside traced function '{fi.qualname}' "
                    f"({fi.trace_reason}) — the value freezes at trace "
                    f"time and silently replays on every call; thread a "
                    f"jax.random key (or pass the value in as an argument)")


@rule("DONATION-REUSE",
      "a buffer donated via donate_argnums is read after the donating call")
def _donation_reuse(ctx: ModuleContext) -> Iterator[Finding]:
    for fi in list(ctx.funcs.values()) + [None]:
        # also scan module level (fi None)
        nodes = (body_stmts(fi.node) if fi is not None
                 else (n for n in ast.walk(ctx.tree)
                       if ctx.func_of(n) is None))
        nodes = list(nodes)
        donators: Dict[str, Tuple[int, ...]] = {}
        assigns: Dict[str, List[int]] = {}
        loads: Dict[str, List[ast.Name]] = {}
        donated: List[Tuple[str, int]] = []   # (name, donating call lineno)

        def parse_donate(call: ast.Call) -> Optional[Tuple[int, ...]]:
            ch = dotted_chain(call.func)
            if not (ch and ch[-1] == "jit"):
                return None
            for kw in call.keywords:
                if kw.arg in ("donate_argnums", "donate_argnames"):
                    v = kw.value
                    if isinstance(v, ast.Constant) and \
                            isinstance(v.value, int):
                        return (v.value,)
                    if isinstance(v, (ast.Tuple, ast.List)):
                        out = tuple(e.value for e in v.elts
                                    if isinstance(e, ast.Constant)
                                    and isinstance(e.value, int))
                        return out or None
            return None

        # pass 1: names, assignments, and which locals hold donating jits
        for node in nodes:
            if isinstance(node, ast.Name):
                if isinstance(node.ctx, ast.Load):
                    loads.setdefault(node.id, []).append(node)
            if isinstance(node, (ast.Assign, ast.AnnAssign, ast.AugAssign,
                                 ast.For)):
                for t in _assign_targets(node):
                    assigns.setdefault(t, []).append(node.lineno)
            if isinstance(node, ast.Assign) and \
                    isinstance(node.value, ast.Call):
                pos = parse_donate(node.value)
                if pos:
                    for t in _assign_targets(node):
                        donators[t] = pos
        # pass 2: donating call sites (body_stmts order is not source order,
        # so the donator table must be complete before this pass)
        for node in nodes:
            if not isinstance(node, ast.Call):
                continue
            pos = None
            if isinstance(node.func, ast.Name) and node.func.id in donators:
                pos = donators[node.func.id]
            elif isinstance(node.func, ast.Call):
                pos = parse_donate(node.func)
            if pos:
                for p in pos:
                    if p < len(node.args) and \
                            isinstance(node.args[p], ast.Name):
                        donated.append((node.args[p].id, node.lineno))

        for name, call_line in donated:
            relivened = [a for a in assigns.get(name, [])
                         if a >= call_line]
            for load in loads.get(name, []):
                if load.lineno <= call_line:
                    continue
                if any(call_line <= a <= load.lineno for a in relivened):
                    continue
                where = fi.qualname if fi is not None else "<module>"
                yield ctx.finding(
                    "DONATION-REUSE", load,
                    f"'{name}' was donated to a jitted call at line "
                    f"{call_line} (donate_argnums) and read again here in "
                    f"'{where}' — the buffer may already be aliased into "
                    f"the output; rebind the result or drop the donation")
                break


@rule("IMPURE-IMPORT",
      "host numpy ops inside a function traced by jax.jit/lax.scan")
def _impure_import(ctx: ModuleContext) -> Iterator[Finding]:
    np_aliases = {alias for alias, mod in ctx.module_aliases.items()
                  if mod == "numpy"}
    if not np_aliases:
        return
    for fi in ctx.traced_funcs():
        for node in body_stmts(fi.node):
            if not (isinstance(node, ast.Call)
                    and isinstance(node.func, ast.Attribute)):
                continue
            ch = dotted_chain(node.func)
            if not (len(ch) >= 2 and ch[0] in np_aliases):
                continue
            if ch[1] in _NUMPY_OK or ch[1] == "random":
                continue                 # dtypes/constants OK; np.random is
                                         # NONDET-IN-PURE's finding
            yield ctx.finding(
                "IMPURE-IMPORT", node,
                f"numpy op {'.'.join(ch)}() inside traced function "
                f"'{fi.qualname}' — host numpy under trace concretizes "
                f"tracers (or bakes in constants) instead of staying in "
                f"the XLA program; use jax.numpy")


_TELEMETRY_MOD = "repro.telemetry"


@rule("TELEMETRY-IN-JIT",
      "telemetry span/registry/timer call inside a jit/scan-traced function")
def _telemetry_in_jit(ctx: ModuleContext) -> Iterator[Finding]:
    """Spans and metric updates are host-side side effects: under trace they
    run ONCE at trace time, get baked out of the XLA program, and silently
    record nothing on every replayed launch (worse: a span opened at trace
    time measures compilation, not execution). Telemetry belongs on the host
    side of the dispatch boundary — around the launch, never inside it."""

    def telemetry_source(ch: Tuple[str, ...]) -> Optional[str]:
        """The repro.telemetry module a call chain resolves to, or None."""
        if not ch:
            return None
        root = ch[0]
        mod = ctx.module_aliases.get(root)
        if mod is not None and (mod == _TELEMETRY_MOD or
                                mod.startswith(_TELEMETRY_MOD + ".")):
            return mod
        src = ctx.from_imports.get(root, "")
        if root == "telemetry" and src == "repro":
            return _TELEMETRY_MOD          # from repro import telemetry
        if src == _TELEMETRY_MOD or src.startswith(_TELEMETRY_MOD + "."):
            return src                     # from repro.telemetry import span
        return None

    for fi in ctx.traced_funcs():
        for node in body_stmts(fi.node):
            if not isinstance(node, ast.Call):
                continue
            ch = dotted_chain(node.func)
            src = telemetry_source(ch)
            if src:
                yield ctx.finding(
                    "TELEMETRY-IN-JIT", node,
                    f"telemetry call {'.'.join(ch)}() (from {src}) inside "
                    f"traced function '{fi.qualname}' ({fi.trace_reason}) "
                    f"— host-side spans/metrics under trace fire once at "
                    f"trace time and are baked out of the compiled "
                    f"program (every replayed launch records nothing); "
                    f"move the instrumentation to the host side of the "
                    f"dispatch boundary")
