"""repro.analysis — JAX-aware static checking for envs, policies, and the
training stack itself.

Two layers (see README §Static analysis):

  * **AST lint** (zero execution): six rules for the hazard classes that
    otherwise only surface at runtime — tracer-dependent Python control
    flow, host syncs in hot loops, blocking queue calls without timeouts,
    nondeterminism under jit, donated-buffer reuse, numpy/jax.numpy mixing.
  * **jaxpr/HLO audit** (trace, never train): no host callbacks, retrace
    ≤ 1 per arg signature, donation consumed in compiled HLO, no silent
    f32→f64 promotion.

CLI: ``python -m repro.analysis [paths | --self] [--format json]``.
"""
from repro.analysis.jaxpr_audit import (AuditResult, AuditViolation,
                                        audit_fn, callback_eqns)
from repro.analysis.lint import (apply_baseline, check_file, check_paths,
                                 check_source, load_baseline, save_baseline)
from repro.analysis.rules import RULES, Finding, Rule
from repro.analysis.targets import (audit_all, audit_engine_tiers,
                                    audit_kernel_ops, audit_ocean_envs)

__all__ = [
    "AuditResult", "AuditViolation", "audit_fn", "callback_eqns",
    "apply_baseline", "check_file", "check_paths", "check_source",
    "load_baseline", "save_baseline", "RULES", "Finding", "Rule",
    "audit_all", "audit_engine_tiers", "audit_kernel_ops",
    "audit_ocean_envs",
]
