"""Repo-wide audit targets for ``analysis.jaxpr_audit``.

Enumerates the three surfaces whose compiled programs must stay clean —

  * every op in the kernel dispatch registry, traced under the canonical
    shapes the test suite sweeps (plus a second batch size for the retrace
    check),
  * all four TrainEngine tiers' device programs: the fused jit and
    shard_map launches (with ``donate_argnums=(0, 1)``, checked against the
    compiled HLO's input/output aliasing), and the pool/host tiers'
    ``learn`` / ``act`` / ``bootstrap`` functions on a real rollout
    trajectory,
  * every registered Ocean env's ``step`` under an emulated random action.

``audit_all()`` is what ``python -m repro.analysis --self`` and the CI
analysis lane run; each target returns an ``AuditResult`` whose violations
gate the build. Enumeration is registry-driven: registering a new kernel op
without adding canonical shapes here fails the audit loudly rather than
silently shrinking coverage.
"""
from __future__ import annotations

from functools import partial
from typing import Dict, List, Sequence

import jax
import jax.numpy as jnp

from repro.analysis.jaxpr_audit import AuditResult, AuditViolation, audit_fn


def _rand(key, shape, dtype=jnp.float32, scale=1.0):
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


# ---------------------------------------------------------------------------
# kernel ops

def _kernel_cases(mode: str) -> Dict[str, tuple]:
    """op name -> (fn, canonical args, variant args) under ``mode``.
    Shapes mirror tests/test_kernels.py's sweeps, scaled down."""
    from repro.kernels import ops
    k0 = jax.random.PRNGKey(0)
    k = lambda i: jax.random.fold_in(k0, i)

    def attn(B):
        return (_rand(k(1), (B, 32, 2, 16)), _rand(k(2), (B, 32, 2, 16)),
                _rand(k(3), (B, 32, 2, 16)))

    def decode(B):
        return (_rand(k(1), (B, 4, 16)), _rand(k(2), (B, 64, 2, 16)),
                _rand(k(3), (B, 64, 2, 16)), jnp.asarray(17, jnp.int32))

    def ssd(B):
        return (_rand(k(1), (B, 16, 1, 8), scale=0.5),
                jax.nn.softplus(_rand(k(2), (B, 16, 1))),
                -jnp.exp(_rand(k(3), (1,), scale=0.3)),
                _rand(k(4), (B, 16, 1, 8), scale=0.5),
                _rand(k(5), (B, 16, 1, 8), scale=0.5))

    def gae(B):
        return (_rand(k(1), (B, 32)), _rand(k(2), (B, 32)),
                jax.random.bernoulli(k(3), 0.1, (B, 32)),
                _rand(k(4), (B,)), 0.99, 0.95)

    def quant(M):
        wq = jax.random.randint(k(2), (32, 32), -127, 128,
                                jnp.int32).astype(jnp.int8)
        return (_rand(k(1), (M, 32)), wq,
                jnp.abs(_rand(k(3), (32,))) * 0.02)

    def pack(B):
        return ([jax.random.randint(k(i), (B, n), 0, 256,
                                    jnp.int32).astype(jnp.uint8)
                 for i, n in enumerate((3, 7))],)

    return {
        "flash_attention": (partial(ops.flash_attention, causal=True,
                                    mode=mode), attn(1), attn(2)),
        "flash_decode": (partial(ops.flash_decode, mode=mode),
                         decode(2), decode(1)),
        "ssd": (partial(ops.ssd, chunk=4, mode=mode), ssd(1), ssd(2)),
        "gae": (partial(ops.gae, mode=mode), gae(4), gae(2)),
        "quant_matmul": (partial(ops.quant_matmul, mode=mode),
                         quant(16), quant(8)),
        "pack": (partial(ops.pack, mode=mode), pack(4), pack(2)),
    }


def audit_kernel_ops(mode: str = "ref") -> List[AuditResult]:
    """Audit every op in the dispatch registry. A registered op with no
    canonical case here is itself a violation (coverage must not silently
    shrink)."""
    from repro.kernels import dispatch
    cases = _kernel_cases(mode)
    out: List[AuditResult] = []
    for op in sorted(dispatch.ops()):
        name = f"kernel:{op}[{mode}]"
        if op not in cases:
            r = AuditResult(target=name)
            r.violations.append(AuditViolation(
                "coverage", name,
                f"op '{op}' is registered in kernels.dispatch but has no "
                f"canonical audit shapes in analysis.targets — add a case "
                f"so the audit keeps covering every registered op"))
            out.append(r)
            continue
        fn, args, variant = cases[op]
        out.append(audit_fn(fn, args, name=name, variants=[variant]))
    return out


# ---------------------------------------------------------------------------
# engine tiers

def _engine_fixture(backend: str, recurrent: bool = False):
    from repro.configs.base import TrainConfig
    from repro.core.emulation import Emulated
    from repro.envs.ocean import Bandit
    from repro.models.policy import OceanPolicy
    from repro.rl.distributions import Dist
    from repro.rl.engine import TrainEngine

    em = Emulated(Bandit())
    dist = Dist("categorical", nvec=em.act_spec.nvec)
    pol = OceanPolicy(em.obs_spec.total, dist.nvec, hidden=16,
                      recurrent=recurrent, num_outputs=dist.num_outputs)
    tcfg = TrainConfig(num_envs=8, unroll_length=8, update_epochs=1,
                       num_minibatches=2, learning_rate=1e-3)
    eng = TrainEngine(em, pol, tcfg, dist, key=jax.random.PRNGKey(0),
                      backend=backend, kernel_mode="ref")
    return eng, em, pol, dist, tcfg


def _host_trajectory(em, pol, dist, tcfg, params, recurrent: bool):
    """A real rollout trajectory for auditing the pool/host learn fn."""
    from repro.core.vector import VecEnv
    from repro.rl.rollout import RolloutCarry, rollout

    key = jax.random.PRNGKey(1)
    vec = VecEnv(em, tcfg.num_envs)
    env_state, obs = vec.init(jax.random.fold_in(key, 0))
    B = vec.batch_size
    rc = RolloutCarry(env_state, obs, pol.initial_carry(B),
                      jnp.zeros((B,), jnp.bool_))
    _, traj, last_value = rollout(pol, params, vec.step_fn(), rc,
                                  jax.random.fold_in(key, 1),
                                  tcfg.unroll_length, dist)
    return traj, last_value, obs, pol.initial_carry(B)


def audit_engine_tiers() -> List[AuditResult]:
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    from repro.rl.engine import _scan_launch
    from repro.rl.learner import make_ocean_learn

    out: List[AuditResult] = []
    key = jax.random.PRNGKey(2)

    # jit tier: the fused K-update launch, state buffers donated
    eng, em, pol, dist, tcfg = _engine_fixture("jit")
    out.append(audit_fn(_scan_launch(eng._update, 2),
                        (eng.ts, eng.rc, key), name="engine:jit:launch",
                        donate_argnums=(0, 1)))

    # shard_map tier: same launch through the mesh wrapper (1-device CPU
    # mesh in CI; the program structure — collectives, specs — is identical)
    sm, *_ = _engine_fixture("shard_map")
    fn = shard_map(_scan_launch(sm._update, 1), mesh=sm.mesh,
                   in_specs=(P(), sm._rc_spec, P()),
                   out_specs=(P(), sm._rc_spec, P()), check_rep=False)
    out.append(audit_fn(fn, (sm.ts, sm.rc, key),
                        name="engine:shard_map:launch",
                        donate_argnums=(0, 1)))

    # pool tier: learn on a real trajectory + act + bootstrap (the three
    # device programs _run_pool dispatches)
    traj, last_value, obs, carry0 = _host_trajectory(
        em, pol, dist, tcfg, eng.ts.params, recurrent=False)
    learn = make_ocean_learn(pol, tcfg, dist, kernel_mode="ref")
    out.append(audit_fn(learn, (eng.ts, carry0, traj, last_value, key),
                        name="engine:pool:learn"))
    B = tcfg.num_envs
    reset = jnp.zeros((B,), jnp.bool_)
    out.append(audit_fn(eng._make_act(),
                        (eng.ts.params, obs, carry0, reset, key),
                        name="engine:pool:act"))
    out.append(audit_fn(eng._make_bootstrap(),
                        (eng.ts.params, obs, carry0, reset),
                        name="engine:pool:bootstrap"))

    # host tier: same learn/act pair but through the recurrent path the
    # bridged first-finisher loop exercises (carry is a live pytree)
    enr, emr, polr, distr, tcfgr = _engine_fixture("jit", recurrent=True)
    traj, last_value, obs, carry0 = _host_trajectory(
        emr, polr, distr, tcfgr, enr.ts.params, recurrent=True)
    learn = make_ocean_learn(polr, tcfgr, distr, kernel_mode="ref")
    out.append(audit_fn(learn, (enr.ts, carry0, traj, last_value, key),
                        name="engine:host:learn"))
    reset = jnp.zeros((tcfgr.num_envs,), jnp.bool_)
    out.append(audit_fn(enr._make_act(),
                        (enr.ts.params, obs, carry0, reset, key),
                        name="engine:host:act"))
    return out


# ---------------------------------------------------------------------------
# Ocean envs

def audit_ocean_envs(names: Sequence[str] = ()) -> List[AuditResult]:
    from repro.core import spaces as sp
    from repro.envs.ocean import OCEAN, make

    out: List[AuditResult] = []
    for name in (names or sorted(OCEAN)):
        env = make(name)
        key = jax.random.PRNGKey(3)
        s = env.init(jax.random.fold_in(key, 0))
        s, _obs = env.reset(s, jax.random.fold_in(key, 1))
        a = sp.sample(env.action_space, jax.random.fold_in(key, 2))
        if env.num_agents > 1:           # agent-major action rows
            a = jax.tree.map(
                lambda x: jnp.stack([x] * env.num_agents), a)
        out.append(audit_fn(env.step, (s, a, jax.random.fold_in(key, 3)),
                            name=f"env:{name}"))
    return out


def audit_all(include: Sequence[str] = ("kernels", "engine", "envs")
              ) -> List[AuditResult]:
    out: List[AuditResult] = []
    if "kernels" in include:
        out.extend(audit_kernel_ops())
    if "engine" in include:
        out.extend(audit_engine_tiers())
    if "envs" in include:
        out.extend(audit_ocean_envs())
    return out
