"""CLI for repro.analysis.

    python -m repro.analysis path/to/env.py other_dir/   # lint your code
    python -m repro.analysis --self                      # gate this repo:
                                                         # self-lint + audit
    python -m repro.analysis tests/ --report-only        # never fails CI
    python -m repro.analysis --self --update-baseline    # regenerate the
                                                         # grandfather file

Exit status: 0 when no non-baselined lint findings and no audit violations;
1 otherwise (``--report-only`` always exits 0).
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

from repro.analysis import lint
from repro.analysis.rules import RULES

SELF_BASELINE = Path(__file__).resolve().parent / "self_baseline.json"
_REPO_SRC = Path(__file__).resolve().parents[2]   # .../src


def _self_paths():
    root = _REPO_SRC.parent
    paths = [_REPO_SRC / "repro"]
    for extra in ("benchmarks",):
        p = root / extra
        if p.is_dir():
            paths.append(p)
    return paths


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="JAX-aware static checks: AST lint + jaxpr/HLO audit")
    ap.add_argument("paths", nargs="*", help="files or directories to lint")
    ap.add_argument("--self", action="store_true", dest="self_check",
                    help="gate this repo: lint src/repro (+benchmarks) "
                         "against the committed baseline and run the full "
                         "jaxpr/HLO audit (kernels, engine tiers, envs)")
    ap.add_argument("--format", choices=("text", "json"), default="text")
    ap.add_argument("--baseline", default=None,
                    help="baseline JSON of grandfathered findings")
    ap.add_argument("--update-baseline", action="store_true",
                    help="write current findings to the baseline and exit 0")
    ap.add_argument("--report-only", action="store_true",
                    help="print findings but always exit 0")
    ap.add_argument("--rules", default=None,
                    help="comma-separated rule IDs to run (default: all)")
    ap.add_argument("--no-audit", action="store_true",
                    help="with --self: skip the jaxpr/HLO audit layer")
    ap.add_argument("--out", default=None,
                    help="also write the JSON report to this file")
    args = ap.parse_args(argv)

    if not args.self_check and not args.paths:
        ap.error("give paths to lint, or --self")
    paths = _self_paths() if args.self_check else args.paths
    baseline = args.baseline or (str(SELF_BASELINE) if args.self_check
                                 else None)
    rules = ([r.strip().upper() for r in args.rules.split(",")]
             if args.rules else None)

    all_findings = []
    for f in lint.iter_python_files(paths):
        all_findings.extend(lint.check_file(f, rules=rules))

    if args.update_baseline:
        target = baseline or "analysis_baseline.json"
        lint.save_baseline(all_findings, target)
        print(f"baseline: {len(all_findings)} finding(s) -> {target}")
        return 0

    fresh = lint.apply_baseline(all_findings, lint.load_baseline(baseline))
    grandfathered = len(all_findings) - len(fresh)

    audits = []
    if args.self_check and not args.no_audit:
        from repro.analysis.targets import audit_all
        audits = audit_all()
    violations = [v for a in audits for v in a.violations]

    report = {
        "findings": [f.to_dict() for f in fresh],
        "grandfathered": grandfathered,
        "audit": {
            "targets": len(audits),
            "passed": sum(a.ok for a in audits),
            "violations": [v.to_dict() for v in violations],
        },
        "rules": {rid: r.summary for rid, r in RULES.items()},
    }
    if args.out:
        Path(args.out).write_text(json.dumps(report, indent=2) + "\n")

    if args.format == "json":
        print(json.dumps(report, indent=2))
    else:
        for f in fresh:
            print(f.render())
        for v in violations:
            print(v.render())
        bits = [f"{len(fresh)} finding(s)"]
        if grandfathered:
            bits.append(f"{grandfathered} baselined")
        if audits:
            bits.append(f"audit {sum(a.ok for a in audits)}/{len(audits)} "
                        f"targets clean")
        print("repro.analysis: " + ", ".join(bits))

    if args.report_only:
        return 0
    return 1 if (fresh or violations) else 0


if __name__ == "__main__":
    sys.exit(main())
