"""Host-environment pool — the paper's Python EnvPool, faithfully.

The JAX-native pool (core/pool.py) covers pure-functional envs. Real
deployments also wrap *host* environments (NetHack, Pokémon Red — stateful
Python/C processes). This module reproduces the paper's mechanism for those:
simulate M envs on worker threads, return batches of N ≪ M from the **first
finishers**, so the learner never waits on stragglers and env stepping
overlaps policy compute. M = 2N ⇒ double buffering (paper §3.3).

(Threads, not processes: env steps that block in C/sleep release the GIL,
which is also how NLE/Atari steps behave. The paper's shared-memory and
busy-wait micro-optimizations are process-world trivia — see DESIGN.md §2.)

Protocol guarantees (what the bridge/engine layers above rely on):

  * autoreset — a worker resets its env in-thread on ``done``; the batch row
    carries the *terminal* step's reward/done/info and the *next* episode's
    first observation, exactly like the JAX ``VecEnv`` autoreset path.
  * seeding — episode ``e`` of env ``i`` resets with ``seed + i + M * e``, a
    deterministic per-env seed sequence (the old ``env.reset(None)`` made
    every post-crash episode nondeterministic).
  * terminal info — ``recv`` surfaces fixed-shape episode stats
    (``score`` / ``episode_return`` / ``episode_length`` / ``valid`` with
    ``valid == done``) accumulated per env, matching ``envs/base.empty_info``.
  * crash propagation — an exception in ``reset``/``step`` is forwarded as a
    ``HostEnvError`` raised from ``recv()`` (naming the env), never a
    silently dead thread with ``recv()`` blocked forever; ``recv(timeout=)``
    additionally bounds the wait on healthy-but-slow workers.
"""
from __future__ import annotations

import queue
import threading
import time
from typing import Callable, List, Sequence

import numpy as np


class HostEnv:
    """Stateful host env: numpy in/out. Subclass or duck-type."""

    def reset(self, seed: int):                 # -> obs
        raise NotImplementedError

    def step(self, action):                     # -> (obs, rew, done, info)
        raise NotImplementedError


class HostEnvError(RuntimeError):
    """A worker env raised; re-raised on the consumer thread by ``recv``."""

    def __init__(self, env_index: int, op: str, cause: BaseException):
        super().__init__(
            f"host env {env_index} raised in {op}: "
            f"{type(cause).__name__}: {cause}")
        self.env_index = env_index
        self.op = op


class _WorkerFailure:
    """Ready-queue sentinel carrying a worker exception to recv()."""

    def __init__(self, env_index: int, op: str, exc: BaseException):
        self.env_index, self.op, self.exc = env_index, op, exc


# "no timeout argument given" marker: distinguishes recv() (use the pool's
# default) from recv(timeout=None) (explicitly wait forever)
_UNSET = object()


class HostPool:
    """EnvPool semantics over host envs.

    recv()  -> (obs (N, …), rew (N, …), done (N,), info, env_ids (N,))
    send(actions, env_ids)

    ``info`` is a dict of per-env arrays — ``score`` (f32), ``episode_return``
    (f32), ``episode_length`` (i32), ``valid`` (bool) — nonzero exactly on the
    rows whose episode ended this step (``valid == done``). ``score`` is taken
    from the env's terminal step info dict (key ``"score"``) when present.

    Batch rows are sorted by env index, so with num_envs == batch_size the
    pool degrades to *deterministic* synchronous vectorization (wait for
    everyone, rows always 0..M-1) — the paper's baseline.
    """

    def __init__(self, env_fns: Sequence[Callable[[], HostEnv]],
                 batch_size: int, seed: int = 0,
                 recv_timeout: float = None):
        self.M = len(env_fns)
        self.N = batch_size
        assert 1 <= self.N <= self.M
        self.seed = seed
        self.recv_timeout = recv_timeout
        self._envs: List[HostEnv] = [fn() for fn in env_fns]
        self._ready: "queue.Queue" = queue.Queue()
        self._threads: List[threading.Thread] = []
        self._inboxes: List["queue.Queue"] = [queue.Queue(1)
                                              for _ in range(self.M)]
        self._stop = False
        self._closed = False
        # episode-stat accumulators (touched only by the recv thread; every
        # ready item passes through recv exactly once, in per-env order)
        self._ep_return = np.zeros((self.M,), np.float64)
        self._ep_length = np.zeros((self.M,), np.int64)
        for i, env in enumerate(self._envs):
            t = threading.Thread(target=self._worker, args=(i,), daemon=True)
            t.start()
            self._threads.append(t)
        for i in range(self.M):                 # initial resets (episode 0)
            self._inboxes[i].put(("reset", seed + i))

    def _worker(self, i: int):
        env = self._envs[i]
        episode = 0
        op = "reset"
        try:
            while not self._stop:
                try:
                    # poll, don't park: an untimed get() here kept the
                    # worker alive forever when the close sentinel was
                    # dropped (full inbox) — _stop must win on its own
                    cmd, arg = self._inboxes[i].get(timeout=0.05)
                except queue.Empty:
                    continue
                if cmd == "close" or self._stop:
                    return
                if cmd == "reset":
                    op = "reset"
                    obs = env.reset(arg)
                    self._ready.put((i, obs, 0.0, False, None, False))
                else:
                    op = "step"
                    obs, rew, done, info = env.step(arg)
                    if done:
                        # deterministic per-env seed sequence: episode e of
                        # env i resets with seed + i + M*e
                        episode += 1
                        op = "reset"
                        obs = env.reset(self.seed + i + self.M * episode)
                    self._ready.put((i, obs, rew, done, info, True))
        except Exception as e:   # noqa: BLE001 — forwarded, never swallowed
            self._ready.put(_WorkerFailure(i, op, e))

    def recv(self, timeout: float = _UNSET):
        """Block until the N first-finished envs have observations.

        Raises ``HostEnvError`` if any of those envs crashed, and
        ``TimeoutError`` if fewer than N envs produce a result within
        ``timeout`` seconds. Defaults to the pool's ``recv_timeout``
        (constructor arg); pass ``timeout=None`` to explicitly opt into
        waiting forever."""
        if timeout is _UNSET:
            timeout = self.recv_timeout
        deadline = None if timeout is None else time.monotonic() + timeout
        items = []
        for _ in range(self.N):
            try:
                if deadline is None:
                    # explicit timeout=None is a deliberate wait-forever
                    it = self._ready.get()  # repro: noqa[BLOCKING-NO-TIMEOUT]
                else:
                    remaining = deadline - time.monotonic()
                    if remaining <= 0:
                        raise queue.Empty
                    it = self._ready.get(timeout=remaining)
            except queue.Empty:
                raise TimeoutError(
                    f"HostPool.recv timed out after {timeout}s with "
                    f"{len(items)}/{self.N} envs ready (slow or deadlocked "
                    f"worker?)") from None
            if isinstance(it, _WorkerFailure):
                raise HostEnvError(it.env_index, it.op, it.exc) from it.exc
            items.append(it)
        items.sort(key=lambda it: it[0])        # deterministic row layout
        ids = np.asarray([it[0] for it in items])
        obs = np.stack([np.asarray(it[1]) for it in items])
        # initial-reset rows carry scalar 0.0 rewards; broadcast them to the
        # step-reward shape (per-agent vectors for multi-agent envs)
        rews = [np.asarray(it[2], np.float32) for it in items]
        shp = max((r.shape for r in rews), default=())
        rew = np.stack([np.broadcast_to(r, shp) for r in rews])
        done = np.asarray([it[3] for it in items], bool)
        info = self._episode_stats(items)
        return obs, rew, done, info, ids

    def _episode_stats(self, items) -> dict:
        """Fold this batch into the per-env accumulators and emit the
        fixed-shape terminal-info rows (valid == done)."""
        n = len(items)
        score = np.zeros((n,), np.float32)
        ep_ret = np.zeros((n,), np.float32)
        ep_len = np.zeros((n,), np.int32)
        valid = np.zeros((n,), bool)
        for j, (i, _obs, rew, done, raw, is_step) in enumerate(items):
            if not is_step:
                continue                        # initial reset: not a step
            self._ep_return[i] += float(np.sum(rew))
            self._ep_length[i] += 1
            if done:
                valid[j] = True
                ep_ret[j] = self._ep_return[i]
                ep_len[j] = self._ep_length[i]
                if raw:
                    score[j] = float(raw.get("score", 0.0))
                self._ep_return[i] = 0.0
                self._ep_length[i] = 0
        return {"score": score, "episode_return": ep_ret,
                "episode_length": ep_len, "valid": valid}

    def send(self, actions, env_ids):
        for a, i in zip(np.asarray(actions), env_ids):
            self._inboxes[int(i)].put(("step", a))

    def close(self, timeout: float = 5.0):
        """Stop workers and join them. Drains each inbox before posting the
        close sentinel so a worker blocked in ``queue.get`` always receives
        it (the old ``put_nowait`` on a full Queue(1) was silently skipped,
        leaving the worker blocked forever)."""
        if self._closed:
            return
        self._closed = True
        self._stop = True
        for i in range(self.M):
            for _ in range(2):                  # drain, then post (bounded)
                try:
                    self._inboxes[i].put_nowait(("close", None))
                    break
                except queue.Full:
                    try:
                        self._inboxes[i].get_nowait()
                    except queue.Empty:
                        pass
        deadline = time.monotonic() + timeout
        for t in self._threads:
            t.join(timeout=max(0.0, deadline - time.monotonic()))
