"""Host-environment pool — the paper's Python EnvPool, faithfully.

The JAX-native pool (core/pool.py) covers pure-functional envs. Real
deployments also wrap *host* environments (NetHack, Pokémon Red — stateful
Python/C processes). This module reproduces the paper's mechanism for those:
simulate M envs on worker threads, return batches of N ≪ M from the **first
finishers**, so the learner never waits on stragglers and env stepping
overlaps policy compute. M = 2N ⇒ double buffering (paper §3.3).

(Threads, not processes: env steps that block in C/sleep release the GIL,
which is also how NLE/Atari steps behave. The paper's shared-memory and
busy-wait micro-optimizations are process-world trivia — see DESIGN.md §2.)
"""
from __future__ import annotations

import queue
import threading
from typing import Callable, List, Optional, Sequence

import numpy as np


class HostEnv:
    """Stateful host env: numpy in/out. Subclass or duck-type."""

    def reset(self, seed: int):                 # -> obs
        raise NotImplementedError

    def step(self, action):                     # -> (obs, rew, done, info)
        raise NotImplementedError


class HostPool:
    """EnvPool semantics over host envs.

    recv() -> (obs (N, …), rew (N,), done (N,), env_ids (N,))
    send(actions, env_ids)

    With num_envs == batch_size this degrades to synchronous vectorization
    (wait for everyone) — the paper's baseline.
    """

    def __init__(self, env_fns: Sequence[Callable[[], HostEnv]],
                 batch_size: int, seed: int = 0):
        self.M = len(env_fns)
        self.N = batch_size
        assert self.N <= self.M
        self._envs: List[HostEnv] = [fn() for fn in env_fns]
        self._ready: "queue.Queue" = queue.Queue()
        self._threads: List[threading.Thread] = []
        self._inboxes: List["queue.Queue"] = [queue.Queue(1)
                                              for _ in range(self.M)]
        self._stop = False
        for i, env in enumerate(self._envs):
            t = threading.Thread(target=self._worker, args=(i,), daemon=True)
            t.start()
            self._threads.append(t)
        for i in range(self.M):                 # initial resets
            self._inboxes[i].put(("reset", seed + i))

    def _worker(self, i: int):
        env = self._envs[i]
        while not self._stop:
            cmd, arg = self._inboxes[i].get()
            if cmd == "close":
                return
            if cmd == "reset":
                obs = env.reset(arg)
                self._ready.put((i, obs, 0.0, False))
            else:
                obs, rew, done, info = env.step(arg)
                if done:
                    obs = env.reset(None)
                self._ready.put((i, obs, rew, done))

    def recv(self):
        """Block until the N first-finished envs have observations."""
        items = [self._ready.get() for _ in range(self.N)]
        ids = np.asarray([it[0] for it in items])
        obs = np.stack([np.asarray(it[1]) for it in items])
        rew = np.asarray([it[2] for it in items], np.float32)
        done = np.asarray([it[3] for it in items], bool)
        return obs, rew, done, ids

    def send(self, actions, env_ids):
        for a, i in zip(np.asarray(actions), env_ids):
            self._inboxes[int(i)].put(("step", a))

    def close(self):
        self._stop = True
        for i in range(self.M):
            try:
                self._inboxes[i].put_nowait(("close", None))
            except queue.Full:
                pass
