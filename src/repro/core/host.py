"""Host-environment pool — the paper's Python EnvPool, faithfully.

The JAX-native pool (core/pool.py) covers pure-functional envs. Real
deployments also wrap *host* environments (NetHack, Pokémon Red — stateful
Python/C processes). This module reproduces the paper's mechanism for those:
simulate M envs on workers, return batches of N ≪ M from the **first
finishers**, so the learner never waits on stragglers and env stepping
overlaps policy compute. M = 2N ⇒ double buffering (paper §3.3).

Two execution backends share one protocol:

  * ``backend="thread"`` (default) — worker threads. Right when env steps
    block in C or sleep on I/O and therefore release the GIL (NLE/Atari-style
    steps); cheapest startup, picklability never matters.
  * ``backend="proc"`` — spawn worker processes over per-pool shared-memory
    slabs (``core/shm.py``) with busy-wait ready flags, the paper's
    multiprocessing design. Pure-Python stepping serializes on the GIL under
    threads; processes actually parallelize it. Measured on a multicore box
    (``benchmarks/bench_hostpool.py``, M=16 N=8, ~2 ms pure-Python step):
    proc sustains ≥2× the thread backend's async SPS, while staying within
    ~15% of it on GIL-releasing sleep envs (where threads are already
    optimal). On a single-core box the gap collapses — the benchmark records
    ``cores`` so numbers are comparable. Zero pickled bytes cross per step:
    workers read actions from and write observations into the slab rows.

Protocol guarantees (what the bridge/engine layers above rely on, identical
under both backends):

  * autoreset — a worker resets its env in-worker on ``done``; the batch row
    carries the *terminal* step's reward/done/info and the *next* episode's
    first observation, exactly like the JAX ``VecEnv`` autoreset path.
  * seeding — episode ``e`` of env ``i`` resets with ``seed + i + M * e``, a
    deterministic per-env seed sequence (the old ``env.reset(None)`` made
    every post-crash episode nondeterministic).
  * terminal info — ``recv`` surfaces fixed-shape episode stats
    (``score`` / ``episode_return`` / ``episode_length`` / ``valid`` with
    ``valid == done``) accumulated per env, matching ``envs/base.empty_info``.
  * crash propagation — an exception in ``reset``/``step`` is forwarded as a
    ``HostEnvError`` raised from ``recv()`` (naming the env and op), never a
    silently dead worker with ``recv()`` blocked forever; ``recv(timeout=)``
    additionally bounds the wait on healthy-but-slow workers, and ``send``
    refuses to queue onto a dead worker instead of deadlocking.
"""
from __future__ import annotations

import queue
import threading
import time
from typing import Callable, List, Sequence

import numpy as np

from repro.core import shm as _shm
from repro.telemetry import span as _span
from repro.telemetry import traceprop as _traceprop
from repro.telemetry.procstats import HOST_FIELDS, StatSlab


class HostEnv:
    """Stateful host env: numpy in/out. Subclass or duck-type."""

    def reset(self, seed: int):                 # -> obs
        raise NotImplementedError

    def step(self, action):                     # -> (obs, rew, done, info)
        raise NotImplementedError


class RemoteEnvError(RuntimeError):
    """A worker-process exception, reconstructed from its shm error row.

    The original traceback lives in the (dead) worker; ``str()`` carries the
    worker-side ``"ExcType: message"`` text."""


class HostEnvError(RuntimeError):
    """A worker env raised; re-raised on the consumer thread by ``recv``."""

    def __init__(self, env_index: int, op: str, cause: BaseException):
        # RemoteEnvError text already reads "ExcType: message" — don't
        # double-prefix it with its own class name
        detail = (str(cause) if isinstance(cause, RemoteEnvError)
                  else f"{type(cause).__name__}: {cause}")
        super().__init__(f"host env {env_index} raised in {op}: {detail}")
        self.env_index = env_index
        self.op = op


class _WorkerFailure:
    """Ready-queue sentinel carrying a worker exception to recv()."""

    def __init__(self, env_index: int, op: str, exc: BaseException):
        self.env_index, self.op, self.exc = env_index, op, exc


# "no timeout argument given" marker: distinguishes recv() (use the pool's
# default) from recv(timeout=None) (explicitly wait forever)
_UNSET = object()

# unlinked-but-unclosable segments (a view was pinned by a caller-held
# traceback at close time); held so their finalizer never retries close
_LEAKED_SEGS: list = []


class HostPool:
    """EnvPool semantics over host envs.

    recv()  -> (obs (N, …), rew (N, …), done (N,), info, env_ids (N,))
    send(actions, env_ids)

    ``info`` is a dict of per-env arrays — ``score`` (f32), ``episode_return``
    (f32), ``episode_length`` (i32), ``valid`` (bool) — nonzero exactly on the
    rows whose episode ended this step (``valid == done``). ``score`` is taken
    from the env's terminal step info dict (key ``"score"``) when present.

    Batch rows are sorted by env index, so with num_envs == batch_size the
    pool degrades to *deterministic* synchronous vectorization (wait for
    everyone, rows always 0..M-1) — the paper's baseline.

    ``backend="proc"`` dispatches construction to :class:`ProcHostPool`
    (same API; requires a picklable ``env_fns`` and a ``slab`` row spec).
    ``rew_shape`` is the per-env reward row shape — ``()`` scalar,
    ``(num_agents,)`` multi-agent; when omitted it is inferred from the
    widest-rank reward seen in a batch (rank, not lexicographic order).
    """

    def __init__(self, env_fns: Sequence[Callable[[], HostEnv]],
                 batch_size: int, seed: int = 0,
                 recv_timeout: float = None, *, backend: str = "thread",
                 rew_shape: tuple = None, slab: "_shm.SlabSpec" = None,
                 spin: "_shm.SpinConfig" = None):
        assert backend == "thread", backend     # "proc" dispatched by __new__
        self.M = len(env_fns)
        self.N = batch_size
        assert 1 <= self.N <= self.M
        self.seed = seed
        self.recv_timeout = recv_timeout
        self.rew_shape = None if rew_shape is None else tuple(rew_shape)
        self._envs: List[HostEnv] = [fn() for fn in env_fns]
        self._ready: "queue.Queue" = queue.Queue()
        self._threads: List[threading.Thread] = []
        self._inboxes: List["queue.Queue"] = [queue.Queue(1)
                                              for _ in range(self.M)]
        self._stop = False
        self._closed = False
        # episode-stat accumulators (touched only by the recv thread; every
        # ready item passes through recv exactly once, in per-env order)
        self._ep_return = np.zeros((self.M,), np.float64)
        self._ep_length = np.zeros((self.M,), np.int64)
        self._stat_steps = 0
        self._stat_episodes = 0
        self._stat_recvs = 0
        # wall-clock liveness beats, one per worker (written by the worker
        # thread, read by liveness()/healthz — int64 stores are atomic)
        self._beat_ns = np.zeros((self.M,), np.int64)
        for i, env in enumerate(self._envs):
            t = threading.Thread(target=self._worker, args=(i,), daemon=True)
            t.start()
            self._threads.append(t)
        for i in range(self.M):                 # initial resets (episode 0)
            self._inboxes[i].put(("reset", seed + i))

    def __new__(cls, env_fns=None, batch_size=None, seed=0,
                recv_timeout=None, *, backend="thread", **kw):
        # Backend dispatch at the public constructor: HostPool(...,
        # backend="proc") builds a ProcHostPool (type.__call__ then runs
        # type(obj).__init__, i.e. ProcHostPool.__init__, with these args).
        if cls is HostPool and backend == "proc":
            return super().__new__(ProcHostPool)
        return super().__new__(cls)

    def _worker(self, i: int):
        env = self._envs[i]
        episode = 0
        op = "reset"
        try:
            while not self._stop:
                self._beat_ns[i] = time.time_ns()
                try:
                    # poll, don't park: an untimed get() here kept the
                    # worker alive forever when the close sentinel was
                    # dropped (full inbox) — _stop must win on its own
                    cmd, arg = self._inboxes[i].get(timeout=0.05)
                except queue.Empty:
                    continue
                if cmd == "close" or self._stop:
                    return
                if cmd == "reset":
                    op = "reset"
                    obs = env.reset(arg)
                    self._ready.put((i, obs, 0.0, False, None, False))
                else:
                    op = "step"
                    obs, rew, done, info = env.step(arg)
                    if done:
                        # deterministic per-env seed sequence: episode e of
                        # env i resets with seed + i + M*e
                        episode += 1
                        op = "reset"
                        obs = env.reset(self.seed + i + self.M * episode)
                    self._ready.put((i, obs, rew, done, info, True))
        except Exception as e:   # noqa: BLE001 — forwarded, never swallowed
            self._ready.put(_WorkerFailure(i, op, e))

    def recv(self, timeout: float = _UNSET):
        """Block until the N first-finished envs have observations.

        Raises ``HostEnvError`` if any of those envs crashed, and
        ``TimeoutError`` if fewer than N envs produce a result within
        ``timeout`` seconds. Defaults to the pool's ``recv_timeout``
        (constructor arg); pass ``timeout=None`` to explicitly opt into
        waiting forever."""
        if timeout is _UNSET:
            timeout = self.recv_timeout
        deadline = None if timeout is None else time.monotonic() + timeout
        items = []
        with _span("host.recv"):
            for _ in range(self.N):
                try:
                    if deadline is None:
                        # explicit timeout=None: a deliberate wait-forever
                        it = self._ready.get()  # repro: noqa[BLOCKING-NO-TIMEOUT]
                    else:
                        remaining = deadline - time.monotonic()
                        if remaining <= 0:
                            raise queue.Empty
                        it = self._ready.get(timeout=remaining)
                except queue.Empty:
                    raise TimeoutError(
                        f"HostPool.recv timed out after {timeout}s with "
                        f"{len(items)}/{self.N} envs ready (slow or "
                        f"deadlocked worker?)") from None
                if isinstance(it, _WorkerFailure):
                    raise HostEnvError(it.env_index, it.op,
                                       it.exc) from it.exc
                items.append(it)
        return self._assemble(items)

    def _assemble(self, items):
        """Batch (i, obs, rew, done, raw_info, is_step) items — shared by
        both backends so row layout/dtypes/info stay bitwise-identical."""
        items.sort(key=lambda it: it[0])        # deterministic row layout
        ids = np.asarray([it[0] for it in items])
        obs = np.stack([np.asarray(it[1]) for it in items])
        # initial-reset rows carry scalar 0.0 rewards; broadcast them to the
        # step-reward shape (per-agent vectors for multi-agent envs)
        rews = [np.asarray(it[2], np.float32) for it in items]
        shp = self.rew_shape
        if shp is None:
            # fall back to the widest-RANK reward in the batch. (A plain
            # max() over shapes compares lexicographically — between (2,)
            # and (10,) it picks (2,) and the stack breaks for mixed-rank
            # batches; the pool's declared rew_shape is authoritative.)
            shp = max((r.shape for r in rews), key=len, default=())
        rew = np.stack([np.broadcast_to(r, shp) for r in rews])
        done = np.asarray([it[3] for it in items], bool)
        info = self._episode_stats(items)
        return obs, rew, done, info, ids

    def _episode_stats(self, items) -> dict:
        """Fold this batch into the per-env accumulators and emit the
        fixed-shape terminal-info rows (valid == done)."""
        n = len(items)
        self._stat_recvs += 1
        score = np.zeros((n,), np.float32)
        ep_ret = np.zeros((n,), np.float32)
        ep_len = np.zeros((n,), np.int32)
        valid = np.zeros((n,), bool)
        for j, (i, _obs, rew, done, raw, is_step) in enumerate(items):
            if not is_step:
                continue                        # initial reset: not a step
            self._ep_return[i] += float(np.sum(rew))
            self._ep_length[i] += 1
            self._stat_steps += 1
            if done:
                self._stat_episodes += 1
                valid[j] = True
                ep_ret[j] = self._ep_return[i]
                ep_len[j] = self._ep_length[i]
                if raw:
                    score[j] = float(raw.get("score", 0.0))
                self._ep_return[i] = 0.0
                self._ep_length[i] = 0
        return {"score": score, "episode_return": ep_ret,
                "episode_length": ep_len, "valid": valid}

    def send(self, actions, env_ids):
        """Queue one step per env. Bounded: an unbounded ``put`` on the
        size-1 inbox of a worker that died mid-step blocked forever; now the
        put re-checks worker liveness and raises ``HostEnvError`` instead."""
        with _span("host.send"):
            for a, i in zip(np.asarray(actions), env_ids):
                i = int(i)
                while True:
                    try:
                        self._inboxes[i].put(("step", a), timeout=0.05)
                        break
                    except queue.Full:
                        if self._stop:
                            return              # pool is closing; drop
                        if not self._threads[i].is_alive():
                            raise HostEnvError(i, "send", RuntimeError(
                                "worker thread is dead and its inbox is "
                                "full; command undeliverable")) from None

    def liveness(self) -> dict:
        """Per-worker liveness for /healthz: wall-clock beats (ns) plus the
        set of workers known dead. ``last_beat_ns == 0`` means "not booted
        yet" — the consumer treats that as booting, not dead."""
        dead = [] if self._stop else [
            i for i, t in enumerate(self._threads) if not t.is_alive()]
        return {"now_ns": time.time_ns(), "workers": self.M,
                "last_beat_ns": [int(b) for b in self._beat_ns],
                "dead": dead}

    def stats(self) -> dict:
        """Parent-side pool counters (both backends; the proc backend adds
        the per-worker shared-memory stat rows on top)."""
        return {"backend": "thread", "workers": self.M,
                "steps": int(self._stat_steps),
                "episodes": int(self._stat_episodes),
                "recv_batches": int(self._stat_recvs),
                "liveness": self.liveness()}

    def close(self, timeout: float = 5.0):
        """Stop workers and join them. Drains each inbox before posting the
        close sentinel so a worker blocked in ``queue.get`` always receives
        it (the old ``put_nowait`` on a full Queue(1) was silently skipped,
        leaving the worker blocked forever)."""
        if self._closed:
            return
        self._closed = True
        self._stop = True
        for i in range(self.M):
            for _ in range(2):                  # drain, then post (bounded)
                try:
                    self._inboxes[i].put_nowait(("close", None))
                    break
                except queue.Full:
                    try:
                        self._inboxes[i].get_nowait()
                    except queue.Empty:
                        pass
        deadline = time.monotonic() + timeout
        for t in self._threads:
            t.join(timeout=max(0.0, deadline - time.monotonic()))


class ProcHostPool(HostPool):
    """``backend="proc"``: spawn worker processes + shared-memory slabs.

    Each env gets a row in one per-pool ``SharedMemory`` segment (layout:
    ``core/shm.SlabLayout``). The parent writes actions/seeds into the rows
    and flips the env's ctrl byte to CMD_*; the worker steps the env
    in-process, writes obs/rew/done/episode-stat fields back into the rows
    and flips the byte to READY. Both sides wait on the byte with the
    spin → sched_yield → sleep ladder; nothing is pickled after startup.

    Requirements beyond the thread backend: ``env_fns`` must pickle (spawn
    context — module-level classes / ``functools.partial``; see
    ``shm.dumps_env_fn``) and ``slab`` (a ``shm.SlabSpec``) must describe
    the per-env obs/action/reward rows. Harvested-but-undelivered results
    are buffered FIFO across ``recv`` calls, which also keeps first-finisher
    batches fair (a pure index scan would starve high-index envs).
    """

    def __init__(self, env_fns: Sequence[Callable[[], HostEnv]],
                 batch_size: int, seed: int = 0,
                 recv_timeout: float = None, *, backend: str = "proc",
                 rew_shape: tuple = None, slab: "_shm.SlabSpec" = None,
                 spin: "_shm.SpinConfig" = None):
        assert backend == "proc", backend
        if slab is None:
            raise ValueError(
                "backend='proc' needs slab=shm.SlabSpec(obs_shape, "
                "act_shape, ...) to size the shared-memory rows")
        self.M = len(env_fns)
        self.N = batch_size
        assert 1 <= self.N <= self.M
        self.seed = seed
        self.recv_timeout = recv_timeout
        self.slab = slab
        self.spin = spin or _shm.default_spin(workers=self.M)
        self.rew_shape = (tuple(slab.rew_shape) if rew_shape is None
                          else tuple(rew_shape))
        self._closed = False
        self._ep_return = np.zeros((self.M,), np.float64)
        self._ep_length = np.zeros((self.M,), np.int64)
        self._stat_steps = 0
        self._stat_episodes = 0
        self._stat_recvs = 0
        payloads = [_shm.dumps_env_fn(fn) for fn in env_fns]  # fail fast
        self._layout = _shm.SlabLayout(slab, self.M)
        from multiprocessing import get_context, shared_memory
        self._seg = shared_memory.SharedMemory(
            create=True, size=self._layout.nbytes)
        self._v = self._layout.views(self._seg.buf)
        self._v["ctrl"][:] = _shm.IDLE
        self._v["stop"][0] = 0
        # initial resets (episode 0): command rows first, then spawn
        self._v["seed"][:] = seed + np.arange(self.M, dtype=np.int64)
        self._v["ctrl"][:] = _shm.CMD_RESET
        self._out = set(range(self.M))          # env ids with commands queued
        self._fifo: List[tuple] = []            # harvested, undelivered items
        # per-worker telemetry rows: workers write lock-free into their own
        # row of a second (tiny) segment; the parent aggregates with one
        # vectorized sum and zero pickling (telemetry.procstats)
        self._stats_slab = StatSlab.create(self.M, HOST_FIELDS)
        ctx = get_context("spawn")              # never fork: jax-in-parent
        self._procs = []
        # cross-process trace propagation: when the parent has tracing on
        # with a run dir, ship a TraceConfig so each worker flushes its own
        # spans-<pid>.jsonl into the same run (None otherwise — free)
        trace_cfg = _traceprop.current()
        with _span("host.spawn"):
            for i in range(self.M):
                cfg = _shm.WorkerConfig(
                    shm_name=self._seg.name, index=i, M=self.M, seed=seed,
                    spec=slab, spin=self.spin, payload=payloads[i],
                    stats=self._stats_slab.spec, trace=trace_cfg)
                p = ctx.Process(target=_shm.worker_main, args=(cfg,),
                                daemon=True)
                p.start()
                self._procs.append(p)

    # -- harvesting ---------------------------------------------------------

    def _raise_error(self, i: int):
        op, msg = _shm.read_error(self._v, i)
        err = RemoteEnvError(msg)
        raise HostEnvError(i, op, err) from err

    def _harvest_ready(self) -> bool:
        """Copy every READY env's rows into the FIFO; raise on ERROR.

        No slab view may live in a local when an exception leaves this
        frame — the traceback would pin the numpy buffer export and
        ``close()``'s ``seg.close()`` would hit BufferError. Views stay
        inside ``self._v`` (released by close) and raising is deferred
        until the loop locals are dropped."""
        got = False
        err_i = -1
        v = self._v
        for i in range(self.M):
            st = int(v["ctrl"][i])
            if st == _shm.ERROR:
                err_i = i
                break
            if st != _shm.READY:
                continue
            item = (i,
                    v["obs"][i].copy(),
                    v["rew"][i].copy(),
                    bool(v["done"][i]),
                    {"score": float(v["score"][i])} if v["meta"][i, 1]
                    else None,
                    bool(v["meta"][i, 0]))
            v["ctrl"][i] = _shm.IDLE            # row copied; slot reusable
            self._out.discard(i)
            self._fifo.append(item)
            got = True
        del v
        if err_i >= 0:
            self._out.discard(err_i)
            self._raise_error(err_i)
        return got

    def _check_liveness(self):
        for i in sorted(self._out):
            st = int(self._v["ctrl"][i])
            if st in (_shm.READY, _shm.ERROR):
                continue                        # result landed; not stuck
            p = self._procs[i]
            if not p.is_alive():
                self._out.discard(i)
                err = RemoteEnvError(
                    f"worker process died without reporting (exitcode "
                    f"{p.exitcode})")
                raise HostEnvError(i, "step", err) from err

    def recv(self, timeout: float = _UNSET):
        """First-finisher batch of N envs (FIFO over harvested results).

        Same contract as the thread backend: ``HostEnvError`` on env crash
        (including a worker process dying without reporting), ``TimeoutError``
        when fewer than N envs finish in ``timeout`` seconds."""
        if timeout is _UNSET:
            timeout = self.recv_timeout
        deadline = None if timeout is None else time.monotonic() + timeout
        wait = _shm.SpinWait(self.spin)
        with _span("host.recv"):
            while len(self._fifo) < self.N:
                if self._harvest_ready():
                    wait.reset()
                    continue
                self._check_liveness()
                if deadline is not None and time.monotonic() > deadline:
                    raise TimeoutError(
                        f"HostPool.recv timed out after {timeout}s with "
                        f"{len(self._fifo)}/{self.N} envs ready (slow or "
                        f"deadlocked worker?)")
                wait.pause()
            items = self._fifo[:self.N]
            del self._fifo[:self.N]
        return self._assemble(items)

    def send(self, actions, env_ids):
        """Write action rows and flip ctrl to CMD_STEP. Refuses (with
        ``HostEnvError``) to command a dead or errored worker — the proc
        analogue of the bounded-put liveness check."""
        acts = np.asarray(actions)
        with _span("host.send"):
            self._send_rows(acts, env_ids)

    def _send_rows(self, acts, env_ids):
        for a, i in zip(acts, env_ids):
            i = int(i)
            st = int(self._v["ctrl"][i])        # no view locals: see harvest
            if st == _shm.ERROR:
                self._out.discard(i)
                self._raise_error(i)
            if not self._procs[i].is_alive():
                err = RemoteEnvError(
                    f"worker process is dead (exitcode "
                    f"{self._procs[i].exitcode}); command undeliverable")
                raise HostEnvError(i, "send", err) from err
            if st != _shm.IDLE:
                raise RuntimeError(
                    f"send to env {i} whose ctrl slot is {st} (double send "
                    f"without recv?)")
            self._v["act"][i] = np.asarray(
                a, self._v["act"].dtype).reshape(self.slab.act_shape)
            self._out.add(i)
            self._v["ctrl"][i] = _shm.CMD_STEP

    def liveness(self) -> dict:
        """Per-worker liveness from the shared-memory ``last_beat_ns`` rows
        (wall clock, written by workers even while idle) plus dead-process
        detection — /healthz tells "slow" from "dead" without waiting for a
        recv timeout."""
        beats = []
        slab = self._stats_slab
        if slab is not None and slab.counters is not None:
            col = slab.spec.fields.index("last_beat_ns")
            beats = [int(b) for b in slab.counters[:, col]]
        dead = [] if self._closed else [
            i for i, p in enumerate(self._procs) if not p.is_alive()]
        return {"now_ns": time.time_ns(), "workers": self.M,
                "last_beat_ns": beats, "dead": dead}

    def stats(self) -> dict:
        """Parent counters + the per-worker shared-memory stat rows
        (steps / resets / errors / wait_ns / busy_ns / last_beat_ns),
        aggregated with zero pickling. Readable even after workers die —
        the rows live in the parent-owned segment."""
        out = super().stats()
        out["backend"] = "proc"
        if self._stats_slab is not None:
            out["workers_detail"] = self._stats_slab.aggregate()
        return out

    def close(self, timeout: float = 5.0):
        """Raise the stop byte, join workers, terminate stragglers, unlink
        the segment. Unlike threads, a worker stuck in a long env.step is
        *actually killed* — close() is bounded even mid-step."""
        if self._closed:
            return
        self._closed = True
        self._v["stop"][0] = 1
        deadline = time.monotonic() + timeout
        for p in self._procs:
            p.join(timeout=max(0.0, deadline - time.monotonic()))
        for p in self._procs:
            if p.is_alive():
                p.terminate()
                p.join(timeout=1.0)
        if self._stats_slab is not None:
            self._stats_slab.close()
            self._stats_slab = None
        self._v = None                          # drop views before close()
        try:
            self._seg.close()
        except BufferError:
            # a caller-held traceback still pins a slab view; unlink anyway
            # (frees the name; the mapping dies with the process). Keep the
            # object alive so its finalizer doesn't retry close() at gc.
            _LEAKED_SEGS.append(self._seg)
        try:
            self._seg.unlink()
        except FileNotFoundError:
            pass

    def __del__(self):
        try:
            if not getattr(self, "_closed", True):
                self.close(timeout=0.5)
        except Exception:
            pass
