"""Static packing specs for emulation — the jax-free half of §3.1.

``FlatSpec`` / ``ActionSpec`` are pure layout metadata: which leaf of a
space tree lands at which offset of the flat buffer. They are computed once,
host-side, with numpy only — and that separation is load-bearing: the
shared-memory worker processes of ``core/shm.py`` unpickle these specs and
run the numpy packing twins (``bridge/adapters.py``) without ever importing
jax (fork/spawn-unsafe and ~seconds of import time per worker).

``core/emulation.py`` re-exports everything here, so established imports
(``emulation.flat_spec`` etc.) keep working; only the jittable transforms
(``emulate`` / ``unemulate`` / ...) live on the jax side.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import numpy as np

from repro.core import spaces as sp


@dataclass(frozen=True)
class LeafSpec:
    path: tuple
    shape: tuple
    dtype: Any
    offset: int          # element offset (mode units) into the flat buffer
    size: int            # element count (mode units)


@dataclass(frozen=True)
class FlatSpec:
    """Static packing plan for one space tree (computed once, host-side)."""
    space: sp.Space
    mode: str            # "f32" | "bytes"
    leaf_specs: tuple
    total: int

    @property
    def dtype(self):
        return np.uint8 if self.mode == "bytes" else np.float32


def flat_spec(space: sp.Space, mode: str = "f32") -> FlatSpec:
    assert mode in ("f32", "bytes")
    specs, offset = [], 0
    for path, leaf in sp.leaves(space):
        shape = sp.leaf_shape(leaf)
        dtype = sp.leaf_dtype(leaf)
        n = int(np.prod(shape, dtype=np.int64)) if shape else 1
        size = n * dtype.itemsize if mode == "bytes" else n
        specs.append(LeafSpec(path, shape, dtype, offset, size))
        offset += size
    return FlatSpec(space, mode, tuple(specs), offset)


@dataclass(frozen=True)
class ActionSpec:
    """Action tree ⇔ single flat action vector (paper §3.1).

    Discrete trees emulate to one MultiDiscrete (the paper's scheme);
    continuous (all-Box) trees emulate to one flat Box — the paper lists
    continuous actions as unsupported (§8); implemented here (beyond-paper).
    Mixed trees are not supported."""
    space: sp.Space
    kind: str            # "discrete" | "continuous"
    nvec: tuple
    cont_dim: int
    leaf_specs: tuple    # (path, leaf_shape, dtype, offset, size)

    @property
    def num_components(self) -> int:
        return len(self.nvec) if self.kind == "discrete" else self.cont_dim


def action_spec(space: sp.Space) -> ActionSpec:
    leaves_ = list(sp.leaves(space))
    boxes = [isinstance(l, sp.Box) for _, l in leaves_]
    if any(boxes):
        assert all(boxes), "mixed discrete/continuous action trees unsupported"
        specs, offset = [], 0
        for path, leaf in leaves_:
            shape = sp.leaf_shape(leaf)
            n = int(np.prod(shape, dtype=np.int64)) if shape else 1
            specs.append(LeafSpec(path, shape, sp.leaf_dtype(leaf), offset, n))
            offset += n
        return ActionSpec(space, "continuous", (), offset, tuple(specs))
    nvec = sp.num_actions(space)
    specs, offset = [], 0
    for path, leaf in leaves_:
        if isinstance(leaf, sp.Discrete):
            size, shape = 1, ()
        else:  # MultiDiscrete
            size, shape = len(leaf.nvec), (len(leaf.nvec),)
        specs.append(LeafSpec(path, shape, sp.leaf_dtype(leaf), offset, size))
        offset += size
    return ActionSpec(space, "discrete", nvec, 0, tuple(specs))
