"""Shared-memory slabs + busy-wait signalling for the process HostPool.

This is the paper's process-based vectorization substrate: one
``multiprocessing.shared_memory`` segment per pool, carved into per-env rows
for observations / actions / rewards / done / episode-stat fields, plus a
one-byte control slot per env that parent and worker flip as a two-party
handshake. The hot path moves **zero pickled bytes** — the worker packs
observations (``np_emulate_obs``) and unpacks actions straight in the slab
rows, and the only thing that "crosses" per step is the env's control byte
changing state.

Control protocol (single writer per state, so no locks):

    parent writes when ctrl[i] ∈ {IDLE, READY, ERROR}:
        IDLE  -> CMD_RESET (seed row filled)   | CMD_STEP (action row filled)
    worker writes when ctrl[i] ∈ {CMD_RESET, CMD_STEP}:
        CMD_* -> READY (result rows filled)    | ERROR (err row filled)
    parent harvests READY -> IDLE after copying the result rows out.

Shutdown is a separate parent-owned ``stop`` byte checked in every worker
wait loop — a worker mid-op finishes (or is terminated by ``close``) and
never races the parent for the ctrl slot.

Both sides wait with the same spin → ``sched_yield`` → escalating-sleep
ladder (``SpinConfig``); pure spinning would melt a shared box, pure
sleeping would add milliseconds of latency per step — the ladder gives
sub-100 µs reaction when the peer is fast and ~``max_sleep_us`` polling when
it is slow.

IMPORTANT: this module (the spawn-worker entrypoint) must stay importable
without jax — jax is fork/spawn-hostile and costs seconds per worker. It
imports numpy and the stdlib only; ``tests/test_host_bridge.py`` has an
import-probe that fails if jax ever sneaks into the chain.
"""
from __future__ import annotations

import os
import pickle
import sys
import time
from dataclasses import dataclass, field
from multiprocessing import shared_memory
from typing import Callable, Tuple

import numpy as np

# ctrl-slot states
IDLE = 0
CMD_RESET = 1
CMD_STEP = 2
READY = 3
ERROR = 4

ERR_BYTES = 1024         # per-env error row: [op u8][len u16le][utf-8 ...]
_ALIGN = 64              # section alignment (cache line)

_OPS = ("reset", "step")


@dataclass(frozen=True)
class SlabSpec:
    """Per-env row shapes/dtypes, derived from the emulation specs.

    ``obs_shape`` / ``act_shape`` are what one env's adapter produces and
    consumes per step — ``(obs_dim,)`` or ``(num_agents, obs_dim)`` f32 rows
    for observations, ``(num_components,)`` (or agent-major) int32/float32
    rows for emulated actions. ``rew_shape`` is ``()`` for single-agent envs
    and ``(num_agents,)`` for padded multi-agent rows. Dtypes are stored as
    names so the spec pickles canonically into the worker."""
    obs_shape: Tuple[int, ...]
    act_shape: Tuple[int, ...]
    act_dtype: str = "int32"
    rew_shape: Tuple[int, ...] = ()
    obs_dtype: str = "float32"


@dataclass(frozen=True)
class SpinConfig:
    """The busy-wait backoff ladder: ``spin`` raw re-checks, then ``yields``
    ``sched_yield`` slices, then sleeps escalating ``min_sleep_us`` →
    ``max_sleep_us``. A wait that drags past ``idle_after_s`` keeps
    escalating to ``idle_sleep_us`` — a worker nobody has commanded for that
    long is *idle*, not mid-handoff, and polling it at ``max_sleep_us``
    forever burns the core everyone else needs (with M ≫ cores, the boot
    storm alone starves un-booted siblings). Recorded in
    BENCH_hostpool.json alongside results."""
    spin: int = 200
    yields: int = 100
    min_sleep_us: float = 20.0
    max_sleep_us: float = 200.0
    idle_sleep_us: float = 10_000.0
    idle_after_s: float = 0.05


def default_spin(workers: int = 0) -> SpinConfig:
    """The pool's default ladder, oversubscription-aware: when worker
    processes outnumber cores (``workers >= os.cpu_count()``), busy-waiting
    *steals the core the peer needs* — spin less, sleep longer. On a box
    with headroom the aggressive ladder minimizes handoff latency."""
    cores = os.cpu_count() or 1
    if workers and workers >= cores:
        # long poll cap: on an oversubscribed box every wakeup steals CPU
        # from the workers actually stepping, and handoff latency is lost
        # in the noise anyway
        return SpinConfig(spin=20, yields=20, min_sleep_us=100.0,
                          max_sleep_us=2000.0, idle_sleep_us=20_000.0)
    return SpinConfig()


class SpinWait:
    """One wait episode of the ladder; ``reset()`` after the flag flips."""

    def __init__(self, cfg: SpinConfig):
        self.cfg = cfg
        self._n = 0
        self._sleep = cfg.min_sleep_us / 1e6
        self._slept = 0.0

    def reset(self):
        self._n = 0
        self._sleep = self.cfg.min_sleep_us / 1e6
        self._slept = 0.0

    def pause(self):
        c = self.cfg
        self._n += 1
        if self._n <= c.spin:
            return
        if self._n <= c.spin + c.yields:
            os.sched_yield()
            return
        time.sleep(self._sleep)
        self._slept += self._sleep
        cap = (c.idle_sleep_us if self._slept >= c.idle_after_s
               else c.max_sleep_us)
        self._sleep = min(self._sleep * 2, cap / 1e6)


def spin_until(pred: Callable[[], bool], spin: SpinConfig = None, *,
               timeout: float) -> bool:
    """Busy-wait the ladder until ``pred()`` is truthy; returns False on
    timeout. The ``timeout`` is mandatory by design — every shared-memory
    wait in this codebase must be bounded (a dead peer otherwise turns a
    spin into a deadlocked run; the analysis BLOCKING-NO-TIMEOUT rule
    enforces the same at lint time)."""
    w = SpinWait(spin or SpinConfig())
    deadline = time.monotonic() + timeout
    while True:
        if pred():
            return True
        if time.monotonic() > deadline:
            return False
        w.pause()


def _section(offset: int, shape, dtype) -> Tuple[int, int]:
    n = int(np.prod(shape, dtype=np.int64)) * np.dtype(dtype).itemsize
    start = ((offset + _ALIGN - 1) // _ALIGN) * _ALIGN
    return start, start + n


class SlabLayout:
    """Byte layout of one pool's segment: M env rows per field."""

    FIELDS = ("ctrl", "stop", "seed", "obs", "act", "rew", "done", "score",
              "meta", "err")

    def __init__(self, spec: SlabSpec, M: int):
        self.spec, self.M = spec, M
        shapes = {
            "ctrl": ((M,), np.uint8),
            "stop": ((1,), np.uint8),
            "seed": ((M,), np.int64),
            "obs": ((M,) + tuple(spec.obs_shape), np.dtype(spec.obs_dtype)),
            "act": ((M,) + tuple(spec.act_shape), np.dtype(spec.act_dtype)),
            "rew": ((M,) + tuple(spec.rew_shape), np.float32),
            "done": ((M,), np.uint8),
            "score": ((M,), np.float32),
            "meta": ((M, 2), np.uint8),          # [is_step, has_score]
            "err": ((M, ERR_BYTES), np.uint8),
        }
        self.sections = {}
        end = 0
        for name in self.FIELDS:
            shape, dtype = shapes[name]
            start, end = _section(end, shape, dtype)
            self.sections[name] = (start, shape, dtype)
        self.nbytes = end

    def views(self, buf) -> dict:
        """Numpy views of every field over a shared-memory buffer."""
        out = {}
        for name, (start, shape, dtype) in self.sections.items():
            n = int(np.prod(shape, dtype=np.int64))
            out[name] = np.frombuffer(
                buf, dtype=dtype, count=n, offset=start).reshape(shape)
        return out

    def slab_bytes(self) -> dict:
        """Per-field byte sizes (recorded by the benchmark)."""
        return {name: int(np.prod(shape, dtype=np.int64)
                          * np.dtype(dtype).itemsize)
                for name, (_s, shape, dtype) in self.sections.items()}


def dumps_env_fn(fn: Callable) -> bytes:
    """Pickle an env factory for the spawn worker, with a useful error.

    Plain classes and ``functools.partial`` of module-level classes pickle
    fine; closures/lambdas need ``cloudpickle`` (used when installed)."""
    try:
        import cloudpickle as _cp      # optional — never a hard dependency
        return _cp.dumps(fn)
    except ImportError:
        pass
    try:
        return pickle.dumps(fn)
    except Exception as e:
        raise ValueError(
            f"backend='proc' spawns worker processes, so the env factory "
            f"must pickle; {fn!r} does not ({type(e).__name__}: {e}). Pass "
            f"a module-level class / function or functools.partial instead "
            f"of a lambda/closure (or install cloudpickle)") from e


@dataclass(frozen=True)
class WorkerConfig:
    """Everything one spawn worker needs (small and picklable)."""
    shm_name: str
    index: int
    M: int
    seed: int            # pool seed; autoreset episode e uses seed + i + M*e
    spec: SlabSpec
    spin: SpinConfig = field(default_factory=SpinConfig)
    payload: bytes = b""                 # pickled env factory
    stats: object = None                 # telemetry.procstats.StatSpec | None
    trace: object = None                 # telemetry.traceprop.TraceConfig | None


def _write_error(views: dict, i: int, op: str, exc: BaseException) -> None:
    row = views["err"][i]
    text = f"{type(exc).__name__}: {exc}".encode("utf-8", "replace")
    text = text[:ERR_BYTES - 3]
    row[0] = _OPS.index(op)
    row[1] = len(text) & 0xFF
    row[2] = (len(text) >> 8) & 0xFF
    row[3:3 + len(text)] = np.frombuffer(text, np.uint8)


def read_error(views: dict, i: int) -> Tuple[str, str]:
    """(op, message) from env ``i``'s error row."""
    row = views["err"][i]
    op = _OPS[int(row[0])] if int(row[0]) < len(_OPS) else "step"
    n = int(row[1]) | (int(row[2]) << 8)
    return op, bytes(row[3:3 + n].tobytes()).decode("utf-8", "replace")


def attach_untracked(name: str) -> shared_memory.SharedMemory:
    """Attach an existing segment without registering it with the resource
    tracker.

    On 3.10 ``SharedMemory(name=...)`` registers the segment with the
    *attaching* process's tracker too (fixed by ``track=False`` only in
    3.13). Worker registrations corrupt the tracker's bookkeeping for a
    segment the parent owns — either the tracker unlinks the slab when a
    worker exits, or the parent's own unlink hits a KeyError. The parent
    owns the lifecycle; workers only map, so we silence ``register`` for
    the duration of the attach."""
    from multiprocessing import resource_tracker
    orig = resource_tracker.register
    resource_tracker.register = lambda *a, **kw: None
    try:
        return shared_memory.SharedMemory(name=name)
    finally:
        resource_tracker.register = orig


def worker_main(cfg: WorkerConfig) -> None:
    """Spawn-worker entrypoint: busy-wait on the ctrl slot, step/reset the
    env in-process, write results into the slab rows. Autoreset seeding
    matches the thread backend exactly: episode ``e`` of env ``i`` resets
    with ``seed + i + M * e``."""
    if "jax" in sys.modules:
        # a spawned interpreter cannot have jax loaded before this line; a
        # forked child of the jax-laden parent can — and forked jax/XLA
        # state deadlocks or corrupts. Enforce the spawn context at runtime.
        raise RuntimeError(
            "HostPool worker started with jax already imported — it was "
            "forked, not spawned. ProcHostPool must use the 'spawn' start "
            "method (multiprocessing.get_context('spawn'))")
    seg = attach_untracked(cfg.shm_name)
    v = SlabLayout(cfg.spec, cfg.M).views(seg.buf)
    i = cfg.index
    env = None
    episode = 0
    spin = SpinWait(cfg.spin)
    slab = srow = None
    if cfg.stats is not None:
        # lock-free per-worker stat row (telemetry slab; parent aggregates).
        # Imported lazily: procstats depends on this module, and the import
        # stays jax-free either way.
        from repro.telemetry.procstats import StatSlab
        slab = StatSlab.attach(cfg.stats)
        srow = slab.row(i)
    # per-process tracing (telemetry.traceprop): the parent ships its
    # TraceConfig only when tracing is on, so the default pays nothing.
    # The tracer writes spans-<pid>.jsonl with its meta header eagerly;
    # periodic + finally flushes make crash output mergeable, and a
    # SIGKILLed worker's already-flushed prefix is still valid JSONL.
    from repro.telemetry.spans import CachedSpan
    tracer = None
    t_flush = time.monotonic()
    if cfg.trace is not None:
        from repro.telemetry import traceprop
        tracer = traceprop.init_worker(cfg.trace, role=f"host-worker-{i}")
    step_span = CachedSpan("worker.step")
    reset_span = CachedSpan("worker.reset")
    beat_i = 0
    try:
        while True:
            t_wait = time.monotonic_ns()
            if srow is not None:
                srow.set("last_beat_ns", time.time_ns())
            while True:                          # wait for a command
                if v["stop"][0]:
                    return
                cmd = int(v["ctrl"][i])
                if cmd in (CMD_RESET, CMD_STEP):
                    break
                spin.pause()
                beat_i += 1
                if srow is not None and not (beat_i & 63):
                    # idle-but-alive workers must keep beating, or /healthz
                    # would call a quiet worker dead; every-64th pause keeps
                    # the store off the hot handshake path
                    srow.set("last_beat_ns", time.time_ns())
            spin.reset()
            t_busy = time.monotonic_ns()
            if srow is not None:
                srow.add("wait_ns", t_busy - t_wait)
            op = "reset"
            try:
                with (step_span if cmd == CMD_STEP else reset_span):
                    if env is None:
                        env = pickle.loads(cfg.payload)()
                    if cmd == CMD_RESET:
                        obs = env.reset(int(v["seed"][i]))
                        rew, done, score, has_score, is_step = \
                            0.0, False, 0.0, 0, 0
                    else:
                        op = "step"
                        obs, rew, done, info = env.step(v["act"][i].copy())
                        is_step = 1
                        info = info if isinstance(info, dict) else {}
                        has_score = 1 if "score" in info else 0
                        score = float(info.get("score", 0.0))
                        if done:
                            episode += 1
                            op = "reset"
                            obs = env.reset(cfg.seed + i + cfg.M * episode)
                    v["obs"][i] = np.asarray(obs, v["obs"].dtype).reshape(
                        cfg.spec.obs_shape)
                    v["rew"][i] = np.asarray(rew, np.float32)
                    v["done"][i] = np.uint8(bool(done))
                    v["score"][i] = np.float32(score)
                    v["meta"][i, 0] = np.uint8(is_step)
                    v["meta"][i, 1] = np.uint8(has_score)
                    v["ctrl"][i] = READY
                if srow is not None:
                    srow.add("steps" if is_step else "resets")
                    srow.add("busy_ns", time.monotonic_ns() - t_busy)
                    srow.set("last_beat_ns", time.time_ns())
                if tracer is not None and time.monotonic() - t_flush > 0.25:
                    tracer.flush()
                    t_flush = time.monotonic()
            except Exception as e:   # noqa: BLE001 — forwarded to the parent
                _write_error(v, i, op, e)
                v["ctrl"][i] = ERROR
                if srow is not None:
                    srow.add("errors")
                return
    finally:
        close = getattr(env, "close", None)
        if callable(close):
            try:
                close()
            except Exception:
                pass
        if tracer is not None:
            # crash-safe: clean exit, stop-flag exit, and the ERROR return
            # all pass through here before the process dies
            try:
                tracer.flush()
            except Exception:
                pass
        del v, srow                              # release buffer views
        seg.close()
        if slab is not None:
            slab.close()
