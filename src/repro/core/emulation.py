"""Emulation — the paper's §3.1, TPU edition.

PufferLib's key insight: wrap any environment so it *looks like Atari* — a
flat observation tensor and a single (multi)discrete action — with an exact
inverse applied in the first line of the model's forward pass, so nothing is
lost. The original implementation packs numpy structured arrays byte-wise
(a Cythonized hot loop, paper §5). On TPU the same idea becomes a pair of
pure, jittable layout transforms over pytrees:

  * ``bytes`` mode — exact structured-array analogue: every leaf is bitcast
    to uint8 and packed into one contiguous byte buffer. Lossless for every
    dtype. This is the transport/vectorization format (one buffer ⇒ one
    collective ⇒ zero-copy batching).
  * ``f32`` mode — leaves promoted to float32 and concatenated. This is the
    model-facing format (what an Atari-shaped network consumes).

``unemulate`` restores the original tree exactly (bytes mode) or up to dtype
promotion (f32 mode) — "no loss of generality".

Startup-only shape checks, canonical ordering, and fixed-size padding for
variable agent counts mirror the paper's remaining emulation features.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import spaces as sp
# The packing specs are jax-free (shared-memory workers unpickle them
# without importing jax); re-exported here so emulation stays the one-stop
# import for the full §3.1 surface.
from repro.core.emuspec import (ActionSpec, FlatSpec, LeafSpec,  # noqa: F401
                                action_spec, flat_spec)


def _to_u8(x):
    if x.dtype == jnp.bool_:
        x = x.astype(jnp.uint8)
    if x.dtype != jnp.uint8:
        x = jax.lax.bitcast_convert_type(x, jnp.uint8)
    return x


def _from_u8(flat_u8, shape, dtype):
    if dtype == jnp.bool_:
        return flat_u8.reshape(shape).astype(jnp.bool_)
    if jnp.dtype(dtype) == jnp.uint8:
        return flat_u8.reshape(shape)
    itemsize = jnp.dtype(dtype).itemsize
    x = flat_u8.reshape(shape + (itemsize,))
    return jax.lax.bitcast_convert_type(x, dtype)


def emulate(spec: FlatSpec, tree) -> jax.Array:
    """Pack a (possibly batched) space element into one flat buffer.

    Leading batch dimensions are inferred per-leaf from the static spec, so
    the same function works unbatched, under vmap, or on pre-batched data —
    the paper's "stack sub-environment data without extra copies".
    """
    parts = []
    batch = None
    for ls in spec.leaf_specs:
        x = jnp.asarray(sp.get_path(tree, ls.path))
        nb = x.ndim - len(ls.shape)
        assert nb >= 0, f"leaf {ls.path}: got shape {x.shape}, want {ls.shape}"
        b = x.shape[:nb]
        assert batch is None or batch == b, "inconsistent batch dims"
        batch = b
        if spec.mode == "bytes":
            x = _to_u8(x)
        else:
            x = x.astype(jnp.float32)
        parts.append(x.reshape(b + (-1,)))
    return jnp.concatenate(parts, axis=-1)


def unemulate(spec: FlatSpec, flat: jax.Array):
    """Exact inverse of ``emulate`` — call this in the first line of the
    model's forward pass (paper §3.1)."""
    batch = flat.shape[:-1]
    assert flat.shape[-1] == spec.total, (flat.shape, spec.total)
    tree = sp.zeros(spec.space)
    for ls in spec.leaf_specs:
        chunk = jax.lax.slice_in_dim(flat, ls.offset, ls.offset + ls.size, axis=-1)
        if spec.mode == "bytes":
            leaf = _from_u8(chunk, batch + ls.shape, ls.dtype)
        else:
            leaf = chunk.reshape(batch + ls.shape).astype(ls.dtype)
        tree = sp.set_path(tree, ls.path, leaf)
    return tree


# -- action emulation --------------------------------------------------------

def unemulate_action(spec: ActionSpec, flat: jax.Array):
    """(…, num_components) int32 → original action tree."""
    batch = flat.shape[:-1]
    tree = sp.zeros(spec.space)
    for ls in spec.leaf_specs:
        chunk = jax.lax.slice_in_dim(flat, ls.offset, ls.offset + ls.size, axis=-1)
        leaf = chunk.reshape(batch + ls.shape).astype(ls.dtype)
        tree = sp.set_path(tree, ls.path, leaf)
    return tree


def emulate_action(spec: ActionSpec, tree) -> jax.Array:
    out_dtype = jnp.int32 if spec.kind == "discrete" else jnp.float32
    parts = []
    for ls in spec.leaf_specs:
        x = jnp.asarray(sp.get_path(tree, ls.path)).astype(out_dtype)
        nb = x.ndim - len(ls.shape)
        parts.append(x.reshape(x.shape[:nb] + (-1,)))
    return jnp.concatenate(parts, axis=-1)


# -- environment wrapper ------------------------------------------------------

class Emulated:
    """One-line wrapper: ``env = Emulated(env)`` makes any structured env look
    like Atari (flat Box obs, MultiDiscrete action) to everything downstream.

    Also implements the paper's multiagent guarantees: observations are
    agent-major in canonical (index) order, and variable agent counts are
    padded to ``num_agents`` with a validity mask so data buffers stay fixed
    size. Shape checks run once, at trace time — zero steady-state cost.
    """

    def __init__(self, env, mode: str = "f32"):
        self.env = env
        self.obs_spec = flat_spec(env.observation_space, mode)
        self.act_spec = action_spec(env.action_space)
        self.num_agents = getattr(env, "num_agents", 1)
        self.observation_space = sp.Box((self.obs_spec.total,),
                                        self.obs_spec.dtype)
        self.action_space = (sp.MultiDiscrete(self.act_spec.nvec)
                             if self.act_spec.kind == "discrete"
                             else sp.Box((self.act_spec.cont_dim,)))
        self._checked = False

    # pure-functional env protocol (see envs/base.py)
    def init(self, key):
        return self.env.init(key)

    def reset(self, state, key):
        state, obs = self.env.reset(state, key)
        return state, self._obs(obs)

    def step(self, state, action, key):
        action = unemulate_action(self.act_spec, action)
        state, obs, rew, done, info = self.env.step(state, action, key)
        return state, self._obs(obs), rew, done, info

    def _obs(self, obs):
        flat = emulate(self.obs_spec, obs)
        if not self._checked:  # paper: check shapes on the first batch only
            want = (self.num_agents, self.obs_spec.total) \
                if self.num_agents > 1 else (self.obs_spec.total,)
            assert flat.shape[-len(want):] == want, (flat.shape, want)
            self._checked = True
        return flat

    def unemulate_obs(self, flat):
        """First line of your model's forward pass."""
        return unemulate(self.obs_spec, flat)


def pad_agents(obs, mask, num_agents: int):
    """Pad agent-major data to a fixed agent count (paper §3.1). ``mask``
    marks live agents; padded rows are zero."""
    cur = obs.shape[0]
    if cur == num_agents:
        return obs, mask
    pad = [(0, num_agents - cur)] + [(0, 0)] * (obs.ndim - 1)
    return jnp.pad(obs, pad), jnp.pad(mask, (0, num_agents - cur))
