"""EnvPool analogue — the paper's double-buffered async vectorization.

The paper's Python EnvPool simulates M = k·N environments and returns batches
of N from the first workers to finish; with k = 2 the CPU steps half the envs
while the GPU computes actions for the other half.

On TPU the jitter the paper exploits (slow envs, slow cores) does not exist
*within* a lockstep SPMD step, but the overlap opportunity is identical:
while the accelerator computes actions (or a learner update) for buffer i,
buffer i+1's environment step is already dispatched. JAX's async dispatch
gives us this for free as long as the host never blocks — so the pool is a
small round-robin scheduler that never calls ``block_until_ready`` on the
in-flight buffer.

API matches EnvPool: ``recv() → (obs, rew, done, info, buf)``, then
``send(actions)``. The paper's "M ≫ 2N, ignore stragglers" mode corresponds
to ``num_buffers > 2``, which also hides multi-step learner latency.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.vector import VecEnv


class Pool:
    def __init__(self, env, num_envs: int, num_buffers: int = 2,
                 backend: str = "vmap",
                 sharding: Optional[jax.sharding.Sharding] = None,
                 key=None):
        from repro.envs.base import empty_info
        assert num_buffers >= 1
        key = key if key is not None else jax.random.PRNGKey(0)
        self.vec = VecEnv(env, num_envs, backend=backend, sharding=sharding)
        self.num_buffers = num_buffers
        self.batch_size = self.vec.batch_size
        # Independent env-state buffers sharing one compiled step program —
        # the analogue of "multiple environments per worker" with zero
        # marginal compile cost.
        self._states, self._pending = [], []
        for b in range(num_buffers):
            state, obs = self.vec.init(jax.random.fold_in(key, b))
            self._states.append(state)
            zero_rew = jnp.zeros((self.batch_size,), jnp.float32)
            done = jnp.zeros((self.batch_size if self.vec.num_agents > 1
                              else num_envs,), jnp.bool_)
            info = jax.vmap(lambda _: empty_info())(jnp.arange(num_envs))
            self._pending.append((obs, zero_rew, done, info))
        self._cursor = 0
        self._key = jax.random.fold_in(key, 997)
        self._awaiting = [False] * num_buffers

    def recv(self):
        """Observations for the current buffer. Non-blocking w.r.t. the other
        buffers — their steps stay in flight on the device queue."""
        b = self._cursor
        assert not self._awaiting[b], "recv() twice without send()"
        self._awaiting[b] = True
        obs, rew, done, info = self._pending[b]
        return obs, rew, done, info, b

    def send(self, actions, buf: Optional[int] = None):
        """Dispatch the step for the awaited buffer and advance the cursor.
        The step is queued, not awaited — overlap happens here.

        The cursor always advances from its own value, never from ``buf``:
        recv() only ever hands out the cursor buffer, so the one awaited
        buffer IS the cursor buffer, and a caller passing a stale ``buf``
        from an older recv() must not be able to skew the round-robin."""
        b = self._cursor
        if buf is not None and buf != b:
            raise ValueError(
                f"send(buf={buf}) does not match the awaited buffer {b}; "
                f"pass the buf returned by the matching recv()")
        assert self._awaiting[b], "send() without recv()"
        self._key, sub = jax.random.split(self._key)
        state, obs, rew, done, info = self.vec.step(self._states[b], actions, sub)
        self._states[b] = state
        self._pending[b] = (obs, rew, done, info)
        self._awaiting[b] = False
        self._cursor = (self._cursor + 1) % self.num_buffers

    # convenience for synchronous use / tests
    def step(self, actions):
        obs, rew, done, info, b = self.recv()
        self.send(actions, b)
        return obs, rew, done, info
