"""Vectorization — the paper's §3.3, TPU edition.

The paper simulates M environments across processes with shared-memory,
zero-copy batching. In JAX the analogue is stronger: all M environment states
live in one contiguous device buffer and stepping them is a single fused XLA
program (``jax.vmap``), so "zero copy" is literal — observations are never
re-laid-out between the env, the emulation layer, and the model.

Backends (one API, mirroring the paper's serial / multiprocessing / ray):
  * ``serial``  — Python loop over jitted single-env steps. For host-bound
    envs and as the autotune baseline.
  * ``vmap``    — fused on-device batch stepping, auto-reset inside the step
    (the paper's "one IPC per episode" becomes *zero* host syncs).
  * ``shard``   — vmap + sharding constraint over the mesh data axes, for
    multi-host rollouts inside pjit.

``autotune`` times every valid backend on the actual env — the paper's
autotune utility.
"""
from __future__ import annotations

import time
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp


def tree_select(pred, on_true, on_false):
    """Branch-free pytree select; `pred` is a scalar bool (broadcasts)."""
    return jax.tree.map(
        lambda a, b: jnp.where(jnp.reshape(pred, (-1,) + (1,) * (a.ndim - 1))
                               if a.ndim else pred, a, b),
        on_true, on_false)


def autoreset_step(env):
    """Single-env step with in-graph auto-reset on done."""
    def step(state, action, key):
        k_step, k_reset = jax.random.split(key)
        s2, obs, rew, done, info = env.step(state, action, k_step)
        s_reset, obs_reset = env.reset(s2, k_reset)
        s3 = tree_select(done, s_reset, s2)
        obs = tree_select(done, obs_reset, obs)
        return s3, obs, rew, done, info
    return step


class VecEnv:
    """N copies of a (usually ``Emulated``) env stepped as one XLA program.

    Multiagent envs are exposed agent-major: ``batch_size = N * num_agents``
    and observations arrive as (batch_size, *obs) in canonical order.
    """

    def __init__(self, env, num_envs: int, backend: str = "vmap",
                 sharding: Optional[jax.sharding.Sharding] = None):
        assert backend in ("serial", "vmap", "shard")
        self.env, self.num_envs, self.backend = env, num_envs, backend
        self.num_agents = getattr(env, "num_agents", 1)
        self.batch_size = num_envs * self.num_agents
        self.single_observation_space = env.observation_space
        self.single_action_space = env.action_space
        self.sharding = sharding
        self._step1 = autoreset_step(env)
        if backend == "serial":
            self._jit_step1 = jax.jit(self._step1)
            self._jit_reset1 = jax.jit(env.reset)
        else:
            self._vstep = jax.jit(jax.vmap(self._step1))
            self._vreset = jax.jit(jax.vmap(env.reset))
            self._vinit = jax.jit(jax.vmap(env.init))

    # -- functional API (used inside fused rollout scans) ---------------------
    def init(self, key):
        keys = jax.random.split(key, self.num_envs)
        if self.backend == "serial":
            states = [self.env.init(k) for k in keys]
            state = jax.tree.map(lambda *xs: jnp.stack(xs), *states)
        else:
            state = self._vinit(keys)
        state, obs = self.reset(state, jax.random.fold_in(key, 1))
        return state, obs

    def reset(self, state, key):
        keys = jax.random.split(key, self.num_envs)
        if self.backend == "serial":
            outs = [self._jit_reset1(jax.tree.map(lambda x: x[i], state), keys[i])
                    for i in range(self.num_envs)]
            state = jax.tree.map(lambda *xs: jnp.stack(xs), *[o[0] for o in outs])
            obs = jnp.stack([o[1] for o in outs])
        else:
            state, obs = self._vreset(state, keys)
        return state, self._flatten_agents(obs)

    def step(self, state, actions, key):
        actions = self._unflatten_agents(actions)
        keys = jax.random.split(key, self.num_envs)
        if self.backend == "serial":
            outs = [self._jit_step1(jax.tree.map(lambda x: x[i], state),
                                    actions[i], keys[i])
                    for i in range(self.num_envs)]
            stack = lambda j: jax.tree.map(lambda *xs: jnp.stack(xs),
                                           *[o[j] for o in outs])
            state, obs, rew, done, info = (stack(0), stack(1), stack(2),
                                           stack(3), stack(4))
        else:
            state, obs, rew, done, info = self._vstep(state, actions, keys)
        if self.sharding is not None:
            obs = jax.lax.with_sharding_constraint(obs, self.sharding)
        return (state, self._flatten_agents(obs), self._flatten_rew(rew),
                self._broadcast_done(done), info)

    # step as a pure function for use inside jit/scan (no host logic),
    # taking one explicit key per env. Shapes are derived from the inputs
    # (not self.num_envs) so the same function works on a per-device shard
    # inside shard_map — the TrainEngine's data-parallel tier relies on this.
    def step_keyed_fn(self):
        step1 = self._step1
        A = self.num_agents

        def f(state, actions, keys):
            n = keys.shape[0]
            if A > 1:
                actions = jax.tree.map(
                    lambda x: x.reshape((n, A) + x.shape[1:]), actions)
            state, obs, rew, done, info = jax.vmap(step1)(state, actions, keys)
            if A > 1:
                obs = jax.tree.map(
                    lambda x: x.reshape((n * A,) + x.shape[2:]), obs)
                rew = rew.reshape((n * A,))
                done = jnp.repeat(done, A)
            return state, obs, rew, done, info
        return f

    # step as a pure function for use inside jit/scan (no host logic)
    def step_fn(self):
        step1 = self._step1
        num_envs, A = self.num_envs, self.num_agents
        fl, ufl, flr, bd = (self._flatten_agents, self._unflatten_agents,
                            self._flatten_rew, self._broadcast_done)
        def f(state, actions, key):
            keys = jax.random.split(key, num_envs)
            state, obs, rew, done, info = jax.vmap(step1)(state, ufl(actions), keys)
            return state, fl(obs), flr(rew), bd(done), info
        return f

    # -- agent-major reshapes --------------------------------------------------
    def _flatten_agents(self, obs):
        if self.num_agents == 1:
            return obs
        return jax.tree.map(
            lambda x: x.reshape((self.batch_size,) + x.shape[2:]), obs)

    def _unflatten_agents(self, actions):
        if self.num_agents == 1:
            return actions
        return jax.tree.map(
            lambda x: x.reshape((self.num_envs, self.num_agents) + x.shape[1:]),
            actions)

    def _flatten_rew(self, rew):
        if self.num_agents == 1:
            return rew
        return rew.reshape((self.batch_size,))

    def _broadcast_done(self, done):
        if self.num_agents == 1:
            return done
        return jnp.repeat(done, self.num_agents)


def autotune(env, num_envs: int, steps: int = 64, key=None):
    """Benchmark every valid backend on the real env (paper's autotune).
    Returns {backend: steps_per_second} and the winner."""
    key = key if key is not None else jax.random.PRNGKey(0)
    results = {}
    for backend in ("serial", "vmap"):
        vec = VecEnv(env, num_envs, backend=backend)
        state, obs = vec.init(key)
        zero_action = jnp.zeros(
            (vec.batch_size,) + _action_shape(vec.single_action_space),
            jnp.int32)
        # warmup (compile)
        state, obs, *_ = vec.step(state, zero_action, key)
        jax.block_until_ready(obs)  # repro: noqa[HOST-SYNC] — autotune warmup barrier: the sync IS the measurement boundary
        t0 = time.perf_counter()
        for i in range(steps):
            state, obs, *_ = vec.step(state, zero_action,
                                      jax.random.fold_in(key, i))
        jax.block_until_ready(obs)  # repro: noqa[HOST-SYNC] — autotune timing barrier (deliberate)
        dt = time.perf_counter() - t0
        results[backend] = steps * vec.batch_size / dt
    best = max(results, key=results.get)
    return results, best


def _action_shape(space) -> tuple:
    from repro.core import spaces as sp
    if isinstance(space, sp.MultiDiscrete):
        return (len(space.nvec),)
    if isinstance(space, sp.Box):
        return space.shape
    return ()
