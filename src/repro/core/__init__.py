# The paper's primary contribution: emulation (structured<->flat layout
# transforms), vectorization backends, and the EnvPool-style async pool.
#
# Submodules load lazily (PEP 562): `import repro.core.shm` from a spawned
# shared-memory env worker must not drag in jax via this package __init__
# (emulation/vector/pool are jax-heavy; spaces/emuspec/host/shm are
# numpy-only). `from repro.core import emulation` etc. still work — the
# attribute access routes through __getattr__ below.

_SUBMODULES = ("spaces", "emulation", "emuspec", "vector", "pool", "host",
               "shm")
_SYMBOLS = {
    "Emulated": "emulation", "flat_spec": "emulation", "emulate": "emulation",
    "unemulate": "emulation", "action_spec": "emulation",
    "emulate_action": "emulation", "unemulate_action": "emulation",
    "VecEnv": "vector", "autotune": "vector", "Pool": "pool",
}

__all__ = list(_SUBMODULES) + list(_SYMBOLS)


def __getattr__(name):
    import importlib
    if name in _SUBMODULES:
        return importlib.import_module(f"repro.core.{name}")
    if name in _SYMBOLS:
        mod = importlib.import_module(f"repro.core.{_SYMBOLS[name]}")
        return getattr(mod, name)
    raise AttributeError(f"module 'repro.core' has no attribute {name!r}")


def __dir__():
    return sorted(__all__)
