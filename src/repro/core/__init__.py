# The paper's primary contribution: emulation (structured<->flat layout
# transforms), vectorization backends, and the EnvPool-style async pool.
from repro.core import spaces, emulation, vector, pool
from repro.core.emulation import (Emulated, flat_spec, emulate, unemulate,
                                  action_spec, emulate_action, unemulate_action)
from repro.core.vector import VecEnv, autotune
from repro.core.pool import Pool

__all__ = ["spaces", "emulation", "vector", "pool", "Emulated", "flat_spec",
           "emulate", "unemulate", "action_spec", "emulate_action",
           "unemulate_action", "VecEnv", "autotune", "Pool"]
