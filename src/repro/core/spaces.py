"""Observation/action space trees (Gym/Gymnasium `spaces` analogue).

PufferLib's emulation layer operates on arbitrarily nested space trees. We
define a minimal, hashable space algebra that covers what the paper handles:
Box / Discrete / MultiDiscrete leaves composed by Dict / Tuple nodes.

Spaces are static metadata — all functions here are trace-safe and the
flattening specs derived from them are computed once, host-side (mirroring the
paper's "shape checks only at startup").
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Mapping, Sequence

import numpy as np


def _canon_dtype(dtype):
    """Canonical numpy scalar type for a leaf dtype. jax's scalar aliases
    (``jnp.int32`` etc.) are distinct objects from numpy's, so without
    canonicalization two structurally identical spaces built on either side
    of the jax boundary would compare unequal; this keeps the module (and
    every shared-memory worker that unpickles a space) jax-import-free."""
    return np.dtype(dtype).type


class Space:
    pass


@dataclass(frozen=True)
class Discrete(Space):
    n: int
    dtype: Any = np.int32

    def __post_init__(self):
        object.__setattr__(self, "dtype", _canon_dtype(self.dtype))


@dataclass(frozen=True)
class MultiDiscrete(Space):
    nvec: tuple
    dtype: Any = np.int32

    def __post_init__(self):
        object.__setattr__(self, "nvec", tuple(int(n) for n in self.nvec))
        object.__setattr__(self, "dtype", _canon_dtype(self.dtype))


@dataclass(frozen=True)
class Box(Space):
    shape: tuple
    dtype: Any = np.float32
    low: float = -np.inf
    high: float = np.inf

    def __post_init__(self):
        object.__setattr__(self, "shape", tuple(int(s) for s in self.shape))
        object.__setattr__(self, "dtype", _canon_dtype(self.dtype))


@dataclass(frozen=True)
class Dict(Space):
    spaces: tuple  # ((key, space), ...) canonically sorted by key

    def __init__(self, spaces: Mapping[str, Space]):
        # Canonical key order — the paper sorts agent/space keys so that
        # packed layouts are deterministic across processes.
        object.__setattr__(
            self, "spaces", tuple(sorted(spaces.items(), key=lambda kv: kv[0])))

    def items(self):
        return self.spaces


@dataclass(frozen=True)
class Tuple(Space):
    spaces: tuple

    def __init__(self, spaces: Sequence[Space]):
        object.__setattr__(self, "spaces", tuple(spaces))


# ---------------------------------------------------------------------------

def leaves(space: Space, path: tuple = ()):
    """Depth-first (path, leaf_space) pairs in canonical order."""
    if isinstance(space, Dict):
        for k, sub in space.items():
            yield from leaves(sub, path + (k,))
    elif isinstance(space, Tuple):
        for i, sub in enumerate(space.spaces):
            yield from leaves(sub, path + (i,))
    else:
        yield path, space


def leaf_shape(space: Space) -> tuple:
    if isinstance(space, Discrete):
        return ()
    if isinstance(space, MultiDiscrete):
        return (len(space.nvec),)
    if isinstance(space, Box):
        return space.shape
    raise TypeError(space)


def leaf_dtype(space: Space):
    return np.dtype(space.dtype)


def zeros(space: Space):
    """A zero element of the space as a pytree. Leaves are numpy — under a
    trace they fold to constants, and the only consumers (``unemulate`` /
    ``np_unemulate_action``) overwrite every leaf anyway."""
    if isinstance(space, Dict):
        return {k: zeros(s) for k, s in space.items()}
    if isinstance(space, Tuple):
        return tuple(zeros(s) for s in space.spaces)
    return np.zeros(leaf_shape(space), leaf_dtype(space))


def sample(space: Space, key):
    """Random element (uniform over the space) — used in tests/mocks.
    The only jax-dependent function in this module; imported lazily so the
    shared-memory env workers can unpickle spaces without loading jax."""
    import jax
    import jax.numpy as jnp
    if isinstance(space, Dict):
        ks = jax.random.split(key, len(space.spaces))
        return {k: sample(s, kk) for (k, s), kk in zip(space.items(), ks)}
    if isinstance(space, Tuple):
        ks = jax.random.split(key, len(space.spaces))
        return tuple(sample(s, kk) for s, kk in zip(space.spaces, ks))
    if isinstance(space, Discrete):
        return jax.random.randint(key, (), 0, space.n, leaf_dtype(space))
    if isinstance(space, MultiDiscrete):
        nvec = jnp.asarray(space.nvec)
        u = jax.random.uniform(key, (len(space.nvec),))
        return (u * nvec).astype(leaf_dtype(space))
    if isinstance(space, Box):
        lo = 0.0 if not np.isfinite(space.low) else space.low
        hi = 1.0 if not np.isfinite(space.high) else space.high
        x = jax.random.uniform(key, space.shape, jnp.float32, lo, hi)
        return x.astype(leaf_dtype(space))
    raise TypeError(space)


def get_path(tree, path: tuple):
    for p in path:
        tree = tree[p]
    return tree


def set_path(tree, path: tuple, value):
    """Functional set — rebuilds nested dict/tuple containers."""
    if not path:
        return value
    head, rest = path[0], path[1:]
    if isinstance(tree, dict):
        out = dict(tree)
        out[head] = set_path(tree[head], rest, value)
        return out
    if isinstance(tree, tuple):
        out = list(tree)
        out[head] = set_path(tree[head], rest, value)
        return tuple(out)
    raise TypeError(tree)


def num_actions(space: Space) -> tuple:
    """Flatten an action space tree to a single MultiDiscrete nvec — the
    paper's action emulation. Continuous action leaves are handled separately
    (beyond-paper; see emulation.ContinuousActionHead)."""
    nvec = []
    for _, leaf in leaves(space):
        if isinstance(leaf, Discrete):
            nvec.append(leaf.n)
        elif isinstance(leaf, MultiDiscrete):
            nvec.extend(leaf.nvec)
        else:
            raise TypeError(
                f"discrete action emulation got {leaf}; use continuous head")
    return tuple(nvec)
