"""Pure-functional environment protocol.

The TPU analogue of a Gym env: ``init``/``reset``/``step`` are pure, jittable
functions over a state pytree. All randomness is explicit (keys), all shapes
static. ``info`` is a fixed-shape pytree with a validity flag — the TPU
analogue of the paper's "empty infos are pruned" (no host sync unless you
fetch them).

Multiagent envs return agent-major arrays in canonical (index) order with a
live-agent mask; ``done`` is episode-scoped. This bakes the paper's canonical
sorting + padding guarantees into the protocol itself.
"""
from __future__ import annotations

from typing import Any, Protocol, runtime_checkable

import jax
import jax.numpy as jnp

from repro.core import spaces as sp


def empty_info():
    return {
        "score": jnp.zeros((), jnp.float32),
        "episode_return": jnp.zeros((), jnp.float32),
        "episode_length": jnp.zeros((), jnp.int32),
        "valid": jnp.zeros((), jnp.bool_),   # True only on episode end
    }


def make_info(score, episode_return, episode_length):
    return {
        "score": jnp.asarray(score, jnp.float32),
        "episode_return": jnp.asarray(episode_return, jnp.float32),
        "episode_length": jnp.asarray(episode_length, jnp.int32),
        "valid": jnp.ones((), jnp.bool_),
    }


@runtime_checkable
class Env(Protocol):
    observation_space: sp.Space
    action_space: sp.Space
    num_agents: int

    def init(self, key) -> Any: ...
    def reset(self, state, key): ...          # -> (state, obs)
    def step(self, state, action, key): ...   # -> (state, obs, rew, done, info)
