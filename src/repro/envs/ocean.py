"""Puffer Ocean (paper §4) in pure JAX.

Each environment is trivial with a correct PPO implementation and impossible
with one specific common bug. All train in well under a minute on one CPU
core; the whole suite is a coffee-break sanity check, never a benchmark.

  Squared     — dense shaped reward; catches reward/advantage sign bugs.
  Password    — sparse exploration; catches premature determinization.
  Stochastic  — optimal policy is nonuniform-stochastic; catches entropy bugs.
  Memory      — recall after delay; catches broken recurrent state handling.
  Multiagent  — per-agent credit; catches agent-ordering scrambles.
  Spaces      — nested Dict obs + Dict action; catches emulation bugs.
  Bandit      — classic multiarmed bandit; catches value-baseline bugs.
  Continuous  — Box actions through a Gaussian head (beyond-paper: the
                paper lists continuous actions as unsupported, §8).

Ocean II (this repo's scenario expansion) — each stresses a distinct code
path the original eight leave untested:

  Pong        — pixel-grid 2D Box obs through the CNN frontend; catches
                obs-layout scrambles between emulation and the encoder.
  Drone       — multi-dim Box actions through the Gaussian head; catches
                per-component action-dim mixups.
  TagTeam     — two competing teams with per-team shared reward and
                padded agent rows (pad_agents); catches team credit
                assignment and dead-agent masking bugs.
  Maze        — per-episode procedurally generated layout; catches stale
                procgen keys through autoreset (every episode must get a
                fresh maze).

Scores are normalized so "solved" is score > 0.9 (paper: ~30k interactions).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import spaces as sp
from repro.envs.base import empty_info, make_info


def _end_info(done, ep_return, t, score):
    info = empty_info()
    return {
        "score": jnp.where(done, score, 0.0).astype(jnp.float32),
        "episode_return": jnp.where(done, ep_return, 0.0).astype(jnp.float32),
        "episode_length": jnp.where(done, t, 0).astype(jnp.int32),
        "valid": done,
    }


class Squared:
    """Agent starts at the center of a g×g grid; targets on the perimeter.
    Reward = 1 − normalized L∞ distance to the closest unhit target ∈ [−1, 1];
    hit targets stop paying; episode ends when all are hit (or at horizon).
    Score = return / optimal return (perfect perimeter sweep) ∈ [0, 1]."""

    num_agents = 1

    def __init__(self, size: int = 5, horizon: int = 32):
        assert size % 2 == 1
        self.size, self.horizon = size, horizon
        self.observation_space = sp.Box((size, size))
        self.action_space = sp.Discrete(5)        # stay, N, S, W, E
        g = size
        per = np.zeros((g, g), bool)
        per[0, :] = per[-1, :] = per[:, 0] = per[:, -1] = True
        self._perimeter = jnp.asarray(per)
        self._coords = jnp.stack(jnp.meshgrid(jnp.arange(g), jnp.arange(g),
                                              indexing="ij"), -1)  # (g,g,2)
        # optimal return: approach rewards + one reward-1 per perimeter cell
        r = g // 2
        self._optimal = float(sum(1.0 - d / r for d in range(1, r))
                              + 4 * (g - 1))

    def init(self, key):
        g = self.size
        return {"pos": jnp.full((2,), g // 2, jnp.int32),
                "hit": jnp.zeros((g, g), jnp.bool_),
                "t": jnp.zeros((), jnp.int32),
                "ret": jnp.zeros((), jnp.float32)}

    def reset(self, state, key):
        return self.init(key), self._obs(self.init(key))

    def _obs(self, s):
        g = self.size
        grid = jnp.where(self._perimeter & ~s["hit"], 0.5, 0.0)
        return grid.at[s["pos"][0], s["pos"][1]].set(1.0)

    def step(self, state, action, key):
        g = self.size
        moves = jnp.asarray([[0, 0], [-1, 0], [1, 0], [0, -1], [0, 1]])
        pos = jnp.clip(state["pos"] + moves[action], 0, g - 1)
        active = self._perimeter & ~state["hit"]
        dist = jnp.max(jnp.abs(self._coords - pos), -1)          # L-inf
        d = jnp.min(jnp.where(active, dist, g * 2))
        any_left = jnp.any(active)
        reward = jnp.where(any_left, 1.0 - d.astype(jnp.float32) / (g // 2), 0.0)
        hit = state["hit"] | (active & jnp.all(self._coords == pos, -1))
        t = state["t"] + 1
        ret = state["ret"] + reward
        done = (t >= self.horizon) | jnp.all(hit | ~self._perimeter)
        score = jnp.clip(ret / self._optimal, 0.0, 1.0)
        s2 = {"pos": pos, "hit": hit, "t": t, "ret": ret}
        return s2, self._obs(s2), reward, done, _end_info(done, ret, t, score)


class Password:
    """Guess a static binary string, one bit per step; reward only if the
    whole string matches. Tests exploration / premature determinization."""

    num_agents = 1
    PASSWORD = (1, 0, 1, 1, 0)

    def __init__(self):
        self.length = len(self.PASSWORD)
        self.observation_space = sp.Box((self.length,))
        self.action_space = sp.Discrete(2)
        self._pw = jnp.asarray(self.PASSWORD, jnp.int32)

    def init(self, key):
        return {"t": jnp.zeros((), jnp.int32),
                "ok": jnp.ones((), jnp.bool_)}

    def reset(self, state, key):
        s = self.init(key)
        return s, self._obs(s)

    def _obs(self, s):
        return jax.nn.one_hot(s["t"] % self.length, self.length)

    def step(self, state, action, key):
        ok = state["ok"] & (action == self._pw[state["t"]])
        t = state["t"] + 1
        done = t >= self.length
        reward = jnp.where(done & ok, 1.0, 0.0)
        score = reward
        s2 = {"t": t, "ok": ok}
        return s2, self._obs(s2), reward, done, _end_info(done, reward, t, score)


class Stochastic:
    """Optimal policy plays action 0 with probability p. The observation is
    constant, so only a *stochastic* policy scores > 0.9: score at episode end
    is max(0, 1 − 2·|freq₀ − p|)."""

    num_agents = 1

    def __init__(self, p: float = 0.75, horizon: int = 64):
        self.p, self.horizon = p, horizon
        self.observation_space = sp.Box((1,))
        self.action_space = sp.Discrete(2)

    def init(self, key):
        return {"t": jnp.zeros((), jnp.int32),
                "count0": jnp.zeros((), jnp.int32)}

    def reset(self, state, key):
        s = self.init(key)
        return s, jnp.zeros((1,))

    def step(self, state, action, key):
        count0 = state["count0"] + (action == 0).astype(jnp.int32)
        t = state["t"] + 1
        done = t >= self.horizon
        freq = count0.astype(jnp.float32) / t.astype(jnp.float32)
        score = jnp.maximum(0.0, 1.0 - 2.0 * jnp.abs(freq - self.p))
        reward = jnp.where(done, score, 0.0)
        s2 = {"t": t, "count0": count0}
        return s2, jnp.zeros((1,)), reward, done, _end_info(done, reward, t, score)


class Memory:
    """Repeat an observed random bit sequence after a delay. Obs shows the
    sequence one symbol at a time, then zeros; actions during the recall phase
    must reproduce it. Unsolvable without memory (recurrent policy)."""

    num_agents = 1

    def __init__(self, length: int = 3):
        self.length = length
        self.horizon = 2 * length
        self.observation_space = sp.Box((3,))   # one-hot: [silent, bit0, bit1]
        self.action_space = sp.Discrete(2)

    def init(self, key):
        seq = jax.random.bernoulli(key, 0.5, (self.length,)).astype(jnp.int32)
        return {"seq": seq, "t": jnp.zeros((), jnp.int32),
                "correct": jnp.zeros((), jnp.int32)}

    def reset(self, state, key):
        s = self.init(key)
        return s, self._obs(s)

    def _obs(self, s):
        t, L = s["t"], self.length
        showing = t < L
        sym = jnp.where(showing, s["seq"][jnp.minimum(t, L - 1)] + 1, 0)
        return jax.nn.one_hot(sym, 3)

    def step(self, state, action, key):
        t, L = state["t"], self.length
        recall = t >= L
        target = state["seq"][jnp.clip(t - L, 0, L - 1)]
        hit = recall & (action == target)
        correct = state["correct"] + hit.astype(jnp.int32)
        reward = jnp.where(hit, 1.0 / L, 0.0)
        t2 = t + 1
        done = t2 >= self.horizon
        score = correct.astype(jnp.float32) / L
        s2 = {"seq": state["seq"], "t": t2, "correct": correct}
        ret = score  # episodic return equals score here
        return s2, self._obs(s2), reward, done, _end_info(done, ret, t2, score)


class Multiagent:
    """Agent 0 must pick action 0; agent 1 must pick action 1. Catches any
    scramble of the canonical agent ordering (score pins to 0.5)."""

    num_agents = 2

    def __init__(self, horizon: int = 8):
        self.horizon = horizon
        self.observation_space = sp.Box((2,))    # per-agent one-hot id
        self.action_space = sp.Discrete(2)

    def init(self, key):
        return {"t": jnp.zeros((), jnp.int32),
                "ret": jnp.zeros((2,), jnp.float32)}

    def reset(self, state, key):
        s = self.init(key)
        return s, jnp.eye(2)

    def step(self, state, action, key):
        # action: (2,) — agent-major, canonical order
        correct = (action == jnp.arange(2)).astype(jnp.float32)
        ret = state["ret"] + correct
        t = state["t"] + 1
        done = t >= self.horizon
        score = jnp.mean(ret) / self.horizon
        s2 = {"t": t, "ret": ret}
        info = _end_info(done, jnp.sum(ret), t, score)
        return s2, jnp.eye(2), correct, done, info


class Spaces:
    """Hierarchical observation AND action spaces. A hidden bit lives in the
    center of obs["image"] and another in obs["flat"][0]; action "a" must match
    the image bit and action "b" the flat bit. Maximal score requires using
    every subspace — a learned end-to-end test of emulation."""

    num_agents = 1

    def __init__(self, horizon: int = 8):
        self.horizon = horizon
        self.observation_space = sp.Dict({
            "image": sp.Box((3, 3)),
            "flat": sp.Box((4,)),
        })
        self.action_space = sp.Dict({
            "a": sp.Discrete(2),
            "b": sp.Discrete(2),
        })

    def init(self, key):
        k1, k2 = jax.random.split(key)
        return {"img_bit": jax.random.bernoulli(k1).astype(jnp.int32),
                "flat_bit": jax.random.bernoulli(k2).astype(jnp.int32),
                "t": jnp.zeros((), jnp.int32),
                "ret": jnp.zeros((), jnp.float32)}

    def reset(self, state, key):
        s = self.init(key)
        return s, self._obs(s)

    def _obs(self, s):
        img = jnp.zeros((3, 3)).at[1, 1].set(s["img_bit"].astype(jnp.float32))
        flat = jnp.zeros((4,)).at[0].set(s["flat_bit"].astype(jnp.float32))
        return {"image": img, "flat": flat}

    def step(self, state, action, key):
        ra = (action["a"] == state["img_bit"]).astype(jnp.float32)
        rb = (action["b"] == state["flat_bit"]).astype(jnp.float32)
        reward = 0.5 * ra + 0.5 * rb
        ret = state["ret"] + reward
        t = state["t"] + 1
        done = t >= self.horizon
        k1, k2, _ = jax.random.split(key, 3)
        s2 = {"img_bit": jax.random.bernoulli(k1).astype(jnp.int32),
              "flat_bit": jax.random.bernoulli(k2).astype(jnp.int32),
              "t": t, "ret": ret}
        score = ret / self.horizon
        return s2, self._obs(s2), reward, done, _end_info(done, ret, t, score)


class Bandit:
    """Classic multiarmed bandit: stochastic payouts, fixed arm probabilities.
    Score = mean reward / best-arm payout."""

    num_agents = 1
    PROBS = (0.2, 0.5, 0.1, 0.9)

    def __init__(self, horizon: int = 16):
        self.horizon = horizon
        self.observation_space = sp.Box((1,))
        self.action_space = sp.Discrete(len(self.PROBS))
        self._probs = jnp.asarray(self.PROBS)

    def init(self, key):
        return {"t": jnp.zeros((), jnp.int32),
                "ret": jnp.zeros((), jnp.float32)}

    def reset(self, state, key):
        return self.init(key), jnp.zeros((1,))

    def step(self, state, action, key):
        reward = jax.random.bernoulli(key, self._probs[action]).astype(jnp.float32)
        ret = state["ret"] + reward
        t = state["t"] + 1
        done = t >= self.horizon
        score = ret / (self.horizon * max(self.PROBS))
        s2 = {"t": t, "ret": ret}
        return s2, jnp.zeros((1,)), reward, done, _end_info(done, ret, t, score)


OCEAN = {
    "squared": Squared,
    "password": Password,
    "stochastic": Stochastic,
    "memory": Memory,
    "multiagent": Multiagent,
    "spaces": Spaces,
    "bandit": Bandit,
}


def make(name: str, **kw):
    return OCEAN[name](**kw)


class Continuous:
    """1-D target tracking with a continuous Box action — exercises the
    Gaussian policy head (the paper's §8 limitation, supported here).
    Reward per step = 1 − |pos − target|; optimal is a one-step jump."""

    num_agents = 1

    def __init__(self, horizon: int = 16):
        self.horizon = horizon
        self.observation_space = sp.Box((2,))
        self.action_space = sp.Box((1,), low=-1.0, high=1.0)

    def init(self, key):
        return {"pos": jnp.zeros(()), 
                "target": jax.random.uniform(key, (), minval=-0.8,
                                             maxval=0.8),
                "t": jnp.zeros((), jnp.int32),
                "ret": jnp.zeros(())}

    def reset(self, state, key):
        s = self.init(key)
        return s, self._obs(s)

    def _obs(self, s):
        return jnp.stack([s["pos"], s["target"]])

    def step(self, state, action, key):
        a = jnp.clip(jnp.reshape(action, ()), -1.0, 1.0)
        pos = jnp.clip(state["pos"] + a, -1.0, 1.0)
        reward = 1.0 - jnp.abs(pos - state["target"])
        ret = state["ret"] + reward
        t = state["t"] + 1
        done = t >= self.horizon
        score = jnp.clip(ret / self.horizon, 0.0, 1.0)
        s2 = {"pos": pos, "target": state["target"], "t": t, "ret": ret}
        return s2, self._obs(s2), reward, done, _end_info(done, ret, t, score)

OCEAN["continuous"] = Continuous


# =========================== Ocean II ========================================
# Scenario expansion: four envs that each stress a code path the original
# eight leave untested (CNN frontend, multi-dim Gaussian actions, per-team
# reward + agent padding, per-episode procgen keys through autoreset).


class Pong:
    """Pixel Pong (catch variant): a ball falls from the top row with a fixed
    per-episode horizontal drift, bouncing off the side walls; a 3-wide paddle
    on the bottom row moves left/right to catch it. The observation is the
    raw 2D pixel grid — the one Ocean env whose obs is an image, exercising
    the CNN frontend end-to-end through emulation (which flattens it) and the
    policy (which restores it). Score = 1 on catch, 0 on miss."""

    num_agents = 1
    obs_frontend = "conv"            # Trainer: route through the CNN encoder

    def __init__(self, rows: int = 6, cols: int = 6):
        assert rows >= 3 and cols >= 3
        self.rows, self.cols = rows, cols
        self.horizon = rows - 1      # ball falls one row per step
        self.observation_space = sp.Box((rows, cols))
        self.action_space = sp.Discrete(3)       # stay, left, right

    def init(self, key):
        k_col, k_dx = jax.random.split(key)
        return {"ball": jnp.stack([jnp.zeros((), jnp.int32),
                                   jax.random.randint(k_col, (), 0, self.cols)]),
                "dx": jax.random.randint(k_dx, (), -1, 2).astype(jnp.int32),
                "paddle": jnp.asarray(self.cols // 2, jnp.int32),
                "t": jnp.zeros((), jnp.int32)}

    def reset(self, state, key):
        s = self.init(key)
        return s, self._obs(s)

    def _obs(self, s):
        grid = jnp.zeros((self.rows, self.cols))
        pad = jnp.clip(s["paddle"] + jnp.arange(-1, 2), 0, self.cols - 1)
        grid = grid.at[self.rows - 1, pad].set(0.5)
        return grid.at[s["ball"][0], s["ball"][1]].set(1.0)

    def step(self, state, action, key):
        moves = jnp.asarray([0, -1, 1])
        paddle = jnp.clip(state["paddle"] + moves[action], 0, self.cols - 1)
        # ball falls one row; horizontal drift reflects off the side walls
        col, dx = state["ball"][1] + state["dx"], state["dx"]
        bounce = (col < 0) | (col >= self.cols)
        dx = jnp.where(bounce, -dx, dx)
        col = jnp.clip(col, 0, self.cols - 1)
        row = state["ball"][0] + 1
        t = state["t"] + 1
        done = row >= self.rows - 1
        caught = done & (jnp.abs(col - paddle) <= 1)
        reward = caught.astype(jnp.float32)
        score = reward
        s2 = {"ball": jnp.stack([row, col]), "dx": dx, "paddle": paddle,
              "t": t}
        return s2, self._obs(s2), reward, done, _end_info(done, reward, t,
                                                          score)


class Drone:
    """3-D waypoint flight: reach and hover at a random target with a
    Box((3,)) thrust action — the multi-dim continuous control case
    (``Continuous`` is 1-D, so a transposed/mixed action component bug is
    invisible there). Reward per step = max(0, 1 − distance/2);
    score = return / horizon."""

    num_agents = 1

    def __init__(self, horizon: int = 16, thrust: float = 0.5):
        self.horizon, self.thrust = horizon, thrust
        self.observation_space = sp.Box((6,))     # [pos ‖ target]
        self.action_space = sp.Box((3,), low=-1.0, high=1.0)

    def init(self, key):
        return {"pos": jnp.zeros((3,)),
                "target": jax.random.uniform(key, (3,), minval=-0.8,
                                             maxval=0.8),
                "t": jnp.zeros((), jnp.int32),
                "ret": jnp.zeros(())}

    def reset(self, state, key):
        s = self.init(key)
        return s, self._obs(s)

    def _obs(self, s):
        return jnp.concatenate([s["pos"], s["target"]])

    def step(self, state, action, key):
        a = jnp.clip(jnp.reshape(action, (3,)), -1.0, 1.0)
        pos = jnp.clip(state["pos"] + self.thrust * a, -1.0, 1.0)
        reward = jnp.maximum(
            0.0, 1.0 - 0.5 * jnp.linalg.norm(pos - state["target"]))
        ret = state["ret"] + reward
        t = state["t"] + 1
        done = t >= self.horizon
        score = jnp.clip(ret / self.horizon, 0.0, 1.0)
        s2 = {"pos": pos, "target": state["target"], "t": t, "ret": ret}
        return s2, self._obs(s2), reward, done, _end_info(done, ret, t, score)


class TagTeam:
    """Two competing teams with *per-team* shared reward and padded agent
    rows. Four live agents (team 0: agents 0–1, team 1: agents 2–3) observe
    a common signal bit; team 0 must match it, team 1 must play its
    complement. Each agent's reward is its **team mean** correctness, so any
    per-agent credit scramble or team mixup pins the score at 0.5. The env
    declares ``num_agents = 6`` and pads the two dead rows with
    ``pad_agents`` — exercising the fixed-size agent padding path end to end
    (padded rows: zero obs, zero reward, excluded from the score)."""

    num_agents = 6
    LIVE = 4                         # 2 teams × 2 agents; rows 4–5 are padding

    def __init__(self, horizon: int = 8):
        self.horizon = horizon
        self.observation_space = sp.Box((4,))    # [team0, team1, signal, live]
        self.action_space = sp.Discrete(2)

    def init(self, key):
        return {"signal": jax.random.bernoulli(key).astype(jnp.int32),
                "t": jnp.zeros((), jnp.int32),
                "ret": jnp.zeros((self.num_agents,), jnp.float32)}

    def reset(self, state, key):
        s = self.init(key)
        return s, self._obs(s)

    def _obs(self, s):
        from repro.core.emulation import pad_agents
        team = jnp.asarray([0, 0, 1, 1], jnp.int32)
        live = jnp.stack([
            (team == 0).astype(jnp.float32),
            (team == 1).astype(jnp.float32),
            jnp.full((self.LIVE,), s["signal"], jnp.float32),
            jnp.ones((self.LIVE,)),
        ], axis=-1)                               # (LIVE, 4) agent-major
        obs, _ = pad_agents(live, jnp.ones((self.LIVE,), bool),
                            self.num_agents)
        return obs

    def step(self, state, action, key):
        live = action[:self.LIVE]
        want = jnp.asarray([0, 0, 1, 1]) ^ state["signal"]   # team target
        correct = (live == want).astype(jnp.float32)
        team_rew = jnp.stack([jnp.mean(correct[:2]), jnp.mean(correct[2:])])
        reward = jnp.concatenate([jnp.repeat(team_rew, 2),
                                  jnp.zeros((self.num_agents - self.LIVE,))])
        ret = state["ret"] + reward
        t = state["t"] + 1
        done = t >= self.horizon
        score = jnp.sum(ret[:self.LIVE]) / (self.LIVE * self.horizon)
        s2 = {"signal": jax.random.bernoulli(key).astype(jnp.int32),
              "t": t, "ret": ret}
        info = _end_info(done, jnp.sum(ret[:self.LIVE]), t, score)
        return s2, self._obs(s2), reward, done, info


class Maze:
    """Per-episode procedurally generated maze: wall pillars, start, and goal
    are all drawn from the episode's reset key, so a stale procgen key
    anywhere in the autoreset path shows up as every episode replaying the
    same maze. Walls occupy a random subset of the odd-odd "pillar" cells —
    a layout that can never disconnect the grid (even rows stay fully open),
    so every maze is solvable. Reward per step is the fraction of the
    initial Manhattan distance closed; score = fraction closed by episode
    end ∈ [0, 1] (reaching the goal scores 1 regardless of path taken)."""

    num_agents = 1

    def __init__(self, size: int = 7, horizon: int = 24):
        assert size % 2 == 1 and size >= 5
        self.size, self.horizon = size, horizon
        self.observation_space = sp.Box((size, size))
        self.action_space = sp.Discrete(5)        # stay, N, S, W, E
        k = size // 2 + 1                         # even-coordinate grid side
        cells = jnp.stack(jnp.meshgrid(jnp.arange(k) * 2, jnp.arange(k) * 2,
                                       indexing="ij"), -1).reshape(-1, 2)
        self._open_cells = cells                  # never walled

    def init(self, key):
        k_w, k_s, k_t = jax.random.split(key, 3)
        p = self.size // 2                        # pillar grid side
        pillars = jax.random.bernoulli(k_w, 0.5, (p, p))
        walls = jnp.zeros((self.size, self.size), jnp.bool_)
        walls = walls.at[1::2, 1::2].set(pillars)
        n = self._open_cells.shape[0]
        start = self._open_cells[jax.random.randint(k_s, (), 0, n)]
        target = self._open_cells[jax.random.randint(k_t, (), 0, n)]
        d0 = jnp.sum(jnp.abs(start - target))
        return {"pos": start.astype(jnp.int32),
                "target": target.astype(jnp.int32),
                "walls": walls,
                "d0": d0.astype(jnp.int32),
                "t": jnp.zeros((), jnp.int32)}

    def reset(self, state, key):
        s = self.init(key)
        return s, self._obs(s)

    def _obs(self, s):
        grid = jnp.where(s["walls"], 0.25, 0.0)
        grid = grid.at[s["target"][0], s["target"][1]].set(0.75)
        return grid.at[s["pos"][0], s["pos"][1]].set(1.0)

    def step(self, state, action, key):
        g = self.size
        moves = jnp.asarray([[0, 0], [-1, 0], [1, 0], [0, -1], [0, 1]])
        cand = state["pos"] + moves[action]
        inside = jnp.all((cand >= 0) & (cand < g))
        blocked = state["walls"][jnp.clip(cand[0], 0, g - 1),
                                 jnp.clip(cand[1], 0, g - 1)]
        pos = jnp.where(inside & ~blocked, cand, state["pos"])
        d_prev = jnp.sum(jnp.abs(state["pos"] - state["target"]))
        d = jnp.sum(jnp.abs(pos - state["target"]))
        denom = jnp.maximum(state["d0"], 1).astype(jnp.float32)
        reward = (d_prev - d).astype(jnp.float32) / denom
        t = state["t"] + 1
        done = (d == 0) | (t >= self.horizon)
        closed = (state["d0"] - d).astype(jnp.float32) / denom
        score = jnp.clip(jnp.where(state["d0"] == 0, 1.0, closed), 0.0, 1.0)
        s2 = {"pos": pos, "target": state["target"], "walls": state["walls"],
              "d0": state["d0"], "t": t}
        return s2, self._obs(s2), reward, done, _end_info(done, closed, t,
                                                          score)


class Duel:
    """Two-player zero-sum grid duel — the Policy League's native workload.

    Both agents race on a g×g grid for a coin; the first to reach it takes
    +1 from the other (simultaneous arrival is a wash) and the coin respawns
    from the step key. A dense shaping term transfers reward for relative
    progress toward the coin, so every step's reward vector sums to exactly
    zero — the defining invariant of a competitive env, and what the
    ``check_selfplay_env`` conformance profile asserts.

    Roles are symmetric: ``swap_agents`` permutes the agent rows of the
    state, and stepping the swapped state with swapped actions yields the
    swapped outputs (obs/reward rows reversed, same done/coin). Score is
    agent-0-centric: 0.5 + (caps₀ − caps₁) / 2·max(1, caps₀ + caps₁) ∈
    [0, 1], so 0.5 is a tie and "winrate vs opponent" is score > 0.5."""

    num_agents = 2
    SHAPING = 0.05                   # zero-sum per-step progress transfer

    def __init__(self, size: int = 5, horizon: int = 32):
        self.size, self.horizon = size, horizon
        self.observation_space = sp.Box((7,))  # [own ‖ opp ‖ coin ‖ t/H]
        self.action_space = sp.Discrete(5)     # stay, N, S, W, E

    def init(self, key):
        k0, k1, kc = jax.random.split(key, 3)
        g = self.size
        pos = jnp.stack([jax.random.randint(k0, (2,), 0, g),
                         jax.random.randint(k1, (2,), 0, g)])
        return {"pos": pos.astype(jnp.int32),
                "coin": jax.random.randint(kc, (2,), 0, g).astype(jnp.int32),
                "caps": jnp.zeros((2,), jnp.int32),
                "ret": jnp.zeros((2,), jnp.float32),
                "t": jnp.zeros((), jnp.int32)}

    def reset(self, state, key):
        s = self.init(key)
        return s, self._obs(s)

    def swap_agents(self, state):
        """Agent-row permutation of the state — the role-swap symmetry the
        selfplay conformance profile checks is ``step ∘ swap == swap ∘ step``
        (with actions permuted too)."""
        return {"pos": state["pos"][::-1], "coin": state["coin"],
                "caps": state["caps"][::-1], "ret": state["ret"][::-1],
                "t": state["t"]}

    def _obs(self, s):
        g = float(self.size - 1)
        own = s["pos"].astype(jnp.float32) / g                    # (2, 2)
        opp = own[::-1]
        coin = jnp.broadcast_to(s["coin"].astype(jnp.float32) / g, (2, 2))
        tt = jnp.full((2, 1), s["t"].astype(jnp.float32) / self.horizon)
        return jnp.concatenate([own, opp, coin, tt], axis=-1)     # (2, 7)

    def step(self, state, action, key):
        g = self.size
        moves = jnp.asarray([[0, 0], [-1, 0], [1, 0], [0, -1], [0, 1]])
        pos = jnp.clip(state["pos"] + moves[action], 0, g - 1)    # (2, 2)
        # zero-sum shaping: transfer for relative progress toward the coin
        d_prev = jnp.sum(jnp.abs(state["pos"] - state["coin"]), -1)
        d_new = jnp.sum(jnp.abs(pos - state["coin"]), -1)
        prog = (d_prev - d_new).astype(jnp.float32)               # (2,)
        shaped0 = self.SHAPING * (prog[0] - prog[1])
        # capture: sole arrival takes +1 from the other; both → wash
        on = jnp.all(pos == state["coin"], -1)                    # (2,) bool
        sole = on & ~on[::-1]
        cap0 = sole[0].astype(jnp.float32) - sole[1].astype(jnp.float32)
        r0 = shaped0 + cap0
        reward = jnp.stack([r0, -r0])                             # sums to 0
        caps = state["caps"] + sole.astype(jnp.int32)
        coin = jnp.where(jnp.any(on),
                         jax.random.randint(key, (2,), 0, g), state["coin"])
        ret = state["ret"] + reward
        t = state["t"] + 1
        done = t >= self.horizon
        total = jnp.maximum(1, caps[0] + caps[1]).astype(jnp.float32)
        score = jnp.clip(
            0.5 + (caps[0] - caps[1]).astype(jnp.float32) / (2.0 * total),
            0.0, 1.0)
        s2 = {"pos": pos, "coin": coin.astype(jnp.int32), "caps": caps,
              "ret": ret, "t": t}
        info = _end_info(done, ret[0], t, score)
        return s2, self._obs(s2), reward, done, info


OCEAN["pong"] = Pong
OCEAN["drone"] = Drone
OCEAN["tagteam"] = TagTeam
OCEAN["maze"] = Maze
OCEAN["duel"] = Duel
