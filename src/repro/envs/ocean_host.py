"""Ocean host mirrors — pure-Python/numpy twins of JAX Ocean envs.

These exist so the HostBridge has *exact parity targets*: each mirror
reimplements an Ocean env's dynamics with plain numpy state (the shape of a
real third-party env — NetHack, Atari — that can't live inside jit), and
deliberately speaks a different host API so ``bridge.wrap``'s auto-detection
is exercised end to end:

  HostBandit   — duck-typed  (``reset(seed) -> obs``, 4-tuple ``step``);
                 mirror of ``ocean.Bandit``.
  HostSquared  — duck-typed; mirror of ``ocean.Squared``.
  HostDrone    — Gymnasium API (``reset(seed=...) -> (obs, info)``, 5-tuple
                 ``step``, real ``gymnasium.spaces`` when installed, duck
                 stand-ins otherwise); mirror of ``ocean.Drone``.
  HostTeam     — PettingZoo-parallel API (``possible_agents`` + per-agent
                 dicts); mirror of ``ocean.Multiagent``.

Terminal step ``info`` carries ``"score"`` normalized to [0, 1] exactly like
the JAX originals, so ``target_score``-driven training and the parity tests
(`host` tier on the mirror vs `jit` tier on the original, same training
params) compare like for like. Optional ``jitter_ms`` injects lognormal step
latency — the NetHack/Crafter-shaped variance the paper's EnvPool exploits —
for the sync-vs-async benchmark (``benchmarks/bench_bridge.py``).
"""
from __future__ import annotations

import time
from typing import Optional

import numpy as np

from repro.core import spaces as sp

try:                                            # real Gymnasium when present
    from gymnasium import spaces as _gym_spaces
except ImportError:                             # duck stand-ins otherwise
    _gym_spaces = None


class _Jitter:
    """Optional lognormal step latency (mean ``jitter_ms``, σ=0.6)."""

    def __init__(self, jitter_ms: float, seed: int):
        self.jitter_ms = jitter_ms
        self.rng = np.random.RandomState(seed)

    def sleep(self):
        if self.jitter_ms > 0:
            time.sleep(self.rng.lognormal(
                np.log(self.jitter_ms), 0.6) / 1e3)


# ---------------------------------------------------------------------------


class HostBandit:
    """Duck-typed mirror of ``ocean.Bandit``: stochastic arm payouts, score
    = return / best-arm payout."""

    PROBS = (0.2, 0.5, 0.1, 0.9)

    def __init__(self, horizon: int = 16, jitter_ms: float = 0.0,
                 jitter_seed: int = 0):
        self.horizon = horizon
        self.observation_space = sp.Box((1,))
        self.action_space = sp.Discrete(len(self.PROBS))
        self._jit = _Jitter(jitter_ms, jitter_seed)
        self.rng: Optional[np.random.RandomState] = None
        self.t, self.ret = 0, 0.0

    def reset(self, seed):
        self.rng = np.random.RandomState(
            None if seed is None else int(seed) % (2 ** 32))
        self.t, self.ret = 0, 0.0
        return np.zeros((1,), np.float32)

    def step(self, action):
        self._jit.sleep()
        rew = float(self.rng.random_sample() < self.PROBS[int(action)])
        self.t += 1
        self.ret += rew
        done = self.t >= self.horizon
        info = {}
        if done:
            info["score"] = min(
                1.0, self.ret / (self.horizon * max(self.PROBS)))
        return np.zeros((1,), np.float32), rew, done, info


class HostSquared:
    """Duck-typed mirror of ``ocean.Squared``: perimeter targets on a g×g
    grid, reward = 1 − normalized L∞ distance to the closest unhit target."""

    def __init__(self, size: int = 5, horizon: int = 32):
        assert size % 2 == 1
        self.size, self.horizon = size, horizon
        self.observation_space = sp.Box((size, size))
        self.action_space = sp.Discrete(5)      # stay, N, S, W, E
        g = size
        per = np.zeros((g, g), bool)
        per[0, :] = per[-1, :] = per[:, 0] = per[:, -1] = True
        self._perimeter = per
        ii, jj = np.meshgrid(np.arange(g), np.arange(g), indexing="ij")
        self._coords = np.stack([ii, jj], -1)
        r = g // 2
        self._optimal = float(sum(1.0 - d / r for d in range(1, r))
                              + 4 * (g - 1))
        self.pos = None
        self.hit = None
        self.t, self.ret = 0, 0.0

    def reset(self, seed):
        g = self.size
        self.pos = np.array([g // 2, g // 2])
        self.hit = np.zeros((g, g), bool)
        self.t, self.ret = 0, 0.0
        return self._obs()

    def _obs(self):
        grid = np.where(self._perimeter & ~self.hit, 0.5, 0.0)
        grid[self.pos[0], self.pos[1]] = 1.0
        return grid.astype(np.float32)

    def step(self, action):
        g = self.size
        moves = np.array([[0, 0], [-1, 0], [1, 0], [0, -1], [0, 1]])
        self.pos = np.clip(self.pos + moves[int(action)], 0, g - 1)
        active = self._perimeter & ~self.hit
        dist = np.max(np.abs(self._coords - self.pos), -1)
        d = np.min(np.where(active, dist, g * 2))
        reward = float(1.0 - d / (g // 2)) if active.any() else 0.0
        if active[self.pos[0], self.pos[1]]:
            self.hit[self.pos[0], self.pos[1]] = True
        self.t += 1
        self.ret += reward
        done = (self.t >= self.horizon
                or bool(np.all(self.hit | ~self._perimeter)))
        info = {}
        if done:
            info["score"] = float(np.clip(self.ret / self._optimal, 0.0, 1.0))
        return self._obs(), reward, done, info


class HostCrafterLite:
    """Duck-typed Crafter-shaped gridworld whose step cost is *pure-Python
    bytecode* — the workload class where thread pools serialize on the GIL
    and ``backend="proc"`` actually parallelizes.

    The agent walks a g×g grid, gathers wood/stone nodes, and crafts tools
    (2 wood + 1 stone → reward 1; gather → 0.1). World randomness is a
    64-bit LCG advanced ``work`` times per step — that walk *is* the CPU
    burn (~2 ms at the default ``work`` on a ~2020s core) and it is
    load-bearing: its final state places regrown resources, so the burn
    cannot be optimized away without changing the dynamics. All integer
    arithmetic ⇒ bitwise-deterministic across processes and backends.

    ``sleep_ms`` swaps the burn profile: a GIL-*releasing* ``time.sleep``
    before the (still deterministic) dynamics, for the benchmark cell where
    threads are already optimal and proc must stay within ~15%.

    Score = tools crafted / (horizon // 8), clipped to [0, 1].
    """

    MOVES = ((-1, 0), (1, 0), (0, -1), (0, 1))
    _LCG_MUL = 6364136223846793005
    _LCG_ADD = 1442695040888963407
    _MASK = (1 << 64) - 1

    def __init__(self, size: int = 8, horizon: int = 32,
                 work: int = 20_000, sleep_ms: float = 0.0):
        self.size, self.horizon = size, horizon
        self.work = int(work)
        self.sleep_ms = float(sleep_ms)
        self.observation_space = sp.Box((size * size + 4,))
        self.action_space = sp.Discrete(6)      # N, S, W, E, gather, craft
        self._h = 1
        self.pos = [0, 0]
        self.res: dict = {}                     # cell -> 1 (wood) | 2 (stone)
        self.inv = [0, 0, 0]                    # wood, stone, tools
        self.t, self.tools = 0, 0

    def _mix(self, rounds: int) -> int:
        h = self._h
        mul, add, mask = self._LCG_MUL, self._LCG_ADD, self._MASK
        for _ in range(rounds):
            h = (h * mul + add) & mask
        self._h = h
        return h

    def reset(self, seed):
        s = 0 if seed is None else int(seed)
        self._h = ((s * 2654435761 + 0x9E3779B9) & self._MASK) or 1
        g = self.size
        self.pos = [g // 2, g // 2]
        self.res = {}
        for kind in (1, 2):                     # g wood + g stone nodes
            for _ in range(g):
                self.res.setdefault((self._mix(1) >> 16) % (g * g), kind)
        self.inv = [0, 0, 0]
        self.t, self.tools = 0, 0
        return self._obs()

    def _obs(self):
        g = self.size
        o = np.zeros((g * g + 4,), np.float32)
        for c, kind in self.res.items():
            o[c] = 0.33 * kind
        o[self.pos[0] * g + self.pos[1]] = 1.0
        o[g * g + 0] = self.inv[0] / 8.0
        o[g * g + 1] = self.inv[1] / 8.0
        o[g * g + 2] = self.inv[2] / 8.0
        o[g * g + 3] = self.t / self.horizon
        return o

    def step(self, action):
        if self.sleep_ms > 0:
            time.sleep(self.sleep_ms / 1e3)
        h = self._mix(self.work)                # CPU burn + world rng tick
        a, g = int(action), self.size
        rew = 0.0
        if a < 4:
            self.pos[0] = min(max(self.pos[0] + self.MOVES[a][0], 0), g - 1)
            self.pos[1] = min(max(self.pos[1] + self.MOVES[a][1], 0), g - 1)
        elif a == 4:                            # gather
            kind = self.res.pop(self.pos[0] * g + self.pos[1], 0)
            if kind:
                self.inv[kind - 1] += 1
                rew += 0.1
                self.res.setdefault((h >> 16) % (g * g), kind)  # regrow
        else:                                   # craft: 2 wood + 1 stone
            if self.inv[0] >= 2 and self.inv[1] >= 1:
                self.inv[0] -= 2
                self.inv[1] -= 1
                self.inv[2] += 1
                self.tools += 1
                rew += 1.0
        self.t += 1
        done = self.t >= self.horizon
        info = {}
        if done:
            info["score"] = min(1.0, self.tools / max(1, self.horizon // 8))
        return self._obs(), rew, done, info


# ---------------------------------------------------------------------------


class _DuckBox:
    """Minimal gymnasium.spaces.Box stand-in (shape/dtype/low/high)."""

    def __init__(self, low, high, shape, dtype=np.float32):
        self.low, self.high = np.full(shape, low), np.full(shape, high)
        self.shape, self.dtype = tuple(shape), np.dtype(dtype)


def _gym_box(low, high, shape):
    if _gym_spaces is not None:
        return _gym_spaces.Box(low, high, shape, np.float32)
    return _DuckBox(low, high, shape)


class HostDrone:
    """Gymnasium-API mirror of ``ocean.Drone``: reach and hover at a random
    3-D target with a Box((3,)) thrust action. ``reset(seed=...) ->
    (obs, info)``; ``step -> (obs, rew, terminated, truncated, info)``
    (episodes end by truncation at the horizon, Gymnasium-style)."""

    metadata = {"render_modes": []}

    def __init__(self, horizon: int = 16, thrust: float = 0.5,
                 jitter_ms: float = 0.0, jitter_seed: int = 0):
        self.horizon, self.thrust = horizon, thrust
        self.observation_space = _gym_box(-1.0, 1.0, (6,))
        self.action_space = _gym_box(-1.0, 1.0, (3,))
        self._jit = _Jitter(jitter_ms, jitter_seed)
        self.pos = self.target = None
        self.t, self.ret = 0, 0.0

    def _obs(self):
        return np.concatenate([self.pos, self.target]).astype(np.float32)

    def reset(self, *, seed=None, options=None):
        rng = np.random.RandomState(
            None if seed is None else int(seed) % (2 ** 32))
        self.pos = np.zeros((3,))
        self.target = rng.uniform(-0.8, 0.8, (3,))
        self.t, self.ret = 0, 0.0
        return self._obs(), {}

    def step(self, action):
        self._jit.sleep()
        a = np.clip(np.asarray(action, np.float64).reshape(3), -1.0, 1.0)
        self.pos = np.clip(self.pos + self.thrust * a, -1.0, 1.0)
        reward = max(0.0, 1.0 - 0.5 * float(
            np.linalg.norm(self.pos - self.target)))
        self.t += 1
        self.ret += reward
        truncated = self.t >= self.horizon
        info = {}
        if truncated:
            info["score"] = float(np.clip(self.ret / self.horizon, 0.0, 1.0))
        return self._obs(), reward, False, truncated, info


class HostTeam:
    """PettingZoo-parallel mirror of ``ocean.Multiagent``: agent j must play
    action j; per-agent reward 1 on a match. Score (reported identically in
    every agent's terminal info) = mean correctness, like the original."""

    possible_agents = ("agent_0", "agent_1")

    def __init__(self, horizon: int = 8):
        self.horizon = horizon
        self.agents = list(self.possible_agents)
        self.t = 0
        self.ret = np.zeros((2,))

    def observation_space(self, agent):
        return sp.Box((2,))

    def action_space(self, agent):
        return sp.Discrete(2)

    def _obs(self):
        eye = np.eye(2, dtype=np.float32)
        return {ag: eye[j] for j, ag in enumerate(self.possible_agents)}

    def reset(self, *, seed=None, options=None):
        self.agents = list(self.possible_agents)
        self.t = 0
        self.ret = np.zeros((2,))
        return self._obs(), {ag: {} for ag in self.possible_agents}

    def step(self, actions):
        correct = np.array([float(int(actions[ag]) == j)
                            for j, ag in enumerate(self.possible_agents)])
        self.ret += correct
        self.t += 1
        done = self.t >= self.horizon
        score = float(np.mean(self.ret) / self.horizon)
        infos = {ag: ({"score": score} if done else {})
                 for ag in self.possible_agents}
        if done:
            self.agents = []
        rew = {ag: float(correct[j])
               for j, ag in enumerate(self.possible_agents)}
        term = {ag: done for ag in self.possible_agents}
        trunc = {ag: False for ag in self.possible_agents}
        return self._obs(), rew, term, trunc, infos


OCEAN_HOST = {
    "bandit": HostBandit,
    "squared": HostSquared,
    "crafter": HostCrafterLite,
    "drone": HostDrone,
    "team": HostTeam,
}


def make(name: str, **kw):
    return OCEAN_HOST[name](**kw)
