"""Env-conformance harness: the machine-checkable definition of "plays nice".

The paper's thesis (§3–4) is that one emulation layer lets arbitrary envs run
unchanged through the same training stack. This module pins down the protocol
that claim rests on and verifies it for any env — ours or a user's:

  jit_purity        — init/reset/step trace under jit, don't retrace on a
                      second same-shaped call, and lower with no host
                      callbacks in the jaxpr.
  vmap_purity       — step/reset vmap cleanly (the VecEnv fused path).
  stability         — obs/reward/done/info shapes and dtypes are identical
                      at every step (static shapes are what lets the whole
                      unroll live in one XLA program).
  determinism       — step is a pure function of (state, action, key):
                      same inputs ⇒ bitwise-identical outputs.
  emulation         — emulate∘unemulate is the identity on observations
                      (f32 and bytes modes) and actions, so the Emulated
                      wrapper loses nothing.
  agent_axis        — multi-agent envs are agent-major with a leading
                      num_agents axis on obs/reward and an episode-scoped
                      scalar done.
  autoreset         — under VecEnv the env episodes terminate within the
                      declared horizon, infos carry valid end-of-episode
                      rows, and stepping continues cleanly past resets.
  procgen_keys      — envs whose layout depends on the reset key actually
                      get fresh layouts across episodes (a stale key in the
                      autoreset path is invisible to every other check).
  score_bounds      — episode scores are normalized to [0, 1] with exact
                      info dtypes, so "score > 0.9 ⇒ solved" is comparable
                      across the whole registry.

Library API: ``check_env(env_or_name) -> ConformanceReport``. The pytest
suite (tests/test_conformance.py) parametrizes this over the OCEAN registry;
env authors point it at their own class the same way.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import spaces as sp
from repro.core import emulation as em
from repro.core.vector import VecEnv


@dataclass
class CheckResult:
    name: str
    ok: bool
    violations: tuple = ()           # human-readable strings, empty when ok


@dataclass
class ConformanceReport:
    env_name: str
    results: list = field(default_factory=list)
    # informational cross-link to the zero-execution layer: repro.analysis
    # lint findings in the env's source (never affects ``ok`` — the runtime
    # checks are the verdict; this tells you what a static pass would have
    # caught before ever stepping the env)
    static_findings: tuple = ()

    @property
    def ok(self) -> bool:
        return all(r.ok for r in self.results)

    @property
    def violations(self) -> list:
        return [f"{r.name}: {v}" for r in self.results for v in r.violations]

    def summary(self) -> str:
        lines = [f"conformance report — {self.env_name}: "
                 f"{'OK' if self.ok else 'VIOLATIONS'}"]
        for r in self.results:
            lines.append(f"  [{'pass' if r.ok else 'FAIL'}] {r.name}")
            for v in r.violations:
                lines.append(f"         - {v}")
        if self.static_findings:
            lines.append(f"  static analysis (informational, "
                         f"{len(self.static_findings)} finding(s) — "
                         f"see `python -m repro.analysis`):")
            for f in self.static_findings:
                lines.append(f"         - {f.render()}")
        return "\n".join(lines)

    __str__ = summary


# ---------------------------------------------------------------------------
# helpers

def _horizon(env) -> int:
    return int(getattr(env, "horizon", getattr(env, "length", 64)))


def _sample_action(env, key):
    a = sp.sample(env.action_space, key)
    if env.num_agents > 1:
        a = jax.tree.map(
            lambda x: jnp.stack([x] * env.num_agents), a)
    return a


def _tree_sig(tree):
    """(path, shape, dtype) signature of a pytree — the stability invariant."""
    leaves, _ = jax.tree_util.tree_flatten_with_path(tree)
    return tuple((jax.tree_util.keystr(p), x.shape, str(x.dtype))
                 for p, x in leaves)


def _trees_equal(a, b) -> bool:
    return all(bool(jnp.all(x == y))
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


def _callback_eqns(jaxpr, found=None):
    """Host-callback primitive names in a (closed) jaxpr — delegates to the
    shared scanner in ``repro.analysis`` (one callback definition for the
    static audit and the runtime conformance check)."""
    from repro.analysis import callback_eqns
    found = [] if found is None else found
    found.extend(name for name, _eqn in callback_eqns(jaxpr))
    return found


# ---------------------------------------------------------------------------
# individual checks — each returns a list of violation strings

def check_jit_purity(env, key) -> list:
    out = []
    # trace counters: the wrapped body runs only while tracing, so a second
    # same-shaped call that retraces (non-hashable statics, host-dependent
    # control flow, weak-type flapping) bumps the counter past 1
    counts = {"init": 0, "reset": 0, "step": 0}

    def cinit(k):
        counts["init"] += 1
        return env.init(k)

    def creset(s, k):
        counts["reset"] += 1
        return env.reset(s, k)

    def cstep(s, a, k):
        counts["step"] += 1
        return env.step(s, a, k)

    try:
        jinit, jreset, jstep = jax.jit(cinit), jax.jit(creset), jax.jit(cstep)
        s = jinit(key)
        s = jinit(jax.random.fold_in(key, 1))
        s, obs = jreset(s, key)
        s, obs = jreset(s, jax.random.fold_in(key, 2))
        a = _sample_action(env, key)
        r1 = jstep(s, a, key)
        r2 = jstep(r1[0], _sample_action(env, jax.random.fold_in(key, 3)),
                   jax.random.fold_in(key, 4))
        jax.block_until_ready(r2[1])
    except Exception as e:   # noqa: BLE001 — any trace failure is the finding
        return [f"init/reset/step failed under jit: {type(e).__name__}: {e}"]
    for name, n in counts.items():
        if n != 1:
            out.append(f"{name} retraced on a second same-shaped call "
                       f"({n} traces); check for non-static host state")
    try:
        jaxpr = jax.make_jaxpr(env.step)(s, a, key)
        cbs = _callback_eqns(jaxpr.jaxpr)
        if cbs:
            out.append(f"step lowers with host callbacks {sorted(set(cbs))}; "
                       f"the fused rollout scan would sync per step")
    except Exception as e:   # noqa: BLE001
        out.append(f"step does not abstract-trace: {type(e).__name__}: {e}")
    return out


def check_vmap_purity(env, key, batch: int = 4) -> list:
    try:
        keys = jax.random.split(key, batch)
        states = jax.vmap(env.init)(keys)
        states, obs = jax.vmap(env.reset)(states, keys)
        acts = jax.vmap(lambda k: _sample_action(env, k))(keys)
        states, obs, rew, done, info = jax.vmap(env.step)(states, acts, keys)
        jax.block_until_ready(obs)
    except Exception as e:   # noqa: BLE001
        return [f"env does not vmap: {type(e).__name__}: {e}"]
    out = []
    lead = jax.tree.leaves(obs)[0].shape[0]
    if lead != batch:
        out.append(f"vmapped obs leading dim {lead} != batch {batch}")
    return out


def check_stability(env, key) -> list:
    out = []
    s = env.init(key)
    s, obs = env.reset(s, key)
    sig0 = None
    for t in range(min(_horizon(env), 32)):
        s, obs, rew, done, info = env.step(
            s, _sample_action(env, jax.random.fold_in(key, t)),
            jax.random.fold_in(key, 100 + t))
        sig = (_tree_sig(obs), _tree_sig(s),
               (jnp.shape(rew), str(jnp.asarray(rew).dtype)),
               (jnp.shape(done), str(jnp.asarray(done).dtype)),
               _tree_sig(info))
        if sig0 is None:
            sig0 = sig
        elif sig != sig0:
            out.append(f"shape/dtype signature changed at step {t}")
            break
        if bool(done):
            break
    rew_dtype = jnp.asarray(rew).dtype
    if not jnp.issubdtype(rew_dtype, jnp.floating):
        out.append(f"reward dtype {rew_dtype} is not floating")
    if jnp.asarray(done).dtype != jnp.bool_:
        out.append(f"done dtype {jnp.asarray(done).dtype} != bool")
    if jnp.shape(done) != ():
        out.append(f"done must be an episode-scoped scalar, got shape "
                   f"{jnp.shape(done)}")
    for f in ("score", "episode_return", "episode_length", "valid"):
        if f not in info:
            out.append(f"info missing required field {f!r}")
    return out


def check_determinism(env, key) -> list:
    s = env.init(key)
    s, obs = env.reset(s, key)
    a = _sample_action(env, key)
    # deliberately NOT jitted: the jit cache would replay one trace and hide
    # host-side impurity (a python counter folded into the key, np.random,
    # time-dependent constants) that a second trace would expose
    r1 = env.step(s, a, jax.random.fold_in(key, 7))
    r2 = env.step(s, a, jax.random.fold_in(key, 7))
    if not _trees_equal(r1, r2):
        return ["step(state, action, key) is not deterministic: identical "
                "inputs gave different outputs (host-side randomness?)"]
    i1 = env.init(key)
    i2 = env.init(key)
    if not _trees_equal(i1, i2):
        return ["init(key) is not deterministic for a fixed key"]
    return []


def check_emulation(env, key) -> list:
    out = []
    for mode in ("f32", "bytes"):
        try:
            spec = em.flat_spec(env.observation_space, mode)
            x = sp.sample(env.observation_space, key)
            back = em.unemulate(spec, em.emulate(spec, x))
        except Exception as e:   # noqa: BLE001
            out.append(f"obs emulation ({mode}) failed: "
                       f"{type(e).__name__}: {e}")
            continue
        for p, _ in sp.leaves(env.observation_space):
            a = np.asarray(sp.get_path(x, p))
            b = np.asarray(sp.get_path(back, p))
            exact = mode == "bytes"
            close = (np.array_equal(a, b) if exact else
                     np.allclose(a.astype(np.float32),
                                 b.astype(np.float32), rtol=1e-6))
            if not close:
                out.append(f"obs round-trip ({mode}) not identity at "
                           f"leaf {p}")
    try:
        aspec = em.action_spec(env.action_space)
        a = sp.sample(env.action_space, jax.random.fold_in(key, 1))
        flat = em.emulate_action(aspec, a)
        back = em.unemulate_action(aspec, flat)
        flat2 = em.emulate_action(aspec, back)
        if not np.allclose(np.asarray(flat), np.asarray(flat2)):
            out.append("action round-trip emulate∘unemulate∘emulate is not "
                       "the identity")
    except Exception as e:   # noqa: BLE001
        out.append(f"action emulation failed: {type(e).__name__}: {e}")
    return out


def check_agent_axis(env, key) -> list:
    A = env.num_agents
    if A == 1:
        return []
    out = []
    s = env.init(key)
    s, obs = env.reset(s, key)
    lead = jax.tree.leaves(obs)[0].shape[0]
    if lead != A:
        out.append(f"reset obs leading dim {lead} != num_agents {A} "
                   f"(obs must be agent-major in canonical order)")
    s, obs, rew, done, info = env.step(s, _sample_action(env, key), key)
    lead = jax.tree.leaves(obs)[0].shape[0]
    if lead != A:
        out.append(f"step obs leading dim {lead} != num_agents {A}")
    if jnp.shape(rew) != (A,):
        out.append(f"multi-agent reward shape {jnp.shape(rew)} != ({A},)")
    return out


def _random_vec_actions(vec: VecEnv, key):
    """Uniform random batch of emulated actions for a VecEnv — each
    MultiDiscrete component drawn over its own [0, n) range."""
    space = vec.single_action_space
    if isinstance(space, sp.MultiDiscrete):
        return jax.random.randint(key, (vec.batch_size, len(space.nvec)),
                                  0, jnp.asarray(space.nvec), jnp.int32)
    return jax.random.uniform(key, (vec.batch_size,) + space.shape,
                              minval=-1.0, maxval=1.0)


def check_autoreset(env, key, num_envs: int = 4) -> list:
    out = []
    try:
        vec = VecEnv(em.Emulated(env), num_envs)
    except Exception as e:   # noqa: BLE001
        return [f"env does not wrap under Emulated+VecEnv: "
                f"{type(e).__name__}: {e}"]
    state, obs = vec.init(key)
    H = _horizon(env)
    dones_seen = 0
    for t in range(2 * H + 2):
        k = jax.random.fold_in(key, t)
        acts = _random_vec_actions(vec, k)
        state, obs, rew, done, info = vec.step(state, acts, k)
        if not bool(jnp.all(jnp.isfinite(obs.astype(jnp.float32)))):
            out.append(f"non-finite observation after autoreset at step {t}")
            break
        d = np.asarray(done)
        v = np.asarray(info["valid"])
        dones_seen += int(d.sum())
        # per-env info rows must fire exactly with that env's done
        env_done = d.reshape(vec.num_envs, vec.num_agents)[:, 0]
        if not np.array_equal(env_done, v):
            out.append(f"info['valid'] disagrees with done at step {t}: "
                       f"episode stats must fire exactly at episode end")
            break
        lens = np.asarray(info["episode_length"])[v]
        if (lens <= 0).any() or (lens > H).any():
            out.append(f"episode_length outside (0, horizon={H}] at "
                       f"step {t}: {lens}")
            break
    if dones_seen == 0:
        out.append(f"no episode terminated in {2 * H + 2} random steps "
                   f"(declared horizon {H})")
    return out


def check_procgen_keys(env, key) -> list:
    """Layout must follow the key. If ``init`` is key-dependent (procgen
    env), ``reset`` — the function the autoreset path calls with a fresh key
    every episode — must thread its key through too: resetting one state
    with the two keys that made ``init`` differ must give different states.
    Catches a reset that ignores its key (every episode replays the same
    layout) without false-flagging envs whose *initial obs* happens to hide
    the key-dependent state (partial observability), since states, not
    observations, are compared."""
    kA, kB = jax.random.fold_in(key, 0), jax.random.fold_in(key, 1)
    if _trees_equal(env.init(kA), env.init(kB)):
        return []                    # key-independent init: static env
    s = env.init(key)
    s, _ = env.reset(s, key)
    rA, _ = env.reset(s, kA)
    rB, _ = env.reset(s, kB)
    if _trees_equal(rA, rB):
        return ["init depends on its key but reset ignores its key — the "
                "procgen key is stale in the autoreset path, so every "
                "episode would replay the same layout"]
    rA2, _ = env.reset(s, kA)
    if not _trees_equal(rA, rA2):
        return ["reset is not deterministic for a fixed key"]
    return []


def check_score_bounds(env, key, episodes: int = 3) -> list:
    out = []
    H = _horizon(env)
    for e in range(episodes):
        s = env.init(jax.random.fold_in(key, e))
        s, obs = env.reset(s, jax.random.fold_in(key, 50 + e))
        for t in range(10 * H):
            s, obs, rew, done, info = env.step(
                s, _sample_action(env, jax.random.fold_in(key, e * 131 + t)),
                jax.random.fold_in(key, e * 977 + t))
            if not bool(jnp.all(jnp.isfinite(jnp.asarray(rew, jnp.float32)))):
                out.append(f"non-finite reward at episode {e} step {t}")
                return out
            if bool(done):
                break
        else:
            out.append(f"episode {e} never terminated within 10×horizon")
            return out
        score = float(info["score"])
        if not (0.0 <= score <= 1.0):
            out.append(f"terminal score {score} outside [0, 1] — scores "
                       f"must be normalized so 0.9 means solved")
        if not bool(info["valid"]):
            out.append(f"info['valid'] false at episode end (episode {e})")
        if info["score"].dtype != jnp.float32:
            out.append(f"info['score'] dtype {info['score'].dtype} "
                       f"!= float32")
        if info["episode_length"].dtype != jnp.int32:
            out.append(f"info['episode_length'] dtype "
                       f"{info['episode_length'].dtype} != int32")
        if int(info["episode_length"]) != t + 1:
            out.append(f"episode_length {int(info['episode_length'])} != "
                       f"actual steps {t + 1}")
    return out


# ---------------------------------------------------------------------------

CHECKS = {
    "jit_purity": check_jit_purity,
    "vmap_purity": check_vmap_purity,
    "stability": check_stability,
    "determinism": check_determinism,
    "emulation": check_emulation,
    "agent_axis": check_agent_axis,
    "autoreset": check_autoreset,
    "procgen_keys": check_procgen_keys,
    "score_bounds": check_score_bounds,
}


def check_env(env_or_name, *, seed: int = 0,
              checks: Optional[list] = None) -> ConformanceReport:
    """Run the conformance suite against an env instance or registry name.

    Returns a ``ConformanceReport``; ``report.ok`` is the machine-checkable
    "plays nice" verdict, ``report.summary()`` the human one. A check that
    raises is recorded as a violation, never as a crash — one broken
    invariant must not mask the others.
    """
    if isinstance(env_or_name, str):
        from repro.envs.ocean import OCEAN
        name = env_or_name
        env = OCEAN[name]()
    else:
        env = env_or_name
        name = type(env).__name__
    key = jax.random.PRNGKey(seed)
    report = ConformanceReport(env_name=name)
    for cname in (checks or CHECKS):
        fn = CHECKS[cname]
        try:
            violations = fn(env, key)
        except Exception as e:   # noqa: BLE001 — report, don't crash
            violations = [f"check raised {type(e).__name__}: {e}"]
        report.results.append(
            CheckResult(cname, not violations, tuple(violations)))
    report.static_findings = _static_findings(type(env))
    return report


def _static_findings(cls) -> tuple:
    """Lint the env class's source with ``repro.analysis`` and keep the
    findings inside the class body — the static half of the report."""
    import inspect
    try:
        from repro.analysis import check_source
        path = inspect.getsourcefile(cls)
        body, start = inspect.getsourcelines(cls)
        src = open(path).read()
    except (TypeError, OSError, ImportError):   # builtins, REPL classes, …
        return ()
    return tuple(f for f in check_source(src, path)
                 if start <= f.line < start + len(body))


# ---------------------------------------------------------------------------
# host profile — the "plays nice" contract for bridged host envs
#
# A bridged env can't satisfy the jit/vmap/purity checks (its state lives in
# Python), but the protocol the training stack consumes — stable flat f32
# observation batches, autoreset with valid == done episode stats, seeded
# determinism — is just as checkable. ``check_host_env`` runs these against a
# *factory* of synchronous (num_envs == batch_size) ``bridge.HostVecEnv``
# instances: sync mode makes row layout deterministic, which the determinism
# check needs; the async first-finisher path shares all the same code below
# the batching order.

def _random_host_actions(venv, rng):
    space = venv.action_space
    if isinstance(space, sp.MultiDiscrete):
        return np.stack([rng.integers(0, n, venv.batch_size)
                         for n in space.nvec], axis=-1).astype(np.int32)
    return rng.uniform(-1.0, 1.0,
                       (venv.batch_size,) + space.shape).astype(np.float32)


def _host_horizon(venv) -> int:
    return int(venv.horizon or 64)


_INFO_DTYPES = {"score": np.float32, "episode_return": np.float32,
                "episode_length": np.int32, "valid": np.bool_}


def check_host_protocol(factory, seed) -> list:
    out = []
    v = factory()
    try:
        if v.num_envs != v.batch_envs:
            out.append(f"host profile needs a sync wrapper (num_envs="
                       f"{v.num_envs} != batch_size={v.batch_envs}); build "
                       f"the factory with bridge.wrap(fn, num_envs=N)")
        obs = v.reset(timeout=30.0)
        if obs.shape != (v.batch_size, v.obs_dim):
            out.append(f"reset obs shape {obs.shape} != "
                       f"{(v.batch_size, v.obs_dim)}")
        if obs.dtype != np.float32:
            out.append(f"reset obs dtype {obs.dtype} != float32 (the bridge "
                       f"packs model-facing f32)")
        if not isinstance(v.action_space, (sp.MultiDiscrete, sp.Box)):
            out.append(f"emulated action space {v.action_space} is neither "
                       f"MultiDiscrete nor Box")
    finally:
        v.close()
    return out


def check_host_stability(factory, seed) -> list:
    out = []
    v = factory()
    rng = np.random.default_rng(seed)
    try:
        obs = v.reset(timeout=30.0)
        sig0 = None
        for t in range(min(2 * _host_horizon(v) + 2, 64)):
            obs, rew, done, info = v.step(_random_host_actions(v, rng),
                                          timeout=30.0)
            sig = (obs.shape, str(obs.dtype), rew.shape, str(rew.dtype),
                   done.shape, str(done.dtype),
                   tuple(sorted((k, x.shape, str(x.dtype))
                                for k, x in info.items())))
            if sig0 is None:
                sig0 = sig
            elif sig != sig0:
                out.append(f"shape/dtype signature changed at step {t}")
                break
            if not np.all(np.isfinite(obs)):
                out.append(f"non-finite observation at step {t}")
                break
            for k, dt in _INFO_DTYPES.items():
                if k not in info:
                    out.append(f"info missing required field {k!r}")
                    return out
                if info[k].dtype != dt:
                    out.append(f"info[{k!r}] dtype {info[k].dtype} != "
                               f"{np.dtype(dt)}")
                    return out
            env_done = done.reshape(v.batch_envs, v.num_agents)[:, 0]
            if not np.array_equal(env_done, info["valid"]):
                out.append(f"info['valid'] disagrees with done at step {t}: "
                           f"episode stats must fire exactly at episode end")
                break
    finally:
        v.close()
    return out


def check_host_autoreset(factory, seed) -> list:
    out = []
    v = factory()
    rng = np.random.default_rng(seed)
    try:
        H = _host_horizon(v)
        v.reset(timeout=30.0)
        dones_seen = 0
        for t in range(2 * H + 2):
            _obs, _rew, done, info = v.step(_random_host_actions(v, rng),
                                            timeout=30.0)
            dones_seen += int(np.asarray(done).sum())
            lens = np.asarray(info["episode_length"])[info["valid"]]
            if len(lens) and ((lens <= 0).any() or (lens > H).any()):
                out.append(f"episode_length outside (0, horizon={H}] at "
                           f"step {t}: {lens}")
                break
            scores = np.asarray(info["score"])[info["valid"]]
            if len(scores) and not np.all((scores >= 0.0) & (scores <= 1.0)):
                out.append(f"terminal score outside [0, 1] at step {t}: "
                           f"{scores}")
                break
        if dones_seen == 0:
            out.append(f"no episode terminated in {2 * H + 2} steps "
                       f"(declared horizon {H}); autoreset unverifiable")
    finally:
        v.close()
    return out


def check_host_determinism(factory, seed) -> list:
    """Two same-seed instances fed the same actions must produce identical
    streams across at least one autoreset boundary — this is what the
    per-env seed sequence in ``HostPool`` guarantees (the old
    ``env.reset(None)`` autoreset made every episode after the first
    nondeterministic)."""
    va, vb = factory(), factory()
    try:
        steps = min(2 * _host_horizon(va) + 2, 80)
        rng = np.random.default_rng(seed)
        acts = [_random_host_actions(va, rng) for _ in range(steps)]
        oa = [va.reset(timeout=30.0)]
        ob = [vb.reset(timeout=30.0)]
        ra, rb = [], []
        for t in range(steps):
            o, r, _d, _i = va.step(acts[t], timeout=30.0)
            oa.append(o)
            ra.append(r)
            o, r, _d, _i = vb.step(acts[t], timeout=30.0)
            ob.append(o)
            rb.append(r)
        for t, (a, b) in enumerate(zip(oa, ob)):
            if not np.array_equal(a, b):
                return [f"same-seed instances diverged in obs at step {t} "
                        f"(autoreset seeding or hidden host randomness?)"]
        for t, (a, b) in enumerate(zip(ra, rb)):
            if not np.array_equal(a, b):
                return [f"same-seed instances diverged in reward at step "
                        f"{t}"]
    finally:
        va.close()
        vb.close()
    return []


HOST_CHECKS = {
    "host_protocol": check_host_protocol,
    "host_stability": check_host_stability,
    "host_autoreset": check_host_autoreset,
    "host_determinism": check_host_determinism,
}


# ---------------------------------------------------------------------------
# selfplay profile — the contract competitive (league) envs add on top of
# the base profile
#
# The Policy League's arena and the engine's selfplay mode assume three
# invariants the base checks can't see: matches are zero-sum (the reward
# vector sums to 0 at every step, so one side's score is the other's loss),
# roles are symmetric under the agent-row permutation (training as row 0 is
# no different from training as row 1 — ``swap_agents`` is the env-declared
# permutation), and episodes are team-consistent (one episode-scoped scalar
# done: no agent's episode outlives another's, so a match has one outcome).

def _rollout_states(env, key, steps):
    """(state, action, key) triples along a random rollout with resets."""
    s = env.init(key)
    s, _ = env.reset(s, key)
    for t in range(steps):
        a = _sample_action(env, jax.random.fold_in(key, t))
        kt = jax.random.fold_in(key, 1000 + t)
        yield s, a, kt
        s, _obs, _rew, done, _info = env.step(s, a, kt)
        if bool(done):
            s, _ = env.reset(s, jax.random.fold_in(key, 2000 + t))


def check_zero_sum(env, key) -> list:
    if env.num_agents < 2:
        return [f"selfplay profile needs a multi-agent env "
                f"(num_agents={env.num_agents})"]
    steps = min(2 * _horizon(env) + 2, 80)   # spans >= 1 episode boundary
    for t, (s, a, kt) in enumerate(_rollout_states(env, key, steps)):
        _s2, _obs, rew, _done, _info = env.step(s, a, kt)
        tot = float(jnp.sum(rew))
        if abs(tot) > 1e-5:
            return [f"reward vector sums to {tot:+.6f} at step {t} "
                    f"(rewards {np.asarray(rew)}); a competitive env must "
                    f"be zero-sum at every step"]
    return []


def check_role_swap(env, key, steps: int = 0) -> list:
    """Stepping the agent-row-reversed state with reversed actions must give
    the reversed outputs: obs/reward rows reversed, same done, and the next
    state equal to ``swap_agents`` of the unswapped next state. The env
    declares the permutation via ``swap_agents(state)``."""
    if not hasattr(env, "swap_agents"):
        return ["competitive envs must expose swap_agents(state) — the "
                "agent-row permutation the role-swap symmetry is checked "
                "under"]
    rev = lambda x: jax.tree.map(lambda v: v[::-1], x)
    out = []
    steps = steps or min(2 * _horizon(env) + 2, 80)
    for t, (s, a, kt) in enumerate(_rollout_states(env, key, steps)):
        s2, obs, rew, done, info = env.step(s, a, kt)
        s2w, obsw, reww, donew, infow = env.step(env.swap_agents(s), rev(a),
                                                 kt)
        if not _trees_equal(obsw, rev(obs)):
            out.append(f"swapped-role obs is not the row-reversed obs at "
                       f"step {t}")
        if not bool(jnp.all(jnp.abs(reww - rew[::-1]) < 1e-6)):
            out.append(f"swapped-role reward is not the row-reversed "
                       f"reward at step {t}: {np.asarray(reww)} vs "
                       f"{np.asarray(rew[::-1])}")
        if bool(donew) != bool(done):
            out.append(f"swapped-role done disagrees at step {t}")
        if not _trees_equal(s2w, env.swap_agents(s2)):
            out.append(f"swapped-role next state != swap_agents(next "
                       f"state) at step {t}")
        if out:
            return out
        # side-0-centric score must mirror at episode end
        if bool(done):
            sc, scw = float(info["score"]), float(infow["score"])
            if abs((1.0 - sc) - scw) > 1e-5:
                return [f"score is not side-0-centric: swap gives "
                        f"{scw:.6f}, expected 1 - {sc:.6f} (the arena "
                        f"reads score > 0.5 as a side-A win)"]
    return []


def check_team_done(env, key, episodes: int = 2) -> list:
    """One match, one outcome: done is an episode-scoped scalar shared by
    every agent row (no per-agent/per-team early termination), and the
    terminal info row fires exactly once per episode."""
    out = []
    H = _horizon(env)
    for e in range(episodes):
        s = env.init(jax.random.fold_in(key, e))
        s, _ = env.reset(s, jax.random.fold_in(key, 50 + e))
        ends = 0
        for t in range(2 * H):
            a = _sample_action(env, jax.random.fold_in(key, e * 71 + t))
            s, _obs, rew, done, info = env.step(
                s, a, jax.random.fold_in(key, e * 113 + t))
            if jnp.shape(done) != ():
                return [f"done shape {jnp.shape(done)} is per-agent; all "
                        f"rows of a match must terminate together "
                        f"(episode-scoped scalar done)"]
            if jnp.shape(rew) != (env.num_agents,):
                return [f"reward shape {jnp.shape(rew)} != "
                        f"({env.num_agents},): every agent row needs its "
                        f"side of the zero-sum transfer"]
            ends += int(bool(info["valid"]))
            if bool(done):
                break
        else:
            out.append(f"episode {e} never terminated within 2×horizon")
            continue
        if ends != 1:
            out.append(f"episode {e}: terminal info fired {ends} times "
                       f"(must fire exactly once, at the shared episode "
                       f"end)")
    return out


SELFPLAY_CHECKS = {
    "zero_sum": check_zero_sum,
    "role_swap": check_role_swap,
    "team_done": check_team_done,
}


def check_selfplay_env(env_or_name, *, seed: int = 0,
                       checks: Optional[list] = None) -> ConformanceReport:
    """Run the selfplay (competitive-env) profile — zero-sum rewards,
    role-swap symmetry under agent-row permutation, and team-consistent
    termination — against an env instance or OCEAN registry name. Same
    report semantics as ``check_env``; league workloads should pass BOTH
    profiles (the base one still governs jit/vmap/emulation purity)."""
    if isinstance(env_or_name, str):
        from repro.envs.ocean import OCEAN
        name, env = env_or_name, OCEAN[env_or_name]()
    else:
        env, name = env_or_name, type(env_or_name).__name__
    key = jax.random.PRNGKey(seed)
    report = ConformanceReport(env_name=f"selfplay/{name}")
    for cname in (checks or SELFPLAY_CHECKS):
        fn = SELFPLAY_CHECKS[cname]
        try:
            violations = fn(env, key)
        except Exception as e:   # noqa: BLE001 — report, don't crash
            violations = [f"check raised {type(e).__name__}: {e}"]
        report.results.append(
            CheckResult(cname, not violations, tuple(violations)))
    return report


def check_host_env(factory, *, name: str = None,
                   seed: int = 0, checks: Optional[list] = None
                   ) -> ConformanceReport:
    """Run the host-profile conformance suite.

    ``factory`` builds a fresh **synchronous** ``bridge.HostVecEnv`` per
    call, e.g. ``lambda: bridge.wrap(MyEnv, num_envs=2)``. Same report
    semantics as ``check_env``: a check that raises is a violation, never a
    crash."""
    report = ConformanceReport(env_name=name or "host_env")
    for cname in (checks or HOST_CHECKS):
        fn = HOST_CHECKS[cname]
        try:
            violations = fn(factory, seed)
        except Exception as e:   # noqa: BLE001 — report, don't crash
            violations = [f"check raised {type(e).__name__}: {e}"]
        report.results.append(
            CheckResult(cname, not violations, tuple(violations)))
    return report


def run_cli(env_arg: str, seed: int = 0, host: bool = False,
            selfplay: bool = False, host_backend: str = "thread") -> int:
    """Check 'all' or a comma-separated name list against the registry,
    print each report, return a process exit code (1 on any violation).
    Shared by this module's __main__ and ``launch.train --conformance``.
    With ``host=True`` the names come from the ``OCEAN_HOST`` mirror
    registry and run the host profile through ``bridge.wrap`` on the given
    ``host_backend`` ("thread" | "proc" — the contract is backend-
    independent, so both must pass the same checks); with ``selfplay=True``
    the competitive-env profile runs instead of the base one."""
    if selfplay:
        from repro.envs.ocean import OCEAN
        names = list(OCEAN) if env_arg == "all" \
            else [n.strip() for n in env_arg.split(",")]
        bad = 0
        for name in names:
            report = check_selfplay_env(name, seed=seed)
            print(report.summary())
            bad += not report.ok
        return 1 if bad else 0
    if host:
        from repro.bridge import wrap
        from repro.envs.ocean_host import OCEAN_HOST
        names = list(OCEAN_HOST) if env_arg == "all" \
            else [n.strip() for n in env_arg.split(",")]
        bad = 0
        for name in names:
            cls = OCEAN_HOST[name]
            report = check_host_env(
                lambda cls=cls: wrap(cls, num_envs=2, seed=seed,
                                     backend=host_backend),
                name=f"host/{name}[{host_backend}]", seed=seed)
            print(report.summary())
            bad += not report.ok
        return 1 if bad else 0
    from repro.envs.ocean import OCEAN
    names = list(OCEAN) if env_arg == "all" \
        else [n.strip() for n in env_arg.split(",")]
    bad = 0
    for name in names:
        report = check_env(name, seed=seed)
        print(report.summary())
        bad += not report.ok
    return 1 if bad else 0


def main(argv=None):
    import argparse
    ap = argparse.ArgumentParser(
        description="Run the env-conformance suite (see envs/conformance.py)")
    ap.add_argument("env", help="OCEAN registry name(s, comma-separated), "
                                "or 'all'")
    ap.add_argument("--host", action="store_true",
                    help="run the host profile over the OCEAN_HOST mirror "
                         "registry (bridge-wrapped) instead of the JAX suite")
    ap.add_argument("--selfplay", action="store_true",
                    help="run the competitive-env (league) profile: "
                         "zero-sum, role-swap symmetry, team done")
    ap.add_argument("--host-backend", default="thread",
                    choices=("thread", "proc"),
                    help="worker backend for the host profile (the contract "
                         "must hold under both)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    return run_cli(args.env, seed=args.seed, host=args.host,
                   selfplay=args.selfplay, host_backend=args.host_backend)


if __name__ == "__main__":
    raise SystemExit(main())
