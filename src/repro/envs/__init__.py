# Lazy (PEP 562) like repro.core: shared-memory env workers unpickle
# `ocean_host` mirror classes, which imports this package — it must not pull
# jax (ocean/conformance are jax-heavy; ocean_host is numpy-only).

_SUBMODULES = ("base", "ocean", "ocean_host", "conformance")
_SYMBOLS = {
    "OCEAN": "ocean", "make": "ocean",
    "ConformanceReport": "conformance", "check_env": "conformance",
}

__all__ = list(_SUBMODULES) + list(_SYMBOLS)


def __getattr__(name):
    import importlib
    if name in _SUBMODULES:
        return importlib.import_module(f"repro.envs.{name}")
    if name in _SYMBOLS:
        mod = importlib.import_module(f"repro.envs.{_SYMBOLS[name]}")
        return getattr(mod, name)
    raise AttributeError(f"module 'repro.envs' has no attribute {name!r}")


def __dir__():
    return sorted(__all__)
