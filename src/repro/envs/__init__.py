from repro.envs import base, ocean
from repro.envs.ocean import OCEAN, make
