from repro.envs import base, ocean
from repro.envs.ocean import OCEAN, make
from repro.envs.conformance import ConformanceReport, check_env
