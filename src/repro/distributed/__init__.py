# Distributed layer: sharding rules, fault tolerance, and the async
# actor–learner topology.
#
# Submodules load lazily (PEP 562, same rule as repro.core): the async
# tier's spawn actors import `repro.distributed.actor_learner` in a fresh
# interpreter, and this package __init__ must not drag in jax on their
# behalf (sharding is jax-heavy; actor_learner/fault are importable
# jax-free). `from repro.distributed import sharding` still works — the
# attribute access routes through __getattr__ below.

_SUBMODULES = ("sharding", "fault", "actor_learner")

__all__ = list(_SUBMODULES)


def __getattr__(name):
    import importlib
    if name in _SUBMODULES:
        return importlib.import_module(f"repro.distributed.{name}")
    raise AttributeError(
        f"module 'repro.distributed' has no attribute {name!r}")


def __dir__():
    return sorted(__all__)
