"""Sharding rules: logical axes → the production mesh.

One rules dict drives everything (params, optimizer states, batches, caches):

  embed (d_model)            → FSDP over ("pod","data")   [ZeRO-3]
  vocab/heads/kv_heads/mlp/expert/ssm_heads → "model"     [TP / EP]
  batch                      → ("pod","data")             [DP]
  ctx (long-context KV seq)  → ("pod","data")             [CP]

"""
from __future__ import annotations

from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models import params as prm
from repro.models import transformer as tr
from repro.models import attention as attn_mod
from repro.models import ssm as ssm_mod
from repro.optim.adamw import AdamWState
from repro.rl.learner import TrainState, lm_batch_fields


def make_rules(mesh: Mesh) -> dict:
    fsdp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    fsdp = fsdp if len(fsdp) > 1 else (fsdp[0] if fsdp else None)
    rules = dict(prm.DEFAULT_RULES)
    rules.update({"embed": fsdp, "batch": fsdp, "ctx": fsdp})
    return rules


def named(mesh: Mesh, pspec_tree):
    return jax.tree.map(lambda p: NamedSharding(mesh, p), pspec_tree,
                        is_leaf=lambda x: isinstance(x, P))


# -- Ocean data-parallel (TrainEngine shard_map tier) --------------------------

def data_axes(mesh: Mesh) -> tuple:
    """The mesh axes Ocean PPO data-parallelizes over (envs + batch)."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def dp_size(mesh: Mesh) -> int:
    n = 1
    for a in data_axes(mesh):
        n *= mesh.shape[a]
    return n


def ocean_batch_spec(mesh: Mesh) -> P:
    """PartitionSpec sharding a leading env/batch dim over the data axes.
    Used as a pytree prefix for the whole RolloutCarry (every leaf of env
    state, obs, policy carry, and done mask is env-major)."""
    axes = data_axes(mesh)
    if not axes:
        return P()
    return P(axes if len(axes) > 1 else axes[0])


# -- train state ---------------------------------------------------------------

def train_state_pspecs(policy, rules: dict) -> TrainState:
    pp = prm.param_pspecs(policy.spec(), rules)
    return TrainState(params=pp,
                      opt=AdamWState(step=P(), m=pp, v=pp),
                      step=P())


def abstract_train_state(policy, opt_dtype) -> TrainState:
    import jax.numpy as jnp
    params = policy.abstract()
    zeros = lambda p: jax.ShapeDtypeStruct(p.shape, jnp.dtype(opt_dtype))
    return TrainState(
        params=params,
        opt=AdamWState(step=jax.ShapeDtypeStruct((), jnp.int32),
                       m=jax.tree.map(zeros, params),
                       v=jax.tree.map(zeros, params)),
        step=jax.ShapeDtypeStruct((), jnp.int32))


# -- batches -------------------------------------------------------------------

def lm_batch_pspecs(cfg: ModelConfig, rules: dict) -> dict:
    b = rules["batch"]
    out = {}
    for k, (shape, _) in lm_batch_fields(cfg, 1, 1 + (cfg.frontend_prefix
                                                      if cfg.frontend else 0)
                                         ).items():
        out[k] = P(*([b] + [None] * (len(shape) - 1)))
    return out


# -- caches ---------------------------------------------------------------------

def cache_pspecs(cfg: ModelConfig, rules: dict,
                 context_parallel: bool = False) -> tr.Caches:
    """PartitionSpec tree mirroring transformer.Caches. decode_32k shards
    batch over DP; long_500k (context_parallel, B=1) shards the KV sequence
    dim over the DP axes instead."""
    b, c = rules["batch"], rules["ctx"]
    period = tr.stack_period(cfg)
    kv, ssm = {}, {}
    for i in range(period):
        mixer, _ = tr.layer_kinds(cfg, i)
        if mixer == "attn":
            if context_parallel:
                spec = P(None, None, c, "model", None)
            else:
                spec = P(None, b, None, "model", None)
            kv[f"l{i}"] = attn_mod.KVCache(k=spec, v=spec, length=P(None))
        else:
            bb = None if context_parallel else b
            ssm[f"l{i}"] = ssm_mod.SSMCache(
                conv=P(None, bb, None, "model"),
                state=P(None, bb, "model", None, None))
    return tr.Caches(kv=kv, ssm=ssm, length=P())


def abstract_caches(cfg: ModelConfig, tp: int, batch: int, max_len: int):
    return jax.eval_shape(
        lambda: tr.init_caches(cfg, tp, batch, max_len))
