"""Fault tolerance and straggler mitigation for the training loop.

Mechanisms (DESIGN.md §4):
  * checkpoint/restart — atomic committed checkpoints (checkpoint.ckpt),
    ``resume_or_init`` picks up the latest on relaunch; restore works onto a
    different mesh (elastic: 512 → 256 chips) because checkpoints are
    sharding-agnostic.
  * step-scoped retry — a failing step (device error, preemption signal)
    triggers restore-from-last-commit and replay; repeated failure at the
    same step aborts with a clear report (poison-pill detection).
  * straggler detection — per-step wall times are tracked; hosts slower than
    ``k×median`` over a window are flagged (on a real cluster the launcher
    would re-shard around them; here we log and expose the signal).
"""
from __future__ import annotations

import collections
import time
from typing import Callable, Optional

import jax

from repro.checkpoint import ckpt


class StragglerMonitor:
    """Rolling per-step wall-time stats with k×median flagging (the paper's
    EnvPool insight at pod scale: never wait on the slowest worker)."""

    def __init__(self, window: int = 64, k: float = 2.0):
        self.times = collections.deque(maxlen=window)
        self.k = k
        self.flagged = 0

    def record(self, dt: float) -> bool:
        self.times.append(dt)
        if len(self.times) >= 8:
            med = sorted(self.times)[len(self.times) // 2]
            if dt > self.k * med:
                self.flagged += 1
                return True
        return False

    @property
    def median(self) -> float:
        if not self.times:
            return 0.0
        return sorted(self.times)[len(self.times) // 2]


class ResilientLoop:
    """Wraps a jitted ``step(state, batch) -> (state, metrics)`` with
    checkpoint/restart fault tolerance."""

    def __init__(self, step_fn: Callable, ckpt_dir: str,
                 save_every: int = 100, max_retries: int = 3,
                 async_save: bool = True, shardings=None):
        self.step_fn = step_fn
        self.ckpt_dir = ckpt_dir
        self.save_every = save_every
        self.max_retries = max_retries
        self.async_save = async_save
        self.shardings = shardings
        self.monitor = StragglerMonitor()
        self._save_handle = None
        self.steps_done = 0
        self.recoveries = 0

    def resume_or_init(self, init_state):
        """Latest committed checkpoint if present, else the given state."""
        path = ckpt.latest(self.ckpt_dir)
        if path is None:
            return init_state, 0
        like = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), init_state)
        state = ckpt.restore(path, like, self.shardings)
        step = int(path.rsplit("_", 1)[1])
        return state, step

    def run(self, state, batches, on_metrics: Optional[Callable] = None):
        """Iterate ``batches``; survives step failures via restore+replay."""
        retries = 0
        it = iter(batches)
        pending = None
        while True:
            if pending is None:
                try:
                    pending = next(it)
                except StopIteration:
                    break
            t0 = time.perf_counter()
            try:
                state, metrics = self.step_fn(state, pending)
                # the sync is the failure detector: a device error only
                # surfaces when the step's result is materialized
                jax.block_until_ready(jax.tree.leaves(metrics)[0])  # repro: noqa[HOST-SYNC]
            except Exception as e:   # device failure / preemption
                retries += 1
                self.recoveries += 1
                if retries > self.max_retries:
                    raise RuntimeError(
                        f"step {self.steps_done} failed {retries}x; "
                        f"aborting (poison pill?)") from e
                restored = ckpt.latest(self.ckpt_dir)
                if restored is not None:
                    state, _ = self.resume_or_init(state)
                continue   # replay the same batch
            retries = 0
            slow = self.monitor.record(time.perf_counter() - t0)
            if slow:
                metrics = dict(metrics, straggler_flag=True)
            self.steps_done += 1
            pending = None
            if on_metrics:
                on_metrics(self.steps_done, metrics)
            if self.steps_done % self.save_every == 0:
                if self._save_handle is not None:
                    self._save_handle.join()   # one in-flight save at a time
                out = ckpt.save(self.ckpt_dir, state, step=self.steps_done,
                                async_=self.async_save)
                self._save_handle = out if self.async_save else None
        if self._save_handle is not None:
            self._save_handle.join()
        return state
