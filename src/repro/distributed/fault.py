"""Fault tolerance and straggler mitigation for the training loop.

Mechanisms (DESIGN.md §4):
  * checkpoint/restart — atomic committed checkpoints (checkpoint.ckpt),
    ``resume_or_init`` picks up the latest on relaunch; restore works onto a
    different mesh (elastic: 512 → 256 chips) because checkpoints are
    sharding-agnostic.
  * step-scoped retry — a failing step (device error, preemption signal)
    triggers restore-from-last-commit and replay; repeated failure at the
    same step aborts with a clear report (poison-pill detection).
  * straggler detection — per-step wall times are tracked; hosts slower than
    ``k×median`` over a window are flagged (on a real cluster the launcher
    would re-shard around them; here we log and expose the signal).

This module stays importable without jax (jax is imported lazily at
run/restore time): the async actor–learner tier's spawn workers import
``repro.distributed`` in a fresh interpreter and must not pay — or
fork-inherit — a jax import they don't need.
"""
from __future__ import annotations

import collections
import os
import time
from typing import Callable, Iterable, Optional, Sequence, Union

from repro.checkpoint import ckpt


def _true_median(xs) -> float:
    """The actual median: mean of the two middle elements for even-length
    windows (``sorted[n // 2]`` alone is the *upper*-middle element, which
    inflated the k×median straggler threshold early in the window and
    under-flagged genuinely slow steps)."""
    s = sorted(xs)
    n = len(s)
    if n == 0:
        return 0.0
    mid = n // 2
    if n % 2:
        return float(s[mid])
    return float(s[mid - 1] + s[mid]) / 2.0


class StragglerMonitor:
    """Rolling per-step wall-time stats with k×median flagging (the paper's
    EnvPool insight at pod scale: never wait on the slowest worker)."""

    def __init__(self, window: int = 64, k: float = 2.0, min_samples: int = 8):
        self.times = collections.deque(maxlen=window)
        self.k = k
        self.min_samples = min_samples
        self.flagged = 0
        self.last_seen: Optional[float] = None   # monotonic s of last record

    def record(self, dt: float) -> bool:
        self.times.append(dt)
        self.last_seen = time.monotonic()
        if len(self.times) >= self.min_samples:
            if dt > self.k * _true_median(self.times):
                self.flagged += 1
                return True
        return False

    @property
    def median(self) -> float:
        return _true_median(self.times)

    def age(self) -> Optional[float]:
        """Seconds since the last recorded arrival — the staleness signal
        /healthz and the async tier's metrics surface. ``None`` until the
        first record (a monitor that never saw a sample is booting, not
        stale)."""
        if self.last_seen is None:
            return None
        return time.monotonic() - self.last_seen

    def stats(self) -> dict:
        """The monitor's exportable view: rolling median, flag count, and
        seconds-since-last-arrival staleness age."""
        return {"median_s": self.median, "flagged": int(self.flagged),
                "samples": len(self.times), "age_s": self.age()}


class ResilientLoop:
    """Wraps a jitted ``step(state, batch) -> (state, metrics)`` with
    checkpoint/restart fault tolerance.

    The ``batches`` contract (what ``run`` accepts, and what recovery can
    promise for each):

      * a **Sequence** (``len`` + integer indexing) — fully replayable.
        ``batches[i]`` drives step ``i + 1``; on a step failure the loop
        restores the newest committed checkpoint (step S), rewinds
        ``steps_done`` to S, and replays batches ``S, S+1, …`` so every
        batch is applied exactly once along the surviving state lineage.
        ``on_metrics`` re-fires for the replayed steps.
      * a **callable** ``batches(start_step) -> iterator`` — replayable by
        construction; recovery calls it again with the restored step.
      * a bare **iterator/iterable** — a live stream (e.g. the async tier's
        rollout-fragment source). It cannot be rewound, so recovery retries
        the *current* batch only; the checkpoint is restored only when it
        sits exactly at ``steps_done`` (restoring an older one would desync
        params from a stream that cannot replay the skipped batches — the
        bug this contract exists to prevent).

    ``ckpt_dir=None`` (or ``save_every <= 0``) disables checkpointing; the
    loop still retries failed steps against the current state.
    """

    def __init__(self, step_fn: Callable, ckpt_dir: Optional[str],
                 save_every: int = 100, max_retries: int = 3,
                 async_save: bool = True, shardings=None,
                 keep: Optional[int] = 3):
        self.step_fn = step_fn
        self.ckpt_dir = ckpt_dir
        self.save_every = save_every
        self.max_retries = max_retries
        self.async_save = async_save
        self.shardings = shardings
        self.keep = keep
        self.monitor = StragglerMonitor()
        self._save_handle = None
        self.steps_done = 0
        self.recoveries = 0

    # -- checkpoint plumbing ---------------------------------------------------
    def _latest(self) -> Optional[str]:
        """Newest committed checkpoint path — ``ckpt_dir`` may itself be a
        committed checkpoint (manually named/renamed dir with an
        ``index.json``), else the newest ``step_N`` under it."""
        if self.ckpt_dir is None:
            return None
        if os.path.exists(os.path.join(self.ckpt_dir, "index.json")):
            return self.ckpt_dir
        return ckpt.latest(self.ckpt_dir)

    def resume_or_init(self, init_state):
        """Latest committed checkpoint if present, else the given state.

        The step count comes from the checkpoint's own metadata
        (``ckpt.step_of`` reads ``index.json``) — never from parsing the
        directory path, which silently mis-parsed (or crashed on) any
        ``ckpt_dir`` whose basename contains an underscore or a manually
        renamed checkpoint dir."""
        import jax
        path = self._latest()
        if path is None:
            return init_state, 0
        like = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), init_state)
        state = ckpt.restore(path, like, self.shardings)
        return state, ckpt.step_of(path)

    def _save(self, state):
        if self._save_handle is not None:
            self._save_handle.join()   # one in-flight save at a time
        out = ckpt.save(self.ckpt_dir, state, step=self.steps_done,
                        async_=self.async_save, keep=self.keep)
        self._save_handle = out if self.async_save else None

    # -- the batch-source protocol ---------------------------------------------
    @staticmethod
    def _replay_fn(batches):
        """``start_step -> iterator`` for replayable sources, None for live
        streams (see the class docstring for the contract)."""
        if callable(batches):
            return lambda start: iter(batches(start))
        if isinstance(batches, Sequence) or (
                hasattr(batches, "__len__") and hasattr(batches, "__getitem__")):
            return lambda start: (batches[i]
                                  for i in range(start, len(batches)))
        return None

    def run(self, state, batches: Union[Sequence, Callable, Iterable],
            on_metrics: Optional[Callable] = None):
        """Iterate ``batches``; survives step failures via restore+replay
        (replayable sources) or restore-in-place+retry (live streams)."""
        import jax
        replay = self._replay_fn(batches)
        it = replay(self.steps_done) if replay is not None else iter(batches)
        retries = 0
        pending = None
        exhausted = object()
        while True:
            if pending is None:
                pending = next(it, exhausted)
                if pending is exhausted:
                    break
            t0 = time.perf_counter()
            try:
                state, metrics = self.step_fn(state, pending)
                # the sync is the failure detector: a device error only
                # surfaces when the step's result is materialized (fall back
                # to a state leaf when a step emits no metrics)
                leaves = jax.tree.leaves(metrics) or jax.tree.leaves(state)
                if leaves:
                    jax.block_until_ready(leaves[0])  # repro: noqa[HOST-SYNC]
            except Exception as e:   # device failure / preemption
                retries += 1
                self.recoveries += 1
                if retries > self.max_retries:
                    raise RuntimeError(
                        f"step {self.steps_done + 1} failed {retries}x; "
                        f"aborting (poison pill?)") from e
                path = self._latest()
                if path is not None:
                    step = ckpt.step_of(path)
                    if replay is not None:
                        # restore AND rewind: replay batches step..steps_done
                        # so none are skipped and none applied twice on the
                        # surviving lineage
                        state, _ = self.resume_or_init(state)
                        self.steps_done = step
                        it = replay(step)
                        pending = None
                    elif step == self.steps_done:
                        # live stream: the checkpoint matches the stream
                        # position exactly, so restoring is a pure state
                        # refresh — retry the same pending batch
                        state, _ = self.resume_or_init(state)
                    # else: checkpoint is behind an unrewindable stream;
                    # retry the pending batch against the current state
                continue
            retries = 0
            slow = self.monitor.record(time.perf_counter() - t0)
            if slow:
                metrics = dict(metrics, straggler_flag=True)
            self.steps_done += 1
            pending = None
            if on_metrics:
                on_metrics(self.steps_done, metrics)
            if (self.ckpt_dir is not None and self.save_every > 0
                    and self.steps_done % self.save_every == 0):
                self._save(state)
        if self._save_handle is not None:
            self._save_handle.join()
        return state
