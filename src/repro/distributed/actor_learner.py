"""Async actor–learner topology: the fifth engine tier (IMPALA-shaped).

N actor processes (spawn context, one fresh interpreter each) run *jitted*
inference + env stepping and stream fixed-size rollout fragments through a
shared-memory slab; the learner consumes fragments at its own rate, applies
staleness policy (drop, or V-trace importance clamps — rl/learner.py), and
broadcasts refreshed params through a versioned seqlock region of the same
slab. This breaks the rollout/learn coupling of the other four tiers: a slow
actor (latency jitter, preemption) no longer stalls the update cadence —
the paper's EnvPool "never wait for the slowest" insight applied at the
process level instead of the env level.

Slab layout (core/shm.py idiom — numpy views over one segment, one-writer
ctrl bytes, no locks):

  * param region — seqlock (u64 counter, odd while the learner writes) +
    version + the flattened param leaves. Actors re-read only when the
    version changes; a torn read is detected by the counter and retried.
  * fragment rings — per env-shard, ``actor_slots`` slots of
    EMPTY → WRITING → FULL (actor) → EMPTY (learner after copy-out). The
    small ring depth is deliberate backpressure: an actor that gets ahead
    of the learner blocks on a full ring, bounding how stale its next
    fragment can be.
  * assignment table — ``assign[shard] -> actor`` + an epoch counter per
    shard. When the ctrl handshake detects a dead actor (process gone
    without an EXIT status) the learner reassigns its shards to the
    least-loaded survivors and bumps the epochs; the new owner re-seeds
    those shards' env states from (shard, epoch), so training proceeds
    instead of hanging (elastic, like the checkpoint layer).
  * per-actor heartbeat / status / error rows — an actor that *raises*
    (poisoned env) reports through its error row and the learner surfaces
    an ``ActorError``; an actor that *dies* (kill, OOM) is reassigned.

This module is the spawn-worker entrypoint, so its import chain must stay
jax-free (same rule as core/shm.py): actors import jax themselves after the
fork guard, and all learner-side jax use is deferred to method bodies.
"""
from __future__ import annotations

import pickle
import sys
import time
from collections import deque
from dataclasses import dataclass, field
from multiprocessing import get_context, shared_memory
from typing import NamedTuple, Optional, Tuple

import numpy as np

from repro.core import shm
from repro.telemetry import span as _span
from repro.telemetry import traceprop as _traceprop
from repro.telemetry.procstats import (ACTOR_FIELDS, STALENESS_EDGES,
                                       StatSlab)

# fragment-slot states (one writer per state transition, like shm ctrl bytes)
SLOT_EMPTY = 0     # learner-owned: actor may claim
SLOT_WRITING = 1   # actor mid-write (reset by the learner if the actor dies)
SLOT_FULL = 2      # complete fragment; learner copies out then EMPTYs

# actor status bytes
A_BOOT = 0
A_RUN = 1
A_ERR = 2          # actor raised; error row holds the message
A_EXIT = 3         # clean exit after stop

INFO_KEYS = ("score", "episode_return", "episode_length", "valid")


class ActorError(RuntimeError):
    """An actor process raised inside its rollout loop (poisoned env/policy;
    the same failure would occur on any actor, so it propagates instead of
    triggering reassignment)."""

    def __init__(self, actor: int, op: str, message: str):
        super().__init__(f"actor {actor} failed during {op}: {message}")
        self.actor, self.op, self.message = actor, op, message


@dataclass(frozen=True)
class ReshardEvent:
    """One dead-actor recovery: which shards moved where."""
    actor: int
    shards: Tuple[int, ...]
    new_owners: Tuple[int, ...]


@dataclass(frozen=True)
class FragSpec:
    """Geometry of the shared slab, pickled into every actor (jax-free)."""
    num_actors: int
    num_shards: int          # disjoint env shards, assign[]-mapped to actors
    slots: int               # fragment ring depth per shard (backpressure)
    unroll: int              # T steps per fragment
    envs_per_shard: int      # E
    num_agents: int          # A (rows per env)
    obs_dim: int
    act_dim: int             # action components per agent row
    act_dtype: str           # "int32" | "float32"
    # flattened param leaves: ((shape, dtype, byte offset), ...) + total size
    param_specs: Tuple[Tuple[Tuple[int, ...], str, int], ...]
    param_bytes: int

    @property
    def rows(self) -> int:   # agent rows per fragment
        return self.envs_per_shard * self.num_agents


class AsyncLayout:
    """Byte layout of the actor–learner slab (SlabLayout idiom)."""

    def __init__(self, spec: FragSpec):
        self.spec = spec
        S, Q, T = spec.num_shards, spec.slots, spec.unroll
        R, E, N = spec.rows, spec.envs_per_shard, spec.num_actors
        shapes = {
            "stop": ((1,), np.uint8),
            "pseq": ((1,), np.int64),     # seqlock counter (odd = writing)
            "pver": ((1,), np.int64),     # published params version
            "params": ((spec.param_bytes,), np.uint8),
            "assign": ((S,), np.int32),   # shard -> owning actor
            "epoch": ((S,), np.int64),    # bumped on reassignment
            "hbeat": ((N,), np.int64),
            "astat": ((N,), np.uint8),
            "err": ((N, shm.ERR_BYTES), np.uint8),
            "fctrl": ((S, Q), np.uint8),
            "fver": ((S, Q), np.int64),   # policy version that acted
            "fseq": ((S, Q), np.int64),   # per-shard fragment counter
            "factor": ((S, Q), np.int32),
            "obs": ((S, Q, T, R, spec.obs_dim), np.float32),
            "act": ((S, Q, T, R, spec.act_dim), np.dtype(spec.act_dtype)),
            "logp": ((S, Q, T, R), np.float32),
            "val": ((S, Q, T, R), np.float32),
            "rew": ((S, Q, T, R), np.float32),
            "done": ((S, Q, T, R), np.uint8),
            "reset": ((S, Q, T, R), np.uint8),
            "i_score": ((S, Q, T, E), np.float32),
            "i_ret": ((S, Q, T, E), np.float32),
            "i_len": ((S, Q, T, E), np.int32),
            "i_valid": ((S, Q, T, E), np.uint8),
            "boot": ((S, Q, R), np.float32),   # bootstrap value rows
        }
        self.sections = {}
        end = 0
        for name, (shape, dtype) in shapes.items():
            start, end = shm._section(end, shape, dtype)
            self.sections[name] = (start, shape, dtype)
        self.nbytes = end

    def views(self, buf) -> dict:
        out = {}
        for name, (start, shape, dtype) in self.sections.items():
            n = int(np.prod(shape, dtype=np.int64))
            out[name] = np.frombuffer(
                buf, dtype=dtype, count=n, offset=start).reshape(shape)
        return out

    def param_views(self, buf) -> list:
        """One numpy view per flattened param leaf, in pytree-flatten order
        (both sides flatten the same dict structure → same sorted-key
        order)."""
        base = self.sections["params"][0]
        return [np.frombuffer(buf, dtype=np.dtype(dt),
                              count=int(np.prod(shape, dtype=np.int64)),
                              offset=base + off).reshape(shape)
                for shape, dt, off in self.spec.param_specs]


def make_param_specs(leaves) -> Tuple[Tuple, int]:
    """((shape, dtype, offset), ...) for host copies of param leaves, each
    offset aligned so ``np.frombuffer`` is legal for its dtype."""
    specs, off = [], 0
    for leaf in leaves:
        a = np.asarray(leaf)
        off = ((off + 7) // 8) * 8
        specs.append((tuple(a.shape), str(a.dtype), off))
        off += a.nbytes
    return tuple(specs), off


def read_params_seqlock(v: dict, pviews: list, spin: shm.SpinConfig,
                        srow=None):
    """Torn-read-safe copy of the published leaves: retry while the seqlock
    counter is odd (write in progress) or changed across the copy.
    ``srow`` (a telemetry ``StatRow``) counts the retries when given."""
    w = shm.SpinWait(spin)
    while True:
        s1 = int(v["pseq"][0])
        if s1 % 2 == 0:
            leaves = [pv.copy() for pv in pviews]
            ver = int(v["pver"][0])
            if int(v["pseq"][0]) == s1:
                return leaves, ver
        if srow is not None:
            srow.add("seqlock_retries")
        w.pause()


@dataclass(frozen=True)
class ActorConfig:
    """Everything one spawn actor needs (small and picklable)."""
    shm_name: str
    actor_id: int
    spec: FragSpec
    seed: int                # shared base seed; streams are keyed by shard
    spin: shm.SpinConfig = field(default_factory=shm.SpinConfig)
    payload_env: bytes = b""
    payload_policy: bytes = b""
    payload_dist: bytes = b""
    jitter_ms: float = 0.0   # injected per-step latency (bench/fault tests)
    stats: object = None     # telemetry.procstats.StatSpec | None
    trace: object = None     # telemetry.traceprop.TraceConfig | None


class Fragment(NamedTuple):
    """One copied-out rollout fragment (numpy, learner-side)."""
    shard: int
    actor: int
    version: int             # params version that produced it
    seq: int
    obs: np.ndarray          # (T, R, obs_dim)
    actions: np.ndarray      # (T, R, act_dim)
    logprobs: np.ndarray     # (T, R)
    values: np.ndarray
    rewards: np.ndarray
    dones: np.ndarray        # (T, R) bool
    resets: np.ndarray
    infos: dict              # {key: (T, E)}
    boot: np.ndarray         # (R,) bootstrap values


# =============================== actor side ==================================

def actor_main(cfg: ActorConfig) -> None:
    """Spawn-actor entrypoint: claim an EMPTY slot per owned shard, run one
    jitted T-step rollout, write the fragment, repeat. Params refresh via
    the seqlock whenever the published version changes; ownership is
    re-read every pass so reassignment takes effect without coordination."""
    if "jax" in sys.modules:
        # same enforcement as shm.worker_main: a forked child inherits the
        # parent's jax/XLA state and deadlocks — actors must be spawned
        raise RuntimeError(
            "actor started with jax already imported — it was forked, not "
            "spawned. AsyncRollouts must use the 'spawn' start method")
    import jax
    import jax.numpy as jnp
    from repro.core.vector import VecEnv
    from repro.rl.rollout import RolloutCarry, rollout

    spec = cfg.spec
    me = cfg.actor_id
    seg = shm.attach_untracked(cfg.shm_name)
    lay = AsyncLayout(spec)
    v = lay.views(seg.buf)
    pviews = lay.param_views(seg.buf)
    slab = srow = None
    if cfg.stats is not None:
        # lock-free per-actor stat row: steps / fragments / ring stalls /
        # seqlock retries / staleness histogram, aggregated by the learner
        slab = StatSlab.attach(cfg.stats)
        srow = slab.row(me)
    # per-process tracing: spans flush to this actor's own spans-<pid>.jsonl
    # (meta header written eagerly, so a killed actor still leaves a
    # mergeable file); CachedSpans are no-ops when the parent shipped no
    # trace config
    from repro.telemetry.spans import CachedSpan
    tracer = None
    if cfg.trace is not None:
        from repro.telemetry import traceprop
        tracer = traceprop.init_worker(cfg.trace, role=f"actor-{me}")
    rollout_span = CachedSpan("actor.rollout")
    refresh_span = CachedSpan("actor.param_refresh")
    t_flush = time.monotonic()
    try:
        env = pickle.loads(cfg.payload_env)
        policy = pickle.loads(cfg.payload_policy)
        dist = pickle.loads(cfg.payload_dist)
        vec = VecEnv(env, spec.envs_per_shard)
        step_fn = vec.step_fn()
        T, R = spec.unroll, spec.rows

        def frag(params, carry, key):
            return rollout(policy, params, step_fn, carry, key, T, dist)
        jfrag = jax.jit(frag)

        base = jax.random.PRNGKey(cfg.seed)
        tmpl = jax.tree.structure(policy.abstract())
        leaves, pver = read_params_seqlock(v, pviews, cfg.spin, srow)
        params = jax.tree.unflatten(tmpl, [jnp.asarray(l) for l in leaves])
        rng = np.random.default_rng(cfg.seed * 7919 + me + 1)
        shard_state = {}      # shard -> [carry, epoch, seq]
        spin = shm.SpinWait(cfg.spin)
        v["astat"][me] = A_RUN
        while not v["stop"][0]:
            v["hbeat"][me] += 1
            if srow is not None:
                # wall-clock liveness beat: /healthz reads its age to tell a
                # slow actor from a dead one (idle passes still beat)
                srow.set("last_beat_ns", time.time_ns())
            produced = False
            t_pass = time.monotonic_ns()
            for s in range(spec.num_shards):
                if v["stop"][0] or int(v["assign"][s]) != me:
                    continue
                if int(v["pver"][0]) != pver:
                    with refresh_span:
                        leaves, pver = read_params_seqlock(v, pviews,
                                                           cfg.spin, srow)
                        params = jax.tree.unflatten(
                            tmpl, [jnp.asarray(l) for l in leaves])
                    if srow is not None:
                        srow.add("param_loads")
                ep = int(v["epoch"][s])
                st = shard_state.get(s)
                if st is None or st[1] != ep:
                    # (shard, epoch)-keyed env state: a reassigned shard
                    # restarts from a deterministic seed on its new owner
                    k0 = jax.random.fold_in(
                        jax.random.fold_in(base, 1_000 + s), ep)
                    env_state, obs = vec.init(k0)
                    carry = RolloutCarry(env_state, obs,
                                         policy.initial_carry(R),
                                         jnp.zeros((R,), jnp.bool_))
                    st = shard_state[s] = [carry, ep, 0]
                slot = None
                for q in range(spec.slots):
                    if int(v["fctrl"][s, q]) == SLOT_EMPTY:
                        slot = q
                        break
                if slot is None:          # ring full: learner is behind —
                    if srow is not None:  # backpressure bounds staleness
                        srow.add("ring_full")
                    continue
                with rollout_span:   # claim → jitted rollout → commit
                    v["fctrl"][s, slot] = SLOT_WRITING
                    kroll = jax.random.fold_in(jax.random.fold_in(
                        jax.random.fold_in(jax.random.fold_in(base, 2), s),
                        ep), st[2])
                    carry, traj, last_value = jfrag(params, st[0], kroll)
                    if cfg.jitter_ms > 0.0:
                        # emulate jitter_ms/step of host latency, ±50%
                        time.sleep(T * cfg.jitter_ms / 1e3
                                   * rng.uniform(0.5, 1.5))
                    v["obs"][s, slot] = np.asarray(traj.obs, np.float32)
                    v["act"][s, slot] = np.asarray(traj.actions,
                                                   v["act"].dtype)
                    v["logp"][s, slot] = np.asarray(traj.logprobs,
                                                    np.float32)
                    v["val"][s, slot] = np.asarray(traj.values, np.float32)
                    v["rew"][s, slot] = np.asarray(traj.rewards, np.float32)
                    v["done"][s, slot] = np.asarray(traj.dones, np.uint8)
                    v["reset"][s, slot] = np.asarray(traj.resets, np.uint8)
                    v["i_score"][s, slot] = np.asarray(traj.infos["score"],
                                                       np.float32)
                    v["i_ret"][s, slot] = np.asarray(
                        traj.infos["episode_return"], np.float32)
                    v["i_len"][s, slot] = np.asarray(
                        traj.infos["episode_length"], np.int32)
                    v["i_valid"][s, slot] = np.asarray(traj.infos["valid"],
                                                       np.uint8)
                    v["boot"][s, slot] = np.asarray(last_value, np.float32)
                    v["fver"][s, slot] = pver
                    v["fseq"][s, slot] = st[2]
                    v["factor"][s, slot] = me
                    st[0], st[2] = carry, st[2] + 1
                    v["fctrl"][s, slot] = SLOT_FULL  # commit (written last)
                produced = True
                if srow is not None:
                    srow.add("fragments")
                    srow.add("steps", T * R)
                    # learner-updates-behind at commit time
                    srow.observe(int(v["pver"][0]) - pver)
            if srow is not None:
                srow.add("busy_ns" if produced else "wait_ns",
                         time.monotonic_ns() - t_pass)
            if produced:
                spin.reset()
            else:
                spin.pause()
            if tracer is not None and time.monotonic() - t_flush > 0.25:
                tracer.flush()
                t_flush = time.monotonic()
        v["astat"][me] = A_EXIT
    except Exception as e:    # noqa: BLE001 — forwarded to the learner
        shm._write_error(v, me, "step", e)
        v["astat"][me] = A_ERR
        if srow is not None:
            srow.add("errors")
    finally:
        if tracer is not None:
            # crash-safe: the error path above and clean exits both flush
            # whatever the periodic flush hasn't written yet
            try:
                tracer.flush()
            except Exception:
                pass
        del v, pviews, srow
        seg.close()
        if slab is not None:
            slab.close()


# =============================== learner side ================================

class AsyncRollouts:
    """Learner-side handle: owns the slab, the actor processes, the param
    broadcast, and dead-actor/straggler monitoring. All jax use is lazy —
    see the module docstring."""

    def __init__(self, env, policy, dist, tcfg, *, params0, seed: int,
                 jitter_ms: float = None, spin: shm.SpinConfig = None):
        import jax
        from repro.distributed.fault import StragglerMonitor

        N = tcfg.num_actors
        S = N * tcfg.shards_per_actor
        if N < 1:
            raise ValueError(f"num_actors must be >= 1, got {N}")
        if tcfg.num_envs % S:
            raise ValueError(
                f"num_envs={tcfg.num_envs} not divisible by num_shards={S} "
                f"(num_actors={N} × shards_per_actor="
                f"{tcfg.shards_per_actor})")
        if policy.recurrent:
            raise ValueError(
                "the async tier does not ship recurrent carries through the "
                "fragment slab yet; use the jit/host tiers for LSTM policies")
        A = getattr(env, "num_agents", 1)
        leaves = jax.tree.leaves(params0)
        pspecs, pbytes = make_param_specs(leaves)
        self.spec = FragSpec(
            num_actors=N, num_shards=S, slots=max(1, tcfg.actor_slots),
            unroll=tcfg.unroll_length, envs_per_shard=tcfg.num_envs // S,
            num_agents=A, obs_dim=policy.obs_dim,
            act_dim=dist.action_dim,
            act_dtype=np.dtype(dist.action_dtype).name,
            param_specs=pspecs, param_bytes=pbytes)
        self.layout = AsyncLayout(self.spec)
        self.spin = spin or shm.default_spin(workers=N + 1)
        jitter = tcfg.actor_jitter_ms if jitter_ms is None else jitter_ms

        self._seg = shared_memory.SharedMemory(
            create=True, size=self.layout.nbytes)
        self._v = self.layout.views(self._seg.buf)
        self._pviews = self.layout.param_views(self._seg.buf)
        self._v["assign"][:] = np.arange(S, dtype=np.int32) % N
        self._v["pver"][0] = -1
        self.publish(params0, 0)

        self._fifo = deque()
        self._dead = set()
        self.events = []
        self._monitors = [StragglerMonitor(window=16, min_samples=4)
                          for _ in range(N)]
        self._last_arrival = [None] * N
        self.straggler_flags = [0] * N
        self._last_liveness = 0.0

        env_p = shm.dumps_env_fn(env)
        pol_p = shm.dumps_env_fn(policy)
        dist_p = shm.dumps_env_fn(dist)
        # per-actor telemetry rows (separate tiny segment, learner-owned):
        # written lock-free by actors, aggregated in stats() — and readable
        # for dead actors, whose rows freeze at their last write
        self._stats_slab = StatSlab.create(N, ACTOR_FIELDS, STALENESS_EDGES)
        # cross-process trace propagation: ship the learner's TraceConfig
        # (None when tracing is off) so each actor flushes its own
        # spans-<pid>.jsonl into the same run dir
        trace_cfg = _traceprop.current()
        ctx = get_context("spawn")
        self._procs = []
        try:
            with _span("async.spawn"):
                for a in range(N):
                    p = ctx.Process(
                        target=actor_main,
                        args=(ActorConfig(
                            shm_name=self._seg.name, actor_id=a,
                            spec=self.spec, seed=seed, spin=self.spin,
                            payload_env=env_p, payload_policy=pol_p,
                            payload_dist=dist_p, jitter_ms=jitter,
                            stats=self._stats_slab.spec, trace=trace_cfg),),
                        daemon=True, name=f"repro-actor-{a}")
                    p.start()
                    self._procs.append(p)
        except Exception:
            self.close()
            raise

    # -- param broadcast -------------------------------------------------------
    def publish(self, params, version: int) -> None:
        """Seqlock-publish new params. Leaves are materialized to host
        *before* the lock window opens, so a poisoned array (failed update)
        raises here without ever touching the slab — actors keep acting on
        the previous version."""
        import jax
        host = [np.asarray(l) for l in jax.tree.leaves(params)]
        with _span("async.publish"):
            v = self._v
            v["pseq"][0] += 1          # odd: readers retry
            for dst, src in zip(self._pviews, host):
                np.copyto(dst, src.astype(dst.dtype, copy=False))
            v["pver"][0] = version
            v["pseq"][0] += 1          # even: committed
            self.version = version

    # -- fragment harvest ------------------------------------------------------
    def poll(self) -> int:
        """Copy out every FULL slot (ordered by per-shard sequence number)
        into the FIFO; returns how many arrived. Also surfaces actor
        errors."""
        v = self._v
        self._check_errors()
        found = []
        S, Q = self.spec.num_shards, self.spec.slots
        for s in range(S):
            for q in range(Q):
                if int(v["fctrl"][s, q]) == SLOT_FULL:
                    found.append((int(v["fseq"][s, q]), s, q))
        found.sort()
        now = time.monotonic()
        for seq, s, q in found:
            actor = int(v["factor"][s, q])
            frag = Fragment(
                shard=s, actor=actor, version=int(v["fver"][s, q]), seq=seq,
                obs=v["obs"][s, q].copy(),
                actions=v["act"][s, q].copy(),
                logprobs=v["logp"][s, q].copy(),
                values=v["val"][s, q].copy(),
                rewards=v["rew"][s, q].copy(),
                dones=v["done"][s, q].astype(bool),
                resets=v["reset"][s, q].astype(bool),
                infos={"score": v["i_score"][s, q].copy(),
                       "episode_return": v["i_ret"][s, q].copy(),
                       "episode_length": v["i_len"][s, q].copy(),
                       "valid": v["i_valid"][s, q].astype(bool)},
                boot=v["boot"][s, q].copy())
            v["fctrl"][s, q] = SLOT_EMPTY         # hand the slot back
            self._fifo.append(frag)
            if 0 <= actor < self.spec.num_actors:
                last = self._last_arrival[actor]
                if last is not None:
                    if self._monitors[actor].record(now - last):
                        self.straggler_flags[actor] += 1
                self._last_arrival[actor] = now
        return len(found)

    def wait_fragments(self, n: int, *, timeout: float) -> list:
        """Block (spin ladder) until ``n`` fragments are buffered; FIFO
        order. Dead actors are detected and resharded *while waiting*, so a
        kill never hangs the learner — only a genuinely fragment-less
        ``timeout`` raises."""
        deadline = time.monotonic() + timeout
        w = shm.SpinWait(self.spin)
        # liveness is checked unconditionally once per call: a fast surviving
        # actor that keeps the FIFO full must not mask a dead peer (its
        # shards would silently stop contributing). The throttle below only
        # bounds waitpid traffic inside the hot spin loop.
        self._check_actors()
        self._last_liveness = time.monotonic()
        with _span("async.wait_fragments"):
            while True:
                if self.poll():
                    w.reset()
                now = time.monotonic()
                if now - self._last_liveness > 0.05:
                    self._last_liveness = now
                    self._check_actors()
                if len(self._fifo) >= n:
                    return [self._fifo.popleft() for _ in range(n)]
                if now > deadline:
                    raise TimeoutError(
                        f"async tier: {n} fragment(s) not produced within "
                        f"{timeout}s (have {len(self._fifo)}; alive="
                        f"{self.alive_actors()}, assign="
                        f"{self._v['assign'].tolist()})")
                w.pause()

    # -- fault handling --------------------------------------------------------
    def _check_errors(self) -> None:
        for a in range(self.spec.num_actors):
            if int(self._v["astat"][a]) == A_ERR and a not in self._dead:
                self._dead.add(a)
                op, msg = shm.read_error(self._v, a)
                raise ActorError(a, op, msg)

    def _check_actors(self) -> None:
        """Ctrl-handshake liveness: a process that is gone without a clean
        EXIT status is dead — harvest nothing from it, reset its half-written
        slots, and reassign its shards to the least-loaded survivors."""
        stopping = bool(self._v["stop"][0])
        for a, p in enumerate(self._procs):
            if a in self._dead or p.is_alive():
                continue
            if stopping and int(self._v["astat"][a]) == A_EXIT:
                continue
            self._dead.add(a)
            self._reshard(a)

    def _reshard(self, dead: int) -> None:
        survivors = [b for b in range(self.spec.num_actors)
                     if b not in self._dead]
        if not survivors:
            # raised before binding any slab view locally: a view captured
            # in this traceback would pin the buffer and break close()
            raise RuntimeError(
                f"all {self.spec.num_actors} actors are dead (last: actor "
                f"{dead}); nothing left to reassign shards to")
        v = self._v
        loads = {b: int(np.sum(np.asarray(v["assign"]) == b))
                 for b in survivors}
        moved, owners = [], []
        for s in range(self.spec.num_shards):
            if int(v["assign"][s]) != dead:
                continue
            b = min(survivors, key=lambda x: (loads[x], x))
            loads[b] += 1
            for q in range(self.spec.slots):
                # the dead writer's half-written slot is garbage; FULL slots
                # were committed before death and stay consumable
                if int(v["fctrl"][s, q]) == SLOT_WRITING:
                    v["fctrl"][s, q] = SLOT_EMPTY
            v["epoch"][s] += 1         # new owner re-seeds (shard, epoch)
            v["assign"][s] = b         # ownership handoff (written last)
            moved.append(s)
            owners.append(b)
        self.events.append(ReshardEvent(actor=dead, shards=tuple(moved),
                                        new_owners=tuple(owners)))

    # -- introspection ---------------------------------------------------------
    def alive_actors(self) -> list:
        return [a for a, p in enumerate(self._procs)
                if a not in self._dead and p.is_alive()]

    def liveness(self) -> dict:
        """Per-actor liveness for /healthz: wall-clock ``last_beat_ns``
        from the stat slab (actors beat every pass, even idle ones) plus
        dead detection that does NOT wait for the learner's next
        ``wait_fragments`` — a killed process shows up here immediately."""
        beats = []
        slab = getattr(self, "_stats_slab", None)
        if slab is not None and slab.counters is not None:
            col = slab.spec.fields.index("last_beat_ns")
            beats = [int(b) for b in slab.counters[:, col]]
        v = getattr(self, "_v", None)
        dead = set(self._dead)
        stopping = v is None or bool(v["stop"][0])
        for a, p in enumerate(self._procs):
            if p.is_alive():
                continue
            if stopping and (v is None or int(v["astat"][a]) == A_EXIT):
                continue                # clean shutdown, not a death
            dead.add(a)
        return {"now_ns": time.time_ns(),
                "workers": self.spec.num_actors,
                "last_beat_ns": beats, "dead": sorted(dead)}

    def stats(self) -> dict:
        out = {
            "assign": self._v["assign"].tolist(),
            "epoch": self._v["epoch"].tolist(),
            "heartbeats": self._v["hbeat"].tolist(),
            "dead": sorted(self._dead),
            "straggler_flags": list(self.straggler_flags),
            "reshards": len(self.events),
            "liveness": self.liveness(),
            # staleness age per actor: seconds since its last fragment
            # arrived (None before the first one) + the monitor medians
            "stragglers": [m.stats() for m in self._monitors],
        }
        if self._stats_slab is not None:
            # per-actor shared-memory rows: steps/fragments/ring stalls/
            # seqlock retries + the staleness histogram, zero pickling.
            # Dead actors' rows stay readable (learner-owned segment).
            out["actors"] = self._stats_slab.aggregate()
        return out

    # -- lifecycle -------------------------------------------------------------
    def close(self) -> None:
        if getattr(self, "_seg", None) is None:
            return
        self._v["stop"][0] = 1
        shm.spin_until(
            lambda: all(not p.is_alive() for p in self._procs),
            self.spin, timeout=5.0)
        for p in self._procs:
            if p.is_alive():
                p.terminate()
            p.join(timeout=5.0)
        if getattr(self, "_stats_slab", None) is not None:
            self._stats_slab.close()
            self._stats_slab = None
        del self._v, self._pviews
        try:
            self._seg.close()
        except BufferError:
            # a propagating exception's traceback frames can still pin slab
            # views (e.g. poll() locals on the ActorError path); the segment
            # is unlinked below regardless and the mapping goes with the
            # process
            pass
        try:
            self._seg.unlink()
        except FileNotFoundError:
            pass
        self._seg = None


def stack_fragments(frags: list):
    """n fragments → one (T, n·R)-batched Trajectory + bootstrap row — the
    async twin of TrainEngine._stack_fragments (fragments arrive already
    time-major, so this is pure concatenation along the batch axis)."""
    from repro.rl.rollout import Trajectory
    cat = lambda key: np.concatenate([getattr(f, key) for f in frags],
                                     axis=1)
    infos = {k: np.concatenate([f.infos[k] for f in frags], axis=1)
             for k in INFO_KEYS}
    traj = Trajectory(
        obs=cat("obs"), actions=cat("actions"), logprobs=cat("logprobs"),
        values=cat("values"), rewards=cat("rewards"), dones=cat("dones"),
        resets=cat("resets"), infos=infos)
    last_value = np.concatenate([f.boot for f in frags])
    return traj, last_value
