"""Launchers. Lazy (PEP 562) like ``repro.core``: under ``python -m
repro.launch.train --host-backend proc`` every spawn worker re-imports the
parent's main module (``repro.launch.train`` as ``__mp_main__``), so this
package must not pull jax at import time — ``mesh`` costs ~0.4 s of jax per
worker and trips ``shm.worker_main``'s forked-jax guard.

NOTE: do not import dryrun eagerly either — it sets XLA_FLAGS at import
time.
"""

_SUBMODULES = ("mesh", "train", "serve", "dryrun", "hlo_analysis")

__all__ = list(_SUBMODULES)


def __getattr__(name):
    import importlib
    if name in _SUBMODULES:
        return importlib.import_module(f"repro.launch.{name}")
    raise AttributeError(f"module 'repro.launch' has no attribute {name!r}")


def __dir__():
    return sorted(__all__)
