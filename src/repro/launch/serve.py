"""Serving launcher: batched autoregressive decoding with a KV/SSM cache
(the serve_step the decode dry-run shapes lower).

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b --smoke \
      --batch 4 --prompt-len 32 --tokens 16
"""
import argparse


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--tokens", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=1.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    import time
    import jax
    import jax.numpy as jnp
    from repro.configs import get_config, get_smoke_config
    from repro.models.policy import BackbonePolicy
    from repro.rl import actor

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    policy = BackbonePolicy(cfg, tp=1, kernel="auto")
    key = jax.random.PRNGKey(args.seed)
    params = policy.init(key)
    prompt = jax.random.randint(jax.random.fold_in(key, 1),
                                (args.batch, args.prompt_len), 0,
                                cfg.vocab_size, jnp.int32)
    t0 = time.perf_counter()
    out = actor.generate(policy, params, prompt, args.tokens,
                         jax.random.fold_in(key, 2),
                         max_len=args.prompt_len + args.tokens,
                         temperature=args.temperature)
    jax.block_until_ready(out)
    dt = time.perf_counter() - t0
    print(f"arch={cfg.name} generated {out.shape} in {dt:.2f}s "
          f"({args.batch * args.tokens / dt:.1f} tok/s incl. compile)")
    print("first sequence:", out[0].tolist())


if __name__ == "__main__":
    main()
