"""Training launcher: Ocean suite PPO or LM-backbone PPO, with fault
tolerance, checkpoint/restart, elastic re-mesh, and straggler monitoring.

  # the paper's coffee-break sanity suite
  PYTHONPATH=src python -m repro.launch.train --ocean all

  # LM-backbone PPO on a (possibly fake-device) mesh
  PYTHONPATH=src python -m repro.launch.train --arch qwen3-0.6b \
      --batch 8 --seq 256 --steps 20 --mesh 1x1
"""
import argparse
import os


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--ocean", default=None,
                    help="ocean env name(s, comma-separated) or 'all'")
    ap.add_argument("--conformance", action="store_true",
                    help="run the env-conformance harness on the --ocean "
                         "env(s) instead of training; exit 1 on violations")
    ap.add_argument("--engine-backend", default=None,
                    choices=("jit", "shard_map", "pool", "host", "async"),
                    help="TrainEngine tier (default: jit for --ocean; "
                         "--host-env always runs the host tier; 'async' is "
                         "the actor–learner split: spawn actors stream "
                         "rollout fragments, the learner consumes at its "
                         "own rate)")
    ap.add_argument("--num-actors", type=int, default=None,
                    help="async tier: spawn actor processes (default 2)")
    ap.add_argument("--max-staleness", type=int, default=None,
                    help="async tier: max learner-version lag before a "
                         "fragment is dropped/importance-clipped (default 2)")
    ap.add_argument("--staleness-mode", default=None,
                    choices=("drop", "vtrace"),
                    help="async tier: stale-fragment policy — 'drop' "
                         "discards, 'vtrace' keeps them under truncated "
                         "importance weights (default drop)")
    ap.add_argument("--host-env", default=None,
                    help="host-mirror env name(s, comma-separated) or 'all' "
                         "(envs/ocean_host.py registry), trained through "
                         "bridge.wrap on the host tier")
    ap.add_argument("--host-backend", default=None,
                    choices=("thread", "proc"),
                    help="host-tier worker backend: 'thread' (default; env "
                         "steps that release the GIL) or 'proc' (shared-"
                         "memory spawn processes; pure-Python env steps "
                         "parallelize across cores)")
    ap.add_argument("--updates-per-launch", "-K", type=int, default=1,
                    help="fused updates per host dispatch (engine K)")
    ap.add_argument("--selfplay", action="store_true",
                    help="train --ocean env(s) under league self-play: "
                         "frozen opponents sampled from the policy store "
                         "in --league-dir (multi-agent envs only)")
    ap.add_argument("--league-dir", default=None,
                    help="policy-league directory (store + ratings); "
                         "required with --selfplay")
    ap.add_argument("--snapshot-every", type=int, default=10,
                    help="selfplay: updates between store snapshots")
    ap.add_argument("--strategy", default="prioritized",
                    choices=("latest", "uniform", "prioritized"),
                    help="selfplay opponent sampling strategy")
    ap.add_argument("--arch", default=None)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced smoke config for --arch")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--total-env-steps", type=int, default=0,
                    help="env-step budget for --ocean (0 → the env preset)")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--mesh", default="1x1",
                    help="DxM (e.g. 16x16); device count must match")
    ap.add_argument("--devices", type=int, default=0,
                    help="force host platform device count (dry runs)")
    ap.add_argument("--run-dir", default=None,
                    help="telemetry for --ocean runs: spans + metrics "
                         "stream into this directory; inspect with "
                         "`python -m repro.telemetry summarize <dir>`")
    ap.add_argument("--metrics-port", type=int, default=None,
                    help="serve /metrics, /healthz, /spans on "
                         "127.0.0.1:<port> for the duration of --ocean "
                         "training (0 = pick a free ephemeral port; "
                         "default: no server)")
    ap.add_argument("--profile", default=None, metavar="DIR",
                    help="wrap the first --profile-launches engine "
                         "launches in a jax.profiler trace written to DIR "
                         "(view in Perfetto/TensorBoard)")
    ap.add_argument("--profile-launches", type=int, default=3,
                    help="launches to capture under --profile (default 3)")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--save-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    if args.devices:
        os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                                   f" --xla_force_host_platform_device_count="
                                   f"{args.devices}")

    import jax
    import jax.numpy as jnp

    if args.conformance and not args.ocean:
        ap.error("--conformance requires --ocean <name(s)|all>")

    async_overrides = {
        k: v for k, v in (("num_actors", args.num_actors),
                          ("max_staleness", args.max_staleness),
                          ("staleness_mode", args.staleness_mode))
        if v is not None}
    if async_overrides and args.engine_backend != "async":
        ap.error("--num-actors/--max-staleness/--staleness-mode are async-"
                 "tier knobs; pass --engine-backend async")
    if args.engine_backend == "async":
        if args.updates_per_launch != 1:
            ap.error("-K/--updates-per-launch is a fused-scan knob; the "
                     "async tier's learner consumes one fragment batch per "
                     "update (K=1)")
        if args.selfplay:
            ap.error("--selfplay drives the device-resident tiers (frozen "
                     "opponents live in the fused update); the async tier "
                     "does not ship opponent params through the slab")

    if args.host_env or args.engine_backend == "host":
        # third-party host envs through the bridge, async host tier
        from repro.bridge import make_host_engine
        from repro.configs.ocean import ocean_tcfg, preset
        from repro.envs.ocean_host import OCEAN_HOST
        if not args.host_env:
            ap.error("--engine-backend host requires --host-env "
                     "<name(s)|all>")
        if args.engine_backend not in (None, "host"):
            ap.error(f"--host-env runs on the host tier; got "
                     f"--engine-backend {args.engine_backend} (bridged "
                     f"host envs cannot run inside jit/shard_map/pool)")
        if args.updates_per_launch != 1:
            ap.error("-K/--updates-per-launch is a fused-scan knob; the "
                     "host tier dispatches one update per trajectory (K=1)")
        names = list(OCEAN_HOST) if args.host_env == "all" \
            else [n.strip() for n in args.host_env.split(",")]
        for name in names:
            p = preset(name)
            tcfg = ocean_tcfg(name, checkpoint_dir=args.ckpt_dir,
                              engine_backend="host", updates_per_launch=1,
                              host_backend=args.host_backend or "thread")
            eng = make_host_engine(OCEAN_HOST[name], tcfg, hidden=p.hidden,
                                   recurrent=p.recurrent, seed=args.seed)
            steps = args.total_env_steps or p.total_steps
            print(f"=== host/{name} (M={eng.hvec.num_envs} "
                  f"N={eng.hvec.batch_envs} "
                  f"workers={eng.hvec.backend}) ===")
            try:
                hist, solved = eng.run(steps,
                                       target_score=p.target_score)
            finally:
                eng.close()
            m = solved if solved is not None else hist[-1]
            status = "SOLVED" if m["score"] >= p.target_score else "unsolved"
            print(f"  -> {status} score={m['score']:.3f} "
                  f"steps={m['env_steps']} sps={m['sps']:.0f}")
        return

    if args.conformance:
        # --selfplay routes to the competitive-env (league) profile
        from repro.envs.conformance import run_cli
        raise SystemExit(run_cli(args.ocean, seed=args.seed,
                                 selfplay=args.selfplay))

    if args.selfplay:
        # league self-play: frozen opponents from the --league-dir store
        from repro.configs.ocean import ocean_tcfg, preset
        from repro.envs.ocean import OCEAN
        from repro.league import run_selfplay
        if not args.ocean:
            ap.error("--selfplay requires --ocean <name(s)> (e.g. duel)")
        if not args.league_dir:
            ap.error("--selfplay requires --league-dir")
        names = [n.strip() for n in args.ocean.split(",")]
        for name in names:
            p = preset(name)
            tcfg = ocean_tcfg(name, checkpoint_dir=args.ckpt_dir,
                              engine_backend=args.engine_backend or "jit",
                              updates_per_launch=args.updates_per_launch)
            steps = args.total_env_steps or p.total_steps
            ldir = os.path.join(args.league_dir, name) if len(names) > 1 \
                else args.league_dir
            print(f"=== selfplay/{name} (league={ldir}) ===")
            res = run_selfplay(
                OCEAN[name](), tcfg, league_dir=ldir, total_steps=steps,
                snapshot_every=args.snapshot_every, hidden=p.hidden,
                recurrent=p.recurrent, conv=p.conv, strategy=args.strategy,
                seed=args.seed, backend=args.engine_backend or "jit",
                log_every=10)
            status = ("SOLVED" if res.winrate_random >= p.target_score
                      else "unsolved")
            print(f"  -> {status} winrate_vs_random="
                  f"{res.winrate_random:.3f} versions={len(res.store)}")
            print(res.ranker.leaderboard())
        return

    if args.ocean:
        from repro import telemetry
        from repro.envs.ocean import OCEAN
        from repro.rl.trainer import Trainer
        from repro.configs.ocean import ocean_tcfg, preset
        if args.run_dir:
            telemetry.enable(args.run_dir)
        server = None
        if args.metrics_port is not None:
            from repro.telemetry.http import MetricsServer
            server = MetricsServer(port=args.metrics_port)
            print(f"monitoring: {server.url}/metrics  "
                  f"{server.url}/healthz  {server.url}/spans")
        on_launch = None
        if args.profile:
            prof = {"launches": 0, "active": False}

            def on_launch(u, _prof=prof):
                if _prof["launches"] == 0:
                    jax.profiler.start_trace(args.profile)
                    _prof["active"] = True
                _prof["launches"] += 1
                if _prof["active"] and \
                        _prof["launches"] >= args.profile_launches:
                    jax.profiler.stop_trace()
                    _prof["active"] = False
        names = list(OCEAN) if args.ocean == "all" \
            else [n.strip() for n in args.ocean.split(",")]
        try:
            for name in names:
                p = preset(name)
                backend = args.engine_backend or "jit"
                tcfg = ocean_tcfg(name, checkpoint_dir=args.ckpt_dir,
                                  engine_backend=backend,
                                  updates_per_launch=args.updates_per_launch,
                                  checkpoint_every=args.save_every,
                                  metrics_port=(server.port if server
                                                else 0),
                                  **async_overrides)
                tr = Trainer(OCEAN[name](), tcfg, hidden=p.hidden,
                             recurrent=p.recurrent, conv=p.conv,
                             seed=args.seed, log_dir=args.run_dir)
                if server is not None:
                    # fixed key: replaces the previous env's source, so a
                    # closed engine never lingers as a dead health source
                    server.add_source("engine", tr.engine.stats)
                steps = args.total_env_steps or p.total_steps
                extra = (f" actors={tcfg.num_actors} staleness="
                         f"{tcfg.staleness_mode}<={tcfg.max_staleness}"
                         if backend == "async" else "")
                print(f"=== {name} (recurrent={p.recurrent}{extra}) ===")
                try:
                    m = tr.train(steps, log_every=10,
                                 target_score=p.target_score,
                                 checkpoint_dir=os.path.join(args.ckpt_dir,
                                                             name),
                                 resume=args.resume, on_launch=on_launch)
                finally:
                    tr.engine.close()  # async tier: actor procs + slab
                    tr.logger.close()  # crash-safe final flush
                if not m:
                    print("  -> resumed past the step budget; nothing to do")
                    continue
                status = ("SOLVED" if m["score"] >= p.target_score
                          else "unsolved")
                print(f"  -> {status} score={m['score']:.3f} "
                      f"steps={m['env_steps']} sps={m['sps']:.0f}")
        finally:
            if server is not None:
                server.close()
            if args.profile and prof["active"]:
                jax.profiler.stop_trace()
            if args.run_dir:
                telemetry.flush()
                print(f"telemetry: python -m repro.telemetry summarize "
                      f"{args.run_dir}")
        return

    # ---- LM backbone PPO ------------------------------------------------------
    from repro.configs import get_config, get_smoke_config
    from repro.configs.base import TrainConfig
    from repro.data.buffer import random_batch
    from repro.distributed import sharding as shd
    from repro.distributed.fault import ResilientLoop
    from repro.launch.mesh import make_mesh
    from repro.models.params import set_fsdp_axes
    from repro.models.policy import BackbonePolicy
    from repro.rl.learner import init_train_state, make_lm_train_step

    shape = tuple(int(x) for x in args.mesh.split("x"))
    axes = ("data", "model")[:len(shape)] if len(shape) == 2 \
        else ("pod", "data", "model")
    mesh = make_mesh(shape, axes)
    set_fsdp_axes(tuple(a for a in ("pod", "data") if a in axes))
    rules = shd.make_rules(mesh)
    tp = dict(zip(axes, shape)).get("model", 1)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    policy = BackbonePolicy(cfg, tp=tp, kernel="auto")
    tcfg = TrainConfig(checkpoint_dir=args.ckpt_dir)
    key = jax.random.PRNGKey(args.seed)

    with mesh:
        state = init_train_state(policy.init(key),
                                 jnp.dtype(tcfg.optimizer_state_dtype))
        state_sh = shd.named(mesh, shd.train_state_pspecs(policy, rules))
        step = jax.jit(make_lm_train_step(policy, tcfg,
                                          loss_chunk=min(256, args.seq)),
                       out_shardings=(state_sh, None))
        loop = ResilientLoop(step, args.ckpt_dir,
                             save_every=args.save_every,
                             shardings=state_sh)
        if args.resume:
            state, start = loop.resume_or_init(state)
            loop.steps_done = start
            print(f"resumed at step {start}")

        def batches():
            for i in range(args.steps - loop.steps_done):
                yield random_batch(cfg, args.batch, args.seq,
                                   jax.random.fold_in(key, 1000 + i))

        def on_metrics(i, m):
            if i % 5 == 0 or i == 1:
                print(f"step {i:5d} loss {float(m['loss']):+.4f} "
                      f"kl {float(m['approx_kl']):.4f} "
                      f"gnorm {float(m['grad_norm']):.2f} "
                      f"median_step {loop.monitor.median*1e3:.0f}ms")

        state = loop.run(state, batches(), on_metrics)
    print(f"done: {loop.steps_done} steps, {loop.recoveries} recoveries, "
          f"{loop.monitor.flagged} straggler flags")


if __name__ == "__main__":
    main()
