"""Multi-pod dry-run: prove every (architecture × shape × mesh) cell lowers,
partitions, and compiles on the production meshes — without hardware.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-0.6b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--out f.json]
"""
# The CPU container exposes one real device; the dry-run builds the 512-chip
# mesh out of host placeholder devices. MUST run before any other jax import.
import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512")

import argparse
import json
import re
import time
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import (ARCHS, SHAPES, get_config, check_applicable,
                           ShapeNotApplicable, with_overrides)
from repro.configs.base import TrainConfig
from repro.data.buffer import abstract_batch
from repro.distributed import sharding as shd
from repro.launch.mesh import make_production_mesh
from repro.models.policy import BackbonePolicy
from repro.rl import actor
from repro.rl.learner import make_lm_train_step

# v5e constants (per chip)
PEAK_FLOPS = 197e12          # bf16
HBM_BW = 819e9               # B/s
ICI_BW = 50e9                # B/s per link direction

_COLLECTIVE_RE = re.compile(
    r"=\s+([a-z0-9]+)\[([\d,]*)\]\S*\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)")

_DTYPE_BYTES = {"pred": 1, "s8": 1, "u8": 1, "bf16": 2, "f16": 2, "s16": 2,
                "u16": 2, "f32": 4, "s32": 4, "u32": 4, "f64": 8, "s64": 8,
                "u64": 8, "c64": 8}

# ring-transfer multiplier per op kind (bytes actually crossing links per
# chip ≈ factor × result_bytes; documented in EXPERIMENTS.md §Roofline)
_COLLECTIVE_FACTOR = {"all-gather": 1.0, "all-reduce": 2.0,
                      "reduce-scatter": 1.0, "all-to-all": 1.0,
                      "collective-permute": 1.0}


def collective_bytes(hlo_text: str) -> dict:
    """Sum result-shape bytes of every collective in post-optimization HLO,
    weighted by the ring-transfer factor."""
    out = {k: 0.0 for k in _COLLECTIVE_FACTOR}
    for m in _COLLECTIVE_RE.finditer(hlo_text):
        dtype, dims, op = m.group(1), m.group(2), m.group(3)
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        out[op] += n * _DTYPE_BYTES[dtype]
    out["weighted_total"] = sum(_COLLECTIVE_FACTOR[k] * v
                                for k, v in out.items() if k in
                                _COLLECTIVE_FACTOR)
    return out


def model_flops(cfg, shape) -> float:
    """Analytic MODEL_FLOPS: 6·N_active·D for training, 2·N_active·D for
    inference (D = tokens processed this step)."""
    from repro.models.params import param_count
    from repro.models import transformer as tr
    pol = BackbonePolicy(cfg, tp=1)
    n_total = param_count(pol.spec())
    # active params: replace full expert count by top_k experts
    if cfg.num_experts:
        moe_layers = sum(cfg.is_moe_layer(i) for i in range(cfg.num_layers))
        per_expert = 3 * cfg.d_model * cfg.expert_d_ff
        n_active = n_total - moe_layers * (cfg.num_experts - cfg.top_k) \
            * per_expert
    else:
        n_active = n_total
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    return 2.0 * n_active * shape.global_batch     # decode: one token/seq


def input_specs(arch: str, shape_name: str, tp: int = 16):
    """ShapeDtypeStruct stand-ins for every model input of one cell —
    weak-type-correct, shardable, no device allocation.

    train_*  -> the PPO rollout batch (tokens, actions, logprobs, rewards,
                dones, values[, prefix for vlm/audio stubs])
    prefill_* -> {"tokens"[, "prefix"]}
    decode_* / long_* -> (tokens (B,1), caches) for one serve_step
    """
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    check_applicable(cfg, shape)
    if shape.kind == "train":
        return abstract_batch(cfg, shape.global_batch, shape.seq_len)
    P_pref = cfg.frontend_prefix if cfg.frontend else 0
    if shape.kind == "prefill":
        specs = {"tokens": jax.ShapeDtypeStruct(
            (shape.global_batch, shape.seq_len - P_pref), jnp.int32)}
        if P_pref:
            specs["prefix"] = jax.ShapeDtypeStruct(
                (shape.global_batch, P_pref, cfg.d_model), jnp.bfloat16)
        return specs
    return {
        "tokens": jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32),
        "caches": shd.abstract_caches(cfg, tp, shape.global_batch,
                                      shape.seq_len),
    }


def build_program(arch: str, shape_name: str, mesh, *,
                  opt_dtype="bfloat16", remat="full", loss_chunk=256,
                  kernel="chunked", microbatches=1, quantize="off"):
    """Returns (lower_fn, meta). lower_fn() -> jax.stages.Lowered.

    kernel="chunked" lowers the flash-equivalent jnp attention (same memory/
    collective profile as the Pallas kernel); "ref" is the naive einsum
    (kept for the §Perf naive→flash iteration record)."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    check_applicable(cfg, shape)
    cfg = with_overrides(cfg, remat=remat)
    tp = dict(zip(mesh.axis_names, mesh.devices.shape)).get("model", 1)
    rules = shd.make_rules(mesh)
    from repro.models.params import set_fsdp_axes
    set_fsdp_axes(tuple(a for a in ("pod", "data") if a in mesh.axis_names))
    q = quantize if (quantize != "off" and shape.kind != "train") else False
    if q == "int4":
        # gather-free serving: int4 fits TP-only => params replicated over
        # the DP axes, zero per-token FSDP gathers (EXPERIMENTS.md §Perf)
        rules = dict(rules, embed=None)
    policy = BackbonePolicy(cfg, tp=tp, kernel=kernel, quantize=q)
    tcfg = TrainConfig(optimizer_state_dtype=opt_dtype)

    if shape.kind == "train":
        state = shd.abstract_train_state(policy, opt_dtype)
        state_sh = shd.named(mesh, shd.train_state_pspecs(policy, rules))
        batch = abstract_batch(cfg, shape.global_batch, shape.seq_len)
        batch_sh = shd.named(mesh, {
            k: P(*([rules["batch"]] + [None] * (len(v.shape) - 1)))
            for k, v in batch.items()})
        step = make_lm_train_step(policy, tcfg, loss_chunk=loss_chunk,
                                   num_microbatches=microbatches)
        fn = jax.jit(step, in_shardings=(state_sh, batch_sh),
                     out_shardings=(state_sh, None),
                     donate_argnums=(0,))   # reuse state buffers in place
        args = (state, batch)

    elif shape.kind == "prefill":
        params = policy.abstract()
        params_sh = shd.named(mesh, policy.pspecs(rules))
        P_pref = cfg.frontend_prefix if cfg.frontend else 0
        inputs = {"tokens": jax.ShapeDtypeStruct(
            (shape.global_batch, shape.seq_len - P_pref), jnp.int32)}
        if P_pref:
            inputs["prefix"] = jax.ShapeDtypeStruct(
                (shape.global_batch, P_pref, cfg.d_model), jnp.bfloat16)
        in_sh = {k: NamedSharding(mesh, P(rules["batch"],
                                          *([None] * (len(v.shape) - 1))))
                 for k, v in inputs.items()}
        key = jax.ShapeDtypeStruct((2,), jnp.uint32)
        pf = actor.make_prefill_step(policy, max_len=shape.seq_len)
        fn = jax.jit(pf, in_shardings=(params_sh, in_sh, None))
        args = (params, inputs, key)

    else:  # decode
        context_parallel = (shape.name == "long_500k")
        params = policy.abstract()
        params_sh = shd.named(mesh, policy.pspecs(rules))
        caches = shd.abstract_caches(cfg, tp, shape.global_batch,
                                     shape.seq_len)
        caches_sh = shd.named(mesh, shd.cache_pspecs(
            cfg, rules, context_parallel=context_parallel))
        tokens = jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)
        tok_sh = NamedSharding(mesh, P(None if context_parallel
                                       else rules["batch"], None))
        key = jax.ShapeDtypeStruct((2,), jnp.uint32)
        sv = actor.make_serve_step(policy, context_parallel=context_parallel)
        fn = jax.jit(sv, in_shardings=(params_sh, tok_sh, caches_sh, None),
                     out_shardings=(None, None, caches_sh),
                     donate_argnums=(2,))   # in-place KV/SSM cache update
        args = (params, tokens, caches, key)

    meta = {"arch": arch, "shape": shape_name, "kind": shape.kind,
            "mesh": "x".join(map(str, mesh.devices.shape)),
            "model_flops": model_flops(get_config(arch), shape)}
    return (lambda: fn.lower(*args)), meta


def roofline(meta, lowered, compiled, chips: int) -> dict:
    """Three roofline terms from the per-device SPMD HLO, with while-loop
    bodies multiplied by their trip counts (hlo_analysis; XLA's own
    cost_analysis undercounts scans — kept as 'xla_raw' for reference)."""
    from repro.launch import hlo_analysis as H
    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    a = H.analyze(compiled.as_text(), chips)
    # per-device numbers; globals = ×chips
    hlo_flops = float(a["flops"]) * chips
    hlo_bytes = float(a["bytes"]) * chips
    coll_bytes = float(a["collective_bytes"]) * chips
    mem = compiled.memory_analysis()
    out = dict(meta)
    out.update({
        "hlo_flops": hlo_flops,
        "hlo_bytes": hlo_bytes,
        "collective_bytes": coll_bytes,
        "collectives": {k: v * chips for k, v in a["collectives"].items()},
        "t_compute_s": hlo_flops / (chips * PEAK_FLOPS),
        "t_memory_s": hlo_bytes / (chips * HBM_BW),
        "t_collective_s": coll_bytes / (chips * ICI_BW),
        "xla_raw": {"flops": float(cost.get("flops", 0.0)),
                    "bytes": float(cost.get("bytes accessed", 0.0))},
        "bytes_per_device": getattr(mem, "temp_size_in_bytes", None),
        "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
        "output_bytes": getattr(mem, "output_size_in_bytes", None),
        "useful_flops_ratio": (meta["model_flops"] / hlo_flops
                               if hlo_flops else None),
    })
    terms = {"compute": out["t_compute_s"], "memory": out["t_memory_s"],
             "collective": out["t_collective_s"]}
    out["bottleneck"] = max(terms, key=terms.get)
    out["roofline_fraction"] = (
        meta["model_flops"] / (chips * PEAK_FLOPS) / max(terms.values())
        if max(terms.values()) > 0 else None)
    return out


def run_cell(arch: str, shape_name: str, multi_pod: bool, **kw) -> dict:
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = int(mesh.devices.size)
    try:
        lower_fn, meta = build_program(arch, shape_name, mesh, **kw)
    except ShapeNotApplicable as e:
        return {"arch": arch, "shape": shape_name,
                "mesh": "x".join(map(str, mesh.devices.shape)),
                "status": "skipped", "reason": str(e)}
    t0 = time.time()
    with mesh:
        lowered = lower_fn()
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
    out = roofline(meta, lowered, compiled, chips)
    out.update({"status": "ok", "lower_s": round(t_lower, 1),
                "compile_s": round(t_compile, 1)})
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--opt-dtype", default="bfloat16")
    ap.add_argument("--remat", default="full")
    ap.add_argument("--loss-chunk", type=int, default=256)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--quantize", default="off",
                    choices=["off", "int8", "int4"],
                    help="quantized weights for prefill/decode cells")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    cells = []
    archs = ARCHS if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    results = []
    for mp in meshes:
        for a in archs:
            for s in shapes:
                r = run_cell(a, s, mp, opt_dtype=args.opt_dtype,
                             remat=args.remat, loss_chunk=args.loss_chunk,
                             microbatches=args.microbatches,
                             quantize=args.quantize)
                line = {k: r.get(k) for k in
                        ("arch", "shape", "mesh", "status", "bottleneck",
                         "t_compute_s", "t_memory_s", "t_collective_s",
                         "roofline_fraction", "compile_s")}
                print(json.dumps(line), flush=True)
                results.append(r)
    if args.out:
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1)
        print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
