"""Production meshes.

Single pod: (data=16, model=16) — 256 chips (one v5e pod).
Multi-pod:  (pod=2, data=16, model=16) — 512 chips over DCN/ICI.

Functions (not module constants) so importing never touches device state.
"""
from __future__ import annotations

import jax

from repro.configs.base import MeshConfig


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def mesh_config(*, multi_pod: bool = False) -> MeshConfig:
    return MeshConfig(shape=(2, 16, 16) if multi_pod else (16, 16),
                      axes=("pod", "data", "model") if multi_pod
                      else ("data", "model"))


def make_mesh(shape, axes):
    return jax.make_mesh(tuple(shape), tuple(axes))
