"""Trip-count-aware cost analysis of post-optimization HLO.

XLA's ``compiled.cost_analysis()`` counts a while-loop body ONCE and reports
per-device numbers (both verified empirically — EXPERIMENTS.md §Roofline
notes). A layer-scanned transformer is therefore undercounted ~n_layers-fold.
This module re-derives the three roofline terms from the HLO text:

  * FLOPs — every ``dot`` (2·(result elements)·(contraction size)),
    recursing into fusions/calls, multiplying while bodies by the trip
    count read from the loop condition's comparison constant.
  * bytes — HBM traffic model: Σ (operand + result bytes) over top-level
    compute/data ops; fusion internals are not double counted (a fusion is
    one read-operands/write-result unit, matching how the TPU memory system
    sees it).
  * collective bytes — per collective with ring-transfer factors from the
    actual group size in replica_groups.

All numbers are per-device (the SPMD module is per-device).
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

DTYPE_BYTES = {"pred": 1, "s4": 0.5, "u4": 0.5, "s8": 1, "u8": 1, "bf16": 2,
               "f16": 2, "s16": 2, "u16": 2, "f32": 4, "s32": 4, "u32": 4,
               "f64": 8, "s64": 8, "u64": 8, "c64": 8, "c128": 16,
               "f8e4m3fn": 1, "f8e5m2": 1}

_COMP_HEADER = re.compile(
    r"^(ENTRY\s+)?%?([\w\.\-]+)\s*\(.*->.*{\s*$")
_TRIP = re.compile(r'known_trip_count[^0-9]*(\d+)')
_INST = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(?:\(([^=]*?)\)|(\w+)\[([\d,]*)\]\S*)\s+"
    r"([\w\-]+)\((.*?)\)(.*)$")
_SCALAR_INST = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(\w+)\[\]\s+([\w\-]+)\((.*?)\)(.*)$")
_SHAPE = re.compile(r"(\w+)\[([\d,]*)\]")
_OPERAND = re.compile(r"%([\w\.\-]+)")
_CALLS = re.compile(r"(?:calls|body|condition|to_apply|branch_computations)="
                    r"{?%?([\w\.\-, %]+)}?")
_GROUPS = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_OLD = re.compile(r"replica_groups=\{\{([^}]*)\}")

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")
_SKIP_BYTES = {"parameter", "get-tuple-element", "tuple", "constant",
               "bitcast", "after-all", "partition-id", "replica-id",
               "while", "conditional", "call", "compare", "add"}


def _nbytes(dtype, dims) -> float:
    n = 1
    for d in dims:
        n *= d
    return n * DTYPE_BYTES.get(dtype, 4)


@dataclass
class Inst:
    name: str
    dtype: Optional[str]          # None for tuple-shaped
    dims: Tuple[int, ...]
    tuple_shapes: List[Tuple[str, Tuple[int, ...]]]
    op: str
    raw_args: str
    operands: List[str]
    attrs: str

    @property
    def result_bytes(self) -> float:
        if self.dtype is not None:
            return _nbytes(self.dtype, self.dims)
        return sum(_nbytes(dt, dims) for dt, dims in self.tuple_shapes)


@dataclass
class Computation:
    name: str
    insts: Dict[str, Inst] = field(default_factory=dict)
    root: Optional[str] = None


@dataclass
class Module:
    comps: Dict[str, Computation]
    entry: str


def parse(text: str) -> Module:
    comps: Dict[str, Computation] = {}
    entry = None
    cur: Optional[Computation] = None
    for line in text.splitlines():
        s = re.sub(r"/\*.*?\*/", "", line).rstrip()
        if cur is None:
            m = _COMP_HEADER.match(s.strip())
            if m:
                cur = Computation(m.group(2))
                if m.group(1):
                    entry = m.group(2)
            continue
        if s.strip().startswith("}"):
            comps[cur.name] = cur
            cur = None
            continue
        m = _INST.match(s)
        if not m:
            continue
        name, tup, dtype, dims, op, raw_args, attrs = m.groups()
        if s.lstrip().startswith("ROOT"):
            cur.root = name
        tuple_shapes = ([(d, tuple(int(x) for x in sh.split(",") if x))
                         for d, sh in _SHAPE.findall(tup)] if tup else [])
        cur.insts[name] = Inst(
            name=name, dtype=dtype,
            dims=tuple(int(x) for x in dims.split(",") if x) if dims else (),
            tuple_shapes=tuple_shapes, op=op, raw_args=raw_args,
            operands=_OPERAND.findall(raw_args), attrs=attrs)
    if entry is None and comps:
        entry = list(comps)[-1]
    return Module(comps, entry)


_ALIAS_ENTRY = re.compile(
    r"\{\s*([\d,\s]*)\}\s*:\s*\((\d+)\s*,\s*\{([\d,\s]*)\}\s*,\s*"
    r"(may-alias|must-alias)\s*\)")


def input_output_aliases(text: str) -> Dict[Tuple[int, ...],
                                            Tuple[int, Tuple[int, ...], str]]:
    """Parse the module header's ``input_output_alias={ {0}: (1, {},
    may-alias), ... }`` — the buffer-donation record XLA writes into
    post-optimization HLO. Returns {output_index: (param_number,
    param_index, kind)}. Empty dict → no donation was consumed."""
    key = "input_output_alias="
    start = text.find(key)
    if start < 0:
        return {}
    i = text.find("{", start)
    if i < 0:
        return {}
    depth, j = 0, i
    while j < len(text):
        if text[j] == "{":
            depth += 1
        elif text[j] == "}":
            depth -= 1
            if depth == 0:
                break
        j += 1
    body = text[i + 1:j]
    out: Dict[Tuple[int, ...], Tuple[int, Tuple[int, ...], str]] = {}
    for m in _ALIAS_ENTRY.finditer(body):
        out_idx = tuple(int(x) for x in m.group(1).split(",") if x.strip())
        pidx = tuple(int(x) for x in m.group(3).split(",") if x.strip())
        out[out_idx] = (int(m.group(2)), pidx, m.group(4))
    return out


def donated_params(text: str) -> set:
    """Flat entry-parameter numbers whose buffers are aliased into the
    output — i.e. donations XLA actually consumed."""
    return {param for param, _idx, _kind in input_output_aliases(text).values()}


def _dot_flops(inst: Inst, comp: Computation) -> float:
    out = 1
    for d in inst.dims:
        out *= d
    m = re.search(r"lhs_contracting_dims={([\d,]*)}", inst.attrs)
    k = 1
    # operand shapes may be inline in raw_args or found by name
    lhs_name = inst.operands[0] if inst.operands else None
    lhs = comp.insts.get(lhs_name)
    lhs_dims = lhs.dims if (lhs and lhs.dtype) else None
    if lhs_dims is None:
        ms = _SHAPE.search(inst.raw_args)
        if ms:
            lhs_dims = tuple(int(x) for x in ms.group(2).split(",") if x)
    if m and lhs_dims:
        for ci in (int(x) for x in m.group(1).split(",") if x):
            if ci < len(lhs_dims):
                k *= lhs_dims[ci]
    return 2.0 * out * k


def _called(inst: Inst) -> List[str]:
    out = []
    for m in _CALLS.finditer(inst.attrs):
        for name in m.group(1).split(","):
            out.append(name.strip().lstrip("%"))
    return out


def _trip_count(mod: Module, cond_name: str) -> int:
    """Max integer constant reachable from the loop condition."""
    vals, seen = [], set()

    def walk(cname):
        if cname in seen or cname not in mod.comps:
            return
        seen.add(cname)
        for inst in mod.comps[cname].insts.values():
            if inst.op == "constant" and inst.dtype in ("s32", "u32", "s64",
                                                        "u64"):
                mm = re.match(r"(\d+)", inst.raw_args.strip())
                if mm:
                    vals.append(int(mm.group(1)))
            for c in _called(inst):
                walk(c)

    walk(cond_name)
    return max(vals) if vals else 1


def _group_size(attrs: str, total_devices: int) -> int:
    m = _GROUPS.search(attrs)
    if m:
        return int(m.group(2))
    m = _GROUPS_OLD.search(attrs)
    if m:
        return len(m.group(1).split(","))
    return total_devices


def ring_factor(op: str, g: int) -> float:
    if g <= 1:
        return 0.0
    if op == "all-reduce":
        return 2.0 * (g - 1) / g
    if op in ("all-gather", "reduce-scatter", "all-to-all"):
        return (g - 1) / g
    return 1.0   # collective-permute


class Analysis(dict):
    pass


_FUSE_AWAY = {"parameter", "convert", "bitcast", "constant", "broadcast",
              "copy", "reshape", "transpose"}


def _convert_only(comp: Computation) -> bool:
    """Fusions that only convert/relayout: zero HBM traffic on the TPU
    target (they fuse into their producer/consumer)."""
    return all(i.op in _FUSE_AWAY for i in comp.insts.values())


def _adj(nbytes_f32_portion, total, half_f32: bool):
    return total - nbytes_f32_portion / 2.0 if half_f32 else total


def _inst_bytes(inst: Inst, half_f32: bool) -> float:
    b = inst.result_bytes
    if not half_f32:
        return b
    if inst.dtype == "f32":
        return b / 2.0
    if inst.dtype is None:
        f32b = sum(_nbytes(dt, dims) for dt, dims in inst.tuple_shapes
                   if dt == "f32")
        return b - f32b / 2.0
    return b


def trip_multipliers(mod: Module) -> Dict[str, int]:
    """computation name -> product of enclosing while trip counts."""
    trips: Dict[str, int] = {}

    def walk(cname, mult):
        comp = mod.comps.get(cname)
        if comp is None:
            return
        for inst in comp.insts.values():
            if inst.op == "while":
                mb = re.search(r"body=%?([\w\.\-]+)", inst.attrs)
                mt = _TRIP.search(inst.attrs)
                t = int(mt.group(1)) if mt else 1
                if mb:
                    trips[mb.group(1)] = mult * t
                    walk(mb.group(1), mult * t)
            else:
                for c in _called(inst):
                    walk(c, mult)

    walk(mod.entry, 1)
    return trips


def explain(text: str, total_devices: int = 1, topn: int = 15,
            what: str = "bytes"):
    """Top-N per-instruction contributions to the bytes or collective term,
    trip-count weighted — the dry-run 'profiler' used by §Perf iterations."""
    from repro.launch import hlo_analysis as H
    mod = parse(text)
    trips = trip_multipliers(mod)
    a = analyze(text, total_devices)
    items = []
    for cname, comp in mod.comps.items():
        mult = trips.get(cname, 1 if cname == mod.entry else 0)
        if mult == 0:
            continue
        for inst in comp.insts.values():
            if what == "collective" and inst.op not in COLLECTIVES:
                continue
            if inst.op in _SKIP_BYTES or "KERNEL_" in inst.attrs:
                continue
            b = inst.result_bytes + sum(
                comp.insts[o].result_bytes for o in inst.operands
                if o in comp.insts)
            mm = re.search(r'op_name="([^"]*)"', inst.attrs)
            items.append((b * mult, inst.op, mult,
                          str(inst.dims or inst.tuple_shapes)[:48],
                          (mm.group(1) if mm else "?")[-80:]))
    items.sort(reverse=True)
    return a, items[:topn]



def _marked(inst: Inst) -> bool:
    return "KERNEL_" in inst.attrs


def _io_bytes(inst: Inst, comp: Computation, half_f32: bool,
              forced_marked: bool = None) -> float:
    """Traffic for one instruction. Unmarked: operands + result. Marked
    (inside a Pallas-kernel stand-in): only *boundary* reads — operands
    produced by unmarked instructions (e.g. the int4 weight feeding a fused
    quantized matmul) — internal tiles are VMEM-resident on the TPU kernel."""
    if _marked(inst) if forced_marked is None else forced_marked:
        return sum(_inst_bytes(comp.insts[o], half_f32)
                   for o in inst.operands
                   if o in comp.insts and not _marked(comp.insts[o])
                   and comp.insts[o].op not in ("constant", "iota"))
    return _inst_bytes(inst, half_f32) + sum(
        _inst_bytes(comp.insts[o], half_f32) for o in inst.operands
        if o in comp.insts)


def analyze(text: str, total_devices: int = 1,
            bf16_dot_legalization: bool = True) -> Analysis:
    """``bf16_dot_legalization``: the CPU backend legalizes every bf16 dot to
    an f32 dot with converted operands, which drags the activation/gradient
    partial-sum collectives inside the layer scan to f32. The TPU target
    keeps them bf16 (native MXU bf16 dots), so f32 collectives inside loop
    bodies are counted at bf16 width. Deliberate f32 collectives outside the
    scan (optimizer global norms, loss reductions) are unaffected."""
    mod = parse(text)
    memo: Dict[tuple, tuple] = {}

    def cost(cname: str, in_loop: bool = False) -> tuple:
        """(flops, bytes, coll_bytes_weighted, coll_breakdown)."""
        if (cname, in_loop) in memo:
            return memo[(cname, in_loop)]
        comp = mod.comps.get(cname)
        if comp is None:
            return (0.0, 0.0, 0.0, {})
        fl = by = cb = 0.0
        breakdown: Dict[str, float] = {}
        for inst in comp.insts.values():
            if inst.op == "dot":
                fl += _dot_flops(inst, comp)
                by += _io_bytes(inst, comp, bf16_dot_legalization and in_loop)
            elif inst.op == "while":
                mb = re.search(r"body=%?([\w\.\-]+)", inst.attrs)
                mc = re.search(r"condition=%?([\w\.\-]+)", inst.attrs)
                body = mb.group(1) if mb else None
                condc = mc.group(1) if mc else None
                mt = _TRIP.search(inst.attrs)
                if mt:  # exact: XLA annotates known_trip_count
                    trip = int(mt.group(1))
                else:
                    trip = _trip_count(mod, condc) if condc else 1
                bfl, bby, bcb, bbd = cost(body, True) if body else (0, 0, 0, {})
                fl += trip * bfl
                by += trip * bby
                cb += trip * bcb
                for k, v in bbd.items():
                    breakdown[k] = breakdown.get(k, 0.0) + trip * v
            elif inst.op in ("fusion", "call", "conditional", "custom-call"):
                called = _called(inst)
                for c in called:
                    cfl, _cby, ccb, cbd = cost(c, in_loop)
                    fl += cfl       # count dots inside fused computations
                    cb += ccb
                    for k, v in cbd.items():
                        breakdown[k] = breakdown.get(k, 0.0) + v
                # fusions sometimes drop op_name metadata; recover the
                # kernel marker from the fused computation's instructions
                marked = "KERNEL_" in inst.attrs or any(
                    "KERNEL_" in ci.attrs
                    for c in called if c in mod.comps
                    for ci in mod.comps[c].insts.values())
                if marked:
                    # boundary reads of a kernel-marked fusion: when the
                    # fused computation dynamic-slices an operand (a scanned
                    # weight stack), the true read is the SLICE, not the
                    # stack — map fusion operands to inner parameters
                    h = bf16_dot_legalization and in_loop
                    for oi, o in enumerate(inst.operands):
                        src = comp.insts.get(o)
                        if src is None or _marked(src) or \
                                src.op in ("constant", "iota"):
                            continue
                        sliced = None
                        for c in called:
                            cc = mod.comps.get(c)
                            if cc is None:
                                continue
                            pname = None
                            for ci in cc.insts.values():
                                if ci.op == "parameter" and \
                                        ci.raw_args.strip() == str(oi):
                                    pname = ci.name
                            if pname is None:
                                continue
                            for ci in cc.insts.values():
                                if ci.op == "dynamic-slice" and \
                                        pname in ci.operands:
                                    sliced = ci.result_bytes
                        by += (sliced if sliced is not None
                               else _inst_bytes(src, h))
                    continue
                # fused in-place dynamic-update-slice (donated buffers):
                # traffic = read-modify-write of the update region only
                dus = None
                for c in called:
                    cc = mod.comps.get(c)
                    if cc is None:
                        continue
                    for ci in cc.insts.values():
                        if ci.op == "dynamic-update-slice" and \
                                ci.result_bytes >= 0.5 * inst.result_bytes:
                            upd = (cc.insts.get(ci.operands[1])
                                   if len(ci.operands) > 1 else None)
                            if upd is not None:
                                dus = upd.result_bytes
                if dus is not None:
                    by += 2 * dus
                elif any(c in mod.comps and _convert_only(mod.comps[c])
                         for c in called):
                    pass   # dtype/layout-only fusion: fuses away on TPU
                else:
                    h = bf16_dot_legalization and in_loop
                    by += _inst_bytes(inst, h) + sum(
                        _inst_bytes(comp.insts[o], h) for o in inst.operands
                        if o in comp.insts)
            elif inst.op in COLLECTIVES:
                g = _group_size(inst.attrs, total_devices)
                rb = inst.result_bytes
                if bf16_dot_legalization and in_loop:
                    f32b = sum(_nbytes(dt, dims) for dt, dims in
                               inst.tuple_shapes if dt == "f32") \
                        if inst.dtype is None else \
                        (rb if inst.dtype == "f32" else 0.0)
                    rb = rb - f32b / 2.0        # f32 -> bf16 width
                w = rb * ring_factor(inst.op, g)
                cb += w
                breakdown[inst.op] = breakdown.get(inst.op, 0.0) + w
                by += 2 * rb
            elif inst.op == "dynamic-update-slice":
                if "KERNEL_" in inst.attrs:
                    continue
                # in-place update (buffer donation aliases input/output):
                # traffic = read-modify-write of the updated region only
                upd = (comp.insts.get(inst.operands[1])
                       if len(inst.operands) > 1 else None)
                by += 2 * (upd.result_bytes if upd is not None
                           else inst.result_bytes)
            elif inst.op not in _SKIP_BYTES:
                by += _io_bytes(inst, comp, bf16_dot_legalization and in_loop)
        memo[(cname, in_loop)] = (fl, by, cb, breakdown)
        return memo[(cname, in_loop)]

    fl, by, cb, bd = cost(mod.entry, False)
    return Analysis(flops=fl, bytes=by, collective_bytes=cb,
                    collectives=bd)
