from repro.data import buffer
