"""Synthetic LM rollout batches (the data pipeline for backbone PPO).

Real deployments stream rollouts from the actor fleet; here we provide the
same batch contract (rl.learner.lm_batch_fields) filled with either
ShapeDtypeStructs (dry-run) or random data (smoke/bench), plus a host-side
ring buffer mirroring the pool's double-buffered handoff.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.rl.learner import lm_batch_fields


def abstract_batch(cfg: ModelConfig, batch_size: int, seq_len: int):
    return {k: jax.ShapeDtypeStruct(sh, dt)
            for k, (sh, dt) in lm_batch_fields(cfg, batch_size, seq_len).items()}


def random_batch(cfg: ModelConfig, batch_size: int, seq_len: int, key):
    out = {}
    for i, (k, (sh, dt)) in enumerate(
            lm_batch_fields(cfg, batch_size, seq_len).items()):
        kk = jax.random.fold_in(key, i)
        if dt == jnp.int32:
            out[k] = jax.random.randint(kk, sh, 0, cfg.vocab_size, dt)
        elif dt == jnp.bool_:
            out[k] = jax.random.bernoulli(kk, 0.02, sh)
        else:
            out[k] = jax.random.normal(kk, sh, jnp.float32).astype(dt) * 0.1
    out["old_logprob"] = -jnp.abs(out["old_logprob"]) - 1.0
    return out


class RingBuffer:
    """Double-buffered batch handoff (paper §3.3, learner side)."""

    def __init__(self, slots: int = 2):
        self._slots = [None] * slots
        self._w = self._r = 0

    def put(self, batch):
        self._slots[self._w % len(self._slots)] = batch
        self._w += 1

    def get(self):
        assert self._r < self._w, "ring buffer empty"
        b = self._slots[self._r % len(self._slots)]
        self._r += 1
        return b
