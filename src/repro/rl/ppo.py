"""PPO objective (Clean PuffeRL: CleanRL's PPO, heavily customized).

Two loss entry points:
  * ``ppo_terms`` — generic clipped objective on precomputed log-probs.
  * ``chunked_token_loss`` — the LM-backbone path: the (B, T, vocab) logit
    tensor for a 200k vocab at 1M tokens is ~3 TB in f32, so the unembed +
    softmax + PPO terms are computed per sequence-chunk under jax.checkpoint
    inside a scan. Peak logit memory drops T/chunk-fold; backward recomputes.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import TrainConfig, ModelConfig
from repro.models import transformer as tr


class PPOStats(NamedTuple):
    pg_loss: jax.Array
    v_loss: jax.Array
    entropy: jax.Array
    approx_kl: jax.Array
    clipfrac: jax.Array


def ppo_terms(new_logp, old_logp, adv, tcfg: TrainConfig):
    """Clipped policy-gradient terms. All inputs (...,). Returns scalars."""
    logratio = new_logp - old_logp
    ratio = jnp.exp(logratio)
    pg1 = -adv * ratio
    pg2 = -adv * jnp.clip(ratio, 1 - tcfg.clip_coef, 1 + tcfg.clip_coef)
    pg_loss = jnp.mean(jnp.maximum(pg1, pg2))
    approx_kl = jnp.mean((ratio - 1.0) - logratio)
    clipfrac = jnp.mean((jnp.abs(ratio - 1.0) > tcfg.clip_coef)
                        .astype(jnp.float32))
    return pg_loss, approx_kl, clipfrac


def value_loss(new_v, old_v, returns, tcfg: TrainConfig):
    if tcfg.vf_clip > 0:
        v_clipped = old_v + jnp.clip(new_v - old_v, -tcfg.vf_clip,
                                     tcfg.vf_clip)
        vl = jnp.maximum(jnp.square(new_v - returns),
                         jnp.square(v_clipped - returns))
    else:
        vl = jnp.square(new_v - returns)
    return 0.5 * jnp.mean(vl)


def normalize_adv(adv, enabled: bool, axis_name=None):
    """Minibatch advantage normalization. Under data-parallel shard_map the
    minibatch is split across devices, so the stats must be computed over the
    *global* minibatch (psum) — normalizing per-shard would silently change
    the objective vs the single-device run. adv is constant w.r.t. params, so
    cross-device stats keep per-shard gradients exact."""
    if not enabled:
        return adv
    if axis_name is None:
        return (adv - jnp.mean(adv)) / (jnp.std(adv) + 1e-8)
    m = jax.lax.pmean(jnp.mean(adv), axis_name)
    var = jax.lax.pmean(jnp.mean(jnp.square(adv - m)), axis_name)
    return (adv - m) / (jnp.sqrt(var) + 1e-8)


def chunked_token_loss(backbone_params, hidden, actions, old_logp, adv,
                       cfg: ModelConfig, tcfg: TrainConfig,
                       chunk: int = 256):
    """Token-level PPO over an LM backbone without materializing full logits.

    hidden: (B, T, d); actions/old_logp/adv: (B, T).
    Returns (pg_loss, entropy, approx_kl, clipfrac) scalars.
    """
    B, T, _ = hidden.shape
    chunk = min(chunk, T)
    assert T % chunk == 0
    nc = T // chunk

    from repro.models.params import constrain as _con

    @jax.checkpoint
    def chunk_terms(h_c, a_c, olp_c, adv_c):
        h_c = _con(h_c, "batch", "null", "null")
        logits = tr.logits_from_hidden(backbone_params, h_c, cfg)  # (B,c,V) f32
        logits = _con(logits, "batch", "null", "vocab")
        lse = jax.nn.logsumexp(logits, axis=-1)
        # one-hot contraction instead of take_along_axis: keeps the vocab
        # shard layout (a gather would all-gather logits over batch)
        onehot = jax.nn.one_hot(a_c, logits.shape[-1], dtype=logits.dtype)
        tok_logit = jnp.sum(logits * onehot, axis=-1)
        new_logp = tok_logit - lse
        p = jax.nn.softmax(logits, axis=-1)
        ent = lse - jnp.sum(p * logits, axis=-1)
        logratio = new_logp - olp_c
        ratio = jnp.exp(logratio)
        pg1 = -adv_c * ratio
        pg2 = -adv_c * jnp.clip(ratio, 1 - tcfg.clip_coef, 1 + tcfg.clip_coef)
        return (jnp.sum(jnp.maximum(pg1, pg2)), jnp.sum(ent),
                jnp.sum((ratio - 1.0) - logratio),
                jnp.sum((jnp.abs(ratio - 1.0) > tcfg.clip_coef)
                        .astype(jnp.float32)))

    def scan_fn(acc, idx):
        sl = lambda x: jax.lax.dynamic_slice_in_dim(x, idx * chunk, chunk, 1)
        out = chunk_terms(sl(hidden), sl(actions), sl(old_logp), sl(adv))
        return jax.tree.map(jnp.add, acc, out), None

    zero = (jnp.zeros(()),) * 4
    (pg, ent, kl, cf), _ = jax.lax.scan(scan_fn, zero, jnp.arange(nc))
    n = float(B * T)
    return pg / n, ent / n, kl / n, cf / n
