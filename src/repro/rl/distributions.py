"""Factored MultiDiscrete categorical over one concatenated logit vector.

The emulation layer turns every action tree into a single MultiDiscrete; the
policy emits one (…, sum(nvec)) logit vector. Joint log-prob/entropy are sums
over the independent components. Segment boundaries are static.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _segments(nvec):
    off = 0
    for n in nvec:
        yield off, n
        off += n


def sample(key, logits, nvec):
    """logits: (..., sum(nvec)) → actions (..., len(nvec)) int32."""
    outs = []
    for i, (off, n) in enumerate(_segments(nvec)):
        k = jax.random.fold_in(key, i)
        outs.append(jax.random.categorical(k, logits[..., off:off + n]))
    return jnp.stack(outs, axis=-1).astype(jnp.int32)


def log_prob(logits, actions, nvec):
    """actions: (..., len(nvec)); returns (...)."""
    total = 0.0
    for i, (off, n) in enumerate(_segments(nvec)):
        lp = jax.nn.log_softmax(logits[..., off:off + n].astype(jnp.float32))
        total = total + jnp.take_along_axis(
            lp, actions[..., i:i + 1], axis=-1)[..., 0]
    return total


def entropy(logits, nvec):
    total = 0.0
    for off, n in _segments(nvec):
        lp = jax.nn.log_softmax(logits[..., off:off + n].astype(jnp.float32))
        total = total + -jnp.sum(jnp.exp(lp) * lp, axis=-1)
    return total


def mode(logits, nvec):
    outs = []
    for off, n in _segments(nvec):
        outs.append(jnp.argmax(logits[..., off:off + n], axis=-1))
    return jnp.stack(outs, axis=-1).astype(jnp.int32)


# -- diagonal Gaussian (continuous actions — the paper's §8 limitation,
# -- implemented here as a beyond-paper feature) ------------------------------

def gaussian_sample(key, out, cont_dim: int):
    """out: (..., 2*cont_dim) = [mean ‖ log_std] from the policy head."""
    mean, log_std = out[..., :cont_dim], out[..., cont_dim:]
    noise = jax.random.normal(key, mean.shape)
    return mean + jnp.exp(jnp.clip(log_std, -5.0, 2.0)) * noise


def gaussian_log_prob(out, actions, cont_dim: int):
    mean, log_std = out[..., :cont_dim], out[..., cont_dim:]
    log_std = jnp.clip(log_std, -5.0, 2.0)
    z = (actions - mean) * jnp.exp(-log_std)
    return jnp.sum(-0.5 * jnp.square(z) - log_std
                   - 0.5 * jnp.log(2 * jnp.pi), axis=-1)


def gaussian_entropy(out, cont_dim: int):
    log_std = jnp.clip(out[..., cont_dim:], -5.0, 2.0)
    return jnp.sum(log_std + 0.5 * jnp.log(2 * jnp.pi * jnp.e), axis=-1)


class Dist:
    """Policy-output distribution facade: one object the rollout/learner use
    regardless of action kind (MultiDiscrete or continuous Gaussian)."""

    def __init__(self, kind: str, nvec=(), cont_dim: int = 0):
        assert kind in ("categorical", "gaussian")
        self.kind, self.nvec, self.cont_dim = kind, tuple(nvec), cont_dim
        self.num_outputs = (sum(self.nvec) if kind == "categorical"
                            else 2 * cont_dim)
        self.action_dim = (len(self.nvec) if kind == "categorical"
                           else cont_dim)
        self.action_dtype = jnp.int32 if kind == "categorical" \
            else jnp.float32

    def sample(self, key, out):
        if self.kind == "categorical":
            return sample(key, out, self.nvec)
        return gaussian_sample(key, out, self.cont_dim)

    def log_prob(self, out, actions):
        if self.kind == "categorical":
            return log_prob(out, actions, self.nvec)
        return gaussian_log_prob(out, actions, self.cont_dim)

    def entropy(self, out):
        if self.kind == "categorical":
            return entropy(out, self.nvec)
        return gaussian_entropy(out, self.cont_dim)
