"""Learners (Clean PuffeRL): the fused Ocean PPO update and the LM-backbone
PPO ``train_step`` that the multi-pod dry-run lowers.

Ocean path: rollout → GAE → minibatched clipped-PPO epochs, all one jit'd
program per update. Recurrent policies recompute hidden states through whole
stored sequences with per-step reset masking (the LSTM-state handling the
paper singles out as the common bug).

LM path: one PPO update on a (B, T) token rollout — the paper's actor/learner
loop at datacenter scale. GAE runs the Pallas kernel; policy terms use the
chunked-vocab loss; AdamW states stay ZeRO-sharded.

Kernel backends (GAE, flash attention, …) come from the kernels.dispatch
registry: ``kernel_mode``/``gae_mode`` of ``None`` means the registry picks
(Pallas on TPU, ref on CPU, env/``dispatch.using`` overrides respected).
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import TrainConfig, ModelConfig
from repro.kernels import ops as kops
from repro.models import transformer as tr
from repro.optim import adamw, schedule
from repro.rl import distributions as D
from repro.rl import ppo
from repro.rl.rollout import rollout, RolloutCarry, Trajectory


class TrainState(NamedTuple):
    params: object
    opt: adamw.AdamWState
    step: jax.Array


def init_train_state(params, state_dtype=jnp.float32) -> TrainState:
    return TrainState(params, adamw.init(params, state_dtype),
                      jnp.zeros((), jnp.int32))


# =============================== Ocean =======================================

def _shard_index(axis_name):
    """Global shard index over (possibly multiple) data axes, row-major."""
    names = (axis_name,) if isinstance(axis_name, str) else tuple(axis_name)
    idx = jnp.zeros((), jnp.int32)
    for a in names:
        idx = idx * jax.lax.psum(1, a) + jax.lax.axis_index(a)
    return idx


def _check_divisible(n, M, num_envs, num_minibatches, unroll_length, what):
    if n % M != 0:
        raise ValueError(
            f"{what} ({n}) is not divisible by num_minibatches="
            f"{num_minibatches} (num_envs={num_envs}, "
            f"num_minibatches={num_minibatches}, "
            f"unroll_length={unroll_length}); pick num_envs / unroll_length "
            f"so each PPO minibatch has the same size")


def make_vtrace_adv(policy, dist, tcfg: TrainConfig,
                    rho_clip: float = 1.0, c_clip: float = 1.0):
    """V-trace advantage/target computation (IMPALA) for the async tier's
    off-policy fragments: truncated importance weights correct for the
    policy-version lag between the actor that produced a fragment and the
    learner consuming it. Plugs into ``make_ocean_learn(adv_fn=...)``.

    rho/c are computed per sample as exp(logpi_current − logpi_behavior)
    and clamped at ``rho_clip`` / ``c_clip``; on-policy fragments give
    rho = c = 1 exactly, so the estimator degrades to one-step-λ=1 GAE-like
    targets as staleness → 0. Non-recurrent policies only (the fragment
    slab does not ship carries)."""
    if policy.recurrent:
        raise ValueError("make_vtrace_adv supports non-recurrent policies "
                         "(fragments carry no recurrent state)")

    def adv_fn(params, traj, last_value):
        # one forward pass under the *current* policy over the whole batch
        logits, values, _ = policy.seq(params, traj.obs, None, traj.resets)
        newlogp = dist.log_prob(logits, traj.actions)
        rho = jnp.exp(newlogp - traj.logprobs)
        rho_c = jnp.minimum(rho, rho_clip)
        c = jnp.minimum(rho, c_clip)
        nd = 1.0 - traj.dones.astype(jnp.float32)     # no bootstrap across
        v_next = jnp.concatenate([values[1:], last_value[None]], axis=0)
        delta = rho_c * (traj.rewards + tcfg.gamma * v_next * nd - values)

        def back(acc, x):
            d_t, c_t, nd_t = x
            acc = d_t + tcfg.gamma * nd_t * c_t * acc
            return acc, acc

        _, vs_minus_v = jax.lax.scan(back, jnp.zeros_like(last_value),
                                     (delta, c, nd), reverse=True)
        vs = values + vs_minus_v
        vs_next = jnp.concatenate([vs[1:], last_value[None]], axis=0)
        adv = rho_c * (traj.rewards + tcfg.gamma * vs_next * nd - values)
        # vs are the value targets; both are fixed targets for the PPO
        # epochs (computed once from pre-update params, like GAE)
        return jax.lax.stop_gradient(adv), jax.lax.stop_gradient(vs)

    return adv_fn


def make_ocean_learn(policy, tcfg: TrainConfig, dist,
                     kernel_mode: str = None, axis_name=None,
                     num_shards: int = 1, adv_fn=None):
    """The post-rollout half of the fused update: GAE → minibatched
    clipped-PPO epochs. Returns jit-able
    ``learn(ts, carry0, traj, last_value, key) → (ts, metrics)``.

    Factored out of ``make_ocean_update`` so the TrainEngine's pool tier
    (host-collected trajectories) reuses the exact same learning math as the
    fused jit / shard_map tiers.

    ``axis_name`` — set when running inside ``shard_map``: ``traj`` then
    holds this device's env shard, minibatch permutations are drawn per
    shard, gradients/stats are pmean'd and advantage normalization uses
    global (psum) statistics.

    ``num_shards`` — the S of the data-parallel layout. With
    ``axis_name=None`` and S > 1 the single device *emulates* the S-way
    block structure: envs are permuted within S contiguous blocks and global
    minibatch m is the union of every block's m-th slice. That makes the
    update semantically identical (up to float reduction order) whether it
    runs on 1 device or S — the seed-matched multi-device parity the
    engine's tests and benchmark rely on.

    ``adv_fn`` — optional ``(params, traj, last_value) -> (adv, returns)``
    replacing the on-policy GAE (e.g. ``make_vtrace_adv`` for the async
    tier's off-policy fragments). Computed once per update from the
    pre-update params, exactly where GAE runs.
    """
    E, M = tcfg.update_epochs, tcfg.num_minibatches
    S = num_shards

    def learn(ts: TrainState, carry0, traj: Trajectory, last_value, key):
        T, B = traj.rewards.shape                       # local shapes
        B_global = B * (S if axis_name is not None else 1)

        if adv_fn is None:
            adv = kops.gae(traj.rewards.T, traj.values.T, traj.dones.T,
                           last_value, tcfg.gamma, tcfg.gae_lambda,
                           mode=kernel_mode).T                 # (T, B)
            returns = adv + traj.values
        else:
            adv, returns = adv_fn(ts.params, traj, last_value)

        if policy.recurrent:
            # minibatch over envs; recompute through full sequences
            n_block = B if axis_name is not None else B // S
            _check_divisible(n_block, M, B_global, M, T,
                             f"envs per data shard ({S} shards)")

            def loss_fn(params, idx):
                obs = traj.obs[:, idx]
                logits, newv, _ = policy.seq(
                    params, obs,
                    jax.tree.map(lambda c: c[idx], carry0)
                    if carry0 is not None else None,
                    traj.resets[:, idx])
                newlogp = dist.log_prob(logits, traj.actions[:, idx])
                ent = dist.entropy(logits)
                a = ppo.normalize_adv(adv[:, idx], tcfg.norm_adv, axis_name)
                pg, kl, cf = ppo.ppo_terms(newlogp, traj.logprobs[:, idx],
                                           a, tcfg)
                vl = ppo.value_loss(newv, traj.values[:, idx],
                                    returns[:, idx], tcfg)
                loss = pg - tcfg.ent_coef * jnp.mean(ent) + tcfg.vf_coef * vl
                return loss, ppo.PPOStats(pg, vl, jnp.mean(ent), kl, cf)

            n_loc = n_block
            to_global = lambda p, s: s * n_block + p
        else:
            flat = jax.tree.map(
                lambda x: x.reshape((T * B,) + x.shape[2:]),
                Trajectory(traj.obs, traj.actions, traj.logprobs, traj.values,
                           traj.rewards, traj.dones, traj.resets, {}))
            flat_adv = adv.reshape(-1)
            flat_ret = returns.reshape(-1)
            n_block = B if axis_name is not None else B // S
            _check_divisible(T * n_block, M, B_global, M, T,
                             f"samples per data shard ({S} shards)")

            def loss_fn(params, idx):
                logits, newv, _ = policy.step(params, flat.obs[idx], None)
                newlogp = dist.log_prob(logits, flat.actions[idx])
                ent = dist.entropy(logits)
                a = ppo.normalize_adv(flat_adv[idx], tcfg.norm_adv, axis_name)
                pg, kl, cf = ppo.ppo_terms(newlogp, flat.logprobs[idx], a, tcfg)
                vl = ppo.value_loss(newv, flat.values[idx], flat_ret[idx], tcfg)
                loss = pg - tcfg.ent_coef * jnp.mean(ent) + tcfg.vf_coef * vl
                return loss, ppo.PPOStats(pg, vl, jnp.mean(ent), kl, cf)

            n_loc = T * n_block
            # block-local flat index (t * n_block + e) → global (t * B + env)
            to_global = lambda p, s: ((p // n_block) * B + s * n_block
                                      + p % n_block)

        def mb_step(ts: TrainState, idx):
            (loss, stats), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(ts.params, idx)
            if axis_name is not None:
                grads = jax.lax.pmean(grads, axis_name)
                loss, stats = jax.lax.pmean((loss, stats), axis_name)
            params, opt, gstats = adamw.update(
                grads, ts.opt, ts.params, lr=tcfg.learning_rate,
                b1=tcfg.adam_b1, b2=tcfg.adam_b2, eps=tcfg.adam_eps,
                weight_decay=tcfg.weight_decay,
                max_grad_norm=tcfg.max_grad_norm)
            ts = TrainState(params, opt, ts.step + 1)
            return ts, (loss, stats, gstats["grad_norm"])

        # epochs × minibatches of shuffled indices, one scan. Per-block keys
        # (fold_in of the shard index) keep the index stream identical
        # between a real S-device run and the single-device S-block emulation.
        def epoch_perm(k):
            if axis_name is not None:
                s = _shard_index(axis_name)
                p = jax.random.permutation(jax.random.fold_in(k, s), n_loc)
                return p.reshape(M, n_loc // M)
            if S == 1:
                return jax.random.permutation(k, n_loc).reshape(M, n_loc // M)
            blocks = []
            for s in range(S):
                p = jax.random.permutation(jax.random.fold_in(k, s), n_loc)
                blocks.append(to_global(p, s).reshape(M, n_loc // M))
            return jnp.concatenate(blocks, axis=1)

        idxs = jnp.concatenate(
            [epoch_perm(jax.random.fold_in(key, e)) for e in range(E)])
        ts, (losses, stats, gnorms) = jax.lax.scan(mb_step, ts, idxs)

        # episode stats from infos (paper: aggregate once per episode)
        psum = ((lambda x: jax.lax.psum(x, axis_name))
                if axis_name is not None else (lambda x: x))
        valid = traj.infos["valid"]
        nv = jnp.maximum(1.0, psum(jnp.sum(valid)))
        metrics = {
            "loss": losses[-1],
            "pg_loss": stats.pg_loss[-1],
            "v_loss": stats.v_loss[-1],
            "entropy": stats.entropy[-1],
            "approx_kl": stats.approx_kl[-1],
            "clipfrac": stats.clipfrac[-1],
            "grad_norm": gnorms[-1],
            "score": psum(jnp.sum(traj.infos["score"] * valid)) / nv,
            "episode_return":
                psum(jnp.sum(traj.infos["episode_return"] * valid)) / nv,
            "episodes": psum(jnp.sum(valid)),
        }
        return ts, metrics

    return learn


def make_ocean_update(policy, step_fn, tcfg: TrainConfig, dist,
                      num_envs: int, kernel_mode: str = None,
                      axis_name=None, num_shards: int = 1,
                      keyed_step: bool = False):
    """Returns jit-able ``update(ts, rollout_carry, key)``. ``dist`` is a
    distributions.Dist (categorical or gaussian).

    ``keyed_step`` — ``step_fn`` takes per-env keys (``step_keyed_fn``) and
    the rollout derives them from global env indices; required for the
    shard-invariant randomness of the engine's shard_map tier (``num_envs``
    is then the *local* env count of one shard).
    """
    T = tcfg.unroll_length
    learn = make_ocean_learn(policy, tcfg, dist, kernel_mode=kernel_mode,
                             axis_name=axis_name, num_shards=num_shards)

    def update(ts: TrainState, rc: RolloutCarry, key):
        k_roll, k_perm = jax.random.split(key)
        carry0 = rc.policy_carry
        if keyed_step:
            off = (_shard_index(axis_name) * num_envs
                   if axis_name is not None else jnp.zeros((), jnp.int32))
            keyed = (num_envs, off)
        else:
            keyed = None
        rc, traj, last_value = rollout(policy, ts.params, step_fn, rc,
                                       k_roll, T, dist, keyed=keyed)
        ts, metrics = learn(ts, carry0, traj, last_value, k_perm)
        return ts, rc, metrics

    return update


# =============================== LM backbone =================================

def lm_batch_fields(cfg: ModelConfig, batch_size: int, seq_len: int):
    """ShapeDtypeStruct fields of one LM PPO rollout batch (used by both the
    data pipeline and launch.dryrun input_specs)."""
    P = cfg.frontend_prefix if cfg.frontend else 0
    f = {
        "tokens": ((batch_size, seq_len - P), jnp.int32),
        "actions": ((batch_size, seq_len), jnp.int32),
        "old_logprob": ((batch_size, seq_len), jnp.float32),
        "old_values": ((batch_size, seq_len), jnp.float32),
        "rewards": ((batch_size, seq_len), jnp.float32),
        "dones": ((batch_size, seq_len), jnp.bool_),
        "last_value": ((batch_size,), jnp.float32),
    }
    if P:
        f["prefix"] = ((batch_size, P, cfg.d_model), jnp.bfloat16)
    return f


def make_lm_train_step(policy, tcfg: TrainConfig, total_steps: int = 10_000,
                       gae_mode: str = None, loss_chunk: int = 256,
                       num_microbatches: int = 1):
    """One PPO update on a token rollout — the train_4k dry-run program.

    ``num_microbatches > 1``: gradient accumulation over batch slices (scan),
    dividing activation residency by m at the cost of re-gathering FSDP
    weights per microbatch — the HBM-fit lever for the 400B-class cells
    (EXPERIMENTS.md §Perf)."""
    cfg = policy.cfg

    def loss_fn(params, batch):
        inputs = {"tokens": batch["tokens"]}
        if "prefix" in batch:
            inputs["prefix"] = batch["prefix"]
        hidden, aux = tr.forward(params["backbone"], inputs, cfg,
                                 policy.tp, kernel=policy.kernel)
        values = policy._value(params, hidden)              # (B, T)

        adv = kops.gae(batch["rewards"], batch["old_values"],
                       batch["dones"], batch["last_value"],
                       tcfg.gamma, tcfg.gae_lambda, mode=gae_mode)
        returns = adv + batch["old_values"]
        adv = ppo.normalize_adv(adv, tcfg.norm_adv)

        pg, ent, kl, cf = ppo.chunked_token_loss(
            params["backbone"], hidden, batch["actions"],
            batch["old_logprob"], adv, cfg, tcfg, chunk=loss_chunk)
        vl = ppo.value_loss(values, batch["old_values"], returns, tcfg)
        loss = (pg - tcfg.ent_coef * ent + tcfg.vf_coef * vl
                + 0.01 * aux["moe_aux"])
        return loss, {"pg_loss": pg, "v_loss": vl, "entropy": ent,
                      "approx_kl": kl, "clipfrac": cf,
                      "moe_aux": aux["moe_aux"]}

    def train_step(ts: TrainState, batch):
        if num_microbatches > 1:
            m = num_microbatches
            mb = jax.tree.map(
                lambda x: x.reshape((m, x.shape[0] // m) + x.shape[1:]),
                batch)

            def acc(gacc, one):
                (l, st), g = jax.value_and_grad(loss_fn, has_aux=True)(
                    ts.params, one)
                gacc = jax.tree.map(
                    lambda a, b: a + b.astype(a.dtype), gacc, g)
                return gacc, (l, st)

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                              ts.params)
            gacc, (ls, sts) = jax.lax.scan(acc, g0, mb)
            grads = jax.tree.map(lambda g: g / m, gacc)
            loss = jnp.mean(ls)
            stats = jax.tree.map(jnp.mean, sts)
        else:
            (loss, stats), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(ts.params, batch)
        lr = schedule.warmup_cosine(ts.step, peak_lr=tcfg.learning_rate,
                                    warmup_steps=tcfg.warmup_steps,
                                    total_steps=total_steps)
        params, opt, gstats = adamw.update(
            grads, ts.opt, ts.params, lr=lr, b1=tcfg.adam_b1, b2=tcfg.adam_b2,
            eps=tcfg.adam_eps, weight_decay=tcfg.weight_decay,
            max_grad_norm=tcfg.max_grad_norm)
        metrics = dict(stats, loss=loss, lr=lr, grad_norm=gstats["grad_norm"])
        return TrainState(params, opt, ts.step + 1), metrics

    return train_step
