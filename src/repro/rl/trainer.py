"""Host-side training driver: Ocean / small-env PPO with checkpoint-restart.

Now a thin facade over ``rl.engine.TrainEngine`` — the engine owns the
device-resident state, the fused K-updates-per-dispatch launch, and the
execution tier (jit / shard_map / pool); the Trainer keeps the stable
user-facing API (construction from a raw env, ``train``, ``save``/
``restore``, history, logging) that tests, examples, and the CLI use.

The old per-update ``{k: float(v)}`` host sync is gone: metrics are fetched
with one ``jax.device_get`` per launch, one launch late when no
``target_score`` is requested, so JAX async dispatch actually overlaps.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import TrainConfig
from repro.core.emulation import Emulated
from repro.models.policy import OceanPolicy
from repro.rl.distributions import Dist
from repro.rl.engine import TrainEngine
from repro.rl.learner import TrainState


def ocean_policy_stack(env, hidden: int = 128, recurrent: bool = False,
                       conv: bool = None):
    """Derive ``(Emulated, Dist, OceanPolicy)`` from a raw Ocean-protocol
    env — the ONE place the env→policy derivation lives (action kind from
    the emulated action spec, the CNN frontend from ``obs_frontend``).
    Used by the Trainer, the league (build_league, CLI), and benchmarks."""
    from repro.core import spaces as sp
    em = Emulated(env)
    if em.act_spec.kind == "discrete":
        dist = Dist("categorical", nvec=em.act_spec.nvec)
    else:       # continuous actions — paper §8 extension
        dist = Dist("gaussian", cont_dim=em.act_spec.cont_dim)
    # pixel envs opt in to the CNN frontend via `obs_frontend = "conv"`;
    # the policy then restores the emulated-flat obs to its 2D layout
    if conv is None:
        conv = getattr(env, "obs_frontend", None) == "conv"
    conv_shape = None
    if conv:
        space = env.observation_space
        if not (isinstance(space, sp.Box) and len(space.shape) == 2):
            raise ValueError(
                f"conv frontend needs a single 2D Box observation, got "
                f"{space}")
        conv_shape = space.shape
    policy = OceanPolicy(em.obs_spec.total, dist.nvec, hidden=hidden,
                         recurrent=recurrent,
                         num_outputs=dist.num_outputs,
                         conv_shape=conv_shape)
    return em, dist, policy


class Trainer:
    def __init__(self, env, tcfg: TrainConfig = None, hidden: int = 128,
                 recurrent: bool = False, seed: int = 0,
                 kernel_mode: str = None, log_dir: str = None,
                 backend: str = None, updates_per_launch: int = None,
                 mesh=None, conv: bool = None):
        from repro.utils.metrics import MetricsLogger
        self.logger = MetricsLogger(log_dir,
                                    run_name=type(env).__name__.lower())
        self.tcfg = tcfg or TrainConfig()
        self.em, self.dist, self.policy = ocean_policy_stack(
            env, hidden=hidden, recurrent=recurrent, conv=conv)
        self.engine = TrainEngine(self.em, self.policy, self.tcfg, self.dist,
                                  key=jax.random.PRNGKey(seed),
                                  backend=backend,
                                  updates_per_launch=updates_per_launch,
                                  mesh=mesh, kernel_mode=kernel_mode)
        self.history = []

    # engine state, exposed under the historical names ------------------------
    @property
    def ts(self) -> TrainState:
        return self.engine.ts

    @property
    def rc(self):
        return self.engine.rc

    @property
    def vec(self):
        return self.engine.vec

    @property
    def steps_per_update(self) -> int:
        return self.engine.steps_per_update

    def train(self, total_steps: int, log_every: int = 0,
              target_score: Optional[float] = None,
              checkpoint_dir: Optional[str] = None, resume: bool = False,
              on_launch=None):
        """Run until total env interactions ≥ total_steps (or solved).
        ``target_score`` is checked at launch boundaries (identical to
        per-update for K = 1). With ``checkpoint_dir`` the engine saves its
        full resumable state every ``tcfg.checkpoint_every`` updates
        (async, at the launch boundary); ``resume=True`` restores the
        newest committed checkpoint first and continues from its update
        count. Metrics stream through the engine into ``self.logger``
        (one flush per launch, crash-safe final flush in the engine)."""
        from repro.checkpoint import ckpt
        if checkpoint_dir:
            self.engine.checkpoint_dir = checkpoint_dir
            if resume and ckpt.latest(checkpoint_dir) is not None:
                u0 = self.engine.restore(checkpoint_dir)
                print(f"  resumed at update {u0}")

        def on_update(u, m):
            self.history.append(m)
            if log_every and (u % log_every == 0):
                print(f"  upd {u:4d} steps {m['env_steps']:7d} "
                      f"score {m['score']:.3f} "
                      f"ret {m['episode_return']:.3f} "
                      f"kl {m['approx_kl']:.4f} "
                      f"sps {m['sps']:.0f}")

        _, solved = self.engine.run(total_steps, target_score=target_score,
                                    on_update=on_update,
                                    on_launch=on_launch, logger=self.logger)
        if solved is not None:
            return solved
        # a fully-resumed run may have no new updates to report
        return self.history[-1] if self.history else {}

    def save(self, ckpt_dir: str):
        from repro.checkpoint import ckpt
        ckpt.save(ckpt_dir, {"params": self.ts.params,
                             "opt": self.ts.opt, "step": self.ts.step})

    def restore(self, ckpt_dir: str):
        from repro.checkpoint import ckpt
        tree = ckpt.restore(ckpt_dir, {"params": self.ts.params,
                                       "opt": self.ts.opt,
                                       "step": self.ts.step})
        self.engine.set_train_state(
            TrainState(tree["params"], tree["opt"], tree["step"]))
