"""Host-side training driver: Ocean / small-env PPO with checkpoint-restart.

Now a thin facade over ``rl.engine.TrainEngine`` — the engine owns the
device-resident state, the fused K-updates-per-dispatch launch, and the
execution tier (jit / shard_map / pool); the Trainer keeps the stable
user-facing API (construction from a raw env, ``train``, ``save``/
``restore``, history, logging) that tests, examples, and the CLI use.

The old per-update ``{k: float(v)}`` host sync is gone: metrics are fetched
with one ``jax.device_get`` per launch, one launch late when no
``target_score`` is requested, so JAX async dispatch actually overlaps.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import TrainConfig
from repro.core.emulation import Emulated
from repro.models.policy import OceanPolicy
from repro.rl.distributions import Dist
from repro.rl.engine import TrainEngine
from repro.rl.learner import TrainState


class Trainer:
    def __init__(self, env, tcfg: TrainConfig = None, hidden: int = 128,
                 recurrent: bool = False, seed: int = 0,
                 kernel_mode: str = None, log_dir: str = None,
                 backend: str = None, updates_per_launch: int = None,
                 mesh=None, conv: bool = None):
        from repro.core import spaces as sp
        from repro.utils.metrics import MetricsLogger
        self.logger = MetricsLogger(log_dir,
                                    run_name=type(env).__name__.lower())
        self.tcfg = tcfg or TrainConfig()
        self.em = Emulated(env)
        if self.em.act_spec.kind == "discrete":
            self.dist = Dist("categorical", nvec=self.em.act_spec.nvec)
        else:   # continuous actions — paper §8 extension
            self.dist = Dist("gaussian", cont_dim=self.em.act_spec.cont_dim)
        # pixel envs opt in to the CNN frontend via `obs_frontend = "conv"`;
        # the policy then restores the emulated-flat obs to its 2D layout
        if conv is None:
            conv = getattr(env, "obs_frontend", None) == "conv"
        conv_shape = None
        if conv:
            space = env.observation_space
            if not (isinstance(space, sp.Box) and len(space.shape) == 2):
                raise ValueError(
                    f"conv frontend needs a single 2D Box observation, got "
                    f"{space}")
            conv_shape = space.shape
        self.policy = OceanPolicy(self.em.obs_spec.total, self.dist.nvec,
                                  hidden=hidden, recurrent=recurrent,
                                  num_outputs=self.dist.num_outputs,
                                  conv_shape=conv_shape)
        self.engine = TrainEngine(self.em, self.policy, self.tcfg, self.dist,
                                  key=jax.random.PRNGKey(seed),
                                  backend=backend,
                                  updates_per_launch=updates_per_launch,
                                  mesh=mesh, kernel_mode=kernel_mode)
        self.history = []

    # engine state, exposed under the historical names ------------------------
    @property
    def ts(self) -> TrainState:
        return self.engine.ts

    @property
    def rc(self):
        return self.engine.rc

    @property
    def vec(self):
        return self.engine.vec

    @property
    def steps_per_update(self) -> int:
        return self.engine.steps_per_update

    def train(self, total_steps: int, log_every: int = 0,
              target_score: Optional[float] = None,
              checkpoint_dir: Optional[str] = None):
        """Run until total env interactions ≥ total_steps (or solved).
        ``target_score`` and checkpointing are engine callbacks checked at
        launch boundaries (identical to per-update for K = 1)."""
        ce = self.tcfg.checkpoint_every
        saved_through = [0]
        pending_log = []

        def on_update(u, m):
            self.history.append(m)
            pending_log.append(m)
            if len(pending_log) >= self.engine.K:   # one write per launch
                self.logger.log_batch(pending_log)
                pending_log.clear()
            if log_every and (u % log_every == 0):
                print(f"  upd {u:4d} steps {m['env_steps']:7d} "
                      f"score {m['score']:.3f} "
                      f"ret {m['episode_return']:.3f} "
                      f"kl {m['approx_kl']:.4f} "
                      f"sps {m['sps']:.0f}")

        def on_launch(updates_done):
            if checkpoint_dir and updates_done // ce > saved_through[0] // ce:
                self.save(checkpoint_dir)
                saved_through[0] = updates_done

        _, solved = self.engine.run(total_steps, target_score=target_score,
                                    on_update=on_update, on_launch=on_launch)
        if pending_log:
            self.logger.log_batch(pending_log)
        return solved if solved is not None else self.history[-1]

    def save(self, ckpt_dir: str):
        from repro.checkpoint import ckpt
        ckpt.save(ckpt_dir, {"params": self.ts.params,
                             "opt": self.ts.opt, "step": self.ts.step})

    def restore(self, ckpt_dir: str):
        from repro.checkpoint import ckpt
        tree = ckpt.restore(ckpt_dir, {"params": self.ts.params,
                                       "opt": self.ts.opt,
                                       "step": self.ts.step})
        self.engine.set_train_state(
            TrainState(tree["params"], tree["opt"], tree["step"]))
