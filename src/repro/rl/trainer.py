"""Host-side training driver: Ocean / small-env PPO with checkpoint-restart.

Composes the whole paper stack: Emulated(env) → VecEnv → OceanPolicy →
fused update, plus fault tolerance (atomic checkpoints, resume) and the
paper's per-experiment recurrence toggle.
"""
from __future__ import annotations

import time
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import TrainConfig
from repro.core.emulation import Emulated
from repro.core.vector import VecEnv
from repro.models.policy import OceanPolicy
from repro.rl.distributions import Dist
from repro.rl.learner import TrainState, init_train_state, make_ocean_update
from repro.rl.rollout import RolloutCarry


class Trainer:
    def __init__(self, env, tcfg: TrainConfig = None, hidden: int = 128,
                 recurrent: bool = False, seed: int = 0,
                 kernel_mode: str = None, log_dir: str = None):
        from repro.utils.metrics import MetricsLogger
        self.logger = MetricsLogger(log_dir,
                                    run_name=type(env).__name__.lower())
        self.tcfg = tcfg or TrainConfig()
        self.key = jax.random.PRNGKey(seed)
        self.em = Emulated(env)
        self.vec = VecEnv(self.em, self.tcfg.num_envs)
        if self.em.act_spec.kind == "discrete":
            self.dist = Dist("categorical", nvec=self.em.act_spec.nvec)
        else:   # continuous actions — paper §8 extension
            self.dist = Dist("gaussian", cont_dim=self.em.act_spec.cont_dim)
        self.policy = OceanPolicy(self.em.obs_spec.total, self.dist.nvec,
                                  hidden=hidden, recurrent=recurrent,
                                  num_outputs=self.dist.num_outputs)
        params = self.policy.init(jax.random.fold_in(self.key, 0))
        self.ts = init_train_state(params)

        env_state, obs = self.vec.init(jax.random.fold_in(self.key, 1))
        B = self.vec.batch_size
        self.rc = RolloutCarry(env_state, obs,
                               self.policy.initial_carry(B),
                               jnp.zeros((B,), jnp.bool_))
        self._update = jax.jit(make_ocean_update(
            self.policy, self.vec.step_fn(), self.tcfg, self.dist,
            self.tcfg.num_envs, kernel_mode=kernel_mode))
        self.history = []

    @property
    def steps_per_update(self) -> int:
        return self.tcfg.unroll_length * self.vec.batch_size

    def train(self, total_steps: int, log_every: int = 0,
              target_score: Optional[float] = None,
              checkpoint_dir: Optional[str] = None):
        """Run until total env interactions ≥ total_steps (or solved)."""
        num_updates = max(1, total_steps // self.steps_per_update)
        t0 = time.perf_counter()
        for u in range(num_updates):
            self.key, sub = jax.random.split(self.key)
            self.ts, self.rc, metrics = self._update(self.ts, self.rc, sub)
            metrics = {k: float(v) for k, v in metrics.items()}
            metrics["env_steps"] = (u + 1) * self.steps_per_update
            metrics["sps"] = metrics["env_steps"] / (time.perf_counter() - t0)
            self.history.append(metrics)
            self.logger.log(metrics["env_steps"], metrics)
            if log_every and (u % log_every == 0):
                print(f"  upd {u:4d} steps {metrics['env_steps']:7d} "
                      f"score {metrics['score']:.3f} "
                      f"ret {metrics['episode_return']:.3f} "
                      f"kl {metrics['approx_kl']:.4f} "
                      f"sps {metrics['sps']:.0f}")
            if checkpoint_dir and (u + 1) % self.tcfg.checkpoint_every == 0:
                self.save(checkpoint_dir)
            if target_score is not None and metrics["episodes"] > 0 \
                    and metrics["score"] >= target_score:
                return metrics
        return self.history[-1]

    def save(self, ckpt_dir: str):
        from repro.checkpoint import ckpt
        ckpt.save(ckpt_dir, {"params": self.ts.params,
                             "opt": self.ts.opt, "step": self.ts.step})

    def restore(self, ckpt_dir: str):
        from repro.checkpoint import ckpt
        tree = ckpt.restore(ckpt_dir, {"params": self.ts.params,
                                       "opt": self.ts.opt,
                                       "step": self.ts.step})
        self.ts = TrainState(tree["params"], tree["opt"], tree["step"])
