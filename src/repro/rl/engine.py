"""Device-resident TrainEngine: K fused PPO updates per host dispatch.

The paper's trajectory is IPC-count reduction — per-step → per-episode → (in
this JAX port) zero host syncs inside one update. This module takes the last
step: zero host round-trips per *K updates*. One launch is a single
``jax.lax.scan`` over the fused update with donated ``TrainState`` /
``RolloutCarry`` buffers, and metrics land in an on-device ring buffer of
shape ``(K, n_metrics)`` that is fetched once per launch — dispatch latency
and host sync amortize K-fold, which is exactly what dominates the
small-unroll Ocean regime the paper benchmarks.

Five execution tiers behind one ``run(total_steps)`` API:

  * ``jit``       — single device; K = 1 is the classic one-update-per-
                    dispatch loop, K > 1 the fused multi-update scan.
  * ``shard_map`` — data-parallel over the mesh's data axes (envs and PPO
                    batch sharded, gradients pmean'd, advantage stats
                    psum'd). Randomness is keyed by *global* env index and
                    minibatch permutations are drawn per shard-block, so an
                    S-device run is seed-matched with the single-device
                    ``num_shards=S`` emulation (same final params up to
                    float reduction order). Testable on CPU via
                    ``--xla_force_host_platform_device_count``.
  * ``pool``      — the double-buffered async host loop (core/pool.py) for
                    host-bound envs: while the learner consumes buffer i,
                    buffer i+1's env step is already on the device queue.
  * ``host``      — bridged third-party host envs (bridge/): a first-
                    finisher ``HostVecEnv`` steps M = 2N envs on workers —
                    threads, or shared-memory spawn processes when built
                    with ``backend="proc"`` (``tcfg.host_backend``; the
                    engine is agnostic, the pool protocol is identical) —
                    while jitted inference + the same ``make_ocean_learn``
                    update stay device-resident.
                    Rollout fragments accumulate *per env* keyed by the
                    pool's ``env_ids``, so GAE bootstraps and recurrent
                    carries stay per-env correct even though every batch is
                    a different first-finisher subset.
  * ``async``     — decoupled actor–learner (distributed/actor_learner.py):
                    N spawn-actor processes run jitted rollouts over
                    disjoint env shards and stream version-tagged fragments
                    through a shared-memory slab; the learner batches one
                    fragment per shard, applies the staleness policy
                    (``tcfg.staleness_mode``: drop stale fragments, or keep
                    them under V-trace rho/c clamps), learns, and
                    seqlock-publishes the new params version. The loop runs
                    through distributed/fault.ResilientLoop (checkpointed
                    kill-and-resume), dead actors are resharded to
                    survivors, and slow actors are straggler-flagged.

Checkpointing, ``target_score`` early-exit, and metric logging fire at
launch boundaries: with ``checkpoint_dir`` set, every
``tcfg.checkpoint_every`` updates the full resumable state (TrainState +
RNG key + rollout carry where device-resident) saves asynchronously at the
``on_launch`` hook point, and ``restore()`` resumes a run so that
interrupted-then-resumed is bitwise-identical to uninterrupted (jit and
shard_map tiers; the pool/host tiers resume the learner but re-seed their
host-side env state).

Self-play (league/): construct with ``selfplay=SelfPlay(next_opponent, L)``
on a multi-agent env and agent rows [0, L) train while rows [L, A) act
under frozen params that ``next_opponent()`` samples from the PolicyStore
once per launch — jit and shard_map tiers only, since the opponent swap is
a host decision at the launch boundary.
"""
from __future__ import annotations

from collections import deque
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.checkpoint import ckpt
from repro.configs.base import TrainConfig
from repro.core.vector import VecEnv
from repro.distributed import sharding as shd
from repro.telemetry import TierTimer
from repro.telemetry import enabled as tel_enabled
from repro.telemetry import flush as tel_flush
from repro.telemetry import registry as tel_registry
from repro.telemetry import span as tel_span
from repro.rl.learner import (TrainState, init_train_state, make_ocean_learn,
                              make_ocean_update, make_vtrace_adv)
from repro.rl.rollout import RolloutCarry, Trajectory


METRIC_KEYS = ("loss", "pg_loss", "v_loss", "entropy", "approx_kl",
               "clipfrac", "grad_norm", "score", "episode_return", "episodes")


def pack_metrics(m: dict) -> jax.Array:
    """Metrics dict → one f32 row of the on-device ring buffer."""
    return jnp.stack([jnp.asarray(m[k], jnp.float32) for k in METRIC_KEYS])


def unpack_metrics(row) -> dict:
    return {k: float(v) for k, v in zip(METRIC_KEYS, row)}


def _scan_launch(update, k: int, selfplay: bool = False):
    """K sequential updates as one traced program; returns the (K, n_metrics)
    metrics ring alongside the threaded state. In selfplay mode the launch
    carries the frozen opponent params as an extra (non-donated) operand —
    all K fused updates face the same opponent; the swap is per launch."""
    if selfplay:
        def launch(ts: TrainState, rc, opp, key):
            def body(carry, uk):
                ts, rc = carry
                ts, rc, m = update(ts, rc, opp, uk)
                return (ts, rc), pack_metrics(m)
            (ts, rc), ring = jax.lax.scan(body, (ts, rc),
                                          jax.random.split(key, k))
            return ts, rc, ring
        return launch

    def launch(ts: TrainState, rc: RolloutCarry, key):
        def body(carry, uk):
            ts, rc = carry
            ts, rc, m = update(ts, rc, uk)
            return (ts, rc), pack_metrics(m)
        (ts, rc), ring = jax.lax.scan(body, (ts, rc),
                                      jax.random.split(key, k))
        return ts, rc, ring
    return launch


class TrainEngine:
    """Owns the device-resident training state and the launch programs.

    ``env`` is a (usually ``Emulated``) pure-functional env; ``policy`` an
    OceanPolicy; ``dist`` a distributions.Dist. ``key`` seeds params
    (fold_in 0), env states (fold_in 1), and the per-launch update keys.

    ``num_shards`` (jit tier only) emulates the S-way block structure of a
    data-parallel run on one device — used by the seed-match parity tests;
    leave at 1 for normal training.
    """

    def __init__(self, env, policy, tcfg: TrainConfig, dist, *, key,
                 backend: str = None, updates_per_launch: int = None,
                 mesh: Optional[Mesh] = None, kernel_mode: str = None,
                 num_shards: int = 1, selfplay=None,
                 checkpoint_dir: Optional[str] = None):
        self.env, self.policy, self.tcfg, self.dist = env, policy, tcfg, dist
        self.backend = backend or tcfg.engine_backend
        self.K = updates_per_launch or tcfg.updates_per_launch
        if self.backend not in ("jit", "shard_map", "pool", "host", "async"):
            raise ValueError(f"unknown engine backend {self.backend!r}; "
                             f"expected jit | shard_map | pool | host | "
                             f"async")
        if self.K < 1:
            raise ValueError(f"updates_per_launch must be >= 1, got {self.K}")
        self.key = key
        self.mesh = mesh
        self._launches = {}
        self.selfplay = selfplay
        self.checkpoint_dir = checkpoint_dir
        self._ckpt_thread = None
        self._resume_update = 0
        self._saved_upto = 0

        self.ts = init_train_state(policy.init(jax.random.fold_in(key, 0)))

        if self.backend != "shard_map" and mesh is not None:
            raise ValueError(f"mesh is only meaningful for the shard_map "
                             f"tier, not backend={self.backend!r}")
        if selfplay is not None:
            if self.backend not in ("jit", "shard_map"):
                raise ValueError(
                    f"selfplay runs on the jit and shard_map tiers (the "
                    f"opponent swap is a launch-boundary decision), not "
                    f"backend={self.backend!r}")
            A = getattr(env, "num_agents", 1)
            if A < 2:
                raise ValueError(
                    f"selfplay needs a multi-agent env to split rows "
                    f"between learner and opponent; num_agents={A}")
            self._sp_agents = selfplay.learner_agents or A // 2
            if not 0 < self._sp_agents < A:
                raise ValueError(
                    f"learner_agents={self._sp_agents} must split "
                    f"num_agents={A} into two non-empty sides")
        if self.backend == "host":
            if self.K != 1:
                raise ValueError(
                    f"updates_per_launch={self.K} is a fused-scan knob; the "
                    f"host tier dispatches one update per collected "
                    f"trajectory (K=1)")
            for attr in ("recv", "send", "batch_envs", "num_agents"):
                if not hasattr(env, attr):
                    raise ValueError(
                        "backend='host' takes a bridge.HostVecEnv (see "
                        "bridge.wrap / bridge.make_host_engine), got "
                        f"{type(env).__name__} without {attr!r}")
            if env.batch_envs != tcfg.num_envs:
                raise ValueError(
                    f"HostVecEnv batches {env.batch_envs} envs but "
                    f"tcfg.num_envs={tcfg.num_envs}; size the bridge batch "
                    f"to the training config")
            self.hvec = self.vec = env
            self.rc = None
            self.num_shards = 1
            self._learn = jax.jit(make_ocean_learn(
                policy, tcfg, dist, kernel_mode=kernel_mode))
            self._act = jax.jit(self._make_act())
            return
        if self.backend == "async":
            if self.K != 1:
                raise ValueError(
                    f"updates_per_launch={self.K} is a fused-scan knob; the "
                    f"async tier dispatches one update per fragment batch "
                    f"(K=1)")
            if tcfg.staleness_mode not in ("drop", "vtrace"):
                raise ValueError(
                    f"staleness_mode={tcfg.staleness_mode!r}; expected "
                    f"'drop' (discard fragments older than max_staleness) "
                    f"or 'vtrace' (importance-clip them)")
            for attr in ("init", "step", "reset"):
                if not hasattr(env, attr):
                    raise ValueError(
                        "backend='async' takes a pure-functional (Emulated) "
                        f"env whose actors rebuild it in-process, got "
                        f"{type(env).__name__} without {attr!r}")
            from types import SimpleNamespace
            from repro.distributed.actor_learner import AsyncRollouts
            A = getattr(env, "num_agents", 1)
            # host-side batch bookkeeping only — the real VecEnvs live in
            # the actor processes, one per shard
            self.vec = SimpleNamespace(batch_size=tcfg.num_envs * A,
                                       num_envs=tcfg.num_envs, num_agents=A)
            self.rc = None
            self.num_shards = 1
            adv = (make_vtrace_adv(policy, dist, tcfg,
                                   rho_clip=tcfg.vtrace_rho,
                                   c_clip=tcfg.vtrace_c)
                   if tcfg.staleness_mode == "vtrace" else None)
            self._learn = jax.jit(make_ocean_learn(
                policy, tcfg, dist, kernel_mode=kernel_mode, adv_fn=adv))
            seed = int(np.asarray(jax.random.randint(
                jax.random.fold_in(key, 1), (), 0, 2**31 - 1)))
            self.rollouts = AsyncRollouts(env, policy, dist, tcfg,
                                          params0=self.ts.params, seed=seed)
            self._dropped = 0
            self._version = 0
            return
        if self.backend == "pool":
            if self.K != 1:
                raise ValueError(
                    f"updates_per_launch={self.K} is a fused-scan knob; the "
                    f"pool tier dispatches one update per trajectory (K=1)")
            from repro.core.pool import Pool
            self.pool = Pool(env, tcfg.num_envs,
                             num_buffers=tcfg.pool_buffers,
                             key=jax.random.fold_in(key, 1))
            self.vec = self.pool.vec
            self.rc = None
            self.num_shards = 1
            self._learn = jax.jit(make_ocean_learn(
                policy, tcfg, dist, kernel_mode=kernel_mode))
            self._act = jax.jit(self._make_act())
            self._boot = jax.jit(self._make_bootstrap())
            return

        self.vec = VecEnv(env, tcfg.num_envs)
        env_state, obs = self.vec.init(jax.random.fold_in(key, 1))
        B = self.vec.batch_size
        if self.selfplay is not None:
            from repro.league.selfplay import SelfPlayCarry
            N, A, L = tcfg.num_envs, self.vec.num_agents, self._sp_agents
            self.rc = SelfPlayCarry(env_state, obs,
                                    policy.initial_carry(N * L),
                                    policy.initial_carry(N * (A - L)),
                                    jnp.zeros((B,), jnp.bool_))
        else:
            self.rc = RolloutCarry(env_state, obs, policy.initial_carry(B),
                                   jnp.zeros((B,), jnp.bool_))

        if self.backend == "shard_map":
            if num_shards != 1:
                raise ValueError("num_shards is derived from the mesh on "
                                 "the shard_map tier; pass a mesh instead")
            if self.mesh is None:
                from repro.launch.mesh import make_mesh
                self.mesh = make_mesh((jax.device_count(),), ("data",))
            axes = shd.data_axes(self.mesh)
            if not axes:
                raise ValueError(
                    f"mesh {self.mesh.axis_names} has no data axes "
                    f"('pod'/'data') to shard Ocean PPO over")
            S = shd.dp_size(self.mesh)
            if self.vec.num_envs % S:
                raise ValueError(
                    f"num_envs={self.vec.num_envs} not divisible by the "
                    f"mesh data-parallel size {S}")
            self._axis = axes if len(axes) > 1 else axes[0]
            self._rc_spec = shd.ocean_batch_spec(self.mesh)
            self.num_shards = S
            self._update = self._make_update(
                self.vec.num_envs // S, kernel_mode,
                axis_name=self._axis, num_shards=S)
            # place state once: params/opt replicated, env batch sharded
            self.ts = jax.device_put(self.ts,
                                     NamedSharding(self.mesh, P()))
            self.rc = jax.device_put(self.rc,
                                     NamedSharding(self.mesh, self._rc_spec))
        else:
            if num_shards < 1 or self.vec.num_envs % num_shards:
                raise ValueError(
                    f"num_envs={self.vec.num_envs} not divisible by "
                    f"num_shards={num_shards}: the S-block emulation would "
                    f"silently drop the tail envs from every minibatch")
            self.num_shards = num_shards
            self._update = self._make_update(self.vec.num_envs, kernel_mode,
                                             num_shards=num_shards)

    def _make_update(self, num_envs_local: int, kernel_mode,
                     axis_name=None, num_shards: int = 1):
        """The per-update program of the fused tiers: the ordinary keyed-step
        Ocean update, or its self-play twin with split agent rows."""
        if self.selfplay is not None:
            from repro.league.selfplay import make_selfplay_update
            return make_selfplay_update(
                self.policy, self.vec.step_keyed_fn(), self.tcfg, self.dist,
                num_envs_local, self.vec.num_agents, self._sp_agents,
                kernel_mode=kernel_mode, axis_name=axis_name,
                num_shards=num_shards)
        return make_ocean_update(
            self.policy, self.vec.step_keyed_fn(), self.tcfg, self.dist,
            num_envs_local, kernel_mode=kernel_mode, axis_name=axis_name,
            num_shards=num_shards, keyed_step=True)

    # -- program cache ---------------------------------------------------------
    def _launch_for(self, k: int):
        """The compiled k-update launch (cached; at most two sizes per run —
        K and the tail). State buffers are donated: the launch consumes its
        inputs and the engine only ever holds the newest generation."""
        if k not in self._launches:
            sp = self.selfplay is not None
            fn = _scan_launch(self._update, k, selfplay=sp)
            if self.backend == "shard_map":
                in_specs = ((P(), self._rc_spec, P(), P()) if sp
                            else (P(), self._rc_spec, P()))
                fn = shard_map(fn, mesh=self.mesh, in_specs=in_specs,
                               out_specs=(P(), self._rc_spec, P()),
                               check_rep=False)
            self._launches[k] = jax.jit(fn, donate_argnums=(0, 1))
        return self._launches[k]

    def update_keys(self, key, k: int = None):
        """Per-update keys of one launch keyed by ``key`` — exposed so the
        fused-vs-sequential parity test can replay the exact schedule."""
        return jax.random.split(key, k or self.K)

    # -- state management (checkpoint save/restore) ----------------------------
    def set_train_state(self, ts: TrainState):
        if self.backend == "shard_map":
            ts = jax.device_put(ts, NamedSharding(self.mesh, P()))
        self.ts = ts

    def _ckpt_like(self):
        sds = lambda t: jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), t)
        like = {"ts": sds(self.ts), "key": sds(self.key),
                "update": jax.ShapeDtypeStruct((), np.int64)}
        if self.rc is not None:
            like["rc"] = sds(self.rc)
        return like

    def save_checkpoint(self, update: int = None, async_: bool = False):
        """Save the full resumable state (TrainState, RNG key, update count,
        and — on the device-resident tiers — the rollout carry) as one
        elastic checkpoint under ``checkpoint_dir``. Async mode snapshots to
        host synchronously and writes on a background thread; overlapping
        saves are serialized (the previous write joins first)."""
        if self.checkpoint_dir is None:
            raise ValueError("engine has no checkpoint_dir")
        if self._ckpt_thread is not None:
            self._ckpt_thread.join()
            self._ckpt_thread = None
        update = self._saved_upto if update is None else update
        tree = {"ts": self.ts, "key": self.key,
                "update": np.asarray(update, np.int64)}
        if self.rc is not None:
            tree["rc"] = self.rc
        out = ckpt.save(self.checkpoint_dir, tree, step=update,
                        async_=async_, keep=self.tcfg.keep_checkpoints)
        if async_:
            self._ckpt_thread = out
        return out

    def restore(self, directory: Optional[str] = None) -> int:
        """Restore the newest committed checkpoint and return the update
        count it was taken at; ``run`` then continues from there. On the jit
        and shard_map tiers the rollout carry restores too, so an
        interrupted-then-resumed run is bitwise-identical to an
        uninterrupted one; pool/host resume the learner + key only (their
        env state lives host-side)."""
        directory = directory or self.checkpoint_dir
        if directory is None:
            raise ValueError("engine has no checkpoint_dir to restore from")
        tree = ckpt.restore(directory, self._ckpt_like())
        self.set_train_state(tree["ts"])
        if self.rc is not None:
            rc = tree["rc"]
            if self.backend == "shard_map":
                rc = jax.device_put(rc,
                                    NamedSharding(self.mesh, self._rc_spec))
            self.rc = rc
        self.key = tree["key"]
        self._resume_update = self._saved_upto = int(tree["update"])
        return self._resume_update

    def _maybe_checkpoint(self, updates_done: int):
        """The launch-boundary checkpoint hook (all four tiers)."""
        ce = self.tcfg.checkpoint_every
        if self.checkpoint_dir is None or ce <= 0:
            return
        if updates_done // ce > self._saved_upto // ce:
            self._saved_upto = updates_done
            self.save_checkpoint(updates_done, async_=True)

    def _join_checkpoint(self):
        if self._ckpt_thread is not None:
            self._ckpt_thread.join()
            self._ckpt_thread = None

    @property
    def batch_size(self) -> int:
        return self.vec.batch_size

    @property
    def steps_per_update(self) -> int:
        return self.tcfg.unroll_length * self.vec.batch_size

    def stats(self) -> dict:
        """Live snapshot for monitoring endpoints: the backend name plus
        whatever worker-pool stats the tier exposes (host tier: the
        HostPool slab aggregates + liveness; async tier: actor slab
        aggregates, liveness, straggler monitors). Cheap enough to call
        from an HTTP request thread mid-run."""
        out = {"backend": self.backend}
        if self.backend == "host":
            pool = getattr(self.hvec, "pool", None)
            if pool is not None:
                out["pool"] = pool.stats()
        if self.backend == "async":
            out["rollouts"] = self.rollouts.stats()
        return out

    # -- the unified run loop --------------------------------------------------
    def run(self, total_steps: int, *, target_score: Optional[float] = None,
            on_update: Optional[Callable] = None,
            on_launch: Optional[Callable] = None, logger=None):
        """Train until env interactions ≥ total_steps (or solved).

        Returns ``(history, solved)``: per-update metric dicts (with the
        unified ``env_steps``/``sps``/``launch_ms``/``fetch_ms`` telemetry
        keys — same semantics on every tier, stamped by one shared
        ``TierTimer``). ``on_update(u, metrics)`` fires per update once its
        launch's ring is fetched; ``on_launch(updates_dispatched)`` fires
        right after each dispatch (host-side, no device sync) — checkpoint
        hooks go there. With ``target_score`` set, every launch is drained
        eagerly so the check happens at each launch boundary; otherwise the
        engine keeps one launch in flight and fetches the ring one launch
        late, so JAX async dispatch overlaps host work with device compute.

        ``logger`` (a ``utils.metrics.MetricsLogger``) streams every drained
        record as it lands and is flushed on *any* exit — an interrupted run
        keeps every fetched record on disk, nothing truncates. With span
        tracing enabled (``telemetry.enable``) a final telemetry-registry
        snapshot is appended on clean completion; disabled runs leave the
        metrics stream exactly one record per update.
        """
        runner = {"pool": self._run_pool, "host": self._run_host,
                  "async": self._run_async}.get(self.backend,
                                                self._run_fused)
        try:
            with tel_span("engine.run"):
                history, solved = runner(
                    total_steps, target_score=target_score,
                    on_update=on_update, on_launch=on_launch, logger=logger)
            if logger is not None and history and tel_enabled():
                tel_registry().emit(logger,
                                    int(history[-1]["env_steps"]))
            return history, solved
        finally:
            if logger is not None:
                logger.flush()
            tel_flush()

    def _run_fused(self, total_steps, *, target_score=None, on_update=None,
                   on_launch=None, logger=None):
        """The jit / shard_map tiers: K fused updates per dispatch."""
        spu = self.steps_per_update
        num_updates = max(1, total_steps // spu)
        history, pending, solved = [], deque(), None
        # resumed runs: sps counts only this process's work
        timer = TierTimer(spu, self._resume_update * spu)
        upd_ctr = tel_registry().counter("engine.updates",
                                         tier=self.backend)

        def drain_one():
            nonlocal solved
            u0, kk, ring = pending.popleft()
            with timer.fetch():
                rows = np.asarray(jax.device_get(ring))
            for i in range(kk):
                md = unpack_metrics(rows[i])
                timer.stamp(md, (u0 + i + 1) * spu)
                history.append(md)
                upd_ctr.inc()
                if logger is not None:
                    logger.log(md["env_steps"], md, flush=False)
                if on_update is not None:
                    on_update(u0 + i, md)
                if (target_score is not None and solved is None
                        and md["episodes"] > 0
                        and md["score"] >= target_score):
                    solved = md
            if logger is not None:
                logger.flush()

        u = self._resume_update
        while u < num_updates:
            k = min(self.K, num_updates - u)
            self.key, sub = jax.random.split(self.key)
            with timer.launch():
                if self.selfplay is not None:
                    opp = self.selfplay.next_opponent()
                    self.ts, self.rc, ring = self._launch_for(k)(
                        self.ts, self.rc, opp, sub)
                else:
                    self.ts, self.rc, ring = self._launch_for(k)(
                        self.ts, self.rc, sub)
            pending.append((u, k, ring))
            u += k
            self._maybe_checkpoint(u)
            if on_launch is not None:
                on_launch(u)
            if target_score is not None:
                while pending:
                    drain_one()
                if solved is not None:
                    break
            elif len(pending) > 1:
                drain_one()
        while pending:
            drain_one()
        self._join_checkpoint()
        return history, solved

    # -- pool tier -------------------------------------------------------------
    def _make_act(self):
        policy, dist = self.policy, self.dist

        def act(params, obs, carry, reset, key):
            logits, value, pc = policy.step(params, obs, carry, reset=reset)
            action = dist.sample(key, logits)
            logp = dist.log_prob(logits, action)
            return action, logp, value, pc
        return act

    def _make_bootstrap(self):
        policy = self.policy

        def boot(params, obs, carry, reset):
            _, value, _ = policy.step(params, obs, carry, reset=reset)
            return value
        return boot

    def _metrics_drainer(self, pending, history, timer, on_update,
                         target_score, st, logger=None):
        """Shared pool/host-tier drain: fetch one update's metrics (blocks
        only on that update's learn, not on later dispatched work), stamp
        the unified telemetry keys, fire ``on_update``, and latch the
        solving update into ``st["solved"]``."""
        upd_ctr = tel_registry().counter("engine.updates",
                                         tier=self.backend)

        def drain_one():
            uu, m = pending.popleft()
            with timer.fetch():
                md = {k: float(v) for k, v in
                      zip(METRIC_KEYS, jax.device_get([m[k] for k in
                                                       METRIC_KEYS]))}
            timer.stamp(md, (uu + 1) * timer.spu)
            history.append(md)
            upd_ctr.inc()
            if logger is not None:
                logger.log(md["env_steps"], md)
            if on_update is not None:
                on_update(uu, md)
            if (target_score is not None and st["solved"] is None
                    and md["episodes"] > 0 and md["score"] >= target_score):
                st["solved"] = md
        return drain_one

    def _run_pool(self, total_steps, *, target_score=None, on_update=None,
                  on_launch=None, logger=None):
        """Host loop over the double-buffered pool. The trajectory for each
        buffer accumulates as in-flight device arrays; when a buffer reaches
        T steps its update runs while the other buffers' env steps stay
        queued on the device — the paper's EnvPool overlap, learner edition.
        """
        tcfg, pool = self.tcfg, self.pool
        T, B = tcfg.unroll_length, pool.batch_size
        spu = T * B
        num_updates = max(1, total_steps // spu)
        nb = pool.num_buffers
        carry = [self.policy.initial_carry(B) for _ in range(nb)]
        carry0 = [self.policy.initial_carry(B) for _ in range(nb)]
        recs = [[] for _ in range(nb)]
        history, pending, st = [], deque(), {"solved": None}
        timer = TierTimer(spu, self._resume_update * spu)
        drain_one = self._metrics_drainer(pending, history, timer,
                                          on_update, target_score, st,
                                          logger)

        u = self._resume_update
        while u < num_updates and st["solved"] is None:
            with tel_span("pool.recv"):
                obs, rew, done, info, b = pool.recv()
            if recs[b]:
                recs[b][-1] = recs[b][-1] + (rew, done, info)
            if len(recs[b]) == T and len(recs[b][-1]) == 8:
                last_value = self._boot(self.ts.params, obs, carry[b], done)
                cols = list(zip(*recs[b]))
                stk = lambda xs: jnp.stack(xs)
                traj = Trajectory(
                    obs=stk(cols[0]), actions=stk(cols[1]),
                    logprobs=stk(cols[2]), values=stk(cols[3]),
                    rewards=stk(cols[5]), dones=stk(cols[6]),
                    resets=stk(cols[4]),
                    infos=jax.tree.map(lambda *x: jnp.stack(x), *cols[7]))
                self.key, kp = jax.random.split(self.key)
                with timer.launch():
                    self.ts, m = self._learn(self.ts, carry0[b], traj,
                                             last_value, kp)
                carry0[b] = carry[b]
                recs[b] = []
                pending.append((u, m))
                u += 1
                self._maybe_checkpoint(u)
                if on_launch is not None:
                    on_launch(u)
                # sync each update only when early-exit needs the score;
                # otherwise stay one update behind so the learn and the other
                # buffers' env steps keep the device queue full
                if target_score is not None:
                    while pending:
                        drain_one()
                elif len(pending) > 1:
                    drain_one()
            # act before checking solved so the recv'd buffer is always
            # sent back — the pool stays reusable after an early exit
            self.key, ka = jax.random.split(self.key)
            action, logp, value, pc = self._act(self.ts.params, obs,
                                                carry[b], done, ka)
            recs[b].append((obs, action, logp, value, done))
            carry[b] = pc
            pool.send(action, b)
        while pending:
            drain_one()
        self._join_checkpoint()
        return history, st["solved"]

    # -- async actor–learner tier ----------------------------------------------
    def _collect_fragments(self, nf: int) -> list:
        """``nf`` fresh-enough fragments from the actor pool. In drop mode,
        fragments older than ``max_staleness`` learner versions are
        discarded before batching (the actors keep producing, so this
        converges); in vtrace mode every fragment batches and the
        importance clamps in the learn program do the correcting."""
        tcfg = self.tcfg
        out = []
        while len(out) < nf:
            got = self.rollouts.wait_fragments(
                nf - len(out), timeout=tcfg.async_recv_timeout)
            for f in got:
                if (tcfg.staleness_mode == "drop"
                        and self._version - f.version > tcfg.max_staleness):
                    self._dropped += 1
                    continue
                out.append(f)
        return out

    def _run_async(self, total_steps, *, target_score=None, on_update=None,
                   on_launch=None, logger=None):
        """The learner half of the actor–learner split, run through the
        (recovery-correct) ResilientLoop: collect one update's worth of
        fragments from the slab, learn, publish the new params version.
        Fragments are a live stream — ResilientLoop's iterator contract —
        so recovery retries the current batch and only restores a
        checkpoint that sits exactly at ``steps_done``. Checkpoints are the
        engine's standard {ts, key, update} tree, so ``restore()`` +
        ``run()`` resumes a killed learner step-count-correctly (actors
        re-seed from the published params like the pool/host tiers
        re-seed their env state)."""
        from repro.distributed.actor_learner import stack_fragments
        from repro.distributed.fault import ResilientLoop
        tcfg, ro = self.tcfg, self.rollouts
        spu = self.steps_per_update
        num_updates = max(1, total_steps // spu)
        nf = ro.spec.num_shards           # fragments per update = one pass
                                          # over every env shard's batch rows
        history, st = [], {"solved": None}
        timer = TierTimer(spu, self._resume_update * spu)
        reg = tel_registry()
        upd_ctr = reg.counter("engine.updates", tier="async")
        age_hist = reg.histogram("async.frag_age",
                                 edges=(0.0, 1.0, 2.0, 4.0, 8.0))

        self._version = self._resume_update
        ro.publish(self.ts.params, self._version)

        def step_fn(state, frags):
            with tel_span("engine.stack_fragments"):
                traj, last_value = stack_fragments(frags)
            key, kp = jax.random.split(state["key"])
            with timer.launch():
                ts, m = self._learn(state["ts"], None, traj, last_value, kp)
            u = int(state["update"]) + 1
            # publish inside the step: np.asarray on a poisoned update
            # raises *before* the slab is touched (see AsyncRollouts
            # .publish), so actors only ever see committed params
            ro.publish(ts.params, u)
            return ({"ts": ts, "key": key,
                     "update": np.asarray(u, np.int64)}, m)

        loop = ResilientLoop(
            step_fn, self.checkpoint_dir,
            save_every=(tcfg.checkpoint_every
                        if self.checkpoint_dir is not None else 0),
            async_save=True, keep=tcfg.keep_checkpoints)
        loop.steps_done = self._resume_update
        state = {"ts": self.ts, "key": self.key,
                 "update": np.asarray(self._resume_update, np.int64)}

        def frag_stream():
            while loop.steps_done < num_updates and st["solved"] is None:
                with tel_span("engine.collect"):
                    batch = self._collect_fragments(nf)
                self._last_ages = [self._version - f.version for f in batch]
                for a in self._last_ages:
                    age_hist.observe(a)
                yield batch

        def on_metrics(u, m):
            self._version = ro.version    # published by step_fn
            with timer.fetch():
                md = {k: float(np.asarray(v)) for k, v in m.items()}
            timer.stamp(md, u * spu)
            ages = getattr(self, "_last_ages", [])
            md["frag_age_mean"] = (float(np.mean(ages)) if ages else 0.0)
            md["frag_age_max"] = (float(np.max(ages)) if ages else 0.0)
            md["dropped_fragments"] = self._dropped
            md["stragglers"] = int(np.sum(ro.straggler_flags))
            md["actors_alive"] = len(ro.alive_actors())
            md["reshards"] = len(ro.events)
            history.append(md)
            upd_ctr.inc()
            if logger is not None:
                logger.log(md["env_steps"], md)
            if on_update is not None:
                on_update(u - 1, md)
            if on_launch is not None:
                on_launch(u)
            if (target_score is not None and st["solved"] is None
                    and md["episodes"] > 0 and md["score"] >= target_score):
                st["solved"] = md

        state = loop.run(state, frag_stream(), on_metrics=on_metrics)
        self.ts, self.key = state["ts"], state["key"]
        self._resume_update = self._saved_upto = int(state["update"])
        if self.checkpoint_dir is not None:
            # final commit: kill-then-resume ends at the same step count
            # (and params) as an uninterrupted run
            self.save_checkpoint(self._resume_update, async_=False)
        return history, st["solved"]

    # -- host tier -------------------------------------------------------------
    def close(self):
        """Release host-side resources (the host tier's worker threads or
        processes, or the async tier's actor processes + slab)."""
        if self.backend == "host":
            self.hvec.close()
        if self.backend == "async":
            self.rollouts.close()

    def _run_host(self, total_steps, *, target_score=None, on_update=None,
                  on_launch=None, logger=None):
        """First-finisher loop over the bridged ``HostVecEnv``: each recv is
        the N (of M = pool_buffers·N) envs that finished stepping first;
        while the device computes their actions, the other M−N envs keep
        stepping on worker threads — the paper's EnvPool overlap with the
        learner on device. Rollout fragments accumulate per env (keyed by
        ``env_ids``), so every fragment is a contiguous T-step slice of one
        env's experience with its own recurrent carry and GAE bootstrap; an
        update fires whenever N fragments are ready, batching whichever envs
        filled first."""
        tcfg, hv = self.tcfg, self.hvec
        T = tcfg.unroll_length
        Nb, A = hv.batch_envs, hv.num_agents
        spu = T * Nb * A
        num_updates = max(1, total_steps // spu)
        M = hv.num_envs
        recurrent = self.policy.recurrent
        carry = [self.policy.initial_carry(A) for _ in range(M)]
        carry0 = [self.policy.initial_carry(A) for _ in range(M)]
        recs = [[] for _ in range(M)]
        ready = deque()
        history, pending, st = [], deque(), {"solved": None}
        timer = TierTimer(spu, self._resume_update * spu)
        drain_one = self._metrics_drainer(pending, history, timer,
                                          on_update, target_score, st,
                                          logger)

        u = self._resume_update
        while u < num_updates and st["solved"] is None:
            obs, rew, done, info, ids = hv.recv(
                timeout=tcfg.host_recv_timeout)
            obs_e = obs.reshape(Nb, A, -1)
            rew_e = rew.reshape(Nb, A)
            done_e = done.reshape(Nb, A)
            # complete each env's previous record with its step outcome
            for j, i in enumerate(ids):
                if recs[i]:
                    inf = {k: info[k][j] for k in info}
                    recs[i][-1] = recs[i][-1] + (rew_e[j], done_e[j], inf)
            # act on the batch (device) while the other envs step (host)
            cb = (jax.tree.map(lambda *xs: jnp.concatenate(xs),
                               *[carry[i] for i in ids])
                  if recurrent else None)
            self.key, ka = jax.random.split(self.key)
            action, logp, value, pc = self._act(self.ts.params, obs, cb,
                                                done, ka)
            action = np.asarray(action)
            act_e = action.reshape((Nb, A) + action.shape[1:])
            logp_e = np.asarray(logp).reshape(Nb, A)
            val_e = np.asarray(value).reshape(Nb, A)
            # harvest full fragments (bootstrapped by this batch's values),
            # then start each env's next fragment with this step
            for j, i in enumerate(ids):
                if len(recs[i]) == T and len(recs[i][-1]) == 8:
                    ready.append((recs[i], carry0[i], val_e[j]))
                    recs[i] = []
                    carry0[i] = carry[i]
                recs[i].append((obs_e[j], act_e[j], logp_e[j], val_e[j],
                                done_e[j]))
                if recurrent:
                    carry[i] = jax.tree.map(
                        lambda x, j=j: x[j * A:(j + 1) * A], pc)
            hv.send(action, ids)
            # one PPO update per Nb collected fragments
            while (len(ready) >= Nb and u < num_updates
                   and st["solved"] is None):
                frags = [ready.popleft() for _ in range(Nb)]
                traj, c0, last_value = self._stack_fragments(frags, T, A,
                                                             recurrent)
                self.key, kp = jax.random.split(self.key)
                with timer.launch():
                    self.ts, m = self._learn(self.ts, c0, traj, last_value,
                                             kp)
                pending.append((u, m))
                u += 1
                self._maybe_checkpoint(u)
                if on_launch is not None:
                    on_launch(u)
                if target_score is not None:
                    while pending:
                        drain_one()
                elif len(pending) > 1:
                    drain_one()
        while pending:
            drain_one()
        self._join_checkpoint()
        return history, st["solved"]

    @staticmethod
    def _stack_fragments(frags, T, A, recurrent):
        """N per-env fragments (each T steps of (A, …) rows) → one
        (T, N·A)-batched Trajectory + per-row carry0 + bootstrap values."""
        Nb = len(frags)
        cols = [list(zip(*rec)) for rec, _c0, _bv in frags]

        def field(k, dtype=None):
            x = np.stack([np.stack(c[k]) for c in cols], axis=1)
            x = x.reshape((T, Nb * A) + x.shape[3:])
            return x if dtype is None else x.astype(dtype)

        infos = {key: np.stack([np.stack([r[key] for r in c[7]])
                                for c in cols], axis=1)
                 for key in cols[0][7][0]}               # (T, Nb) per key
        traj = Trajectory(
            obs=field(0, np.float32), actions=field(1),
            logprobs=field(2, np.float32), values=field(3, np.float32),
            rewards=field(5, np.float32), dones=field(6, bool),
            resets=field(4, bool), infos=infos)
        c0 = (jax.tree.map(lambda *xs: jnp.concatenate(xs),
                           *[f[1] for f in frags]) if recurrent else None)
        last_value = np.concatenate([np.asarray(f[2]) for f in frags])
        return traj, c0, last_value
