"""Acting / serving: prefill_step and serve_step (the decode-shape programs).

serve_step is one token of autoregressive acting against the recurrent cell
(KV cache / SSM state) — the paper's encode→recurrent→decode interface at
inference time. ``context_parallel`` shards the KV sequence dim over "data"
for long_500k (DESIGN.md §4 CP).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def make_prefill_step(policy, max_len: int):
    def prefill_step(params, inputs, key):
        logits, value, caches = policy.prefill(params, inputs, max_len)
        tok = jax.random.categorical(key, logits).astype(jnp.int32)
        return tok[:, None], value, caches
    return prefill_step


def make_serve_step(policy, temperature: float = 1.0,
                    context_parallel: bool = False, greedy: bool = False):
    def serve_step(params, tokens, caches, key):
        logits, value, caches = policy.decode(
            params, tokens, caches, context_parallel=context_parallel)
        if greedy:
            tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        else:
            tok = jax.random.categorical(
                key, logits / temperature).astype(jnp.int32)
        return tok[:, None], value, caches
    return serve_step


def generate(policy, params, prompt, num_tokens: int, key, max_len: int = 0,
             temperature: float = 1.0):
    """Batched autoregressive generation (examples/serving driver)."""
    B, Tp = prompt.shape
    max_len = max_len or (Tp + num_tokens)
    prefill = make_prefill_step(policy, max_len)
    serve = jax.jit(make_serve_step(policy, temperature))
    k0, key = jax.random.split(key)
    tok, _, caches = prefill(params, {"tokens": prompt}, k0)
    out = [tok]
    for i in range(num_tokens - 1):
        key, sub = jax.random.split(key)
        tok, _, caches = serve(params, tok, caches, sub)
        out.append(tok)
    return jnp.concatenate(out, axis=1)
