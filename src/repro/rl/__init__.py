from repro.rl import distributions, ppo, rollout, learner, actor, trainer
from repro.rl.learner import TrainState, init_train_state, \
    make_ocean_update, make_lm_train_step, lm_batch_fields
from repro.rl.trainer import Trainer
