from repro.rl import distributions, ppo, rollout, learner, engine, actor, \
    trainer
from repro.rl.learner import TrainState, init_train_state, \
    make_ocean_update, make_ocean_learn, make_lm_train_step, lm_batch_fields
from repro.rl.engine import TrainEngine, METRIC_KEYS
from repro.rl.trainer import Trainer
