"""On-device rollout: the paper's actor loop as one fused scan.

The entire unroll (policy step + env step + auto-reset, T times) lives inside
jit — the endpoint of the paper's trajectory away from per-step host IPC
("only one step per episode requires communication" → zero). The EnvPool
double-buffered host loop in core/pool.py covers host-bound envs; this path
covers JAX-native ones.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.rl import distributions as D


class Trajectory(NamedTuple):
    obs: jax.Array        # (T, B, obs)
    actions: jax.Array    # (T, B, n_comp)
    logprobs: jax.Array   # (T, B)
    values: jax.Array     # (T, B)
    rewards: jax.Array    # (T, B)
    dones: jax.Array      # (T, B)  done AT this step
    resets: jax.Array     # (T, B)  obs at this step began an episode
    infos: dict           # (T, num_envs) pytree


class RolloutCarry(NamedTuple):
    env_state: object
    obs: jax.Array
    policy_carry: object
    done_prev: jax.Array


def rollout(policy, params, step_fn, carry: RolloutCarry, key,
            unroll: int, dist, keyed=None):
    """Returns (carry', Trajectory, last_value (B,)). ``dist`` is a
    distributions.Dist (categorical or gaussian — paper §8 extension).

    ``keyed``: None → legacy randomness (one key per step, split per env
    inside ``step_fn``). Otherwise ``(num_envs, env_offset)``: per-env keys
    derived from the *global* env index ``env_offset + arange(num_envs)``,
    and ``step_fn`` must accept ``(state, actions, keys)`` with one key per
    env (``VecEnv.step_keyed_fn``). This makes the rollout bitwise
    independent of how envs are sharded across devices — device d of an
    S-way data-parallel run passes ``env_offset = d * (B // S)`` and draws
    exactly the keys the single-device run draws for those envs.
    """

    def one(c: RolloutCarry, k):
        k_act, k_env = jax.random.split(k)
        logits, value, pc = policy.step(params, c.obs, c.policy_carry,
                                        reset=c.done_prev)
        if keyed is None:
            action = dist.sample(k_act, logits)
            logp = dist.log_prob(logits, action)
            env_state, obs, rew, done, info = step_fn(c.env_state, action,
                                                      k_env)
        else:
            num_envs, off = keyed
            batch = logits.shape[0]
            agents = batch // num_envs
            act_idx = off * agents + jnp.arange(batch)
            act_keys = jax.vmap(lambda i: jax.random.fold_in(k_act, i))(
                act_idx)
            action = jax.vmap(dist.sample)(act_keys, logits)
            logp = dist.log_prob(logits, action)
            env_keys = jax.vmap(lambda i: jax.random.fold_in(k_env, i))(
                off + jnp.arange(num_envs))
            env_state, obs, rew, done, info = step_fn(c.env_state, action,
                                                      env_keys)
        out = Trajectory(c.obs, action, logp, value, rew, done,
                         c.done_prev, info)
        return RolloutCarry(env_state, obs, pc, done), out

    keys = jax.random.split(key, unroll)
    carry, traj = jax.lax.scan(one, carry, keys)

    # bootstrap value for GAE
    _, last_value, _ = policy.step(params, carry.obs, carry.policy_carry,
                                   reset=carry.done_prev)
    return carry, traj, last_value
