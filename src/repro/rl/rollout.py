"""On-device rollout: the paper's actor loop as one fused scan.

The entire unroll (policy step + env step + auto-reset, T times) lives inside
jit — the endpoint of the paper's trajectory away from per-step host IPC
("only one step per episode requires communication" → zero). The EnvPool
double-buffered host loop in core/pool.py covers host-bound envs; this path
covers JAX-native ones.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.rl import distributions as D


class Trajectory(NamedTuple):
    obs: jax.Array        # (T, B, obs)
    actions: jax.Array    # (T, B, n_comp)
    logprobs: jax.Array   # (T, B)
    values: jax.Array     # (T, B)
    rewards: jax.Array    # (T, B)
    dones: jax.Array      # (T, B)  done AT this step
    resets: jax.Array     # (T, B)  obs at this step began an episode
    infos: dict           # (T, num_envs) pytree


class RolloutCarry(NamedTuple):
    env_state: object
    obs: jax.Array
    policy_carry: object
    done_prev: jax.Array


def rollout(policy, params, step_fn, carry: RolloutCarry, key,
            unroll: int, dist):
    """Returns (carry', Trajectory, last_value (B,)). ``dist`` is a
    distributions.Dist (categorical or gaussian — paper §8 extension)."""

    def one(c: RolloutCarry, k):
        k_act, k_env = jax.random.split(k)
        logits, value, pc = policy.step(params, c.obs, c.policy_carry,
                                        reset=c.done_prev)
        action = dist.sample(k_act, logits)
        logp = dist.log_prob(logits, action)
        env_state, obs, rew, done, info = step_fn(c.env_state, action, k_env)
        out = Trajectory(c.obs, action, logp, value, rew, done,
                         c.done_prev, info)
        return RolloutCarry(env_state, obs, pc, done), out

    keys = jax.random.split(key, unroll)
    carry, traj = jax.lax.scan(one, carry, keys)

    # bootstrap value for GAE
    _, last_value, _ = policy.step(params, carry.obs, carry.policy_carry,
                                   reset=carry.done_prev)
    return carry, traj, last_value
