from repro.utils import metrics
