"""Metrics logging (the paper's §6 "better logging and WandB integration",
dependency-free edition): JSONL stream + rolling aggregates, one file per
run, safe under checkpoint-restart (append mode, step-keyed) and under
interruption (context manager; ``close()`` is idempotent and always leaves
a complete final record on disk)."""
from __future__ import annotations

import json
import math
import os
import time
from typing import Optional


def _scrub(v: float):
    """JSON has no NaN/Inf: ``json.dumps`` with the default ``allow_nan``
    writes bare ``NaN`` tokens that ``json.loads`` round-trips but every
    strict parser (jq, browsers, pandas ``read_json``) rejects. Non-finite
    values become ``null`` — explicitly absent, not silently poisoned."""
    return v if math.isfinite(v) else None


class MetricsLogger:
    """JSONL metrics stream. Usable as a context manager::

        with MetricsLogger("runs/exp1", "bandit") as ml:
            ml.log(step, metrics)

    so an exception (or a normal exit) always flushes + fsyncs the final
    record instead of truncating it mid-line."""

    def __init__(self, log_dir: Optional[str] = None, run_name: str = "run"):
        self.path = None
        self._f = None
        if log_dir:
            os.makedirs(log_dir, exist_ok=True)
            self.path = os.path.join(log_dir, f"{run_name}.jsonl")
            self._f = open(self.path, "a")
        self._t0 = time.time()

    def log(self, step: int, metrics: dict, flush: bool = True):
        if self._f is None:
            return
        rec = {"step": int(step), "wall_s": round(time.time() - self._t0, 3)}
        for k, v in metrics.items():
            try:
                rec[k] = _scrub(float(v))
            except (TypeError, ValueError):
                pass
        self._f.write(json.dumps(rec, allow_nan=False) + "\n")
        if flush:
            self._f.flush()

    def log_batch(self, records):
        """One write + flush for a whole launch of per-update metric dicts
        (each carrying its own ``env_steps``) — the host-side counterpart of
        the engine's once-per-launch metrics fetch."""
        if self._f is None:
            return
        for rec in records:
            self.log(int(rec.get("env_steps", 0)), rec, flush=False)
        self._f.flush()

    def flush(self):
        if self._f is not None:
            self._f.flush()

    def close(self):
        """Idempotent: flush + fsync + close once; later calls are no-ops."""
        f, self._f = self._f, None
        if f is None:
            return
        try:
            f.flush()
            os.fsync(f.fileno())
        except (OSError, ValueError):
            pass
        f.close()

    def __enter__(self):
        return self

    def __exit__(self, et, ev, tb):
        self.close()
        return False


def read(path: str):
    with open(path) as f:
        return [json.loads(l) for l in f if l.strip()]
