"""Metrics logging (the paper's §6 "better logging and WandB integration",
dependency-free edition): JSONL stream + rolling aggregates, one file per
run, safe under checkpoint-restart (append mode, step-keyed)."""
from __future__ import annotations

import json
import os
import time
from typing import Optional


class MetricsLogger:
    def __init__(self, log_dir: Optional[str] = None, run_name: str = "run"):
        self.path = None
        self._f = None
        if log_dir:
            os.makedirs(log_dir, exist_ok=True)
            self.path = os.path.join(log_dir, f"{run_name}.jsonl")
            self._f = open(self.path, "a")
        self._t0 = time.time()

    def log(self, step: int, metrics: dict, flush: bool = True):
        if self._f is None:
            return
        rec = {"step": int(step), "wall_s": round(time.time() - self._t0, 3)}
        for k, v in metrics.items():
            try:
                rec[k] = float(v)
            except (TypeError, ValueError):
                pass
        self._f.write(json.dumps(rec) + "\n")
        if flush:
            self._f.flush()

    def log_batch(self, records):
        """One write + flush for a whole launch of per-update metric dicts
        (each carrying its own ``env_steps``) — the host-side counterpart of
        the engine's once-per-launch metrics fetch."""
        if self._f is None:
            return
        for rec in records:
            self.log(int(rec.get("env_steps", 0)), rec, flush=False)
        self._f.flush()

    def close(self):
        if self._f:
            self._f.close()


def read(path: str):
    with open(path) as f:
        return [json.loads(l) for l in f if l.strip()]
