"""Mamba2 block (SSD) — module layer over the kernels/ssd Pallas kernel.

Block structure (Mamba2 paper): in_proj → [z | x | B | C | dt], short causal
conv over (x,B,C), SiLU, SSD scan, gated RMSNorm (y·silu(z)), out_proj.
Heads are sharded over "model" (they are independent); the recurrent state
(B, H, hd, ds) is the policy's recurrent cell for serve_step — the paper's
"LSTM sandwich" slot (DESIGN.md §Arch-applicability).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.params import ParamSpec, constrain, use_weight, weight
from repro.models.layers import rms_norm
from repro.kernels import ops as kops


class SSMCache(NamedTuple):
    conv: jax.Array    # (B, d_conv-1, conv_dim) rolling input window
    state: jax.Array   # (B, H, hd, ds)


def _dims(cfg: ModelConfig):
    di = cfg.d_inner
    H = cfg.ssm_heads
    ds = cfg.ssm_state
    G = cfg.ssm_groups
    conv_dim = di + 2 * G * ds
    proj_dim = 2 * di + 2 * G * ds + H   # z, x, B, C, dt
    return di, H, ds, G, conv_dim, proj_dim


def ssm_spec(cfg: ModelConfig, stack: tuple = ()):
    sizes = tuple(s for s, _ in stack)
    names = tuple(n for _, n in stack)
    di, H, ds, G, conv_dim, proj_dim = _dims(cfg)
    return {
        "in_proj": ParamSpec(sizes + (cfg.d_model, proj_dim),
                             names + ("embed", "ssm_heads"), fan_in=cfg.d_model),
        "conv_w": ParamSpec(sizes + (cfg.ssm_conv, conv_dim),
                            names + ("null", "ssm_heads"), fan_in=cfg.ssm_conv),
        "A_log": ParamSpec(sizes + (H,), names + ("ssm_heads",), init="zeros",
                           dtype=jnp.float32),
        "D": ParamSpec(sizes + (H,), names + ("ssm_heads",), init="zeros",
                       dtype=jnp.float32),
        "dt_bias": ParamSpec(sizes + (H,), names + ("ssm_heads",),
                             init="zeros", dtype=jnp.float32),
        "norm": ParamSpec(sizes + (di,), names + ("ssm_heads",), init="zeros",
                          dtype=jnp.float32),
        "out_proj": ParamSpec(sizes + (di, cfg.d_model),
                              names + ("ssm_heads", "embed"), fan_in=di),
    }


def _split_proj(zxbcdt, cfg):
    di, H, ds, G, conv_dim, _ = _dims(cfg)
    z, xBC, dt = jnp.split(zxbcdt, [di, di + conv_dim], axis=-1)
    return z, xBC, dt


def _expand_groups(b, cfg):
    """(.., G, ds) group-projected B/C → per-head (.., H, ds)."""
    H, G = cfg.ssm_heads, cfg.ssm_groups
    return jnp.repeat(b, H // G, axis=-2)


def ssm_apply(params, x, cfg: ModelConfig, kernel: str = None,
              return_cache: bool = False):
    """Full-sequence SSD. x: (B, T, d_model) → (B, T, d_model).
    With ``return_cache`` also returns the SSMCache a decode loop continues
    from (conv window of raw xBC + final SSD state)."""
    B, T, _ = x.shape
    di, H, ds, G, conv_dim, _ = _dims(cfg)
    dt_ = cfg.dtype

    w_in = weight(params, "in_proj", ("embed", "ssm_heads"))
    zxbcdt = jnp.einsum("btd,dp->btp", x, w_in.astype(dt_))
    z, xBC_raw, dt = _split_proj(zxbcdt, cfg)

    # short causal conv over the (x,B,C) channels
    w = params["conv_w"].astype(dt_)                     # (k, conv_dim)
    pad = jnp.zeros((B, cfg.ssm_conv - 1, conv_dim), dt_)
    xp = jnp.concatenate([pad, xBC_raw], axis=1)
    xBC = sum(xp[:, i:i + T] * w[i] for i in range(cfg.ssm_conv))
    xBC = jax.nn.silu(xBC)

    xs, Bc, Cc = jnp.split(xBC, [di, di + G * ds], axis=-1)
    xs = xs.reshape(B, T, H, cfg.ssm_head_dim)
    Bc = _expand_groups(Bc.reshape(B, T, G, ds), cfg)
    Cc = _expand_groups(Cc.reshape(B, T, G, ds), cfg)
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + params["dt_bias"][None, None])
    A = -jnp.exp(params["A_log"])

    xs = constrain(xs, "batch", "null", "ssm_heads", "null")
    y, h_last = kops.ssd(xs, dt, A, Bc, Cc, chunk=cfg.ssm_chunk, mode=kernel)
    y = y + params["D"].astype(dt_)[None, None, :, None] * xs
    y = y.reshape(B, T, di)

    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(dt_),
                 params["norm"], cfg.norm_eps)
    w_out = weight(params, "out_proj", ("ssm_heads", "embed"))
    out = jnp.einsum("bti,id->btd", y, w_out.astype(dt_))
    if return_cache:
        window = xp[:, T:]                                # last d_conv-1 raw
        return out, SSMCache(window, h_last)
    return out


def init_ssm_cache(cfg: ModelConfig, batch: int, stack_dims: tuple = (),
                   dtype=None) -> SSMCache:
    di, H, ds, G, conv_dim, _ = _dims(cfg)
    dtype = dtype or cfg.dtype
    return SSMCache(
        conv=jnp.zeros(stack_dims + (batch, cfg.ssm_conv - 1, conv_dim), dtype),
        state=jnp.zeros(stack_dims + (batch, H, cfg.ssm_head_dim, ds),
                        jnp.float32))


def ssm_decode(params, x, cfg: ModelConfig, cache: SSMCache):
    """One-token step: O(1) in context length. x: (B, 1, d_model)."""
    B = x.shape[0]
    di, H, ds, G, conv_dim, _ = _dims(cfg)
    dt_ = cfg.dtype

    w_in = weight(params, "in_proj", ("embed", "ssm_heads"))
    zxbcdt = jnp.einsum("btd,dp->btp", x, w_in.astype(dt_))
    z, xBC, dt = _split_proj(zxbcdt, cfg)               # (B,1,*)

    window = jnp.concatenate([cache.conv, xBC], axis=1)  # (B, k, conv)
    w = params["conv_w"].astype(dt_)
    xc = jnp.einsum("bkc,kc->bc", window, w)[:, None]    # (B,1,conv)
    xc = jax.nn.silu(xc)
    new_conv = window[:, 1:]

    xs, Bc, Cc = jnp.split(xc, [di, di + G * ds], axis=-1)
    xs = xs.reshape(B, H, cfg.ssm_head_dim)
    Bc = _expand_groups(Bc.reshape(B, G, ds), cfg).astype(jnp.float32)
    Cc = _expand_groups(Cc.reshape(B, G, ds), cfg).astype(jnp.float32)
    dtv = jax.nn.softplus(dt.astype(jnp.float32)[:, 0]
                          + params["dt_bias"][None])     # (B,H)
    A = -jnp.exp(params["A_log"])
    decay = jnp.exp(dtv * A[None])                       # (B,H)
    upd = jnp.einsum("bh,bhd,bhs->bhds", dtv, xs.astype(jnp.float32), Bc)
    state = cache.state * decay[..., None, None] + upd
    y = jnp.einsum("bhds,bhs->bhd", state, Cc).astype(dt_)
    y = y + params["D"].astype(dt_)[None, :, None] * xs
    y = y.reshape(B, 1, di)
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(dt_),
                 params["norm"], cfg.norm_eps)
    w_out = weight(params, "out_proj", ("ssm_heads", "embed"))
    out = jnp.einsum("bti,id->btd", y, w_out.astype(dt_))
    return out, SSMCache(new_conv, state)
