"""Modality frontend STUBS (per assignment: [vlm]/[audio] specify the
transformer backbone only; input_specs() provides precomputed patch/frame
embeddings). These helpers fabricate such prefixes for smoke tests/examples."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig


def stub_prefix(cfg: ModelConfig, key, batch: int):
    """Precomputed frame/patch embeddings: (B, P, d_model)."""
    assert cfg.frontend in ("vlm", "audio")
    return jax.random.normal(key, (batch, cfg.frontend_prefix, cfg.d_model),
                             jnp.float32).astype(cfg.dtype) * 0.02
