"""Mixture-of-Experts with expert parallelism.

Baseline path ("gather"): GShard-style capacity dispatch, but index-based —
tokens are scattered into per-expert capacity slots by integer index instead
of one-hot einsums, keeping memory at O(tokens × d_model) rather than
O(tokens × experts × capacity). Experts are sharded over the "model" mesh
axis (EP); groups (one per sequence) over "data"; GSPMD inserts the
dispatch/return collectives.

The router runs in float32 (numerics) and its auxiliary load-balancing loss
is returned for the training objective (Switch/GShard aux loss).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.params import ParamSpec, constrain, use_weight, weight


def moe_spec(cfg: ModelConfig, stack: tuple = ()):
    sizes = tuple(s for s, _ in stack)
    names = tuple(n for _, n in stack)
    d, f, E = cfg.d_model, cfg.expert_d_ff, cfg.num_experts
    return {
        "router": ParamSpec(sizes + (d, E), names + ("embed", "expert"),
                            fan_in=d, dtype=jnp.float32),
        "wi": ParamSpec(sizes + (E, d, 2 * f),
                        names + ("expert", "embed", "mlp"), fan_in=d),
        "wo": ParamSpec(sizes + (E, f, d),
                        names + ("expert", "mlp", "embed"), fan_in=f),
    }


def capacity(cfg: ModelConfig, group_size: int) -> int:
    c = int(math.ceil(group_size * cfg.top_k * cfg.capacity_factor
                      / cfg.num_experts))
    return max(8, ((c + 3) // 4) * 4)   # align a little for layout


def moe_apply(params, x, cfg: ModelConfig, deterministic: bool = True):
    """x: (B, T, d) — groups are sequences (G=B). Returns (y, aux_loss)."""
    Bg, S, d = x.shape
    E, k = cfg.num_experts, cfg.top_k
    C = capacity(cfg, S)
    dt = cfg.dtype

    # The router dot runs in x.dtype and upcasts AFTER: an f32 dot output
    # makes dx f32, and cotangent-dtype promotion then turns the WHOLE
    # backward residual stream f32 for every layer — 2x on the dominant
    # all-reduce (measured; EXPERIMENTS.md §Perf iteration 2).
    router = weight(params, "router", ("embed", "expert"))
    logits = jnp.einsum("gsd,de->gse", x,
                        router.astype(x.dtype)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)                      # (G,S,E)
    gate, eidx = jax.lax.top_k(probs, k)                         # (G,S,k)
    gate = gate / jnp.maximum(jnp.sum(gate, -1, keepdims=True), 1e-9)

    # Switch aux loss: fraction routed vs mean prob per expert
    me = jnp.mean(probs, axis=(0, 1))                            # (E,)
    ce = jnp.mean(jnp.sum(jax.nn.one_hot(eidx[..., 0], E), axis=1)
                  / S, axis=0)                                   # (E,)
    aux = E * jnp.sum(me * ce)

    # position of each (token, k) within its expert's capacity buffer
    onehot = jax.nn.one_hot(eidx, E, dtype=jnp.int32)            # (G,S,k,E)
    flat = onehot.reshape(Bg, S * k, E)
    pos = jnp.cumsum(flat, axis=1) - 1                           # (G,S*k,E)
    pos = jnp.take_along_axis(
        pos, eidx.reshape(Bg, S * k, 1), axis=-1)[..., 0]        # (G,S*k)
    pos = pos.reshape(Bg, S, k)
    keep = pos < C
    slot = jnp.where(keep, eidx * C + pos, E * C)                # (G,S,k)

    # scatter tokens into capacity slots (extra row E*C swallows drops)
    def scatter_one(xg, slotg):
        buf = jnp.zeros((E * C + 1, d), dt)
        idx = slotg.reshape(-1)                                  # (S*k,)
        src = jnp.repeat(xg, k, axis=0)                          # (S*k, d)
        return buf.at[idx].add(src.astype(dt))

    ebuf = jax.vmap(scatter_one)(x.astype(dt), slot)             # (G,E*C+1,d)
    ebuf = ebuf[:, :E * C].reshape(Bg, E, C, d)
    ebuf = constrain(ebuf, "batch", "expert", "null", "null")

    # expert FFN (SwiGLU) — EP: E sharded over "model". When quantized the
    # dequant+dot pair lowers as one fused W4/W8 matmul (kernels/quant_matmul
    # on TPU; KERNEL_qmm-scoped jnp stand-in for the dry-run).
    # jax.named_scope context managers are single-use: build one per `with`
    scope = "KERNEL_qmm" if "wi_scale" in params else "moe_ffn"
    wi = weight(params, "wi", ("expert", "embed", "mlp"))
    with jax.named_scope(scope):
        h = jnp.einsum("gecd,edf->gecf", ebuf, wi.astype(dt))
    g, u = jnp.split(h, 2, axis=-1)
    act = jax.nn.silu(g) if cfg.mlp_activation == "silu" \
        else jax.nn.gelu(g, approximate=True)
    wo = weight(params, "wo", ("expert", "mlp", "embed"))
    with jax.named_scope(scope):
        y = jnp.einsum("gecf,efd->gecd", act * u, wo.astype(dt))
    y = constrain(y, "batch", "expert", "null", "null")

    # gather back: token t takes its k slots, weighted by gates
    ypad = jnp.concatenate([y.reshape(Bg, E * C, d),
                            jnp.zeros((Bg, 1, d), dt)], axis=1)
    def gather_one(yg, slotg, gateg):
        out = yg[slotg.reshape(-1)].reshape(S, k, d)
        return jnp.sum(out * gateg[..., None].astype(dt), axis=1)
    out = jax.vmap(gather_one)(ypad, slot, gate)
    return out.astype(x.dtype), aux
