"""Shared layers: norms, rotary embeddings, embeddings, gated MLP."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.params import ParamSpec, constrain, use_weight, weight


def rms_norm(x, scale, eps: float = 1e-6):
    # f32 only for the (…,1) variance reduction; the wide elementwise math
    # stays in x.dtype so residual-chain cotangents (which ride the TP
    # all-reduces) stay bf16 — 2x on the dominant collective term
    # (EXPERIMENTS.md §Perf iteration 2).
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    inv = jax.lax.rsqrt(var + eps)
    w = 1.0 + scale.astype(jnp.float32)
    return x * inv.astype(x.dtype) * w.astype(x.dtype)


def rms_norm_spec(dim: int, axes=("embed",)) -> ParamSpec:
    # stored as (scale - 1) so zero-init == identity
    return ParamSpec((dim,), axes, init="zeros", dtype=jnp.float32)


# -- rotary -------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x, positions, theta: float):
    """x: (..., T, H, hd); positions: (..., T) int32."""
    hd = x.shape[-1]
    freqs = rope_frequencies(hd, theta)                       # (hd/2,)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (...,T,hd/2)
    cos = jnp.cos(angles)[..., :, None, :]                    # (...,T,1,hd/2)
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# -- embedding ----------------------------------------------------------------

def embedding_spec(cfg: ModelConfig):
    v = cfg.padded_vocab()
    return {
        "embed": ParamSpec((v, cfg.d_model), ("vocab", "embed"),
                           fan_in=cfg.d_model),
    }


def embed_tokens(params, tokens, cfg: ModelConfig):
    w = weight(params, "embed", ("vocab", "embed"))
    x = jnp.take(w, tokens, axis=0).astype(cfg.dtype)
    return x * jnp.asarray(jnp.sqrt(float(cfg.d_model)), cfg.dtype)


def unembed_spec(cfg: ModelConfig):
    if cfg.tie_embeddings:
        return {}
    v = cfg.padded_vocab()
    return {"unembed": ParamSpec((cfg.d_model, v), ("embed", "vocab"),
                                 fan_in=cfg.d_model)}


def unembed(params, embed_params, x, cfg: ModelConfig):
    if cfg.tie_embeddings:
        w = weight(embed_params, "embed", ("vocab", "embed")).T
    else:
        w = weight(params, "unembed", ("embed", "vocab"))
    logits = jnp.einsum("...d,dv->...v", x, w.astype(cfg.dtype))
    logits = constrain(logits, "batch", "null", "vocab") \
        if logits.ndim == 3 else logits
    return logits.astype(jnp.float32)


# -- gated MLP (SwiGLU / GeGLU) ------------------------------------------------

def make_mlp_spec(cfg: ModelConfig, d_ff: int = 0, stack: tuple = ()):
    """``stack``: leading (size, axis_name) dims (e.g. ((n_periods,'periods'),))."""
    d_ff = d_ff or cfg.d_ff
    sizes = tuple(s for s, _ in stack)
    names = tuple(n for _, n in stack)
    return {
        "wi": ParamSpec(sizes + (cfg.d_model, 2 * d_ff),
                        names + ("embed", "mlp"), fan_in=cfg.d_model),
        "wo": ParamSpec(sizes + (d_ff, cfg.d_model),
                        names + ("mlp", "embed"), fan_in=d_ff),
    }


def mlp_apply(params, x, cfg: ModelConfig):
    # jax.named_scope context managers are single-use: build one per `with`
    scope = "KERNEL_qmm" if "wi_scale" in params else "mlp"
    wi = weight(params, "wi", ("embed", "mlp")).astype(cfg.dtype)
    wo = weight(params, "wo", ("mlp", "embed")).astype(cfg.dtype)
    with jax.named_scope(scope):
        h = jnp.einsum("...d,df->...f", x, wi)
    gate, up = jnp.split(h, 2, axis=-1)
    act = jax.nn.silu(gate) if cfg.mlp_activation == "silu" \
        else jax.nn.gelu(gate, approximate=True)
    h = act * up
    h = constrain(h, "batch", "null", "mlp") if h.ndim == 3 else h
    with jax.named_scope(scope):
        return jnp.einsum("...f,fd->...d", h, wo)
