"""Backbone assembler: dense / MoE / SSM / hybrid stacks from one config.

Layers are grouped into *periods* (the lcm of the MoE and attention interleave
patterns — gemma: 1, llama4: 2, jamba: 8) and the stack is a ``lax.scan`` over
periods with the period body under ``jax.checkpoint``. This keeps the traced
HLO a single period deep regardless of depth — essential for the 80-cell
multi-pod dry-run compile budget — and gives remat for the memory roofline.

Three entry points: ``forward`` (train), ``prefill`` (build caches),
``decode`` (one token against caches). Caches are pytrees stacked over
periods, so decode is also a single scan.
"""
from __future__ import annotations

import math
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.params import ParamSpec, constrain
from repro.models import layers as L
from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod


def stack_period(cfg: ModelConfig) -> int:
    p = 1
    if cfg.num_experts:
        p = math.lcm(p, cfg.moe_period)
    if cfg.ssm_state and cfg.attn_period:
        p = math.lcm(p, cfg.attn_period)
    return p


def _norm_spec(cfg, stack):
    sizes = tuple(s for s, _ in stack)
    names = tuple(n for _, n in stack)
    return ParamSpec(sizes + (cfg.d_model,), names + ("embed",),
                     init="zeros", dtype=jnp.float32)


def layer_kinds(cfg: ModelConfig, i: int):
    mixer = "attn" if cfg.is_attn_layer(i) else "ssm"
    if cfg.d_ff == 0 and not cfg.is_moe_layer(i):
        ffn = None
    else:
        ffn = "moe" if cfg.is_moe_layer(i) else "mlp"
    return mixer, ffn


def transformer_spec(cfg: ModelConfig, tp: int):
    period = stack_period(cfg)
    assert cfg.num_layers % period == 0, (cfg.name, cfg.num_layers, period)
    n_periods = cfg.num_layers // period
    stack = ((n_periods, "periods"),)

    spec: dict = {"embedding": L.embedding_spec(cfg)}
    layers = {}
    for i in range(period):
        mixer, ffn = layer_kinds(cfg, i)
        l: dict = {}
        if mixer == "attn":
            l["ln_mix"] = _norm_spec(cfg, stack)
            l["attn"] = attn.attention_spec(cfg, tp, stack)
        else:
            l["ln_mix"] = _norm_spec(cfg, stack)
            l["ssm"] = ssm_mod.ssm_spec(cfg, stack)
        if ffn == "mlp":
            l["ln_ffn"] = _norm_spec(cfg, stack)
            l["mlp"] = L.make_mlp_spec(cfg, stack=stack)
        elif ffn == "moe":
            l["ln_ffn"] = _norm_spec(cfg, stack)
            l["moe"] = moe_mod.moe_spec(cfg, stack)
        layers[f"l{i}"] = l
    spec["layers"] = layers
    spec["final_norm"] = _norm_spec(cfg, ())
    spec.update(L.unembed_spec(cfg))
    return spec


def _remat(f, cfg: ModelConfig):
    if cfg.remat == "none":
        return f
    if cfg.remat == "dots":
        pol = jax.checkpoint_policies.dots_saveable
        return jax.checkpoint(f, policy=pol)
    return jax.checkpoint(f)   # "full": save nothing


def _embed_inputs(params, inputs, cfg: ModelConfig):
    """inputs: {"tokens": (B,Tt) i32, ["prefix": (B,P,d)]} → (B,T,d)."""
    x = L.embed_tokens(params["embedding"], inputs["tokens"], cfg)
    if "prefix" in inputs:   # vlm/audio stub frontend (DESIGN.md §3)
        x = jnp.concatenate([inputs["prefix"].astype(cfg.dtype), x], axis=1)
    return constrain(x, "batch", "null", "embed_act")


# activation sharding rules (logical names used only inside this module)
ACT_RULES = {"batch": "data", "embed_act": None, "null": None}


def _period_body_full(cfg: ModelConfig, tp: int, kernel: str = None):
    period = stack_period(cfg)

    def body(x, pparams):
        aux = jnp.zeros((), jnp.float32)
        for i in range(period):
            p = pparams[f"l{i}"]
            mixer, ffn = layer_kinds(cfg, i)
            h = L.rms_norm(x, p["ln_mix"], cfg.norm_eps)
            if mixer == "attn":
                x = x + attn.attend_full(p["attn"], h, cfg, tp, kernel=kernel)
            else:
                x = x + ssm_mod.ssm_apply(p["ssm"], h, cfg, kernel=kernel)
            if ffn is not None:
                h = L.rms_norm(x, p["ln_ffn"], cfg.norm_eps)
                if ffn == "moe":
                    y, a = moe_mod.moe_apply(p["moe"], h, cfg)
                    x, aux = x + y, aux + a
                else:
                    x = x + L.mlp_apply(p["mlp"], h, cfg)
            x = constrain(x, "batch", "null", "embed_act")
        return x, aux
    return body


def forward(params, inputs, cfg: ModelConfig, tp: int = 1,
            kernel: str = None):
    """Full-sequence forward. Returns (hidden (B,T,d), aux dict).
    ``kernel=None`` defers backend choice to the kernels.dispatch registry
    (platform default / env override / ``dispatch.using`` scope)."""
    x = _embed_inputs(params, inputs, cfg)
    body = _period_body_full(cfg, tp, kernel)
    body = _remat(body, cfg)

    def scan_fn(carry, pparams):
        x, aux = carry
        x, a = body(x, pparams)
        return (x, aux + a), None

    (x, aux), _ = jax.lax.scan(scan_fn, (x, jnp.zeros((), jnp.float32)),
                               params["layers"])
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    return x, {"moe_aux": aux}


def logits_from_hidden(params, x, cfg: ModelConfig):
    logits = L.unembed(params, params["embedding"], x, cfg)
    v = cfg.padded_vocab()
    if v != cfg.vocab_size:   # mask TP padding, keep the shard layout
        mask = jnp.arange(v) < cfg.vocab_size
        logits = jnp.where(mask, logits, -1e30)
    return logits


# -- caches -------------------------------------------------------------------

class Caches(NamedTuple):
    kv: Any      # dict l{i} -> KVCache with leading (n_periods,) OR None
    ssm: Any     # dict l{i} -> SSMCache with leading (n_periods,) OR None
    length: jax.Array


def init_caches(cfg: ModelConfig, tp: int, batch: int, max_len: int) -> Caches:
    period = stack_period(cfg)
    n_periods = cfg.num_layers // period
    kv, ssm = {}, {}
    for i in range(period):
        mixer, _ = layer_kinds(cfg, i)
        if mixer == "attn":
            kv[f"l{i}"] = attn.init_cache(cfg, tp, batch, max_len,
                                          stack_dims=(n_periods,))
        else:
            ssm[f"l{i}"] = ssm_mod.init_ssm_cache(cfg, batch,
                                                  stack_dims=(n_periods,))
    return Caches(kv, ssm, jnp.zeros((), jnp.int32))


def prefill(params, inputs, cfg: ModelConfig, tp: int = 1, max_len: int = 0,
            kernel: str = None):
    """Forward + cache build. Returns (hidden, caches)."""
    x = _embed_inputs(params, inputs, cfg)
    B, T, _ = x.shape
    max_len = max_len or T
    period = stack_period(cfg)

    def body(x, scanned):
        pparams, cin = scanned
        new_kv, new_ssm = {}, {}
        for i in range(period):
            p = pparams[f"l{i}"]
            mixer, ffn = layer_kinds(cfg, i)
            h = L.rms_norm(x, p["ln_mix"], cfg.norm_eps)
            if mixer == "attn":
                y, c = attn.attend_prefill(p["attn"], h, cfg, tp,
                                           cin[0][f"l{i}"], kernel=kernel)
                new_kv[f"l{i}"] = c
                x = x + y
            else:
                y, c = ssm_mod.ssm_apply(p["ssm"], h, cfg, kernel=kernel,
                                         return_cache=True)
                new_ssm[f"l{i}"] = c
                x = x + y
            if ffn is not None:
                h = L.rms_norm(x, p["ln_ffn"], cfg.norm_eps)
                if ffn == "moe":
                    y, _ = moe_mod.moe_apply(p["moe"], h, cfg)
                    x = x + y
                else:
                    x = x + L.mlp_apply(p["mlp"], h, cfg)
        return x, (new_kv, new_ssm)

    caches = init_caches(cfg, tp, B, max_len)

    def scan_fn(x, scanned):
        return body(x, scanned)

    x, (kv, ssm) = jax.lax.scan(scan_fn, x, (params["layers"],
                                             (caches.kv, caches.ssm)))
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    return x, Caches(kv, ssm, jnp.asarray(T, jnp.int32))


def decode(params, inputs, cfg: ModelConfig, caches: Caches, tp: int = 1,
           context_parallel: bool = False):
    """One-token step. inputs: {"tokens": (B, 1)}. Returns (hidden, caches)."""
    x = _embed_inputs(params, inputs, cfg)
    period = stack_period(cfg)

    def body(x, scanned):
        pparams, cin = scanned
        new_kv, new_ssm = {}, {}
        for i in range(period):
            p = pparams[f"l{i}"]
            mixer, ffn = layer_kinds(cfg, i)
            h = L.rms_norm(x, p["ln_mix"], cfg.norm_eps)
            if mixer == "attn":
                kvc = cin[0][f"l{i}"]._replace(length=caches.length)
                y, c = attn.attend_decode(p["attn"], h, cfg, tp, kvc,
                                          context_parallel=context_parallel)
                new_kv[f"l{i}"] = c._replace(length=jnp.zeros((), jnp.int32))
                x = x + y
            else:
                y, c = ssm_mod.ssm_decode(p["ssm"], h, cfg, cin[1][f"l{i}"])
                new_ssm[f"l{i}"] = c
                x = x + y
            if ffn is not None:
                h = L.rms_norm(x, p["ln_ffn"], cfg.norm_eps)
                if ffn == "moe":
                    y, _ = moe_mod.moe_apply(p["moe"], h, cfg)
                    x = x + y
                else:
                    x = x + L.mlp_apply(p["mlp"], h, cfg)
        return x, (new_kv, new_ssm)

    x, (kv, ssm) = jax.lax.scan(body, x, (params["layers"],
                                          (caches.kv, caches.ssm)))
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    return x, Caches(kv, ssm, caches.length + 1)
