from repro.models import params, layers, attention, moe, ssm, transformer, \
    policy, frontends
from repro.models.policy import OceanPolicy, BackbonePolicy
