"""Parameter system: a single source of truth for shapes, init, and sharding.

Modules declare nested dicts of ``ParamSpec`` (shape + logical axis names +
init). From one spec tree we derive:
  * materialized params (``init_params``),
  * abstract params for dry-runs (``abstract_params`` — ShapeDtypeStructs,
    no allocation),
  * mesh PartitionSpecs (``param_pspecs``) via logical→mesh axis rules.

Logical axes used across the framework:
  layers/periods — scan dim, never sharded
  embed          — d_model;     FSDP/ZeRO axis ("data")
  vocab/heads/kv_heads/mlp/expert/ssm_heads — tensor axis ("model")
  null           — never sharded

Rules map logical→mesh axes; a mesh axis is used at most once per param
(first logical axis wins — e.g. expert weights (expert, embed, mlp) shard
expert→model, embed→data, and mlp stays replicated).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


@dataclass(frozen=True)
class ParamSpec:
    shape: tuple
    axes: tuple                     # logical axis names, len == len(shape)
    init: str = "normal"            # normal | zeros | ones
    fan_in: Optional[int] = None    # for "normal": std = 1/sqrt(fan_in)
    dtype: Any = None               # None => use param_dtype

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


# default logical→mesh rules for the production mesh (data, model[, pod])
DEFAULT_RULES = {
    "embed": "data",        # FSDP / ZeRO-3
    "vocab": "model",
    "heads": "model",
    "kv_heads": "model",
    "mlp": "model",
    "expert": "model",
    "ssm_heads": "model",
    "layers": None,
    "periods": None,
    "null": None,
    # activation logical axes
    "batch": "data",
    "seq": None,
    "embed_act": None,
    "ctx": "data",          # context-parallel KV sequence dim (long_500k)
}


def is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def tree_map_specs(fn, spec_tree):
    return jax.tree.map(fn, spec_tree, is_leaf=is_spec)


def init_params(spec_tree, key, param_dtype=jnp.float32):
    leaves, treedef = jax.tree.flatten(spec_tree, is_leaf=is_spec)
    keys = jax.random.split(key, len(leaves))
    out = []
    for spec, k in zip(leaves, keys):
        dtype = spec.dtype or param_dtype
        if spec.init == "zeros":
            x = jnp.zeros(spec.shape, dtype)
        elif spec.init == "ones":
            x = jnp.ones(spec.shape, dtype)
        else:
            fan = spec.fan_in or (spec.shape[-2] if len(spec.shape) >= 2
                                  else spec.shape[-1])
            x = (jax.random.normal(k, spec.shape, jnp.float32)
                 / jnp.sqrt(float(fan))).astype(dtype)
        out.append(x)
    return jax.tree.unflatten(treedef, out)


def abstract_params(spec_tree, param_dtype=jnp.float32):
    """ShapeDtypeStruct tree — the dry-run path (no allocation)."""
    return tree_map_specs(
        lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype or param_dtype),
        spec_tree)


def make_pspec(axes: tuple, rules: dict) -> P:
    """Logical axes → PartitionSpec. Rule values may be a mesh axis name or a
    tuple of names (e.g. FSDP over ("pod", "data")); each mesh axis is used
    at most once per param."""
    used, parts = set(), []
    for a in axes:
        m = rules.get(a)
        if m is None:
            parts.append(None)
            continue
        if isinstance(m, (tuple, list)):
            avail = tuple(x for x in m if x not in used)
            if not avail:
                parts.append(None)
                continue
            used.update(avail)
            parts.append(avail if len(avail) > 1 else avail[0])
        elif m in used:
            parts.append(None)
        else:
            parts.append(m)
            used.add(m)
    return P(*parts)


def param_pspecs(spec_tree, rules: dict = None):
    rules = DEFAULT_RULES if rules is None else rules
    return tree_map_specs(lambda s: make_pspec(s.axes, rules), spec_tree)


def param_count(spec_tree) -> int:
    import numpy as np
    leaves = jax.tree.leaves(spec_tree, is_leaf=is_spec)
    return int(sum(int(np.prod(s.shape, dtype=np.int64)) for s in leaves))


# Weight layout at USE time: tensor axes stay sharded, the FSDP ("embed")
# axis is gathered. Annotating every weight use with this makes GSPMD insert
# the per-layer FSDP all-gather of the (small) weight instead of choosing to
# gather the (huge) batch activations — the ZeRO-3 compute pattern.
USE_RULES = {"vocab": "model", "heads": "model", "kv_heads": "model",
             "mlp": "model", "expert": "model", "ssm_heads": "model"}

# FSDP axes of the active mesh (set by the launcher; ("data",) or
# ("pod", "data")). Used for the *storage/gradient* layout in use_weight's
# backward rule.
_FSDP_AXES = ("data",)


def set_fsdp_axes(axes) -> None:
    global _FSDP_AXES
    _FSDP_AXES = tuple(axes)


def _wsc(x, spec):
    try:
        return jax.lax.with_sharding_constraint(x, spec)
    except (ValueError, RuntimeError):
        return x


def use_weight(w, axes: tuple):
    """Constrain a weight at its einsum use site.

    Forward: FSDP axis gathered (compute layout). Backward: the weight
    gradient is constrained to the *storage* layout (FSDP-sharded), which
    makes GSPMD lower dW as local-partial + reduce-scatter instead of
    all-gathering the batch activations (a ~300× collective-bytes difference
    measured on qwen3 train_4k — EXPERIMENTS.md §Perf)."""
    axes = tuple(axes)
    use_spec = make_pspec(axes, USE_RULES)
    storage_rules = dict(DEFAULT_RULES)
    storage_rules["embed"] = _FSDP_AXES
    storage_spec = make_pspec(axes, storage_rules)

    @jax.custom_vjp
    def f(w):
        return _wsc(w, use_spec)

    def fwd(w):
        return _wsc(w, use_spec), None

    def bwd(_, g):
        return (_wsc(g, storage_spec),)

    f.defvjp(fwd, bwd)
    return f(w)


def weight(params: dict, name: str, axes: tuple, dtype=None):
    """Fetch a weight at its use site: FSDP-gather constraint + optional
    int8 dequantization (serving: ``<name>_scale`` present ⇒ the int8 tensor
    is gathered/read at 1 byte/elem, then dequantized per output channel —
    halves the dominant collective+memory terms of weight-gathered decode,
    EXPERIMENTS.md §Perf)."""
    w = use_weight(params[name], axes)
    scale = params.get(name + "_scale")
    if scale is not None:
        # barrier + post-dequant constraint pin the FSDP all-gather on the
        # int8 value (1 byte/elem); otherwise XLA sinks the dequant below
        # the gather and moves bf16/f32 over the wire
        w = jax.lax.optimization_barrier(w)
        dt = dtype or jnp.bfloat16
        # the dequantized weight is never materialized on the TPU target —
        # kernels/quant_matmul fuses dequant into the MXU feed; the scope
        # tells the dry-run analyzer to treat it as VMEM-resident
        with jax.named_scope("KERNEL_qmm"):
            w = _wsc(w.astype(dt) * scale.astype(dt),
                     make_pspec(tuple(axes), USE_RULES))
    elif dtype is not None:
        w = w.astype(dtype)
    return w


def quantize_spec(spec_tree, qdtype=jnp.int8):
    """Transform a ParamSpec tree for int8/int4 serving: every >=2D matmul
    weight becomes qdtype with a per-output-channel f32 scale."""
    def walk(d):
        if isinstance(d, ParamSpec):
            return d
        out = {}
        for k, v in d.items():
            if isinstance(v, ParamSpec):
                quantizable = (len(v.shape) >= 2 and v.init == "normal"
                               and v.dtype is None)
                out[k] = dataclasses.replace(v, dtype=qdtype) \
                    if quantizable else v
                if quantizable:
                    # keep scan-stack dims so per-period slicing still works
                    nstack = sum(1 for a in v.axes
                                 if a in ("periods", "layers"))
                    out[k + "_scale"] = ParamSpec(
                        v.shape[:nstack] + v.shape[-1:],
                        v.axes[:nstack] + (v.axes[-1],),
                        init="ones", dtype=jnp.float32)
            else:
                out[k] = walk(v)
        return out
    return walk(spec_tree)


def quantize_params(params, spec_tree, qdtype=jnp.int8):
    """Materialize int8/int4 params from bf16/f32 ones (symmetric, per
    output channel over the last dim)."""
    qspec = quantize_spec(spec_tree, qdtype)
    qmax = 7.0 if qdtype == jnp.int4 else 127.0

    def walk(p, d):
        out = {}
        for k, v in d.items():
            if k.endswith("_scale") and k[:-6] in d:
                continue
            if isinstance(v, ParamSpec):
                if (k + "_scale") in d:
                    nstack = sum(1 for a in v.axes
                                 if a in ("periods", "layers"))
                    w = p[k].astype(jnp.float32)
                    red = tuple(range(nstack, w.ndim - 1))
                    s = jnp.max(jnp.abs(w), axis=red) / qmax + 1e-12
                    sb = jnp.expand_dims(s, red)
                    out[k] = jnp.clip(jnp.round(w / sb), -qmax, qmax
                                      ).astype(qdtype)
                    out[k + "_scale"] = s.astype(jnp.float32)
                else:
                    out[k] = p[k]
            else:
                out[k] = walk(p[k], v)
        return out
    return walk(params, qspec)


def constrain(x, *logical_axes, rules: dict = None):
    """with_sharding_constraint via logical axes (no-op outside a mesh)."""
    rules = DEFAULT_RULES if rules is None else rules
    try:
        return jax.lax.with_sharding_constraint(
            x, make_pspec(tuple(logical_axes), rules))
    except (ValueError, RuntimeError):
        return x   # no mesh in scope (tests / single device)
