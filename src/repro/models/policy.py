"""Policies in the paper's §3.4 model format: ``encode → recurrent → decode``.

The forward pass is split so a recurrent cell can be sandwiched between the
computation of hidden state and actions *per experiment*, without writing two
models. ``OceanPolicy`` uses an MLP encoder with an optional LSTM cell;
``BackbonePolicy`` wraps any assigned LM architecture — there the "recurrent
cell" is the KV/SSM cache used by serve_step, flowing through the same
interface.

Both emit flat MultiDiscrete logits (one concatenated vector, static segment
sizes) and a value estimate — exactly what an Atari-shaped learner expects,
which is the emulation thesis end-to-end.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.params import ParamSpec, init_params, abstract_params, \
    param_pspecs
from repro.models import transformer as tr
from repro.models.layers import rms_norm


# -- LSTM cell ----------------------------------------------------------------

def lstm_spec(in_dim: int, hidden: int):
    return {
        "wi": ParamSpec((in_dim, 4 * hidden), ("null", "null"), fan_in=in_dim),
        "wh": ParamSpec((hidden, 4 * hidden), ("null", "null"), fan_in=hidden),
        "b": ParamSpec((4 * hidden,), ("null",), init="zeros"),
    }


def lstm_step(params, x, carry):
    c, h = carry
    gates = x @ params["wi"] + h @ params["wh"] + params["b"]
    i, f, g, o = jnp.split(gates, 4, axis=-1)
    c = jax.nn.sigmoid(f + 1.0) * c + jax.nn.sigmoid(i) * jnp.tanh(g)
    h = jax.nn.sigmoid(o) * jnp.tanh(c)
    return h, (c, h)


# -- Ocean policy ---------------------------------------------------------------

class OceanPolicy:
    """MLP encoder (+ optional LSTM) + multidiscrete/value heads. The default
    architecture of the paper's model zoo: "an MLP sized to the flat
    observation and action spaces".

    ``conv_shape=(H, W)`` enables the CNN frontend for pixel-grid envs: the
    flat emulated observation is restored to its 2D layout (the paper's
    "unemulate in the first line of the forward pass") and passed through a
    small conv layer before the MLP. Requires ``obs_dim == H * W``."""

    CONV_FILTERS = 8

    def __init__(self, obs_dim: int, nvec: tuple = (), hidden: int = 128,
                 recurrent: bool = False, num_outputs: int = 0,
                 conv_shape: Optional[tuple] = None):
        self.obs_dim, self.nvec, self.hidden = obs_dim, tuple(nvec), hidden
        self.recurrent = recurrent
        self.conv_shape = tuple(conv_shape) if conv_shape else None
        if self.conv_shape:
            H, W = self.conv_shape
            assert H * W == obs_dim, (self.conv_shape, obs_dim)
        # num_outputs overrides for continuous heads (mean ++ log_std)
        self.num_actions = num_outputs or sum(self.nvec)

    @property
    def enc_in(self) -> int:
        if self.conv_shape:
            return self.obs_dim * self.CONV_FILTERS
        return self.obs_dim

    def spec(self):
        h = self.hidden
        s = {
            "enc1": ParamSpec((self.enc_in, h), ("null", "null"),
                              fan_in=self.enc_in),
            "b1": ParamSpec((h,), ("null",), init="zeros"),
            "enc2": ParamSpec((h, h), ("null", "null"), fan_in=h),
            "b2": ParamSpec((h,), ("null",), init="zeros"),
            "act": ParamSpec((h, self.num_actions), ("null", "null"),
                             fan_in=h),
            "b_act": ParamSpec((self.num_actions,), ("null",), init="zeros"),
            "val": ParamSpec((h, 1), ("null", "null"), fan_in=h),
            "b_val": ParamSpec((1,), ("null",), init="zeros"),
        }
        if self.recurrent:
            s["lstm"] = lstm_spec(h, h)
        if self.conv_shape:
            s["conv"] = ParamSpec((3, 3, 1, self.CONV_FILTERS),
                                  ("null", "null", "null", "null"), fan_in=9)
            s["b_conv"] = ParamSpec((self.CONV_FILTERS,), ("null",),
                                    init="zeros")
        return s

    def init(self, key, dtype=jnp.float32):
        return init_params(self.spec(), key, dtype)

    def abstract(self, dtype=jnp.float32):
        """ShapeDtypeStruct tree of the params — the ``like`` template for
        checkpoint/PolicyStore restores (no allocation, any mesh)."""
        return abstract_params(self.spec(), dtype)

    def initial_carry(self, batch: int):
        if not self.recurrent:
            return None
        # two distinct buffers: the engine donates the whole carry to its
        # fused launch, and XLA rejects donating one buffer twice
        return (jnp.zeros((batch, self.hidden), jnp.float32),
                jnp.zeros((batch, self.hidden), jnp.float32))

    # paper §3.4 split ---------------------------------------------------------
    def _conv_frontend(self, params, obs):
        """(…, H*W) flat obs → (…, H*W*filters): restore the 2D pixel layout
        and run one SAME-padded 3×3 conv. Handles any leading batch dims
        ((B, obs) in step, (T, B, obs) in the non-recurrent seq path)."""
        H, W = self.conv_shape
        lead = obs.shape[:-1]
        x = obs.reshape((-1, H, W, 1))
        x = jax.lax.conv_general_dilated(
            x, params["conv"], window_strides=(1, 1), padding="SAME",
            dimension_numbers=("NHWC", "HWIO", "NHWC"))
        x = jnp.tanh(x + params["b_conv"])
        return x.reshape(lead + (H * W * self.CONV_FILTERS,))

    def encode(self, params, obs):
        if self.conv_shape:
            obs = self._conv_frontend(params, obs)
        h = jnp.tanh(obs @ params["enc1"] + params["b1"])
        return jnp.tanh(h @ params["enc2"] + params["b2"])

    def recurrent_cell(self, params, h, carry, reset=None):
        if not self.recurrent:
            return h, None
        if reset is not None:
            m = 1.0 - reset.astype(jnp.float32)[:, None]
            carry = (carry[0] * m, carry[1] * m)
        return lstm_step(params["lstm"], h, carry)

    def decode(self, params, h):
        logits = h @ params["act"] + params["b_act"]
        value = (h @ params["val"] + params["b_val"])[..., 0]
        return logits, value

    # ---------------------------------------------------------------------------
    def step(self, params, obs, carry, reset=None):
        h = self.encode(params, obs)
        h, carry = self.recurrent_cell(params, h, carry, reset)
        logits, value = self.decode(params, h)
        return logits, value, carry

    def seq(self, params, obs_seq, carry, resets):
        """obs_seq: (T, B, obs); resets: (T, B). Scan the cell over time,
        resetting carry at episode starts (the LSTM-state bug the paper calls
        out is exactly mishandling this)."""
        if not self.recurrent:
            h = self.encode(params, obs_seq)
            logits, value = self.decode(params, h)
            return logits, value, carry

        def f(c, inp):
            obs, reset = inp
            h = self.encode(params, obs)
            h, c = self.recurrent_cell(params, h, c, reset)
            logits, value = self.decode(params, h)
            return c, (logits, value)

        carry, (logits, value) = jax.lax.scan(f, carry, (obs_seq, resets))
        return logits, value, carry


# -- LM backbone policy ---------------------------------------------------------

class BackbonePolicy:
    """Any assigned architecture as a token-level policy: actions are
    next-token choices, the critic reads the same final hidden state."""

    def __init__(self, cfg: ModelConfig, tp: int = 1, kernel: str = None,
                 quantize: bool = False):
        # kernel=None → backend per kernels.dispatch (platform/env/scope);
        # an explicit name ("ref", "chunked", "interpret", "pallas") wins
        self.cfg, self.tp, self.kernel = cfg, tp, kernel
        self.quantize = quantize     # int8 weights (serving path)
        self.nvec = (cfg.vocab_size,)

    def spec(self):
        s = {"backbone": tr.transformer_spec(self.cfg, self.tp)}
        if self.cfg.value_head:
            s["value"] = ParamSpec((self.cfg.d_model, 1), ("embed", "null"),
                                   fan_in=self.cfg.d_model)
        if self.quantize:
            import jax.numpy as _jnp
            from repro.models.params import quantize_spec
            qd = _jnp.int4 if self.quantize == "int4" else _jnp.int8
            s = quantize_spec(s, qd)
        return s

    def init(self, key, dtype=None):
        dtype = dtype or self.cfg.param_dtype
        return init_params(self.spec(), key, jnp.dtype(dtype))

    def abstract(self, dtype=None):
        dtype = dtype or self.cfg.param_dtype
        return abstract_params(self.spec(), jnp.dtype(dtype))

    def pspecs(self, rules=None):
        return param_pspecs(self.spec(), rules)

    def _value(self, params, hidden):
        if not self.cfg.value_head:
            return jnp.zeros(hidden.shape[:-1], jnp.float32)
        # dot in hidden.dtype, upcast after — an f32 dot here would promote
        # the backward scan carry to f32 (see moe.moe_apply router note)
        v = jnp.einsum("...d,dv->...v",
                       hidden, params["value"].astype(hidden.dtype))
        return v[..., 0].astype(jnp.float32)

    def seq(self, params, inputs):
        """Training forward. inputs: {"tokens": (B,T)[, "prefix": (B,P,d)]}.
        Returns (logits (B,T',V), values (B,T'), aux)."""
        hidden, aux = tr.forward(params["backbone"], inputs, self.cfg,
                                 self.tp, kernel=self.kernel)
        logits = tr.logits_from_hidden(params["backbone"], hidden, self.cfg)
        return logits, self._value(params, hidden), aux

    def prefill(self, params, inputs, max_len: int):
        hidden, caches = tr.prefill(params["backbone"], inputs, self.cfg,
                                    self.tp, max_len=max_len,
                                    kernel=self.kernel)
        last = hidden[:, -1:]
        logits = tr.logits_from_hidden(params["backbone"], last, self.cfg)
        return logits[:, 0], self._value(params, last)[:, 0], caches

    def decode(self, params, tokens, caches, context_parallel: bool = False):
        """tokens: (B, 1) — one serve_step."""
        hidden, caches = tr.decode(params["backbone"], {"tokens": tokens},
                                   self.cfg, caches, self.tp,
                                   context_parallel=context_parallel)
        logits = tr.logits_from_hidden(params["backbone"], hidden, self.cfg)
        return logits[:, 0], self._value(params, hidden)[:, 0], caches

    def init_caches(self, batch: int, max_len: int):
        return tr.init_caches(self.cfg, self.tp, batch, max_len)
