"""Grouped-query attention with KV cache, rope, qk_norm, TP padding.

Head counts are padded to the tensor-parallel size (DESIGN.md §3): padded
query heads are zero-initialized and their outputs are annihilated by the
zero rows of ``wo``; KV heads are replicated so every shard owns whole heads.

Three entry points:
  * ``attend_full``  — training / prefill over a whole sequence (flash kernel
    on TPU, jnp reference elsewhere).
  * ``attend_decode`` — one new token against a KV cache (context-parallel
    capable: for long_500k the cache's sequence dim is sharded over "data"
    and GSPMD all-reduces the softmax statistics).

Kernel selection goes through the kernels.dispatch registry; ``kernel=None``
(the default) lets the registry pick per platform / env override /
``dispatch.using(...)`` scope. Passing an explicit name still wins.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.params import ParamSpec, constrain, use_weight, weight
from repro.models.layers import apply_rope, rms_norm, rms_norm_spec

from repro.kernels import ops as kops


def attention_spec(cfg: ModelConfig, tp: int, stack: tuple = ()):
    H, K, hd, d = (cfg.padded_heads(tp), cfg.padded_kv_heads(tp),
                   cfg.head_dim, cfg.d_model)
    sizes = tuple(s for s, _ in stack)
    names = tuple(n for _, n in stack)
    spec = {
        "wq": ParamSpec(sizes + (d, H, hd), names + ("embed", "heads", "null"),
                        fan_in=d),
        "wk": ParamSpec(sizes + (d, K, hd), names + ("embed", "kv_heads", "null"),
                        fan_in=d),
        "wv": ParamSpec(sizes + (d, K, hd), names + ("embed", "kv_heads", "null"),
                        fan_in=d),
        "wo": ParamSpec(sizes + (H, hd, d), names + ("heads", "null", "embed"),
                        fan_in=H * hd),
    }
    if cfg.qk_norm:
        spec["q_norm"] = ParamSpec(sizes + (hd,), names + ("null",),
                                   init="zeros", dtype=jnp.float32)
        spec["k_norm"] = ParamSpec(sizes + (hd,), names + ("null",),
                                   init="zeros", dtype=jnp.float32)
    return spec


class KVCache(NamedTuple):
    k: jax.Array          # (B, S_max, K, hd)
    v: jax.Array          # (B, S_max, K, hd)
    length: jax.Array     # () int32 — filled prefix


def init_cache(cfg: ModelConfig, tp: int, batch: int, max_len: int,
               dtype=None, stack_dims: tuple = ()) -> KVCache:
    K, hd = cfg.padded_kv_heads(tp), cfg.head_dim
    shape = stack_dims + (batch, max_len, K, hd)
    dtype = dtype or cfg.dtype
    return KVCache(jnp.zeros(shape, dtype), jnp.zeros(shape, dtype),
                   jnp.zeros(stack_dims, jnp.int32))


def _project_qkv(params, x, cfg: ModelConfig, positions):
    dt = cfg.dtype
    wq = weight(params, "wq", ("embed", "heads", "null"))
    wk = weight(params, "wk", ("embed", "kv_heads", "null"))
    wv = weight(params, "wv", ("embed", "kv_heads", "null"))
    q = jnp.einsum("btd,dhk->bthk", x, wq.astype(dt))
    k = jnp.einsum("btd,dhk->bthk", x, wk.astype(dt))
    v = jnp.einsum("btd,dhk->bthk", x, wv.astype(dt))
    if cfg.qk_norm:
        q = rms_norm(q, params["q_norm"], cfg.norm_eps)
        k = rms_norm(k, params["k_norm"], cfg.norm_eps)
    if cfg.use_rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def attend_full(params, x, cfg: ModelConfig, tp: int,
                positions=None, kernel: str = None):
    """Causal self-attention over a full sequence. x: (B, T, d)."""
    B, T, _ = x.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32), (B, T))
    q, k, v = _project_qkv(params, x, cfg, positions)
    q = constrain(q, "batch", "null", "heads", "null")
    k = constrain(k, "batch", "null", "kv_heads", "null")
    out = kops.flash_attention(q, k, v, causal=True, mode=kernel)
    out = constrain(out, "batch", "null", "heads", "null")
    wo = weight(params, "wo", ("heads", "null", "embed"))
    return jnp.einsum("bthk,hkd->btd", out, wo.astype(cfg.dtype))


def attend_prefill(params, x, cfg: ModelConfig, tp: int, cache: KVCache,
                   kernel: str = None):
    """Full-sequence attention that also fills the KV cache."""
    B, T, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32), (B, T))
    q, k, v = _project_qkv(params, x, cfg, positions)
    out = kops.flash_attention(q, k, v, causal=True, mode=kernel)
    newk = jax.lax.dynamic_update_slice_in_dim(cache.k, k, 0, axis=1)
    newv = jax.lax.dynamic_update_slice_in_dim(cache.v, v, 0, axis=1)
    cache = KVCache(newk, newv, jnp.asarray(T, jnp.int32))
    wo = weight(params, "wo", ("heads", "null", "embed"))
    y = jnp.einsum("bthk,hkd->btd", out, wo.astype(cfg.dtype))
    return y, cache


def attend_decode(params, x, cfg: ModelConfig, tp: int, cache: KVCache,
                  context_parallel: bool = False):
    """One-token decode. x: (B, 1, d); cache holds ``cache.length`` tokens.

    The cache is updated in place at position ``length``. When
    ``context_parallel`` (long_500k), the cache seq dim is sharded over
    "data"; the softmax reduction over the sharded axis becomes a GSPMD
    all-reduce of (num, denom) — flash-decode's two-pass trick, done by the
    partitioner.
    """
    B, one, d = x.shape
    assert one == 1
    pos = jnp.broadcast_to(cache.length[None], (B, 1)).astype(jnp.int32)
    q, k_new, v_new = _project_qkv(params, x, cfg, pos)
    k = jax.lax.dynamic_update_slice_in_dim(cache.k, k_new, cache.length, axis=1)
    v = jax.lax.dynamic_update_slice_in_dim(cache.v, v_new, cache.length, axis=1)
    if context_parallel:
        k = constrain(k, "null", "ctx", "kv_heads", "null")   # seq -> data (B=1)
        v = constrain(v, "null", "ctx", "kv_heads", "null")
    # flash-decode kernel (Pallas on TPU; scoped jnp oracle elsewhere)
    out = kops.flash_decode(q[:, 0], k, v, cache.length)      # (B, H, hd)
    out = out[:, None].astype(cfg.dtype)                      # (B, 1, H, hd)
    wo = weight(params, "wo", ("heads", "null", "embed"))
    y = jnp.einsum("bthk,hkd->btd", out, wo.astype(cfg.dtype))
    return y, KVCache(k, v, cache.length + 1)
