"""Perf-regression sentinel: bench history recording + drift detection.

Every ``benchmarks/bench_*.py`` appends one schema-versioned JSON line to
``BENCH_history.jsonl`` (shared across all benches) via :func:`record`:

    {"schema": 1, "bench": "telemetry", "ts": "2026-08-08T…Z",
     "fingerprint": {"cores": 8, "python": "3.11", "platform": "Linux-x86_64"},
     "cells": {"jit_enabled_sps": 51234.0, ...},
     "acceptance": {"overhead_lt_10pct": true, ...},
     "meta": {...}}

``python -m repro.telemetry compare`` then pits the newest record of each
bench against a rolling baseline (median of up to ``window`` prior records
with the SAME machine fingerprint) and flags any cell whose value dropped
by more than the noise band. Fingerprints gate comparison because an SPS
number from a 4-core CI runner says nothing about a 64-core dev box — a
mismatch means "no baseline yet", never a regression.

Report-only by default; ``--gate`` turns confirmed regressions into a
non-zero exit for CI lanes that want to block.

Cells are flat ``{name: value}`` dicts where bigger is better (SPS,
speedups, calls/s). Benches that measure wall time should record the
derived rate, not the seconds.
"""
from __future__ import annotations

import datetime
import json
import os
import platform
import sys
from typing import Dict, List, Optional

__all__ = [
    "SCHEMA", "HISTORY_FILE", "fingerprint", "record", "load_history",
    "compare", "format_report",
]

SCHEMA = 1
HISTORY_FILE = "BENCH_history.jsonl"
# relative drop beyond this fraction of baseline counts as a regression
DEFAULT_NOISE = 0.10
# rolling baseline = median of up to this many prior same-fingerprint records
DEFAULT_WINDOW = 5


def fingerprint() -> Dict[str, object]:
    """What must match for two bench records to be comparable. Coarse on
    purpose: cores + python minor + platform — not CPU model or load."""
    return {
        "cores": os.cpu_count() or 1,
        "python": f"{sys.version_info.major}.{sys.version_info.minor}",
        "platform": f"{platform.system()}-{platform.machine()}",
    }


def history_path(history: Optional[str] = None) -> str:
    """Default history file lives next to the BENCH_*.json results, i.e.
    the repo root (cwd of ``python benchmarks/bench_*.py`` runs)."""
    return history or HISTORY_FILE


def record(bench: str, cells: Dict[str, float], *,
           acceptance: Optional[Dict[str, bool]] = None,
           meta: Optional[dict] = None,
           history: Optional[str] = None) -> dict:
    """Append one bench run to the shared history file and return the
    record. Never raises on IO problems (a read-only checkout must not
    fail the bench) — returns the record either way."""
    rec = {
        "schema": SCHEMA,
        "bench": bench,
        "ts": datetime.datetime.now(datetime.timezone.utc).isoformat(),
        "fingerprint": fingerprint(),
        "cells": {k: float(v) for k, v in cells.items()
                  if isinstance(v, (int, float))},
        "acceptance": dict(acceptance or {}),
        "meta": dict(meta or {}),
    }
    path = history_path(history)
    try:
        with open(path, "a") as f:
            f.write(json.dumps(rec) + "\n")
    except OSError as e:
        print(f"[benchwatch] could not append to {path}: {e}", file=sys.stderr)
    return rec


def load_history(history: Optional[str] = None) -> List[dict]:
    """All parseable records, file order (oldest first). Torn tails and
    foreign-schema lines are skipped, not fatal."""
    path = history_path(history)
    records: List[dict] = []
    if not os.path.exists(path):
        return records
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            try:
                rec = json.loads(line)
            except json.JSONDecodeError:
                continue
            if isinstance(rec, dict) and rec.get("schema") == SCHEMA \
                    and "bench" in rec and isinstance(rec.get("cells"), dict):
                records.append(rec)
    return records


def _median(xs: List[float]) -> float:
    s = sorted(xs)
    n = len(s)
    mid = n // 2
    if n % 2:
        return float(s[mid])
    return float(s[mid - 1] + s[mid]) / 2.0


def compare(history: Optional[str] = None, *, noise: float = DEFAULT_NOISE,
            window: int = DEFAULT_WINDOW) -> dict:
    """Newest record of each bench vs. its rolling same-fingerprint
    baseline.

    Returns ``{"benches": {name: {"status", "cells", ...}},
    "regressions": [...]}`` where status is one of:

      * ``"ok"``           — every cell within the noise band (or improved)
      * ``"regression"``   — ≥1 cell dropped more than ``noise`` vs baseline
      * ``"no_baseline"``  — no prior record with a matching fingerprint
        (first run on this machine, or the machine changed) — never gates
    """
    records = load_history(history)
    by_bench: Dict[str, List[dict]] = {}
    for rec in records:
        by_bench.setdefault(rec["bench"], []).append(rec)

    out = {"benches": {}, "regressions": []}
    for bench, recs in by_bench.items():
        newest = recs[-1]
        fp = newest.get("fingerprint")
        prior = [r for r in recs[:-1] if r.get("fingerprint") == fp]
        prior = prior[-window:]
        if not prior:
            out["benches"][bench] = {
                "status": "no_baseline", "runs": len(recs),
                "fingerprint": fp, "cells": {}}
            continue
        cells = {}
        status = "ok"
        for name, value in newest["cells"].items():
            base_vals = [r["cells"][name] for r in prior
                         if isinstance(r["cells"].get(name), (int, float))]
            if not base_vals:
                cells[name] = {"value": value, "baseline": None,
                               "delta_pct": None, "status": "new_cell"}
                continue
            baseline = _median(base_vals)
            if baseline > 0:
                delta = (value - baseline) / baseline
            else:
                delta = 0.0
            cell_status = "ok"
            if delta < -noise:
                cell_status = "regression"
                status = "regression"
                out["regressions"].append(
                    {"bench": bench, "cell": name, "value": value,
                     "baseline": baseline, "delta_pct": round(delta * 100, 2)})
            cells[name] = {"value": value, "baseline": baseline,
                           "delta_pct": round(delta * 100, 2),
                           "status": cell_status}
        out["benches"][bench] = {
            "status": status, "runs": len(recs),
            "baseline_runs": len(prior), "fingerprint": fp, "cells": cells}
    return out


def format_report(result: dict) -> str:
    lines = ["bench history comparison", "=" * 40]
    for bench in sorted(result["benches"]):
        info = result["benches"][bench]
        lines.append(f"{bench}: {info['status']} "
                     f"({info['runs']} run(s) on record)")
        for name, cell in sorted(info.get("cells", {}).items()):
            if cell["baseline"] is None:
                lines.append(f"  {name}: {cell['value']:.4g} (new cell)")
            else:
                mark = " <-- REGRESSION" if cell["status"] == "regression" \
                    else ""
                lines.append(
                    f"  {name}: {cell['value']:.4g} vs baseline "
                    f"{cell['baseline']:.4g} ({cell['delta_pct']:+.1f}%)"
                    f"{mark}")
    n = len(result["regressions"])
    lines.append("-" * 40)
    lines.append(f"{n} regression(s) beyond the noise band"
                 if n else "no regressions beyond the noise band")
    return "\n".join(lines)
