"""Metrics registry: counters / gauges / histograms with labels.

The registry complements — never replaces — the JSONL ``MetricsLogger``
stream: training metrics (loss, sps, ...) keep flowing through the engine's
per-update records, while the registry holds *operational* counters (dropped
fragments, reshards, checkpoint writes, seqlock retries) that accumulate
across the run and export in one shot.

Concurrency model: instrument handles are cached per ``(name, labels)`` so
hot loops pay one dict lookup once and then plain attribute arithmetic.
Counter/gauge updates are single bytecode-level float ops under the GIL —
racing increments can in principle interleave, which is acceptable for
telemetry (we trade perfect counts for a lock-free hot path); the registry
lock only guards instrument *creation* and ``snapshot()``.

Exports: ``snapshot()`` (plain dict), ``to_prometheus()`` (text exposition
format), and ``emit(logger, step)`` which appends one flattened record to an
existing ``MetricsLogger`` stream.

jax-free: stdlib only (spawn workers may import this chain).
"""
from __future__ import annotations

import threading
from bisect import bisect_right
from typing import Dict, Iterable, Tuple

__all__ = ["Counter", "Gauge", "Histogram", "Registry", "registry",
           "DEFAULT_BUCKETS_MS"]

# generic latency buckets (ms) — callers with known scales pass their own
DEFAULT_BUCKETS_MS = (1.0, 2.0, 5.0, 10.0, 25.0, 50.0, 100.0, 250.0,
                      1000.0, 5000.0)


class Counter:
    """Monotonically increasing value. ``inc`` only."""
    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def inc(self, n: float = 1.0) -> None:
        self.value += n


class Gauge:
    """Last-write-wins value."""
    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)

    def inc(self, n: float = 1.0) -> None:
        self.value += n

    def dec(self, n: float = 1.0) -> None:
        self.value -= n


class Histogram:
    """Fixed-bucket histogram: ``observe(v)`` bisects into ``edges`` (bucket
    ``i`` counts ``v <= edges[i]``; the last bucket is +Inf overflow)."""
    __slots__ = ("edges", "counts", "sum", "count")

    def __init__(self, edges: Iterable[float] = DEFAULT_BUCKETS_MS):
        self.edges = tuple(float(e) for e in edges)
        self.counts = [0] * (len(self.edges) + 1)
        self.sum = 0.0
        self.count = 0

    def observe(self, v: float) -> None:
        self.counts[bisect_right(self.edges, v)] += 1
        self.sum += v
        self.count += 1

    def quantile(self, q: float) -> float:
        """Bucket-resolution quantile (upper edge of the q-th bucket)."""
        if self.count == 0:
            return 0.0
        target = q * self.count
        acc = 0
        for i, c in enumerate(self.counts):
            acc += c
            if acc >= target and c:
                return self.edges[i] if i < len(self.edges) else float("inf")
        return float("inf")


_Key = Tuple[str, str, Tuple[Tuple[str, str], ...]]


def _label_key(labels: dict) -> Tuple[Tuple[str, str], ...]:
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


def _flat_name(name: str, labels: Tuple[Tuple[str, str], ...]) -> str:
    if not labels:
        return name
    inner = ",".join(f"{k}={v}" for k, v in labels)
    return f"{name}{{{inner}}}"


class Registry:
    """Get-or-create instrument store. Hold the returned handle in hot loops
    — the lookup is cheap but not free."""

    def __init__(self):
        self._lock = threading.Lock()
        self._metrics: Dict[_Key, object] = {}

    def _get(self, kind: str, name: str, labels: dict, factory):
        key = (kind, name, _label_key(labels))
        m = self._metrics.get(key)
        if m is None:
            with self._lock:
                m = self._metrics.setdefault(key, factory())
        return m

    def counter(self, name: str, **labels) -> Counter:
        return self._get("counter", name, labels, Counter)

    def gauge(self, name: str, **labels) -> Gauge:
        return self._get("gauge", name, labels, Gauge)

    def histogram(self, name: str, edges: Iterable[float] = DEFAULT_BUCKETS_MS,
                  **labels) -> Histogram:
        return self._get("histogram", name, labels,
                         lambda: Histogram(edges))

    def reset(self) -> None:
        with self._lock:
            self._metrics.clear()

    # -- exports -----------------------------------------------------------
    def snapshot(self) -> dict:
        """Plain-dict view: {"counters": {...}, "gauges": {...},
        "histograms": {flat_name: {edges, counts, sum, count}}}."""
        with self._lock:
            items = list(self._metrics.items())
        out = {"counters": {}, "gauges": {}, "histograms": {}}
        for (kind, name, labels), m in items:
            flat = _flat_name(name, labels)
            if kind == "counter":
                out["counters"][flat] = m.value
            elif kind == "gauge":
                out["gauges"][flat] = m.value
            else:
                out["histograms"][flat] = {
                    "edges": list(m.edges), "counts": list(m.counts),
                    "sum": m.sum, "count": m.count,
                }
        return out

    def flat(self, prefix: str = "") -> dict:
        """Scalars-only flattening (histograms become _count/_sum/_p50/_p99)
        — the shape ``MetricsLogger`` can serialize."""
        snap = self.snapshot()
        out = {}
        for k, v in snap["counters"].items():
            out[prefix + k] = v
        for k, v in snap["gauges"].items():
            out[prefix + k] = v
        for k, h in snap["histograms"].items():
            hist = Histogram(h["edges"])
            hist.counts, hist.sum, hist.count = \
                list(h["counts"]), h["sum"], h["count"]
            out[prefix + k + "_count"] = h["count"]
            out[prefix + k + "_sum"] = h["sum"]
            out[prefix + k + "_p50"] = hist.quantile(0.50)
            out[prefix + k + "_p99"] = hist.quantile(0.99)
        return out

    def emit(self, logger, step: int, prefix: str = "telemetry.") -> None:
        """Append one flattened registry record to an existing
        ``utils.metrics.MetricsLogger`` stream (same JSONL file, extra keys
        namespaced under ``prefix``)."""
        flat = self.flat(prefix)
        if flat:
            logger.log(step, flat)

    def to_prometheus(self) -> str:
        """Prometheus text exposition (metric names sanitized to
        ``[a-zA-Z0-9_]``, labels preserved)."""
        snap = self.snapshot()
        lines = []

        def _san(name: str) -> str:
            return "".join(c if c.isalnum() or c == "_" else "_"
                           for c in name)

        def _split(flat: str):
            """Flat name -> (sanitized base, quoted-label block): the
            exposition format requires ``k="v"``, not the registry's
            bare ``k=v``."""
            if "{" not in flat:
                return _san(flat), ""
            base, rest = flat.split("{", 1)
            pairs = [p.split("=", 1) for p in rest[:-1].split(",") if p]
            inner = ",".join(f'{k}="{v}"' for k, v in pairs)
            return _san(base), "{" + inner + "}"

        for kind, bucket in (("counter", "counters"), ("gauge", "gauges")):
            for flat, v in sorted(snap[bucket].items()):
                base, lbl = _split(flat)
                lines.append(f"# TYPE {base} {kind}")
                lines.append(f"{base}{lbl} {v}")
        for flat, h in sorted(snap["histograms"].items()):
            base, lbl = _split(flat)
            inner = lbl[1:-1] if lbl else ""
            lines.append(f"# TYPE {base} histogram")
            acc = 0
            for edge, c in zip(list(h["edges"]) + ["+Inf"],
                               h["counts"]):
                acc += c
                le = f'le="{edge}"'
                joined = f"{inner},{le}" if inner else le
                lines.append(f"{base}_bucket{{{joined}}} {acc}")
            lines.append(f"{base}_sum{lbl} {h['sum']}")
            lines.append(f"{base}_count{lbl} {h['count']}")
        return "\n".join(lines) + "\n"


_DEFAULT = Registry()


def registry() -> Registry:
    """The process-wide default registry."""
    return _DEFAULT
