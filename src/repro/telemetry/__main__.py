"""Run-inspection CLI.

    python -m repro.telemetry summarize <run_dir>
        Per-span p50/p99 latency table (merged across the learner's
        spans.jsonl and every worker's spans-<pid>.jsonl) plus an SPS
        curve reconstructed from the run's metrics JSONL stream.

    python -m repro.telemetry export-trace <run_dir> [--out trace.json]
        Merge all spans*.jsonl files into ONE Chrome trace-event JSON
        (Perfetto / chrome://tracing) with per-process lanes: worker
        timestamps are rebased onto the shared wall clock via each
        file's recorded clock offset, so a learner ``launch`` and the
        worker ``step``s it waited on line up on one timeline.

    python -m repro.telemetry compare [--history BENCH_history.jsonl]
                                      [--gate] [--noise 0.1] [--window 5]
        Compare the newest bench record per bench against its rolling
        same-machine baseline (see telemetry/benchwatch.py). Report-only
        by default; --gate exits non-zero on confirmed regressions.
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import sys

from repro.telemetry import benchwatch, traceprop
from repro.telemetry.spans import percentile, summarize_records

_SPARK = "▁▂▃▄▅▆▇█"


def _read_jsonl(path: str) -> list:
    out = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line:
                out.append(json.loads(line))
    return out


def load_spans(run_dir: str) -> list:
    """All span records in the run dir, merged across processes and
    rebased onto the shared wall clock (meta header lines excluded)."""
    return traceprop.merged_records(run_dir)


def load_metrics(run_dir: str) -> list:
    """All metric records in the run dir (every *.jsonl except span
    files), ordered by env_steps/step."""
    recs = []
    for path in sorted(glob.glob(os.path.join(run_dir, "*.jsonl"))):
        base = os.path.basename(path)
        if base.startswith("spans") and base.endswith(".jsonl"):
            continue
        recs.extend(_read_jsonl(path))
    recs.sort(key=lambda r: r.get("env_steps", r.get("step", 0)))
    return recs


def sparkline(vals, width: int = 48) -> str:
    if not vals:
        return ""
    if len(vals) > width:                       # downsample by striding
        stride = len(vals) / width
        vals = [vals[int(i * stride)] for i in range(width)]
    lo, hi = min(vals), max(vals)
    rng = (hi - lo) or 1.0
    return "".join(_SPARK[int((v - lo) / rng * (len(_SPARK) - 1))]
                   for v in vals)


def summarize(run_dir: str, out=sys.stdout) -> dict:
    """Print the summary; returns the data (the tests consume the dict)."""
    spans = load_spans(run_dir)
    summary = summarize_records(spans)
    procs = sorted({(r.get("pid"), r.get("role", "main")) for r in spans})
    w = max([len(n) for n in summary] + [4])
    print(f"# spans — {len(spans)} records, {len(summary)} names, "
          f"{len(procs)} process(es) ({run_dir})", file=out)
    hdr = (f"{'name':<{w}}  {'count':>7}  {'p50_ms':>9}  {'p99_ms':>9}  "
           f"{'mean_ms':>9}  {'max_ms':>9}  {'total_ms':>10}")
    print(hdr, file=out)
    print("-" * len(hdr), file=out)
    for name, s in summary.items():
        print(f"{name:<{w}}  {s['count']:>7}  {s['p50_ms']:>9.3f}  "
              f"{s['p99_ms']:>9.3f}  {s['mean_ms']:>9.3f}  "
              f"{s['max_ms']:>9.3f}  {s['total_ms']:>10.1f}", file=out)

    metrics = load_metrics(run_dir)
    sps = [r["sps"] for r in metrics
           if isinstance(r.get("sps"), (int, float))]
    curve = {}
    if sps:
        srt = sorted(sps)
        curve = {"n": len(sps), "min": srt[0], "max": srt[-1],
                 "mean": sum(sps) / len(sps),
                 "p50": percentile(srt, 0.5), "last": sps[-1]}
        print(f"\n# sps curve — {curve['n']} updates  "
              f"min {curve['min']:.0f}  p50 {curve['p50']:.0f}  "
              f"max {curve['max']:.0f}  last {curve['last']:.0f}", file=out)
        print(sparkline(sps), file=out)
    elif metrics:
        print(f"\n# {len(metrics)} metric records (no sps key)", file=out)
    return {"spans": summary, "sps_curve": curve,
            "n_span_records": len(spans), "n_processes": len(procs)}


def export_trace(run_dir: str, out_path: str) -> int:
    """Merged multi-process Chrome trace; returns the number of duration
    (``ph: "X"``) events written — lane-name metadata events don't count."""
    trace = traceprop.merge_chrome_trace(run_dir)
    with open(out_path, "w") as f:
        json.dump(trace, f)
    return sum(1 for e in trace["traceEvents"] if e.get("ph") == "X")


def main(argv=None) -> int:
    p = argparse.ArgumentParser(prog="python -m repro.telemetry",
                                description=__doc__)
    sub = p.add_subparsers(dest="cmd", required=True)
    ps = sub.add_parser("summarize", help="p50/p99 per span + SPS curve")
    ps.add_argument("run_dir")
    pe = sub.add_parser("export-trace",
                        help="merge spans*.jsonl -> one Chrome trace JSON")
    pe.add_argument("run_dir")
    pe.add_argument("--out", default="")
    pc = sub.add_parser("compare",
                        help="newest bench record vs rolling baseline")
    pc.add_argument("--history", default=benchwatch.HISTORY_FILE)
    pc.add_argument("--gate", action="store_true",
                    help="exit 1 on confirmed regressions (default: report)")
    pc.add_argument("--report-only", action="store_true",
                    help="explicit no-gate (the default; for CI readability)")
    pc.add_argument("--noise", type=float, default=benchwatch.DEFAULT_NOISE)
    pc.add_argument("--window", type=int, default=benchwatch.DEFAULT_WINDOW)
    args = p.parse_args(argv)

    if args.cmd == "compare":
        result = benchwatch.compare(args.history, noise=args.noise,
                                    window=args.window)
        print(benchwatch.format_report(result))
        if args.gate and not args.report_only and result["regressions"]:
            return 1
        return 0

    if not os.path.isdir(args.run_dir):
        print(f"error: not a directory: {args.run_dir}", file=sys.stderr)
        return 2
    if args.cmd == "summarize":
        data = summarize(args.run_dir)
        return 0 if data["n_span_records"] else 1
    out_path = args.out or os.path.join(args.run_dir, "trace.json")
    n = export_trace(args.run_dir, out_path)
    print(f"wrote {n} events -> {out_path}")
    return 0 if n else 1


if __name__ == "__main__":
    sys.exit(main())
