"""Run-inspection CLI.

    python -m repro.telemetry summarize <run_dir>
        Per-span p50/p99 latency table (from <run_dir>/spans.jsonl) plus an
        SPS curve reconstructed from the run's metrics JSONL stream.

    python -m repro.telemetry export-trace <run_dir> [--out trace.json]
        Convert spans.jsonl to Chrome trace-event JSON for Perfetto /
        chrome://tracing.
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import sys

from repro.telemetry.spans import (SPANS_FILE, chrome_trace, percentile,
                                   summarize_records)

_SPARK = "▁▂▃▄▅▆▇█"


def _read_jsonl(path: str) -> list:
    out = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line:
                out.append(json.loads(line))
    return out


def load_spans(run_dir: str) -> list:
    path = os.path.join(run_dir, SPANS_FILE)
    if not os.path.exists(path):
        return []
    return _read_jsonl(path)


def load_metrics(run_dir: str) -> list:
    """All metric records in the run dir (every *.jsonl except spans),
    ordered by env_steps/step."""
    recs = []
    for path in sorted(glob.glob(os.path.join(run_dir, "*.jsonl"))):
        if os.path.basename(path) == SPANS_FILE:
            continue
        recs.extend(_read_jsonl(path))
    recs.sort(key=lambda r: r.get("env_steps", r.get("step", 0)))
    return recs


def sparkline(vals, width: int = 48) -> str:
    if not vals:
        return ""
    if len(vals) > width:                       # downsample by striding
        stride = len(vals) / width
        vals = [vals[int(i * stride)] for i in range(width)]
    lo, hi = min(vals), max(vals)
    rng = (hi - lo) or 1.0
    return "".join(_SPARK[int((v - lo) / rng * (len(_SPARK) - 1))]
                   for v in vals)


def summarize(run_dir: str, out=sys.stdout) -> dict:
    """Print the summary; returns the data (the tests consume the dict)."""
    spans = load_spans(run_dir)
    summary = summarize_records(spans)
    w = max([len(n) for n in summary] + [4])
    print(f"# spans — {len(spans)} records, "
          f"{len(summary)} names ({run_dir})", file=out)
    hdr = (f"{'name':<{w}}  {'count':>7}  {'p50_ms':>9}  {'p99_ms':>9}  "
           f"{'mean_ms':>9}  {'max_ms':>9}  {'total_ms':>10}")
    print(hdr, file=out)
    print("-" * len(hdr), file=out)
    for name, s in summary.items():
        print(f"{name:<{w}}  {s['count']:>7}  {s['p50_ms']:>9.3f}  "
              f"{s['p99_ms']:>9.3f}  {s['mean_ms']:>9.3f}  "
              f"{s['max_ms']:>9.3f}  {s['total_ms']:>10.1f}", file=out)

    metrics = load_metrics(run_dir)
    sps = [r["sps"] for r in metrics
           if isinstance(r.get("sps"), (int, float))]
    curve = {}
    if sps:
        srt = sorted(sps)
        curve = {"n": len(sps), "min": srt[0], "max": srt[-1],
                 "mean": sum(sps) / len(sps),
                 "p50": percentile(srt, 0.5), "last": sps[-1]}
        print(f"\n# sps curve — {curve['n']} updates  "
              f"min {curve['min']:.0f}  p50 {curve['p50']:.0f}  "
              f"max {curve['max']:.0f}  last {curve['last']:.0f}", file=out)
        print(sparkline(sps), file=out)
    elif metrics:
        print(f"\n# {len(metrics)} metric records (no sps key)", file=out)
    return {"spans": summary, "sps_curve": curve,
            "n_span_records": len(spans)}


def export_trace(run_dir: str, out_path: str) -> int:
    spans = load_spans(run_dir)
    trace = chrome_trace(spans)
    with open(out_path, "w") as f:
        json.dump(trace, f)
    return len(trace["traceEvents"])


def main(argv=None) -> int:
    p = argparse.ArgumentParser(prog="python -m repro.telemetry",
                                description=__doc__)
    sub = p.add_subparsers(dest="cmd", required=True)
    ps = sub.add_parser("summarize", help="p50/p99 per span + SPS curve")
    ps.add_argument("run_dir")
    pe = sub.add_parser("export-trace", help="spans.jsonl -> Chrome JSON")
    pe.add_argument("run_dir")
    pe.add_argument("--out", default="")
    args = p.parse_args(argv)

    if not os.path.isdir(args.run_dir):
        print(f"error: not a directory: {args.run_dir}", file=sys.stderr)
        return 2
    if args.cmd == "summarize":
        data = summarize(args.run_dir)
        return 0 if data["n_span_records"] else 1
    out_path = args.out or os.path.join(args.run_dir, "trace.json")
    n = export_trace(args.run_dir, out_path)
    print(f"wrote {n} events -> {out_path}")
    return 0 if n else 1


if __name__ == "__main__":
    sys.exit(main())
