"""Low-overhead span tracer: the timing substrate for every hot path.

Design constraints, in order:

1. **Disabled must be free.** ``span(name)`` with telemetry off returns a
   module-level ``_NullSpan`` singleton — no allocation, no clock read, one
   global load and one ``is None`` test. Hot loops (engine launch/fetch,
   host recv, actor fragment commits) keep their span calls unconditionally;
   the cost only exists when someone turned tracing on.
2. **Enabled must be cheap.** One ``time.monotonic_ns()`` pair per span and
   one ``deque.append`` (GIL-atomic, so thread-safe without a lock) into a
   bounded ring. No string formatting, no dict building on the hot path.
3. **Host-side only.** Spans wrap Python host code — launch dispatch, device
   fetches, shared-memory waits. They must never appear inside jitted
   functions (they would run once at trace time and lie forever); the
   ``TELEMETRY-IN-JIT`` rule in ``repro.analysis`` enforces this statically.

Nesting is tracked per-thread/task via a ``contextvars.ContextVar`` depth
counter so the Chrome trace export reconstructs the flame graph. Export
targets: ``spans.jsonl`` (one record per span, appended by ``flush()``) and
the Chrome trace-event JSON that Perfetto / ``chrome://tracing`` loads.

jax-free by design: spawn workers (``core/shm.py`` / ``actor_main``) import
this module before jax exists in their interpreter.
"""
from __future__ import annotations

import json
import os
import threading
import time
import uuid
from collections import deque
from contextvars import ContextVar
from typing import List, NamedTuple, Optional

__all__ = [
    "Tracer", "SpanRecord", "span", "CachedSpan", "enable", "disable",
    "enabled", "get_tracer", "flush", "clock_offset_ns", "percentile",
    "summarize_records",
]

SPANS_FILE = "spans.jsonl"


def clock_offset_ns() -> int:
    """Wall-clock minus monotonic-clock offset for THIS process, in ns.

    Span timestamps use ``time.monotonic_ns()`` (cheap, never steps
    backward) whose epoch is arbitrary per process — raw ``ts_ns`` values
    from two processes are not comparable. Each process records its own
    offset once, in its spans-file meta header, and the merge step maps
    every span onto the shared wall clock via ``ts_ns + offset``. Median
    of five tight samples rejects a scheduler preemption landing between
    the two clock reads.
    """
    samples = []
    for _ in range(5):
        a = time.monotonic_ns()
        w = time.time_ns()
        b = time.monotonic_ns()
        samples.append(w - (a + b) // 2)
    samples.sort()
    return samples[2]

# (depth, parent-name) of the innermost open span on this thread/task
_STACK: ContextVar[tuple] = ContextVar("repro_span_stack", default=(0, ""))


class SpanRecord(NamedTuple):
    """One completed span. ``ts_ns`` is ``time.monotonic_ns()`` at entry —
    comparable within a process, not across processes."""
    name: str
    ts_ns: int
    dur_ns: int
    pid: int
    tid: int
    depth: int
    parent: str


class _NullSpan:
    """The disabled fast path: a stateless singleton context manager."""
    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, et, ev, tb):
        return False


_NULL_SPAN = _NullSpan()


class _Span:
    """One live span (enabled path). Records itself into the tracer ring on
    exit; exceptions propagate (the span still records its duration)."""
    __slots__ = ("_ring", "name", "_t0", "_tok", "_depth", "_parent")

    def __init__(self, ring: deque, name: str):
        self._ring = ring
        self.name = name

    def __enter__(self):
        depth, _parent = _STACK.get((0, ""))   # ContextVar read, never blocks
        self._depth = depth
        self._parent = _parent
        self._tok = _STACK.set((depth + 1, self.name))
        self._t0 = time.monotonic_ns()
        return self

    def __exit__(self, et, ev, tb):
        dur = time.monotonic_ns() - self._t0
        _STACK.reset(self._tok)
        self._ring.append(SpanRecord(
            self.name, self._t0, dur, os.getpid(),
            threading.get_ident() & 0xFFFFFFFF, self._depth, self._parent))
        return False


class CachedSpan:
    """A reusable named span for non-reentrant hot call sites.

    ``span(name)`` allocates one ``_Span`` per use on the enabled path;
    a ``CachedSpan`` held by the call site (e.g. ``TierTimer``'s launch /
    fetch contexts) is allocation-free in BOTH modes: the tracer is
    re-read on every ``__enter__`` so mid-run enable/disable still works.
    Not safe for the same instance to be entered concurrently from two
    threads or re-entered recursively — one instance per call site.
    """
    __slots__ = ("name", "_ring", "_t0", "_tok", "_depth", "_parent")

    def __init__(self, name: str):
        self.name = name
        self._ring = None

    def __enter__(self):
        t = _TRACER
        if t is None:
            self._ring = None
            return self
        self._ring = t._ring
        depth, parent = _STACK.get((0, ""))
        self._depth = depth
        self._parent = parent
        self._tok = _STACK.set((depth + 1, self.name))
        self._t0 = time.monotonic_ns()
        return self

    def __exit__(self, et, ev, tb):
        ring = self._ring
        if ring is None:
            return False
        dur = time.monotonic_ns() - self._t0
        _STACK.reset(self._tok)
        self._ring = None
        ring.append(SpanRecord(
            self.name, self._t0, dur, os.getpid(),
            threading.get_ident() & 0xFFFFFFFF, self._depth, self._parent))
        return False


class Tracer:
    """Bounded ring of completed spans. ``deque(maxlen=)`` appends are
    GIL-atomic, so concurrent host threads record without a lock; the lock
    below only serializes drains/flushes against each other.

    With a ``run_dir``, the tracer owns one spans file (``file_name``,
    default ``spans.jsonl``; workers use ``spans-<pid>.jsonl``) and writes
    a meta header line on creation — ``{"kind": "meta", trace_id, pid,
    role, clock_offset_ns}`` — eagerly, so even a process killed before
    its first flush leaves a mergeable (if empty) file behind.
    """

    def __init__(self, run_dir: Optional[str] = None, capacity: int = 65536,
                 *, file_name: Optional[str] = None,
                 trace_id: Optional[str] = None, role: str = "main"):
        self.run_dir = run_dir
        self.capacity = int(capacity)
        self.file_name = file_name or SPANS_FILE
        self.trace_id = trace_id or uuid.uuid4().hex[:16]
        self.role = role
        self.clock_offset_ns = clock_offset_ns()
        self._ring: deque = deque(maxlen=self.capacity)
        self._io_lock = threading.Lock()
        if run_dir:
            os.makedirs(run_dir, exist_ok=True)
            self._write_meta()

    def _write_meta(self) -> None:
        rec = {"kind": "meta", "schema": 1, "trace_id": self.trace_id,
               "pid": os.getpid(), "role": self.role,
               "clock_offset_ns": self.clock_offset_ns}
        path = os.path.join(self.run_dir, self.file_name)
        with self._io_lock, open(path, "a") as f:
            f.write(json.dumps(rec) + "\n")
            f.flush()
            os.fsync(f.fileno())

    # -- recording ---------------------------------------------------------
    def span(self, name: str) -> _Span:
        return _Span(self._ring, name)

    def records(self) -> List[SpanRecord]:
        """Snapshot of the ring without draining it."""
        return list(self._ring)

    def drain(self) -> List[SpanRecord]:
        """Atomically take everything recorded so far."""
        with self._io_lock:
            out = []
            ring = self._ring
            while True:
                try:
                    out.append(ring.popleft())
                except IndexError:
                    return out

    # -- export ------------------------------------------------------------
    def flush(self) -> int:
        """Append drained spans to ``<run_dir>/<file_name>``; returns the
        number written. Without a run_dir the ring just keeps accumulating
        (bounded) and flush is a no-op returning 0."""
        if not self.run_dir:
            return 0
        recs = self.drain()
        if not recs:
            return 0
        path = os.path.join(self.run_dir, self.file_name)
        with self._io_lock, open(path, "a") as f:
            for r in recs:
                f.write(json.dumps(r._asdict()) + "\n")
            f.flush()
            os.fsync(f.fileno())
        return len(recs)

    def summary(self) -> dict:
        return summarize_records(self.records())

    def to_chrome_trace(self, records: Optional[List[SpanRecord]] = None) -> dict:
        return chrome_trace(self.records() if records is None else records)


# -- module-level switch ---------------------------------------------------
_TRACER: Optional[Tracer] = None


def span(name: str):
    """THE hot-path entry point. Disabled: returns the shared no-op span
    (zero allocations). Enabled: returns a recording span."""
    t = _TRACER
    if t is None:
        return _NULL_SPAN
    return _Span(t._ring, name)


def enable(run_dir: Optional[str] = None, capacity: int = 65536, *,
           file_name: Optional[str] = None, trace_id: Optional[str] = None,
           role: str = "main") -> Tracer:
    """Turn tracing on process-wide; returns the (new) tracer. Re-enabling
    with the same args keeps the existing tracer so spans survive."""
    global _TRACER
    if (_TRACER is not None and _TRACER.run_dir == run_dir
            and _TRACER.capacity == int(capacity)
            and _TRACER.file_name == (file_name or SPANS_FILE)):
        return _TRACER
    _TRACER = Tracer(run_dir=run_dir, capacity=capacity,
                     file_name=file_name, trace_id=trace_id, role=role)
    return _TRACER


def disable() -> None:
    """Turn tracing off (flushing any pending spans first)."""
    global _TRACER
    if _TRACER is not None:
        try:
            _TRACER.flush()
        finally:
            _TRACER = None


def enabled() -> bool:
    return _TRACER is not None


def get_tracer() -> Optional[Tracer]:
    return _TRACER


def flush() -> int:
    """Flush the active tracer (no-op when disabled)."""
    t = _TRACER
    return t.flush() if t is not None else 0


# -- pure helpers (shared with the CLI) ------------------------------------
def percentile(sorted_vals, q: float) -> float:
    """Nearest-rank percentile over an already-sorted list."""
    if not sorted_vals:
        return 0.0
    i = min(len(sorted_vals) - 1, max(0, int(q * len(sorted_vals))))
    return float(sorted_vals[i])


def summarize_records(records) -> dict:
    """Per-name stats: count / total_ms / mean_ms / p50_ms / p99_ms / max_ms.
    Accepts SpanRecords or dicts (the spans.jsonl rows)."""
    by_name: dict = {}
    for r in records:
        if isinstance(r, dict):
            name, dur = r["name"], int(r["dur_ns"])
        else:
            name, dur = r.name, r.dur_ns
        by_name.setdefault(name, []).append(dur)
    out = {}
    for name, durs in sorted(by_name.items()):
        durs.sort()
        total = sum(durs)
        out[name] = {
            "count": len(durs),
            "total_ms": total / 1e6,
            "mean_ms": total / len(durs) / 1e6,
            "p50_ms": percentile(durs, 0.50) / 1e6,
            "p99_ms": percentile(durs, 0.99) / 1e6,
            "max_ms": durs[-1] / 1e6,
        }
    return out


def chrome_trace(records) -> dict:
    """Chrome trace-event JSON (``ph: "X"`` complete events, µs units) —
    loads directly in Perfetto / chrome://tracing."""
    events = []
    for r in records:
        if isinstance(r, dict):
            r = SpanRecord(**r)
        events.append({
            "name": r.name,
            "cat": "repro",
            "ph": "X",
            "ts": r.ts_ns / 1e3,
            "dur": r.dur_ns / 1e3,
            "pid": r.pid,
            "tid": r.tid,
            "args": {"depth": r.depth, "parent": r.parent},
        })
    return {"traceEvents": events, "displayTimeUnit": "ms"}
