"""Low-overhead span tracer: the timing substrate for every hot path.

Design constraints, in order:

1. **Disabled must be free.** ``span(name)`` with telemetry off returns a
   module-level ``_NullSpan`` singleton — no allocation, no clock read, one
   global load and one ``is None`` test. Hot loops (engine launch/fetch,
   host recv, actor fragment commits) keep their span calls unconditionally;
   the cost only exists when someone turned tracing on.
2. **Enabled must be cheap.** One ``time.monotonic_ns()`` pair per span and
   one ``deque.append`` (GIL-atomic, so thread-safe without a lock) into a
   bounded ring. No string formatting, no dict building on the hot path.
3. **Host-side only.** Spans wrap Python host code — launch dispatch, device
   fetches, shared-memory waits. They must never appear inside jitted
   functions (they would run once at trace time and lie forever); the
   ``TELEMETRY-IN-JIT`` rule in ``repro.analysis`` enforces this statically.

Nesting is tracked per-thread/task via a ``contextvars.ContextVar`` depth
counter so the Chrome trace export reconstructs the flame graph. Export
targets: ``spans.jsonl`` (one record per span, appended by ``flush()``) and
the Chrome trace-event JSON that Perfetto / ``chrome://tracing`` loads.

jax-free by design: spawn workers (``core/shm.py`` / ``actor_main``) import
this module before jax exists in their interpreter.
"""
from __future__ import annotations

import json
import os
import threading
import time
from collections import deque
from contextvars import ContextVar
from typing import List, NamedTuple, Optional

__all__ = [
    "Tracer", "SpanRecord", "span", "enable", "disable", "enabled",
    "get_tracer", "flush", "percentile", "summarize_records",
]

SPANS_FILE = "spans.jsonl"

# (depth, parent-name) of the innermost open span on this thread/task
_STACK: ContextVar[tuple] = ContextVar("repro_span_stack", default=(0, ""))


class SpanRecord(NamedTuple):
    """One completed span. ``ts_ns`` is ``time.monotonic_ns()`` at entry —
    comparable within a process, not across processes."""
    name: str
    ts_ns: int
    dur_ns: int
    pid: int
    tid: int
    depth: int
    parent: str


class _NullSpan:
    """The disabled fast path: a stateless singleton context manager."""
    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, et, ev, tb):
        return False


_NULL_SPAN = _NullSpan()


class _Span:
    """One live span (enabled path). Records itself into the tracer ring on
    exit; exceptions propagate (the span still records its duration)."""
    __slots__ = ("_ring", "name", "_t0", "_tok", "_depth", "_parent")

    def __init__(self, ring: deque, name: str):
        self._ring = ring
        self.name = name

    def __enter__(self):
        depth, _parent = _STACK.get((0, ""))   # ContextVar read, never blocks
        self._depth = depth
        self._parent = _parent
        self._tok = _STACK.set((depth + 1, self.name))
        self._t0 = time.monotonic_ns()
        return self

    def __exit__(self, et, ev, tb):
        dur = time.monotonic_ns() - self._t0
        _STACK.reset(self._tok)
        self._ring.append(SpanRecord(
            self.name, self._t0, dur, os.getpid(),
            threading.get_ident() & 0xFFFFFFFF, self._depth, self._parent))
        return False


class Tracer:
    """Bounded ring of completed spans. ``deque(maxlen=)`` appends are
    GIL-atomic, so concurrent host threads record without a lock; the lock
    below only serializes drains/flushes against each other."""

    def __init__(self, run_dir: Optional[str] = None, capacity: int = 65536):
        self.run_dir = run_dir
        self.capacity = int(capacity)
        self._ring: deque = deque(maxlen=self.capacity)
        self._io_lock = threading.Lock()
        if run_dir:
            os.makedirs(run_dir, exist_ok=True)

    # -- recording ---------------------------------------------------------
    def span(self, name: str) -> _Span:
        return _Span(self._ring, name)

    def records(self) -> List[SpanRecord]:
        """Snapshot of the ring without draining it."""
        return list(self._ring)

    def drain(self) -> List[SpanRecord]:
        """Atomically take everything recorded so far."""
        with self._io_lock:
            out = []
            ring = self._ring
            while True:
                try:
                    out.append(ring.popleft())
                except IndexError:
                    return out

    # -- export ------------------------------------------------------------
    def flush(self) -> int:
        """Append drained spans to ``<run_dir>/spans.jsonl``; returns the
        number written. Without a run_dir the ring just keeps accumulating
        (bounded) and flush is a no-op returning 0."""
        if not self.run_dir:
            return 0
        recs = self.drain()
        if not recs:
            return 0
        path = os.path.join(self.run_dir, SPANS_FILE)
        with self._io_lock, open(path, "a") as f:
            for r in recs:
                f.write(json.dumps(r._asdict()) + "\n")
            f.flush()
            os.fsync(f.fileno())
        return len(recs)

    def summary(self) -> dict:
        return summarize_records(self.records())

    def to_chrome_trace(self, records: Optional[List[SpanRecord]] = None) -> dict:
        return chrome_trace(self.records() if records is None else records)


# -- module-level switch ---------------------------------------------------
_TRACER: Optional[Tracer] = None


def span(name: str):
    """THE hot-path entry point. Disabled: returns the shared no-op span
    (zero allocations). Enabled: returns a recording span."""
    t = _TRACER
    if t is None:
        return _NULL_SPAN
    return _Span(t._ring, name)


def enable(run_dir: Optional[str] = None, capacity: int = 65536) -> Tracer:
    """Turn tracing on process-wide; returns the (new) tracer. Re-enabling
    with the same args keeps the existing tracer so spans survive."""
    global _TRACER
    if (_TRACER is not None and _TRACER.run_dir == run_dir
            and _TRACER.capacity == int(capacity)):
        return _TRACER
    _TRACER = Tracer(run_dir=run_dir, capacity=capacity)
    return _TRACER


def disable() -> None:
    """Turn tracing off (flushing any pending spans first)."""
    global _TRACER
    if _TRACER is not None:
        try:
            _TRACER.flush()
        finally:
            _TRACER = None


def enabled() -> bool:
    return _TRACER is not None


def get_tracer() -> Optional[Tracer]:
    return _TRACER


def flush() -> int:
    """Flush the active tracer (no-op when disabled)."""
    t = _TRACER
    return t.flush() if t is not None else 0


# -- pure helpers (shared with the CLI) ------------------------------------
def percentile(sorted_vals, q: float) -> float:
    """Nearest-rank percentile over an already-sorted list."""
    if not sorted_vals:
        return 0.0
    i = min(len(sorted_vals) - 1, max(0, int(q * len(sorted_vals))))
    return float(sorted_vals[i])


def summarize_records(records) -> dict:
    """Per-name stats: count / total_ms / mean_ms / p50_ms / p99_ms / max_ms.
    Accepts SpanRecords or dicts (the spans.jsonl rows)."""
    by_name: dict = {}
    for r in records:
        if isinstance(r, dict):
            name, dur = r["name"], int(r["dur_ns"])
        else:
            name, dur = r.name, r.dur_ns
        by_name.setdefault(name, []).append(dur)
    out = {}
    for name, durs in sorted(by_name.items()):
        durs.sort()
        total = sum(durs)
        out[name] = {
            "count": len(durs),
            "total_ms": total / 1e6,
            "mean_ms": total / len(durs) / 1e6,
            "p50_ms": percentile(durs, 0.50) / 1e6,
            "p99_ms": percentile(durs, 0.99) / 1e6,
            "max_ms": durs[-1] / 1e6,
        }
    return out


def chrome_trace(records) -> dict:
    """Chrome trace-event JSON (``ph: "X"`` complete events, µs units) —
    loads directly in Perfetto / chrome://tracing."""
    events = []
    for r in records:
        if isinstance(r, dict):
            r = SpanRecord(**r)
        events.append({
            "name": r.name,
            "cat": "repro",
            "ph": "X",
            "ts": r.ts_ns / 1e3,
            "dur": r.dur_ns / 1e3,
            "pid": r.pid,
            "tid": r.tid,
            "args": {"depth": r.depth, "parent": r.parent},
        })
    return {"traceEvents": events, "displayTimeUnit": "ms"}
