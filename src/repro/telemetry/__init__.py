"""repro.telemetry — unified observability: spans, metrics, proc stats.

Quickstart::

    from repro import telemetry

    telemetry.enable(run_dir="runs/exp1")      # or enable() for in-memory
    with telemetry.span("my.section"):
        ...
    telemetry.registry().counter("my.events").inc()
    telemetry.flush()                          # -> runs/exp1/spans.jsonl

    # later, from a shell:
    #   python -m repro.telemetry summarize runs/exp1
    #   python -m repro.telemetry export-trace runs/exp1 --out trace.json

Everything here is jax-free (stdlib + numpy): spawn workers in
``core/shm.py`` and ``distributed/actor_learner.py`` import this chain
before jax exists in their interpreter, and the fork-guard depends on that.
Imports are eager (no PEP 562 laziness) — the whole package is a few
hundred lines of stdlib with no heavy deps.
"""
from repro.telemetry.registry import (Counter, Gauge, Histogram, Registry,
                                      registry)
from repro.telemetry.spans import (SpanRecord, Tracer, chrome_trace, disable,
                                   enable, enabled, flush, get_tracer, span,
                                   summarize_records)
from repro.telemetry.timers import TierTimer

__all__ = [
    "Counter", "Gauge", "Histogram", "Registry", "registry",
    "SpanRecord", "Tracer", "chrome_trace", "disable", "enable", "enabled",
    "flush", "get_tracer", "span", "summarize_records",
    "TierTimer",
]
