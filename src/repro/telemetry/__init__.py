"""repro.telemetry — unified observability: spans, metrics, proc stats.

Quickstart::

    from repro import telemetry

    telemetry.enable(run_dir="runs/exp1")      # or enable() for in-memory
    with telemetry.span("my.section"):
        ...
    telemetry.registry().counter("my.events").inc()
    telemetry.flush()                          # -> runs/exp1/spans.jsonl

    # later, from a shell:
    #   python -m repro.telemetry summarize runs/exp1
    #   python -m repro.telemetry export-trace runs/exp1 --out trace.json
    #   python -m repro.telemetry compare --gate   # bench regression check
    #   curl localhost:9100/healthz                # with MetricsServer up

Everything here is jax-free (stdlib + numpy): spawn workers in
``core/shm.py`` and ``distributed/actor_learner.py`` import this chain
before jax exists in their interpreter, and the fork-guard depends on that.
Imports are eager (no PEP 562 laziness) — the whole package is a few
hundred lines of stdlib with no heavy deps. ``http`` and ``benchwatch``
are NOT imported eagerly: training loops that never start a monitoring
server shouldn't pay for http.server machinery, and benches import
benchwatch directly.
"""
from repro.telemetry.registry import (Counter, Gauge, Histogram, Registry,
                                      registry)
from repro.telemetry.spans import (CachedSpan, SpanRecord, Tracer,
                                   chrome_trace, clock_offset_ns, disable,
                                   enable, enabled, flush, get_tracer, span,
                                   summarize_records)
from repro.telemetry.timers import TierTimer

__all__ = [
    "Counter", "Gauge", "Histogram", "Registry", "registry",
    "CachedSpan", "SpanRecord", "Tracer", "chrome_trace", "clock_offset_ns",
    "disable", "enable", "enabled",
    "flush", "get_tracer", "span", "summarize_records",
    "TierTimer",
]
