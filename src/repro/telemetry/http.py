"""Live monitoring endpoints: /metrics, /healthz, /spans — stdlib only.

A tiny threaded HTTP server for watching a training run from outside the
process (``curl``, Prometheus scrape, a k8s liveness probe) without
touching the hot path:

  * ``/metrics``  — the registry's Prometheus text exposition plus
    StatSlab-derived per-worker counters from every registered stats
    source (``repro_worker_steps_total{source=...,worker=...}`` lines).
  * ``/healthz``  — JSON per-worker/actor liveness computed from the
    ``last_beat_ns`` slab rows: HTTP 200 while every worker is alive
    (idle, slow-but-beating included), 503 the moment any is dead. A
    worker with a stale beat is labeled ``"stale"`` but does not flip the
    status — that is the "slow vs. dead" distinction the beat rows exist
    to make.
  * ``/spans``    — p50/p99 summary of the live tracer ring (JSON; empty
    object when tracing is off).

Server discipline (and why the BLOCKING-NO-TIMEOUT lint stays quiet):
the accept queue is bounded (``request_queue_size``), requests are
serviced by a daemon thread running ``handle_request()`` under the
server's class-level ``timeout`` (bounded poll — never ``serve_forever``,
which blocks unboundedly and the lint rejects), handler threads are
daemonic, and ``close()`` is idempotent. Stats callables run on the
request thread; they must be cheap snapshot reads (``engine.stats`` /
``pool.stats`` are — slab aggregation is one vectorized sum).
"""
from __future__ import annotations

import json
import threading
import time
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Callable, Dict, List, Tuple

# import the submodule directly: the package __init__ rebinds the name
# ``registry`` to the accessor *function*, shadowing the module attribute
from repro.telemetry import spans as _spans
from repro.telemetry.registry import registry as _registry_fn

__all__ = ["MetricsServer", "collect_health", "slab_prometheus_lines"]

# beyond this beat age a live worker is labeled "stale" (slow, not dead)
DEFAULT_STALE_AFTER_S = 10.0


def _san(name: str) -> str:
    return "".join(c if (c.isalnum() or c == "_") else "_" for c in name)


def slab_prometheus_lines(sources: List[Tuple[str, dict]]) -> List[str]:
    """Prometheus lines from nested stats dicts.

    Walks each source dict for StatSlab aggregates (any mapping with a
    ``per_worker`` field table) and emits one
    ``repro_worker_<field>_total{source="...",worker="i"}`` line per
    worker per field, plus ``repro_stat_<key>{source="..."}`` lines for
    plain numeric leaves at any nesting level.
    """
    lines: List[str] = []

    def walk(prefix: str, d: dict):
        pw = d.get("per_worker")
        if isinstance(pw, dict):
            for field, vals in pw.items():
                if not isinstance(vals, (list, tuple)):
                    continue
                for w, x in enumerate(vals):
                    if isinstance(x, (int, float)):
                        lines.append(
                            f'repro_worker_{_san(field)}_total'
                            f'{{source="{prefix}",worker="{w}"}} {x}')
            return
        for key, val in d.items():
            sub = f"{prefix}.{key}" if prefix else str(key)
            if isinstance(val, dict):
                walk(sub, val)
            elif isinstance(val, bool):
                lines.append(f'repro_stat_{_san(key)}'
                             f'{{source="{prefix}"}} {int(val)}')
            elif isinstance(val, (int, float)):
                lines.append(f'repro_stat_{_san(key)}'
                             f'{{source="{prefix}"}} {val}')

    for name, stats in sources:
        if isinstance(stats, dict):
            walk(name, stats)
    return lines


def _find_liveness(d: dict, path: str = "") -> List[Tuple[str, dict]]:
    """Every ``liveness`` block (``{"last_beat_ns", "dead", ...}``) in a
    nested stats dict, with its dotted path."""
    found = []
    for key, val in d.items():
        if not isinstance(val, dict):
            continue
        sub = f"{path}.{key}" if path else str(key)
        if key == "liveness" and "last_beat_ns" in val:
            found.append((path, val))
        else:
            found.extend(_find_liveness(val, sub))
    return found


def collect_health(sources: List[Tuple[str, Callable[[], dict]]],
                   stale_after_s: float = DEFAULT_STALE_AFTER_S) -> dict:
    """The /healthz document: per-worker status rows over every liveness
    block every source exposes. ``ok`` is False iff any worker is dead (or
    a source itself raised) — stale/booting workers do not flip it."""
    now = time.time_ns()
    workers = []
    ok = True
    for name, fn in sources:
        try:
            st = fn()
        except Exception as e:   # noqa: BLE001 — a broken source is a finding
            ok = False
            workers.append({"source": name, "worker": None,
                            "status": "source_error",
                            "error": f"{type(e).__name__}: {e}"})
            continue
        if not isinstance(st, dict):
            continue
        for path, live in _find_liveness(st):
            src = f"{name}.{path}" if path else name
            dead = set(live.get("dead") or ())
            beats = live.get("last_beat_ns") or []
            n = max(len(beats), int(live.get("workers") or 0))
            for i in range(n):
                beat = int(beats[i]) if i < len(beats) else 0
                age = (now - beat) / 1e9 if beat > 0 else None
                if i in dead:
                    status = "dead"
                    ok = False
                elif beat == 0:
                    status = "booting"
                elif age is not None and age > stale_after_s:
                    status = "stale"
                else:
                    status = "ok"
                workers.append({"source": src, "worker": i,
                                "status": status,
                                "beat_age_s": (round(age, 3)
                                               if age is not None else None)})
    return {"ok": ok, "checked_ns": now, "workers": workers}


class _Server(ThreadingHTTPServer):
    # bounded accept queue: a scrape storm backs up in the kernel and
    # overflows to connection refused instead of unbounded thread growth
    request_queue_size = 16
    daemon_threads = True
    allow_reuse_address = True
    # bounds each handle_request() poll so the serve loop re-checks the
    # stop flag instead of parking forever on accept
    timeout = 0.5


class MetricsServer:
    """Threaded monitoring server bound to ``127.0.0.1`` (loopback only by
    default — exposing training internals on all interfaces is an explicit
    opt-in via ``host=``). ``port=0`` picks a free ephemeral port; read it
    back from ``self.port``."""

    def __init__(self, port: int = 0, host: str = "127.0.0.1", *,
                 stale_after_s: float = DEFAULT_STALE_AFTER_S):
        self._sources: Dict[str, Callable[[], dict]] = {}
        self._lock = threading.Lock()
        self.stale_after_s = float(stale_after_s)
        self._closed = False
        server = self

        class _Handler(BaseHTTPRequestHandler):
            def log_message(self, *a):            # silence per-request noise
                pass

            def _send(self, code: int, body: bytes, ctype: str):
                self.send_response(code)
                self.send_header("Content-Type", ctype)
                self.send_header("Content-Length", str(len(body)))
                self.end_headers()
                self.wfile.write(body)

            def do_GET(self):
                path = self.path.split("?", 1)[0]
                try:
                    if path == "/metrics":
                        body = server.render_metrics().encode()
                        self._send(200, body,
                                   "text/plain; version=0.0.4; charset=utf-8")
                    elif path == "/healthz":
                        doc = server.render_health()
                        self._send(200 if doc["ok"] else 503,
                                   json.dumps(doc, indent=2).encode(),
                                   "application/json")
                    elif path == "/spans":
                        body = json.dumps(server.render_spans(),
                                          indent=2).encode()
                        self._send(200, body, "application/json")
                    else:
                        self._send(404, b'{"error": "not found"}',
                                   "application/json")
                except BrokenPipeError:
                    pass
                except Exception as e:  # noqa: BLE001 — 500, never a hang
                    try:
                        self._send(500, json.dumps(
                            {"error": f"{type(e).__name__}: {e}"}).encode(),
                            "application/json")
                    except Exception:
                        pass

        self._srv = _Server((host, int(port)), _Handler)
        self.host, self.port = self._srv.server_address[:2]
        self._thread = threading.Thread(
            target=self._serve, daemon=True,
            name=f"repro-metrics-http:{self.port}")
        self._thread.start()

    # -- sources -----------------------------------------------------------
    def add_source(self, name: str, stats_fn: Callable[[], dict]) -> None:
        """Register (or replace) a stats provider — e.g.
        ``add_source("engine", engine.stats)``. Called on request threads;
        must be a cheap snapshot read."""
        with self._lock:
            self._sources[name] = stats_fn

    def remove_source(self, name: str) -> None:
        with self._lock:
            self._sources.pop(name, None)

    def _snapshot_sources(self) -> List[Tuple[str, Callable[[], dict]]]:
        with self._lock:
            return list(self._sources.items())

    # -- endpoint bodies ---------------------------------------------------
    def render_metrics(self) -> str:
        text = _registry_fn().to_prometheus()
        evaluated = []
        for name, fn in self._snapshot_sources():
            try:
                evaluated.append((name, fn()))
            except Exception:   # noqa: BLE001 — /metrics must always serve
                continue
        lines = slab_prometheus_lines(evaluated)
        if lines:
            text = text + "\n".join(lines) + "\n"
        return text

    def render_health(self) -> dict:
        return collect_health(self._snapshot_sources(),
                              stale_after_s=self.stale_after_s)

    def render_spans(self) -> dict:
        t = _spans.get_tracer()
        if t is None:
            return {}
        return _spans.summarize_records(t.records())

    # -- lifecycle ---------------------------------------------------------
    def _serve(self) -> None:
        while not self._closed:
            try:
                # bounded by _Server.timeout (0.5s poll), so the loop
                # re-checks _closed instead of parking on accept forever
                self._srv.handle_request()
            except Exception:
                if self._closed:
                    return

    def close(self, timeout: float = 2.0) -> None:
        """Idempotent shutdown: stop the serve loop, close the socket,
        join the thread (bounded)."""
        if self._closed:
            return
        self._closed = True
        try:
            self._srv.server_close()
        except Exception:
            pass
        self._thread.join(timeout=timeout)

    def __enter__(self):
        return self

    def __exit__(self, et, ev, tb):
        self.close()
        return False

    @property
    def url(self) -> str:
        return f"http://{self.host}:{self.port}"
