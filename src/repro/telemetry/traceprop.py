"""Cross-process trace propagation: one timeline across learner + workers.

The learner enables tracing with a run dir (``telemetry.enable``); spawn
workers — proc HostPool workers and async-tier actors — are separate
interpreters that inherit nothing. This module is the handshake:

1. The parent snapshots its live tracer into a picklable ``TraceConfig``
   (``current()``) and ships it inside the existing spawn-time config
   (``shm.WorkerConfig.trace`` / ``actor_learner.ActorConfig.trace``).
   When tracing is off, ``current()`` is ``None`` and workers pay nothing.
2. Each worker calls ``init_worker(cfg, role)``: it enables a process-local
   tracer writing ``spans-<pid>.jsonl`` in the same run dir, stamped with
   the shared trace id and the worker's own wall-vs-monotonic clock offset
   (``spans.clock_offset_ns``). The meta header is written eagerly, so a
   worker killed before its first flush still leaves a mergeable file.
3. ``merge_chrome_trace(run_dir)`` reads every ``spans*.jsonl``, maps each
   file's monotonic timestamps onto the shared wall clock via its recorded
   offset, and emits ONE Chrome trace with per-process pid lanes labeled
   by role (``process_name`` metadata events) — a learner ``launch`` and
   the worker ``step``s it waited on line up on one timeline.

Partial files are expected, not errors: a SIGKILLed worker can leave a
torn final line (flush is append + fsync, so at most the last line is
damaged) — unparsable lines are skipped, everything before them merges.

jax-free by design: spawn workers import this before jax exists.
"""
from __future__ import annotations

import glob
import json
import os
from dataclasses import dataclass
from typing import List, Optional, Tuple

from repro.telemetry import spans

__all__ = [
    "TraceConfig", "current", "init_worker", "worker_spans_name",
    "load_run_spans", "merged_records", "merge_chrome_trace",
]

SPANS_GLOB = "spans*.jsonl"


@dataclass(frozen=True)
class TraceConfig:
    """Picklable snapshot of the parent's tracing state, shipped to spawn
    workers inside their start-up config."""
    run_dir: str
    trace_id: str
    capacity: int = 65536


def current() -> Optional[TraceConfig]:
    """The parent side of the handshake: ``None`` unless tracing is on
    with a run dir (ring-only tracing has nowhere for workers to flush)."""
    t = spans.get_tracer()
    if t is None or not t.run_dir:
        return None
    return TraceConfig(run_dir=t.run_dir, trace_id=t.trace_id,
                       capacity=t.capacity)


def worker_spans_name(pid: Optional[int] = None) -> str:
    return f"spans-{os.getpid() if pid is None else pid}.jsonl"


def init_worker(cfg: Optional[TraceConfig],
                role: str) -> Optional[spans.Tracer]:
    """The worker side: enable a per-process tracer writing its own
    ``spans-<pid>.jsonl`` (meta header written immediately). Returns the
    tracer, or ``None`` when the parent shipped no trace config."""
    if cfg is None:
        return None
    return spans.enable(cfg.run_dir, capacity=cfg.capacity,
                        file_name=worker_spans_name(),
                        trace_id=cfg.trace_id, role=role)


# -- merge ------------------------------------------------------------------
def load_run_spans(run_dir: str) -> List[Tuple[dict, List[dict]]]:
    """``[(meta, records), ...]`` — one entry per ``spans*.jsonl`` file.

    Tolerant by construction: unreadable files, blank lines, torn tails of
    killed workers, and records from pre-meta writers all degrade to "use
    what parses". A file whose meta never landed gets offset 0 and a pid
    recovered from its first span record.
    """
    out = []
    for path in sorted(glob.glob(os.path.join(run_dir, SPANS_GLOB))):
        meta = {"pid": None, "role": "", "clock_offset_ns": 0,
                "trace_id": ""}
        recs: List[dict] = []
        try:
            fh = open(path, "r")
        except OSError:
            continue
        with fh:
            for line in fh:
                line = line.strip()
                if not line:
                    continue
                try:
                    r = json.loads(line)
                except ValueError:
                    continue            # torn tail of a killed worker
                if not isinstance(r, dict):
                    continue
                if r.get("kind") == "meta":
                    meta.update(r)      # last meta wins (re-enabled tracer)
                elif "name" in r and "ts_ns" in r and "dur_ns" in r:
                    recs.append(r)
        if meta["pid"] is None and recs:
            meta["pid"] = recs[0].get("pid")
        if recs or meta["pid"] is not None:
            if not meta["role"]:
                base = os.path.basename(path)
                meta["role"] = ("main" if base == spans.SPANS_FILE
                                else f"pid-{meta['pid']}")
            out.append((meta, recs))
    return out


def merged_records(run_dir: str) -> List[dict]:
    """Every span from every process, ``ts_ns`` rebased onto the shared
    wall clock (per-file clock offset applied), sorted by start time."""
    merged = []
    for meta, recs in load_run_spans(run_dir):
        off = int(meta.get("clock_offset_ns") or 0)
        for r in recs:
            r = dict(r)
            r["ts_ns"] = int(r["ts_ns"]) + off
            if r.get("pid") is None:
                r["pid"] = meta["pid"]
            r["role"] = meta["role"]
            merged.append(r)
    merged.sort(key=lambda r: r["ts_ns"])
    return merged


def merge_chrome_trace(run_dir: str) -> dict:
    """One Chrome trace-event JSON over ALL processes in the run dir, with
    a pid lane per process named by role (learner / host-worker-i /
    actor-i) via ``process_name`` metadata events. Timestamps are wall-
    aligned and rebased so the trace starts near zero."""
    files = load_run_spans(run_dir)
    base = None
    for meta, recs in files:
        off = int(meta.get("clock_offset_ns") or 0)
        for r in recs:
            t = int(r["ts_ns"]) + off
            if base is None or t < base:
                base = t
    base = base or 0

    events = []
    lanes = {}
    for meta, recs in files:
        off = int(meta.get("clock_offset_ns") or 0)
        pid = meta["pid"] if meta["pid"] is not None else 0
        lanes.setdefault(int(pid), meta["role"])
        for r in recs:
            events.append({
                "name": r["name"],
                "cat": "repro",
                "ph": "X",
                "ts": (int(r["ts_ns"]) + off - base) / 1e3,
                "dur": int(r["dur_ns"]) / 1e3,
                "pid": int(r.get("pid") or pid),
                "tid": int(r.get("tid") or 0),
                "args": {"depth": r.get("depth", 0),
                         "parent": r.get("parent", "")},
            })
    meta_events = [
        {"name": "process_name", "ph": "M", "pid": pid, "tid": 0,
         "args": {"name": role}}
        for pid, role in sorted(lanes.items())
    ]
    trace_ids = {m.get("trace_id") for m, _ in files if m.get("trace_id")}
    return {
        "traceEvents": meta_events + events,
        "displayTimeUnit": "ms",
        "otherData": {"trace_ids": sorted(trace_ids),
                      "processes": len(files)},
    }
