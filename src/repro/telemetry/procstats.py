"""Cross-process stat slabs: per-worker shared-memory counter rows.

Same slab idiom as ``core/shm.py`` (one segment, 64-byte-aligned sections,
numpy views, parent owns the lifecycle, workers attach untracked): a
``(rows, fields)`` int64 counter matrix plus an optional ``(rows, buckets)``
int64 histogram matrix. Each worker/actor owns exactly one row and is its
only writer, so every update is a lock-free in-place add; the parent
aggregates with one vectorized ``sum`` — **zero pickling, zero locks, zero
messages** on the stats path.

Torn reads are tolerated by design: a parent aggregate racing a worker's
int64 add can see the value from just-before or just-after the add (int64
stores are atomic on the platforms we target), never garbage. Stats survive
worker death — the rows live in the parent-owned segment, so a killed
worker's counters stay readable and survivors keep writing theirs.

jax-free: spawn workers import this before jax exists in their interpreter.
"""
from __future__ import annotations

from dataclasses import dataclass
from multiprocessing import shared_memory
from typing import Optional, Sequence, Tuple

import numpy as np

from repro.core.shm import _ALIGN, _section, attach_untracked

__all__ = ["StatSpec", "StatRow", "StatSlab",
           "HOST_FIELDS", "ACTOR_FIELDS", "STALENESS_EDGES"]

# ProcHostPool workers: env steps/resets, errors, ns spent waiting for a
# command vs. executing one, plus the wall-clock liveness beat.
# ``last_beat_ns`` is ``time.time_ns()`` (wall, cross-process comparable —
# NOT monotonic) set by the worker whenever it proves it is scheduled; the
# /healthz endpoint reads its age to tell "slow" from "dead" without
# waiting for a recv timeout. A gauge, not a counter: use ``set``.
HOST_FIELDS = ("steps", "resets", "errors", "wait_ns", "busy_ns",
               "last_beat_ns")

# actor_learner actors: env steps, committed fragments, ring-full stalls,
# seqlock read retries, param refreshes, errors, wait vs. inference ns,
# and the same wall-clock liveness beat as HOST_FIELDS.
ACTOR_FIELDS = ("steps", "fragments", "ring_full", "seqlock_retries",
                "param_loads", "errors", "wait_ns", "busy_ns",
                "last_beat_ns")

# staleness histogram (learner-updates-behind at fragment commit): buckets
# are <=0, <=1, <=2, <=4, <=8, >8
STALENESS_EDGES = (0.0, 1.0, 2.0, 4.0, 8.0)


@dataclass(frozen=True)
class StatSpec:
    """Everything a worker needs to attach its row (small and picklable)."""
    shm_name: str
    rows: int
    fields: Tuple[str, ...]
    hist_edges: Tuple[float, ...] = ()

    @property
    def hist_buckets(self) -> int:
        return len(self.hist_edges) + 1 if self.hist_edges else 0


def _layout(spec: StatSpec):
    counters_shape = (spec.rows, len(spec.fields))
    start_c, end = _section(0, counters_shape, np.int64)
    sections = {"counters": (start_c, counters_shape)}
    if spec.hist_buckets:
        hist_shape = (spec.rows, spec.hist_buckets)
        start_h, end = _section(end, hist_shape, np.int64)
        sections["hist"] = (start_h, hist_shape)
    # pad to alignment so the segment size is stable across platforms
    nbytes = ((end + _ALIGN - 1) // _ALIGN) * _ALIGN
    return sections, nbytes


class StatRow:
    """One worker's writer handle: plain int64 adds on its own row.

    Holds live views into the slab — drop every row (``del``) before
    calling ``StatSlab.close()`` or the mapping cannot unmap cleanly."""
    __slots__ = ("_row", "_hist", "_idx", "_edges")

    def __init__(self, counters: np.ndarray, hist: Optional[np.ndarray],
                 index: int, fields: Tuple[str, ...],
                 edges: Tuple[float, ...]):
        self._row = counters[index]
        self._hist = None if hist is None else hist[index]
        self._idx = {f: i for i, f in enumerate(fields)}
        self._edges = edges

    def add(self, field: str, n: int = 1) -> None:
        self._row[self._idx[field]] += n

    def set(self, field: str, v: int) -> None:
        self._row[self._idx[field]] = v

    def observe(self, v: float) -> None:
        """Bump the histogram bucket for ``v`` (no-op without a histogram)."""
        h = self._hist
        if h is None:
            return
        i = 0
        for e in self._edges:
            if v <= e:
                break
            i += 1
        h[i] += 1


class StatSlab:
    """Parent-side owner (create/aggregate/unlink) and worker-side attach
    point for one stats segment."""

    def __init__(self, spec: StatSpec, segment: shared_memory.SharedMemory,
                 owner: bool):
        self.spec = spec
        self._seg = segment
        self._owner = owner
        sections, _ = _layout(spec)
        start, shape = sections["counters"]
        self.counters = np.frombuffer(
            segment.buf, dtype=np.int64,
            count=int(np.prod(shape)), offset=start).reshape(shape)
        self.hist = None
        if "hist" in sections:
            start, shape = sections["hist"]
            self.hist = np.frombuffer(
                segment.buf, dtype=np.int64,
                count=int(np.prod(shape)), offset=start).reshape(shape)

    # -- lifecycle ---------------------------------------------------------
    @classmethod
    def create(cls, rows: int, fields: Sequence[str] = HOST_FIELDS,
               hist_edges: Sequence[float] = ()) -> "StatSlab":
        probe = StatSpec("", int(rows), tuple(fields), tuple(hist_edges))
        _, nbytes = _layout(probe)
        seg = shared_memory.SharedMemory(create=True, size=nbytes)
        spec = StatSpec(seg.name, int(rows), tuple(fields), tuple(hist_edges))
        slab = cls(spec, seg, owner=True)
        slab.counters[:] = 0
        if slab.hist is not None:
            slab.hist[:] = 0
        return slab

    @classmethod
    def attach(cls, spec: StatSpec) -> "StatSlab":
        return cls(spec, attach_untracked(spec.shm_name), owner=False)

    def close(self) -> None:
        # release views before closing the mapping (else BufferError)
        self.counters = None
        self.hist = None
        try:
            self._seg.close()
        except Exception:
            pass
        if self._owner:
            try:
                self._seg.unlink()
            except Exception:
                pass

    # -- access ------------------------------------------------------------
    def row(self, index: int) -> StatRow:
        return StatRow(self.counters, self.hist, int(index),
                       self.spec.fields, self.spec.hist_edges)

    def aggregate(self) -> dict:
        """Zero-pickle parent-side rollup: per-field totals, per-row values,
        and the summed histogram."""
        c = np.array(self.counters)          # one racing-tolerant copy
        out = {
            "rows": int(self.spec.rows),
            "total": {f: int(c[:, i].sum())
                      for i, f in enumerate(self.spec.fields)},
            "per_worker": {f: c[:, i].tolist()
                           for i, f in enumerate(self.spec.fields)},
        }
        if self.hist is not None:
            h = np.array(self.hist)
            out["hist"] = {
                "edges": list(self.spec.hist_edges),
                "counts": h.sum(axis=0).astype(int).tolist(),
                "per_worker": h.astype(int).tolist(),
            }
        return out
