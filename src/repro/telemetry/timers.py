"""TierTimer: the one SPS / launch / fetch clock shared by all engine tiers.

Before this existed, each of the five ``rl/engine.py`` tiers computed SPS
with its own ad-hoc ``time.perf_counter()`` arithmetic — five slightly
different formulas for the same number. TierTimer centralizes it so every
tier's history records carry the *same* keys with the *same* semantics:

- ``sps``       steps/sec since ``run()`` started, resume-aware (steps done
                in previous runs are subtracted from the numerator).
- ``launch_ms`` wall-time of the most recent learner/launch dispatch.
- ``fetch_ms``  wall-time of the most recent device→host metrics fetch.

``launch()`` / ``fetch()`` return context managers that both time the block
and open the matching span (``engine.launch`` / ``engine.fetch``), so the
Chrome trace and the history records agree by construction. Both contexts
are pre-built once per TierTimer on a ``CachedSpan`` — the per-launch hot
loop allocates nothing and the tracer enabled-check happens exactly once
per block entry, whether tracing is on or off.

jax-free (stdlib only).
"""
from __future__ import annotations

import time

from repro.telemetry.spans import CachedSpan

__all__ = ["TierTimer"]


class _Timed:
    """Times a block into ``timer.<attr>`` (ms) and mirrors it as a span.
    Reused across launches — one instance per (timer, attr); not reentrant,
    which launch/fetch blocks never are."""
    __slots__ = ("_timer", "_attr", "_span", "_t0")

    def __init__(self, timer: "TierTimer", attr: str, span_name: str):
        self._timer = timer
        self._attr = attr
        self._span = CachedSpan(span_name)

    def __enter__(self):
        self._span.__enter__()
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, et, ev, tb):
        setattr(self._timer, self._attr,
                (time.perf_counter() - self._t0) * 1e3)
        return self._span.__exit__(et, ev, tb)


class TierTimer:
    """Per-``run()`` clock. ``done_before_steps`` is the env-step count
    already completed by previous (resumed) runs, so a resumed run reports
    the rate of *this* run, not a number polluted by zero-cost history."""

    def __init__(self, steps_per_update: int, done_before_steps: int = 0):
        self.spu = int(steps_per_update)
        self.done_before = int(done_before_steps)
        self.t0 = time.perf_counter()
        self.launch_ms = 0.0
        self.fetch_ms = 0.0
        self._launch = _Timed(self, "launch_ms", "engine.launch")
        self._fetch = _Timed(self, "fetch_ms", "engine.fetch")

    def launch(self) -> _Timed:
        return self._launch

    def fetch(self) -> _Timed:
        return self._fetch

    def elapsed(self) -> float:
        return time.perf_counter() - self.t0

    def sps(self, env_steps: int) -> float:
        return (int(env_steps) - self.done_before) / max(
            self.elapsed(), 1e-9)

    def stamp(self, md: dict, env_steps: int) -> dict:
        """Set the unified keys on one history/metrics record in place."""
        md["env_steps"] = int(env_steps)
        md["sps"] = self.sps(env_steps)
        md["launch_ms"] = self.launch_ms
        md["fetch_ms"] = self.fetch_ms
        return md
