"""HostVecEnv — the VecEnv protocol over bridged host environments.

``wrap(env_fn)`` is the one-liner: auto-detect the env's API, derive the
emulation specs from ``core/emulation``, and return a ``HostVecEnv`` whose
batches look exactly like the JAX ``VecEnv``'s — flat f32 observations of
stable shape, flat emulated actions, autoreset with ``valid == done``
episode stats — so the policy, the learner, and the conformance harness
never notice the env lives outside jit.

Two usage modes, mirroring ``core/pool.py`` vs ``core/vector.py``:

  * async (num_envs > batch_size): ``recv()/send()`` over the first-finisher
    ``HostPool`` — M = 2N double-buffers env stepping against device compute
    (the paper's EnvPool, §3.3). This is what the TrainEngine ``host`` tier
    drives.
  * sync (num_envs == batch_size): deterministic wait-for-all rows, the
    Gymnasium/SB3 baseline; ``reset()``/``step()`` convenience methods give
    the classic loop for tests and the conformance host profile.
"""
from __future__ import annotations

from typing import Callable, Optional, Sequence, Union

import numpy as np

from repro.configs.base import TrainConfig
from repro.core import emuspec as em
from repro.core import shm as _shm
from repro.core import spaces as sp
from repro.core.host import HostPool, _UNSET
from repro.bridge import adapters as ad


class HostVecEnv:
    """N-of-M first-finisher batches of bridged host envs.

    Shapes (``A = num_agents``, rows agent-major like ``VecEnv``):
      recv obs  (batch_size, obs_dim) f32   batch_size = batch_envs * A
      recv rew  (batch_size,) f32
      recv done (batch_size,) bool          broadcast per env
      recv info {score, episode_return, episode_length, valid} (batch_envs,)
      env_ids   (batch_envs,)               which envs these rows belong to
    """

    def __init__(self, env_fns: Sequence[Callable], batch_size: int,
                 *, seed: int = 0, obs_spec: em.FlatSpec,
                 act_spec: em.ActionSpec, single_observation_space: sp.Space,
                 single_action_space: sp.Space, num_agents: int = 1,
                 horizon: Optional[int] = None,
                 recv_timeout: Optional[float] = None,
                 backend: str = "thread",
                 spin: Optional["_shm.SpinConfig"] = None):
        self.num_envs = len(env_fns)            # M simulated envs
        self.batch_envs = int(batch_size)       # N envs per batch
        self.num_agents = int(num_agents)
        self.batch_size = self.batch_envs * self.num_agents
        self.obs_spec, self.act_spec = obs_spec, act_spec
        self.obs_dim = obs_spec.total
        self.single_observation_space = single_observation_space
        self.single_action_space = single_action_space
        # emulated (Atari-shaped) spaces, like Emulated.observation_space
        self.observation_space = sp.Box((obs_spec.total,), np.float32)
        self.action_space = (sp.MultiDiscrete(act_spec.nvec)
                             if act_spec.kind == "discrete"
                             else sp.Box((act_spec.cont_dim,)))
        self.horizon = horizon
        self.backend = backend
        A = self.num_agents
        # per-env slab rows, sized from the emulation specs (used by the
        # proc backend; harmless metadata under threads)
        self.slab = _shm.SlabSpec(
            obs_shape=(A, obs_spec.total) if A > 1 else (obs_spec.total,),
            act_shape=((A, act_spec.num_components) if A > 1
                       else (act_spec.num_components,)),
            act_dtype=("int32" if act_spec.kind == "discrete"
                       else "float32"),
            rew_shape=(A,) if A > 1 else ())
        self.pool = HostPool(env_fns, batch_size=self.batch_envs, seed=seed,
                             recv_timeout=recv_timeout, backend=backend,
                             rew_shape=self.slab.rew_shape, slab=self.slab,
                             spin=spin)
        self._ids = None

    @property
    def is_sync(self) -> bool:
        return self.num_envs == self.batch_envs

    # -- async protocol (what the engine's host tier drives) -----------------
    def recv(self, timeout=_UNSET):
        """Defaults to the pool's ``recv_timeout``; ``timeout=None`` is an
        explicit wait-forever opt-in (a hung env then deadlocks the loop —
        prefer a finite timeout, which raises ``TimeoutError``)."""
        obs, rew, done, info, ids = self.pool.recv(timeout=timeout)
        A = self.num_agents
        obs = np.asarray(obs, np.float32).reshape(len(ids) * A, self.obs_dim)
        if A > 1:
            rew = np.broadcast_to(
                np.asarray(rew, np.float32).reshape(len(ids), -1),
                (len(ids), A)).reshape(len(ids) * A)
            done = np.repeat(done, A)
        return obs, rew, done, info, ids

    def send(self, actions, env_ids):
        actions = np.asarray(actions)
        if self.num_agents > 1:
            actions = actions.reshape((len(env_ids), self.num_agents)
                                      + actions.shape[1:])
        self.pool.send(actions, env_ids)

    # -- sync convenience (tests, conformance, sync baselines) ---------------
    def reset(self, timeout=_UNSET):
        """First observations (construction already queued the resets)."""
        assert self._ids is None, "reset() after stepping; build a fresh env"
        obs, _rew, _done, _info, self._ids = self.recv(timeout=timeout)
        return obs

    def step(self, actions, timeout=_UNSET):
        """``send`` for the last received rows, then ``recv`` the next batch
        (identical to the classic VecEnv step in sync mode)."""
        assert self._ids is not None, "call reset() before step()"
        self.send(actions, self._ids)
        obs, rew, done, info, self._ids = self.recv(timeout=timeout)
        return obs, rew, done, info

    @property
    def last_ids(self):
        return self._ids

    def close(self, timeout: float = 5.0):
        self.pool.close(timeout=timeout)


def wrap(env_fn: Union[Callable, object], num_envs: int = 1,
         batch_size: Optional[int] = None, *, seed: int = 0,
         api: Optional[str] = None, pad_to: Optional[int] = None,
         horizon: Optional[int] = None,
         recv_timeout: Optional[float] = TrainConfig.host_recv_timeout,
         backend: str = "thread",
         spin: Optional["_shm.SpinConfig"] = None) -> HostVecEnv:
    """One-line wrapper: any host env factory → a trainable ``HostVecEnv``.

        venv = bridge.wrap(lambda: MyGymEnv(), num_envs=8)

    ``env_fn`` — factory returning a fresh env (an instance is accepted for
    ``num_envs=1``). API style is auto-detected (``detect_api``); pass
    ``api=`` ("gymnasium" | "pettingzoo" | "duck") to skip the probe.
    ``num_envs``/``batch_size`` — M simulated / N batched; defaults give the
    synchronous baseline, ``num_envs=2 * batch_size`` the paper's
    double-buffered async pool. ``pad_to`` — pad pettingzoo agent rows to a
    fixed larger count; ``horizon`` — declared episode bound (defaults to
    the env's ``horizon`` attribute), used by the conformance host profile.
    ``recv_timeout`` — default bound on every ``recv``/``reset``/``step``
    wait (``TrainConfig.host_recv_timeout``, 60 s): a hung host env raises
    ``TimeoutError`` instead of deadlocking; ``None`` waits forever.
    ``backend`` — "thread" (default; GIL-releasing env steps) or "proc"
    (spawn processes over shared-memory slabs; pure-Python env steps
    actually parallelize). proc requires ``env_fn`` to be picklable — a
    module-level class/function or ``functools.partial``, not a lambda.
    """
    if callable(env_fn):
        probe = env_fn()
    else:
        probe, env_fn = env_fn, None
        if num_envs != 1:
            raise ValueError("pass a factory (callable) to wrap more than "
                             "one env instance")
    if api is None:
        api = ad.detect_api(probe)
    if api not in ad.APIS:
        raise ValueError(f"unknown host-env api {api!r}; expected one of "
                         f"{ad.APIS}")
    obs_space, act_space = ad.spaces_of(probe, api)
    obs_spec = em.flat_spec(obs_space, "f32")
    act_spec = em.action_spec(act_space)
    adapter_cls = ad.ADAPTERS[api]
    num_agents = 1
    kw = {}
    if api == "pettingzoo":
        num_agents = pad_to or len(probe.possible_agents)
        kw["num_agents"] = num_agents

    if backend == "proc":
        # workers rebuild envs from pickled factories; the probe instance
        # cannot be shipped, so it is only spec metadata here
        if env_fn is None:
            raise ValueError("backend='proc' needs an env *factory* "
                             "(instances cannot be shipped to workers)")
        close = getattr(probe, "close", None)
        if callable(close):
            close()
        env_fns = [ad.AdapterFactory(api, env_fn, obs_spec, act_spec,
                                     kw.get("num_agents"))
                   for _ in range(num_envs)]
    else:
        def make(fn=None, inst=None):
            return adapter_cls(inst if inst is not None else fn(),
                               obs_spec, act_spec, **kw)

        env_fns = [lambda: make(inst=probe)]    # reuse the probe as env 0
        env_fns += [lambda: make(fn=env_fn) for _ in range(num_envs - 1)]
    return HostVecEnv(
        env_fns, batch_size or num_envs, seed=seed,
        obs_spec=obs_spec, act_spec=act_spec,
        single_observation_space=obs_space, single_action_space=act_space,
        num_agents=num_agents,
        horizon=horizon if horizon is not None
        else getattr(probe, "horizon", None),
        recv_timeout=recv_timeout, backend=backend, spin=spin)


def make_host_engine(env_fn, tcfg, *, hidden: int = 64,
                     recurrent: bool = False, seed: int = 0,
                     kernel_mode: Optional[str] = None,
                     num_envs: Optional[int] = None, api: Optional[str] = None,
                     pad_to: Optional[int] = None,
                     backend: Optional[str] = None):
    """Build a ``TrainEngine(backend="host")`` around a bridged env: policy
    and distribution are sized from the bridge's emulation specs exactly as
    ``Trainer`` sizes them from ``Emulated``. ``tcfg.num_envs`` is the batch
    N; M defaults to ``tcfg.pool_buffers * N`` (M = 2N ⇒ the paper's double
    buffering). ``backend`` overrides ``tcfg.host_backend`` (worker threads
    vs shared-memory processes). Close with ``engine.hvec.close()``."""
    import jax
    from repro.models.policy import OceanPolicy
    from repro.rl.distributions import Dist
    from repro.rl.engine import TrainEngine

    N = tcfg.num_envs
    M = num_envs or tcfg.pool_buffers * N
    hv = wrap(env_fn, num_envs=M, batch_size=N, seed=seed, api=api,
              pad_to=pad_to, recv_timeout=tcfg.host_recv_timeout,
              backend=backend or tcfg.host_backend)
    if hv.act_spec.kind == "discrete":
        dist = Dist("categorical", nvec=hv.act_spec.nvec)
    else:
        dist = Dist("gaussian", cont_dim=hv.act_spec.cont_dim)
    policy = OceanPolicy(hv.obs_spec.total, dist.nvec, hidden=hidden,
                         recurrent=recurrent, num_outputs=dist.num_outputs)
    return TrainEngine(hv, policy, tcfg, dist, key=jax.random.PRNGKey(seed),
                       backend="host", kernel_mode=kernel_mode)
