"""HostBridge: one-line wrappers for third-party host environments.

    from repro import bridge
    venv = bridge.wrap(lambda: MyGymnasiumEnv(), num_envs=8)

Auto-detects Gymnasium / PettingZoo-parallel / duck-typed ``reset``+``step``
APIs, derives emulation specs from ``core/emulation``, and exposes the
VecEnv batch protocol over the first-finisher ``core/host.HostPool``.
``make_host_engine`` lifts a wrapped env into the TrainEngine's async
``host`` tier. See ``bridge/vecenv.py`` and ``bridge/adapters.py``.
"""
from repro.bridge.adapters import (ADAPTERS, APIS, DuckAdapter,
                                   GymnasiumAdapter, PettingZooAdapter,
                                   convert_space, detect_api, np_emulate_obs,
                                   np_unemulate_action, spaces_of)
from repro.bridge.vecenv import HostVecEnv, make_host_engine, wrap

__all__ = [
    "ADAPTERS", "APIS", "DuckAdapter", "GymnasiumAdapter",
    "PettingZooAdapter", "HostVecEnv", "convert_space", "detect_api",
    "make_host_engine", "np_emulate_obs", "np_unemulate_action", "spaces_of",
    "wrap",
]
