"""HostBridge adapters: any third-party host env → the HostPool protocol.

The paper's one-line-wrapper claim is that envs written for *other* stacks
(Gymnasium, PettingZoo, or nothing at all) train unchanged. This module is
the normalization layer that makes it true for host (stateful Python) envs:

  * ``detect_api`` duck-types the env into one of three styles —
    ``"gymnasium"`` (``reset(seed=) -> (obs, info)``, 5-tuple ``step``),
    ``"pettingzoo"`` (parallel API: ``possible_agents`` + per-agent dicts),
    ``"duck"`` (``reset(seed) -> obs``, 4-tuple ``step``) — without
    importing any of those libraries.
  * ``convert_space`` maps foreign space objects (again by duck-typing:
    ``.nvec`` / ``.n`` / ``.spaces`` / ``.shape``) onto ``repro.core.spaces``
    trees, so the emulation specs come from the same ``core/emulation``
    machinery the JAX envs use.
  * ``np_emulate_obs`` / ``np_unemulate_action`` are numpy twins of
    ``emulation.emulate`` / ``unemulate_action`` driven by the *same*
    ``FlatSpec`` / ``ActionSpec`` layouts — packing happens on the worker
    (thread or process), off the device, but byte-for-byte where the model
    expects it.
  * the three ``*Adapter`` classes present every style as the minimal host
    protocol ``core/host.py`` speaks: ``reset(seed) -> obs`` and
    ``step(flat_action) -> (obs, rew, done, info)`` with flat f32
    observations and flat emulated actions.
  * ``AdapterFactory`` is the picklable form of "build env, wrap in
    adapter" that the ``backend="proc"`` shared-memory workers unpickle.

This module must stay importable without jax (it runs inside spawn
workers), which is why it consumes the specs from ``core.emuspec`` — the
numpy-only half of the emulation machinery.
"""
from __future__ import annotations

from typing import Any, Callable, Mapping, Optional

import numpy as np

from repro.core import emuspec as em
from repro.core import spaces as sp

APIS = ("gymnasium", "pettingzoo", "duck")


# ---------------------------------------------------------------------------
# space conversion (duck-typed: no gymnasium/pettingzoo import)

def convert_space(space) -> sp.Space:
    """Foreign (Gymnasium-like) space → ``repro.core.spaces`` tree.

    Detection is structural: ``.nvec`` ⇒ MultiDiscrete, ``.n`` ⇒ Discrete
    (``MultiBinary`` by class name, since it also has ``.n``), ``.spaces``
    mapping/sequence ⇒ Dict/Tuple, ``.shape``+``.dtype`` ⇒ Box."""
    if isinstance(space, sp.Space):
        return space
    if type(space).__name__ == "MultiBinary":
        n = int(np.prod(np.asarray(space.n)))
        return sp.MultiDiscrete((2,) * n)
    nvec = getattr(space, "nvec", None)
    if nvec is not None:
        return sp.MultiDiscrete(tuple(int(v)
                                      for v in np.asarray(nvec).reshape(-1)))
    n = getattr(space, "n", None)
    if n is not None:
        return sp.Discrete(int(n))
    sub = getattr(space, "spaces", None)
    if sub is not None:
        if isinstance(sub, Mapping) or hasattr(sub, "items"):
            return sp.Dict({str(k): convert_space(v) for k, v in sub.items()})
        return sp.Tuple([convert_space(s) for s in sub])
    shape = getattr(space, "shape", None)
    if shape is not None:
        dtype = np.dtype(getattr(space, "dtype", None) or np.float32)
        low = np.min(np.asarray(getattr(space, "low", -np.inf)))
        high = np.max(np.asarray(getattr(space, "high", np.inf)))
        return sp.Box(tuple(int(s) for s in shape), dtype,
                      low=float(low), high=float(high))
    raise TypeError(f"cannot convert space {space!r} (type {type(space)}) "
                    f"to a repro.core.spaces tree")


# ---------------------------------------------------------------------------
# numpy emulation twins (same FlatSpec/ActionSpec layouts as core/emulation)

def np_emulate_obs(spec: em.FlatSpec, tree) -> np.ndarray:
    """Pack one unbatched obs tree into the flat f32 buffer ``spec``
    describes — the host-side twin of ``emulation.emulate``."""
    assert spec.mode == "f32", "host bridge packs model-facing f32 obs"
    out = np.empty((spec.total,), np.float32)
    for ls in spec.leaf_specs:
        x = np.asarray(sp.get_path(tree, ls.path), dtype=np.float32)
        out[ls.offset:ls.offset + ls.size] = x.reshape(-1)
    return out


def np_unemulate_action(spec: em.ActionSpec, flat) -> Any:
    """Flat emulated action row → env-native action tree (numpy / python
    scalars) — the host-side twin of ``emulation.unemulate_action``.
    Discrete leaves come back as python ints (what Gymnasium envs expect)."""
    flat = np.asarray(flat).reshape(-1)
    tree = _np_zeros_tree(spec.space)
    for ls in spec.leaf_specs:
        chunk = flat[ls.offset:ls.offset + ls.size]
        if spec.kind == "discrete" and ls.shape == ():
            leaf: Any = int(chunk[0])
        else:
            leaf = chunk.astype(np.dtype(ls.dtype)).reshape(ls.shape)
        tree = sp.set_path(tree, ls.path, leaf)
    return tree


def _np_zeros_tree(space: sp.Space):
    if isinstance(space, sp.Dict):
        return {k: _np_zeros_tree(s) for k, s in space.items()}
    if isinstance(space, sp.Tuple):
        return tuple(_np_zeros_tree(s) for s in space.spaces)
    return None                                 # leaf — filled by set_path


# ---------------------------------------------------------------------------
# API detection

def detect_api(env) -> str:
    """Which of the three host-env styles ``env`` speaks.

    PettingZoo-parallel is structural (``possible_agents``); Gymnasium vs
    duck is probed with one ``reset`` call — a keyword ``seed`` plus an
    ``(obs, info)`` 2-tuple return is the Gymnasium signature. The probe env
    is reset again by the pool before use, so the call is side-effect-free
    for training. Pass ``api=`` to ``wrap`` to skip the probe."""
    if hasattr(env, "possible_agents"):
        return "pettingzoo"
    try:
        out = env.reset(seed=0)
    except TypeError:
        return "duck"
    if (isinstance(out, tuple) and len(out) == 2
            and isinstance(out[1], dict)):
        return "gymnasium"
    return "duck"


def _pz_agent_space(env, name: str, agent):
    """PettingZoo space lookup across API generations: method
    ``observation_space(agent)`` (modern) or ``observation_spaces`` dict."""
    attr = getattr(env, name, None)
    if callable(attr):
        return attr(agent)
    maps = getattr(env, name + "s", None)
    if maps is not None:
        return maps[agent]
    raise TypeError(f"pettingzoo env exposes neither {name}(agent) nor "
                    f"{name}s")


def spaces_of(env, api: str):
    """(observation_space, action_space) as repro space trees. For
    pettingzoo-parallel envs the per-agent spaces must be homogeneous (the
    paper's fixed-size batching needs one layout for every agent row)."""
    if api != "pettingzoo":
        return (convert_space(env.observation_space),
                convert_space(env.action_space))
    agents = list(env.possible_agents)
    obs = [convert_space(_pz_agent_space(env, "observation_space", a))
           for a in agents]
    act = [convert_space(_pz_agent_space(env, "action_space", a))
           for a in agents]
    if any(o != obs[0] for o in obs) or any(a != act[0] for a in act):
        raise ValueError(
            "bridge.wrap requires homogeneous per-agent spaces on "
            "pettingzoo-parallel envs (heterogeneous agents would need "
            "per-agent emulation specs)")
    return obs[0], act[0]


# ---------------------------------------------------------------------------
# adapters: each presents `reset(seed) -> obs` / `step(a) -> (o, r, d, info)`

class DuckAdapter:
    """``reset(seed) -> obs``, ``step(a) -> (obs, rew, done, info)``."""

    api = "duck"

    def __init__(self, env, obs_spec: em.FlatSpec, act_spec: em.ActionSpec):
        self.env, self.obs_spec, self.act_spec = env, obs_spec, act_spec

    def reset(self, seed: int):
        return np_emulate_obs(self.obs_spec, self.env.reset(seed))

    def step(self, flat_action):
        a = np_unemulate_action(self.act_spec, flat_action)
        obs, rew, done, info = self.env.step(a)
        return (np_emulate_obs(self.obs_spec, obs), float(rew), bool(done),
                info if isinstance(info, dict) else {})


class GymnasiumAdapter:
    """Gymnasium API: ``reset(seed=) -> (obs, info)``,
    ``step(a) -> (obs, rew, terminated, truncated, info)``."""

    api = "gymnasium"

    def __init__(self, env, obs_spec: em.FlatSpec, act_spec: em.ActionSpec):
        self.env, self.obs_spec, self.act_spec = env, obs_spec, act_spec

    def reset(self, seed: int):
        obs, _info = self.env.reset(seed=int(seed))
        return np_emulate_obs(self.obs_spec, obs)

    def step(self, flat_action):
        a = np_unemulate_action(self.act_spec, flat_action)
        obs, rew, terminated, truncated, info = self.env.step(a)
        done = bool(terminated) or bool(truncated)
        return (np_emulate_obs(self.obs_spec, obs), float(rew), done,
                info if isinstance(info, dict) else {})


class PettingZooAdapter:
    """PettingZoo parallel API, flattened agent-major: observations are
    stacked per-agent rows in ``possible_agents`` (canonical) order, padded
    to ``num_agents`` with zero rows (the host twin of
    ``emulation.pad_agents``); rewards follow the same layout; ``done`` is
    episode-scoped (all agents terminated/truncated)."""

    api = "pettingzoo"

    def __init__(self, env, obs_spec: em.FlatSpec, act_spec: em.ActionSpec,
                 num_agents: int = None):
        self.env, self.obs_spec, self.act_spec = env, obs_spec, act_spec
        self.order = list(env.possible_agents)
        self.num_agents = num_agents or len(self.order)
        assert self.num_agents >= len(self.order)

    def _rows(self, obs_dict):
        rows = np.zeros((self.num_agents, self.obs_spec.total), np.float32)
        for j, ag in enumerate(self.order):
            if ag in obs_dict:
                rows[j] = np_emulate_obs(self.obs_spec, obs_dict[ag])
        return rows

    def reset(self, seed: int):
        obs, _infos = self.env.reset(seed=int(seed))
        return self._rows(obs)

    def step(self, flat_actions):
        flat_actions = np.asarray(flat_actions)
        live = getattr(self.env, "agents", None) or self.order
        acts = {ag: np_unemulate_action(self.act_spec, flat_actions[j])
                for j, ag in enumerate(self.order) if ag in live}
        obs, rew, term, trunc, infos = self.env.step(acts)
        rew_rows = np.zeros((self.num_agents,), np.float32)
        for j, ag in enumerate(self.order):
            rew_rows[j] = float(rew.get(ag, 0.0))
        done = all(bool(term.get(ag, True)) or bool(trunc.get(ag, True))
                   for ag in self.order)
        info: dict = {}
        scores = [i["score"] for i in infos.values()
                  if isinstance(i, dict) and "score" in i]
        if scores:
            info["score"] = float(np.mean(scores))
        return self._rows(obs), rew_rows, done, info


ADAPTERS = {
    "duck": DuckAdapter,
    "gymnasium": GymnasiumAdapter,
    "pettingzoo": PettingZooAdapter,
}


class AdapterFactory:
    """Picklable "build env, wrap in the right adapter" closure substitute.

    The proc backend ships env factories into spawn workers with plain
    pickle, so they cannot be lambdas/closures. This object carries the api
    *name* plus the (picklable) emulation specs and the user's env factory;
    calling it inside the worker constructs the env and wraps it. Also works
    under ``backend="thread"``, where picklability is simply unused."""

    def __init__(self, api: str, env_fn: Callable, obs_spec: em.FlatSpec,
                 act_spec: em.ActionSpec, num_agents: Optional[int] = None):
        assert api in ADAPTERS, api
        self.api = api
        self.env_fn = env_fn
        self.obs_spec = obs_spec
        self.act_spec = act_spec
        self.num_agents = num_agents

    def __call__(self):
        kw = {} if self.num_agents is None else {"num_agents":
                                                 self.num_agents}
        return ADAPTERS[self.api](self.env_fn(), self.obs_spec,
                                  self.act_spec, **kw)
