"""Sharding-agnostic checkpointing with atomic commit and async save.

Format: one ``.npy`` per addressable shard per array plus ``index.json``
recording global shapes, dtypes, and each shard's global slice. Restore
assembles any target sharding from whatever shards exist — the checkpoint is
valid across mesh changes (elastic restart: save on 512 chips, restore on
256) and across host counts (each host writes only its shards).

Commit protocol: write into ``<dir>/step_N.tmp``, fsync, atomic rename to
``<dir>/step_N`` — a crash mid-save never corrupts the latest checkpoint.
``latest()`` returns the newest committed step. Async mode snapshots to host
memory synchronously (cheap) and writes on a background thread, overlapping
I/O with the next training steps (straggler/jitter hiding).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Optional

import jax
import jax.numpy as jnp
import ml_dtypes
import numpy as np

from repro.telemetry import span as _span


def _np_dtype(name: str):
    """Resolve dtype names incl. ml_dtypes customs (bfloat16, int4, ...)."""
    try:
        d = np.dtype(name)
        if d.kind != "V":
            return d
    except TypeError:
        pass
    return np.dtype(getattr(ml_dtypes, name))


def _to_storable(arr: np.ndarray):
    """Custom dtypes (kind 'V': bfloat16/int4/...) round-trip through .npy
    as raw void — store them viewed as uint8 instead."""
    if arr.dtype.kind == "V":
        return arr.view(np.uint8)
    return arr


def _from_storable(arr: np.ndarray, dtype_name: str) -> np.ndarray:
    want = _np_dtype(dtype_name)
    if want.kind == "V" or arr.dtype == np.uint8 and want != np.uint8:
        return arr.view(want)
    return arr.astype(want, copy=False)


def _flatten(tree):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def _names(tree):
    paths = jax.tree_util.tree_flatten_with_path(tree)[0]
    return ["/".join(str(getattr(k, "key", getattr(k, "idx", getattr(k, "name", k))))
                     for k in path) for path, _ in paths]


def _slice_spec(idx, shape):
    out = []
    for sl, n in zip(idx, shape):
        start = 0 if sl.start is None else int(sl.start)
        stop = n if sl.stop is None else int(sl.stop)
        out.append([start, stop])
    return out


def save(directory: str, tree, step: Optional[int] = None,
         async_: bool = False, keep: Optional[int] = 3):
    """Save ``tree``. Returns the committed path (or a join handle if async).
    ``keep=None`` disables GC — every step is kept (the policy-league store
    is an archive, not a ring buffer)."""
    leaves, _ = _flatten(tree)
    names = _names(tree)
    step = int(step if step is not None else _next_step(directory))
    final = os.path.join(directory, f"step_{step}")
    tmp = final + ".tmp"

    # synchronous device→host snapshot (consistent view)
    with _span("ckpt.snapshot"):
        host = [np.asarray(l) if not hasattr(l, "addressable_shards")
                else l for l in leaves]
        shards = []
        index = {"arrays": {}, "step": step}
        for name, leaf in zip(names, host):
            if hasattr(leaf, "addressable_shards"):
                entry = {"shape": list(leaf.shape), "dtype": str(leaf.dtype),
                         "shards": []}
                for i, s in enumerate(leaf.addressable_shards):
                    fn = f"{name.replace('/', '.')}.{s.device.id}.npy"
                    entry["shards"].append(
                        {"file": fn,
                         "slice": _slice_spec(s.index, leaf.shape)})
                    shards.append((fn, _to_storable(np.asarray(s.data))))
                index["arrays"][name] = entry
            else:
                arr = np.asarray(leaf)
                fn = f"{name.replace('/', '.')}.full.npy"
                index["arrays"][name] = {
                    "shape": list(arr.shape), "dtype": str(arr.dtype),
                    "shards": [{"file": fn,
                                "slice": _slice_spec(
                                    (slice(None),) * arr.ndim, arr.shape)}]}
                shards.append((fn, _to_storable(arr)))

    def _write():
        with _span("ckpt.write"):
            os.makedirs(tmp, exist_ok=True)
            for fn, arr in shards:
                np.save(os.path.join(tmp, fn), arr)
            with open(os.path.join(tmp, "index.json"), "w") as f:
                json.dump(index, f)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)        # atomic commit
            if keep is not None:
                _gc(directory, keep)

    if async_:
        t = threading.Thread(target=_write, daemon=True)
        t.start()
        return t
    _write()
    return final


def _steps(directory: str):
    if not os.path.isdir(directory):
        return []
    out = []
    for d in os.listdir(directory):
        if d.startswith("step_") and not d.endswith(".tmp"):
            try:
                out.append(int(d.split("_")[1]))
            except ValueError:
                pass
    return sorted(out)


def _next_step(directory: str) -> int:
    s = _steps(directory)
    return (s[-1] + 1) if s else 0


def latest(directory: str) -> Optional[str]:
    s = _steps(directory)
    return os.path.join(directory, f"step_{s[-1]}") if s else None


def step_of(path: str) -> int:
    """The step a committed checkpoint was saved at, from its own metadata.

    Reads ``index.json`` (``save`` always records ``"step"``), falling back
    to the ``step_N`` basename for pre-metadata checkpoints. Never parses
    the surrounding directory path — a manually named dir (``best_model_v2``)
    or an underscored ``ckpt_dir`` must not change the answer."""
    try:
        with open(os.path.join(path, "index.json")) as f:
            step = json.load(f).get("step")
        if step is not None:
            return int(step)
    except (OSError, ValueError):
        pass
    base = os.path.basename(os.path.normpath(path))
    if base.startswith("step_"):
        try:
            return int(base[len("step_"):])
        except ValueError:
            pass
    raise ValueError(
        f"cannot determine the step of checkpoint {path!r}: no 'step' in "
        f"index.json and basename is not of the form step_<N>")


def _gc(directory: str, keep: int):
    for s in _steps(directory)[:-keep]:
        shutil.rmtree(os.path.join(directory, f"step_{s}"),
                      ignore_errors=True)


def restore(path_or_dir: str, like, shardings=None):
    """Restore into the structure of ``like`` (arrays or ShapeDtypeStructs).
    ``shardings``: optional matching tree of jax.sharding.Sharding — shards
    are assembled per-device (reshard-on-restore)."""
    with _span("ckpt.restore"):
        return _restore(path_or_dir, like, shardings)


def _restore(path_or_dir: str, like, shardings=None):
    path = path_or_dir
    if not os.path.exists(os.path.join(path, "index.json")):
        path = latest(path_or_dir)
        if path is None:
            raise FileNotFoundError(f"no checkpoint in {path_or_dir}")
    with open(os.path.join(path, "index.json")) as f:
        index = json.load(f)

    leaves, treedef = _flatten(like)
    names = _names(like)
    shard_leaves = (jax.tree.flatten(shardings)[0] if shardings is not None
                    else [None] * len(leaves))
    out = []
    for name, leaf, shd in zip(names, leaves, shard_leaves):
        entry = index["arrays"][name]
        shape, dtype = tuple(entry["shape"]), _np_dtype(entry["dtype"])

        def read_region(region_idx, entry=entry, shape=shape, dtype=dtype,
                        path=path):
            """Assemble an arbitrary global slice from saved shards."""
            want = [(0 if s.start is None else s.start,
                     n if s.stop is None else s.stop)
                    for s, n in zip(region_idx, shape)]
            out = np.zeros([b - a for a, b in want], dtype)
            for sh in entry["shards"]:
                src_sl, dst_sl, overlap = [], [], True
                for (ws, we), (ss, se) in zip(want, sh["slice"]):
                    lo, hi = max(ws, ss), min(we, se)
                    if lo >= hi:
                        overlap = False
                        break
                    src_sl.append(slice(lo - ss, hi - ss))
                    dst_sl.append(slice(lo - ws, hi - ws))
                if not overlap:
                    continue
                data = _from_storable(np.load(os.path.join(path, sh["file"])),
                                      entry["dtype"])
                out[tuple(dst_sl)] = data[tuple(src_sl)]
            return out

        if shd is not None:
            arr = jax.make_array_from_callback(shape, shd, lambda idx,
                                               rr=read_region: rr(idx))
        else:
            full = read_region((slice(None),) * len(shape))
            arr = jnp.asarray(full)
        out.append(arr)
    return jax.tree.unflatten(treedef, out)
