"""Sharded AdamW.

States inherit the parameter PartitionSpecs (ZeRO: FSDP-sharded params ⇒
FSDP-sharded moments, never gathered). For >100B-parameter models the
moments can be stored bfloat16 (``state_dtype``) — together with bf16 params
this is what fits llama4-maverick training on a 256-chip v5e pod
(DESIGN.md §4). Global-norm clipping runs in f32.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    m: any
    v: any


def init(params, state_dtype=jnp.float32):
    zeros = lambda p: jnp.zeros(p.shape, state_dtype)
    return AdamWState(jnp.zeros((), jnp.int32),
                      jax.tree.map(zeros, params),
                      jax.tree.map(zeros, params))


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def update(grads, state: AdamWState, params, *, lr, b1=0.9, b2=0.95,
           eps=1e-8, weight_decay=0.0, max_grad_norm=0.0):
    """Returns (new_params, new_state, stats)."""
    gnorm = global_norm(grads)
    if max_grad_norm:
        scale = jnp.minimum(1.0, max_grad_norm / jnp.maximum(gnorm, 1e-12))
        grads = jax.tree.map(lambda g: g * scale.astype(g.dtype), grads)

    step = state.step + 1
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        m32 = b1 * m.astype(jnp.float32) + (1 - b1) * g32
        v32 = b2 * v.astype(jnp.float32) + (1 - b2) * jnp.square(g32)
        u = (m32 / c1) / (jnp.sqrt(v32 / c2) + eps)
        if weight_decay:
            u = u + weight_decay * p.astype(jnp.float32)
        p2 = p.astype(jnp.float32) - lr * u
        return p2.astype(p.dtype), m32.astype(m.dtype), v32.astype(v.dtype)

    out = jax.tree.map(upd, params, grads, state.m, state.v)
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree.map(lambda t: t[1], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree.map(lambda t: t[2], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    return new_params, AdamWState(step, new_m, new_v), {"grad_norm": gnorm}
