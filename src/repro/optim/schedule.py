"""LR schedules (pure functions of the int32 step)."""
import jax.numpy as jnp


def warmup_cosine(step, *, peak_lr, warmup_steps, total_steps, final_frac=0.1):
    s = step.astype(jnp.float32)
    warm = peak_lr * s / jnp.maximum(1.0, float(warmup_steps))
    t = jnp.clip((s - warmup_steps) / max(1.0, total_steps - warmup_steps),
                 0.0, 1.0)
    cos = peak_lr * (final_frac + (1 - final_frac) * 0.5
                     * (1 + jnp.cos(jnp.pi * t)))
    return jnp.where(s < warmup_steps, warm, cos)


def constant(step, *, peak_lr, **_):
    return jnp.full((), peak_lr, jnp.float32)
