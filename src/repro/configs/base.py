"""Config system.

Every architecture (and the paper's own Ocean suite) is described by a frozen
dataclass. Configs are *exact* per the assignment; any deliberate deviation is
documented in DESIGN.md §3 (llama4 moe_period, TP padding, vocab padding).

The model code reads only from these dataclasses — there is no other source of
architecture truth in the framework.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@dataclass(frozen=True)
class ModelConfig:
    """Backbone definition for a token-level policy."""

    name: str
    family: str                      # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int                   # query heads (0 for attn-free)
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 128

    # -- attention details --------------------------------------------------
    qk_norm: bool = False            # qwen3-style per-head RMSNorm on q,k
    use_rope: bool = True            # jamba: no positional encoding
    rope_theta: float = 10_000.0
    mlp_activation: str = "silu"     # silu => SwiGLU, gelu => GeGLU
    attn_logit_softcap: float = 0.0

    # -- MoE ----------------------------------------------------------------
    num_experts: int = 0
    top_k: int = 0
    moe_period: int = 1              # every `moe_period`-th layer is MoE
    moe_d_ff: int = 0                # expert hidden (defaults to d_ff)
    capacity_factor: float = 1.25

    # -- SSM (mamba2) ---------------------------------------------------------
    ssm_state: int = 0               # d_state; 0 => no SSM layers
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_conv: int = 4
    ssm_groups: int = 1              # B/C projection groups
    ssm_chunk: int = 128             # SSD chunk length
    attn_period: int = 0             # hybrid: every `attn_period`-th layer is
                                     # attention (jamba: 8 => 1:7), 0 => none

    # -- modality frontend (stub; see DESIGN.md) ------------------------------
    frontend: Optional[str] = None   # "vlm" | "audio"
    frontend_prefix: int = 256       # precomputed embedding prefix length

    # -- numerics / memory ----------------------------------------------------
    dtype: str = "bfloat16"          # activation dtype
    param_dtype: str = "bfloat16"
    remat: str = "full"              # full | dots | none
    tie_embeddings: bool = False
    norm_eps: float = 1e-6

    # -- RL policy head --------------------------------------------------------
    value_head: bool = True          # PPO critic head

    # Derived ------------------------------------------------------------------
    @property
    def d_inner(self) -> int:        # mamba inner width
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim if self.ssm_state else 0

    @property
    def expert_d_ff(self) -> int:
        return self.moe_d_ff or self.d_ff

    def is_moe_layer(self, i: int) -> bool:
        if self.num_experts == 0:
            return False
        # MoE on layers where (i % moe_period) == moe_period - 1, matching
        # interleaved dense/MoE stacks (llama4 maverick, jamba).
        return (i % self.moe_period) == (self.moe_period - 1)

    def is_attn_layer(self, i: int) -> bool:
        """Hybrid stacks: which layers are attention (rest are SSM)."""
        if self.ssm_state == 0:
            return True              # pure transformer
        if self.attn_period == 0:
            return False             # pure SSM
        return (i % self.attn_period) == (self.attn_period - 1)

    @property
    def attn_free(self) -> bool:
        return self.ssm_state > 0 and self.attn_period == 0

    @property
    def subquadratic(self) -> bool:
        """Can this arch run long_500k decode? SSM and hybrids can: their
        state (or data-axis-sharded KV for the sparse attention layers) is
        sub-quadratic in context. Pure full-attention archs cannot."""
        return self.ssm_state > 0

    # -- TP-aligned (padded) sizes --------------------------------------------
    def padded_heads(self, tp: int) -> int:
        return _round_up(self.num_heads, tp) if self.num_heads else 0

    def padded_kv_heads(self, tp: int) -> int:
        if not self.num_kv_heads:
            return 0
        kv = self.num_kv_heads
        if kv < tp:
            # replicate whole KV heads so each shard owns >=1 (GQA practice)
            assert tp % kv == 0, (self.name, kv, tp)
            return tp
        return _round_up(kv, tp)

    def padded_vocab(self, multiple: int = 128) -> int:
        return _round_up(self.vocab_size, multiple)


@dataclass(frozen=True)
class MeshConfig:
    shape: tuple = (16, 16)
    axes: tuple = ("data", "model")

    @property
    def data_axes(self) -> tuple:
        return tuple(a for a in self.axes if a in ("pod", "data"))

    @property
    def tp(self) -> int:
        return dict(zip(self.axes, self.shape)).get("model", 1)

    @property
    def dp(self) -> int:
        d = dict(zip(self.axes, self.shape))
        return d.get("pod", 1) * d.get("data", 1)


@dataclass(frozen=True)
class TrainConfig:
    """PPO / optimization hyperparameters (Clean PuffeRL defaults)."""
    learning_rate: float = 3e-4
    adam_b1: float = 0.9
    adam_b2: float = 0.95
    adam_eps: float = 1e-8
    weight_decay: float = 0.0
    max_grad_norm: float = 1.0
    warmup_steps: int = 100
    optimizer_state_dtype: str = "float32"   # "bfloat16" for >100B models

    # PPO
    gamma: float = 0.99
    gae_lambda: float = 0.95
    clip_coef: float = 0.2
    vf_coef: float = 0.5
    vf_clip: float = 0.2
    ent_coef: float = 0.01
    update_epochs: int = 4
    num_minibatches: int = 4
    norm_adv: bool = True
    target_kl: float = 0.0           # 0 => disabled

    # rollout
    unroll_length: int = 128
    num_envs: int = 64
    pool_buffers: int = 2            # EnvPool double buffering (M = buffers*N)

    # training engine (rl/engine.py)
    updates_per_launch: int = 1      # K: fused updates per host dispatch
    engine_backend: str = "jit"      # jit | shard_map | pool | host | async
    host_recv_timeout: float = 60.0  # host tier: bound on one first-finisher
                                     # batch (turns a hung worker into an
                                     # error instead of a deadlocked run)
    host_backend: str = "thread"     # host tier workers: "thread" (GIL-
                                     # releasing C/sleep steps) | "proc"
                                     # (pure-Python steps; shared-memory
                                     # spawn processes — core/host.py)

    # async actor–learner tier (distributed/actor_learner.py)
    num_actors: int = 2              # spawn actor processes
    shards_per_actor: int = 1        # env shards per actor (num_shards =
                                     # num_actors * shards_per_actor)
    actor_slots: int = 2             # fragment ring depth per shard; small
                                     # on purpose — backpressure bounds how
                                     # stale an actor's next fragment can be
    max_staleness: int = 2           # versions; fragments older than this are
                                     # dropped ("drop") or importance-clipped
                                     # ("vtrace") per staleness_mode
    staleness_mode: str = "drop"     # drop | vtrace
    vtrace_rho: float = 1.0          # rho-bar clamp (vtrace mode)
    vtrace_c: float = 1.0            # c-bar clamp (vtrace mode)
    async_recv_timeout: float = 120.0  # bound on waiting for one update's
                                       # fragments (hang -> error)
    actor_jitter_ms: float = 0.0     # injected per-step actor latency
                                     # (benchmarks / fault injection)

    # fault tolerance
    checkpoint_every: int = 100
    checkpoint_dir: str = "/tmp/repro_ckpt"
    keep_checkpoints: int = 3

    # observability (telemetry/http.py)
    metrics_port: int = 0            # 0 = no monitoring server; >0 binds
                                     # /metrics, /healthz, /spans on
                                     # 127.0.0.1:<port> for the run


@dataclass(frozen=True)
class ShapeConfig:
    """One assigned input-shape cell."""
    name: str                        # train_4k | prefill_32k | decode_32k | long_500k
    kind: str                        # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k":    ShapeConfig("train_4k",    "train",   4_096,   256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32_768,  32),
    "decode_32k":  ShapeConfig("decode_32k",  "decode",  32_768,  128),
    "long_500k":   ShapeConfig("long_500k",   "decode",  524_288, 1),
}


class ShapeNotApplicable(Exception):
    """Raised for (arch, shape) cells excluded by the assignment rules
    (long_500k on pure full-attention archs)."""


def check_applicable(model: ModelConfig, shape: ShapeConfig) -> None:
    if shape.name == "long_500k" and not model.subquadratic:
        raise ShapeNotApplicable(
            f"{model.name} is pure full-attention; long_500k requires a "
            f"sub-quadratic mechanism (see DESIGN.md §Arch-applicability)")


def with_overrides(cfg, **kw):
    return dataclasses.replace(cfg, **kw)
