"""Llama-4 Maverick 400B-A17B [hf:meta-llama; unverified].

48L d_model=5120 40H (GQA kv=8) d_ff=8192 vocab=202048, MoE 128e top-1.
moe_period=2 (alternating dense/MoE) so total params match the 400B name —
the literal every-layer reading gives ~775B; see DESIGN.md §3.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b", family="moe",
    num_layers=48, d_model=5120, num_heads=40, num_kv_heads=8, head_dim=128,
    d_ff=8192, vocab_size=202048,
    num_experts=128, top_k=1, moe_period=2, moe_d_ff=8192,
    rope_theta=500000.0,
)
