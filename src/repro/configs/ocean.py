"""Per-env training presets for the Ocean suite (original eight + Ocean II).

One place records the knobs each scenario needs to solve (score > 0.9) in a
CI-smoke budget: policy width, LSTM for the memory env, the CNN frontend for
pixel envs, and the env-step budget. ``launch.train --ocean`` and the smoke
tests read these so "train env X" never re-hardcodes per-env flags.
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.configs.base import TrainConfig


@dataclass(frozen=True)
class OceanPreset:
    hidden: int = 64
    recurrent: bool = False
    conv: bool = None                # None → env's obs_frontend attr decides
    total_steps: int = 200_000
    target_score: float = 0.9
    tcfg_overrides: tuple = ()       # ((field, value), ...) on the base tcfg


def ocean_tcfg(name: str, **overrides) -> TrainConfig:
    """The Ocean training config: the launcher's defaults + the env preset's
    overrides + caller overrides (highest precedence)."""
    base = dict(num_envs=64, unroll_length=64, update_epochs=4,
                num_minibatches=4, learning_rate=1e-3, gamma=0.95)
    base.update(dict(preset(name).tcfg_overrides))
    base.update(overrides)
    return TrainConfig(**base)


OCEAN_PRESETS = {
    "squared": OceanPreset(total_steps=300_000),
    "password": OceanPreset(total_steps=300_000),
    "stochastic": OceanPreset(),
    "memory": OceanPreset(recurrent=True, total_steps=500_000),
    "multiagent": OceanPreset(total_steps=150_000),
    "spaces": OceanPreset(),
    "bandit": OceanPreset(total_steps=150_000),
    "continuous": OceanPreset(total_steps=400_000),
    # Ocean II — budgets/overrides are where PPO (seed 0) solves with margin
    "pong": OceanPreset(),           # conv picked up from Pong.obs_frontend
    "drone": OceanPreset(total_steps=1_000_000,
                         # entropy bonus keeps the Gaussian σ too wide to
                         # hover precisely; solved at ~650k with it off
                         tcfg_overrides=(("ent_coef", 0.0),)),
    "tagteam": OceanPreset(total_steps=600_000,
                           tcfg_overrides=(("ent_coef", 0.003),)),
    "maze": OceanPreset(total_steps=1_000_000,   # procgen: fresh maze/episode
                        tcfg_overrides=(("gamma", 0.98),)),
    # Policy League — duel trains under self-play (launch.train --selfplay):
    # score vs the frozen pool hovers near 0.5 by construction, so the
    # solved criterion is arena winrate vs the random baseline, not score
    "duel": OceanPreset(total_steps=300_000, target_score=0.9),
}


def preset(name: str) -> OceanPreset:
    return OCEAN_PRESETS.get(name, OceanPreset())
