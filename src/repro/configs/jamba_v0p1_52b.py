"""Jamba v0.1 52B [arXiv:2403.19887; hf]. Mamba+attention 1:7, MoE 16e top-2.

32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=65536; attention every 8th
layer (1:7 interleave), MoE every 2nd layer.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="jamba-v0.1-52b", family="hybrid",
    num_layers=32, d_model=4096, num_heads=32, num_kv_heads=8, head_dim=128,
    d_ff=14336, vocab_size=65536,
    num_experts=16, top_k=2, moe_period=2, moe_d_ff=14336,
    ssm_state=16, ssm_head_dim=64, ssm_expand=2, attn_period=8,
    use_rope=False,
)
