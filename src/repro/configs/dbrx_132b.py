"""DBRX 132B [hf:databricks/dbrx-base; unverified].

40L d_model=6144 48H (GQA kv=8) d_ff=10752 vocab=100352, MoE 16e top-4
(fine-grained, every layer).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="dbrx-132b", family="moe",
    num_layers=40, d_model=6144, num_heads=48, num_kv_heads=8, head_dim=128,
    d_ff=10752, vocab_size=100352,
    num_experts=16, top_k=4, moe_period=1, moe_d_ff=10752,
    rope_theta=500000.0,
)
