"""MusicGen medium [arXiv:2306.05284; hf]. Decoder-only over EnCodec tokens.

48L d_model=1536 24H (kv=24) d_ff=6144 vocab=2048. The EnCodec frontend is a
STUB: input_specs() provides precomputed frame embeddings.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-medium", family="audio",
    num_layers=48, d_model=1536, num_heads=24, num_kv_heads=24, head_dim=64,
    d_ff=6144, vocab_size=2048, mlp_activation="gelu",
    frontend="audio", frontend_prefix=256,
)
