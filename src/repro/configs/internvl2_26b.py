"""InternVL2 26B [arXiv:2404.16821; hf]. InternViT frontend + InternLM2-20B.

Backbone: 48L d_model=6144 48H (GQA kv=8) d_ff=16384 vocab=92553.
The InternViT vision tower is a STUB frontend: input_specs() provides
precomputed patch embeddings (see DESIGN.md §3).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-26b", family="vlm",
    num_layers=48, d_model=6144, num_heads=48, num_kv_heads=8, head_dim=128,
    d_ff=16384, vocab_size=92553, rope_theta=1000000.0,
    frontend="vlm", frontend_prefix=256,
)
