"""Architecture registry: one module per assigned architecture.

``get_config(arch)`` returns the exact full-size config; ``get_smoke_config``
returns a reduced same-family config for CPU smoke tests (small widths, few
experts, tiny vocab) — the full configs are exercised only via the dry-run.
"""
from __future__ import annotations

import dataclasses

from repro.configs.base import (ModelConfig, MeshConfig, TrainConfig,
                                ShapeConfig, SHAPES, ShapeNotApplicable,
                                check_applicable, with_overrides)

from repro.configs import (llama4_maverick_400b_a17b, dbrx_132b, mamba2_1p3b,
                           gemma_7b, internlm2_20b, stablelm_12b, qwen3_0p6b,
                           internvl2_26b, musicgen_medium, jamba_v0p1_52b)

_MODULES = {
    "llama4-maverick-400b-a17b": llama4_maverick_400b_a17b,
    "dbrx-132b": dbrx_132b,
    "mamba2-1.3b": mamba2_1p3b,
    "gemma-7b": gemma_7b,
    "internlm2-20b": internlm2_20b,
    "stablelm-12b": stablelm_12b,
    "qwen3-0.6b": qwen3_0p6b,
    "internvl2-26b": internvl2_26b,
    "musicgen-medium": musicgen_medium,
    "jamba-v0.1-52b": jamba_v0p1_52b,
}

ARCHS = tuple(_MODULES)


def get_config(arch: str) -> ModelConfig:
    return _MODULES[arch].CONFIG


def get_smoke_config(arch: str) -> ModelConfig:
    """Reduced config of the same family, runnable on one CPU core."""
    cfg = get_config(arch)
    kw = dict(
        num_layers=min(cfg.num_layers, 4),
        d_model=128,
        d_ff=256,
        vocab_size=512,
        head_dim=32,
        num_heads=4 if cfg.num_heads else 0,
        num_kv_heads=2 if cfg.num_kv_heads else 0,
        frontend_prefix=8 if cfg.frontend else 0,
    )
    if cfg.num_experts:
        kw.update(num_experts=4, top_k=min(cfg.top_k, 2), moe_d_ff=256)
    if cfg.ssm_state:
        kw.update(ssm_state=16, ssm_head_dim=16, ssm_chunk=16)
    if cfg.attn_period:
        kw.update(attn_period=2, num_layers=4)
    if cfg.moe_period > 1:
        kw.update(moe_period=2)
    return with_overrides(cfg, **kw)


__all__ = ["ModelConfig", "MeshConfig", "TrainConfig", "ShapeConfig", "SHAPES",
           "ShapeNotApplicable", "check_applicable", "with_overrides",
           "ARCHS", "get_config", "get_smoke_config"]
