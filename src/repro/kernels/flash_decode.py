"""Flash-decode: one-token attention against a long KV cache, as a Pallas
TPU kernel — the serve_step hot spot (decode_32k / long_500k shapes).

Per (batch, kv-head) grid cell the kernel streams (block_s × hd) KV tiles
through VMEM and attends all G grouped query heads against them at once
(GQA: the tile is loaded once per group, not per query head). The online
softmax statistics (m, l) and the (G × hd) output accumulator live in VMEM
scratch across the sequential KV-block dimension. Positions beyond the
filled cache length are masked, so one compiled kernel serves every prefix
length.

Grid: (B, K, S / block_s) — last dim sequential.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import compiler_params

NEG_INF = -1e30


def _kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            scale: float, block_s: int, num_blocks: int):
    si = pl.program_id(2)

    @pl.when(si == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)                  # (G, hd)
    k = k_ref[0, 0].astype(jnp.float32)                  # (bs, hd)
    v = v_ref[0, 0].astype(jnp.float32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale  # (G, bs)

    # mask positions beyond the filled cache prefix (length is inclusive of
    # the token being attended from: positions [0, length] are valid)
    pos = si * block_s + jax.lax.broadcasted_iota(jnp.int32,
                                                  (q.shape[0], block_s), 1)
    s = jnp.where(pos <= len_ref[0], s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = (acc_ref[...] * alpha +
                    jax.lax.dot_general(p, v, (((1,), (0,)), ((), ()))))
    m_ref[...] = m_new

    @pl.when(si == num_blocks - 1)
    def _finish():
        o_ref[0, 0] = (acc_ref[...] /
                       jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_s", "interpret"))
def flash_decode(q, k, v, length, *, block_s: int = 512,
                 interpret: bool = False):
    """q: (B, H, hd) one query per sequence; k, v: (B, S, K, hd) caches;
    length: () int32 — index of the newest valid cache entry.
    Returns (B, H, hd)."""
    B, H, hd = q.shape
    S, K = k.shape[1], k.shape[2]
    G = H // K
    scale = 1.0 / (hd ** 0.5)
    block_s = min(block_s, S)
    assert S % block_s == 0
    nb = S // block_s

    qg = q.reshape(B, K, G, hd)
    kh = jnp.moveaxis(k, 2, 1)            # (B, K, S, hd)
    vh = jnp.moveaxis(v, 2, 1)
    lens = jnp.broadcast_to(jnp.asarray(length, jnp.int32), (1,))

    grid = (B, K, nb)
    out = pl.pallas_call(
        functools.partial(_kernel, scale=scale, block_s=block_s,
                          num_blocks=nb),
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1, G, hd), lambda b, h, s: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, block_s, hd), lambda b, h, s: (b, h, s, 0)),
            pl.BlockSpec((1, 1, block_s, hd), lambda b, h, s: (b, h, s, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, hd), lambda b, h, s: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, K, G, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, hd), jnp.float32),
        ],
        compiler_params=compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(lens, qg, kh, vh)
    return out.reshape(B, H, hd)
