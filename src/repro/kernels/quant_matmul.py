"""Weight-quantized matmul (W8A16 / W4A16) as a Pallas TPU kernel.

Serving at 400B scale only fits a pod with ≤8-bit weights, and the win only
materializes if dequantization happens *in registers*: the kernel streams
int8/int4 weight tiles into VMEM, dequantizes per output channel, and feeds
the MXU — HBM traffic is the quantized bytes, never a materialized bf16
weight. (An XLA-level dequant writes the bf16 weight back to HBM first —
~3x the traffic; measured in EXPERIMENTS.md §Perf.)

Grid: (M/bm, N/bn, K/bk) — K sequential, f32 accumulator in VMEM scratch.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import compiler_params


def _kernel(x_ref, wq_ref, scale_ref, o_ref, acc_ref, *, nk: int):
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...].astype(jnp.float32)              # (bm, bk)
    w = wq_ref[...].astype(jnp.float32)             # (bk, bn) dequant in VREG
    acc_ref[...] += jax.lax.dot_general(x, w, (((1,), (0,)), ((), ())))

    @pl.when(ki == nk - 1)
    def _finish():
        s = scale_ref[...].astype(jnp.float32)      # (1, bn)
        o_ref[...] = (acc_ref[...] * s).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_m", "block_n", "block_k",
                                             "interpret"))
def quant_matmul(x, w_q, scale, *, block_m: int = 128, block_n: int = 128,
                 block_k: int = 128, interpret: bool = False):
    """x: (M, K) bf16/f32; w_q: (K, N) int8/int4; scale: (N,) f32.
    Returns x @ (w_q * scale) in x.dtype."""
    M, K = x.shape
    K2, N = w_q.shape
    assert K == K2 and scale.shape == (N,)
    block_m = min(block_m, M)
    block_n = min(block_n, N)
    block_k = min(block_k, K)
    assert M % block_m == 0 and N % block_n == 0 and K % block_k == 0
    nk = K // block_k
    grid = (M // block_m, N // block_n, nk)
    return pl.pallas_call(
        functools.partial(_kernel, nk=nk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, k: (i, k)),
            pl.BlockSpec((block_k, block_n), lambda i, j, k: (k, j)),
            pl.BlockSpec((1, block_n), lambda i, j, k: (0, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), x.dtype),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)],
        compiler_params=compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x, w_q, scale[None, :])
