"""Public kernel ops — thin wrappers over the dispatch registry.

Every op registers up to four backends in ``kernels.dispatch``:

  ref              — pure-jnp oracle (CPU default)
  chunked          — kernel-equivalent jnp program under a ``KERNEL_`` named
                     scope (dry-run roofline lowering; the HLO of these
                     regions stands in for the Pallas kernel, see
                     launch.hlo_analysis)
  pallas_interpret — the real Pallas kernel body interpreted on CPU
                     ("interpret" is accepted as an alias)
  pallas           — compiled Pallas (TPU default)

Selection: explicit ``mode=`` > ``dispatch.using(...)`` scope >
``REPRO_KERNEL_<OP>`` / ``REPRO_KERNELS`` env > cached autotune winner >
platform default. ``mode=None`` and ``mode="auto"`` both mean
"dispatch decides"; see kernels/dispatch.py.
"""
from __future__ import annotations

import jax

from repro.kernels import dispatch
from repro.kernels import ref as _ref

# The Pallas kernel modules are optional: a JAX build without pallas (or
# with incompatible API drift beyond what kernels.compat shims) still
# serves every op through ``ref``/``chunked``.
try:
    from repro.kernels import flash_attention as _fa
    from repro.kernels import ssd as _ssd
    from repro.kernels import gae_scan as _gae
    from repro.kernels import pack as _pack
    from repro.kernels import quant_matmul as _qmm
    from repro.kernels import flash_decode as _fd
    HAS_PALLAS_KERNELS = True
except ImportError:   # pragma: no cover — exercised only without pallas
    HAS_PALLAS_KERNELS = False


# "KERNEL_" named scopes mark regions whose HLO stands in for a Pallas kernel
# during CPU dry-run lowering: launch.hlo_analysis excludes their *internal*
# HBM traffic (VMEM-resident on the real TPU kernel) while keeping their
# FLOPs. Inputs/outputs are still counted by the unmarked neighbor ops.
# Scopes are created fresh per call — jax.named_scope context managers are
# single-use (the mlp_apply reuse bug class; see tests/test_dispatch.py).


# -- flash_attention ----------------------------------------------------------

@dispatch.register("flash_attention", dispatch.REF)
def _fa_ref(q, k, v, *, causal=True, block_q=128, block_k=128):
    return _ref.flash_attention(q, k, v, causal=causal)


@dispatch.register("flash_attention", dispatch.CHUNKED)
def _fa_chunked(q, k, v, *, causal=True, block_q=128, block_k=128):
    with jax.named_scope("KERNEL_flash"):
        return _ref.flash_attention_chunked(q, k, v, causal=causal)


# -- ssd ----------------------------------------------------------------------

@dispatch.register("ssd", dispatch.REF)
def _ssd_ref(x, dt, A, B_, C, *, chunk=128):
    return _ref.ssd(x, dt, A, B_, C)


@dispatch.register("ssd", dispatch.CHUNKED)
def _ssd_chunked(x, dt, A, B_, C, *, chunk=128):
    with jax.named_scope("KERNEL_ssd"):
        return _ref.ssd_chunked(x, dt, A, B_, C, chunk=chunk)


# -- gae ----------------------------------------------------------------------

def _gae_ref(rewards, values, dones, last_value, gamma, lam, *, block_t=128):
    with jax.named_scope("KERNEL_gae"):
        return _ref.gae(rewards, values, dones, last_value, gamma, lam)


dispatch.register("gae", dispatch.REF)(_gae_ref)
dispatch.register("gae", dispatch.CHUNKED)(_gae_ref)


# -- pack ---------------------------------------------------------------------

@dispatch.register("pack", dispatch.REF)
def _pack_ref(leaves):
    return _ref.pack(leaves)


# -- quant_matmul -------------------------------------------------------------

def _qmm_ref(x, w_q, scale):
    with jax.named_scope("KERNEL_qmm"):
        return _ref.quant_matmul(x, w_q, scale)


dispatch.register("quant_matmul", dispatch.REF)(_qmm_ref)
dispatch.register("quant_matmul", dispatch.CHUNKED)(_qmm_ref)


# -- flash_decode -------------------------------------------------------------

def _fd_ref(q, k, v, length, *, block_s=512):
    with jax.named_scope("KERNEL_flash_decode"):
        return _ref.flash_decode(q, k, v, length)


dispatch.register("flash_decode", dispatch.REF)(_fd_ref)
dispatch.register("flash_decode", dispatch.CHUNKED)(_fd_ref)


# -- Pallas backends (interpret + compiled share one body per op) -------------

if HAS_PALLAS_KERNELS:

    def _pallas_pair(op, fn):
        """Register ``fn(*a, interpret=...)`` as both the interpret-mode CI
        backend and the compiled TPU backend of ``op``."""
        import functools
        dispatch.register(op, dispatch.INTERPRET)(
            functools.partial(fn, interpret=True))
        dispatch.register(op, dispatch.PALLAS, requires_tpu=True)(
            functools.partial(fn, interpret=False))

    _pallas_pair("flash_attention",
                 lambda q, k, v, *, causal=True, block_q=128, block_k=128,
                 interpret: _fa.flash_attention(
                     q, k, v, causal=causal, block_q=block_q,
                     block_k=block_k, interpret=interpret))
    _pallas_pair("ssd",
                 lambda x, dt, A, B_, C, *, chunk=128, interpret:
                 _ssd.ssd(x, dt, A, B_, C, chunk=chunk, interpret=interpret))
    _pallas_pair("gae",
                 lambda rewards, values, dones, last_value, gamma, lam, *,
                 block_t=128, interpret: _gae.gae(
                     rewards, values, dones, last_value, gamma, lam,
                     block_t=block_t, interpret=interpret))
    _pallas_pair("pack",
                 lambda leaves, *, interpret:
                 _pack.pack(leaves, interpret=interpret))
    _pallas_pair("quant_matmul",
                 lambda x, w_q, scale, *, interpret:
                 _qmm.quant_matmul(x, w_q, scale, interpret=interpret))
    _pallas_pair("flash_decode",
                 lambda q, k, v, length, *, block_s=512, interpret:
                 _fd.flash_decode(q, k, v, length, block_s=block_s,
                                  interpret=interpret))


# -- public ops ---------------------------------------------------------------

def flash_attention(q, k, v, causal: bool = True, mode: str = None,
                    block_q: int = 128, block_k: int = 128):
    return dispatch.call("flash_attention", q, k, v, mode=mode,
                         causal=causal, block_q=block_q, block_k=block_k)


def ssd(x, dt, A, B_, C, chunk: int = 128, mode: str = None):
    return dispatch.call("ssd", x, dt, A, B_, C, mode=mode, chunk=chunk)


def gae(rewards, values, dones, last_value, gamma: float, lam: float,
        mode: str = None, block_t: int = 128):
    return dispatch.call("gae", rewards, values, dones, last_value,
                         gamma, lam, mode=mode, block_t=block_t)


def pack(leaves, mode: str = None):
    return dispatch.call("pack", leaves, mode=mode)


def quant_matmul(x, w_q, scale, mode: str = None):
    return dispatch.call("quant_matmul", x, w_q, scale, mode=mode)


def flash_decode(q, k, v, length, mode: str = None, block_s: int = 512):
    return dispatch.call("flash_decode", q, k, v, length, mode=mode,
                         block_s=block_s)
