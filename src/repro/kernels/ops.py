"""Jit'd dispatch wrappers over the Pallas kernels.

``mode``:
  auto      — Pallas on TPU, jnp reference elsewhere (CPU dev / dry-run:
              the lowered HLO of the reference has equivalent roofline terms,
              see EXPERIMENTS.md §Roofline notes)
  pallas    — compiled Pallas (TPU)
  interpret — Pallas body interpreted in Python (CPU correctness tests)
  ref       — pure-jnp oracle
"""
from __future__ import annotations

import jax

from repro.kernels import ref as _ref
from repro.kernels import flash_attention as _fa
from repro.kernels import ssd as _ssd
from repro.kernels import gae_scan as _gae
from repro.kernels import pack as _pack
from repro.kernels import quant_matmul as _qmm
from repro.kernels import flash_decode as _fd


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _resolve(mode: str) -> str:
    if mode == "auto":
        return "pallas" if _on_tpu() else "ref"
    return mode


# "KERNEL_" named scopes mark regions whose HLO stands in for a Pallas kernel
# during CPU dry-run lowering: launch.hlo_analysis excludes their *internal*
# HBM traffic (VMEM-resident on the real TPU kernel) while keeping their
# FLOPs. Inputs/outputs are still counted by the unmarked neighbor ops.


def flash_attention(q, k, v, causal: bool = True, mode: str = "auto",
                    block_q: int = 128, block_k: int = 128):
    m = _resolve(mode)
    if m == "ref":
        return _ref.flash_attention(q, k, v, causal=causal)
    if m == "chunked":   # kernel-equivalent jnp program (dry-run lowering)
        with jax.named_scope("KERNEL_flash"):
            return _ref.flash_attention_chunked(q, k, v, causal=causal)
    return _fa.flash_attention(q, k, v, causal=causal, block_q=block_q,
                               block_k=block_k, interpret=(m == "interpret"))


def ssd(x, dt, A, B_, C, chunk: int = 128, mode: str = "auto"):
    m = _resolve(mode)
    if m == "ref":
        return _ref.ssd(x, dt, A, B_, C)
    if m == "chunked":
        with jax.named_scope("KERNEL_ssd"):
            return _ref.ssd_chunked(x, dt, A, B_, C, chunk=chunk)
    return _ssd.ssd(x, dt, A, B_, C, chunk=chunk, interpret=(m == "interpret"))


def gae(rewards, values, dones, last_value, gamma: float, lam: float,
        mode: str = "auto", block_t: int = 128):
    m = _resolve(mode)
    if m in ("ref", "chunked"):
        with jax.named_scope("KERNEL_gae"):
            return _ref.gae(rewards, values, dones, last_value, gamma, lam)
    return _gae.gae(rewards, values, dones, last_value, gamma, lam,
                    block_t=block_t, interpret=(m == "interpret"))


def pack(leaves, mode: str = "auto"):
    m = _resolve(mode)
    if m == "ref":
        return _ref.pack(leaves)
    return _pack.pack(leaves, interpret=(m == "interpret"))


def quant_matmul(x, w_q, scale, mode: str = "auto"):
    m = _resolve(mode)
    if m in ("ref", "chunked"):
        with jax.named_scope("KERNEL_qmm"):
            return _ref.quant_matmul(x, w_q, scale)
    return _qmm.quant_matmul(x, w_q, scale, interpret=(m == "interpret"))


def flash_decode(q, k, v, length, mode: str = "auto", block_s: int = 512):
    m = _resolve(mode)
    if m in ("ref", "chunked"):
        with jax.named_scope("KERNEL_flash_decode"):
            return _ref.flash_decode(q, k, v, length)
    return _fd.flash_decode(q, k, v, length, block_s=block_s,
                            interpret=(m == "interpret"))
