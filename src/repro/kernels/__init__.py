"""Kernel layer: Pallas TPU kernels behind a dispatch registry.

- ``ops``      — public op functions (what models/rl call)
- ``dispatch`` — registry: (op, platform, JAX version) → implementation,
  env/scoped overrides, autotune
- ``compat``   — shims over ``jax.experimental.pallas`` API drift
- ``ref``      — pure-jnp oracles (correctness ground truth)
- one module per Pallas kernel (flash_attention, flash_decode,
  quant_matmul, gae_scan, ssd, pack)

New fused kernels land as registry entries (``dispatch.register``) and
automatically join the interpret-vs-ref parity sweep in tests.
"""
