"""GAE as a Pallas kernel — PPO's sequential bottleneck, blocked in time.

GAE is a length-T reverse scalar recurrence per environment: tiny FLOPs,
purely memory-bound, and painful as T separate XLA ops. We tile (block_b
envs × block_t steps) into VMEM and walk time blocks in reverse via the
index map; the carried (advantage, next-value) pair lives in VMEM scratch
across the sequential time-grid dimension. One launch, one pass over HBM.

Grid: (B / block_b, T / block_t) — time dim sequential, reversed.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import compiler_params


def _kernel(r_ref, v_ref, nt_ref, lastv_ref, adv_ref, carry_ref, *,
            gamma: float, lam: float, block_t: int):
    ti = pl.program_id(1)

    @pl.when(ti == 0)
    def _init():
        # carry rows: [0] = A_{t+1}, [1] = V_{t+1}
        carry_ref[0, :] = jnp.zeros_like(carry_ref[0, :])
        carry_ref[1, :] = lastv_ref[:, 0].astype(jnp.float32)

    r = r_ref[...].astype(jnp.float32)        # (bb, bt)
    v = v_ref[...].astype(jnp.float32)
    nt = nt_ref[...].astype(jnp.float32)

    def step(i, carry):
        adv_next, v_next = carry
        t = block_t - 1 - i
        rt = jax.lax.dynamic_slice_in_dim(r, t, 1, 1)[:, 0]
        vt = jax.lax.dynamic_slice_in_dim(v, t, 1, 1)[:, 0]
        ntt = jax.lax.dynamic_slice_in_dim(nt, t, 1, 1)[:, 0]
        delta = rt + gamma * v_next * ntt - vt
        adv = delta + gamma * lam * ntt * adv_next
        adv_ref[:, t] = adv.astype(adv_ref.dtype)
        return adv, vt

    carry = (carry_ref[0, :], carry_ref[1, :])
    adv, vt = jax.lax.fori_loop(0, block_t, step, carry)
    carry_ref[0, :] = adv
    carry_ref[1, :] = vt


@functools.partial(jax.jit, static_argnames=("gamma", "lam", "block_b",
                                             "block_t", "interpret"))
def gae(rewards, values, dones, last_value, gamma: float, lam: float,
        *, block_b: int = 128, block_t: int = 128, interpret: bool = False):
    """Same contract as ref.gae. rewards/values/dones: (B, T);
    last_value: (B,). Returns advantages (B, T) float32."""
    B, T = rewards.shape
    block_b = min(block_b, B)
    block_t = min(block_t, T)
    assert B % block_b == 0 and T % block_t == 0
    nb, ntb = B // block_b, T // block_t
    nonterm = 1.0 - dones.astype(jnp.float32)

    grid = (nb, ntb)
    rev = lambda b, t, n=ntb: (b, n - 1 - t)   # walk time blocks in reverse
    return pl.pallas_call(
        functools.partial(_kernel, gamma=gamma, lam=lam, block_t=block_t),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, block_t), rev),
            pl.BlockSpec((block_b, block_t), rev),
            pl.BlockSpec((block_b, block_t), rev),
            pl.BlockSpec((block_b, 1), lambda b, t: (b, 0)),
        ],
        out_specs=pl.BlockSpec((block_b, block_t), rev),
        out_shape=jax.ShapeDtypeStruct((B, T), jnp.float32),
        scratch_shapes=[pltpu.VMEM((2, block_b), jnp.float32)],
        compiler_params=compiler_params(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(rewards.astype(jnp.float32), values.astype(jnp.float32), nonterm,
      last_value.astype(jnp.float32)[:, None])
