"""Flash attention (causal, GQA) as a Pallas TPU kernel.

TPU adaptation of the classic GPU algorithm (DESIGN.md §2): instead of a
warp-level softmax we tile for the MXU — (block_q × head_dim) query tiles in
VMEM, streaming (block_k × head_dim) KV tiles; the online-softmax running
max/denominator live in VMEM scratch that persists across the sequential
KV grid dimension. GQA is handled in the index maps (K/V blocks are fetched
for head h // group_size), so KV tiles are never materially replicated.

Grid: (batch, q_heads, num_q_blocks, num_kv_blocks) — the last dimension is
sequential on TPU, which is what makes the scratch carry legal.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import compiler_params

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            scale: float, causal: bool, block_q: int, block_k: int,
            num_kv_blocks: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = qi * block_q
    k_start = ki * block_k
    # Causal: skip fully-masked KV blocks (they contribute nothing).
    run = (not causal) or (k_start <= q_start + block_q - 1)

    @pl.when(run)
    def _step():
        q = q_ref[0, 0].astype(jnp.float32)              # (bq, hd)
        k = k_ref[0, 0].astype(jnp.float32)              # (bk, hd)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale
        if causal:
            rows = q_start + jax.lax.broadcasted_iota(jnp.int32,
                                                      (block_q, block_k), 0)
            cols = k_start + jax.lax.broadcasted_iota(jnp.int32,
                                                      (block_q, block_k), 1)
            s = jnp.where(rows >= cols, s, NEG_INF)
        m_prev = m_ref[...]                              # (bq, 1)
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
        acc_ref[...] = (acc_ref[...] * alpha +
                        jax.lax.dot_general(p, v, (((1,), (0,)), ((), ()))))
        m_ref[...] = m_new

    @pl.when(ki == num_kv_blocks - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "scale", "block_q",
                                             "block_k", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, scale: float = None,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = False):
    """q: (B, T, H, hd); k, v: (B, S, K, hd); returns (B, T, H, hd)."""
    B, T, H, hd = q.shape
    S, K = k.shape[1], k.shape[2]
    G = H // K
    scale = scale if scale is not None else 1.0 / (hd ** 0.5)
    block_q = min(block_q, T)
    block_k = min(block_k, S)
    assert T % block_q == 0 and S % block_k == 0, (T, S, block_q, block_k)
    nq, nk = T // block_q, S // block_k

    # head-major layout so each grid cell touches one contiguous tile
    qh = jnp.moveaxis(q, 2, 1)            # (B, H, T, hd)
    kh = jnp.moveaxis(k, 2, 1)            # (B, K, S, hd)
    vh = jnp.moveaxis(v, 2, 1)

    grid = (B, H, nq, nk)
    out = pl.pallas_call(
        functools.partial(_kernel, scale=scale, causal=causal,
                          block_q=block_q, block_k=block_k, num_kv_blocks=nk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, hd),
                         lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, block_k, hd),
                         lambda b, h, i, j, G=G: (b, h // G, j, 0)),
            pl.BlockSpec((1, 1, block_k, hd),
                         lambda b, h, i, j, G=G: (b, h // G, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, hd),
                               lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, T, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),    # running max
            pltpu.VMEM((block_q, 1), jnp.float32),    # running denom
            pltpu.VMEM((block_q, hd), jnp.float32),   # output accumulator
        ],
        compiler_params=compiler_params(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(qh, kh, vh)
    return jnp.moveaxis(out, 1, 2)        # back to (B, T, H, hd)
