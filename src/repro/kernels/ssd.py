"""Mamba2 SSD (state-space duality) chunked scan as a Pallas TPU kernel.

The SSD insight: within a chunk of Q timesteps the recurrence collapses to a
masked (semiseparable) attention-like matmul — MXU food — while states are
passed *between* chunks by a cheap rank-preserving recurrence. We tile one
(head, chunk) per grid cell; the (hd × ds) state lives in VMEM scratch and is
carried across the sequential chunk dimension of the grid, so the whole
sequence is processed with one kernel launch and zero HBM state traffic.

Grid: (B*H, num_chunks) — last dim sequential (state carry).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import compiler_params


def _kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, hlast_ref, h_ref, *,
            chunk: int, num_chunks: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    x = x_ref[0].astype(jnp.float32)         # (Q, hd)
    dt = dt_ref[0].astype(jnp.float32)       # (Q, 1)
    A = a_ref[0, 0]                          # scalar decay rate (negative)
    B = b_ref[0].astype(jnp.float32)         # (Q, ds)
    C = c_ref[0].astype(jnp.float32)         # (Q, ds)

    dA = dt[:, 0] * A                        # (Q,)
    cum = jnp.cumsum(dA)                     # inclusive (Q,)

    # Within-chunk (the "duality": a decay-masked attention matmul)
    # L[i,j] = exp(cum_i - cum_j) for i >= j
    li = cum[:, None] - cum[None, :]
    rows = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    L = jnp.where(rows >= cols, jnp.exp(li), 0.0)
    scores = jax.lax.dot_general(C, B, (((1,), (1,)), ((), ())))   # (Q,Q)
    y = jax.lax.dot_general(scores * L * dt[:, 0][None, :], x,
                            (((1,), (0,)), ((), ())))              # (Q,hd)

    # Inter-chunk: contribution of the carried state
    decay_in = jnp.exp(cum)[:, None]                               # (Q,1)
    h = h_ref[...]                                                 # (hd,ds)
    y = y + decay_in * jax.lax.dot_general(C, h,
                                           (((1,), (1,)), ((), ())))
    y_ref[0] = y.astype(y_ref.dtype)

    # State update: h' = exp(cum_Q) h + sum_j exp(cum_Q - cum_j) dt_j x_j B_j^T
    w = (jnp.exp(cum[-1] - cum) * dt[:, 0])[:, None]               # (Q,1)
    upd = jax.lax.dot_general(x * w, B, (((0,), (0,)), ((), ())))  # (hd,ds)
    h_ref[...] = jnp.exp(cum[-1]) * h + upd

    @pl.when(ci == num_chunks - 1)
    def _emit_state():
        hlast_ref[0] = h_ref[...]


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd(x, dt, A, B_, C, *, chunk: int = 128, interpret: bool = False):
    """Same contract as ref.ssd (h0 = 0). x: (B,T,H,hd), dt: (B,T,H),
    A: (H,), B_/C: (B,T,H,ds). Returns (y, h_last)."""
    Bb, T, H, hd = x.shape
    ds = B_.shape[-1]
    chunk = min(chunk, T)
    assert T % chunk == 0, (T, chunk)
    nc = T // chunk
    BH = Bb * H

    # (B,T,H,*) -> (B*H, T, *)
    xh = jnp.moveaxis(x, 2, 1).reshape(BH, T, hd)
    dth = jnp.moveaxis(dt, 2, 1).reshape(BH, T, 1)
    bh = jnp.moveaxis(B_, 2, 1).reshape(BH, T, ds)
    ch = jnp.moveaxis(C, 2, 1).reshape(BH, T, ds)
    ah = jnp.tile(A.astype(jnp.float32)[:, None], (Bb, 1))        # (BH, 1)

    grid = (BH, nc)
    y, hlast = pl.pallas_call(
        functools.partial(_kernel, chunk=chunk, num_chunks=nc),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, hd), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, 1), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, 1), lambda b, c: (b, 0)),
            pl.BlockSpec((1, chunk, ds), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, ds), lambda b, c: (b, c, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, hd), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, hd, ds), lambda b, c: (b, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BH, T, hd), x.dtype),
            jax.ShapeDtypeStruct((BH, hd, ds), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((hd, ds), jnp.float32)],
        compiler_params=compiler_params(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(xh, dth, ah, bh, ch)

    y = jnp.moveaxis(y.reshape(Bb, H, T, hd), 1, 2)
    return y, hlast.reshape(Bb, H, hd, ds)
