"""Kernel dispatch: one registry from op name to its implementations.

Each op (``flash_attention``, ``flash_decode``, ``quant_matmul``,
``gae``, ``ssd``, ``pack``) registers up to four backends:

  ``ref``              pure-jnp oracle — correctness ground truth, CPU default
  ``chunked``          kernel-equivalent jnp program under a ``KERNEL_`` named
                       scope (the dry-run roofline stand-in, launch.hlo_analysis)
  ``pallas_interpret`` the real Pallas kernel body interpreted on CPU
                       (``interpret`` is accepted as an alias)
  ``pallas``           compiled Pallas — TPU default

Selection per (op, platform, JAX version), highest precedence first:

  1. explicit ``mode=`` at the call site
  2. a ``dispatch.using(mode)`` scope — replaces threading ``kernel=``
     strings through every model layer
  3. per-op env override  ``REPRO_KERNEL_<OP>``   (strict: unknown ⇒ error)
  4. global env override  ``REPRO_KERNELS``       (lenient: skipped where
     the named impl is not registered for the op)
  5. the cached :func:`autotune` winner for (op, platform)
  6. platform default — ``pallas`` on TPU, ``ref`` elsewhere

Impls that require a TPU or a minimum JAX version are excluded from
:func:`available` on hosts that can't run them, so graceful degradation
(ref math on CPU, interpret-mode Pallas in CI, compiled Pallas on TPU)
is a property of the registry, not of each call site.
"""
from __future__ import annotations

import os
import threading
import time
from contextlib import contextmanager
from dataclasses import dataclass
from typing import Callable, Dict, Tuple

import jax

from repro.kernels import compat

OPS = ("flash_attention", "flash_decode", "quant_matmul", "gae", "ssd",
       "pack")

REF = "ref"
CHUNKED = "chunked"
INTERPRET = "pallas_interpret"
PALLAS = "pallas"

ENV_GLOBAL = "REPRO_KERNELS"
_ALIASES = {"interpret": INTERPRET}


def env_var(op: str) -> str:
    return "REPRO_KERNEL_" + op.upper()


@dataclass(frozen=True)
class Impl:
    op: str
    name: str
    fn: Callable
    requires_tpu: bool = False
    min_jax: Tuple[int, ...] = ()


_REGISTRY: Dict[str, Dict[str, Impl]] = {}
_AUTOTUNED: Dict[Tuple[str, str], str] = {}
_TLS = threading.local()


def register(op: str, name: str, *, requires_tpu: bool = False,
             min_jax: tuple = ()):
    """Decorator: register ``fn`` as implementation ``name`` of ``op``."""
    def deco(fn):
        _REGISTRY.setdefault(op, {})[name] = Impl(
            op, name, fn, requires_tpu, tuple(min_jax))
        return fn
    return deco


def _check_op(op: str):
    if op not in _REGISTRY:
        # built-in impls live in kernels.ops and register on import; pull
        # them in lazily so `import dispatch` alone sees a full registry
        import repro.kernels.ops  # noqa: F401
    if op not in _REGISTRY:
        raise KeyError(f"unknown kernel op {op!r}; registered: "
                       f"{tuple(sorted(_REGISTRY))}")


def ops() -> tuple:
    if not _REGISTRY:
        import repro.kernels.ops  # noqa: F401
    return tuple(sorted(_REGISTRY))


def implementations(op: str) -> tuple:
    _check_op(op)
    return tuple(_REGISTRY[op])


def platform() -> str:
    return jax.default_backend()


def _usable(impl: Impl, plat: str) -> bool:
    if impl.requires_tpu and plat != "tpu":
        return False
    if impl.min_jax and compat.jax_version() < impl.min_jax:
        return False
    return True


def available(op: str, plat: str = None) -> tuple:
    """Impl names runnable on ``plat`` (default: this host)."""
    _check_op(op)
    plat = plat or platform()
    return tuple(n for n, i in _REGISTRY[op].items() if _usable(i, plat))


# -- scoped override ----------------------------------------------------------

@contextmanager
def using(mode: str):
    """Scoped default backend: ``with dispatch.using("interpret"): ...``
    applies to every op call in the block (and anything it traces) that
    doesn't pass an explicit ``mode=``. Thread-local and reentrant."""
    stack = getattr(_TLS, "stack", None)
    if stack is None:
        stack = _TLS.stack = []
    stack.append(mode)
    try:
        yield
    finally:
        stack.pop()


def _scoped_mode():
    stack = getattr(_TLS, "stack", None)
    return stack[-1] if stack else None


# -- resolution ---------------------------------------------------------------

def resolve(op: str, mode: str = None, plat: str = None) -> str:
    """Pick the impl name for ``op`` (see module docstring for precedence).
    ``mode`` in (None, "auto") means "dispatch decides"."""
    _check_op(op)
    plat = plat or platform()
    if mode not in (None, "auto"):
        name = _ALIASES.get(mode, mode)
        if name not in _REGISTRY[op]:
            raise KeyError(f"{op}: no implementation {mode!r}; "
                           f"have {implementations(op)}")
        return name
    # (candidate, strict): scoped/global overrides are lenient because they
    # blanket-cover ops that may not register every backend (e.g. pack has
    # no "chunked"); the per-op env names exactly one op, so typos raise.
    for cand, strict in ((_scoped_mode(), False),
                         (os.environ.get(env_var(op)), True),
                         (os.environ.get(ENV_GLOBAL), False)):
        if cand and cand != "auto":
            name = _ALIASES.get(cand, cand)
            if name in _REGISTRY[op] and _usable(_REGISTRY[op][name], plat):
                return name
            if strict:
                raise KeyError(
                    f"{env_var(op)}={cand!r} is not a usable implementation "
                    f"of {op} on {plat}; have {available(op, plat)}")
    tuned = _AUTOTUNED.get((op, plat))
    if tuned in _REGISTRY[op]:
        return tuned
    if plat == "tpu" and PALLAS in available(op, plat):
        return PALLAS
    return REF


def call(op: str, *args, mode: str = None, **kwargs):
    """Resolve and invoke: the single entry point ops.py wraps."""
    name = resolve(op, mode)   # also lazy-loads the built-in registry
    return _REGISTRY[op][name].fn(*args, **kwargs)


# -- autotune (paper §3.3, mirroring core.vector.autotune) --------------------

def autotune(op: str, *args, impls: tuple = None, iters: int = 3,
             warmup: int = 1, **kwargs):
    """Time every runnable impl of ``op`` on the given concrete args.

    Returns ``({impl: calls_per_second}, best)`` and caches the winner so
    subsequent ``mode=None/"auto"`` dispatch on this platform uses it
    (cleared with :func:`clear_autotune`). Impls that fail to run are
    skipped — a Pallas kernel that can't lower here simply loses."""
    _check_op(op)
    results = {}
    names = tuple(_ALIASES.get(n, n) for n in impls) if impls \
        else available(op)
    for name in names:
        if name not in _REGISTRY[op]:
            raise KeyError(f"{op}: no implementation {name!r}; "
                           f"have {implementations(op)}")
        fn = _REGISTRY[op][name].fn
        try:
            for _ in range(warmup):
                jax.block_until_ready(fn(*args, **kwargs))  # repro: noqa[HOST-SYNC] — autotune warmup (deliberate sync)
            t0 = time.perf_counter()
            out = None
            for _ in range(iters):
                out = fn(*args, **kwargs)
            jax.block_until_ready(out)  # repro: noqa[HOST-SYNC] — autotune timing barrier (deliberate)
            results[name] = iters / (time.perf_counter() - t0)
        except Exception:
            continue
    if not results:
        raise RuntimeError(f"autotune: no implementation of {op!r} ran")
    best = max(results, key=results.get)
    _AUTOTUNED[(op, platform())] = best
    return results, best


def clear_autotune():
    _AUTOTUNED.clear()
