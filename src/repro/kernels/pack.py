"""Emulation byte-packing as a Pallas kernel — the paper's §5 hot loop.

PufferLib Cythonizes the structured-array pack because it sits on every
env→learner transfer. The TPU edition: K flat u8 leaves are DMA'd into one
contiguous output buffer at static offsets, a batch-tile at a time. The
offsets come from the same static FlatSpec the emulation layer computes at
startup, so the kernel body is pure data movement (memory-roofline op).

Grid: (B / block_b,).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(*refs, sizes: tuple):
    in_refs, o_ref = refs[:-1], refs[-1]
    off = 0
    for r, n in zip(in_refs, sizes):
        o_ref[:, off:off + n] = r[...]
        off += n


@functools.partial(jax.jit, static_argnames=("block_b", "interpret"))
def pack(leaves, *, block_b: int = 256, interpret: bool = False):
    """[(B, n_i) u8] -> (B, sum n_i) u8 — one contiguous buffer per batch row."""
    B = leaves[0].shape[0]
    sizes = tuple(l.shape[1] for l in leaves)
    total = sum(sizes)
    block_b = min(block_b, B)
    assert B % block_b == 0
    grid = (B // block_b,)
    return pl.pallas_call(
        functools.partial(_kernel, sizes=sizes),
        grid=grid,
        in_specs=[pl.BlockSpec((block_b, n), lambda b: (b, 0)) for n in sizes],
        out_specs=pl.BlockSpec((block_b, total), lambda b: (b, 0)),
        out_shape=jax.ShapeDtypeStruct((B, total), jnp.uint8),
        interpret=interpret,
    )(*leaves)
