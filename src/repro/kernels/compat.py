"""Version-compat shims over ``jax.experimental.pallas`` API drift.

The Pallas TPU surface keeps getting renamed across JAX releases — most
visibly the compiler-params class (plain dicts, then
``pltpu.TPUCompilerParams``, then ``pltpu.CompilerParams``). Kernel
modules must not construct a hardcoded TPU-only name at trace time:
they call :func:`compiler_params`, which resolves whichever spelling
this JAX ships and returns ``None`` (a valid ``pallas_call`` argument)
when none exists — e.g. a CPU-only install without the TPU extras,
where interpret mode ignores compiler params anyway.

Everything Pallas-shaped is imported through here so the rest of the
package degrades to the ``ref`` implementations when Pallas itself is
absent.
"""
from __future__ import annotations

import jax


def jax_version() -> tuple:
    """(major, minor, patch) ints, tolerant of dev/rc suffixes."""
    parts = []
    for piece in jax.__version__.split(".")[:3]:
        digits = "".join(ch for ch in piece if ch.isdigit())
        if not digits:
            break
        parts.append(int(digits))
    return tuple(parts)


try:
    from jax.experimental import pallas as pl            # noqa: F401
    from jax.experimental.pallas import tpu as pltpu     # noqa: F401
    HAS_PALLAS = True
except ImportError:   # pragma: no cover — CPU wheels without pallas
    pl = None
    pltpu = None
    HAS_PALLAS = False


def _compiler_params_cls():
    if pltpu is None:
        return None
    # newest spelling first; fall back through the rename history
    for name in ("CompilerParams", "TPUCompilerParams"):
        cls = getattr(pltpu, name, None)
        if cls is not None:
            return cls
    return None


COMPILER_PARAMS_CLS = _compiler_params_cls()


def compiler_params(*, dimension_semantics=None, **kwargs):
    """Build the TPU compiler-params object under whichever name this JAX
    spells it. Unknown kwargs are dropped (fields also drift between
    releases); returns ``None`` when no class is available."""
    cls = COMPILER_PARAMS_CLS
    if cls is None:
        return None
    kw = dict(kwargs)
    if dimension_semantics is not None:
        kw["dimension_semantics"] = tuple(dimension_semantics)
    try:
        return cls(**kw)
    except TypeError:
        import inspect
        fields = inspect.signature(cls).parameters
        return cls(**{k: v for k, v in kw.items() if k in fields})
