"""Pure-jnp oracles for every Pallas kernel.

These are the correctness ground truth (tests assert_allclose kernels against
them across shape/dtype sweeps) AND the CPU execution path used by models
when no TPU is present.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def flash_attention(q, k, v, causal: bool = True, scale: float = None):
    """q: (B,T,H,hd); k,v: (B,S,K,hd) with H = K*G (GQA). f32 softmax."""
    B, T, H, hd = q.shape
    S, K = k.shape[1], k.shape[2]
    G = H // K
    scale = scale if scale is not None else 1.0 / jnp.sqrt(float(hd))
    qg = q.reshape(B, T, K, G, hd)
    s = jnp.einsum("btkgh,bskh->bkgts", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if causal:
        mask = jnp.arange(T)[:, None] >= jnp.arange(S)[None, :]
        s = jnp.where(mask[None, None, None], s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgts,bskh->btkgh", w, v.astype(jnp.float32))
    return o.reshape(B, T, H, hd).astype(q.dtype)


def flash_attention_chunked(q, k, v, causal: bool = True, scale: float = None,
                            block_k: int = 512):
    """Online-softmax attention, scanning KV blocks — the pure-jnp program
    whose HLO has the SAME memory/collective profile as the Pallas flash
    kernel (no materialized (T, S) scores or masks). Used as the kernel
    stand-in for CPU dry-run lowering; numerically identical to
    ``flash_attention`` (tested)."""
    B, T, H, hd = q.shape
    S, K = k.shape[1], k.shape[2]
    G = H // K
    scale = scale if scale is not None else 1.0 / jnp.sqrt(float(hd))
    block_k = min(block_k, S)
    assert S % block_k == 0
    nb = S // block_k
    qg = q.reshape(B, T, K, G, hd).astype(jnp.float32)
    rows = jnp.arange(T)[:, None]

    # GSPMD loses batch sharding on loop-carried tensors without explicit
    # constraints (measured: full-batch all-gathers inside the block scan)
    from repro.models.params import constrain as _con
    _c4 = lambda t: _con(t, "batch", "null", "kv_heads", "null")
    _c5 = lambda t: _con(t, "batch", "null", "kv_heads", "null", "null")

    def step(carry, i):
        m, l, acc = carry
        kb = _c4(jax.lax.dynamic_slice_in_dim(k, i * block_k, block_k, 1))
        vb = _c4(jax.lax.dynamic_slice_in_dim(v, i * block_k, block_k, 1))
        s = jnp.einsum("btkgh,bskh->btkgs", qg,
                       kb.astype(jnp.float32)) * scale
        if causal:
            cols = i * block_k + jnp.arange(block_k)[None, :]
            s = jnp.where((rows >= cols)[None, :, None, None, :], s, -1e30)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l = l * alpha + jnp.sum(p, axis=-1)
        acc = acc * alpha[..., None] + jnp.einsum(
            "btkgs,bskh->btkgh", p, vb.astype(jnp.float32))
        return (_c4(m_new), _c4(l), _c5(acc)), None

    init = (_c4(jnp.full((B, T, K, G), -1e30)),
            _c4(jnp.zeros((B, T, K, G))),
            _c5(jnp.zeros((B, T, K, G, hd))))
    (m, l, acc), _ = jax.lax.scan(step, init, jnp.arange(nb))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.reshape(B, T, H, hd).astype(q.dtype)


def ssd(x, dt, A, B_, C, h0=None):
    """Mamba2 selective-state recurrence, exact step-by-step oracle.

    x:  (B, T, H, hd)   inputs per head
    dt: (B, T, H)       positive step sizes (post-softplus)
    A:  (H,)            negative decay rates
    B_: (B, T, H, ds)   input gates (already head-expanded)
    C:  (B, T, H, ds)   output gates
    h0: (B, H, hd, ds)  optional initial state
    returns y (B, T, H, hd), h_last (B, H, hd, ds)
    """
    Bb, T, H, hd = x.shape
    ds = B_.shape[-1]
    f32 = jnp.float32
    in_dtype = x.dtype
    x, dt, B_, C = (t.astype(f32) for t in (x, dt, B_, C))
    A = A.astype(f32)
    if h0 is None:
        h0 = jnp.zeros((Bb, H, hd, ds), f32)

    def step(h, inp):
        xt, dtt, Bt, Ct = inp                       # (B,H,hd),(B,H),(B,H,ds)x2
        decay = jnp.exp(dtt * A[None])              # (B,H)
        upd = jnp.einsum("bh,bhd,bhs->bhds", dtt, xt, Bt)
        h = h * decay[..., None, None] + upd
        y = jnp.einsum("bhds,bhs->bhd", h, Ct)
        return h, y

    xs = (jnp.moveaxis(x, 1, 0), jnp.moveaxis(dt, 1, 0),
          jnp.moveaxis(B_, 1, 0), jnp.moveaxis(C, 1, 0))
    h_last, ys = jax.lax.scan(step, h0, xs)
    return jnp.moveaxis(ys, 0, 1).astype(in_dtype), h_last


def ssd_chunked(x, dt, A, B_, C, chunk: int = 128):
    """Chunked SSD in pure jnp — the exact algorithm of kernels/ssd.py
    (within-chunk dual matmuls + inter-chunk state scan), used as the
    kernel stand-in for dry-run lowering. Same contract as ``ssd``."""
    Bb, T, H, hd = x.shape
    ds = B_.shape[-1]
    chunk = min(chunk, T)
    assert T % chunk == 0
    nc = T // chunk
    f32 = jnp.float32
    in_dtype = x.dtype
    xc = x.astype(f32).reshape(Bb, nc, chunk, H, hd)
    dtc = dt.astype(f32).reshape(Bb, nc, chunk, H)
    Bc = B_.astype(f32).reshape(Bb, nc, chunk, H, ds)
    Cc = C.astype(f32).reshape(Bb, nc, chunk, H, ds)
    A = A.astype(f32)

    dA = dtc * A[None, None, None]                   # (B,nc,Q,H)
    cum = jnp.cumsum(dA, axis=2)
    li = cum[:, :, :, None] - cum[:, :, None, :]     # (B,nc,Qi,Qj,H)
    mask = (jnp.arange(chunk)[:, None] >= jnp.arange(chunk)[None, :])
    L = jnp.where(mask[None, None, :, :, None], jnp.exp(li), 0.0)
    scores = jnp.einsum("bnihs,bnjhs->bnijh", Cc, Bc)
    y_diag = jnp.einsum("bnijh,bnjh,bnjhd->bnihd", scores * L, dtc, xc)

    # per-chunk candidate states and decay
    w = jnp.exp(cum[:, :, -1:, :] - cum) * dtc       # (B,nc,Q,H)
    s_new = jnp.einsum("bnjh,bnjhd,bnjhs->bnhds", w, xc, Bc)
    chunk_decay = jnp.exp(cum[:, :, -1])             # (B,nc,H)

    def scan_fn(h, inp):
        s_n, dec = inp                                # (B,H,hd,ds),(B,H)
        h_out = h
        h = h * dec[..., None, None] + s_n
        return h, h_out

    h0 = jnp.zeros((Bb, H, hd, ds), f32)
    h_last, h_prev = jax.lax.scan(
        scan_fn, h0, (jnp.moveaxis(s_new, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)))
    h_prev = jnp.moveaxis(h_prev, 0, 1)              # (B,nc,H,hd,ds)
    y_off = jnp.einsum("bnihs,bnhds->bnihd", Cc * jnp.exp(cum)[..., None],
                       h_prev)
    y = (y_diag + y_off).reshape(Bb, T, H, hd).astype(in_dtype)
    return y, h_last


def gae(rewards, values, dones, last_value, gamma: float, lam: float):
    """Generalized advantage estimation, time-major reverse scan oracle.

    rewards/dones: (B, T); values: (B, T); last_value: (B,)
    done_t marks that the episode ended *at* step t (no bootstrap across it).
    returns advantages (B, T).
    """
    f32 = jnp.float32
    rewards, values, last_value = (t.astype(f32) for t in
                                   (rewards, values, last_value))
    nonterm = 1.0 - dones.astype(f32)

    def step(carry, inp):
        adv_next, v_next = carry
        r, v, nt = inp
        delta = r + gamma * v_next * nt - v
        adv = delta + gamma * lam * nt * adv_next
        return (adv, v), adv

    xs = (jnp.moveaxis(rewards, 1, 0)[::-1], jnp.moveaxis(values, 1, 0)[::-1],
          jnp.moveaxis(nonterm, 1, 0)[::-1])
    _, advs = jax.lax.scan(step, (jnp.zeros_like(last_value), last_value), xs)
    return jnp.moveaxis(advs[::-1], 0, 1)


def pack(leaves):
    """Batched flat-buffer packing oracle: [(B, n_i) u8] -> (B, sum n_i) u8."""
    return jnp.concatenate(leaves, axis=-1)


def quant_matmul(x, w_q, scale):
    """W8/W4A16 oracle: x @ (w_q · scale) with f32 accumulation."""
    w = w_q.astype(jnp.float32) * scale.astype(jnp.float32)[None, :]
    return (x.astype(jnp.float32) @ w).astype(x.dtype)


def flash_decode(q, k, v, length):
    """One-token decode attention oracle. q: (B,H,hd); k,v: (B,S,K,hd);
    length: () — newest valid cache index. Returns (B,H,hd)."""
    B, H, hd = q.shape
    S, K = k.shape[1], k.shape[2]
    G = H // K
    qg = q.reshape(B, K, G, hd)
    s = jnp.einsum("bkgh,bskh->bkgs", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) / jnp.sqrt(float(hd))
    valid = jnp.arange(S)[None, None, None, :] <= length
    s = jnp.where(valid, s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgs,bskh->bkgh", w, v.astype(jnp.float32))
    return o.reshape(B, H, hd).astype(q.dtype)
