"""Telemetry overhead: enabled-vs-disabled end-to-end PPO SPS.

Two tiers train the same bandit MDP twice each — spans + registry OFF
(the shipped default) and ON (``telemetry.enable`` with a run dir, the
``--run-dir`` path) — and the bench records the relative SPS cost:

  * ``jit``  — the fused single-process tier: the worst case for span
               overhead, since there is no host latency to hide behind
               (every span brackets a dispatch that is itself fast).
  * ``host`` — the bridged first-finisher tier: spans wrap real recv/send
               waits, plus the proc-stat path exercised by thread workers.

SPS is measured from the *second* update onward (the first is XLA
compilation) and each cell takes the best of ``--repeats`` runs, which
rejects transient machine noise without hiding a systematic slowdown.

Acceptance (``overhead <= 3%``) is machine-aware, same contract as the
other BENCH_*.json files: on a single-core box the enabled run's flush
I/O and the trainer time-slice the only CPU, so the criterion is only
asserted when ``cores >= 2``; measured overheads are recorded honestly
either way. The enabled jit cell's spans are also exported as a sample
Chrome trace (``--trace-out``) for Perfetto.

  PYTHONPATH=src python benchmarks/bench_telemetry.py --quick

Writes BENCH_telemetry.json.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import tempfile
import time


def timed_sps(run_fn, spu: int):
    """(sps, updates) with the compile-dominated first update excluded."""
    stamps = []
    run_fn(lambda u, md: stamps.append(time.perf_counter()))
    if len(stamps) < 2:
        return 0.0, len(stamps)
    return (len(stamps) - 1) * spu / (stamps[-1] - stamps[0]), len(stamps)


def make_engine(tier, tcfg):
    import jax
    if tier == "host":
        from repro.bridge import make_host_engine
        from repro.envs.ocean_host import HostBandit
        return make_host_engine(HostBandit, tcfg, hidden=32,
                                kernel_mode="ref")
    from repro.envs.ocean import Bandit
    from repro.rl.engine import TrainEngine
    from repro.rl.trainer import ocean_policy_stack
    em, dist, policy = ocean_policy_stack(Bandit(), hidden=32,
                                          recurrent=False, conv=None)
    return TrainEngine(em, policy, tcfg, dist, key=jax.random.PRNGKey(0),
                       backend=tier, kernel_mode="ref", checkpoint_dir=None)


def bench_cell(tier, tcfg, updates, enabled, run_dir, repeats):
    """Best-of-``repeats`` SPS for one (tier, telemetry on/off) cell."""
    from repro import telemetry
    best, n_seen = 0.0, 0
    for _ in range(repeats):
        eng = make_engine(tier, tcfg)
        spu = eng.steps_per_update
        try:
            if enabled:
                telemetry.enable(run_dir=run_dir)
            sps, n = timed_sps(
                lambda cb: eng.run(total_steps=spu * updates, on_update=cb),
                spu)
        finally:
            if enabled:
                telemetry.disable()       # flushes spans to run_dir
            eng.close()
        best, n_seen = max(best, sps), n
    return best, n_seen


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="fewer timed updates (CI smoke)")
    ap.add_argument("--out", default="BENCH_telemetry.json")
    ap.add_argument("--trace-out", default="",
                    help="sample Chrome trace from the enabled jit cell "
                         "(default <out dir>/docs/trace_sample.json)")
    ap.add_argument("--repeats", type=int, default=3)
    args = ap.parse_args(argv)

    from repro.configs.base import TrainConfig
    from repro.telemetry.__main__ import export_trace

    cores = os.cpu_count() or 1
    updates = 8 if args.quick else 16
    base = dict(num_envs=16, unroll_length=32, update_epochs=2,
                num_minibatches=2, learning_rate=1e-3, gamma=0.95,
                checkpoint_every=0)
    trace_out = args.trace_out or os.path.join(
        os.path.dirname(os.path.abspath(args.out)), "docs",
        "trace_sample.json")
    os.makedirs(os.path.dirname(os.path.abspath(trace_out)), exist_ok=True)
    print(f"cores={cores}, updates={updates}, repeats={args.repeats}")

    cells = {}
    overheads = {}
    with tempfile.TemporaryDirectory() as tmp:
        for tier in ("jit", "host"):
            run_dir = os.path.join(tmp, tier)
            off, n = bench_cell(tier, TrainConfig(**base), updates,
                                enabled=False, run_dir=None,
                                repeats=args.repeats)
            on, _ = bench_cell(tier, TrainConfig(**base), updates,
                               enabled=True, run_dir=run_dir,
                               repeats=args.repeats)
            ovh = (off - on) / max(off, 1e-9)
            cells[tier] = {"sps_disabled": round(off, 1),
                           "sps_enabled": round(on, 1),
                           "updates": n,
                           "overhead_pct": round(100 * ovh, 2)}
            overheads[tier] = ovh
            print(f"bench_telemetry/{tier},off={off:.0f},on={on:.0f},"
                  f"overhead={100 * ovh:+.2f}%")
            if tier == "jit":
                n_ev = export_trace(run_dir, trace_out)
                print(f"  sample trace: {n_ev} events -> {trace_out}")

    worst = max(overheads.values())
    multicore = cores >= 2
    ok = worst <= 0.03
    if not multicore:
        print("=" * 72)
        print("WARNING: SINGLE-CORE MACHINE — ACCEPTANCE CRITERIA NOT "
              "APPLICABLE")
        print("  The enabled run's span flush and the trainer time-slice")
        print("  the only CPU, and run-to-run SPS noise on a contended")
        print("  single core exceeds the 3% criterion. Measured overheads")
        print("  are recorded honestly; the <=3% bound is not asserted.")
        print("  acceptance.acceptance_applicable=false in the JSON —")
        print("  re-run on a multicore machine (CI runners) for numbers")
        print("  the criterion applies to.")
        print("=" * 72)
    out = {
        "meta": {
            "updates": updates, "quick": bool(args.quick),
            "repeats": args.repeats, "cores": cores,
            "python": sys.version.split()[0],
            "tcfg": {k: base[k] for k in ("num_envs", "unroll_length",
                                          "update_epochs",
                                          "num_minibatches")},
            "sps_excludes_first_update": True,
            "cells_take_best_of_repeats": True,
        },
        "cells": cells,
        "acceptance": {
            "acceptance_applicable": multicore,
            "worst_overhead_pct": round(100 * worst, 2),
            "overhead_le_3pct": ok if multicore else None,
        },
    }
    with open(args.out, "w") as f:
        json.dump(out, f, indent=2)
    print(f"wrote {args.out}")
    from repro.telemetry import benchwatch
    benchwatch.record(
        "telemetry",
        {f"{tier}_{mode}_sps": cells[tier][f"sps_{mode}"]
         for tier in cells for mode in ("disabled", "enabled")},
        acceptance={"acceptance_applicable": multicore,
                    "overhead_le_3pct": bool(ok) if multicore else None},
        meta={"updates": updates, "quick": bool(args.quick)})
    if multicore and not ok:
        print(f"FAIL: telemetry overhead {100 * worst:.2f}% > 3% on a "
              f"multicore machine")
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
