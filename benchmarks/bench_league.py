"""Policy-League arena benchmark: vmapped K-opponent pool vs sequential
per-opponent dispatch.

The arena's pitch is the engine's pitch applied to evaluation: a K-opponent
pool stacked along a leading param axis evaluates as ONE vmapped/jitted
rollout scan instead of K Python-dispatched matches. In the small-model
Ocean regime per-dispatch overhead dominates, so the fused launch should
win by a wide margin — acceptance is ≥ 3× at K = 8 (both paths warmed, so
the comparison is pure dispatch + batching, not compile time).

  PYTHONPATH=src python benchmarks/bench_league.py --quick

Writes BENCH_league.json: per-K timings, the K=8 speedup vs acceptance,
an Elo sanity record (planted ordering recovered from noisy matches), and
the match-count bookkeeping.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time


def build_arena(num_envs, steps, hidden=64):
    from repro.envs.ocean import Duel
    from repro.league import Arena
    from repro.rl.trainer import ocean_policy_stack
    em, dist, pol = ocean_policy_stack(Duel(), hidden=hidden)
    return pol, Arena(em, pol, dist, num_envs=num_envs, steps=steps)


def bench_pool(arena, pol, K, repeats):
    """Warmed wall-time of one learner-vs-K-pool evaluation, both paths."""
    import jax
    import jax.numpy as jnp
    key = jax.random.PRNGKey(0)
    pa = pol.init(jax.random.fold_in(key, 1000))
    stacked = jax.tree.map(
        lambda *xs: jnp.stack(xs),
        *[pol.init(jax.random.fold_in(key, i)) for i in range(K)])

    # warm both programs (compile excluded from timing)
    arena.vs_pool(pa, stacked, key)
    arena.vs_pool_sequential(pa, stacked, key)

    def timed(fn):
        # min over repeats: the least-noise estimate of the true cost on a
        # shared machine (both paths measured the same way)
        best, out = float("inf"), None
        for r in range(repeats):
            t0 = time.perf_counter()
            out = fn(jax.random.fold_in(key, r))
            best = min(best, time.perf_counter() - t0)
        return best, out

    t_vmap, out_v = timed(lambda k: arena.vs_pool(pa, stacked, k))
    t_seq, out_s = timed(lambda k: arena.vs_pool_sequential(pa, stacked, k))

    # same keys ⇒ the two paths must agree exactly
    for a, b in zip(out_v, out_s):
        assert abs(a["outcome"] - b["outcome"]) < 1e-6, (a, b)
    return t_vmap, t_seq


def elo_sanity():
    """The ranker recovers 5 planted skill tiers from noisy outcomes."""
    import numpy as np
    from repro.league import Ranker
    skills = [-2.0, -1.0, 0.0, 1.0, 2.0]
    rng = np.random.default_rng(7)
    ranker = Ranker()
    for _ in range(400):
        a, b = rng.choice(5, size=2, replace=False)
        p_a = 1.0 / (1.0 + np.exp(-(skills[a] - skills[b])))
        ranker.update(int(a), int(b), float(rng.random() < p_a))
    return {"planted_order": [4, 3, 2, 1, 0], "recovered": ranker.rank(),
            "ok": ranker.rank() == [4, 3, 2, 1, 0]}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smaller arena + fewer repeats (CI)")
    ap.add_argument("--out", default="BENCH_league.json")
    args = ap.parse_args()

    os.environ.setdefault("JAX_PLATFORMS", "cpu")
    sys.path.insert(0, os.path.join(os.path.dirname(
        os.path.dirname(os.path.abspath(__file__))), "src"))

    # the paper's small-env regime: per-match compute is tiny, so dispatch
    # count is the cost — exactly where the fused pool launch pays off
    num_envs = 8
    steps = 40 if args.quick else 64
    repeats = 5 if args.quick else 10

    pol, arena = build_arena(num_envs, steps)
    results = {}
    for K in (2, 4, 8):
        t_vmap, t_seq = bench_pool(arena, pol, K, repeats)
        results[f"K{K}"] = {
            "vmapped_s": round(t_vmap, 4), "sequential_s": round(t_seq, 4),
            "speedup": round(t_seq / t_vmap, 2),
            "matches": K, "envs_per_match": num_envs, "steps": steps,
        }
        print(f"K={K}: vmapped {t_vmap*1e3:7.1f} ms  "
              f"sequential {t_seq*1e3:7.1f} ms  "
              f"speedup {t_seq / t_vmap:5.2f}x")

    elo = elo_sanity()
    print(f"elo planted-order recovery: {'OK' if elo['ok'] else 'FAILED'}")

    sp8 = results["K8"]["speedup"]
    out = {
        "bench": "league_arena",
        "acceptance": {"metric": "K8 vmapped pool vs sequential dispatch",
                       "threshold_x": 3.0, "measured_x": sp8,
                       "ok": sp8 >= 3.0},
        "results": results,
        "elo_sanity": elo,
    }
    with open(args.out, "w") as f:
        json.dump(out, f, indent=2)
    print(f"wrote {args.out}; K8 speedup {sp8}x "
          f"(acceptance >= 3x: {'OK' if sp8 >= 3.0 else 'FAILED'})")
    from repro.telemetry import benchwatch
    benchwatch.record(
        "league",
        {f"{k}_speedup": v["speedup"] for k, v in results.items()},
        acceptance={"k8_speedup_ge_3x": sp8 >= 3.0,
                    "elo_sanity": bool(elo["ok"])},
        meta={"quick": bool(args.quick)})
    if not out["acceptance"]["ok"] or not elo["ok"]:
        sys.exit(1)


if __name__ == "__main__":
    main()
