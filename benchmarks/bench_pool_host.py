"""Paper Table 2, the EnvPool claim proper: on jittered host envs, taking
the first N of M finishers beats synchronous vectorization by ≥30% (paper:
30%–6x, largest when step-time variance is high — e.g. Crafter resets).

We reproduce it with a host env whose step blocks (GIL released) for a
lognormal duration and a policy with fixed latency:
  sync      — N == M, wait for all (Gymnasium/SB3 semantics)
  pool 2N   — M = 2N, double-buffered (paper's recommended setting)
"""
from __future__ import annotations

import time

import numpy as np

from repro.core.host import HostEnv, HostPool


class JitteredEnv(HostEnv):
    """Blocking step with lognormal latency — NetHack/Crafter-shaped."""

    def __init__(self, mean_ms: float = 2.0, sigma: float = 0.6,
                 reset_ms: float = 10.0, horizon: int = 64, seed: int = 0):
        self.rng = np.random.RandomState(seed)
        self.mean_ms, self.sigma, self.reset_ms = mean_ms, sigma, reset_ms
        self.horizon = horizon
        self.t = 0

    def reset(self, seed):
        time.sleep(self.reset_ms / 1e3)         # slow resets (paper: Crafter)
        self.t = 0
        return np.zeros(8, np.float32)

    def step(self, action):
        dt = self.rng.lognormal(np.log(self.mean_ms), self.sigma) / 1e3
        time.sleep(dt)
        self.t += 1
        done = self.t >= self.horizon
        return np.full(8, self.t, np.float32), 1.0, done, {}


def _policy(obs, latency_ms=1.5):
    time.sleep(latency_ms / 1e3)                # GPU forward stand-in
    return np.zeros((obs.shape[0],), np.int64)


def run_once(M: int, N: int, steps: int = 300, seed: int = 0):
    pool = HostPool([lambda i=i: JitteredEnv(seed=seed + i)
                     for i in range(M)], batch_size=N, seed=seed)
    t0 = time.perf_counter()
    for _ in range(steps):
        obs, rew, done, info, ids = pool.recv()
        act = _policy(obs)
        pool.send(act, ids)
    sps = steps * N / (time.perf_counter() - t0)
    pool.close()
    return sps


def run(N: int = 8, steps: int = 200):
    sync = run_once(M=N, N=N, steps=steps)          # wait-for-all baseline
    pool2 = run_once(M=2 * N, N=N, steps=steps)     # paper's M = 2N
    pool4 = run_once(M=4 * N, N=N, steps=steps)     # M >> 2N straggler mode
    return {"sync_sps": sync, "pool2_sps": pool2, "pool4_sps": pool4,
            "pool2_gain_pct": (pool2 / sync - 1) * 100,
            "pool4_gain_pct": (pool4 / sync - 1) * 100}


def main():
    from repro.telemetry import benchwatch
    r = run()
    print(f"bench_pool_host/envpool,{1e6 / r['pool2_sps']:.1f},"
          f"sync_sps={r['sync_sps']:.0f};pool2_sps={r['pool2_sps']:.0f};"
          f"pool4_sps={r['pool4_sps']:.0f};"
          f"pool2_gain_pct={r['pool2_gain_pct']:.1f};"
          f"pool4_gain_pct={r['pool4_gain_pct']:.1f}")
    benchwatch.record(
        "pool_host", {k: r[k] for k in ("sync_sps", "pool2_sps",
                                        "pool4_sps")})


if __name__ == "__main__":
    main()
