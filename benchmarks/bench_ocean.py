"""Paper §4 claim: each Ocean env solved (score > 0.9) in roughly 30k
interactions with one barely-tuned hyperparameter set; whole suite in a
coffee break on one CPU core."""
from __future__ import annotations

import time

from repro.configs.base import TrainConfig
from repro.envs.ocean import OCEAN
from repro.rl.trainer import Trainer

TCFG = TrainConfig(num_envs=64, unroll_length=64, update_epochs=4,
                   num_minibatches=4, learning_rate=1e-3, gamma=0.95,
                   ent_coef=0.01)

BUDGET = {"squared": 300_000, "password": 300_000, "stochastic": 200_000,
          "memory": 500_000, "multiagent": 150_000, "spaces": 200_000,
          "bandit": 150_000, "continuous": 400_000}


def run():
    rows = []
    for name, cls in OCEAN.items():
        t0 = time.perf_counter()
        tr = Trainer(cls(), TCFG, hidden=64, recurrent=(name == "memory"),
                     kernel_mode="ref")
        m = tr.train(BUDGET[name], target_score=0.9)
        rows.append({"env": name, "score": m["score"],
                     "env_steps": m["env_steps"],
                     "solved": m["score"] >= 0.9,
                     "wall_s": time.perf_counter() - t0})
    return rows


def main():
    from repro.telemetry import benchwatch
    rows = run()
    for r in rows:
        print(f"bench_ocean/{r['env']},{r['wall_s']*1e6:.0f},"
              f"score={r['score']:.3f};steps={r['env_steps']};"
              f"solved={int(r['solved'])}")
    benchwatch.record(
        "ocean",
        {f"{r['env']}_sps": r["env_steps"] / max(r["wall_s"], 1e-9)
         for r in rows},
        acceptance={f"{r['env']}_solved": bool(r["solved"]) for r in rows})


if __name__ == "__main__":
    main()
