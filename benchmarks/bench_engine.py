"""TrainEngine SPS trajectory: K fused updates per dispatch × backend.

The paper's pitch is IPC-count reduction; the engine's is dispatch-count
reduction. This benchmark measures steps/second for K ∈ {1, 4, 16, 64}
(one launch = one ``lax.scan`` of K fused PPO updates) on the jit and
shard_map tiers, in the small-unroll Ocean regime where per-update dispatch
and host sync dominate. K=1 is the per-update-dispatch baseline the repo
trained with before the engine landed.

  PYTHONPATH=src python benchmarks/bench_engine.py --quick
  PYTHONPATH=src python benchmarks/bench_engine.py --devices 8   # shard_map DP=8

Writes BENCH_engine.json: the SPS grid, the K16/K1 speedups (acceptance:
≥ 1.5× on ≥ 2 envs), and the shard_map seed-match parity (max |Δparam| vs
the single-device run).
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time


def build_engine(env_cls, tcfg, backend, num_shards=1, seed=0):
    import jax
    from repro.core.emulation import Emulated
    from repro.models.policy import OceanPolicy
    from repro.rl.distributions import Dist
    from repro.rl.engine import TrainEngine
    em = Emulated(env_cls())
    dist = Dist("categorical", nvec=em.act_spec.nvec)
    pol = OceanPolicy(em.obs_spec.total, dist.nvec, hidden=32,
                      num_outputs=dist.num_outputs)
    return TrainEngine(em, pol, tcfg, dist, key=jax.random.PRNGKey(seed),
                       backend=backend, kernel_mode="ref",
                       num_shards=num_shards)


def bench_one(env_cls, tcfg, backend, num_updates):
    import jax
    eng = build_engine(env_cls, tcfg, backend)
    eng.run(eng.K * eng.steps_per_update)            # warmup: compile K launch
    # tail launches compile a second program; warm it too when sizes differ
    tail = num_updates % eng.K
    if tail:
        eng.run(tail * eng.steps_per_update)
    jax.block_until_ready(eng.ts.params)
    t0 = time.perf_counter()
    hist, _ = eng.run(num_updates * eng.steps_per_update)
    jax.block_until_ready(eng.ts.params)
    dt = time.perf_counter() - t0
    assert len(hist) == num_updates
    return num_updates * eng.steps_per_update / dt


def shard_parity(env_cls, tcfg, updates=6):
    """Max |Δparam| between the S-device shard_map run and the seed-matched
    single-device S-block emulation."""
    import jax
    import numpy as np
    S = jax.device_count()
    single = build_engine(env_cls, tcfg, "jit", num_shards=S)
    single.run(updates * single.steps_per_update)
    sharded = build_engine(env_cls, tcfg, "shard_map")
    sharded.run(updates * sharded.steps_per_update)
    diffs = [float(np.max(np.abs(np.asarray(a) - np.asarray(b))))
             for a, b in zip(jax.tree.leaves(jax.device_get(single.ts.params)),
                             jax.tree.leaves(jax.device_get(sharded.ts.params)))]
    return {"devices": S, "updates": updates, "max_param_diff": max(diffs)}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="fewer timed updates; skip K=64")
    ap.add_argument("--devices", type=int, default=0,
                    help="force host platform device count (shard_map tier)")
    ap.add_argument("--out", default="BENCH_engine.json")
    args = ap.parse_args(argv)
    if args.devices:
        os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                                   f" --xla_force_host_platform_device_count="
                                   f"{args.devices}")

    import jax
    from repro.configs.base import TrainConfig
    from repro.envs.ocean import Bandit, Squared

    envs = {"bandit": Bandit, "squared": Squared}
    ks = (1, 4, 16) if args.quick else (1, 4, 16, 64)
    backends = ["jit"]
    ndev = jax.device_count()
    if ndev > 1 or args.devices:
        backends.append("shard_map")

    def tcfg_for(k):
        return TrainConfig(num_envs=16, unroll_length=16, update_epochs=2,
                           num_minibatches=2, learning_rate=1e-3, gamma=0.95,
                           updates_per_launch=k)

    num_updates = 64 if args.quick else 192
    results = []
    for env_name, env_cls in envs.items():
        for backend in backends:
            for k in ks:
                if backend == "shard_map" and k not in (1, 16):
                    continue          # trajectory endpoints only
                sps = bench_one(env_cls, tcfg_for(k), backend, num_updates)
                results.append({"env": env_name, "backend": backend, "K": k,
                                "sps": round(sps, 1)})
                print(f"bench_engine/{env_name}/{backend}/K{k},"
                      f"{num_updates * 256 / sps * 1e6:.0f},sps={sps:.0f}")

    speedups = {}
    for env_name in envs:
        row = {r["K"]: r["sps"] for r in results
               if r["env"] == env_name and r["backend"] == "jit"}
        speedups[env_name] = round(row[16] / row[1], 2)
        print(f"bench_engine/{env_name}/speedup_K16_over_K1,"
              f"0,x={speedups[env_name]:.2f}")

    parity = None
    if ndev > 1:
        parity = shard_parity(Bandit, tcfg_for(3))
        print(f"bench_engine/shard_parity,0,"
              f"max_param_diff={parity['max_param_diff']:.2e};"
              f"devices={parity['devices']}")

    out = {
        "meta": {"num_updates": num_updates, "devices": ndev,
                 "steps_per_update": 256, "quick": bool(args.quick),
                 "config": {"num_envs": 16, "unroll_length": 16,
                            "update_epochs": 2, "num_minibatches": 2,
                            "hidden": 32}},
        "results": results,
        "speedup_K16_over_K1": speedups,
        "shard_parity": parity,
    }
    with open(args.out, "w") as f:
        json.dump(out, f, indent=2)
    print(f"wrote {args.out}")
    from repro.telemetry import benchwatch
    benchwatch.record(
        "engine",
        {f"{r['env']}_{r['backend']}_K{r['K']}_sps": r["sps"]
         for r in results},
        meta={"quick": bool(args.quick), "devices": ndev})


if __name__ == "__main__":
    main()
