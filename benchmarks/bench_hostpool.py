"""HostPool thread-vs-proc SPS: where does killing the GIL pay?

Two workload cells over the same first-finisher pool (M = 16, N = 8):

  * ``cpu``   — ``HostCrafterLite`` with its pure-Python LCG burn calibrated
                to ~2 ms/step. Threads serialize on the GIL; ``proc``
                (shared-memory spawn workers) parallelizes across cores.
                Acceptance (multicore only): proc ≥ 2× thread async SPS.
  * ``sleep`` — the same env with a GIL-*releasing* ``time.sleep`` step and
                no burn. Threads are already optimal here; proc must not
                regress materially. Acceptance: proc ≥ 0.85× thread.

The report is machine-aware: the ≥ 2× criterion is *physically impossible*
on a single core (processes cannot run in parallel), so ``acceptance``
records ``multicore_criteria_applicable`` and only asserts the ratios when
``cores >= 2`` — CI's multicore runners regenerate the artifact and enforce
them for real. Slab section sizes and the busy-wait ladder parameters are
recorded alongside the numbers so regressions are attributable.

  PYTHONPATH=src python benchmarks/bench_hostpool.py --quick

Writes BENCH_hostpool.json.
"""
from __future__ import annotations

import argparse
import functools
import json
import os
import sys
import time

import numpy as np


def calibrate_work(target_ms: float = 2.0) -> tuple:
    """LCG iterations per step for ~``target_ms`` of pure-Python burn on
    this machine, plus the step time measured at that setting."""
    from repro.envs.ocean_host import HostCrafterLite
    probe = HostCrafterLite(work=20_000)
    probe.reset(0)
    t0 = time.perf_counter()
    for t in range(20):
        probe.step(t % 6)
    per_iter = (time.perf_counter() - t0) / 20 / 20_000
    work = max(1000, int(target_ms / 1e3 / per_iter))
    env = HostCrafterLite(work=work)
    env.reset(0)
    t0 = time.perf_counter()
    for t in range(20):
        env.step(t % 6)
    return work, (time.perf_counter() - t0) / 20 * 1e3


def pool_sps(env_fn, M: int, N: int, steps: int, backend: str,
             spin=None) -> float:
    """SPS of a bare recv→send loop (no policy) over ``HostVecEnv``."""
    from repro.bridge import wrap
    venv = wrap(env_fn, num_envs=M, batch_size=N, seed=0,
                recv_timeout=120.0, backend=backend, spin=spin)
    try:
        _obs, _r, _d, _i, ids = venv.recv()
        t0 = time.perf_counter()
        for _ in range(steps):
            venv.send(np.zeros((N, 1), np.int64), ids)
            _obs, _r, _d, _i, ids = venv.recv()
        return steps * N / (time.perf_counter() - t0)
    finally:
        venv.close()


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="fewer timed steps (CI smoke)")
    ap.add_argument("--out", default="BENCH_hostpool.json")
    ap.add_argument("--target-step-ms", type=float, default=2.0,
                    help="calibrated pure-Python step cost for the cpu cell")
    args = ap.parse_args(argv)

    from repro.core import shm
    from repro.envs.ocean_host import HostCrafterLite

    M, N = 16, 8
    steps = 30 if args.quick else 120
    cores = os.cpu_count() or 1
    spin = shm.default_spin(workers=M)

    work, step_ms = calibrate_work(args.target_step_ms)
    print(f"calibrated work={work} (~{step_ms:.2f} ms/step), cores={cores}")

    cells = {}
    cpu_fn = functools.partial(HostCrafterLite, work=work)
    sleep_fn = functools.partial(HostCrafterLite, work=0,
                                 sleep_ms=args.target_step_ms)
    for cell, fn in (("cpu", cpu_fn), ("sleep", sleep_fn)):
        res = {}
        for backend in ("thread", "proc"):
            res[backend] = pool_sps(fn, M, N, steps, backend, spin=spin)
            print(f"bench_hostpool/{cell}_{backend},"
                  f"{1e6 / res[backend]:.1f},sps={res[backend]:.0f}")
        res["proc_over_thread"] = res["proc"] / res["thread"]
        print(f"  {cell}: proc/thread = {res['proc_over_thread']:.2f}x")
        cells[cell] = {k: round(v, 2) for k, v in res.items()}

    multicore = cores >= 2
    cpu_ok = cells["cpu"]["proc_over_thread"] >= 2.0
    sleep_ok = cells["sleep"]["proc_over_thread"] >= 0.85
    if not multicore:
        print("=" * 72)
        print("WARNING: SINGLE-CORE MACHINE — ACCEPTANCE CRITERIA NOT "
              "APPLICABLE")
        print("  Both proc-vs-thread criteria need real parallelism: on one")
        print("  core the proc backend cannot beat threads by construction")
        print("  (cpu cell), and the spin/flag handshake itself has nowhere")
        print("  to run (sleep cell). Measured ratios are recorded honestly;")
        print("  neither is asserted. acceptance.acceptance_applicable=false")
        print("  in the JSON — re-run on a multicore machine (CI runners)")
        print("  for numbers the >=2x / >=0.85x criteria apply to.")
        print("=" * 72)
    layout = shm.SlabLayout(
        shm.SlabSpec(obs_shape=(8 * 8 + 4,), act_shape=(1,)), M)
    out = {
        "meta": {
            "M": M, "N": N, "steps": steps, "quick": bool(args.quick),
            "cores": cores,
            "python": sys.version.split()[0],
            "cpu_cell": {"work": work, "measured_step_ms":
                         round(step_ms, 3)},
            "sleep_cell": {"sleep_ms": args.target_step_ms},
            "spin": {"spin": spin.spin, "yields": spin.yields,
                     "min_sleep_us": spin.min_sleep_us,
                     "max_sleep_us": spin.max_sleep_us,
                     "idle_sleep_us": spin.idle_sleep_us,
                     "idle_after_s": spin.idle_after_s},
            "slab_bytes": layout.slab_bytes(),
            "slab_total_bytes": layout.nbytes,
        },
        "cells": cells,
        "acceptance": {
            # both criteria need real parallelism: on one core the proc
            # backend cannot beat threads by construction (cpu cell), and
            # the flag handshake itself has nowhere to run (sleep cell).
            # acceptance_applicable is THE machine-applicability bit readers
            # should key on (multicore_criteria_applicable kept as an alias
            # for earlier consumers of this artifact)
            "acceptance_applicable": multicore,
            "multicore_criteria_applicable": multicore,
            "cpu_proc_ge_2x_thread": cpu_ok if multicore else None,
            "sleep_proc_ge_0p85x_thread": sleep_ok if multicore else None,
        },
    }
    with open(args.out, "w") as f:
        json.dump(out, f, indent=2)
    print(f"wrote {args.out}")
    from repro.telemetry import benchwatch
    benchwatch.record(
        "hostpool",
        {f"{cell}_{bk}_sps": cells[cell][bk]
         for cell in cells for bk in ("thread", "proc")},
        acceptance={
            "acceptance_applicable": multicore,
            "cpu_proc_ge_2x_thread": cpu_ok if multicore else None,
            "sleep_proc_ge_0p85x_thread": sleep_ok if multicore else None},
        meta={"quick": bool(args.quick), "M": M, "N": N})
    if multicore and not cpu_ok:
        print("FAIL: cpu cell proc < 2x thread on a multicore machine")
        return 1
    if multicore and not sleep_ok:
        print("FAIL: sleep cell proc < 0.85x thread")
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
