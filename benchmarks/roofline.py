"""Roofline report generator: reads the dry-run sweep JSON and emits the
EXPERIMENTS.md §Roofline table (terms in seconds, bottleneck, MODEL_FLOPS /
HLO_FLOPs ratio, one-line recommendation)."""
from __future__ import annotations

import json
import sys


def reco(r) -> str:
    b = r.get("bottleneck")
    kind = r.get("kind")
    if b == "collective":
        if kind == "decode":
            return "gather-free decode: quantize weights / shrink TP group"
        return "overlap FSDP gathers with compute; bf16 collectives"
    if b == "memory":
        if kind == "decode":
            return "KV cache quantization (int8) halves the dominant reads"
        return "fuse elementwise chains; fewer f32 intermediates"
    return "MXU-bound: increase per-chip batch or reduce remat recompute"


def table(results, mesh_filter="16x16"):
    rows = []
    for r in results:
        if r.get("mesh") != mesh_filter:
            continue
        if r.get("status") == "skipped":
            rows.append(f"| {r['arch']} | {r['shape']} | — | — | — | n/a | — | "
                        f"skipped (full attention, see DESIGN.md) |")
            continue
        terms = (r["t_compute_s"], r["t_memory_s"], r["t_collective_s"])
        ratio = r.get("useful_flops_ratio")
        frac = r.get("roofline_fraction")
        rows.append(
            f"| {r['arch']} | {r['shape']} | {terms[0]:.3g} | {terms[1]:.3g} "
            f"| {terms[2]:.3g} | {r['bottleneck']} | "
            f"{ratio:.2f} / {frac:.4f} | {reco(r)} |")
    hdr = ("| arch | shape | t_compute (s) | t_memory (s) | t_collective (s) "
           "| bottleneck | useful-FLOPs ratio / roofline frac | "
           "what moves the dominant term |\n|---|---|---|---|---|---|---|---|")
    return hdr + "\n" + "\n".join(rows)


def main():
    path = sys.argv[1] if len(sys.argv) > 1 else "results/dryrun_baseline_final.json"
    with open(path) as f:
        results = json.load(f)
    print(table(results))


if __name__ == "__main__":
    main()
