"""Kernel backend autotune table — dispatch.autotune over every registered
op, timing each runnable backend (ref / chunked / pallas_interpret on CPU;
plus compiled pallas on TPU) and printing the per-op winner the registry
will use for subsequent auto dispatch.

Prints ``kernels/<op>/<backend>,us_per_call,winner=<best>`` CSV rows.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import dispatch


def _args(op, key):
    r = lambda i, shape, scale=1.0: (
        jax.random.normal(jax.random.fold_in(key, i), shape, jnp.float32)
        * scale)
    if op == "flash_attention":
        shp = (2, 128, 4, 32)
        return (r(1, shp), r(2, shp), r(3, shp)), dict(causal=True)
    if op == "flash_decode":
        return (r(1, (4, 8, 32)), r(2, (4, 256, 2, 32)),
                r(3, (4, 256, 2, 32)), jnp.asarray(200, jnp.int32)), {}
    if op == "quant_matmul":
        wq = jax.random.randint(jax.random.fold_in(key, 2), (128, 256),
                                -127, 128, jnp.int32).astype(jnp.int8)
        return (r(1, (64, 128)), wq, jnp.abs(r(3, (256,))) * 0.02), {}
    if op == "gae":
        d = jax.random.bernoulli(jax.random.fold_in(key, 3), 0.1, (64, 128))
        return (r(1, (64, 128)), r(2, (64, 128)), d, r(4, (64,)),
                0.99, 0.95), {}
    if op == "ssd":
        return (r(1, (2, 128, 4, 32), 0.5),
                jax.nn.softplus(r(2, (2, 128, 4))),
                -jnp.exp(r(3, (4,), 0.3)),
                r(4, (2, 128, 4, 16), 0.5),
                r(5, (2, 128, 4, 16), 0.5)), dict(chunk=32)
    if op == "pack":
        leaves = [jax.random.randint(jax.random.fold_in(key, i), (256, n),
                                     0, 256, jnp.int32).astype(jnp.uint8)
                  for i, n in enumerate((8, 32, 64))]
        return (leaves,), {}
    raise AssertionError(op)


def main(include_interpret: bool = False) -> None:
    """Interpret mode is 100-1000x slower than compiled paths — skipped by
    default so the table reflects deployable backends."""
    key = jax.random.PRNGKey(0)
    cells = {}
    try:
        for op in dispatch.OPS:
            impls = dispatch.available(op)
            if not include_interpret:
                impls = tuple(n for n in impls if n != dispatch.INTERPRET)
            args, kw = _args(op, key)
            results, best = dispatch.autotune(op, *args, impls=impls,
                                              iters=10, **kw)
            cells[f"{op}_best_calls_per_s"] = results[best]
            for name, calls_per_s in sorted(results.items(),
                                            key=lambda kv: -kv[1]):
                print(f"kernels/{op}/{name},{1e6 / calls_per_s:.1f},"
                      f"winner={best}")
    finally:
        # winners were tuned on this table's fixed shapes — don't let them
        # leak into auto dispatch for the rest of the process
        dispatch.clear_autotune()
    from repro.telemetry import benchwatch
    benchwatch.record("kernels", cells,
                      meta={"include_interpret": bool(include_interpret)})


if __name__ == "__main__":
    main()
