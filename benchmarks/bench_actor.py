"""Async actor–learner SPS vs the host and jit tiers under actor jitter.

Three cells train the same bandit MDP with the same policy/learner math:

  * ``jit``   — the fused single-process tier on the jax-native ``Bandit``;
                no host latency is physically possible here, so this is the
                no-jitter ceiling.
  * ``host``  — the bridged first-finisher tier on ``HostBandit`` with
                ~``jitter_ms`` of lognormal per-step host latency: the
                learner still waits for a full batch of N envs each update.
  * ``async`` — the actor–learner tier (2 spawn actors) on ``Bandit`` with
                ``actor_jitter_ms = jitter_ms`` injected in the actor loop:
                actors absorb the latency while the learner consumes
                fragments at its own rate.

SPS is measured from the *second* update onward (the first update's wall
time is dominated by XLA compilation in every tier).

The report is machine-aware, same contract as BENCH_hostpool.json: hiding
actor latency needs the actors and the learner to actually run in parallel,
so the ``async >= 1.3x host`` criterion is only asserted when ``cores >= 2``
— ``acceptance.acceptance_applicable`` records the machine's verdict and the
measured ratios are written honestly either way.

  PYTHONPATH=src python benchmarks/bench_actor.py --quick

Writes BENCH_actor.json.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time


def timed_sps(run_fn, spu: int):
    """(sps, updates) with the compile-dominated first update excluded."""
    stamps = []
    hist = run_fn(lambda u, md: stamps.append(time.perf_counter()))
    if len(stamps) < 2:
        return 0.0, len(stamps)
    return (len(stamps) - 1) * spu / (stamps[-1] - stamps[0]), len(stamps)


def bench_jit(tcfg, updates: int):
    import jax
    from repro.envs.ocean import Bandit
    from repro.rl.engine import TrainEngine
    from repro.rl.trainer import ocean_policy_stack
    em, dist, policy = ocean_policy_stack(Bandit(), hidden=32,
                                          recurrent=False, conv=None)
    eng = TrainEngine(em, policy, tcfg, dist, key=jax.random.PRNGKey(0),
                      backend="jit", kernel_mode="ref", checkpoint_dir=None)
    spu = eng.steps_per_update
    try:
        return timed_sps(lambda cb: eng.run(total_steps=spu * updates,
                                            on_update=cb), spu)
    finally:
        eng.close()


def bench_host(tcfg, updates: int, jitter_ms: float):
    import functools
    from repro.bridge import make_host_engine
    from repro.envs.ocean_host import HostBandit
    fn = functools.partial(HostBandit, jitter_ms=jitter_ms)
    eng = make_host_engine(fn, tcfg, hidden=32, kernel_mode="ref")
    spu = eng.steps_per_update
    try:
        return timed_sps(lambda cb: eng.run(total_steps=spu * updates,
                                            on_update=cb), spu)
    finally:
        eng.close()


def bench_async(tcfg, updates: int):
    import jax
    from repro.envs.ocean import Bandit
    from repro.rl.engine import TrainEngine
    from repro.rl.trainer import ocean_policy_stack
    em, dist, policy = ocean_policy_stack(Bandit(), hidden=32,
                                          recurrent=False, conv=None)
    eng = TrainEngine(em, policy, tcfg, dist, key=jax.random.PRNGKey(0),
                      backend="async", kernel_mode="ref", checkpoint_dir=None)
    spu = eng.steps_per_update
    try:
        sps, n = timed_sps(lambda cb: eng.run(total_steps=spu * updates,
                                              on_update=cb), spu)
        return sps, n, eng.rollouts.layout.nbytes
    finally:
        eng.close()


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="fewer timed updates (CI smoke)")
    ap.add_argument("--out", default="BENCH_actor.json")
    ap.add_argument("--jitter-ms", type=float, default=2.0,
                    help="injected per-step actor/env host latency")
    args = ap.parse_args(argv)

    from repro.configs.base import TrainConfig

    cores = os.cpu_count() or 1
    updates = 4 if args.quick else 8
    base = dict(num_envs=16, unroll_length=32, update_epochs=2,
                num_minibatches=2, learning_rate=1e-3, gamma=0.95,
                checkpoint_every=0)
    print(f"cores={cores}, updates={updates}, "
          f"jitter={args.jitter_ms:.1f} ms/step")

    cells = {}
    sps, n = bench_jit(TrainConfig(**base), updates)
    cells["jit"] = {"sps": round(sps, 1), "updates": n, "jitter_ms": 0.0}
    print(f"bench_actor/jit,{1e6 / max(sps, 1e-9):.2f},sps={sps:.0f}")

    sps, n = bench_host(TrainConfig(**base), updates, args.jitter_ms)
    cells["host"] = {"sps": round(sps, 1), "updates": n,
                     "jitter_ms": args.jitter_ms}
    print(f"bench_actor/host,{1e6 / max(sps, 1e-9):.2f},sps={sps:.0f}")

    acfg = TrainConfig(**base, num_actors=2,
                       actor_jitter_ms=args.jitter_ms)
    sps, n, slab = bench_async(acfg, updates)
    cells["async"] = {"sps": round(sps, 1), "updates": n,
                      "jitter_ms": args.jitter_ms, "num_actors": 2}
    print(f"bench_actor/async,{1e6 / max(sps, 1e-9):.2f},sps={sps:.0f}")

    ratio = cells["async"]["sps"] / max(cells["host"]["sps"], 1e-9)
    print(f"  async/host = {ratio:.2f}x, "
          f"async/jit = {cells['async']['sps'] / max(cells['jit']['sps'], 1e-9):.2f}x")

    multicore = cores >= 2
    ok = ratio >= 1.3
    if not multicore:
        print("=" * 72)
        print("WARNING: SINGLE-CORE MACHINE — ACCEPTANCE CRITERIA NOT "
              "APPLICABLE")
        print("  Hiding actor latency needs the actors and the learner to")
        print("  run in parallel; on one core they time-slice and the slab")
        print("  handshake itself competes for the only CPU. Measured")
        print("  ratios are recorded honestly; the >=1.3x criterion is not")
        print("  asserted. acceptance.acceptance_applicable=false in the")
        print("  JSON — re-run on a multicore machine (CI runners) for")
        print("  numbers the criterion applies to.")
        print("=" * 72)
    out = {
        "meta": {
            "updates": updates, "quick": bool(args.quick), "cores": cores,
            "python": sys.version.split()[0],
            "jitter_ms": args.jitter_ms,
            "tcfg": {k: base[k] for k in ("num_envs", "unroll_length",
                                          "update_epochs",
                                          "num_minibatches")},
            "async": {"num_actors": 2, "shards_per_actor": 1,
                      "actor_slots": 2, "slab_bytes": slab},
            "sps_excludes_first_update": True,
        },
        "cells": cells,
        "acceptance": {
            # the criterion needs real parallelism (see the warning above);
            # single-core machines record the measured ratio, assert nothing
            "acceptance_applicable": multicore,
            "async_over_host": round(ratio, 3),
            "async_ge_1p3x_host": ok if multicore else None,
        },
    }
    with open(args.out, "w") as f:
        json.dump(out, f, indent=2)
    print(f"wrote {args.out}")
    from repro.telemetry import benchwatch
    benchwatch.record(
        "actor",
        {f"{tier}_sps": cells[tier]["sps"] for tier in cells},
        acceptance={"acceptance_applicable": multicore,
                    "async_ge_1p3x_host": ok if multicore else None},
        meta={"updates": updates, "quick": bool(args.quick),
              "jitter_ms": args.jitter_ms})
    if multicore and not ok:
        print("FAIL: async < 1.3x host under jitter on a multicore machine")
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
