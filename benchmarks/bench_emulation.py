"""Paper Table 1: emulation overhead.

Times raw env steps vs emulated (flattened) env steps, single instance,
jitted, on this machine. The paper's claim: overhead is a few tens of µs and
negligible for envs slower than a few thousand SPS.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core import spaces as sp
from repro.core.emulation import Emulated, flat_spec, emulate, unemulate
from repro.envs.ocean import OCEAN


class MockStructured:
    """NetHack-shaped mock: dict of mixed-dtype arrays (paper §3.1)."""
    num_agents = 1

    def __init__(self):
        self.observation_space = sp.Dict({
            "glyphs": sp.Box((21, 79), jnp.int32),
            "chars": sp.Box((21, 79), jnp.uint8),
            "blstats": sp.Box((27,), jnp.float32),
            "message": sp.Box((256,), jnp.uint8),
        })
        self.action_space = sp.Discrete(23)

    def init(self, key):
        return {"t": jnp.zeros((), jnp.int32)}

    def reset(self, state, key):
        return state, self._obs(state)

    def _obs(self, s):
        t = s["t"].astype(jnp.float32)
        return {"glyphs": jnp.full((21, 79), s["t"], jnp.int32),
                "chars": jnp.full((21, 79), 32, jnp.uint8),
                "blstats": jnp.full((27,), t),
                "message": jnp.zeros((256,), jnp.uint8)}

    def step(self, state, action, key):
        s = {"t": state["t"] + 1}
        from repro.envs.base import empty_info
        return s, self._obs(s), jnp.float32(0), s["t"] >= 1000, empty_info()


def _time_step(env, steps=3000):
    key = jax.random.PRNGKey(0)
    state = env.init(key)
    state, obs = env.reset(state, key)
    if isinstance(env, Emulated):
        if env.act_spec.kind == "discrete":
            act = jnp.zeros((len(env.action_space.nvec),), jnp.int32)
        else:
            act = jnp.zeros((env.act_spec.cont_dim,), jnp.float32)
    else:
        act = sp.zeros(env.action_space)
    step = jax.jit(env.step)
    state, obs, *_ = step(state, act, key)      # compile
    jax.block_until_ready(jax.tree.leaves(obs)[0])
    t0 = time.perf_counter()
    for i in range(steps):
        state, obs, *_ = step(state, act, key)
    jax.block_until_ready(jax.tree.leaves(obs)[0])
    return (time.perf_counter() - t0) / steps


def run():
    rows = []
    envs = {name: cls() for name, cls in OCEAN.items()}
    envs["mock_nethack"] = MockStructured()
    for name, env in envs.items():
        t_raw = _time_step(env)
        t_emu = _time_step(Emulated(env))
        overhead = (t_emu - t_raw) / max(t_raw, 1e-12) * 100
        rows.append({"env": name, "raw_us": t_raw * 1e6,
                     "emulated_us": t_emu * 1e6,
                     "sps_emulated": 1.0 / t_emu,
                     "overhead_pct": overhead})
    return rows


def main():
    from repro.telemetry import benchwatch
    rows = run()
    for r in rows:
        print(f"bench_emulation/{r['env']},{r['emulated_us']:.1f},"
              f"raw_us={r['raw_us']:.1f};overhead_pct={r['overhead_pct']:.1f};"
              f"sps={r['sps_emulated']:.0f}")
    benchwatch.record(
        "emulation", {f"{r['env']}_sps": r["sps_emulated"] for r in rows})


if __name__ == "__main__":
    main()
