"""Paper Table 2: vectorized throughput — serial vs fused-vmap vs
double-buffered pool (the EnvPool analogue), on real envs.

The paper's result to reproduce: vectorization beats serial everywhere, and
pooling adds ≥30% on top for envs with any policy/step overlap to hide.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core.emulation import Emulated
from repro.core.vector import VecEnv
from repro.core.pool import Pool
from repro.envs.ocean import OCEAN


def _actions(vec_or_pool, batch):
    return jnp.zeros((batch, 1), jnp.int32)


def _policy_like_work(obs):
    """Stand-in policy compute so the pool has something to overlap."""
    w = jnp.ones((obs.shape[-1], 64), obs.dtype)
    return jnp.tanh(obs @ w).sum()


def bench_serial(env, num_envs, steps):
    vec = VecEnv(Emulated(env), num_envs, backend="serial")
    state, obs = vec.init(jax.random.PRNGKey(0))
    act = jnp.zeros((vec.batch_size, len(vec.single_action_space.nvec)),
                    jnp.int32)
    state, obs, *_ = vec.step(state, act, jax.random.PRNGKey(1))
    jax.block_until_ready(obs)
    t0 = time.perf_counter()
    for i in range(steps):
        state, obs, *_ = vec.step(state, act, jax.random.fold_in(
            jax.random.PRNGKey(2), i))
        _policy_like_work(obs).block_until_ready()  # repro: noqa[HOST-SYNC] — measures per-step latency incl. the sync (deliberate)
    return steps * vec.batch_size / (time.perf_counter() - t0)


def bench_vmap(env, num_envs, steps):
    vec = VecEnv(Emulated(env), num_envs, backend="vmap")
    state, obs = vec.init(jax.random.PRNGKey(0))
    act = jnp.zeros((vec.batch_size, len(vec.single_action_space.nvec)),
                    jnp.int32)
    state, obs, *_ = vec.step(state, act, jax.random.PRNGKey(1))
    jax.block_until_ready(obs)
    t0 = time.perf_counter()
    for i in range(steps):
        state, obs, *_ = vec.step(state, act, jax.random.fold_in(
            jax.random.PRNGKey(2), i))
        _policy_like_work(obs).block_until_ready()  # repro: noqa[HOST-SYNC] — measures per-step latency incl. the sync (deliberate)
    return steps * vec.batch_size / (time.perf_counter() - t0)


def bench_pool(env, num_envs, steps, buffers=2):
    pool = Pool(Emulated(env), num_envs, num_buffers=buffers)
    act = jnp.zeros((pool.batch_size,
                     len(pool.vec.single_action_space.nvec)), jnp.int32)
    for _ in range(buffers):                    # warm both buffers
        obs, *_ , b = pool.recv()
        pool.send(act, b)
    t0 = time.perf_counter()
    for i in range(steps):
        obs, rew, done, info, b = pool.recv()
        _policy_like_work(obs)                  # NOT blocked — overlap
        pool.send(act, b)
    jax.block_until_ready(obs)
    return steps * pool.batch_size / (time.perf_counter() - t0)


def run(num_envs=64, steps=200):
    rows = []
    for name in ("squared", "bandit", "stochastic", "memory"):
        env_cls = OCEAN[name]
        r = {"env": name,
             "serial": bench_serial(env_cls(), min(num_envs, 8), steps // 4)
             * num_envs / min(num_envs, 8),   # extrapolated (serial is slow)
             "vmap": bench_vmap(env_cls(), num_envs, steps),
             "pool": bench_pool(env_cls(), num_envs, steps)}
        r["pool_vs_vmap_pct"] = (r["pool"] / r["vmap"] - 1) * 100
        rows.append(r)
    return rows


def main():
    from repro.telemetry import benchwatch
    rows = run()
    cells = {}
    for r in rows:
        print(f"bench_vector/{r['env']},{1e6 / r['vmap']:.2f},"
              f"serial_sps={r['serial']:.0f};vmap_sps={r['vmap']:.0f};"
              f"pool_sps={r['pool']:.0f};"
              f"pool_gain_pct={r['pool_vs_vmap_pct']:.1f}")
        cells[f"{r['env']}_vmap_sps"] = r["vmap"]
        cells[f"{r['env']}_pool_sps"] = r["pool"]
    benchwatch.record("vector", cells)


if __name__ == "__main__":
    main()
