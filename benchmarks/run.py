"""Benchmark harness: one module per paper table. Prints
``name,us_per_call,derived`` CSV (plus the roofline table if the dry-run
sweep results exist)."""
import os


def main() -> None:
    from benchmarks import bench_emulation, bench_vector, bench_ocean
    print("# Table 1 — emulation overhead (paper §5)")
    bench_emulation.main()
    print("# Table 2 — vectorized throughput (paper §5)")
    bench_vector.main()
    print("# Table 2 — EnvPool vs synchronous on jittered host envs")
    from benchmarks import bench_pool_host
    bench_pool_host.main()
    print("# §4 — Ocean solve table")
    bench_ocean.main()
    print("# §3.3 — kernel backend autotune")
    from benchmarks import bench_kernels
    bench_kernels.main()
    if os.path.exists("results/dryrun_baseline_final.json"):
        print("# §Roofline (from dry-run sweep)")
        from benchmarks import roofline
        import json
        with open("results/dryrun_baseline_final.json") as f:
            results = json.load(f)
        for r in results:
            if r.get("status") == "ok":
                print(f"roofline/{r['arch']}/{r['shape']}/{r['mesh']},"
                      f"{max(r['t_compute_s'], r['t_memory_s'], r['t_collective_s'])*1e6:.0f},"
                      f"bottleneck={r['bottleneck']};frac={r.get('roofline_fraction', 0):.4f}")


if __name__ == '__main__':
    main()
