"""HostBridge sync-vs-async SPS under injected step jitter (paper Table 2).

The EnvPool claim the ``host`` tier inherits: on jittered host envs, batching
the first N of M = 2N finishers beats synchronous (M = N, wait-for-all)
vectorization by ≥ 30%, because stragglers never gate the batch and env
stepping overlaps policy compute. Measured twice:

  * ``vecenv`` — a bridge-wrapped Gymnasium-API env with lognormal step
    latency driven by a fixed-latency policy stand-in (pure bridge overhead,
    no learner).
  * ``engine`` — the real thing: ``TrainEngine(backend="host")`` PPO on the
    jittered ``HostBandit`` mirror, M = N vs M = 2N.

  PYTHONPATH=src python benchmarks/bench_bridge.py --quick

Writes BENCH_bridge.json; acceptance: async (M = 2N) ≥ 1.3× sync (M = N) on
the vecenv benchmark.
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np


class JitteredGymEnv:
    """Gymnasium-API env with lognormal step latency — NetHack-shaped."""

    def __init__(self, mean_ms: float = 2.0, sigma: float = 0.6,
                 reset_ms: float = 10.0, horizon: int = 64, seed: int = 0):
        from repro.envs.ocean_host import _gym_box
        self.observation_space = _gym_box(-1.0, 1.0, (8,))
        self.action_space = _gym_box(-1.0, 1.0, (1,))
        self.rng = np.random.RandomState(seed)
        self.mean_ms, self.sigma, self.reset_ms = mean_ms, sigma, reset_ms
        self.horizon = horizon
        self.t = 0

    def reset(self, *, seed=None, options=None):
        time.sleep(self.reset_ms / 1e3)         # slow resets (Crafter-shaped)
        if seed is not None:
            # derive the latency stream from the pool's per-env reset seed:
            # distinct streams per env under BOTH backends (a constructor
            # seed can't vary per worker once factories are pickled)
            self.rng = np.random.RandomState(int(seed) % (2 ** 32))
        self.t = 0
        return np.zeros(8, np.float32), {}

    def step(self, action):
        dt = self.rng.lognormal(np.log(self.mean_ms), self.sigma) / 1e3
        time.sleep(dt)
        self.t += 1
        truncated = self.t >= self.horizon
        info = {"score": 0.5} if truncated else {}
        return (np.full(8, self.t / self.horizon, np.float32), 1.0, False,
                truncated, info)


def run_once(M: int, N: int, steps: int = 200, seed: int = 0,
             policy_latency_ms: float = 1.5,
             backend: str = "thread") -> float:
    """SPS of a recv→policy→send loop over the bridged jittered env.
    Per-env latency streams stay distinct (a shared stream would phase-lock
    the envs and understate the straggler variance the pool exploits): each
    env reseeds from the pool's ``seed + i`` reset seed."""
    from repro.bridge import wrap
    venv = wrap(JitteredGymEnv, num_envs=M,
                batch_size=N, seed=seed, backend=backend)
    try:
        obs, _rew, _done, _info, ids = venv.recv(timeout=60)
        t0 = time.perf_counter()
        for _ in range(steps):
            time.sleep(policy_latency_ms / 1e3)     # device forward stand-in
            venv.send(np.zeros((N, 1), np.float32), ids)
            obs, _rew, _done, _info, ids = venv.recv(timeout=60)
        sps = steps * N / (time.perf_counter() - t0)
    finally:
        venv.close()
    return sps


def engine_once(M_mult: int, updates: int = 12, jitter_ms: float = 2.0,
                seed: int = 0) -> float:
    """Training SPS of the host tier on jittered HostBandit, M = M_mult·N."""
    import itertools
    from repro.bridge import make_host_engine
    from repro.configs.base import TrainConfig
    from repro.envs.ocean_host import HostBandit
    tcfg = TrainConfig(num_envs=16, unroll_length=16, update_epochs=2,
                       num_minibatches=2, learning_rate=1e-3, gamma=0.95,
                       pool_buffers=M_mult)
    counter = itertools.count(seed)             # distinct per-env jitter
    eng = make_host_engine(
        lambda: HostBandit(jitter_ms=jitter_ms, jitter_seed=next(counter)),
        tcfg, hidden=32, kernel_mode="ref", seed=seed)
    try:
        eng.run(2 * eng.steps_per_update)           # warmup: compile act+learn
        t0 = time.perf_counter()
        hist, _ = eng.run(updates * eng.steps_per_update)
        dt = time.perf_counter() - t0
        assert len(hist) == updates
        return updates * eng.steps_per_update / dt
    finally:
        eng.close()


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="fewer timed steps (CI smoke)")
    ap.add_argument("--backend", default="thread",
                    choices=("thread", "proc"),
                    help="HostPool worker backend for the vecenv cells "
                         "(thread-vs-proc head-to-head lives in "
                         "bench_hostpool.py)")
    ap.add_argument("--out", default="BENCH_bridge.json")
    args = ap.parse_args(argv)

    N = 8
    steps = 120 if args.quick else 300
    sync = run_once(M=N, N=N, steps=steps, backend=args.backend)
    async2 = run_once(M=2 * N, N=N, steps=steps, backend=args.backend)
    async4 = run_once(M=4 * N, N=N, steps=steps, backend=args.backend)
    gain2 = async2 / sync
    print(f"bench_bridge/vecenv,{1e6 / async2:.1f},sync_sps={sync:.0f};"
          f"async2_sps={async2:.0f};async4_sps={async4:.0f};"
          f"async2_gain={gain2:.2f}x")

    upd = 8 if args.quick else 16
    engine = {}
    for jitter in ((2.0,) if args.quick else (2.0, 4.0)):
        eng_sync = engine_once(1, updates=upd, jitter_ms=jitter)
        eng_async = engine_once(2, updates=upd, jitter_ms=jitter)
        engine[f"jitter_{jitter:g}ms"] = {
            "sync_sps": round(eng_sync, 1),
            "async_sps": round(eng_async, 1),
            "gain": round(eng_async / eng_sync, 3)}
        print(f"bench_bridge/engine_j{jitter:g},{1e6 / eng_async:.1f},"
              f"sync_sps={eng_sync:.0f};async_sps={eng_async:.0f};"
              f"gain={eng_async / eng_sync:.2f}x")

    out = {
        "meta": {"batch_envs": N, "steps": steps, "engine_updates": upd,
                 "quick": bool(args.quick), "backend": args.backend,
                 "jitter": {"vecenv_mean_ms": 2.0, "vecenv_sigma": 0.6,
                            "policy_latency_ms": 1.5}},
        "vecenv": {"sync_sps": round(sync, 1),
                   "async2_sps": round(async2, 1),
                   "async4_sps": round(async4, 1),
                   "async2_gain": round(gain2, 3)},
        "engine": engine,
        "acceptance": {"async2_ge_1p3x_sync": gain2 >= 1.3},
    }
    with open(args.out, "w") as f:
        json.dump(out, f, indent=2)
    print(f"wrote {args.out}")
    from repro.telemetry import benchwatch
    bw_cells = {"vecenv_sync_sps": round(sync, 1),
                "vecenv_async2_sps": round(async2, 1),
                "vecenv_async4_sps": round(async4, 1)}
    for jkey, cell in engine.items():
        bw_cells[f"engine_{jkey}_sync_sps"] = cell["sync_sps"]
        bw_cells[f"engine_{jkey}_async_sps"] = cell["async_sps"]
    benchwatch.record("bridge", bw_cells,
                      acceptance={"async2_ge_1p3x_sync": gain2 >= 1.3},
                      meta={"quick": bool(args.quick), "steps": steps})


if __name__ == "__main__":
    main()
