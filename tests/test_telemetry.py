"""Telemetry subsystem: spans (nesting, bounded ring, the disabled no-op
fast path, Chrome export, flush + CLI summarize), the metrics registry
(labels, histograms, Prometheus exposition, MetricsLogger feed), the
cross-process stat slabs (proc host pool + async actors, survivor
consistency after a kill), the shared per-tier TierTimer keys, and the
MetricsLogger hardening satellites (NaN scrubbing, idempotent close)."""
import json
import math
import os
import threading
import time
import tracemalloc

import numpy as np
import pytest

import jax

from repro import telemetry
from repro.telemetry import __main__ as tcli
from repro.telemetry import spans as tspans
from repro.telemetry.procstats import (ACTOR_FIELDS, STALENESS_EDGES,
                                       StatSlab)
from repro.telemetry.registry import registry
from repro.telemetry.timers import TierTimer
from repro.utils import metrics as ml

RECV_T = 30.0


@pytest.fixture(autouse=True)
def _clean_telemetry():
    """Span tracing and the default registry are process-global switches —
    every test starts and ends with both off/empty."""
    telemetry.disable()
    registry().reset()
    yield
    telemetry.disable()
    registry().reset()


# ---------------------------------------------------------------------------
# spans: disabled fast path

def test_disabled_span_is_shared_singleton():
    assert not telemetry.enabled()
    s1, s2 = telemetry.span("a"), telemetry.span("b")
    assert s1 is s2                       # one module-level no-op object
    with s1:
        pass                              # usable as a context manager
    assert telemetry.flush() == 0


def test_disabled_span_allocates_nothing():
    for _ in range(10):                   # warm up interpreter caches
        with telemetry.span("warm"):
            pass
    tracemalloc.start()
    try:
        before = tracemalloc.take_snapshot()
        for _ in range(1000):
            with telemetry.span("hot"):
                pass
        after = tracemalloc.take_snapshot()
    finally:
        tracemalloc.stop()
    filt = (tracemalloc.Filter(True, tspans.__file__),)
    stats = after.filter_traces(filt).compare_to(
        before.filter_traces(filt), "filename")
    assert sum(s.size_diff for s in stats) == 0


def test_disabled_span_tight_loop_bound():
    n = 200_000
    t0 = time.perf_counter()
    for _ in range(n):
        with telemetry.span("x"):
            pass
    dt = time.perf_counter() - t0
    # generous CI-safe bound: < 5 µs/span (measured ~0.1 µs); a regression
    # that makes the disabled path allocate or lock blows way past this
    assert dt < n * 5e-6, f"{dt / n * 1e9:.0f} ns per disabled span"


# ---------------------------------------------------------------------------
# spans: enabled path

def test_span_nesting_records_depth_and_parent():
    telemetry.enable()
    with telemetry.span("outer"):
        with telemetry.span("inner"):
            pass
    recs = {r.name: r for r in telemetry.get_tracer().records()}
    assert recs["inner"].depth == 1 and recs["inner"].parent == "outer"
    assert recs["outer"].depth == 0 and recs["outer"].parent == ""
    assert recs["outer"].dur_ns >= recs["inner"].dur_ns >= 0


def test_span_ring_is_bounded():
    telemetry.enable(capacity=16)
    for i in range(100):
        with telemetry.span(f"s{i}"):
            pass
    recs = telemetry.get_tracer().records()
    assert len(recs) == 16
    assert recs[-1].name == "s99"         # newest survive


def test_span_ring_is_thread_safe():
    telemetry.enable(capacity=100_000)

    def burn():
        for _ in range(500):
            with telemetry.span("t"):
                pass

    threads = [threading.Thread(target=burn) for _ in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=30)
    assert len(telemetry.get_tracer().drain()) == 8 * 500


def test_reenable_same_args_keeps_tracer():
    t1 = telemetry.enable()
    with telemetry.span("kept"):
        pass
    t2 = telemetry.enable()
    assert t1 is t2 and len(t2.records()) == 1


def test_chrome_trace_structure():
    telemetry.enable()
    with telemetry.span("a"):
        with telemetry.span("b"):
            pass
    trace = tspans.chrome_trace(telemetry.get_tracer().records())
    evs = trace["traceEvents"]
    assert len(evs) == 2
    for ev in evs:
        assert ev["ph"] == "X"
        assert ev["name"] in ("a", "b")
        assert ev["dur"] >= 0 and "ts" in ev and "pid" in ev and "tid" in ev


def test_summarize_records_percentiles():
    mk = lambda name, dur: {"name": name, "dur_ns": dur}  # noqa: E731
    recs = [mk("op", int(d * 1e6)) for d in range(1, 101)]  # 1..100 ms
    s = tspans.summarize_records(recs)["op"]
    assert s["count"] == 100
    assert 50.0 <= s["p50_ms"] <= 52.0
    assert 99.0 <= s["p99_ms"] <= 100.0
    assert s["max_ms"] == 100.0


# ---------------------------------------------------------------------------
# spans: flush + CLI

def test_flush_and_cli_summarize(tmp_path):
    run_dir = str(tmp_path)
    telemetry.enable(run_dir=run_dir)
    for _ in range(5):
        with telemetry.span("engine.launch"):
            pass
    with telemetry.span("engine.fetch"):
        pass
    assert telemetry.flush() == 6
    assert telemetry.flush() == 0         # drained; second flush is empty
    assert os.path.exists(os.path.join(run_dir, tspans.SPANS_FILE))

    data = tcli.summarize(run_dir, out=open(os.devnull, "w"))
    assert data["n_span_records"] == 6
    assert set(data["spans"]) == {"engine.launch", "engine.fetch"}
    assert data["spans"]["engine.launch"]["count"] == 5

    out = str(tmp_path / "trace.json")
    assert tcli.export_trace(run_dir, out) == 6
    with open(out) as f:
        events = json.load(f)["traceEvents"]
    # 6 duration events + one process_name lane-metadata event per process
    assert sum(1 for e in events if e.get("ph") == "X") == 6
    assert sum(1 for e in events if e.get("ph") == "M") == 1

    assert tcli.main(["summarize", run_dir]) == 0
    assert tcli.main(["summarize", str(tmp_path / "nope")]) == 2


def test_cli_summarize_reads_sps_curve(tmp_path):
    run_dir = str(tmp_path)
    telemetry.enable(run_dir=run_dir)
    with telemetry.span("s"):
        pass
    telemetry.flush()
    with ml.MetricsLogger(run_dir, "run") as logger:
        for i in range(4):
            logger.log((i + 1) * 64, {"env_steps": (i + 1) * 64,
                                      "sps": 1000.0 + i})
    data = tcli.summarize(run_dir, out=open(os.devnull, "w"))
    assert data["sps_curve"]["n"] == 4
    assert data["sps_curve"]["last"] == 1003.0


# ---------------------------------------------------------------------------
# registry

def test_registry_counters_gauges_labels():
    reg = registry()
    reg.counter("updates", tier="jit").inc()
    reg.counter("updates", tier="jit").inc(2)
    reg.counter("updates", tier="pool").inc()
    reg.gauge("workers").set(4)
    snap = reg.snapshot()
    assert snap["counters"]["updates{tier=jit}"] == 3.0
    assert snap["counters"]["updates{tier=pool}"] == 1.0
    assert snap["gauges"]["workers"] == 4.0


def test_registry_histogram_quantiles_and_flat():
    reg = registry()
    h = reg.histogram("lat_ms", edges=(1.0, 10.0, 100.0))
    for v in (0.5, 0.7, 5.0, 50.0):
        h.observe(v)
    assert h.count == 4 and h.sum == pytest.approx(56.2)
    assert reg.histogram("lat_ms", edges=(1.0, 10.0, 100.0)) is h
    flat = reg.flat(prefix="telemetry.")
    assert flat["telemetry.lat_ms_count"] == 4
    assert flat["telemetry.lat_ms_p50"] == 1.0   # 2 of 4 in the <=1 bucket
    assert flat["telemetry.lat_ms_p99"] == 100.0


def test_registry_prometheus_cumulative_buckets():
    reg = registry()
    h = reg.histogram("wait", edges=(1.0, 2.0), tier="async")
    for v in (0.5, 1.5, 99.0):
        h.observe(v)
    reg.counter("errs").inc()
    text = reg.to_prometheus()
    assert "# TYPE errs counter" in text
    assert "# TYPE wait histogram" in text
    assert 'wait_bucket{tier="async",le="1.0"} 1' in text
    assert 'wait_bucket{tier="async",le="2.0"} 2' in text
    assert 'wait_bucket{tier="async",le="+Inf"} 3' in text


def test_registry_emit_feeds_metrics_logger(tmp_path):
    reg = registry()
    reg.counter("engine.updates", tier="jit").inc(7)
    with ml.MetricsLogger(str(tmp_path), "run") as logger:
        reg.emit(logger, step=640)
    rows = ml.read(logger.path)
    assert len(rows) == 1
    assert rows[0]["step"] == 640
    assert rows[0]["telemetry.engine.updates{tier=jit}"] == 7.0


# ---------------------------------------------------------------------------
# MetricsLogger hardening satellites

def test_metrics_logger_nan_inf_round_trip(tmp_path):
    with ml.MetricsLogger(str(tmp_path), "run") as logger:
        logger.log(1, {"loss": float("nan"), "kl": float("inf"),
                       "score": 0.5})
    raw = open(logger.path).read()
    assert "NaN" not in raw and "Infinity" not in raw  # strict-parser-safe
    rows = ml.read(logger.path)
    assert rows[0]["loss"] is None and rows[0]["kl"] is None
    assert rows[0]["score"] == 0.5


def test_metrics_logger_close_idempotent(tmp_path):
    logger = ml.MetricsLogger(str(tmp_path), "run")
    logger.log(1, {"a": 1.0}, flush=False)
    logger.close()
    logger.close()                        # second close is a no-op
    logger.log(2, {"a": 2.0})             # post-close log is a silent no-op
    logger.flush()
    rows = ml.read(logger.path)
    assert len(rows) == 1 and rows[0]["a"] == 1.0


def test_metrics_logger_context_manager_flushes_on_error(tmp_path):
    with pytest.raises(RuntimeError):
        with ml.MetricsLogger(str(tmp_path), "run") as logger:
            logger.log(1, {"a": 1.0}, flush=False)
            raise RuntimeError("interrupted")
    rows = ml.read(logger.path)
    assert rows and rows[-1]["a"] == 1.0  # final record survived the crash


# ---------------------------------------------------------------------------
# TierTimer: the one shared sps/launch_ms/fetch_ms implementation

def test_tier_timer_stamps_unified_keys():
    timer = TierTimer(64)
    with timer.launch():
        pass
    with timer.fetch():
        pass
    md = {}
    timer.stamp(md, 3 * 64)
    assert md["env_steps"] == 192
    assert md["sps"] > 0
    assert md["launch_ms"] >= 0 and md["fetch_ms"] >= 0


def test_tier_timer_resume_aware_sps():
    timer = TierTimer(64, done_before_steps=10 * 64)
    time.sleep(0.01)
    # resumed run: only the 2 new updates count toward this run's rate
    assert timer.sps(12 * 64) == pytest.approx(
        2 * 64 / timer.elapsed(), rel=0.5)


def test_tier_timer_opens_spans_when_enabled():
    telemetry.enable()
    timer = TierTimer(64)
    with timer.launch():
        pass
    with timer.fetch():
        pass
    names = [r.name for r in telemetry.get_tracer().records()]
    assert names == ["engine.launch", "engine.fetch"]


@pytest.mark.timeout(300)
def test_jit_and_pool_tiers_emit_same_telemetry_keys():
    """Satellite: all tiers report the same unified keys (here the two
    cheapest tiers; the async acceptance test covers the fifth)."""
    from repro.configs.base import TrainConfig
    from repro.core.emulation import Emulated
    from repro.envs.ocean import Bandit
    from repro.models.policy import OceanPolicy
    from repro.rl.distributions import Dist
    from repro.rl.engine import TrainEngine

    tcfg = TrainConfig(num_envs=16, unroll_length=16, update_epochs=1,
                       num_minibatches=2, learning_rate=1e-3, gamma=0.95)
    keys = ("env_steps", "sps", "launch_ms", "fetch_ms")
    for backend in ("jit", "pool"):
        em = Emulated(Bandit())
        dist = Dist("categorical", nvec=em.act_spec.nvec)
        pol = OceanPolicy(em.obs_spec.total, dist.nvec, hidden=32,
                          num_outputs=dist.num_outputs)
        eng = TrainEngine(em, pol, tcfg, dist, key=jax.random.PRNGKey(0),
                          backend=backend, kernel_mode="ref")
        hist, _ = eng.run(2 * eng.steps_per_update)
        assert len(hist) == 2
        for m in hist:
            for k in keys:
                assert k in m, (backend, k)
                assert math.isfinite(m[k]), (backend, k)


# ---------------------------------------------------------------------------
# stat slabs

def test_stat_slab_create_attach_aggregate():
    owner = StatSlab.create(2, ACTOR_FIELDS, STALENESS_EDGES)
    try:
        worker = StatSlab.attach(owner.spec)  # what a spawn worker does
        row = worker.row(1)
        row.add("steps", 64)
        row.add("fragments")
        row.set("errors", 0)
        for v in (0.0, 3.0, 99.0):
            row.observe(v)
        del row                            # drop views before close
        worker.close()

        agg = owner.aggregate()
        assert agg["rows"] == 2
        assert agg["total"]["steps"] == 64
        assert agg["per_worker"]["steps"] == [0, 64]
        assert agg["per_worker"]["fragments"] == [0, 1]
        assert agg["hist"]["edges"] == list(STALENESS_EDGES)
        # 0.0 <= edge 0; 3.0 <= 4; 99 overflows
        assert agg["hist"]["counts"] == [1, 0, 0, 1, 0, 1]
    finally:
        owner.close()


@pytest.mark.timeout(300)
def test_proc_host_pool_stats_aggregate():
    from repro.bridge import wrap
    from repro.envs.ocean_host import HostBandit
    v = wrap(HostBandit, num_envs=2, backend="proc")
    try:
        obs = v.reset(timeout=RECV_T)
        for _ in range(3):
            obs, _rew, _done, _info = v.step(
                np.zeros((len(obs), 1), np.int32), timeout=RECV_T)
        st = v.pool.stats()
        assert st["backend"] == "proc" and st["workers"] == 2
        assert st["steps"] >= 3           # parent-side recv accounting
        det = st["workers_detail"]        # worker-side slab accounting
        assert det["rows"] == 2
        assert det["total"]["steps"] + det["total"]["resets"] >= 5
        assert det["total"]["errors"] == 0
        assert det["total"]["wait_ns"] > 0
    finally:
        v.close()


def _async_engine(tmpdir=None, **overrides):
    from repro.configs.ocean import ocean_tcfg
    from repro.envs.ocean import Bandit
    from repro.rl.engine import TrainEngine
    from repro.rl.trainer import ocean_policy_stack
    em, dist, policy = ocean_policy_stack(Bandit(), hidden=32,
                                          recurrent=False, conv=None)
    kw = dict(num_envs=8, unroll_length=8, num_actors=2, checkpoint_every=0)
    kw.update(overrides)
    tcfg = ocean_tcfg("bandit", **kw)
    return TrainEngine(em, policy, tcfg, dist, key=jax.random.PRNGKey(0),
                       backend="async",
                       checkpoint_dir=str(tmpdir) if tmpdir else None)


@pytest.mark.timeout(300)
def test_actor_stat_slab_survives_killed_actor():
    """Satellite: after a mid-run actor kill + reshard the slab stays
    consistent — the survivor's row keeps counting, the dead actor's row
    stays readable (frozen), aggregation never blocks or pickles."""
    eng = _async_engine()
    spu = 8 * 8
    killed = {"done": False}

    def on_update(u, md):
        if u >= 1 and not killed["done"]:
            eng.rollouts._procs[1].terminate()
            killed["done"] = True

    try:
        hist, _ = eng.run(total_steps=spu * 6, on_update=on_update)
        assert len(hist) == 6
        st = eng.rollouts.stats()
        assert st["dead"] == [1]
        agg = st["actors"]
        assert agg["rows"] == 2
        per = agg["per_worker"]
        assert per["fragments"][0] > 0            # survivor kept committing
        assert per["fragments"][1] >= 0           # dead row readable, frozen
        assert agg["total"]["steps"] >= 6 * spu   # every update's data came
        assert sum(agg["hist"]["counts"]) == agg["total"]["fragments"]
    finally:
        eng.close()


# ---------------------------------------------------------------------------
# acceptance: a real async run, summarized

@pytest.mark.timeout(600)
def test_async_run_summarize_reports_span_breadth(tmp_path):
    """Acceptance: `python -m repro.telemetry summarize <run_dir>` on a real
    --engine-backend async run reports p50/p99 for >= 8 distinct spans."""
    run_dir = str(tmp_path / "run")
    ckpt_dir = str(tmp_path / "ckpt")
    telemetry.enable(run_dir=run_dir)
    spu = 8 * 8
    eng = _async_engine(ckpt_dir, checkpoint_every=2)
    logger = ml.MetricsLogger(run_dir, "bandit")
    try:
        hist, _ = eng.run(total_steps=spu * 4, logger=logger)
        assert len(hist) == 4
    finally:
        eng.close()
        logger.close()
    time.sleep(0.5)                       # async ckpt write thread lands
    telemetry.flush()

    data = tcli.summarize(run_dir, out=open(os.devnull, "w"))
    names = set(data["spans"])
    assert len(names) >= 8, sorted(names)
    expect = {"engine.run", "engine.launch", "engine.fetch",
              "engine.collect", "engine.stack_fragments",
              "async.wait_fragments", "async.publish", "ckpt.snapshot"}
    assert expect <= names, sorted(expect - names)
    for s in data["spans"].values():
        assert s["p99_ms"] >= s["p50_ms"] >= 0
    assert data["sps_curve"]["n"] == 4    # logger stream fed the curve
    # registry summary record landed in the same stream (telemetry enabled)
    recs = tcli.load_metrics(run_dir)
    assert any(k.startswith("telemetry.engine.updates")
               for r in recs for k in r)
