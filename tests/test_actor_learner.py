"""Async actor–learner tier (distributed/actor_learner.py): slab layout,
seqlock param broadcast, fragment stacking, staleness policy, V-trace, and
the process-level fault paths (dead-actor reshard, kill-then-resume)."""
import threading
import time
from types import SimpleNamespace

import jax
import numpy as np
import pytest

from repro.configs.base import TrainConfig
from repro.core import shm
from repro.distributed.actor_learner import (
    AsyncLayout, FragSpec, Fragment, SLOT_EMPTY, SLOT_FULL,
    make_param_specs, read_params_seqlock, stack_fragments)


def _spec(**kw):
    leaves = [np.zeros((3, 5), np.float32), np.zeros((7,), np.float32)]
    pspecs, pbytes = make_param_specs(leaves)
    base = dict(num_actors=2, num_shards=2, slots=2, unroll=4,
                envs_per_shard=3, num_agents=1, obs_dim=6, act_dim=1,
                act_dtype="int32", param_specs=pspecs, param_bytes=pbytes)
    base.update(kw)
    return FragSpec(**base)


# ------------------------------ unit layer -----------------------------------

def test_param_specs_aligned_and_disjoint():
    leaves = [np.zeros((3,), np.float32), np.zeros((2, 2), np.float64),
              np.zeros((5,), np.int8), np.zeros((), np.float32)]
    specs, total = make_param_specs(leaves)
    prev_end = 0
    for (shape, dtype, off), leaf in zip(specs, leaves):
        assert off % 8 == 0                      # frombuffer-legal for any dtype
        assert off >= prev_end                   # no overlap
        assert shape == leaf.shape and dtype == str(leaf.dtype)
        prev_end = off + leaf.nbytes
    assert total == prev_end


def test_async_layout_sections_disjoint_and_viewable():
    spec = _spec()
    lay = AsyncLayout(spec)
    spans = sorted((start, start + np.dtype(dt).itemsize *
                    int(np.prod(shape, dtype=np.int64)), name)
                   for name, (start, shape, dt) in lay.sections.items())
    for (_, e0, n0), (s1, _, n1) in zip(spans, spans[1:]):
        assert e0 <= s1, (n0, n1)
    buf = bytearray(lay.nbytes)
    v = lay.views(buf)
    assert v["obs"].shape == (2, 2, 4, 3, 6)
    assert v["fctrl"].shape == (2, 2)
    v["obs"][1, 1, 3, 2, 5] = 7.0               # writes land in the buffer
    assert lay.views(buf)["obs"][1, 1, 3, 2, 5] == 7.0
    pv = lay.param_views(buf)
    assert [p.shape for p in pv] == [(3, 5), (7,)]


def test_seqlock_publish_read_roundtrip():
    spec = _spec()
    lay = AsyncLayout(spec)
    buf = bytearray(lay.nbytes)
    v, pviews = lay.views(buf), lay.param_views(buf)
    w = np.arange(15, dtype=np.float32).reshape(3, 5)
    b = np.arange(7, dtype=np.float32)
    v["pseq"][0] += 1
    pviews[0][:] = w
    pviews[1][:] = b
    v["pver"][0] = 3
    v["pseq"][0] += 1
    leaves, ver = read_params_seqlock(v, pviews, shm.SpinConfig())
    assert ver == 3
    np.testing.assert_array_equal(leaves[0], w)
    np.testing.assert_array_equal(leaves[1], b)


def test_seqlock_torn_read_retries_until_commit():
    """A reader that arrives mid-write (odd counter) must spin until the
    write commits and then see the *new* leaves, never a torn mix."""
    spec = _spec()
    lay = AsyncLayout(spec)
    buf = bytearray(lay.nbytes)
    v, pviews = lay.views(buf), lay.param_views(buf)
    v["pseq"][0] = 1                             # writer mid-flight
    pviews[0][:] = 1.0

    def finish_write():
        time.sleep(0.05)
        pviews[0][:] = 2.0
        pviews[1][:] = 2.0
        v["pver"][0] = 9
        v["pseq"][0] = 2                         # commit

    t = threading.Thread(target=finish_write)
    t.start()
    leaves, ver = read_params_seqlock(v, pviews, shm.SpinConfig())
    t.join()
    assert ver == 9
    assert np.all(leaves[0] == 2.0) and np.all(leaves[1] == 2.0)


def _frag(shard, version, seq, fill, T=3, R=2, obs_dim=4):
    a = lambda *s: np.full(s, fill, np.float32)
    return Fragment(
        shard=shard, actor=0, version=version, seq=seq,
        obs=a(T, R, obs_dim), actions=np.full((T, R, 1), fill, np.int32),
        logprobs=a(T, R), values=a(T, R), rewards=a(T, R),
        dones=np.zeros((T, R), bool), resets=np.zeros((T, R), bool),
        infos={"score": a(T, R), "episode_return": a(T, R),
               "episode_length": np.full((T, R), fill, np.int32),
               "valid": np.zeros((T, R), bool)},
        boot=a(R))


def test_stack_fragments_batches_along_rows():
    traj, last = stack_fragments([_frag(0, 0, 0, 1.0), _frag(1, 0, 0, 2.0)])
    assert traj.obs.shape == (3, 4, 4)           # (T, 2 frags × R, obs_dim)
    assert np.all(traj.obs[:, :2] == 1.0) and np.all(traj.obs[:, 2:] == 2.0)
    assert traj.actions.shape == (3, 4, 1)
    assert traj.infos["score"].shape == (3, 4)
    np.testing.assert_array_equal(last, [1.0, 1.0, 2.0, 2.0])


def test_staleness_drop_filter():
    """Drop mode discards fragments older than max_staleness learner
    versions and keeps pulling until the batch is full."""
    from repro.rl.engine import TrainEngine
    frags = [SimpleNamespace(version=v) for v in (2, 5, 3, 4)]

    class FakeRollouts:
        def wait_fragments(self, n, *, timeout):
            assert timeout > 0
            return [frags.pop(0) for _ in range(min(n, len(frags)))]

    fake = SimpleNamespace(
        tcfg=TrainConfig(max_staleness=1, staleness_mode="drop"),
        rollouts=FakeRollouts(), _version=5, _dropped=0)
    out = TrainEngine._collect_fragments(fake, 2)
    assert [f.version for f in out] == [5, 4]    # ages 0 and 1 survive
    assert fake._dropped == 2                    # ages 3 and 2 dropped


def test_vtrace_adv_matches_numpy_reference():
    from repro.core.emulation import Emulated
    from repro.envs.ocean import Bandit
    from repro.models.policy import OceanPolicy
    from repro.rl.distributions import Dist
    from repro.rl.learner import make_vtrace_adv
    from repro.rl.rollout import Trajectory

    em = Emulated(Bandit())
    dist = Dist("categorical", nvec=em.act_spec.nvec)
    pol = OceanPolicy(em.obs_spec.total, dist.nvec, hidden=16,
                      num_outputs=dist.num_outputs)
    params = pol.init(jax.random.PRNGKey(0))
    tcfg = TrainConfig(gamma=0.9)
    T, B = 5, 4
    rng = np.random.default_rng(0)
    obs = rng.normal(size=(T, B, em.obs_spec.total)).astype(np.float32)
    actions = rng.integers(0, int(em.act_spec.nvec[0]),
                           size=(T, B, 1)).astype(np.int32)
    behavior_logp = rng.normal(scale=0.3, size=(T, B)).astype(np.float32)
    rewards = rng.normal(size=(T, B)).astype(np.float32)
    dones = (rng.random((T, B)) < 0.2)
    traj = Trajectory(obs=obs, actions=actions, logprobs=behavior_logp,
                      values=np.zeros((T, B), np.float32), rewards=rewards,
                      dones=dones, resets=np.zeros((T, B), bool), infos={})
    last_value = rng.normal(size=(B,)).astype(np.float32)

    rho_bar, c_bar = 1.0, 1.0
    adv, vs = make_vtrace_adv(pol, dist, tcfg, rho_bar, c_bar)(
        params, traj, last_value)

    # numpy reference: same forward pass, explicit reverse recursion
    logits, values, _ = pol.seq(params, traj.obs, None, traj.resets)
    newlogp = np.asarray(dist.log_prob(logits, traj.actions))
    values = np.asarray(values)
    rho = np.exp(newlogp - behavior_logp)
    rho_c, c = np.minimum(rho, rho_bar), np.minimum(rho, c_bar)
    nd = 1.0 - dones.astype(np.float32)
    v_next = np.concatenate([values[1:], last_value[None]], axis=0)
    delta = rho_c * (rewards + tcfg.gamma * v_next * nd - values)
    vs_ref = np.zeros_like(values)
    acc = np.zeros((B,), np.float32)
    for t in reversed(range(T)):
        acc = delta[t] + tcfg.gamma * nd[t] * c[t] * acc
        vs_ref[t] = acc
    vs_ref = values + vs_ref
    vs_next = np.concatenate([vs_ref[1:], last_value[None]], axis=0)
    adv_ref = rho_c * (rewards + tcfg.gamma * vs_next * nd - values)
    np.testing.assert_allclose(np.asarray(vs), vs_ref, atol=1e-5)
    np.testing.assert_allclose(np.asarray(adv), adv_ref, atol=1e-5)

    # on-policy fragments (behavior == current) give rho = c = 1 exactly
    traj1 = traj._replace(logprobs=newlogp)
    adv1, vs1 = make_vtrace_adv(pol, dist, tcfg)(params, traj1, last_value)
    assert np.all(np.isfinite(np.asarray(adv1)))


# --------------------------- integration layer -------------------------------

def _async_engine(tmpdir=None, **overrides):
    from repro.configs.ocean import ocean_tcfg
    from repro.envs.ocean import Bandit
    from repro.rl.engine import TrainEngine
    from repro.rl.trainer import ocean_policy_stack
    em, dist, policy = ocean_policy_stack(Bandit(), hidden=32,
                                          recurrent=False, conv=None)
    kw = dict(num_envs=8, unroll_length=8, num_actors=2, checkpoint_every=0)
    kw.update(overrides)
    tcfg = ocean_tcfg("bandit", **kw)
    return TrainEngine(em, policy, tcfg, dist, key=jax.random.PRNGKey(0),
                       backend="async",
                       checkpoint_dir=str(tmpdir) if tmpdir else None)


def test_async_config_validation():
    from repro.envs.ocean import Bandit
    with pytest.raises(ValueError):               # 8 envs % 3 shards != 0
        _async_engine(num_actors=3)
    with pytest.raises(ValueError):
        _async_engine(staleness_mode="nope")
    from repro.rl.trainer import ocean_policy_stack
    em, dist, policy = ocean_policy_stack(Bandit(), hidden=32,
                                          recurrent=True, conv=None)
    from repro.configs.ocean import ocean_tcfg
    from repro.rl.engine import TrainEngine
    with pytest.raises(ValueError):               # no recurrent carries in slab
        TrainEngine(em, policy, ocean_tcfg("bandit", num_envs=8,
                                           unroll_length=8),
                    dist, key=jax.random.PRNGKey(0), backend="async",
                    checkpoint_dir=None)


@pytest.mark.timeout(300)
def test_async_tier_runs_and_accounts():
    eng = _async_engine()
    spu = 8 * 8
    try:
        hist, solved = eng.run(total_steps=spu * 4)
        assert len(hist) == 4
        assert hist[-1]["env_steps"] == 4 * spu
        for k in ("frag_age_mean", "frag_age_max", "dropped_fragments",
                  "stragglers", "actors_alive", "reshards", "sps"):
            assert k in hist[-1], k
        assert hist[-1]["actors_alive"] == 2
        assert hist[-1]["reshards"] == 0
    finally:
        eng.close()


@pytest.mark.timeout(300)
def test_async_kill_actor_reshards_without_hang():
    """Acceptance: killing one actor mid-run reassigns its shards to the
    survivor and the run completes (bounded by the pytest timeout)."""
    eng = _async_engine()
    spu = 8 * 8
    killed = {"done": False}

    def on_update(u, md):
        if u >= 1 and not killed["done"]:
            eng.rollouts._procs[1].terminate()
            killed["done"] = True

    try:
        hist, _ = eng.run(total_steps=spu * 6, on_update=on_update)
        assert len(hist) == 6                    # no updates lost
        assert len(eng.rollouts.events) == 1
        ev = eng.rollouts.events[0]
        assert ev.actor == 1 and ev.new_owners == (0,)
        st = eng.rollouts.stats()
        assert st["assign"] == [0, 0] and st["dead"] == [1]
        assert st["epoch"][ev.shards[0]] == 1    # new owner re-seeds
        assert hist[-1]["actors_alive"] == 1
    finally:
        eng.close()


@pytest.mark.timeout(600)
def test_async_kill_then_resume_step_count(tmp_path):
    """Acceptance: a learner killed mid-run resumes from its checkpoint and
    ends at the same step count as an uninterrupted run."""
    from repro.checkpoint import ckpt

    spu = 8 * 8
    eng = _async_engine(tmp_path, checkpoint_every=2)

    class Kill(BaseException):                   # not caught by ResilientLoop
        pass

    def on_update(u, md):
        if u >= 2:                               # updates 1..3 done, ckpt at 2
            raise Kill

    try:
        with pytest.raises(Kill):
            eng.run(total_steps=spu * 6, on_update=on_update)
    finally:
        eng.close()
    time.sleep(0.5)                              # async ckpt thread lands
    assert ckpt.step_of(ckpt.latest(str(tmp_path))) == 2

    eng2 = _async_engine(tmp_path, checkpoint_every=2)
    try:
        assert eng2.restore() == 2
        hist, _ = eng2.run(total_steps=spu * 6)
    finally:
        eng2.close()
    assert len(hist) == 4                        # updates 3..6 only
    assert hist[-1]["env_steps"] == 6 * spu
    assert ckpt.step_of(ckpt.latest(str(tmp_path))) == 6


@pytest.mark.slow
@pytest.mark.timeout(1800)
def test_async_tier_trains_bandit_two_actors():
    """Acceptance: the async tier actually trains — bandit to >= 0.9 with 2
    actors under the committed preset budget."""
    from repro.configs.ocean import preset
    p = preset("bandit")
    eng = _async_engine(num_envs=64, unroll_length=64, num_actors=2)
    try:
        hist, solved = eng.run(total_steps=p.total_steps, target_score=0.9)
    finally:
        eng.close()
    best = max(m["score"] for m in hist if m["episodes"] > 0)
    assert solved is not None or best >= 0.9, (
        f"async tier failed to train bandit: best score {best:.3f} over "
        f"{len(hist)} updates")
