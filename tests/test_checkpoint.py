"""Checkpoint: roundtrip, atomic commit, gc, async, resume, resharding."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import ckpt


def _tree():
    return {"params": {"w": jnp.arange(24.0).reshape(4, 6),
                       "b": jnp.ones((6,), jnp.int32)},
            "step": jnp.asarray(7, jnp.int32)}


def test_roundtrip(tmp_path):
    t = _tree()
    ckpt.save(str(tmp_path), t, step=3)
    like = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), t)
    r = ckpt.restore(str(tmp_path), like)
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(r)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_latest_and_gc(tmp_path):
    t = _tree()
    for s in range(6):
        ckpt.save(str(tmp_path), t, step=s, keep=2)
    assert ckpt.latest(str(tmp_path)).endswith("step_5")
    kept = sorted(os.listdir(tmp_path))
    assert kept == ["step_4", "step_5"]


def test_async_save(tmp_path):
    h = ckpt.save(str(tmp_path), _tree(), step=1, async_=True)
    h.join()
    assert ckpt.latest(str(tmp_path)).endswith("step_1")


def test_no_partial_commit(tmp_path):
    """A .tmp dir is never picked up as a checkpoint."""
    os.makedirs(tmp_path / "step_9.tmp")
    assert ckpt.latest(str(tmp_path)) is None


def test_resilient_loop_recovers(tmp_path):
    """Inject a step failure; the loop restores and replays."""
    from repro.distributed.fault import ResilientLoop
    calls = {"n": 0}

    def step(state, batch):
        calls["n"] += 1
        if calls["n"] == 3:            # fail once, mid-run
            raise RuntimeError("injected device failure")
        return {"x": state["x"] + batch}, {"loss": state["x"]}

    loop = ResilientLoop(step, str(tmp_path), save_every=1, async_save=False)
    state = {"x": jnp.zeros(())}
    out = loop.run(state, [jnp.ones(())] * 4)
    assert loop.recoveries == 1
    assert float(out["x"]) == 4.0      # all 4 batches applied exactly once
    assert loop.steps_done == 4


def test_resilient_loop_rewinds_past_checkpoint_gap(tmp_path):
    """Regression for the recovery desync: with save_every > 1, a failure k
    steps past the last checkpoint must restore AND rewind — replaying
    batches S..S+k on the restored lineage — not resume the *restored*
    state at the *pre-failure* step count (which silently dropped the k
    replayed batches' worth of progress)."""
    from repro.distributed.fault import ResilientLoop
    calls = {"n": 0}
    applied = []

    def step(state, batch):
        calls["n"] += 1
        if calls["n"] == 6:            # step 6 = 2 past the step-4 checkpoint
            raise RuntimeError("injected failure at S+2")
        return {"x": state["x"] + batch}, {"x_after": float(state["x"]) + 1}

    def on_metrics(step_no, m):
        applied.append((step_no, m["x_after"]))

    loop = ResilientLoop(step, str(tmp_path), save_every=2, async_save=False)
    out = loop.run({"x": jnp.zeros(())}, [jnp.ones(())] * 8,
                   on_metrics=on_metrics)
    assert loop.recoveries == 1
    assert float(out["x"]) == 8.0      # every batch applied exactly once
    assert loop.steps_done == 8
    # steps 5..8 re-fire after the rewind to the step-4 checkpoint, and the
    # state each one observes matches the uninterrupted lineage
    assert applied == [(s, float(s)) for s in
                       [1, 2, 3, 4, 5, 5, 6, 7, 8]]


def test_resilient_loop_replayable_callable_source(tmp_path):
    """callable(start)->iterator sources replay from the restored step."""
    from repro.distributed.fault import ResilientLoop
    calls = {"n": 0}
    starts = []

    def batches(start):
        starts.append(start)
        return (jnp.ones(()) for _ in range(start, 6))

    def step(state, batch):
        calls["n"] += 1
        if calls["n"] == 4:
            raise RuntimeError("injected")
        return {"x": state["x"] + batch}, {}

    loop = ResilientLoop(step, str(tmp_path), save_every=3, async_save=False)
    out = loop.run({"x": jnp.zeros(())}, batches)
    assert float(out["x"]) == 6.0 and loop.steps_done == 6
    assert starts == [0, 3]            # recovery re-invoked it at the ckpt


def test_resilient_loop_live_stream_retries_in_place(tmp_path):
    """A bare iterator cannot rewind: recovery retries the *current* batch
    and only restores a checkpoint sitting exactly at steps_done."""
    from repro.distributed.fault import ResilientLoop
    calls = {"n": 0}

    def step(state, batch):
        calls["n"] += 1
        if calls["n"] == 3:            # fails on stream item 3, ckpt at 2
            raise RuntimeError("injected")
        return {"x": state["x"] + batch}, {}

    loop = ResilientLoop(step, str(tmp_path), save_every=2, async_save=False)
    out = loop.run({"x": jnp.zeros(())}, iter([jnp.ones(())] * 4))
    assert loop.recoveries == 1
    assert float(out["x"]) == 4.0      # no stream item skipped or doubled
    assert loop.steps_done == 4


def test_resilient_loop_poison_pill_aborts(tmp_path):
    from repro.distributed.fault import ResilientLoop

    def step(state, batch):
        raise RuntimeError("always fails")

    loop = ResilientLoop(step, str(tmp_path), save_every=1, max_retries=2,
                         async_save=False)
    with pytest.raises(RuntimeError, match="poison pill"):
        loop.run({"x": jnp.zeros(())}, [jnp.ones(())] * 3)
    assert loop.recoveries == 3        # max_retries failures + the fatal one


def test_resilient_loop_async_save_joins_before_next(tmp_path, monkeypatch):
    """Overlapping async saves serialize: the previous handle joins before
    the next save starts (and the final handle joins before run returns)."""
    from repro.distributed import fault
    log = []

    class Handle:
        def __init__(self, step):
            self.step = step

        def join(self):
            log.append(("join", self.step))

    def fake_save(d, state, step, async_=False, keep=None):
        log.append(("save", step))
        assert async_
        return Handle(step)

    monkeypatch.setattr(fault.ckpt, "save", fake_save)
    loop = fault.ResilientLoop(lambda s, b: (s, {}), str(tmp_path),
                               save_every=1, async_save=True)
    loop.run({"x": jnp.zeros(())}, [jnp.ones(())] * 3)
    assert log == [("save", 1), ("join", 1), ("save", 2), ("join", 2),
                   ("save", 3), ("join", 3)]


def test_resume_from_underscored_and_renamed_dirs(tmp_path):
    """Step parsing comes from checkpoint metadata (index.json), so
    underscored ckpt_dir basenames and manually renamed checkpoint dirs
    resume correctly (path.rsplit('_') misread both)."""
    from repro.distributed.fault import ResilientLoop
    t = _tree()
    d = tmp_path / "run_v2_final"      # underscores in the parent dir name
    ckpt.save(str(d), t, step=12)
    state, step = ResilientLoop(lambda s, b: (s, {}), str(d)).resume_or_init(
        jax.tree.map(jnp.zeros_like, t))
    assert step == 12

    # a committed checkpoint renamed to something that isn't step_N at all
    src = ckpt.latest(str(d))
    dst = tmp_path / "best_model_final"
    os.rename(src, dst)
    state, step = ResilientLoop(lambda s, b: (s, {}),
                                str(dst)).resume_or_init(
        jax.tree.map(jnp.zeros_like, t))
    assert step == 12
    np.testing.assert_array_equal(np.asarray(state["params"]["w"]),
                                  np.asarray(t["params"]["w"]))
    assert ckpt.step_of(str(dst)) == 12


def test_true_median_and_straggler_flagging():
    """Even-length windows use the true median (mean of the two middles);
    the upper-middle shortcut inflated the k x median threshold and
    under-flagged genuinely slow steps."""
    from repro.distributed.fault import StragglerMonitor, _true_median
    assert _true_median([]) == 0.0
    assert _true_median([3.0]) == 3.0
    assert _true_median([1.0, 1.0, 3.0]) == 1.0
    assert _true_median([1.0, 1.0, 3.0, 3.0]) == 2.0

    mon = StragglerMonitor(window=8, k=2.0, min_samples=4)
    for dt in (1.0, 1.0, 3.0):
        assert not mon.record(dt)
    # window [1, 1, 3, 4.2]: true median 2.0 -> threshold 4.0 -> flagged;
    # the upper-middle (3.0 -> threshold 6.0) would have missed it
    assert mon.record(4.2)
    assert mon.flagged == 1
    assert mon.median == pytest.approx(2.0)


def test_straggler_flag_propagates_into_metrics(tmp_path):
    """A slow step's metrics dict gains straggler_flag=True on its way to
    on_metrics (the launcher's re-shard/alert signal)."""
    from repro.distributed.fault import ResilientLoop, StragglerMonitor
    seen = []
    loop = ResilientLoop(lambda s, b: (s, {"loss": 0.0}), None, save_every=0)
    loop.monitor = StragglerMonitor(window=8, k=1e-9, min_samples=1)
    loop.run({"x": jnp.zeros(())}, [jnp.ones(())] * 2,
             on_metrics=lambda u, m: seen.append(m))
    assert all(m.get("straggler_flag") for m in seen[1:])
    assert loop.monitor.flagged >= 1


RESUME_SHARDED_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np, sys
from jax.sharding import PartitionSpec as P, NamedSharding
from repro.checkpoint import ckpt
from repro.distributed.fault import ResilientLoop

d = sys.argv[1]
mesh1 = jax.make_mesh((8,), ("x",))
w = jax.device_put(jnp.arange(64.0).reshape(8, 8),
                   NamedSharding(mesh1, P("x", None)))
ckpt.save(d, {"w": w}, step=5)

# resume onto a DIFFERENT mesh: ResilientLoop(shardings=...) places the
# restored leaves (elastic recovery, 8 -> 2x4)
mesh2 = jax.make_mesh((2, 4), ("a", "b"))
sh = {"w": NamedSharding(mesh2, P(None, "b"))}
loop = ResilientLoop(lambda s, b: (s, {}), d, shardings=sh)
state, step = loop.resume_or_init({"w": jnp.zeros((8, 8))})
assert step == 5, step
np.testing.assert_array_equal(np.asarray(state["w"]),
                              np.arange(64.0).reshape(8, 8))
assert state["w"].sharding.is_equivalent_to(sh["w"], 2), state["w"].sharding
print("RESUME_SHARDED_OK")
"""


def test_resume_or_init_onto_different_shardings(tmp_path):
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run([sys.executable, "-c", RESUME_SHARDED_SCRIPT,
                          str(tmp_path)], capture_output=True, text=True,
                         env=env, cwd=os.path.dirname(os.path.dirname(
                             os.path.abspath(__file__))))
    assert "RESUME_SHARDED_OK" in out.stdout, out.stderr[-2000:]


def test_resume_or_init(tmp_path):
    from repro.distributed.fault import ResilientLoop
    t = _tree()
    ckpt.save(str(tmp_path), t, step=11)
    loop = ResilientLoop(lambda s, b: (s, {}), str(tmp_path))
    state, step = loop.resume_or_init(jax.tree.map(jnp.zeros_like, t))
    assert step == 11
    np.testing.assert_array_equal(np.asarray(state["params"]["w"]),
                                  np.asarray(t["params"]["w"]))


RESHARD_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np, sys
from jax.sharding import PartitionSpec as P, NamedSharding
from repro.checkpoint import ckpt

d = sys.argv[1]
mesh1 = jax.make_mesh((8,), ("x",))
w = jax.device_put(jnp.arange(64.0).reshape(8, 8),
                   NamedSharding(mesh1, P("x", None)))
ckpt.save(d, {"w": w}, step=0)

# restore onto a DIFFERENT mesh (elastic 8 -> 2x4, other axis sharded)
mesh2 = jax.make_mesh((2, 4), ("a", "b"))
sh = {"w": NamedSharding(mesh2, P(None, "b"))}
like = {"w": jax.ShapeDtypeStruct((8, 8), jnp.float32)}
r = ckpt.restore(d, like, sh)
np.testing.assert_array_equal(np.asarray(r["w"]),
                              np.arange(64.0).reshape(8, 8))
print("RESHARD_OK")
"""


def test_reshard_across_meshes(tmp_path):
    """Save sharded on 8 devices, restore onto a 2x4 mesh (elastic)."""
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run([sys.executable, "-c", RESHARD_SCRIPT,
                          str(tmp_path)], capture_output=True, text=True,
                         env=env, cwd=os.path.dirname(os.path.dirname(
                             os.path.abspath(__file__))))
    assert "RESHARD_OK" in out.stdout, out.stderr[-2000:]


def test_bf16_and_custom_dtype_roundtrip(tmp_path):
    """Custom ml_dtypes (bfloat16, int8) survive the .npy storage format
    (numpy round-trips kind-'V' dtypes as raw void without this)."""
    t = {"w": jnp.arange(12, dtype=jnp.bfloat16).reshape(3, 4),
         "q": jnp.arange(-8, 8, dtype=jnp.int8),
         "s": jnp.asarray(3, jnp.int32)}
    ckpt.save(str(tmp_path), t, step=0)
    like = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), t)
    r = ckpt.restore(str(tmp_path), like)
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(r)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
