"""Checkpoint: roundtrip, atomic commit, gc, async, resume, resharding."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import ckpt


def _tree():
    return {"params": {"w": jnp.arange(24.0).reshape(4, 6),
                       "b": jnp.ones((6,), jnp.int32)},
            "step": jnp.asarray(7, jnp.int32)}


def test_roundtrip(tmp_path):
    t = _tree()
    ckpt.save(str(tmp_path), t, step=3)
    like = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), t)
    r = ckpt.restore(str(tmp_path), like)
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(r)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_latest_and_gc(tmp_path):
    t = _tree()
    for s in range(6):
        ckpt.save(str(tmp_path), t, step=s, keep=2)
    assert ckpt.latest(str(tmp_path)).endswith("step_5")
    kept = sorted(os.listdir(tmp_path))
    assert kept == ["step_4", "step_5"]


def test_async_save(tmp_path):
    h = ckpt.save(str(tmp_path), _tree(), step=1, async_=True)
    h.join()
    assert ckpt.latest(str(tmp_path)).endswith("step_1")


def test_no_partial_commit(tmp_path):
    """A .tmp dir is never picked up as a checkpoint."""
    os.makedirs(tmp_path / "step_9.tmp")
    assert ckpt.latest(str(tmp_path)) is None


def test_resilient_loop_recovers(tmp_path):
    """Inject a step failure; the loop restores and replays."""
    from repro.distributed.fault import ResilientLoop
    calls = {"n": 0}

    def step(state, batch):
        calls["n"] += 1
        if calls["n"] == 3:            # fail once, mid-run
            raise RuntimeError("injected device failure")
        return {"x": state["x"] + batch}, {"loss": state["x"]}

    loop = ResilientLoop(step, str(tmp_path), save_every=1, async_save=False)
    state = {"x": jnp.zeros(())}
    out = loop.run(state, [jnp.ones(())] * 4)
    assert loop.recoveries == 1
    assert float(out["x"]) == 4.0      # all 4 batches applied exactly once
    assert loop.steps_done == 4


def test_resume_or_init(tmp_path):
    from repro.distributed.fault import ResilientLoop
    t = _tree()
    ckpt.save(str(tmp_path), t, step=11)
    loop = ResilientLoop(lambda s, b: (s, {}), str(tmp_path))
    state, step = loop.resume_or_init(jax.tree.map(jnp.zeros_like, t))
    assert step == 11
    np.testing.assert_array_equal(np.asarray(state["params"]["w"]),
                                  np.asarray(t["params"]["w"]))


RESHARD_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np, sys
from jax.sharding import PartitionSpec as P, NamedSharding
from repro.checkpoint import ckpt

d = sys.argv[1]
mesh1 = jax.make_mesh((8,), ("x",))
w = jax.device_put(jnp.arange(64.0).reshape(8, 8),
                   NamedSharding(mesh1, P("x", None)))
ckpt.save(d, {"w": w}, step=0)

# restore onto a DIFFERENT mesh (elastic 8 -> 2x4, other axis sharded)
mesh2 = jax.make_mesh((2, 4), ("a", "b"))
sh = {"w": NamedSharding(mesh2, P(None, "b"))}
like = {"w": jax.ShapeDtypeStruct((8, 8), jnp.float32)}
r = ckpt.restore(d, like, sh)
np.testing.assert_array_equal(np.asarray(r["w"]),
                              np.arange(64.0).reshape(8, 8))
print("RESHARD_OK")
"""


def test_reshard_across_meshes(tmp_path):
    """Save sharded on 8 devices, restore onto a 2x4 mesh (elastic)."""
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run([sys.executable, "-c", RESHARD_SCRIPT,
                          str(tmp_path)], capture_output=True, text=True,
                         env=env, cwd=os.path.dirname(os.path.dirname(
                             os.path.abspath(__file__))))
    assert "RESHARD_OK" in out.stdout, out.stderr[-2000:]


def test_bf16_and_custom_dtype_roundtrip(tmp_path):
    """Custom ml_dtypes (bfloat16, int8) survive the .npy storage format
    (numpy round-trips kind-'V' dtypes as raw void without this)."""
    t = {"w": jnp.arange(12, dtype=jnp.bfloat16).reshape(3, 4),
         "q": jnp.arange(-8, 8, dtype=jnp.int8),
         "s": jnp.asarray(3, jnp.int32)}
    ckpt.save(str(tmp_path), t, step=0)
    like = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), t)
    r = ckpt.restore(str(tmp_path), like)
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(r)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
