"""Emulation properties: the paper's 'no loss of generality' claim, as code.

Property tests run everywhere: with ``hypothesis`` installed (the dev/CI
environment) they use real shrinking strategies; without it they fall back to
a seeded random space-tree generator, so this module never skips — the suite
reports 0 skips in either environment.
"""
import importlib.util

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import spaces as sp
from repro.core import emulation as em

HAS_HYPOTHESIS = importlib.util.find_spec("hypothesis") is not None


# -- seeded random space trees (the hypothesis-free generator) -----------------

LEAF_DTYPES = [jnp.float32, jnp.int32, jnp.uint8, jnp.bool_]


def random_obs_leaf(rng: np.random.Generator) -> sp.Space:
    kind = rng.integers(3)
    if kind == 0:
        return sp.Discrete(int(rng.integers(2, 9)))
    if kind == 1:
        return sp.MultiDiscrete(tuple(rng.integers(2, 6, rng.integers(1, 4))))
    shape = tuple(int(s) for s in rng.integers(1, 5, rng.integers(0, 4)))
    return sp.Box(shape, LEAF_DTYPES[rng.integers(len(LEAF_DTYPES))])


def random_space(rng: np.random.Generator, depth: int = 2) -> sp.Space:
    if depth == 0 or rng.random() < 0.4:
        return random_obs_leaf(rng)
    n = int(rng.integers(1, 4))
    if rng.random() < 0.5:
        keys = rng.choice(list("abcdef"), size=n, replace=False)
        return sp.Dict({k: random_space(rng, depth - 1) for k in keys})
    return sp.Tuple([random_space(rng, depth - 1) for _ in range(n)])


def random_discrete_action_space(rng: np.random.Generator) -> sp.Space:
    n = int(rng.integers(1, 4))
    leaves = []
    for _ in range(n):
        if rng.random() < 0.5:
            leaves.append(sp.Discrete(int(rng.integers(2, 7))))
        else:
            leaves.append(sp.MultiDiscrete(
                tuple(rng.integers(2, 5, rng.integers(1, 3)))))
    if n == 1:
        return leaves[0]
    return sp.Dict({k: s for k, s in zip("abcdef", leaves)})


def random_box_action_space(rng: np.random.Generator) -> sp.Space:
    n = int(rng.integers(1, 4))
    leaves = [sp.Box(tuple(int(s) for s in
                           rng.integers(1, 4, rng.integers(1, 3))),
                     low=-1.0, high=1.0) for _ in range(n)]
    if n == 1:
        return leaves[0]
    return sp.Tuple(leaves)


def assert_obs_roundtrip(space: sp.Space, seed: int, mode: str):
    spec = em.flat_spec(space, mode)
    x = sp.sample(space, jax.random.PRNGKey(seed))
    flat = em.emulate(spec, x)
    assert flat.ndim == 1 and flat.shape[0] == spec.total
    assert flat.dtype == spec.dtype
    back = em.unemulate(spec, flat)
    for p, _ in sp.leaves(space):
        a, b = np.asarray(sp.get_path(x, p)), np.asarray(sp.get_path(back, p))
        if mode == "bytes":
            np.testing.assert_array_equal(a, b)     # lossless
        else:
            np.testing.assert_allclose(a.astype(np.float32),
                                       b.astype(np.float32), rtol=1e-6)


def assert_action_roundtrip(space: sp.Space, seed: int):
    spec = em.action_spec(space)
    x = sp.sample(space, jax.random.PRNGKey(seed))
    flat = em.emulate_action(spec, x)
    assert flat.shape == (spec.num_components,)
    back = em.unemulate_action(spec, flat)
    for p, _ in sp.leaves(space):
        np.testing.assert_allclose(np.asarray(sp.get_path(x, p)),
                                   np.asarray(sp.get_path(back, p)))
    # emulate is a left inverse of unemulate too
    np.testing.assert_allclose(np.asarray(em.emulate_action(spec, back)),
                               np.asarray(flat))


# -- the properties, over seeded random trees (always run) ---------------------

@pytest.mark.parametrize("mode", ["f32", "bytes"])
@pytest.mark.parametrize("seed", range(20))
def test_roundtrip_property(seed, mode):
    """emulate∘unemulate == identity for arbitrary nested obs spaces."""
    rng = np.random.default_rng(seed)
    assert_obs_roundtrip(random_space(rng), seed, mode)


@pytest.mark.parametrize("seed", range(15))
def test_action_roundtrip_property(seed):
    """emulate_action∘unemulate_action == identity for random discrete and
    continuous action trees."""
    rng = np.random.default_rng(1000 + seed)
    assert_action_roundtrip(random_discrete_action_space(rng), seed)
    assert_action_roundtrip(random_box_action_space(rng), seed)


@pytest.mark.parametrize("seed", range(10))
def test_batched_roundtrip(seed):
    rng = np.random.default_rng(2000 + seed)
    space = random_space(rng, depth=1)
    spec = em.flat_spec(space, "f32")
    keys = jax.random.split(jax.random.PRNGKey(seed), 5)
    xs = jax.vmap(lambda k: sp.sample(space, k))(keys)
    flat = em.emulate(spec, xs)
    assert flat.shape == (5, spec.total)
    back = em.unemulate(spec, flat)
    for p, _ in sp.leaves(space):
        np.testing.assert_allclose(
            np.asarray(sp.get_path(xs, p), np.float32),
            np.asarray(sp.get_path(back, p), np.float32), rtol=1e-6)


# -- the same properties under hypothesis (dev/CI: shrinking + more cases) -----

if HAS_HYPOTHESIS:
    from hypothesis import given, settings, strategies as st

    leaf_obs = st.one_of(
        st.builds(lambda n: sp.Discrete(n), st.integers(2, 8)),
        st.builds(lambda v: sp.MultiDiscrete(tuple(v)),
                  st.lists(st.integers(2, 5), min_size=1, max_size=3)),
        st.builds(lambda s, d: sp.Box(tuple(s), d),
                  st.lists(st.integers(1, 4), min_size=0, max_size=3),
                  st.sampled_from([jnp.float32, jnp.int32, jnp.uint8,
                                   jnp.bool_])),
    )

    def tree_space(depth):
        if depth == 0:
            return leaf_obs
        sub = tree_space(depth - 1)
        return st.one_of(
            leaf_obs,
            st.builds(lambda d: sp.Dict(d),
                      st.dictionaries(st.text("abcdef", min_size=1,
                                              max_size=3),
                                      sub, min_size=1, max_size=3)),
            st.builds(lambda l: sp.Tuple(l),
                      st.lists(sub, min_size=1, max_size=3)),
        )

    leaf_discrete = st.one_of(
        st.builds(lambda n: sp.Discrete(n), st.integers(2, 8)),
        st.builds(lambda v: sp.MultiDiscrete(tuple(v)),
                  st.lists(st.integers(2, 5), min_size=1, max_size=3)),
    )
    leaf_box = st.builds(
        lambda s: sp.Box(tuple(s), low=-1.0, high=1.0),
        st.lists(st.integers(1, 4), min_size=1, max_size=2))

    def action_tree(leaf):
        return st.one_of(
            leaf,
            st.builds(lambda d: sp.Dict(d),
                      st.dictionaries(st.text("abcdef", min_size=1,
                                              max_size=2),
                                      leaf, min_size=1, max_size=3)),
            st.builds(lambda l: sp.Tuple(l),
                      st.lists(leaf, min_size=1, max_size=3)),
        )

    @settings(max_examples=40, deadline=None)
    @given(space=tree_space(2), seed=st.integers(0, 2**31 - 1),
           mode=st.sampled_from(["f32", "bytes"]))
    def test_roundtrip_hypothesis(space, seed, mode):
        assert_obs_roundtrip(space, seed, mode)

    @settings(max_examples=30, deadline=None)
    @given(space=action_tree(leaf_discrete), seed=st.integers(0, 2**31 - 1))
    def test_discrete_action_roundtrip_hypothesis(space, seed):
        assert_action_roundtrip(space, seed)

    @settings(max_examples=30, deadline=None)
    @given(space=action_tree(leaf_box), seed=st.integers(0, 2**31 - 1))
    def test_continuous_action_roundtrip_hypothesis(space, seed):
        assert_action_roundtrip(space, seed)


# -- fixed-case regression tests ----------------------------------------------

def test_action_emulation_roundtrip():
    space = sp.Dict({"a": sp.Discrete(3),
                     "b": sp.MultiDiscrete((2, 4)),
                     "c": sp.Tuple([sp.Discrete(5)])})
    spec = em.action_spec(space)
    assert spec.nvec == (3, 2, 4, 5)
    x = sp.sample(space, jax.random.PRNGKey(0))
    flat = em.emulate_action(spec, x)
    assert flat.shape == (4,)
    back = em.unemulate_action(spec, flat)
    assert int(back["a"]) == int(x["a"])
    np.testing.assert_array_equal(np.asarray(back["b"]), np.asarray(x["b"]))


def test_canonical_dict_ordering():
    """Dict spaces sort keys — packed layout is order-independent."""
    s1 = sp.Dict({"z": sp.Discrete(2), "a": sp.Box((3,))})
    s2 = sp.Dict({"a": sp.Box((3,)), "z": sp.Discrete(2)})
    assert em.flat_spec(s1, "f32").leaf_specs == em.flat_spec(s2, "f32").leaf_specs


def test_bytes_mode_is_exact_for_floats():
    space = sp.Box((4,), jnp.float32)
    spec = em.flat_spec(space, "bytes")
    x = jnp.asarray([1e-38, -0.0, np.pi, np.inf], jnp.float32)
    back = em.unemulate(spec, em.emulate(spec, x))
    np.testing.assert_array_equal(np.asarray(x), np.asarray(back))


def test_pad_agents():
    obs = jnp.ones((2, 5))
    mask = jnp.ones((2,), bool)
    p, m = em.pad_agents(obs, mask, 4)
    assert p.shape == (4, 5) and not bool(m[2])
    np.testing.assert_array_equal(np.asarray(p[2:]), 0.0)


def test_emulated_env_shapes():
    from repro.envs.ocean import Spaces
    env = em.Emulated(Spaces())
    state = env.init(jax.random.PRNGKey(0))
    state, obs = env.reset(state, jax.random.PRNGKey(1))
    assert obs.shape == (env.obs_spec.total,)
    act = jnp.zeros((2,), jnp.int32)
    state, obs, rew, done, info = env.step(state, act, jax.random.PRNGKey(2))
    tree = env.unemulate_obs(obs)
    assert tree["image"].shape == (3, 3) and tree["flat"].shape == (4,)


def test_continuous_action_emulation():
    """Box action trees emulate to one flat Box (paper §8 extension)."""
    space = sp.Dict({"steer": sp.Box((1,), low=-1, high=1),
                     "pedals": sp.Box((2,), low=0, high=1)})
    spec = em.action_spec(space)
    assert spec.kind == "continuous" and spec.cont_dim == 3
    flat = jnp.asarray([0.5, 0.1, 0.9])
    tree = em.unemulate_action(spec, flat)
    np.testing.assert_allclose(np.asarray(tree["pedals"]), [0.5, 0.1])
    np.testing.assert_allclose(np.asarray(tree["steer"]), [0.9])
    back = em.emulate_action(spec, tree)
    np.testing.assert_allclose(np.asarray(back), np.asarray(flat))


def test_mixed_action_tree_rejected():
    space = sp.Dict({"a": sp.Discrete(2), "b": sp.Box((1,))})
    with pytest.raises(AssertionError):
        em.action_spec(space)
