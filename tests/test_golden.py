"""Golden-rollout regression suite: seeded metric digests per engine backend.

Each (backend, env) cell runs 3 seeded PPO updates and compares every metric
of every update against a committed fixture to 1e-6 — any cross-PR numeric
drift in the rollout, GAE, learner, or engine dispatch order fails loudly
here before it can silently change training behaviour.

Regenerate (after an *intentional* numeric change, with the diff reviewed):

    REPRO_UPDATE_GOLDEN=1 PYTHONPATH=src python -m pytest tests/test_golden.py

The fixtures are generated on 1 device with kernel_mode="ref"; the shard_map
cell pins a 1-device mesh so the digest is identical on multi-device hosts
(cross-device reduction order is covered by the engine parity tests, not
here).
"""
import json
import os

import jax
import numpy as np
import pytest

from repro.configs.base import TrainConfig
from repro.core.emulation import Emulated
from repro.envs.ocean import OCEAN
from repro.models.policy import OceanPolicy
from repro.rl.distributions import Dist
from repro.rl.engine import TrainEngine, METRIC_KEYS

GOLDEN_PATH = os.path.join(os.path.dirname(__file__), "golden",
                           "engine_rollouts.json")
UPDATE = os.environ.get("REPRO_UPDATE_GOLDEN") == "1"

BACKENDS = ("jit", "shard_map", "pool")
ENVS = ("bandit", "squared")
NUM_UPDATES = 3
TOL = 1e-6
# wall-clock metrics can never be golden
DIGEST_KEYS = tuple(k for k in METRIC_KEYS) + ("env_steps",)

TCFG = TrainConfig(num_envs=8, unroll_length=8, update_epochs=2,
                   num_minibatches=2, learning_rate=1e-3, gamma=0.95)


def _run_cell(backend: str, env_name: str):
    env = Emulated(OCEAN[env_name]())
    dist = Dist("categorical", nvec=env.act_spec.nvec)
    pol = OceanPolicy(env.obs_spec.total, dist.nvec, hidden=32,
                      num_outputs=dist.num_outputs)
    mesh = None
    if backend == "shard_map":
        # pin one device: golden digests must not depend on host device count
        from repro.launch.mesh import make_mesh
        mesh = make_mesh((1,), ("data",))
    engine = TrainEngine(env, pol, TCFG, dist, key=jax.random.PRNGKey(0),
                         backend=backend, kernel_mode="ref", mesh=mesh)
    hist, _ = engine.run(NUM_UPDATES * engine.steps_per_update)
    assert len(hist) == NUM_UPDATES
    return [[float(h[k]) for k in DIGEST_KEYS] for h in hist]


def _load_golden() -> dict:
    with open(GOLDEN_PATH) as f:
        return json.load(f)


@pytest.mark.parametrize("env_name", ENVS)
@pytest.mark.parametrize("backend", BACKENDS)
def test_golden_rollout(backend, env_name):
    cell = f"{backend}/{env_name}"
    got = _run_cell(backend, env_name)
    if UPDATE:
        data = _load_golden() if os.path.exists(GOLDEN_PATH) else {
            "metric_keys": list(DIGEST_KEYS), "cells": {}}
        data["cells"][cell] = got
        os.makedirs(os.path.dirname(GOLDEN_PATH), exist_ok=True)
        with open(GOLDEN_PATH, "w") as f:
            json.dump(data, f, indent=1, sort_keys=True)
        pytest.skip(f"golden fixture updated for {cell}")
    data = _load_golden()
    assert data["metric_keys"] == list(DIGEST_KEYS), \
        "metric schema changed — regenerate the golden fixtures"
    want = data["cells"][cell]
    for u, (w_row, g_row) in enumerate(zip(want, got)):
        for k, w, g in zip(DIGEST_KEYS, w_row, g_row):
            assert abs(w - g) <= TOL, (
                f"{cell} update {u} metric {k!r} drifted: "
                f"golden {w!r} vs current {g!r} (|Δ|={abs(w - g):.3e} > "
                f"{TOL}). If this change is intentional, regenerate with "
                f"REPRO_UPDATE_GOLDEN=1 and review the fixture diff.")


def test_golden_fixture_committed():
    """The fixture must exist and cover the full backend × env grid — a
    missing cell means a backend silently dropped out of regression cover."""
    data = _load_golden()
    want = {f"{b}/{e}" for b in BACKENDS for e in ENVS}
    assert set(data["cells"]) == want
    for cell, rows in data["cells"].items():
        assert len(rows) == NUM_UPDATES
        assert all(len(r) == len(DIGEST_KEYS) for r in rows)
        assert all(np.isfinite(v) for r in rows for v in r), cell
