"""Per-architecture smoke tests: reduced same-family configs, one forward +
one train step on CPU, output shapes + no NaNs (assignment requirement).
Full configs are exercised only via the dry-run."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config, get_smoke_config, with_overrides
from repro.configs.base import TrainConfig
from repro.data.buffer import random_batch
from repro.models.policy import BackbonePolicy
from repro.models.params import param_count
from repro.rl.learner import init_train_state, make_lm_train_step

KEY = jax.random.PRNGKey(0)


def _inputs(cfg, B=2, T=16):
    inputs = {"tokens": jnp.ones((B, T), jnp.int32)}
    if cfg.frontend:
        inputs["prefix"] = jnp.zeros((B, cfg.frontend_prefix, cfg.d_model))
    return inputs


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward(arch):
    cfg = get_smoke_config(arch)
    pol = BackbonePolicy(cfg, tp=1, kernel="ref")
    params = pol.init(KEY, jnp.float32)
    logits, values, aux = pol.seq(params, _inputs(cfg))
    T = 16 + (cfg.frontend_prefix if cfg.frontend else 0)
    assert logits.shape == (2, T, cfg.padded_vocab())
    assert values.shape == (2, T)
    assert bool(jnp.all(jnp.isfinite(values)))
    assert bool(jnp.all(jnp.isfinite(logits[..., :cfg.vocab_size])))


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_train_step(arch):
    cfg = with_overrides(get_smoke_config(arch), dtype="float32",
                         param_dtype="float32")
    pol = BackbonePolicy(cfg, tp=1, kernel="ref")
    ts = init_train_state(pol.init(KEY))
    step = jax.jit(make_lm_train_step(pol, TrainConfig(), loss_chunk=8))
    batch = random_batch(cfg, 2, 16, KEY)
    ts1, m1 = step(ts, batch)
    ts2, m2 = step(ts1, batch)
    for k in ("loss", "pg_loss", "v_loss", "entropy", "grad_norm"):
        assert np.isfinite(float(m2[k])), (arch, k, m2[k])
    assert float(m2["grad_norm"]) > 0
    assert int(ts2.step) == 2
    # params actually moved
    d = max(float(jnp.max(jnp.abs(a - b)))
            for a, b in zip(jax.tree.leaves(ts.params),
                            jax.tree.leaves(ts2.params)))
    assert d > 0


@pytest.mark.parametrize("arch", ["qwen3-0.6b", "mamba2-1.3b",
                                  "jamba-v0.1-52b", "dbrx-132b"])
def test_decode_consistency(arch):
    """prefill+decode token-by-token == full forward (f32).

    MoE archs use a dropless capacity factor here: capacity-dropped routing
    is inherently non-causal (tokens compete for expert slots), so exact
    decode/train parity only holds without drops — a documented property of
    GShard/Switch-style MoE (DESIGN.md §3)."""
    cfg = with_overrides(get_smoke_config(arch), dtype="float32",
                         param_dtype="float32")
    if cfg.num_experts:
        cfg = with_overrides(cfg, capacity_factor=float(cfg.num_experts))
    pol = BackbonePolicy(cfg, tp=1, kernel="ref")
    params = pol.init(jax.random.PRNGKey(1), jnp.float32)
    B, T, Tp = 2, 12, 8
    toks = jax.random.randint(KEY, (B, T), 0, cfg.vocab_size)
    logits_full, values_full, _ = pol.seq(params, {"tokens": toks})
    lg, v, caches = pol.prefill(params, {"tokens": toks[:, :Tp]}, max_len=T)
    np.testing.assert_allclose(np.asarray(lg), np.asarray(logits_full[:, Tp-1]),
                               atol=3e-4, rtol=1e-3)
    for t in range(Tp, T):
        lg, v, caches = pol.decode(params, toks[:, t:t+1], caches)
        np.testing.assert_allclose(np.asarray(lg),
                                   np.asarray(logits_full[:, t]),
                                   atol=3e-4, rtol=1e-3)
        np.testing.assert_allclose(np.asarray(v),
                                   np.asarray(values_full[:, t]),
                                   atol=3e-4, rtol=1e-3)


def test_full_config_param_counts():
    """Full (unpadded-vocab) param counts land near the architectures' names."""
    expect = {"llama4-maverick-400b-a17b": (3.5e11, 4.6e11),
              "dbrx-132b": (1.2e11, 1.45e11),
              "mamba2-1.3b": (1.0e9, 1.7e9),
              "gemma-7b": (7.5e9, 9.5e9),   # 8.5B incl. 256k-vocab embeddings
              "internlm2-20b": (1.7e10, 2.3e10),
              "stablelm-12b": (1.0e10, 1.4e10),
              "qwen3-0.6b": (5e8, 9e8),
              "jamba-v0.1-52b": (4.6e10, 5.8e10)}
    for arch, (lo, hi) in expect.items():
        cfg = get_config(arch)
        n = param_count(BackbonePolicy(cfg, tp=1).spec())
        assert lo <= n <= hi, f"{arch}: {n:.3e} not in [{lo:.1e},{hi:.1e}]"


def test_moe_active_params_llama4():
    from repro.launch.dryrun import model_flops
    from repro.configs.base import SHAPES
    cfg = get_config("llama4-maverick-400b-a17b")
    f = model_flops(cfg, SHAPES["train_4k"])
    # 6 * ~17B active * 1M tokens ~ 1.1e17; allow wide band
    assert 5e16 < f < 3e17


def test_tp_padding_math():
    cfg = get_config("llama4-maverick-400b-a17b")
    assert cfg.padded_heads(16) == 48 and cfg.padded_kv_heads(16) == 16
    cfg = get_config("musicgen-medium")
    assert cfg.padded_heads(16) == 32
    cfg = get_config("internvl2-26b")
    assert cfg.padded_vocab() % 128 == 0 and cfg.padded_vocab() >= 92553


def test_recurrent_toggle_same_model():
    """Paper §3.4: same policy ± recurrent cell via a flag, no rewrite."""
    from repro.models.policy import OceanPolicy
    for rec in (False, True):
        pol = OceanPolicy(8, (4,), hidden=16, recurrent=rec)
        params = pol.init(KEY)
        carry = pol.initial_carry(3)
        obs = jnp.ones((3, 8))
        logits, value, carry = pol.step(params, obs, carry)
        assert logits.shape == (3, 4) and value.shape == (3,)
        assert (carry is None) == (not rec)


def test_conv_frontend_shapes_and_batching():
    """CNN frontend: flat emulated obs restored to 2D, conv'd, and the
    result identical whether stepped as (B, obs) or scanned as (T, B, obs)
    — the seq path the learner recomputes through."""
    from repro.models.policy import OceanPolicy
    pol = OceanPolicy(36, (3,), hidden=16, conv_shape=(6, 6))
    params = pol.init(KEY)
    assert params["conv"].shape == (3, 3, 1, pol.CONV_FILTERS)
    obs = jax.random.uniform(KEY, (4, 36))
    logits, value, _ = pol.step(params, obs, None)
    assert logits.shape == (4, 3) and value.shape == (4,)
    assert bool(jnp.all(jnp.isfinite(logits)))
    seq = jnp.stack([obs, obs])                       # (T=2, B=4, 36)
    l2, v2, _ = pol.seq(params, seq, None, jnp.zeros((2, 4), bool))
    np.testing.assert_allclose(np.asarray(l2[0]), np.asarray(logits),
                               rtol=1e-6, atol=1e-6)
    # translation sensitivity: moving the pixel changes the logits (the
    # conv actually reads layout, not just a flat sum)
    img = jnp.zeros((6, 6)).at[1, 1].set(1.0)
    img2 = jnp.zeros((6, 6)).at[4, 2].set(1.0)
    la, *_ = pol.step(params, img.reshape(1, 36), None)
    lb, *_ = pol.step(params, img2.reshape(1, 36), None)
    assert float(jnp.abs(la - lb).max()) > 1e-6


def test_conv_frontend_requires_matching_shape():
    from repro.models.policy import OceanPolicy
    with pytest.raises(AssertionError):
        OceanPolicy(35, (3,), conv_shape=(6, 6))


def test_int8_quantized_policy_matches():
    """int8 serving path: same predictions, half the weight bytes."""
    from repro.models.params import quantize_params, param_count
    cfg = with_overrides(get_smoke_config("qwen3-0.6b"), dtype="float32",
                         param_dtype="float32")
    pol = BackbonePolicy(cfg, tp=1, kernel="ref")
    params = pol.init(KEY, jnp.float32)
    polq = BackbonePolicy(cfg, tp=1, kernel="ref", quantize="int8")
    pq = quantize_params(params, pol.spec())
    toks = jax.random.randint(KEY, (2, 16), 0, cfg.vocab_size)
    lf, vf, _ = pol.seq(params, {"tokens": toks})
    lq, vq, _ = polq.seq(pq, {"tokens": toks})
    agree = float(jnp.mean(jnp.argmax(lf, -1) == jnp.argmax(lq, -1)))
    assert agree > 0.95, agree
    # decode path works quantized too
    lgq, _, caches = polq.prefill(pq, {"tokens": toks[:, :12]}, max_len=16)
    lgq2, _, caches = polq.decode(pq, toks[:, 12:13], caches)
    assert bool(jnp.all(jnp.isfinite(lgq2[..., :cfg.vocab_size])))
