"""Ocean env invariants (paper §4): bounded rewards, correct horizons,
scores in [0,1], and the intended optimal behaviours score ~1."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import spaces as sp
from repro.envs.ocean import OCEAN, Squared, Password, Stochastic, Memory, \
    Multiagent, Spaces, Bandit, Pong, Drone, TagTeam, Maze


@pytest.mark.parametrize("name", list(OCEAN))
def test_env_protocol(name):
    env = OCEAN[name]()
    key = jax.random.PRNGKey(0)
    state = env.init(key)
    state, obs = env.reset(state, key)
    horizon = getattr(env, "horizon", getattr(env, "length", 64))
    for t in range(horizon + 1):
        act = sp.sample(env.action_space, jax.random.fold_in(key, t))
        if env.num_agents > 1:
            act = jnp.stack([act] * env.num_agents)
        state, obs, rew, done, info = env.step(state, act,
                                               jax.random.fold_in(key, 100 + t))
        assert jnp.all(jnp.isfinite(jnp.asarray(rew, jnp.float32)))
        if bool(done):
            assert 0.0 <= float(info["score"]) <= 1.0
            assert bool(info["valid"])
            break
    else:
        pytest.fail(f"{name} never terminated")


def _run_policy(env, policy_fn, episodes=20, seed=0):
    """Roll a hand-written policy; return mean episode score."""
    key = jax.random.PRNGKey(seed)
    scores = []
    for e in range(episodes):
        state = env.init(jax.random.fold_in(key, e))
        state, obs = env.reset(state, jax.random.fold_in(key, 1000 + e))
        t = 0
        while True:
            act = policy_fn(obs, t, jax.random.fold_in(key, e * 7919 + t))
            state, obs, rew, done, info = env.step(
                state, act, jax.random.fold_in(key, e * 31 + t))
            t += 1
            if bool(done):
                scores.append(float(info["score"]))
                break
            assert t < 1000
    return float(np.mean(scores))


def test_password_optimal():
    env = Password()
    pw = list(env.PASSWORD)
    s = _run_policy(env, lambda obs, t, k: jnp.asarray(pw[t % len(pw)]))
    assert s == 1.0


def test_bandit_optimal():
    env = Bandit()
    best = int(np.argmax(env.PROBS))
    s = _run_policy(env, lambda obs, t, k: jnp.asarray(best), episodes=30)
    assert s > 0.85   # stochastic payouts


def test_stochastic_optimal():
    env = Stochastic()
    s = _run_policy(
        env, lambda obs, t, k: (jax.random.uniform(k) > env.p).astype(jnp.int32),
        episodes=30)
    assert s > 0.85
    # deterministic policy must score poorly (the env's whole point)
    s_det = _run_policy(env, lambda obs, t, k: jnp.asarray(0))
    assert s_det < 0.6


def test_memory_requires_memory():
    env = Memory()
    # cheating policy that peeks at the env state is impossible through obs;
    # a random policy scores ~0.5
    s = _run_policy(env, lambda obs, t, k:
                    jax.random.bernoulli(k).astype(jnp.int32), episodes=40)
    assert 0.2 < s < 0.8


def test_squared_perimeter_sweep_scores_1():
    env = Squared(size=5)
    # scripted sweep: go north to the perimeter, then walk the ring
    path = [1, 1] + [4, 4, 2, 2, 2, 2, 3, 3, 3, 3, 1, 1, 1, 1, 4]
    s = _run_policy(env, lambda obs, t, k:
                    jnp.asarray(path[t] if t < len(path) else 0), episodes=3)
    assert s > 0.95


def test_spaces_optimal():
    env = Spaces()
    def pol(obs, t, k):
        return {"a": obs["image"][1, 1].astype(jnp.int32),
                "b": obs["flat"][0].astype(jnp.int32)}
    assert _run_policy(env, pol) == 1.0


# -- Ocean II ----------------------------------------------------------------

def test_pong_greedy_tracking_catches():
    """A memoryless greedy tracker (move toward the ball's current column)
    always catches with the 3-wide paddle — the env is solvable from single
    frames, no recurrence needed."""
    env = Pong()
    key = jax.random.PRNGKey(0)
    scores = []
    for e in range(100):
        s = env.init(jax.random.fold_in(key, e))
        s, obs = env.reset(s, jax.random.fold_in(key, 1000 + e))
        while True:
            ball, pad = int(s["ball"][1]), int(s["paddle"])
            a = 0 if ball == pad else (1 if ball < pad else 2)
            s, obs, rew, done, info = env.step(s, jnp.asarray(a), key)
            if bool(done):
                scores.append(float(info["score"]))
                break
    assert np.mean(scores) == 1.0


def test_pong_obs_is_pixel_grid():
    env = Pong()
    s = env.init(jax.random.PRNGKey(3))
    s, obs = env.reset(s, jax.random.PRNGKey(4))
    assert obs.shape == (6, 6)
    assert float(obs.max()) == 1.0           # ball pixel
    assert (np.asarray(obs) == 0.5).sum() in (2, 3)   # paddle (clipped at wall)


def test_drone_direct_flight_scores_high():
    env = Drone()
    key = jax.random.PRNGKey(0)
    scores = []
    for e in range(30):
        s = env.init(jax.random.fold_in(key, e))
        s, obs = env.reset(s, jax.random.fold_in(key, 500 + e))
        while True:
            a = np.clip((np.asarray(s["target"]) - np.asarray(s["pos"]))
                        / env.thrust, -1, 1)
            s, obs, rew, done, info = env.step(s, jnp.asarray(a), key)
            if bool(done):
                scores.append(float(info["score"]))
                break
    assert np.mean(scores) > 0.95


def test_tagteam_per_team_reward_and_padding():
    env = TagTeam()
    key = jax.random.PRNGKey(0)
    s = env.init(key)
    s, obs = env.reset(s, key)
    assert obs.shape == (6, 4)
    np.testing.assert_array_equal(np.asarray(obs[4:]), 0.0)   # padded rows
    sig = int(np.asarray(obs)[0, 2])
    # team 0 plays the signal, team 1 misplays: team rewards 1.0 / 0.0
    act = jnp.asarray([sig, sig, sig, sig, 0, 0])
    s, obs, rew, done, info = env.step(s, act, key)
    np.testing.assert_allclose(np.asarray(rew), [1, 1, 0, 0, 0, 0])
    # one team-0 agent defects: BOTH team-0 agents drop to 0.5 (shared)
    sig = int(np.asarray(obs)[0, 2])
    act = jnp.asarray([sig, 1 - sig, 1 - sig, 1 - sig, 0, 0])
    s, obs, rew, done, info = env.step(s, act, key)
    np.testing.assert_allclose(np.asarray(rew), [0.5, 0.5, 1, 1, 0, 0])


def test_tagteam_optimal_scores_1():
    env = TagTeam()
    key = jax.random.PRNGKey(7)
    s = env.init(key)
    s, obs = env.reset(s, key)
    while True:
        sig = int(np.asarray(obs)[0, 2])
        act = jnp.asarray([sig, sig, 1 - sig, 1 - sig, 0, 0])
        s, obs, rew, done, info = env.step(s, act,
                                           jax.random.fold_in(key, int(s["t"])))
        if bool(done):
            break
    assert float(info["score"]) == 1.0


def test_maze_procgen_layouts_differ_per_key():
    env = Maze()
    key = jax.random.PRNGKey(0)
    layouts = {np.asarray(env.init(jax.random.fold_in(key, i))["walls"])
               .tobytes() for i in range(12)}
    assert len(layouts) > 1            # procgen actually follows the key
    s = env.init(key)
    # walls only on odd-odd pillar cells — connectivity guaranteed
    walls = np.asarray(s["walls"])
    rr, cc = np.nonzero(walls)
    assert all(r % 2 == 1 and c % 2 == 1 for r, c in zip(rr, cc))
    assert not walls[tuple(np.asarray(s["pos"]))]
    assert not walls[tuple(np.asarray(s["target"]))]


def test_maze_greedy_with_wall_avoidance_solves():
    env = Maze()
    key = jax.random.PRNGKey(1)
    scores = []
    for e in range(50):
        s = env.init(jax.random.fold_in(key, e))
        s, obs = env.reset(s, jax.random.fold_in(key, 900 + e))
        for t in range(env.horizon):
            pos, tgt = np.asarray(s["pos"]), np.asarray(s["target"])
            walls = np.asarray(s["walls"])
            moves = [(0, 0), (-1, 0), (1, 0), (0, -1), (0, 1)]

            def cost(i):
                r, c = pos[0] + moves[i][0], pos[1] + moves[i][1]
                if not (0 <= r < 7 and 0 <= c < 7) or walls[r, c]:
                    return 99
                return abs(r - tgt[0]) + abs(c - tgt[1])

            a = min(range(5), key=cost)
            s, obs, rew, done, info = env.step(s, jnp.asarray(a),
                                               jax.random.fold_in(key, t))
            if bool(done):
                break
        scores.append(float(info["score"]))
    assert np.mean(scores) > 0.95


def test_multiagent_reward_assignment():
    env = Multiagent()
    key = jax.random.PRNGKey(0)
    state = env.init(key)
    state, obs = env.reset(state, key)
    state, obs, rew, done, info = env.step(
        state, jnp.asarray([0, 1]), key)
    np.testing.assert_allclose(np.asarray(rew), [1.0, 1.0])
    state, obs, rew, done, info = env.step(
        state, jnp.asarray([1, 0]), key)
    np.testing.assert_allclose(np.asarray(rew), [0.0, 0.0])
