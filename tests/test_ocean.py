"""Ocean env invariants (paper §4): bounded rewards, correct horizons,
scores in [0,1], and the intended optimal behaviours score ~1."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import spaces as sp
from repro.envs.ocean import OCEAN, Squared, Password, Stochastic, Memory, \
    Multiagent, Spaces, Bandit


@pytest.mark.parametrize("name", list(OCEAN))
def test_env_protocol(name):
    env = OCEAN[name]()
    key = jax.random.PRNGKey(0)
    state = env.init(key)
    state, obs = env.reset(state, key)
    horizon = getattr(env, "horizon", getattr(env, "length", 64))
    for t in range(horizon + 1):
        act = sp.sample(env.action_space, jax.random.fold_in(key, t))
        if env.num_agents > 1:
            act = jnp.stack([act] * env.num_agents)
        state, obs, rew, done, info = env.step(state, act,
                                               jax.random.fold_in(key, 100 + t))
        assert jnp.all(jnp.isfinite(jnp.asarray(rew, jnp.float32)))
        if bool(done):
            assert 0.0 <= float(info["score"]) <= 1.0
            assert bool(info["valid"])
            break
    else:
        pytest.fail(f"{name} never terminated")


def _run_policy(env, policy_fn, episodes=20, seed=0):
    """Roll a hand-written policy; return mean episode score."""
    key = jax.random.PRNGKey(seed)
    scores = []
    for e in range(episodes):
        state = env.init(jax.random.fold_in(key, e))
        state, obs = env.reset(state, jax.random.fold_in(key, 1000 + e))
        t = 0
        while True:
            act = policy_fn(obs, t, jax.random.fold_in(key, e * 7919 + t))
            state, obs, rew, done, info = env.step(
                state, act, jax.random.fold_in(key, e * 31 + t))
            t += 1
            if bool(done):
                scores.append(float(info["score"]))
                break
            assert t < 1000
    return float(np.mean(scores))


def test_password_optimal():
    env = Password()
    pw = list(env.PASSWORD)
    s = _run_policy(env, lambda obs, t, k: jnp.asarray(pw[t % len(pw)]))
    assert s == 1.0


def test_bandit_optimal():
    env = Bandit()
    best = int(np.argmax(env.PROBS))
    s = _run_policy(env, lambda obs, t, k: jnp.asarray(best), episodes=30)
    assert s > 0.85   # stochastic payouts


def test_stochastic_optimal():
    env = Stochastic()
    s = _run_policy(
        env, lambda obs, t, k: (jax.random.uniform(k) > env.p).astype(jnp.int32),
        episodes=30)
    assert s > 0.85
    # deterministic policy must score poorly (the env's whole point)
    s_det = _run_policy(env, lambda obs, t, k: jnp.asarray(0))
    assert s_det < 0.6


def test_memory_requires_memory():
    env = Memory()
    # cheating policy that peeks at the env state is impossible through obs;
    # a random policy scores ~0.5
    s = _run_policy(env, lambda obs, t, k:
                    jax.random.bernoulli(k).astype(jnp.int32), episodes=40)
    assert 0.2 < s < 0.8


def test_squared_perimeter_sweep_scores_1():
    env = Squared(size=5)
    # scripted sweep: go north to the perimeter, then walk the ring
    path = [1, 1] + [4, 4, 2, 2, 2, 2, 3, 3, 3, 3, 1, 1, 1, 1, 4]
    s = _run_policy(env, lambda obs, t, k:
                    jnp.asarray(path[t] if t < len(path) else 0), episodes=3)
    assert s > 0.95


def test_spaces_optimal():
    env = Spaces()
    def pol(obs, t, k):
        return {"a": obs["image"][1, 1].astype(jnp.int32),
                "b": obs["flat"][0].astype(jnp.int32)}
    assert _run_policy(env, pol) == 1.0


def test_multiagent_reward_assignment():
    env = Multiagent()
    key = jax.random.PRNGKey(0)
    state = env.init(key)
    state, obs = env.reset(state, key)
    state, obs, rew, done, info = env.step(
        state, jnp.asarray([0, 1]), key)
    np.testing.assert_allclose(np.asarray(rew), [1.0, 1.0])
    state, obs, rew, done, info = env.step(
        state, jnp.asarray([1, 0]), key)
    np.testing.assert_allclose(np.asarray(rew), [0.0, 0.0])
