"""Ocean II training smoke (CI slow lane): each new env must actually train
to score > 0.9 under the jit engine with its committed preset — the
end-to-end proof that the scenario is learnable and wired correctly through
emulation, the policy frontend, and the engine."""
import jax
import pytest

from repro.configs.ocean import ocean_tcfg, preset
from repro.envs.ocean import OCEAN
from repro.rl.trainer import Trainer

OCEAN_II = ("pong", "drone", "tagteam", "maze")


@pytest.mark.slow
@pytest.mark.parametrize("name", OCEAN_II)
def test_ocean_ii_trains_to_target(name):
    p = preset(name)
    tcfg = ocean_tcfg(name, updates_per_launch=4)
    tr = Trainer(OCEAN[name](), tcfg, hidden=p.hidden, recurrent=p.recurrent,
                 conv=p.conv, seed=0)
    m = tr.train(p.total_steps, target_score=p.target_score)
    assert m["score"] > p.target_score, (
        f"{name} failed its smoke budget: score {m['score']:.3f} after "
        f"{m['env_steps']} env steps (preset target {p.target_score})")


@pytest.mark.slow
def test_pong_trains_through_conv_frontend():
    """The pixel env must be learning through the CNN, not around it: the
    trained conv kernel has moved away from its init."""
    import numpy as np
    p = preset("pong")
    tr = Trainer(OCEAN["pong"](), ocean_tcfg("pong", updates_per_launch=4),
                 hidden=p.hidden, seed=0)
    assert tr.policy.conv_shape == (6, 6)
    k0 = np.asarray(jax.device_get(tr.ts.params["conv"])).copy()
    m = tr.train(100_000, target_score=0.9)
    assert m["score"] > 0.9
    k1 = np.asarray(jax.device_get(tr.ts.params["conv"]))
    assert np.abs(k1 - k0).max() > 1e-3
