"""Policy League: store versioning/round-trip, Elo ranker, samplers, the
vmapped arena, selfplay engine tiers, and the Duel acceptance smoke."""
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import TrainConfig
from repro.core.emulation import Emulated
from repro.envs.ocean import OCEAN, Duel
from repro.league import (Arena, OpponentSampler, PolicyStore, Ranker,
                          SelfPlay, run_selfplay)
from repro.models.policy import OceanPolicy
from repro.rl.distributions import Dist
from repro.rl.engine import TrainEngine

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

TCFG = TrainConfig(num_envs=16, unroll_length=16, update_epochs=2,
                   num_minibatches=2, learning_rate=1e-3, gamma=0.95)


def _policy(env, hidden=32, recurrent=False):
    em = Emulated(env)
    dist = Dist("categorical", nvec=em.act_spec.nvec)
    pol = OceanPolicy(em.obs_spec.total, dist.nvec, hidden=hidden,
                      recurrent=recurrent, num_outputs=dist.num_outputs)
    return em, dist, pol


# =========================== PolicyStore =====================================

def test_store_roundtrip_and_metadata(tmp_path):
    _, _, pol = _policy(Duel())
    store = PolicyStore(str(tmp_path))
    p0 = pol.init(jax.random.PRNGKey(0))
    p1 = pol.init(jax.random.PRNGKey(1))
    v0 = store.add(p0, step=0, score=0.5)
    v1 = store.add(p1, step=1000, score=0.7, rating=1100.0)
    assert (v0, v1) == (0, 1) and store.versions() == [0, 1]
    assert store.latest() == 1 and len(store) == 2
    assert store.meta(1) == {"step": 1000, "score": 0.7, "rating": 1100.0}
    # v1 inherits nothing; a v2 with no explicit rating inherits v1's
    v2 = store.add(p0, step=2000)
    assert store.meta(2)["rating"] == 1100.0
    r = store.load(v1, pol.abstract())
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(r)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # a second handle on the same directory sees the same league
    store2 = PolicyStore(str(tmp_path))
    assert store2.versions() == [0, 1, 2]
    assert store2.meta(1)["rating"] == 1100.0


def test_store_load_stacked(tmp_path):
    _, _, pol = _policy(Duel())
    store = PolicyStore(str(tmp_path))
    trees = [pol.init(jax.random.PRNGKey(i)) for i in range(3)]
    for t in trees:
        store.add(t)
    stacked = store.load_stacked([0, 1, 2], pol.abstract())
    for name in ("enc1", "act"):
        assert stacked[name].shape == (3,) + trees[0][name].shape
        for i in range(3):
            np.testing.assert_array_equal(stacked[name][i],
                                          np.asarray(trees[i][name]))


MESH_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, numpy as np, sys
from jax.sharding import PartitionSpec as P, NamedSharding
from repro.core.emulation import Emulated
from repro.envs.ocean import Duel
from repro.league import PolicyStore
from repro.models.policy import OceanPolicy
from repro.rl.distributions import Dist

d = sys.argv[1]
em = Emulated(Duel())
dist = Dist("categorical", nvec=em.act_spec.nvec)
pol = OceanPolicy(em.obs_spec.total, dist.nvec, hidden=32,
                  num_outputs=dist.num_outputs)
store = PolicyStore(d)
mesh1 = jax.make_mesh((8,), ("data",))
params = jax.device_put(pol.init(jax.random.PRNGKey(3)),
                        NamedSharding(mesh1, P()))
v = store.add(jax.device_get(params))
# restore the snapshot assembled directly onto a DIFFERENT (2x4) mesh
mesh2 = jax.make_mesh((2, 4), ("a", "b"))
sh = jax.tree.map(lambda _: NamedSharding(mesh2, P()), pol.abstract())
r = store.load(v, pol.abstract(), shardings=sh)
for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(r)):
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert b.sharding.mesh.shape == {"a": 2, "b": 4}
print("MESH_ROUNDTRIP_OK")
"""


def test_store_roundtrip_across_mesh_change(tmp_path):
    """Snapshot saved under an 8-way mesh restores assembled onto a 2x4
    mesh — the elastic property selfplay relies on when a league trained on
    one topology resumes on another."""
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run([sys.executable, "-c", MESH_SCRIPT, str(tmp_path)],
                         capture_output=True, text=True, env=env, cwd=ROOT)
    assert "MESH_ROUNDTRIP_OK" in out.stdout, out.stderr[-2000:]


# =============================== Ranker ======================================

def test_ranker_elo_updates_are_zero_sum():
    r = Ranker()
    r.update(0, 1, 1.0)
    assert r.rating(0) > 1000.0 > r.rating(1)
    assert abs(r.rating(0) + r.rating(1) - 2000.0) < 1e-9
    # upset moves more rating than an expected win
    r2 = Ranker({0: 1200.0, 1: 800.0})
    r2.update(1, 0, 1.0)                     # 800 beats 1200
    upset_gain = r2.rating(1) - 800.0
    r3 = Ranker({0: 1200.0, 1: 800.0})
    r3.update(0, 1, 1.0)                     # favorite wins
    fav_gain = r3.rating(0) - 1200.0
    assert upset_gain > fav_gain > 0


def test_ranker_recovers_planted_skill_ordering():
    """5 planted skill tiers, noisy Bernoulli match outcomes under a
    logistic skill-gap model: Elo must recover the exact order."""
    skills = {0: -2.0, 1: -1.0, 2: 0.0, 3: 1.0, 4: 2.0}
    rng = np.random.default_rng(7)
    ranker = Ranker()
    for _ in range(400):
        a, b = rng.choice(5, size=2, replace=False)
        p_a = 1.0 / (1.0 + np.exp(-(skills[a] - skills[b])))
        ranker.update(int(a), int(b), float(rng.random() < p_a))
    assert ranker.rank() == [4, 3, 2, 1, 0], ranker.ratings


# ============================== Samplers =====================================

def _seeded_store(tmp_path, pol, n=5):
    store = PolicyStore(str(tmp_path))
    for i in range(n):
        store.add(pol.init(jax.random.PRNGKey(i)))
    return store


@pytest.mark.parametrize("strategy", ["latest", "uniform", "prioritized"])
def test_sampler_determinism_under_fixed_seed(tmp_path, strategy):
    _, _, pol = _policy(Duel())
    store = _seeded_store(tmp_path, pol)
    ranker = Ranker({0: 900.0, 1: 950.0, 2: 1000.0, 3: 1050.0, 4: 1060.0})
    draws = []
    for _ in range(2):
        s = OpponentSampler(store, ranker, pol.abstract(),
                            strategy=strategy, seed=123)
        draws.append([s.sample() for _ in range(20)])
    assert draws[0] == draws[1]
    if strategy == "latest":
        assert set(draws[0]) == {4}


def test_prioritized_sampler_favors_rating_proximity(tmp_path):
    """With one version rated far below the learner anchor, prioritized
    sampling should pick it much less often than the peers."""
    _, _, pol = _policy(Duel())
    store = _seeded_store(tmp_path, pol)
    ranker = Ranker({0: 200.0, 1: 1000.0, 2: 1000.0, 3: 1000.0, 4: 1000.0})
    s = OpponentSampler(store, ranker, pol.abstract(),
                        strategy="prioritized", seed=0, temperature=100.0)
    draws = [s.sample() for _ in range(200)]
    assert draws.count(0) < 0.1 * len(draws)
    # repeat loads of one version come from the cache (no store I/O)
    s2 = OpponentSampler(store, ranker, pol.abstract(), strategy="latest",
                         seed=0)
    assert s2.next_params() is s2.next_params()


def test_sampler_empty_store_raises(tmp_path):
    _, _, pol = _policy(Duel())
    store = PolicyStore(str(tmp_path / "empty"))
    s = OpponentSampler(store, Ranker(), pol.abstract())
    with pytest.raises(ValueError, match="empty"):
        s.sample()


# ================================ Arena ======================================

def test_arena_vmapped_pool_matches_sequential():
    """The one-launch vmapped K-opponent evaluation must produce exactly
    the per-opponent results of K sequential dispatches (same keys)."""
    em, dist, pol = _policy(Duel())
    arena = Arena(em, pol, dist, num_envs=4, steps=40)
    pa = pol.init(jax.random.PRNGKey(0))
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs),
                           *[pol.init(jax.random.PRNGKey(i))
                             for i in range(1, 5)])
    key = jax.random.PRNGKey(42)
    pooled = arena.vs_pool(pa, stacked, key)
    seq = arena.vs_pool_sequential(pa, stacked, key)
    assert len(pooled) == len(seq) == 4
    for a, b in zip(pooled, seq):
        for k in ("wins_a", "wins_b", "draws", "episodes", "outcome"):
            np.testing.assert_allclose(a[k], b[k], rtol=1e-6, err_msg=k)


def test_arena_round_robin_records():
    em, dist, pol = _policy(Duel())
    arena = Arena(em, pol, dist, num_envs=4, steps=40)
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs),
                           *[pol.init(jax.random.PRNGKey(i))
                             for i in range(3)])
    recs = arena.round_robin(stacked, [10, 11, 12], jax.random.PRNGKey(0))
    assert [(a, b) for a, b, _ in recs] == [(10, 11), (10, 12), (11, 12)]
    for _, _, outcome in recs:
        assert 0.0 <= outcome <= 1.0
    ranker = Ranker()
    ranker.record(recs)
    assert set(ranker.ratings) == {10, 11, 12}


def test_arena_outcomes_are_mirror_consistent():
    """Zero-sum env + side-0-centric score: every completed episode is
    exactly one of win/draw/loss, so outcomes always lie in [0, 1] and the
    counts add up."""
    em, dist, pol = _policy(Duel())
    arena = Arena(em, pol, dist, num_envs=8, steps=66)
    pa, pb = (pol.init(jax.random.PRNGKey(i)) for i in range(2))
    r = arena.play(pa, pb, jax.random.PRNGKey(5))
    assert r["episodes"] == r["wins_a"] + r["wins_b"] + r["draws"]
    assert r["episodes"] >= 8            # 66 steps of horizon-32 episodes
    assert 0.0 <= r["outcome"] <= 1.0


def test_arena_rejects_single_agent_env():
    from repro.envs.ocean import Bandit
    em, dist, pol = _policy(Bandit())
    with pytest.raises(ValueError, match="multi-agent"):
        Arena(em, dist=dist, policy=pol)


# ========================= selfplay engine tier ==============================

def _selfplay_engine(env, backend="jit", recurrent=False, learner_agents=0,
                     tcfg=TCFG):
    em, dist, pol = _policy(env, recurrent=recurrent)
    opp = pol.init(jax.random.PRNGKey(99))
    return TrainEngine(em, pol, tcfg, dist, key=jax.random.PRNGKey(0),
                       backend=backend, kernel_mode="ref",
                       selfplay=SelfPlay(lambda: opp, learner_agents))


@pytest.mark.parametrize("name,recurrent",
                         [("duel", False), ("multiagent", False),
                          ("tagteam", False), ("duel", True)])
def test_selfplay_smoke(name, recurrent):
    """Self-play splits rows and trains on the competitive env AND on the
    ordinary multi-agent envs (Multiagent A=2, TagTeam A=6 with padding)."""
    e = _selfplay_engine(OCEAN[name](), recurrent=recurrent)
    hist, _ = e.run(2 * e.steps_per_update)
    assert len(hist) == 2
    assert np.isfinite(hist[-1]["loss"]) and np.isfinite(hist[-1]["entropy"])


def test_selfplay_opponent_resampled_each_launch():
    em, dist, pol = _policy(Duel())
    calls = {"n": 0}

    def next_opponent():
        calls["n"] += 1
        return pol.init(jax.random.PRNGKey(calls["n"]))

    e = TrainEngine(em, pol, TCFG, dist, key=jax.random.PRNGKey(0),
                    kernel_mode="ref", updates_per_launch=2,
                    selfplay=SelfPlay(next_opponent))
    e.run(6 * e.steps_per_update)        # 3 launches of K=2
    assert calls["n"] == 3


def test_selfplay_learner_actually_learns_vs_frozen():
    """Against a FROZEN opponent the learner's score must climb well past
    the 0.5 symmetry point — opponent rows are part of the env, not of the
    PPO batch."""
    tcfg = TrainConfig(num_envs=32, unroll_length=32, update_epochs=2,
                       num_minibatches=2, learning_rate=1e-3, gamma=0.95)
    e = _selfplay_engine(Duel(), tcfg=tcfg)
    hist, _ = e.run(40 * e.steps_per_update)
    late = [m["score"] for m in hist[-5:] if m["episodes"] > 0]
    assert np.mean(late) > 0.7, late


def test_selfplay_rejects_bad_configs():
    from repro.envs.ocean import Bandit
    em, dist, pol = _policy(Bandit())
    opp = pol.init(jax.random.PRNGKey(1))
    with pytest.raises(ValueError, match="multi-agent"):
        TrainEngine(em, pol, TCFG, dist, key=jax.random.PRNGKey(0),
                    selfplay=SelfPlay(lambda: opp))
    em2, dist2, pol2 = _policy(Duel())
    with pytest.raises(ValueError, match="learner_agents"):
        TrainEngine(em2, pol2, TCFG, dist2, key=jax.random.PRNGKey(0),
                    selfplay=SelfPlay(lambda: opp, learner_agents=2))
    with pytest.raises(ValueError, match="tiers"):
        TrainEngine(em2, pol2, TCFG, dist2, key=jax.random.PRNGKey(0),
                    backend="pool", selfplay=SelfPlay(lambda: opp))


SHARD_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, numpy as np
from repro.configs.base import TrainConfig
from repro.core.emulation import Emulated
from repro.envs.ocean import Duel
from repro.league import SelfPlay
from repro.models.policy import OceanPolicy
from repro.rl.distributions import Dist
from repro.rl.engine import TrainEngine

tcfg = TrainConfig(num_envs=16, unroll_length=16, update_epochs=2,
                   num_minibatches=2, learning_rate=1e-3, gamma=0.95)

def build(backend, num_shards=1):
    em = Emulated(Duel())
    dist = Dist("categorical", nvec=em.act_spec.nvec)
    pol = OceanPolicy(em.obs_spec.total, dist.nvec, hidden=32,
                      num_outputs=dist.num_outputs)
    opp = pol.init(jax.random.PRNGKey(99))
    return TrainEngine(em, pol, tcfg, dist, key=jax.random.PRNGKey(0),
                       backend=backend, kernel_mode="ref",
                       num_shards=num_shards, selfplay=SelfPlay(lambda: opp))

a = build("jit", num_shards=4)
a.run(3 * a.steps_per_update)
b = build("shard_map")
b.run(3 * b.steps_per_update)
d = max(float(np.max(np.abs(np.asarray(x) - np.asarray(y))))
        for x, y in zip(jax.tree.leaves(a.ts.params),
                        jax.tree.leaves(b.ts.params)))
assert d < 1e-5, d
print("SELFPLAY_SHARD_PARITY_OK", d)
"""


@pytest.mark.multi_device
def test_selfplay_shard_map_seed_parity():
    """4-device shard_map selfplay is seed-matched with the single-device
    4-block emulation — split rows keep the global-row key contract."""
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run([sys.executable, "-c", SHARD_SCRIPT],
                         capture_output=True, text=True, env=env, cwd=ROOT)
    assert "SELFPLAY_SHARD_PARITY_OK" in out.stdout, out.stderr[-2000:]


# ========================= run_selfplay driver ===============================

def test_run_selfplay_builds_league(tmp_path):
    """Short league run: versions accumulate (init + snapshots + final),
    ratings persist to league.json, and the sampler's opponent schedule is
    drawn from the store."""
    tcfg = TrainConfig(num_envs=8, unroll_length=16, update_epochs=1,
                       num_minibatches=2, learning_rate=1e-3, gamma=0.95)
    res = run_selfplay(Duel(), tcfg, league_dir=str(tmp_path),
                       total_steps=6 * 16 * 8 * 2, snapshot_every=2,
                       hidden=16, seed=0)
    assert len(res.history) == 6
    assert len(res.store) >= 3           # v0 + >=1 snapshot + final
    with open(tmp_path / "league.json") as f:
        idx = json.load(f)
    assert set(idx["versions"]) == {str(v) for v in res.store.versions()}
    assert all(v in res.ranker.ratings for v in res.store.versions())
    assert 0.0 <= res.winrate_random <= 1.0
    # resuming the same league dir picks up the stored versions
    res2 = run_selfplay(Duel(), tcfg, league_dir=str(tmp_path),
                        total_steps=16 * 8 * 2, snapshot_every=2,
                        hidden=16, seed=1)
    assert len(res2.store) == len(res.store) + 1


@pytest.mark.slow
def test_duel_selfplay_beats_random_baseline():
    """Acceptance: Duel self-play on the jit tier reaches >= 0.9 winrate
    vs the random-policy baseline within the committed preset budget."""
    import tempfile
    from repro.configs.ocean import ocean_tcfg, preset
    p = preset("duel")
    tcfg = ocean_tcfg("duel", updates_per_launch=4)
    with tempfile.TemporaryDirectory() as d:
        res = run_selfplay(OCEAN["duel"](), tcfg, league_dir=d,
                           total_steps=p.total_steps, snapshot_every=8,
                           hidden=p.hidden, seed=0)
    assert res.winrate_random >= p.target_score, (
        f"duel selfplay winrate vs random {res.winrate_random:.3f} < "
        f"{p.target_score} after {p.total_steps} steps")
