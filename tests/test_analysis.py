"""repro.analysis: every lint rule fires on a planted violation (and not on
noqa'd / static-attribute lookalikes), every jaxpr/HLO audit check fires on a
planted program (and not on clean ones), baselines round-trip count-aware,
the CLI gates correctly, and the host-pool timeout satellites hold."""
import json
import os
import queue
import subprocess
import sys
import textwrap
import threading
import warnings

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.analysis import (RULES, apply_baseline, audit_fn, check_source,
                            load_baseline, save_baseline)

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _src(text: str) -> str:
    return textwrap.dedent(text)


def _rules(findings) -> set:
    return {f.rule for f in findings}


# ---------------------------------------------------------------------------
# layer 1: one planted violation per rule

def test_tracer_branch_on_jitted_if():
    fs = check_source(_src("""
        import jax

        @jax.jit
        def branchy(x):
            if x > 0:
                return x
            return -x
    """))
    assert "TRACER-BRANCH" in _rules(fs)


def test_tracer_branch_via_scan_body_assert():
    fs = check_source(_src("""
        import jax
        import jax.numpy as jnp

        def inner_traced():
            def body(c, x):
                assert x > 0
                return c + x, x
            return jax.lax.scan(body, 0.0, jnp.ones(3))
    """))
    assert "TRACER-BRANCH" in _rules(fs)


def test_host_sync_in_traced_and_loop():
    fs = check_source(_src("""
        import jax

        @jax.jit
        def syncy(x):
            y = x * 2
            return float(y)

        def hot_loop(vals):
            out = []
            for v in vals:
                out.append(float(jax.device_get(v)))
            return out
    """))
    assert "HOST-SYNC" in _rules(fs)
    assert sum(f.rule == "HOST-SYNC" for f in fs) >= 2


def test_blocking_no_timeout_on_bare_get():
    fs = check_source(_src("""
        import queue

        def worker(q: "queue.Queue"):
            item = q.get()
            return item
    """))
    assert "BLOCKING-NO-TIMEOUT" in _rules(fs)


def test_blocking_with_timeout_not_flagged():
    fs = check_source(_src("""
        import queue

        def worker(q: "queue.Queue"):
            return q.get(timeout=1.0)
    """))
    assert "BLOCKING-NO-TIMEOUT" not in _rules(fs)


def test_blocking_gate_sees_submodule_imports():
    """``import multiprocessing.shared_memory`` must arm the threaded-code
    gate (root-normalized), so multiprocessing Queue.get()/Process.join()
    sites are covered like their ``queue``/``threading`` twins."""
    fs = check_source(_src("""
        import multiprocessing.shared_memory

        def pump(q, p):
            item = q.get()
            p.join()
            return item
    """))
    assert sum(f.rule == "BLOCKING-NO-TIMEOUT" for f in fs) == 2


def test_blocking_connection_wait_flagged():
    """``connection.wait(objects)`` blocks forever by default — its
    positional arg is the object list, not a timeout."""
    fs = check_source(_src("""
        from multiprocessing import connection

        def pump(sentinels):
            return connection.wait(sentinels)
    """))
    assert "BLOCKING-NO-TIMEOUT" in _rules(fs)

    fs = check_source(_src("""
        from multiprocessing import connection

        def pump(sentinels):
            return connection.wait(sentinels, timeout=1.0)
    """))
    assert "BLOCKING-NO-TIMEOUT" not in _rules(fs)


def test_blocking_bare_wait_from_import_flagged():
    fs = check_source(_src("""
        from multiprocessing.connection import wait

        def pump(sentinels):
            return wait(sentinels)
    """))
    assert "BLOCKING-NO-TIMEOUT" in _rules(fs)


def test_blocking_repro_waits_need_timeout_kwarg():
    """The repo's own cross-process waits (shm.spin_until, the async
    tier's wait_fragments) are covered — with or without the stdlib
    import gate, as a method or a bare call."""
    fs = check_source(_src("""
        from repro.core import shm

        def drain(ro, pred):
            shm.spin_until(pred)                 # no timeout
            frags = ro.wait_fragments(4)         # no timeout
            return frags
    """))
    assert sum(f.rule == "BLOCKING-NO-TIMEOUT" for f in fs) == 2

    fs = check_source(_src("""
        from repro.core.shm import spin_until

        def drain(ro, pred):
            spin_until(pred, timeout=5.0)
            return ro.wait_fragments(4, timeout=60.0)
    """))
    assert "BLOCKING-NO-TIMEOUT" not in _rules(fs)


def test_nondet_in_pure_on_time_call():
    fs = check_source(_src("""
        import time
        import jax

        @jax.jit
        def stampy(x):
            return x + time.time()
    """))
    assert "NONDET-IN-PURE" in _rules(fs)


def test_donation_reuse_after_donating_call():
    fs = check_source(_src("""
        import jax

        def trainer(ts, batch):
            step = jax.jit(lambda a, b: a + b, donate_argnums=(0,))
            out = step(ts, batch)
            print(ts.mean())
            return out
    """))
    assert "DONATION-REUSE" in _rules(fs)


def test_impure_import_numpy_in_jitted():
    fs = check_source(_src("""
        import numpy as np
        import jax

        @jax.jit
        def mixed(x):
            return np.tanh(x)
    """))
    assert "IMPURE-IMPORT" in _rules(fs)


def test_telemetry_in_jit_flags_span_under_trace():
    fs = check_source(_src("""
        import jax
        from repro.telemetry import span

        @jax.jit
        def instrumented(x):
            with span("learn"):
                return x * 2
    """))
    assert "TELEMETRY-IN-JIT" in _rules(fs)


def test_telemetry_in_jit_flags_aliased_and_scan_body():
    fs = check_source(_src("""
        import jax
        import jax.numpy as jnp
        from repro import telemetry
        from repro.telemetry import span as _span

        def launch():
            def body(c, x):
                telemetry.registry().counter("steps").inc()
                with _span("step"):
                    c = c + x
                return c, x
            return jax.lax.scan(body, 0.0, jnp.ones(3))
    """))
    assert sum(f.rule == "TELEMETRY-IN-JIT" for f in fs) >= 2


def test_telemetry_in_host_loop_is_clean():
    fs = check_source(_src("""
        import jax
        from repro.telemetry import span

        def host_loop(launch, n):
            out = []
            for i in range(n):
                with span("engine.launch"):
                    out.append(launch(i))
            return out
    """))
    assert "TELEMETRY-IN-JIT" not in _rules(fs)


def test_telemetry_in_jit_noqa_suppresses():
    fs = check_source(_src("""
        import jax
        from repro.telemetry import span

        @jax.jit
        def waived(x):
            with span("trace-time-only"):  # repro: noqa[TELEMETRY-IN-JIT]
                return x * 2
    """))
    assert "TELEMETRY-IN-JIT" not in _rules(fs)


# ---------------------------------------------------------------------------
# layer 1: suppression and static lookalikes

def test_noqa_suppresses_named_rule():
    fs = check_source(_src("""
        import jax

        @jax.jit
        def quiet(x):
            if x > 0:                      # repro: noqa[TRACER-BRANCH]
                return x
            return -x
    """))
    assert "TRACER-BRANCH" not in _rules(fs)


def test_bare_noqa_suppresses_everything():
    fs = check_source(_src("""
        import jax

        @jax.jit
        def quiet(x):
            if x > 0:                      # repro: noqa
                return x
            return -x
    """))
    assert not fs


def test_noqa_for_other_rule_does_not_suppress():
    fs = check_source(_src("""
        import jax

        @jax.jit
        def loud(x):
            if x > 0:                      # repro: noqa[HOST-SYNC]
                return x
            return -x
    """))
    assert "TRACER-BRANCH" in _rules(fs)


def test_shape_branch_is_static_and_clean():
    fs = check_source(_src("""
        import jax

        @jax.jit
        def shape_branch(x):
            if x.shape[0] > 2:
                return x
            return x * 2
    """))
    assert not fs


def test_syntax_error_is_a_finding():
    fs = check_source("def broken(:\n")
    assert [f.rule for f in fs] == ["SYNTAX"]


# ---------------------------------------------------------------------------
# baseline round-trip (count-aware multiset)

_TWO_GETS = _src("""
    import queue

    def worker_a(q: "queue.Queue"):
        return q.get()

    def worker_b(q: "queue.Queue"):
        return q.get()
""")

_ONE_GET = _src("""
    import queue

    def worker_a(q: "queue.Queue"):
        return q.get()
""")


def test_baseline_roundtrip(tmp_path):
    fs = check_source(_TWO_GETS, path="w.py")
    assert len(fs) == 2
    bl = tmp_path / "baseline.json"
    save_baseline(fs, bl)
    loaded = load_baseline(bl)
    assert sum(loaded.values()) == 2
    assert apply_baseline(fs, loaded) == []


def test_baseline_is_count_aware(tmp_path):
    bl = tmp_path / "baseline.json"
    save_baseline(check_source(_ONE_GET, path="w.py"), bl)
    fresh = apply_baseline(check_source(_TWO_GETS, path="w.py"),
                           load_baseline(bl))
    assert len(fresh) == 1            # one grandfathered, one fresh


def test_missing_baseline_is_empty(tmp_path):
    assert load_baseline(tmp_path / "nope.json") == {}
    assert load_baseline(None) == {}


# ---------------------------------------------------------------------------
# layer 2: planted audit violations, one per check

def test_audit_clean_function_passes():
    res = audit_fn(lambda x: jnp.tanh(x) * 2.0,
                   (jnp.ones((4,), jnp.float32),),
                   variants=[(jnp.ones((8,), jnp.float32),)],
                   name="clean")
    assert res.ok, [v.render() for v in res.violations]
    assert set(res.checks) == {"host-callback", "f64-promotion", "retrace"}


def test_audit_detects_host_callback():
    def cb(x):
        return jax.pure_callback(
            lambda a: np.asarray(a) * 2,
            jax.ShapeDtypeStruct(x.shape, x.dtype), x)

    res = audit_fn(cb, (jnp.ones((4,), jnp.float32),),
                   check_retrace=False, check_f64=False)
    assert any(v.check == "host-callback" for v in res.violations)


def test_audit_allow_callbacks_whitelist():
    def cb(x):
        return jax.pure_callback(
            lambda a: np.asarray(a) * 2,
            jax.ShapeDtypeStruct(x.shape, x.dtype), x)

    res = audit_fn(cb, (jnp.ones((4,), jnp.float32),),
                   check_retrace=False, check_f64=False,
                   allow_callbacks=("pure_callback",))
    assert res.ok


def test_audit_detects_retrace_on_static_flip():
    def rt(x, flag):
        return x * (2.0 if flag else 3.0)

    x = jnp.ones((4,), jnp.float32)
    res = audit_fn(rt, (x, False), variants=[(x, True)],
                   check_callbacks=False, check_f64=False)
    assert any(v.check == "retrace" for v in res.violations)


def test_audit_no_retrace_across_shape_sweep():
    res = audit_fn(lambda x: x * 2.0, (jnp.ones((4,), jnp.float32),),
                   variants=[(jnp.ones((8,), jnp.float32),)],
                   check_callbacks=False, check_f64=False)
    assert res.ok


def test_audit_detects_unconsumed_donation():
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")   # XLA warns about the same thing
        res = audit_fn(lambda x: jnp.sum(x),
                       (jnp.ones((64,), jnp.float32),),
                       donate_argnums=(0,), check_retrace=False)
    assert any(v.check == "donation" for v in res.violations)


def test_audit_donation_consumed_passes():
    res = audit_fn(lambda x: x + 1.0, (jnp.ones((64,), jnp.float32),),
                   donate_argnums=(0,), check_retrace=False)
    assert res.ok, [v.render() for v in res.violations]
    assert "donation" in res.checks


def test_audit_detects_f64_promotion():
    from jax.experimental import enable_x64

    def widen(x):
        return x.astype(jnp.float64) * 2.0

    with enable_x64():
        res = audit_fn(widen, (jnp.ones((4,), jnp.float32),),
                       check_retrace=False)
    assert any(v.check == "f64-promotion" for v in res.violations)


def test_audit_f64_input_is_allowed():
    from jax.experimental import enable_x64
    with enable_x64():
        res = audit_fn(lambda x: x * 2.0,
                       (jnp.ones((4,), jnp.float64),),
                       check_retrace=False)
    assert res.ok


def test_audit_trace_failure_is_reported():
    res = audit_fn(lambda x: x @ jnp.ones((99, 2)),
                   (jnp.ones((4, 4), jnp.float32),))
    assert any(v.check == "trace" for v in res.violations)


# ---------------------------------------------------------------------------
# target enumeration: coverage must not silently shrink

def test_kernel_coverage_gate(monkeypatch):
    from repro.analysis import targets
    from repro.kernels import dispatch
    monkeypatch.setattr(dispatch, "ops", lambda: ["mystery_op"])
    out = targets.audit_kernel_ops()
    assert len(out) == 1
    assert any(v.check == "coverage" for v in out[0].violations)


def test_audit_bandit_env_clean():
    from repro.analysis import audit_ocean_envs
    (res,) = audit_ocean_envs(["bandit"])
    assert res.ok, [v.render() for v in res.violations]


# ---------------------------------------------------------------------------
# CLI

_BAD = ("import jax\n\n"
        "@jax.jit\n"
        "def f(x):\n"
        "    if x > 0:\n"
        "        return x\n"
        "    return -x\n")


def _cli(*argv):
    env = dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src"))
    return subprocess.run([sys.executable, "-m", "repro.analysis", *argv],
                          capture_output=True, text=True, env=env, cwd=ROOT)


def test_cli_exits_nonzero_on_finding(tmp_path):
    bad = tmp_path / "bad.py"
    bad.write_text(_BAD)
    out = _cli(str(bad))
    assert out.returncode == 1, out.stdout + out.stderr
    assert "TRACER-BRANCH" in out.stdout


def test_cli_report_only_and_json(tmp_path):
    bad = tmp_path / "bad.py"
    bad.write_text(_BAD)
    out = _cli(str(bad), "--report-only", "--format", "json")
    assert out.returncode == 0, out.stdout + out.stderr
    report = json.loads(out.stdout)
    assert any(f["rule"] == "TRACER-BRANCH" for f in report["findings"])
    assert set(RULES) <= set(report["rules"])


def test_cli_baseline_gates(tmp_path):
    bad = tmp_path / "bad.py"
    bad.write_text(_BAD)
    bl = tmp_path / "baseline.json"
    up = _cli(str(bad), "--baseline", str(bl), "--update-baseline")
    assert up.returncode == 0, up.stdout + up.stderr
    out = _cli(str(bad), "--baseline", str(bl))
    assert out.returncode == 0, out.stdout + out.stderr


# ---------------------------------------------------------------------------
# satellites: HostPool stop-polling and recv timeouts

class _TinyEnv:
    def reset(self, seed):
        return np.zeros((1,), np.float32)

    def step(self, a):
        return np.zeros((1,), np.float32), 1.0, False, {}


class _HangEnv:
    """reset() blocks until released — a deadlocked host env."""

    def __init__(self, release):
        self._release = release

    def reset(self, seed):
        self._release.wait(20)
        return np.zeros((1,), np.float32)

    def step(self, a):
        return np.zeros((1,), np.float32), 0.0, False, {}


class _DeadInbox:
    """Inbox whose sentinel can never be delivered nor drained."""

    def get(self, timeout=None):
        raise queue.Empty

    def get_nowait(self):
        raise queue.Empty

    def put_nowait(self, item):
        raise queue.Full


def test_close_joins_workers_with_empty_inbox():
    from repro.core.host import HostPool
    pool = HostPool([_TinyEnv, _TinyEnv], batch_size=2, recv_timeout=5.0)
    pool.recv()                       # drain the initial resets
    pool.close(timeout=3.0)           # workers are parked on empty inboxes
    assert all(not t.is_alive() for t in pool._threads)


def test_stop_flag_wins_when_sentinel_undeliverable():
    """Regression: the worker must poll, not park — with the close sentinel
    undeliverable, only the _stop check can end the loop."""
    from repro.core.host import HostPool
    pool = HostPool([_TinyEnv], batch_size=1, recv_timeout=5.0)
    pool.recv()
    pool._inboxes[0] = _DeadInbox()
    pool.close(timeout=3.0)
    assert not pool._threads[0].is_alive()


def test_recv_uses_pool_default_timeout():
    from repro.core.host import HostPool
    release = threading.Event()
    pool = HostPool([lambda: _HangEnv(release)], batch_size=1,
                    recv_timeout=0.2)
    with pytest.raises(TimeoutError):
        pool.recv()                   # no argument: pool default applies
    release.set()
    pool.close(timeout=3.0)


def test_recv_explicit_timeout_overrides_default():
    from repro.core.host import HostPool
    release = threading.Event()
    pool = HostPool([lambda: _HangEnv(release)], batch_size=1,
                    recv_timeout=None)
    with pytest.raises(TimeoutError):
        pool.recv(timeout=0.2)
    release.set()
    pool.close(timeout=3.0)


def test_wrap_default_timeout_is_trainconfig():
    import inspect
    from repro.bridge.vecenv import wrap
    from repro.configs.base import TrainConfig
    default = inspect.signature(wrap).parameters["recv_timeout"].default
    assert default == TrainConfig.host_recv_timeout
    assert default is not None        # hung host envs raise, not deadlock


def test_hostvecenv_reset_times_out_on_hung_env():
    from repro.bridge.vecenv import wrap
    from repro.core import spaces as sp

    class _HangDuck(_HangEnv):
        def __init__(self, release):
            super().__init__(release)
            self.observation_space = sp.Box((1,))
            self.action_space = sp.Discrete(2)

    release = threading.Event()
    hv = wrap(lambda: _HangDuck(release), num_envs=1, api="duck",
              recv_timeout=0.25)
    with pytest.raises(TimeoutError):
        hv.reset()
    release.set()
    hv.close(timeout=3.0)
