"""Per-kernel correctness: Pallas (interpret mode) vs pure-jnp oracle,
swept across shapes and dtypes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


def _rand(key, shape, dtype=jnp.float32, scale=1.0):
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


@pytest.mark.parametrize("B,T,H,K,hd,bq,bk", [
    (1, 32, 2, 2, 16, 16, 16),      # MHA
    (2, 64, 4, 2, 32, 32, 32),      # GQA 2:1
    (1, 128, 8, 2, 64, 128, 64),    # GQA 4:1, uneven blocks
    (2, 64, 4, 1, 32, 16, 64),      # MQA
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(B, T, H, K, hd, bq, bk, dtype):
    k0 = jax.random.PRNGKey(B * T + H)
    q = _rand(k0, (B, T, H, hd), dtype)
    k = _rand(jax.random.fold_in(k0, 1), (B, T, K, hd), dtype)
    v = _rand(jax.random.fold_in(k0, 2), (B, T, K, hd), dtype)
    want = ref.flash_attention(q, k, v, causal=True)
    got = ops.flash_attention(q, k, v, causal=True, mode="interpret",
                              block_q=bq, block_k=bk)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=tol, rtol=tol)


def test_flash_attention_chunked_matches():
    k0 = jax.random.PRNGKey(7)
    q = _rand(k0, (2, 64, 4, 32))
    k = _rand(jax.random.fold_in(k0, 1), (2, 64, 2, 32))
    v = _rand(jax.random.fold_in(k0, 2), (2, 64, 2, 32))
    np.testing.assert_allclose(
        np.asarray(ref.flash_attention_chunked(q, k, v, block_k=16)),
        np.asarray(ref.flash_attention(q, k, v)), atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("B,T,H,hd,ds,chunk", [
    (1, 16, 1, 8, 8, 4),
    (2, 64, 3, 16, 32, 16),
    (1, 128, 2, 32, 16, 64),
])
def test_ssd_sweep(B, T, H, hd, ds, chunk):
    k0 = jax.random.PRNGKey(T + H)
    x = _rand(k0, (B, T, H, hd), scale=0.5)
    dt = jax.nn.softplus(_rand(jax.random.fold_in(k0, 1), (B, T, H)))
    A = -jnp.exp(_rand(jax.random.fold_in(k0, 2), (H,), scale=0.3))
    B_ = _rand(jax.random.fold_in(k0, 3), (B, T, H, ds), scale=0.5)
    C = _rand(jax.random.fold_in(k0, 4), (B, T, H, ds), scale=0.5)
    y_ref, h_ref = ref.ssd(x, dt, A, B_, C)
    y_pl, h_pl = ops.ssd(x, dt, A, B_, C, chunk=chunk, mode="interpret")
    np.testing.assert_allclose(np.asarray(y_pl), np.asarray(y_ref),
                               atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(h_pl), np.asarray(h_ref),
                               atol=1e-4, rtol=1e-4)
    # chunked-jnp twin (the dry-run stand-in) must match too
    y_ch, h_ch = ref.ssd_chunked(x, dt, A, B_, C, chunk=chunk)
    np.testing.assert_allclose(np.asarray(y_ch), np.asarray(y_ref),
                               atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(h_ch), np.asarray(h_ref),
                               atol=1e-4, rtol=1e-4)


@pytest.mark.parametrize("B,T,bt", [(4, 32, 8), (8, 128, 128), (2, 64, 16)])
@pytest.mark.parametrize("done_p", [0.0, 0.1, 0.5])
def test_gae_sweep(B, T, bt, done_p):
    k0 = jax.random.PRNGKey(B + T)
    r = _rand(k0, (B, T))
    v = _rand(jax.random.fold_in(k0, 1), (B, T))
    d = jax.random.bernoulli(jax.random.fold_in(k0, 2), done_p, (B, T))
    lv = _rand(jax.random.fold_in(k0, 3), (B,))
    want = ref.gae(r, v, d, lv, 0.99, 0.95)
    got = ops.gae(r, v, d, lv, 0.99, 0.95, mode="interpret", block_t=bt)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-5, rtol=1e-5)


def test_gae_matches_python_reference():
    """Oracle vs an independent step-by-step python implementation."""
    rng = np.random.RandomState(0)
    B, T, g, lam = 3, 20, 0.9, 0.8
    r = rng.randn(B, T).astype(np.float32)
    v = rng.randn(B, T).astype(np.float32)
    d = rng.rand(B, T) < 0.2
    lv = rng.randn(B).astype(np.float32)
    adv = np.zeros((B, T), np.float32)
    for b in range(B):
        a = 0.0
        for t in reversed(range(T)):
            nt = 1.0 - float(d[b, t])
            vn = lv[b] if t == T - 1 else v[b, t + 1]
            delta = r[b, t] + g * vn * nt - v[b, t]
            a = delta + g * lam * nt * a
            adv[b, t] = a
    got = ref.gae(jnp.asarray(r), jnp.asarray(v), jnp.asarray(d),
                  jnp.asarray(lv), g, lam)
    np.testing.assert_allclose(np.asarray(got), adv, atol=1e-5, rtol=1e-5)


@pytest.mark.parametrize("sizes", [(3,), (3, 7, 16), (1, 1, 1, 128)])
@pytest.mark.parametrize("B", [4, 8])
def test_pack_sweep(sizes, B):
    k0 = jax.random.PRNGKey(sum(sizes))
    leaves = [jax.random.randint(jax.random.fold_in(k0, i), (B, n), 0, 256,
                                 jnp.int32).astype(jnp.uint8)
              for i, n in enumerate(sizes)]
    want = ref.pack(leaves)
    got = ops.pack(leaves, mode="interpret")
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("qdtype,qmax", [(jnp.int8, 127), (jnp.int4, 7)])
@pytest.mark.parametrize("M,K,N,bm,bk", [(32, 64, 128, 16, 32),
                                         (64, 128, 128, 64, 64)])
def test_quant_matmul_sweep(qdtype, qmax, M, K, N, bm, bk):
    k0 = jax.random.PRNGKey(M + N)
    x = _rand(k0, (M, K))
    wq = jax.random.randint(jax.random.fold_in(k0, 1), (K, N), -qmax,
                            qmax + 1, jnp.int32).astype(qdtype)
    s = jnp.abs(_rand(jax.random.fold_in(k0, 2), (N,))) * 0.02
    want = ref.quant_matmul(x, wq, s)
    got = ops.quant_matmul(x, wq, s, mode="interpret")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-4, rtol=1e-4)


@pytest.mark.parametrize("B,H,K,hd,S,bs", [
    (2, 4, 2, 32, 64, 16),     # GQA 2:1
    (1, 8, 2, 64, 128, 32),    # GQA 4:1
    (3, 4, 4, 16, 64, 64),     # MHA, single block
    (2, 4, 1, 32, 96, 32),     # MQA
])
@pytest.mark.parametrize("frac", [0.0, 0.6, 1.0])
def test_flash_decode_sweep(B, H, K, hd, S, bs, frac):
    """Decode attention kernel vs oracle across GQA ratios and cache fills."""
    k0 = jax.random.PRNGKey(B * S + H)
    q = _rand(k0, (B, H, hd))
    k = _rand(jax.random.fold_in(k0, 1), (B, S, K, hd))
    v = _rand(jax.random.fold_in(k0, 2), (B, S, K, hd))
    L = jnp.asarray(int(frac * (S - 1)), jnp.int32)
    want = ref.flash_decode(q, k, v, L)
    got = ops.flash_decode(q, k, v, L, mode="interpret", block_s=bs)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=2e-5)
