"""Conformance harness: every registered Ocean env passes with zero
violations, and deliberately broken envs are caught by the right check —
the harness is only trustworthy if it fails when it should."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import spaces as sp
from repro.envs.conformance import (CHECKS, ConformanceReport, check_env)
from repro.envs.ocean import OCEAN, Bandit, Maze


# -- the registry suite (auto-discovers new envs as they are registered) ------

@pytest.mark.parametrize("name", sorted(OCEAN))
def test_registry_env_conforms(name):
    report = check_env(name)
    assert report.ok, "\n" + report.summary()
    assert len(report.results) == len(CHECKS)


def test_report_summary_readable():
    report = check_env("bandit")
    s = report.summary()
    assert "bandit" in s and "OK" in s and "[pass] jit_purity" in s


def test_check_subset_and_instance():
    """Library API: pass an instance and restrict the checks."""
    report = check_env(Bandit(), checks=["determinism", "score_bounds"])
    assert report.ok and len(report.results) == 2
    assert report.env_name == "Bandit"


# -- broken envs must be caught ----------------------------------------------

class _Wrapped:
    """Pass-through base: subclass and break one invariant."""

    def __init__(self, env):
        self._env = env
        self.observation_space = env.observation_space
        self.action_space = env.action_space
        self.num_agents = env.num_agents
        self.horizon = getattr(env, "horizon", 64)

    def init(self, key):
        return self._env.init(key)

    def reset(self, state, key):
        return self._env.reset(state, key)

    def step(self, state, action, key):
        return self._env.step(state, action, key)


def _violations(report: ConformanceReport, check: str):
    return next(r for r in report.results if r.name == check).violations


def test_catches_unnormalized_score():
    class BadScore(_Wrapped):
        def step(self, state, action, key):
            s, obs, rew, done, info = super().step(state, action, key)
            info = dict(info, score=info["score"] * 10.0 + 5.0)
            return s, obs, rew, done, info

    report = check_env(BadScore(Bandit()))
    assert not report.ok
    assert any("outside [0, 1]" in v
               for v in _violations(report, "score_bounds"))


def test_catches_nondeterministic_step():
    class Impure(_Wrapped):
        def step(self, state, action, key):
            # host-side RNG leaking into the obs: same (state, action, key)
            # gives different outputs — invisible once jitted (the constant
            # is baked into the trace), so the check must compare unjitted
            s, obs, rew, done, info = super().step(state, action, key)
            return s, obs + np.random.randn(), rew, done, info

    report = check_env(Impure(Bandit()), checks=["determinism"])
    assert not report.ok
    assert any("not deterministic" in v
               for v in _violations(report, "determinism"))


def test_catches_trace_failure():
    class Untraceable(_Wrapped):
        def step(self, state, action, key):
            # host branching on a live value: concretization error under jit
            if float(jnp.sum(action)) > 1e9:
                return super().step(state, action, key)
            return super().step(state, action, key)

    report = check_env(Untraceable(Bandit()), checks=["jit_purity"])
    assert not report.ok
    assert any("failed under jit" in v
               for v in _violations(report, "jit_purity"))


def test_catches_retrace():
    class DtypeDrift(_Wrapped):
        def step(self, state, action, key):
            # the returned state's dtype differs from the input state's, so
            # feeding step's output back in changes the arg signature and
            # every single step retraces — the silent recompile treadmill
            s, obs, rew, done, info = super().step(state, action, key)
            s = dict(s, t=s["t"].astype(jnp.float32))
            return s, obs, rew, done, info

    report = check_env(DtypeDrift(Bandit()), checks=["jit_purity"])
    assert not report.ok
    assert any("retraced" in v for v in _violations(report, "jit_purity"))


def test_catches_host_callback_in_branch():
    """A host callback hidden inside a lax.cond branch must still be found —
    cond's branches live in a tuple-valued jaxpr param."""
    class CallbackInBranch(_Wrapped):
        def step(self, state, action, key):
            s, obs, rew, done, info = super().step(state, action, key)
            rew = jax.lax.cond(
                done,
                lambda r: jax.pure_callback(
                    lambda x: np.asarray(x, np.float32),
                    jax.ShapeDtypeStruct((), jnp.float32), r),
                lambda r: r,
                rew)
            return s, obs, rew, done, info

    report = check_env(CallbackInBranch(Bandit()), checks=["jit_purity"])
    assert not report.ok
    assert any("host callbacks" in v
               for v in _violations(report, "jit_purity"))


def test_catches_shape_instability():
    class Unstable(_Wrapped):
        def step(self, state, action, key):
            s, obs, rew, done, info = super().step(state, action, key)
            # obs grows with t — shapes must be static for the fused scan
            t = int(np.asarray(state["t"]))
            obs = jnp.concatenate([jnp.atleast_1d(obs)] * (t + 1))
            return s, obs, rew, done, info

    report = check_env(Unstable(Bandit()),
                       checks=["stability"])
    assert not report.ok


def test_catches_agent_axis_scramble():
    from repro.envs.ocean import Multiagent

    class Scrambled(_Wrapped):
        def step(self, state, action, key):
            s, obs, rew, done, info = super().step(state, action, key)
            # flattened the agent axis away — downstream batching would
            # silently misalign agents and rewards
            return s, obs.reshape(-1), jnp.sum(rew), done, info

    report = check_env(Scrambled(Multiagent()), checks=["agent_axis"])
    assert not report.ok
    vs = "\n".join(_violations(report, "agent_axis"))
    assert "num_agents" in vs and "reward shape" in vs


def test_catches_stale_procgen_key():
    class StaleKey(_Wrapped):
        def init(self, key):
            # ignores the episode key — every maze is the same maze, but a
            # fixed folded key still *looks* random to a shape check
            return self._env.init(jax.random.PRNGKey(1234))

    report = check_env(StaleKey(Maze()), checks=["procgen_keys"])
    # init is now key-independent, which reads as a static env — the check
    # must treat that as conforming only when init truly ignores keys, and
    # StaleKey does, so this passes; the real stale-key bug (fresh init,
    # stale reset) is caught below
    assert report.ok

    class StaleReset(_Wrapped):
        def reset(self, state, key):
            return self._env.reset(state, jax.random.PRNGKey(1234))

    report = check_env(StaleReset(Maze()), checks=["procgen_keys"])
    assert not report.ok
    assert any("stale" in v for v in _violations(report, "procgen_keys"))


def test_catches_never_terminating_env():
    class Endless(_Wrapped):
        def step(self, state, action, key):
            s, obs, rew, done, info = super().step(state, action, key)
            return s, obs, rew, jnp.zeros((), jnp.bool_), info

    report = check_env(Endless(Bandit()),
                       checks=["autoreset", "score_bounds"])
    assert not report.ok


def test_check_that_raises_is_reported_not_crashed():
    class Exploding(_Wrapped):
        def init(self, key):
            raise RuntimeError("boom")

    report = check_env(Exploding(Bandit()))
    assert not report.ok
    assert any("boom" in v or "RuntimeError" in v
               for v in report.violations)


# -- selfplay (competitive-env) profile ---------------------------------------

def test_duel_passes_selfplay_profile():
    from repro.envs.conformance import SELFPLAY_CHECKS, check_selfplay_env
    report = check_selfplay_env("duel")
    assert report.ok, "\n" + report.summary()
    assert len(report.results) == len(SELFPLAY_CHECKS)
    assert report.env_name == "selfplay/duel"


def test_selfplay_profile_catches_broken_zero_sum():
    """A per-step bonus paid to both sides breaks the zero-sum invariant
    and must be caught by exactly that check."""
    from repro.envs.conformance import check_selfplay_env
    from repro.envs.ocean import Duel

    class LeakyDuel(_Wrapped):
        def __init__(self):
            super().__init__(Duel())
            self.swap_agents = self._env.swap_agents

        def step(self, state, action, key):
            s, obs, rew, done, info = self._env.step(state, action, key)
            return s, obs, rew + 0.01, done, info      # both rows gain

    report = check_selfplay_env(LeakyDuel())
    assert not report.ok
    assert any("zero-sum" in v for v in _violations(report, "zero_sum"))


def test_selfplay_profile_catches_role_asymmetry():
    """An env that pays a positional bonus to agent row 0 is not symmetric
    under the agent-row permutation — the role_swap check must flag it."""
    from repro.envs.conformance import check_selfplay_env
    from repro.envs.ocean import Duel

    class HomeAdvantageDuel(_Wrapped):
        def __init__(self):
            super().__init__(Duel())
            self.swap_agents = self._env.swap_agents

        def step(self, state, action, key):
            s, obs, rew, done, info = self._env.step(state, action, key)
            bonus = jnp.asarray([0.01, -0.01])         # row 0 always favored
            return s, obs, rew + bonus, done, info

    report = check_selfplay_env(HomeAdvantageDuel())
    assert not report.ok
    assert any("row-reversed reward" in v
               for v in _violations(report, "role_swap"))


def test_selfplay_profile_requires_swap_agents():
    from repro.envs.conformance import check_selfplay_env
    from repro.envs.ocean import Duel

    class NoSwap(_Wrapped):
        def __init__(self):
            super().__init__(Duel())

    report = check_selfplay_env(NoSwap())
    assert any("swap_agents" in v for v in _violations(report, "role_swap"))


def test_selfplay_profile_catches_per_agent_done():
    from repro.envs.conformance import check_selfplay_env
    from repro.envs.ocean import Duel

    class PerAgentDone(_Wrapped):
        def __init__(self):
            super().__init__(Duel())
            self.swap_agents = self._env.swap_agents

        def step(self, state, action, key):
            s, obs, rew, done, info = self._env.step(state, action, key)
            return s, obs, rew, jnp.stack([done, done]), info

    report = check_selfplay_env(PerAgentDone())
    assert any("episode-scoped scalar done" in v
               for v in _violations(report, "team_done"))


def test_selfplay_profile_rejects_single_agent_env():
    from repro.envs.conformance import check_selfplay_env
    report = check_selfplay_env("bandit")
    assert any("multi-agent" in v for v in _violations(report, "zero_sum"))


def test_selfplay_cli_lane():
    """--selfplay routes the conformance CLI through the league profile."""
    from repro.envs.conformance import run_cli
    assert run_cli("duel", selfplay=True) == 0
