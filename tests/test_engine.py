"""TrainEngine: fused multi-update scan, shard_map data-parallel tier,
pool tier, and the launch-boundary run loop."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import TrainConfig
from repro.core.emulation import Emulated
from repro.models.policy import OceanPolicy
from repro.rl.distributions import Dist
from repro.rl.engine import TrainEngine, METRIC_KEYS, pack_metrics, \
    unpack_metrics

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

TCFG = TrainConfig(num_envs=16, unroll_length=16, update_epochs=2,
                   num_minibatches=2, learning_rate=1e-3, gamma=0.95)


def _build(env, tcfg=TCFG, backend="jit", recurrent=False, num_shards=1,
           seed=0, updates_per_launch=None):
    em = Emulated(env)
    dist = Dist("categorical", nvec=em.act_spec.nvec)
    pol = OceanPolicy(em.obs_spec.total, dist.nvec, hidden=32,
                      recurrent=recurrent, num_outputs=dist.num_outputs)
    return TrainEngine(em, pol, tcfg, dist, key=jax.random.PRNGKey(seed),
                       backend=backend, kernel_mode="ref",
                       num_shards=num_shards,
                       updates_per_launch=updates_per_launch)


def _sequential_reference(engine, k):
    """Replay engine.run's first-launch key schedule, one jitted update at a
    time (the pre-engine dispatch pattern)."""
    key = jax.random.PRNGKey(0)
    _, sub = jax.random.split(key)
    uks = engine.update_keys(sub, k)
    upd = jax.jit(engine._update)
    ts, rc, rows = engine.ts, engine.rc, []
    for i in range(k):
        ts, rc, m = upd(ts, rc, uks[i])
        rows.append({kk: float(m[kk]) for kk in METRIC_KEYS})
    return ts, rows


def test_fused_scan_matches_sequential_updates():
    """K=8 in one lax.scan launch == 8 one-at-a-time dispatches: identical
    params and identical per-update metrics."""
    from repro.envs.ocean import Bandit
    ref = _build(Bandit())
    ts_ref, rows_ref = _sequential_reference(ref, 8)

    fused = _build(Bandit(), updates_per_launch=8)
    hist, _ = fused.run(8 * fused.steps_per_update)
    assert len(hist) == 8

    for a, b in zip(jax.tree.leaves(ts_ref.params),
                    jax.tree.leaves(fused.ts.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-6, atol=1e-7)
    for r_ref, r in zip(rows_ref, hist):
        for k in METRIC_KEYS:
            np.testing.assert_allclose(r_ref[k], r[k], rtol=1e-5,
                                       atol=1e-6, err_msg=k)


def test_recurrent_engine_carry_threading():
    """Memory env (LSTM policy): the policy carry must thread through the
    K-update scan exactly as through sequential updates."""
    from repro.envs.ocean import Memory
    ref = _build(Memory(), recurrent=True)
    ts_ref, rows_ref = _sequential_reference(ref, 4)

    fused = _build(Memory(), recurrent=True, updates_per_launch=4)
    hist, _ = fused.run(4 * fused.steps_per_update)
    assert len(hist) == 4
    # the carry the next launch would start from is a live (B, hidden) pair
    c, h = fused.rc.policy_carry
    assert c.shape == (TCFG.num_envs, 32) and bool(jnp.all(jnp.isfinite(h)))

    for a, b in zip(jax.tree.leaves(ts_ref.params),
                    jax.tree.leaves(fused.ts.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(rows_ref[-1]["loss"], hist[-1]["loss"],
                               rtol=1e-4, atol=1e-5)


def test_partial_tail_launch_and_accounting():
    """num_updates not divisible by K: the tail compiles a shorter launch
    and the history covers exactly total_steps // steps_per_update rows."""
    from repro.envs.ocean import Bandit
    e = _build(Bandit(), updates_per_launch=4)
    hist, solved = e.run(6 * e.steps_per_update)
    assert solved is None
    assert len(hist) == 6
    assert [h["env_steps"] for h in hist] == \
        [(i + 1) * e.steps_per_update for i in range(6)]
    assert sorted(e._launches) == [2, 4]


def test_target_score_checked_at_launch_boundaries():
    from repro.envs.ocean import Bandit
    e = _build(Bandit(), updates_per_launch=4)
    hist, solved = e.run(400 * e.steps_per_update, target_score=0.5)
    assert solved is not None and solved["score"] >= 0.5
    # stopped at a launch boundary, far short of the full budget
    assert len(hist) < 400 and len(hist) % 4 == 0


def test_pool_tier_runs_and_accounts():
    from repro.envs.ocean import Bandit
    tcfg = TrainConfig(num_envs=16, unroll_length=16, update_epochs=2,
                       num_minibatches=2, learning_rate=1e-3, gamma=0.95,
                       pool_buffers=3)
    e = _build(Bandit(), tcfg=tcfg, backend="pool")
    hist, _ = e.run(6 * e.steps_per_update)
    assert len(hist) == 6
    assert hist[-1]["env_steps"] == 6 * e.steps_per_update
    assert all(np.isfinite(h["loss"]) for h in hist)


def test_pool_tier_recurrent_learns_shapes():
    from repro.envs.ocean import Memory
    tcfg = TrainConfig(num_envs=8, unroll_length=16, update_epochs=1,
                       num_minibatches=2, learning_rate=1e-3, gamma=0.95,
                       pool_buffers=2)
    e = _build(Memory(), tcfg=tcfg, backend="pool", recurrent=True)
    hist, _ = e.run(4 * e.steps_per_update)
    assert len(hist) == 4 and np.isfinite(hist[-1]["loss"])


def test_minibatch_mismatch_raises_value_error():
    """The old bare assert is now a ValueError naming the offending knobs."""
    from repro.envs.ocean import Bandit
    tcfg = TrainConfig(num_envs=10, unroll_length=7, update_epochs=1,
                       num_minibatches=4)
    e = _build(Bandit(), tcfg=tcfg)
    with pytest.raises(ValueError) as ei:
        e.run(e.steps_per_update)
    msg = str(ei.value)
    assert "num_minibatches=4" in msg and "num_envs=10" in msg \
        and "unroll_length=7" in msg


def test_pool_tier_reusable_after_early_exit():
    """Early exit on target_score must leave the pool protocol clean (every
    recv answered by a send) so the engine can keep training."""
    from repro.envs.ocean import Bandit
    tcfg = TrainConfig(num_envs=16, unroll_length=16, update_epochs=2,
                       num_minibatches=2, learning_rate=1e-3, gamma=0.95)
    e = _build(Bandit(), tcfg=tcfg, backend="pool")
    hist, solved = e.run(200 * e.steps_per_update, target_score=0.3)
    assert solved is not None
    hist2, _ = e.run(2 * e.steps_per_update)    # would assert pre-fix
    assert len(hist2) == 2


def test_engine_config_validation():
    from repro.envs.ocean import Bandit
    with pytest.raises(ValueError, match="num_shards"):
        _build(Bandit(), num_shards=3)          # 16 envs % 3 != 0
    with pytest.raises(ValueError, match="pool tier"):
        _build(Bandit(), backend="pool", updates_per_launch=4)
    with pytest.raises(ValueError, match="backend"):
        _build(Bandit(), backend="nope")


def test_trainer_logs_once_per_launch(tmp_path):
    from repro.envs.ocean import Bandit
    from repro.rl.trainer import Trainer
    from repro.utils import metrics as ml
    tcfg = TrainConfig(num_envs=16, unroll_length=16, update_epochs=1,
                       num_minibatches=2, learning_rate=1e-3, gamma=0.95,
                       updates_per_launch=4)
    tr = Trainer(Bandit(), tcfg, hidden=32, kernel_mode="ref",
                 log_dir=str(tmp_path))
    tr.train(8 * tr.steps_per_update)
    rows = ml.read(tr.logger.path)
    assert len(rows) == 8
    assert [r["step"] for r in rows] == \
        [(i + 1) * tr.steps_per_update for i in range(8)]


def test_metrics_ring_pack_unpack_roundtrip():
    m = {k: float(i) for i, k in enumerate(METRIC_KEYS)}
    row = pack_metrics(m)
    assert row.shape == (len(METRIC_KEYS),)
    assert unpack_metrics(np.asarray(row)) == m


SHARD_PARITY = r"""
import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=8")
import jax, numpy as np
from repro.envs.ocean import Bandit
from repro.core.emulation import Emulated
from repro.models.policy import OceanPolicy
from repro.rl.distributions import Dist
from repro.rl.engine import TrainEngine
from repro.configs.base import TrainConfig

assert jax.device_count() == 8
tcfg = TrainConfig(num_envs=16, unroll_length=16, update_epochs=2,
                   num_minibatches=2, learning_rate=1e-3, gamma=0.95,
                   updates_per_launch=3)

def build(backend, num_shards=1):
    em = Emulated(Bandit())
    dist = Dist("categorical", nvec=em.act_spec.nvec)
    pol = OceanPolicy(em.obs_spec.total, dist.nvec, hidden=32,
                      num_outputs=dist.num_outputs)
    return TrainEngine(em, pol, tcfg, dist, key=jax.random.PRNGKey(0),
                       backend=backend, kernel_mode="ref",
                       num_shards=num_shards)

single = build("jit", num_shards=8)
h1, _ = single.run(6 * single.steps_per_update)
sharded = build("shard_map")
assert sharded.num_shards == 8
h8, _ = sharded.run(6 * sharded.steps_per_update)

for a, b in zip(jax.tree.leaves(jax.device_get(single.ts.params)),
                jax.tree.leaves(jax.device_get(sharded.ts.params))):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=1e-5, atol=1e-6)
for r1, r8 in zip(h1, h8):
    np.testing.assert_allclose(r1["loss"], r8["loss"], rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(r1["score"], r8["score"], rtol=1e-4, atol=1e-5)
print("SHARD_PARITY_OK")
"""


@pytest.mark.multi_device
def test_shard_map_tier_seed_matched_parity():
    """8-way shard_map data-parallel PPO is seed-matched with the
    single-device run (same rollout randomness via global-env-index keys,
    same minibatch composition via per-block permutations, pmean'd grads):
    final params agree to float reduction order."""
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run([sys.executable, "-c", SHARD_PARITY],
                         capture_output=True, text=True, env=env, cwd=ROOT,
                         timeout=560)
    assert "SHARD_PARITY_OK" in out.stdout, out.stderr[-3000:]


def test_shard_map_tier_runs_on_available_devices():
    """Direct (non-subprocess) shard_map run on whatever mesh this process
    has — S=1 degenerates to plain data-parallel over one device; the CI
    multi-device job runs this with 8 forced host devices."""
    from repro.envs.ocean import Squared
    if TCFG.num_envs % jax.device_count():
        pytest.skip("num_envs not divisible by device count")
    e = _build(Squared(), backend="shard_map", updates_per_launch=2)
    hist, _ = e.run(4 * e.steps_per_update)
    assert len(hist) == 4 and np.isfinite(hist[-1]["loss"])


@pytest.mark.parametrize("backend", ["jit", "shard_map", "pool"])
@pytest.mark.parametrize("name", ["pong", "drone", "tagteam", "maze"])
def test_ocean_ii_envs_run_on_every_tier(name, backend):
    """Each Ocean II env steps + learns under all three engine tiers — the
    'plays nice' claim holds for pixel obs (CNN frontend), multi-dim
    Gaussian actions, padded multi-agent rows, and procgen state alike."""
    from repro.envs.ocean import OCEAN
    from repro.rl.trainer import Trainer
    tcfg = TrainConfig(num_envs=8, unroll_length=8, update_epochs=1,
                       num_minibatches=2, learning_rate=1e-3, gamma=0.95,
                       engine_backend=backend)
    if backend == "shard_map" and 8 % jax.device_count():
        pytest.skip("num_envs not divisible by device count")
    tr = Trainer(OCEAN[name](), tcfg, hidden=16, kernel_mode="ref")
    m = tr.train(2 * tr.steps_per_update)
    assert len(tr.history) == 2
    assert np.isfinite(m["loss"]) and np.isfinite(m["entropy"])
    if name == "pong":
        assert tr.policy.conv_shape == (6, 6)   # CNN frontend engaged


# ===================== periodic checkpointing + resume =======================

CKPT_TCFG = TrainConfig(num_envs=16, unroll_length=16, update_epochs=2,
                        num_minibatches=2, learning_rate=1e-3, gamma=0.95,
                        checkpoint_every=3)


def test_resume_parity_jit(tmp_path):
    """Interrupted-then-resumed == uninterrupted, bitwise: the checkpoint
    carries TrainState + RNG key + rollout carry, so the resumed engine
    replays exactly the launches the uninterrupted one would have run."""
    from repro.envs.ocean import Bandit
    a = _build(Bandit(), tcfg=CKPT_TCFG)
    a.run(6 * a.steps_per_update)

    b = _build(Bandit(), tcfg=CKPT_TCFG)
    b.checkpoint_dir = str(tmp_path)
    hist_b, _ = b.run(3 * b.steps_per_update)     # "interrupted" at update 3
    assert len(hist_b) == 3

    c = _build(Bandit(), tcfg=CKPT_TCFG, seed=1)  # seed irrelevant: restored
    c.checkpoint_dir = str(tmp_path)
    assert c.restore() == 3
    hist_c, _ = c.run(6 * c.steps_per_update)
    assert len(hist_c) == 3                       # only the remaining updates
    assert hist_c[0]["env_steps"] == 4 * c.steps_per_update
    for x, y in zip(jax.tree.leaves(a.ts.params), jax.tree.leaves(c.ts.params)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_resume_parity_recurrent_and_fused(tmp_path):
    """Same bitwise property with an LSTM policy and K=2 fused launches —
    the policy carry and the launch-boundary key schedule both restore."""
    from repro.envs.ocean import Memory
    a = _build(Memory(), tcfg=CKPT_TCFG, recurrent=True,
               updates_per_launch=2)
    a.run(6 * a.steps_per_update)

    b = _build(Memory(), tcfg=CKPT_TCFG, recurrent=True,
               updates_per_launch=2)
    b.checkpoint_dir = str(tmp_path)
    b.run(4 * b.steps_per_update)                 # checkpoints at update 4
    c = _build(Memory(), tcfg=CKPT_TCFG, recurrent=True,
               updates_per_launch=2, seed=9)
    c.checkpoint_dir = str(tmp_path)
    assert c.restore() == 4
    c.run(6 * c.steps_per_update)
    for x, y in zip(jax.tree.leaves(a.ts.params), jax.tree.leaves(c.ts.params)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_checkpoint_cadence_and_gc(tmp_path):
    """Saves fire every checkpoint_every updates at the launch boundary and
    the ring keeps tcfg.keep_checkpoints newest."""
    import dataclasses
    from repro.envs.ocean import Bandit
    from repro.checkpoint import ckpt
    tcfg = dataclasses.replace(CKPT_TCFG, checkpoint_every=2,
                               keep_checkpoints=2)
    e = _build(Bandit(), tcfg=tcfg)
    e.checkpoint_dir = str(tmp_path)
    e.run(7 * e.steps_per_update)
    steps = sorted(int(d.split("_")[1]) for d in os.listdir(tmp_path)
                   if d.startswith("step_") and not d.endswith(".tmp"))
    assert steps == [4, 6]                        # 2 kept of 2,4,6
    assert ckpt.latest(str(tmp_path)).endswith("step_6")


def test_pool_tier_checkpoints_and_resumes(tmp_path):
    """The pool tier saves TrainState + key (its env state is host-side)
    and a fresh engine resumes from the restored update count."""
    from repro.envs.ocean import Bandit
    e = _build(Bandit(), tcfg=CKPT_TCFG, backend="pool")
    e.checkpoint_dir = str(tmp_path)
    hist, _ = e.run(4 * e.steps_per_update)
    assert len(hist) == 4 and os.path.isdir(tmp_path / "step_3")

    e2 = _build(Bandit(), tcfg=CKPT_TCFG, backend="pool")
    e2.checkpoint_dir = str(tmp_path)
    assert e2.restore() == 3
    hist2, _ = e2.run(5 * e2.steps_per_update)
    assert len(hist2) == 2                        # updates 3 and 4
    assert hist2[0]["env_steps"] == 4 * e2.steps_per_update


def test_trainer_resume_flag(tmp_path):
    """Trainer.train(checkpoint_dir=..., resume=True) restores the newest
    committed engine checkpoint and continues the update count."""
    from repro.envs.ocean import Bandit
    from repro.rl.trainer import Trainer
    tr = Trainer(Bandit(), CKPT_TCFG, hidden=32, kernel_mode="ref")
    tr.train(3 * tr.steps_per_update, checkpoint_dir=str(tmp_path))

    tr2 = Trainer(Bandit(), CKPT_TCFG, hidden=32, kernel_mode="ref")
    m = tr2.train(6 * tr2.steps_per_update, checkpoint_dir=str(tmp_path),
                  resume=True)
    assert len(tr2.history) == 3                  # updates 3..5 only
    assert m["env_steps"] == 6 * tr2.steps_per_update
