"""HostBridge: wrap() API detection, HostPool hardening (crash propagation,
seeded autoreset, close), first-finisher batching — all parametrized over
the ``thread`` and shared-memory ``proc`` backends — plus the conformance
host profile and the TrainEngine ``host`` tier (incl. JAX-vs-host parity
training). Every blocking call carries a timeout so a regression can never
hang the suite."""
import functools
import os
import subprocess
import sys
import time
from pathlib import Path

import jax
import numpy as np
import pytest

# module-level (picklable into spawn workers without importing this
# jax-loading test module); pytest puts tests/ on sys.path
from host_envs import CrashyEnv, JitterEnv, SlowEnv

from repro.bridge import (convert_space, detect_api, make_host_engine,
                          np_emulate_obs, np_unemulate_action, wrap)
from repro.configs.base import TrainConfig
from repro.core import emulation as em
from repro.core import shm
from repro.core import spaces as sp
from repro.core.host import HostEnvError, HostPool, ProcHostPool
from repro.envs.ocean_host import (OCEAN_HOST, HostBandit, HostCrafterLite,
                                   HostDrone, HostSquared, HostTeam)

RECV_T = 30.0          # generous per-call bound; hit only on regressions
BACKENDS = ("thread", "proc")

TCFG = TrainConfig(num_envs=8, unroll_length=8, update_epochs=1,
                   num_minibatches=2, learning_rate=1e-3, gamma=0.95)


def workers_dead(pool) -> bool:
    ws = pool._procs if isinstance(pool, ProcHostPool) else pool._threads
    return not any(w.is_alive() for w in ws)


# ---------------------------------------------------------------------------
# wrap(): API detection + space conversion

def test_detect_api_three_styles():
    assert detect_api(HostBandit()) == "duck"
    assert detect_api(HostSquared()) == "duck"
    assert detect_api(HostDrone()) == "gymnasium"
    assert detect_api(HostTeam()) == "pettingzoo"


def test_convert_space_duck_objects():
    class N:                     # gymnasium-shaped duck objects
        n = 5

    class MD:
        nvec = np.array([2, 3])

    class B:
        shape, dtype = (4, 2), np.float32
        low, high = -1.0, 1.0

    assert convert_space(N()) == sp.Discrete(5)
    assert convert_space(MD()) == sp.MultiDiscrete((2, 3))
    b = convert_space(B())
    assert isinstance(b, sp.Box) and b.shape == (4, 2)
    assert convert_space(sp.Discrete(3)) == sp.Discrete(3)   # passthrough


def test_np_emulation_matches_jax_specs():
    """The numpy pack/unpack twins follow the exact FlatSpec/ActionSpec
    layouts of core/emulation."""
    space = sp.Dict({"image": sp.Box((3, 3)), "flat": sp.Box((4,))})
    spec = em.flat_spec(space, "f32")
    x = {"image": np.arange(9, dtype=np.float32).reshape(3, 3),
         "flat": np.arange(4, dtype=np.float32)}
    flat = np_emulate_obs(spec, x)
    jflat = np.asarray(em.emulate(spec, x))
    np.testing.assert_array_equal(flat, jflat)

    aspace = sp.Dict({"a": sp.Discrete(2), "b": sp.MultiDiscrete((3, 4))})
    aspec = em.action_spec(aspace)
    tree = np_unemulate_action(aspec, np.asarray([1, 2, 3]))
    assert tree["a"] == 1 and isinstance(tree["a"], int)
    np.testing.assert_array_equal(tree["b"], [2, 3])


def test_wrap_duck_api():
    v = wrap(HostBandit, num_envs=3)
    try:
        assert v.is_sync and v.batch_size == 3
        obs = v.reset(timeout=RECV_T)
        assert obs.shape == (3, 1) and obs.dtype == np.float32
        assert v.action_space == sp.MultiDiscrete((4,))
        obs, rew, done, info = v.step(np.zeros((3, 1), np.int32),
                                      timeout=RECV_T)
        assert rew.shape == (3,) and done.dtype == bool
    finally:
        v.close()


def test_wrap_gymnasium_api():
    v = wrap(HostDrone, num_envs=2)
    try:
        assert isinstance(v.action_space, sp.Box)      # Gaussian-head case
        assert v.obs_dim == 6 and v.act_spec.cont_dim == 3
        v.reset(timeout=RECV_T)
        obs, rew, done, info = v.step(np.zeros((2, 3), np.float32),
                                      timeout=RECV_T)
        assert obs.shape == (2, 6) and np.all(np.isfinite(obs))
    finally:
        v.close()


def test_wrap_pettingzoo_api_agent_major_rows():
    v = wrap(HostTeam, num_envs=2)
    try:
        assert v.num_agents == 2 and v.batch_size == 4
        obs = v.reset(timeout=RECV_T)
        # rows alternate agent0, agent1 in canonical order (one-hot ids)
        np.testing.assert_array_equal(obs[::2, 0], 1.0)
        np.testing.assert_array_equal(obs[1::2, 1], 1.0)
        act = np.tile(np.asarray([[0], [1]], np.int32), (2, 1))
        obs, rew, done, info = v.step(act, timeout=RECV_T)
        np.testing.assert_allclose(rew, 1.0)    # each agent matched its id
    finally:
        v.close()


def test_wrap_real_gymnasium_env():
    """End-to-end on an actual gymnasium env (not a mirror) when the
    library is installed — the paper's one-line claim on foreign code."""
    gymnasium = pytest.importorskip("gymnasium")
    v = wrap(lambda: gymnasium.make("CartPole-v1"), num_envs=2)
    try:
        assert v.obs_dim == 4 and v.action_space == sp.MultiDiscrete((2,))
        obs = v.reset(timeout=RECV_T)
        assert obs.shape == (2, 4)
        for _ in range(5):
            obs, rew, done, info = v.step(
                np.zeros((2, 1), np.int32), timeout=RECV_T)
        assert np.all(np.isfinite(obs)) and rew.dtype == np.float32
    finally:
        v.close()


def test_wrap_instance_requires_factory_for_many():
    with pytest.raises(ValueError, match="factory"):
        wrap(HostBandit(), num_envs=2)
    v = wrap(HostBandit(), num_envs=1)          # instance OK for one env
    try:
        assert v.reset(timeout=RECV_T).shape == (1, 1)
    finally:
        v.close()


# ---------------------------------------------------------------------------
# pool semantics

@pytest.mark.parametrize("backend", BACKENDS)
def test_first_finisher_batching(backend):
    """M=2N jittered envs: batches are N distinct envs, every env gets
    served (no starvation), ids are sorted."""
    v = wrap(JitterEnv, num_envs=6, batch_size=3, seed=0, backend=backend)
    seen = set()
    try:
        # loop until every env has been served (bounded): early rounds can
        # outrun slow-spawning proc workers, so a fixed round count races
        deadline = time.monotonic() + RECV_T
        while seen != set(range(6)) and time.monotonic() < deadline:
            obs, rew, done, info, ids = v.recv(timeout=RECV_T)
            assert len(ids) == 3 and len(set(ids.tolist())) == 3
            assert sorted(ids.tolist()) == ids.tolist()
            seen.update(int(i) for i in ids)
            v.send(np.zeros((3, 1), np.int32), ids)
    finally:
        v.close()
    assert seen == set(range(6))


@pytest.mark.parametrize("backend", BACKENDS)
def test_sync_degradation_deterministic_rows(backend):
    """M == N waits for everyone: every batch is exactly envs 0..M-1."""
    v = wrap(JitterEnv, num_envs=4, seed=0, backend=backend)
    try:
        for _ in range(6):
            obs, rew, done, info, ids = v.recv(timeout=RECV_T)
            np.testing.assert_array_equal(ids, np.arange(4))
            v.send(np.zeros((4, 1), np.int32), ids)
    finally:
        v.close()


@pytest.mark.parametrize("backend", BACKENDS)
def test_crash_propagation_step(backend):
    v = wrap(functools.partial(CrashyEnv, crash_step=2), num_envs=2,
             backend=backend)
    try:
        v.reset(timeout=RECV_T)
        with pytest.raises(HostEnvError, match=r"env [01] raised in step"):
            for _ in range(4):
                v.step(np.zeros((2, 1), np.int32), timeout=RECV_T)
    finally:
        v.close()


@pytest.mark.parametrize("backend", BACKENDS)
def test_crash_propagation_reset(backend):
    # api="duck" skips wrap()'s probe reset, which would crash in the parent
    v = wrap(functools.partial(CrashyEnv, crash_reset=True), num_envs=1,
             backend=backend, api="duck")
    try:
        with pytest.raises(HostEnvError, match="reset"):
            v.reset(timeout=RECV_T)
    finally:
        v.close()


@pytest.mark.parametrize("backend", BACKENDS)
def test_recv_timeout_guard(backend):
    v = wrap(functools.partial(SlowEnv, step_s=30.0), num_envs=1,
             backend=backend)
    try:
        v.reset(timeout=RECV_T)
        t0 = time.monotonic()
        with pytest.raises(TimeoutError, match="0/1 envs ready"):
            v.step(np.zeros((1, 1), np.int32), timeout=0.2)
        assert time.monotonic() - t0 < 5.0
    finally:
        # worker mid-sleep: close must still return promptly (threads leave
        # the daemon sleeping; the proc backend actually terminates it)
        t0 = time.monotonic()
        v.close(timeout=0.5)
        assert time.monotonic() - t0 < 5.0


@pytest.mark.parametrize("backend", BACKENDS)
def test_close_joins_idle_workers(backend):
    """close() reaches idle workers promptly (inbox sentinel / stop byte),
    so they all join; double close is a no-op."""
    v = wrap(HostBandit, num_envs=4, backend=backend)
    v.reset(timeout=RECV_T)
    t0 = time.monotonic()
    v.close(timeout=5.0)
    assert time.monotonic() - t0 < 5.0
    assert workers_dead(v.pool)
    v.close()                                   # idempotent


def test_close_with_undelivered_commands():
    """A pending inbox command must not wedge close() (the old put_nowait on
    a full Queue(1) silently skipped the close sentinel)."""
    pool = HostPool([lambda: SlowEnv(step_s=0.3)], batch_size=1)
    pool.recv(timeout=RECV_T)
    pool.send(np.zeros(1), np.asarray([0]))     # worker begins a slow step
    pool.send(np.zeros(1), np.asarray([0]))     # second command sits queued
    t0 = time.monotonic()
    pool.close(timeout=5.0)
    assert time.monotonic() - t0 < 5.0
    time.sleep(0.5)                             # step finishes, sentinel read
    assert not any(t.is_alive() for t in pool._threads)


@pytest.mark.parametrize("backend", BACKENDS)
def test_seed_determinism_across_autoreset(backend):
    """Same-seed wrappers replay identical reward streams across episode
    boundaries (the per-env autoreset seed sequence); different seeds
    diverge."""
    def stream(seed):
        v = wrap(HostBandit, num_envs=2, seed=seed, backend=backend)
        try:
            v.reset(timeout=RECV_T)
            rows = []
            for _ in range(40):                 # horizon 16 → crosses resets
                _o, rew, _d, _i = v.step(np.full((2, 1), 3, np.int32),
                                         timeout=RECV_T)
                rows.append(rew.copy())
        finally:
            v.close()
        return np.stack(rows)

    a, b, c = stream(0), stream(0), stream(1)
    np.testing.assert_array_equal(a, b)
    assert not np.array_equal(a, c)


@pytest.mark.parametrize("backend", BACKENDS)
def test_terminal_info_surfaced(backend):
    """Autoreset surfaces episode stats exactly at episode end, valid==done,
    with the env's normalized score — the old pool discarded all of it."""
    v = wrap(HostBandit, num_envs=2, seed=3, backend=backend)
    try:
        v.reset(timeout=RECV_T)
        rets = np.zeros(2)
        for t in range(16):
            _o, rew, done, info = v.step(np.full((2, 1), 3, np.int32),
                                         timeout=RECV_T)
            rets += rew
            if t < 15:
                assert not info["valid"].any() and not done.any()
        assert done.all() and info["valid"].all()
        np.testing.assert_array_equal(info["episode_length"], 16)
        np.testing.assert_allclose(info["episode_return"], rets)
        np.testing.assert_allclose(
            info["score"], np.minimum(1.0, rets / (16 * 0.9)), rtol=1e-6)
        # next episode: counters restarted
        _o, rew, done, info = v.step(np.full((2, 1), 3, np.int32),
                                     timeout=RECV_T)
        assert not info["valid"].any()
    finally:
        v.close()


# ---------------------------------------------------------------------------
# backend parity + proc-backend hardening

def test_backend_sync_parity_bitwise():
    """The acceptance cell: thread and proc backends are *bitwise* identical
    in sync mode — obs, rew, done, every info field, and env_ids — over three
    full episodes of the seeded HostBandit (horizon 16 → autoreset crossed
    twice), so the slab round-trip and worker-side autoreset change nothing
    observable."""
    kw = dict(num_envs=4, seed=11, recv_timeout=RECV_T)
    vt = wrap(HostBandit, **kw)
    vp = wrap(HostBandit, backend="proc", **kw)
    try:
        np.testing.assert_array_equal(vt.reset(), vp.reset())
        rng = np.random.default_rng(0)
        for t in range(3 * 16):
            acts = rng.integers(0, 4, size=(4, 1)).astype(np.int32)
            o1, r1, d1, i1 = vt.step(acts, timeout=RECV_T)
            o2, r2, d2, i2 = vp.step(acts, timeout=RECV_T)
            assert np.array_equal(o1, o2), f"obs diverge at step {t}"
            assert np.array_equal(r1, r2), f"rew diverge at step {t}"
            assert np.array_equal(d1, d2), f"done diverge at step {t}"
            np.testing.assert_array_equal(vt.last_ids, vp.last_ids)
            assert i1.keys() == i2.keys()
            for k in i1:
                assert np.array_equal(i1[k], i2[k]), \
                    f"info[{k!r}] diverges at step {t}"
    finally:
        vt.close()
        vp.close()


def test_backend_parity_cpu_heavy_env():
    """Same check on the CPU-heavy HostCrafterLite (the env the proc backend
    exists for): its LCG dynamics are seed-deterministic, so both backends
    must produce identical trajectories."""
    fn = functools.partial(HostCrafterLite, size=6, horizon=8, work=500)
    vt = wrap(fn, num_envs=2, seed=3, recv_timeout=RECV_T)
    vp = wrap(fn, num_envs=2, seed=3, recv_timeout=RECV_T, backend="proc")
    try:
        np.testing.assert_array_equal(vt.reset(), vp.reset())
        for t in range(12):                     # crosses one autoreset
            acts = np.full((2, 1), t % 6, np.int32)
            a1 = vt.step(acts, timeout=RECV_T)
            a2 = vp.step(acts, timeout=RECV_T)
            for x, y in zip(a1[:3], a2[:3]):
                assert np.array_equal(x, y), f"diverge at step {t}"
    finally:
        vt.close()
        vp.close()


def test_thread_send_dead_worker_raises():
    """Satellite regression: ``send`` to a dead worker whose inbox is full
    must raise ``HostEnvError``, not block forever (the old unbounded
    ``put`` on the size-1 inbox deadlocked the trainer)."""
    pool = HostPool([HostBandit, HostBandit], batch_size=2)
    try:
        pool.recv(timeout=RECV_T)
        pool._inboxes[0].put(("close", None))   # kill worker 0 out-of-band
        pool._threads[0].join(timeout=RECV_T)
        assert not pool._threads[0].is_alive()
        t0 = time.monotonic()
        with pytest.raises(HostEnvError, match="dead"):
            for _ in range(3):                  # 1st put lands in the empty
                pool.send(np.zeros(2, np.int32), np.asarray([0, 1]))
        assert time.monotonic() - t0 < 5.0      # bounded, not a deadlock
    finally:
        pool.close()


def test_proc_send_dead_worker_raises():
    """Proc analogue: a worker killed mid-flight turns ``send`` into
    ``HostEnvError`` (liveness check), never a silent hang."""
    v = wrap(HostBandit, num_envs=2, backend="proc")
    try:
        v.reset(timeout=RECV_T)
        v.pool._procs[1].terminate()
        v.pool._procs[1].join()
        with pytest.raises(HostEnvError, match="dead"):
            v.send(np.zeros((2, 1), np.int32), np.asarray([0, 1]))
    finally:
        v.close()


def test_proc_dead_worker_detected_by_recv():
    """A worker that dies *after* taking a command surfaces from recv() as
    HostEnvError (exitcode in the message), not a TimeoutError."""
    v = wrap(functools.partial(SlowEnv, step_s=30.0), num_envs=1,
             backend="proc")
    try:
        v.reset(timeout=RECV_T)
        v.send(np.zeros((1, 1), np.int32), np.asarray([0]))
        v.pool._procs[0].terminate()
        v.pool._procs[0].join()
        with pytest.raises(HostEnvError, match="died without reporting"):
            v.recv(timeout=RECV_T)
    finally:
        v.close()


def test_proc_requires_slab_and_factory():
    with pytest.raises(ValueError, match="slab"):
        HostPool([HostBandit], batch_size=1, backend="proc")
    with pytest.raises(ValueError, match="factory"):
        wrap(HostBandit(), num_envs=1, backend="proc")


def test_proc_backend_dispatch_and_slab_metadata():
    """HostPool(..., backend="proc") constructs a ProcHostPool via __new__;
    the bridge sizes the slab rows from the emulation specs."""
    v = wrap(HostBandit, num_envs=2, backend="proc")
    try:
        assert isinstance(v.pool, ProcHostPool)
        assert v.slab.obs_shape == (1,) and v.slab.act_shape == (1,)
        assert v.slab.act_dtype == "int32" and v.slab.rew_shape == ()
        assert v.pool._layout.nbytes > 0
    finally:
        v.close()


def test_proc_lambda_factory_via_cloudpickle():
    """Lambdas work under proc when cloudpickle is installed (it serializes
    the closure by value; referenced classes stay by-reference imports)."""
    pytest.importorskip("cloudpickle")
    v = wrap(lambda: HostBandit(), num_envs=2, backend="proc")
    try:
        assert v.reset(timeout=RECV_T).shape == (2, 1)
    finally:
        v.close()


def test_dumps_env_fn_error_without_cloudpickle(monkeypatch):
    """Without cloudpickle, an unpicklable factory fails *fast* at
    construction with an actionable message (not deep inside Process.start)."""
    monkeypatch.setitem(sys.modules, "cloudpickle", None)
    x = object()                                # closure → unpicklable
    with pytest.raises(ValueError, match="module-level"):
        shm.dumps_env_fn(lambda: x)


def test_worker_main_refuses_forked_context():
    """The worker entrypoint hard-fails if jax is already loaded (i.e. it
    was forked off the jax-laden parent instead of spawned) — forked XLA
    state deadlocks. This process has jax imported, so calling it inline
    must refuse before touching the slab."""
    cfg = shm.WorkerConfig(shm_name="nonexistent", index=0, M=1, seed=0,
                           spec=shm.SlabSpec(obs_shape=(1,), act_shape=(1,)))
    with pytest.raises(RuntimeError, match="spawn"):
        shm.worker_main(cfg)


def test_worker_import_chain_stays_jax_free():
    """Satellite guard: the spawn-worker import chain (shm + bridge +
    mirror envs) must never pull jax — jax is spawn-hostile and costs
    seconds per worker. Probed in a clean interpreter."""
    src = Path(shm.__file__).resolve().parents[2]      # .../src
    # repro.launch.train is in the chain because spawn re-imports the
    # parent's main module: under `python -m repro.launch.train` every
    # worker imports it as __mp_main__ before worker_main runs
    code = ("import sys; "
            "import repro.core.shm, repro.bridge, repro.envs.ocean_host, "
            "repro.launch.train; "
            "assert 'jax' not in sys.modules, 'jax leaked into the chain'")
    r = subprocess.run([sys.executable, "-c", code], timeout=120,
                       capture_output=True, text=True,
                       env={**os.environ, "PYTHONPATH": str(src)})
    assert r.returncode == 0, r.stderr


# ---------------------------------------------------------------------------
# conformance host profile

@pytest.mark.parametrize("name", sorted(OCEAN_HOST))
def test_host_profile_conformance(name):
    from repro.envs.conformance import check_host_env
    cls = OCEAN_HOST[name]
    report = check_host_env(lambda: wrap(cls, num_envs=2),
                            name=f"host/{name}")
    assert report.ok, report.summary()


def test_host_profile_conformance_proc_backend():
    """The conformance host profile passes unchanged over the proc backend
    (what ``conformance.run_cli --host-backend proc`` exercises)."""
    from repro.envs.conformance import check_host_env
    report = check_host_env(
        lambda: wrap(HostBandit, num_envs=2, backend="proc"),
        name="host/bandit[proc]")
    assert report.ok, report.summary()


def test_host_profile_catches_broken_env():
    """Negative control: an env whose autoreset ignores the seed must fail
    the determinism check."""
    class Unseeded:
        horizon = 4

        def __init__(self):
            self.observation_space = sp.Box((1,))
            self.action_space = sp.Discrete(2)
            self.t = 0

        def reset(self, seed):
            self.t = 0
            return np.zeros(1, np.float32)

        def step(self, a):
            self.t += 1
            rew = float(np.random.random())     # hidden host randomness
            return np.zeros(1, np.float32), rew, self.t >= 4, {}

    from repro.envs.conformance import check_host_env
    report = check_host_env(lambda: wrap(Unseeded, num_envs=2),
                            name="host/unseeded")
    bad = {r.name for r in report.results if not r.ok}
    assert "host_determinism" in bad, report.summary()


# ---------------------------------------------------------------------------
# TrainEngine host tier

def test_engine_host_tier_smoke():
    e = make_host_engine(HostBandit, TCFG, hidden=16, kernel_mode="ref")
    try:
        assert e.hvec.num_envs == 2 * TCFG.num_envs     # M = 2N default
        hist, solved = e.run(3 * e.steps_per_update)
        assert solved is None and len(hist) == 3
        assert [h["env_steps"] for h in hist] == \
            [(i + 1) * e.steps_per_update for i in range(3)]
        assert all(np.isfinite(h["loss"]) for h in hist)
    finally:
        e.close()


def test_engine_host_tier_recurrent():
    e = make_host_engine(HostSquared, TCFG, hidden=16, recurrent=True,
                         kernel_mode="ref")
    try:
        hist, _ = e.run(2 * e.steps_per_update)
        assert len(hist) == 2 and np.isfinite(hist[-1]["loss"])
    finally:
        e.close()


def test_engine_host_tier_multiagent():
    tcfg = TrainConfig(num_envs=4, unroll_length=8, update_epochs=1,
                       num_minibatches=2, learning_rate=1e-3, gamma=0.95)
    e = make_host_engine(HostTeam, tcfg, hidden=16, kernel_mode="ref")
    try:
        assert e.batch_size == 8                # 4 envs × 2 agent rows
        hist, _ = e.run(2 * e.steps_per_update)
        assert len(hist) == 2 and np.isfinite(hist[-1]["loss"])
    finally:
        e.close()


def test_engine_host_tier_target_score_early_exit():
    e = make_host_engine(HostBandit, TCFG, hidden=16, kernel_mode="ref")
    try:
        hist, solved = e.run(400 * e.steps_per_update, target_score=0.3)
        assert solved is not None and solved["score"] >= 0.3
        assert len(hist) < 400
    finally:
        e.close()


def test_engine_host_tier_validation():
    from repro.models.policy import OceanPolicy
    from repro.rl.distributions import Dist
    from repro.rl.engine import TrainEngine
    from repro.core.emulation import Emulated
    from repro.envs.ocean import Bandit

    # K > 1 rejected
    tcfg_k = TrainConfig(num_envs=8, unroll_length=8, updates_per_launch=4)
    with pytest.raises(ValueError, match="host tier"):
        make_host_engine(HostBandit, tcfg_k, hidden=16)
    # a JAX env is not a HostVecEnv
    em_env = Emulated(Bandit())
    dist = Dist("categorical", nvec=em_env.act_spec.nvec)
    pol = OceanPolicy(em_env.obs_spec.total, dist.nvec, hidden=16,
                      num_outputs=dist.num_outputs)
    with pytest.raises(ValueError, match="HostVecEnv"):
        TrainEngine(em_env, pol, TCFG, dist, key=jax.random.PRNGKey(0),
                    backend="host")
    # batch size must match the training config
    v = wrap(HostBandit, num_envs=4)
    try:
        with pytest.raises(ValueError, match="num_envs"):
            TrainEngine(v, pol, TCFG, dist, key=jax.random.PRNGKey(0),
                        backend="host")
    finally:
        v.close()


def test_async_beats_sync_under_jitter():
    """The EnvPool claim through the whole bridge: first N of M=2N finishers
    ≥ 30% faster than wait-for-all on jittered envs."""
    from benchmarks.bench_bridge import run_once
    sync = run_once(M=4, N=4, steps=40)
    pool = run_once(M=8, N=4, steps=40)
    assert pool > 1.3 * sync, (sync, pool)


@pytest.mark.slow
def test_host_bandit_parity_with_jit_tier():
    """The acceptance cell: the bridged numpy bandit trains to the same
    solved score as the JAX bandit on the jit tier under identical training
    params — the mirror env and the bridge change nothing about learning."""
    from repro.envs.ocean import Bandit
    from repro.rl.trainer import Trainer
    tcfg = TrainConfig(num_envs=32, unroll_length=32, update_epochs=4,
                       num_minibatches=4, learning_rate=1e-3, gamma=0.95)
    e = make_host_engine(HostBandit, tcfg, hidden=64, kernel_mode="ref",
                         seed=0)
    try:
        hist, solved = e.run(400_000, target_score=0.9)
    finally:
        e.close()
    assert solved is not None, f"host bandit unsolved: {hist[-1]}"
    assert solved["score"] > 0.9

    tr = Trainer(Bandit(), tcfg, hidden=64, kernel_mode="ref", seed=0)
    m = tr.train(400_000, target_score=0.9)
    assert m["score"] > 0.9, f"jit bandit unsolved: {m}"
