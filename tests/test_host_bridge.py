"""HostBridge: wrap() API detection, HostPool hardening (crash propagation,
seeded autoreset, close), first-finisher batching, the conformance host
profile, and the TrainEngine ``host`` tier (incl. JAX-vs-host parity
training). Every blocking call carries a timeout so a regression can never
hang the suite."""
import time

import jax
import numpy as np
import pytest

from repro.bridge import (convert_space, detect_api, make_host_engine,
                          np_emulate_obs, np_unemulate_action, wrap)
from repro.configs.base import TrainConfig
from repro.core import emulation as em
from repro.core import spaces as sp
from repro.core.host import HostEnvError, HostPool
from repro.envs.ocean_host import (OCEAN_HOST, HostBandit, HostDrone,
                                   HostSquared, HostTeam)

RECV_T = 30.0          # generous per-call bound; hit only on regressions

TCFG = TrainConfig(num_envs=8, unroll_length=8, update_epochs=1,
                   num_minibatches=2, learning_rate=1e-3, gamma=0.95)


# ---------------------------------------------------------------------------
# helper envs

class SlowEnv:
    """Duck env whose step blocks long enough to trip small timeouts."""

    def __init__(self, step_s: float = 30.0):
        self.step_s = step_s
        self.observation_space = sp.Box((1,))
        self.action_space = sp.Discrete(2)

    def reset(self, seed):
        return np.zeros(1, np.float32)

    def step(self, a):
        time.sleep(self.step_s)
        return np.zeros(1, np.float32), 0.0, False, {}


class CrashyEnv:
    """Duck env that raises on the k-th step (or on reset)."""

    def __init__(self, crash_step: int = 3, crash_reset: bool = False):
        self.crash_step, self.crash_reset = crash_step, crash_reset
        self.observation_space = sp.Box((1,))
        self.action_space = sp.Discrete(2)
        self.t = 0

    def reset(self, seed):
        if self.crash_reset:
            raise RuntimeError("reset kaboom")
        self.t = 0
        return np.zeros(1, np.float32)

    def step(self, a):
        self.t += 1
        if self.t >= self.crash_step:
            raise RuntimeError("step kaboom")
        return np.zeros(1, np.float32), 1.0, False, {}


class JitterEnv:
    """Duck env with lognormal step latency (first-finisher tests)."""

    def __init__(self, mean_ms=0.5, seed=0, horizon=64):
        self.observation_space = sp.Box((2,))
        self.action_space = sp.Discrete(2)
        self.rng = np.random.RandomState(seed)
        self.mean_ms, self.horizon, self.t = mean_ms, horizon, 0

    def reset(self, seed):
        self.t = 0
        return np.zeros(2, np.float32)

    def step(self, a):
        time.sleep(self.rng.lognormal(np.log(self.mean_ms), 0.6) / 1e3)
        self.t += 1
        done = self.t >= self.horizon
        return np.zeros(2, np.float32), 0.0, done, {}


# ---------------------------------------------------------------------------
# wrap(): API detection + space conversion

def test_detect_api_three_styles():
    assert detect_api(HostBandit()) == "duck"
    assert detect_api(HostSquared()) == "duck"
    assert detect_api(HostDrone()) == "gymnasium"
    assert detect_api(HostTeam()) == "pettingzoo"


def test_convert_space_duck_objects():
    class N:                     # gymnasium-shaped duck objects
        n = 5

    class MD:
        nvec = np.array([2, 3])

    class B:
        shape, dtype = (4, 2), np.float32
        low, high = -1.0, 1.0

    assert convert_space(N()) == sp.Discrete(5)
    assert convert_space(MD()) == sp.MultiDiscrete((2, 3))
    b = convert_space(B())
    assert isinstance(b, sp.Box) and b.shape == (4, 2)
    assert convert_space(sp.Discrete(3)) == sp.Discrete(3)   # passthrough


def test_np_emulation_matches_jax_specs():
    """The numpy pack/unpack twins follow the exact FlatSpec/ActionSpec
    layouts of core/emulation."""
    space = sp.Dict({"image": sp.Box((3, 3)), "flat": sp.Box((4,))})
    spec = em.flat_spec(space, "f32")
    x = {"image": np.arange(9, dtype=np.float32).reshape(3, 3),
         "flat": np.arange(4, dtype=np.float32)}
    flat = np_emulate_obs(spec, x)
    jflat = np.asarray(em.emulate(spec, x))
    np.testing.assert_array_equal(flat, jflat)

    aspace = sp.Dict({"a": sp.Discrete(2), "b": sp.MultiDiscrete((3, 4))})
    aspec = em.action_spec(aspace)
    tree = np_unemulate_action(aspec, np.asarray([1, 2, 3]))
    assert tree["a"] == 1 and isinstance(tree["a"], int)
    np.testing.assert_array_equal(tree["b"], [2, 3])


def test_wrap_duck_api():
    v = wrap(HostBandit, num_envs=3)
    try:
        assert v.is_sync and v.batch_size == 3
        obs = v.reset(timeout=RECV_T)
        assert obs.shape == (3, 1) and obs.dtype == np.float32
        assert v.action_space == sp.MultiDiscrete((4,))
        obs, rew, done, info = v.step(np.zeros((3, 1), np.int32),
                                      timeout=RECV_T)
        assert rew.shape == (3,) and done.dtype == bool
    finally:
        v.close()


def test_wrap_gymnasium_api():
    v = wrap(HostDrone, num_envs=2)
    try:
        assert isinstance(v.action_space, sp.Box)      # Gaussian-head case
        assert v.obs_dim == 6 and v.act_spec.cont_dim == 3
        v.reset(timeout=RECV_T)
        obs, rew, done, info = v.step(np.zeros((2, 3), np.float32),
                                      timeout=RECV_T)
        assert obs.shape == (2, 6) and np.all(np.isfinite(obs))
    finally:
        v.close()


def test_wrap_pettingzoo_api_agent_major_rows():
    v = wrap(HostTeam, num_envs=2)
    try:
        assert v.num_agents == 2 and v.batch_size == 4
        obs = v.reset(timeout=RECV_T)
        # rows alternate agent0, agent1 in canonical order (one-hot ids)
        np.testing.assert_array_equal(obs[::2, 0], 1.0)
        np.testing.assert_array_equal(obs[1::2, 1], 1.0)
        act = np.tile(np.asarray([[0], [1]], np.int32), (2, 1))
        obs, rew, done, info = v.step(act, timeout=RECV_T)
        np.testing.assert_allclose(rew, 1.0)    # each agent matched its id
    finally:
        v.close()


def test_wrap_real_gymnasium_env():
    """End-to-end on an actual gymnasium env (not a mirror) when the
    library is installed — the paper's one-line claim on foreign code."""
    gymnasium = pytest.importorskip("gymnasium")
    v = wrap(lambda: gymnasium.make("CartPole-v1"), num_envs=2)
    try:
        assert v.obs_dim == 4 and v.action_space == sp.MultiDiscrete((2,))
        obs = v.reset(timeout=RECV_T)
        assert obs.shape == (2, 4)
        for _ in range(5):
            obs, rew, done, info = v.step(
                np.zeros((2, 1), np.int32), timeout=RECV_T)
        assert np.all(np.isfinite(obs)) and rew.dtype == np.float32
    finally:
        v.close()


def test_wrap_instance_requires_factory_for_many():
    with pytest.raises(ValueError, match="factory"):
        wrap(HostBandit(), num_envs=2)
    v = wrap(HostBandit(), num_envs=1)          # instance OK for one env
    try:
        assert v.reset(timeout=RECV_T).shape == (1, 1)
    finally:
        v.close()


# ---------------------------------------------------------------------------
# pool semantics

def test_first_finisher_batching():
    """M=2N jittered envs: batches are N distinct envs, every env gets
    served (no starvation), ids are sorted."""
    v = wrap(lambda: JitterEnv(), num_envs=6, batch_size=3, seed=0)
    seen = set()
    try:
        for _ in range(16):
            obs, rew, done, info, ids = v.recv(timeout=RECV_T)
            assert len(ids) == 3 and len(set(ids.tolist())) == 3
            assert sorted(ids.tolist()) == ids.tolist()
            seen.update(int(i) for i in ids)
            v.send(np.zeros((3, 1), np.int32), ids)
    finally:
        v.close()
    assert seen == set(range(6))


def test_sync_degradation_deterministic_rows():
    """M == N waits for everyone: every batch is exactly envs 0..M-1."""
    v = wrap(lambda: JitterEnv(), num_envs=4, seed=0)
    try:
        for _ in range(6):
            obs, rew, done, info, ids = v.recv(timeout=RECV_T)
            np.testing.assert_array_equal(ids, np.arange(4))
            v.send(np.zeros((4, 1), np.int32), ids)
    finally:
        v.close()


def test_crash_propagation_step():
    v = wrap(lambda: CrashyEnv(crash_step=2), num_envs=2)
    try:
        v.reset(timeout=RECV_T)
        with pytest.raises(HostEnvError, match=r"env [01] raised in step"):
            for _ in range(4):
                v.step(np.zeros((2, 1), np.int32), timeout=RECV_T)
    finally:
        v.close()


def test_crash_propagation_reset():
    pool = HostPool([lambda: CrashyEnv(crash_reset=True)], batch_size=1)
    try:
        with pytest.raises(HostEnvError, match="reset"):
            pool.recv(timeout=RECV_T)
    finally:
        pool.close()


def test_recv_timeout_guard():
    v = wrap(lambda: SlowEnv(step_s=30.0), num_envs=1)
    try:
        v.reset(timeout=RECV_T)
        t0 = time.monotonic()
        with pytest.raises(TimeoutError, match="0/1 envs ready"):
            v.step(np.zeros((1, 1), np.int32), timeout=0.2)
        assert time.monotonic() - t0 < 5.0
    finally:
        v.close(timeout=0.5)    # worker mid-sleep: close must still return


def test_close_joins_idle_workers():
    """close() drains inboxes and posts the sentinel, so idle workers join
    promptly; double close is a no-op."""
    v = wrap(HostBandit, num_envs=4)
    v.reset(timeout=RECV_T)
    t0 = time.monotonic()
    v.close(timeout=5.0)
    assert time.monotonic() - t0 < 5.0
    assert not any(t.is_alive() for t in v.pool._threads)
    v.close()                                   # idempotent


def test_close_with_undelivered_commands():
    """A pending inbox command must not wedge close() (the old put_nowait on
    a full Queue(1) silently skipped the close sentinel)."""
    pool = HostPool([lambda: SlowEnv(step_s=0.3)], batch_size=1)
    pool.recv(timeout=RECV_T)
    pool.send(np.zeros(1), np.asarray([0]))     # worker begins a slow step
    pool.send(np.zeros(1), np.asarray([0]))     # second command sits queued
    t0 = time.monotonic()
    pool.close(timeout=5.0)
    assert time.monotonic() - t0 < 5.0
    time.sleep(0.5)                             # step finishes, sentinel read
    assert not any(t.is_alive() for t in pool._threads)


def test_seed_determinism_across_autoreset():
    """Same-seed wrappers replay identical reward streams across episode
    boundaries (the per-env autoreset seed sequence); different seeds
    diverge."""
    def stream(seed):
        v = wrap(HostBandit, num_envs=2, seed=seed)
        try:
            v.reset(timeout=RECV_T)
            rows = []
            for _ in range(40):                 # horizon 16 → crosses resets
                _o, rew, _d, _i = v.step(np.full((2, 1), 3, np.int32),
                                         timeout=RECV_T)
                rows.append(rew.copy())
        finally:
            v.close()
        return np.stack(rows)

    a, b, c = stream(0), stream(0), stream(1)
    np.testing.assert_array_equal(a, b)
    assert not np.array_equal(a, c)


def test_terminal_info_surfaced():
    """Autoreset surfaces episode stats exactly at episode end, valid==done,
    with the env's normalized score — the old pool discarded all of it."""
    v = wrap(HostBandit, num_envs=2, seed=3)
    try:
        v.reset(timeout=RECV_T)
        rets = np.zeros(2)
        for t in range(16):
            _o, rew, done, info = v.step(np.full((2, 1), 3, np.int32),
                                         timeout=RECV_T)
            rets += rew
            if t < 15:
                assert not info["valid"].any() and not done.any()
        assert done.all() and info["valid"].all()
        np.testing.assert_array_equal(info["episode_length"], 16)
        np.testing.assert_allclose(info["episode_return"], rets)
        np.testing.assert_allclose(
            info["score"], np.minimum(1.0, rets / (16 * 0.9)), rtol=1e-6)
        # next episode: counters restarted
        _o, rew, done, info = v.step(np.full((2, 1), 3, np.int32),
                                     timeout=RECV_T)
        assert not info["valid"].any()
    finally:
        v.close()


# ---------------------------------------------------------------------------
# conformance host profile

@pytest.mark.parametrize("name", sorted(OCEAN_HOST))
def test_host_profile_conformance(name):
    from repro.envs.conformance import check_host_env
    cls = OCEAN_HOST[name]
    report = check_host_env(lambda: wrap(cls, num_envs=2),
                            name=f"host/{name}")
    assert report.ok, report.summary()


def test_host_profile_catches_broken_env():
    """Negative control: an env whose autoreset ignores the seed must fail
    the determinism check."""
    class Unseeded:
        horizon = 4

        def __init__(self):
            self.observation_space = sp.Box((1,))
            self.action_space = sp.Discrete(2)
            self.t = 0

        def reset(self, seed):
            self.t = 0
            return np.zeros(1, np.float32)

        def step(self, a):
            self.t += 1
            rew = float(np.random.random())     # hidden host randomness
            return np.zeros(1, np.float32), rew, self.t >= 4, {}

    from repro.envs.conformance import check_host_env
    report = check_host_env(lambda: wrap(Unseeded, num_envs=2),
                            name="host/unseeded")
    bad = {r.name for r in report.results if not r.ok}
    assert "host_determinism" in bad, report.summary()


# ---------------------------------------------------------------------------
# TrainEngine host tier

def test_engine_host_tier_smoke():
    e = make_host_engine(HostBandit, TCFG, hidden=16, kernel_mode="ref")
    try:
        assert e.hvec.num_envs == 2 * TCFG.num_envs     # M = 2N default
        hist, solved = e.run(3 * e.steps_per_update)
        assert solved is None and len(hist) == 3
        assert [h["env_steps"] for h in hist] == \
            [(i + 1) * e.steps_per_update for i in range(3)]
        assert all(np.isfinite(h["loss"]) for h in hist)
    finally:
        e.close()


def test_engine_host_tier_recurrent():
    e = make_host_engine(HostSquared, TCFG, hidden=16, recurrent=True,
                         kernel_mode="ref")
    try:
        hist, _ = e.run(2 * e.steps_per_update)
        assert len(hist) == 2 and np.isfinite(hist[-1]["loss"])
    finally:
        e.close()


def test_engine_host_tier_multiagent():
    tcfg = TrainConfig(num_envs=4, unroll_length=8, update_epochs=1,
                       num_minibatches=2, learning_rate=1e-3, gamma=0.95)
    e = make_host_engine(HostTeam, tcfg, hidden=16, kernel_mode="ref")
    try:
        assert e.batch_size == 8                # 4 envs × 2 agent rows
        hist, _ = e.run(2 * e.steps_per_update)
        assert len(hist) == 2 and np.isfinite(hist[-1]["loss"])
    finally:
        e.close()


def test_engine_host_tier_target_score_early_exit():
    e = make_host_engine(HostBandit, TCFG, hidden=16, kernel_mode="ref")
    try:
        hist, solved = e.run(400 * e.steps_per_update, target_score=0.3)
        assert solved is not None and solved["score"] >= 0.3
        assert len(hist) < 400
    finally:
        e.close()


def test_engine_host_tier_validation():
    from repro.models.policy import OceanPolicy
    from repro.rl.distributions import Dist
    from repro.rl.engine import TrainEngine
    from repro.core.emulation import Emulated
    from repro.envs.ocean import Bandit

    # K > 1 rejected
    tcfg_k = TrainConfig(num_envs=8, unroll_length=8, updates_per_launch=4)
    with pytest.raises(ValueError, match="host tier"):
        make_host_engine(HostBandit, tcfg_k, hidden=16)
    # a JAX env is not a HostVecEnv
    em_env = Emulated(Bandit())
    dist = Dist("categorical", nvec=em_env.act_spec.nvec)
    pol = OceanPolicy(em_env.obs_spec.total, dist.nvec, hidden=16,
                      num_outputs=dist.num_outputs)
    with pytest.raises(ValueError, match="HostVecEnv"):
        TrainEngine(em_env, pol, TCFG, dist, key=jax.random.PRNGKey(0),
                    backend="host")
    # batch size must match the training config
    v = wrap(HostBandit, num_envs=4)
    try:
        with pytest.raises(ValueError, match="num_envs"):
            TrainEngine(v, pol, TCFG, dist, key=jax.random.PRNGKey(0),
                        backend="host")
    finally:
        v.close()


def test_async_beats_sync_under_jitter():
    """The EnvPool claim through the whole bridge: first N of M=2N finishers
    ≥ 30% faster than wait-for-all on jittered envs."""
    from benchmarks.bench_bridge import run_once
    sync = run_once(M=4, N=4, steps=40)
    pool = run_once(M=8, N=4, steps=40)
    assert pool > 1.3 * sync, (sync, pool)


@pytest.mark.slow
def test_host_bandit_parity_with_jit_tier():
    """The acceptance cell: the bridged numpy bandit trains to the same
    solved score as the JAX bandit on the jit tier under identical training
    params — the mirror env and the bridge change nothing about learning."""
    from repro.envs.ocean import Bandit
    from repro.rl.trainer import Trainer
    tcfg = TrainConfig(num_envs=32, unroll_length=32, update_epochs=4,
                       num_minibatches=4, learning_rate=1e-3, gamma=0.95)
    e = make_host_engine(HostBandit, tcfg, hidden=64, kernel_mode="ref",
                         seed=0)
    try:
        hist, solved = e.run(400_000, target_score=0.9)
    finally:
        e.close()
    assert solved is not None, f"host bandit unsolved: {hist[-1]}"
    assert solved["score"] > 0.9

    tr = Trainer(Bandit(), tcfg, hidden=64, kernel_mode="ref", seed=0)
    m = tr.train(400_000, target_score=0.9)
    assert m["score"] > 0.9, f"jit bandit unsolved: {m}"
