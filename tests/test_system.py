"""End-to-end behaviour: the paper's full stack solves its own sanity suite,
the LM path learns, and checkpoint-restart is transparent."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import TrainConfig


def test_emulated_ppo_solves_spaces_env():
    """Nested Dict obs + Dict actions through emulation + PPO end-to-end —
    the paper's central claim, learned, not just round-tripped."""
    from repro.envs.ocean import Spaces
    from repro.rl.trainer import Trainer
    tr = Trainer(Spaces(), TrainConfig(num_envs=64, unroll_length=64,
                                       update_epochs=4, num_minibatches=4,
                                       learning_rate=1e-3, gamma=0.95),
                 hidden=64, kernel_mode="ref")
    m = tr.train(150_000, target_score=0.9)
    assert m["score"] >= 0.9, m


def test_ocean_coffee_break_suite():
    """Three envs, each < ~60s on one CPU core (paper §4)."""
    from repro.envs.ocean import Bandit, Stochastic, Squared
    from repro.rl.trainer import Trainer
    tcfg = TrainConfig(num_envs=64, unroll_length=64, update_epochs=4,
                       num_minibatches=4, learning_rate=1e-3, gamma=0.95)
    for env, steps in [(Squared(), 300_000), (Stochastic(), 200_000),
                       (Bandit(), 120_000)]:
        m = Trainer(env, tcfg, hidden=64, kernel_mode="ref").train(
            steps, target_score=0.9)
        assert m["score"] >= 0.9, (type(env).__name__, m)


def test_lm_ppo_improves_objective():
    """Token-level PPO on a fixed batch reduces its own loss (sanity that
    the whole learner stack — GAE, chunked loss, AdamW — optimizes)."""
    from repro.configs import get_smoke_config, with_overrides
    from repro.models.policy import BackbonePolicy
    from repro.rl.learner import init_train_state, make_lm_train_step
    from repro.data.buffer import random_batch
    cfg = with_overrides(get_smoke_config("qwen3-0.6b"), num_layers=2,
                         dtype="float32", param_dtype="float32")
    pol = BackbonePolicy(cfg, tp=1, kernel="ref")
    ts = init_train_state(pol.init(jax.random.PRNGKey(0)))
    step = jax.jit(make_lm_train_step(pol, TrainConfig(learning_rate=1e-4,
                                                       warmup_steps=1),
                                      loss_chunk=8))
    batch = random_batch(cfg, 4, 32, jax.random.PRNGKey(1))
    losses = []
    for _ in range(8):
        ts, m = step(ts, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0], losses


def test_checkpoint_restart_transparent(tmp_path):
    """Kill-and-resume reproduces the uninterrupted run exactly."""
    from repro.envs.ocean import Bandit
    from repro.rl.trainer import Trainer
    tcfg = TrainConfig(num_envs=16, unroll_length=32, update_epochs=1,
                       num_minibatches=1)
    tr = Trainer(Bandit(), tcfg, hidden=32, kernel_mode="ref", seed=3)
    tr.train(5 * tr.steps_per_update)
    tr.save(str(tmp_path))
    w_before = np.asarray(tr.ts.params["act"])

    tr2 = Trainer(Bandit(), tcfg, hidden=32, kernel_mode="ref", seed=99)
    tr2.restore(str(tmp_path))
    np.testing.assert_array_equal(np.asarray(tr2.ts.params["act"]), w_before)
    assert int(tr2.ts.step) == int(tr.ts.step)


def test_generate_produces_tokens():
    from repro.configs import get_smoke_config
    from repro.models.policy import BackbonePolicy
    from repro.rl import actor
    cfg = get_smoke_config("qwen3-0.6b")
    pol = BackbonePolicy(cfg, tp=1, kernel="ref")
    params = pol.init(jax.random.PRNGKey(0))
    prompt = jnp.ones((2, 8), jnp.int32)
    out = actor.generate(pol, params, prompt, 6, jax.random.PRNGKey(1),
                         max_len=14)
    assert out.shape == (2, 6)
    assert bool(jnp.all((out >= 0) & (out < cfg.vocab_size)))
