"""Vectorization + pool behaviour (paper §3.3)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.emulation import Emulated
from repro.core.vector import VecEnv, autotune
from repro.core.pool import Pool
from repro.envs.ocean import Bandit, Multiagent, Password


def _zero_actions(vec):
    n = len(vec.single_action_space.nvec)
    return jnp.zeros((vec.batch_size, n), jnp.int32)


def test_serial_vmap_equivalence():
    """Both backends step identical env states to identical results."""
    outs = {}
    for backend in ("serial", "vmap"):
        vec = VecEnv(Emulated(Password()), 4, backend=backend)
        state, obs = vec.init(jax.random.PRNGKey(0))
        act = _zero_actions(vec)
        for i in range(7):
            state, obs, rew, done, info = vec.step(
                state, act, jax.random.PRNGKey(100 + i))
        outs[backend] = (np.asarray(obs), np.asarray(rew), np.asarray(done))
    for a, b in zip(outs["serial"], outs["vmap"]):
        np.testing.assert_allclose(a, b)


def test_autoreset():
    """Envs reset in-graph at episode end; no host round trip."""
    env = Emulated(Password())
    vec = VecEnv(env, 2)
    state, obs = vec.init(jax.random.PRNGKey(0))
    act = _zero_actions(vec)
    dones = []
    for i in range(12):
        state, obs, rew, done, info = vec.step(state, act,
                                               jax.random.PRNGKey(i))
        dones.append(bool(done[0]))
    assert sum(dones) == 2   # horizon 5 -> episodes end twice in 12 steps
    # after reset the obs is step-0 one-hot again
    assert float(obs[0, 0]) in (0.0, 1.0)


def test_multiagent_canonical_order():
    """Agent-major flattening keeps canonical order (paper guarantee)."""
    vec = VecEnv(Emulated(Multiagent()), 3)
    state, obs = vec.init(jax.random.PRNGKey(0))
    assert vec.batch_size == 6
    obs = np.asarray(obs)
    # agent ids are one-hot in obs: rows alternate agent0, agent1
    np.testing.assert_array_equal(obs[::2, 0], 1.0)
    np.testing.assert_array_equal(obs[1::2, 1], 1.0)
    # correct actions give reward 1 to each agent
    act = jnp.tile(jnp.asarray([[0], [1]], jnp.int32), (3, 1))
    state, obs2, rew, done, info = vec.step(state, act, jax.random.PRNGKey(1))
    np.testing.assert_allclose(np.asarray(rew), 1.0)


def test_pool_round_robin_and_async():
    pool = Pool(Emulated(Bandit()), 4, num_buffers=3)
    seen = []
    for i in range(9):
        obs, rew, done, info, b = pool.recv()
        seen.append(b)
        pool.send(jnp.zeros((4, 1), jnp.int32))
    assert seen == [0, 1, 2, 0, 1, 2, 0, 1, 2]


def test_pool_recv_send_protocol():
    pool = Pool(Emulated(Bandit()), 2, num_buffers=2)
    pool.recv()
    with pytest.raises(AssertionError):
        pool.recv()   # recv twice without send


def test_pool_send_stale_buf_rejected():
    """An out-of-order buf must not skew the round-robin cursor: send()
    advances from the internal cursor and rejects a mismatched buf."""
    pool = Pool(Emulated(Bandit()), 2, num_buffers=3)
    act = jnp.zeros((2, 1), jnp.int32)
    *_, b0 = pool.recv()
    pool.send(act, b0)
    *_, b1 = pool.recv()
    assert (b0, b1) == (0, 1)
    with pytest.raises(ValueError, match="awaited buffer"):
        pool.send(act, buf=b0)          # stale buf from the older recv
    pool.send(act, b1)                  # correct buf still works
    *_, b2 = pool.recv()
    assert b2 == 2                      # cursor un-skewed


def test_autotune_runs():
    results, best = autotune(Emulated(Bandit()), 4, steps=8)
    assert set(results) == {"serial", "vmap"}
    assert all(v > 0 for v in results.values())
    assert best in results


def test_host_pool_first_finishers_beat_sync():
    """The paper's EnvPool claim on jittered host envs: taking the first N
    of M=2N finishers is >=30% faster than waiting for everyone."""
    from benchmarks.bench_pool_host import run_once
    sync = run_once(M=4, N=4, steps=40)
    pool = run_once(M=8, N=4, steps=40)
    assert pool > 1.3 * sync, (sync, pool)


def test_host_pool_delivers_all_envs():
    import numpy as np
    from repro.core.host import HostPool
    from benchmarks.bench_pool_host import JitteredEnv
    pool = HostPool([lambda i=i: JitteredEnv(mean_ms=0.5, reset_ms=1,
                                             seed=i) for i in range(6)],
                    batch_size=3)
    seen = set()
    for _ in range(12):
        obs, rew, done, info, ids = pool.recv(timeout=30)
        seen.update(int(i) for i in ids)
        pool.send(np.zeros(3), ids)
    pool.close()
    assert seen == set(range(6))   # no env starves
