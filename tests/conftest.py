import pytest


def pytest_configure(config):
    # keep in sync with [tool.pytest.ini_options] markers in pyproject.toml
    # (registered here too so bare `pytest tests/...` runs from any cwd
    # never warn on unknown markers)
    config.addinivalue_line("markers", "slow: long-running training tests")
    config.addinivalue_line(
        "markers", "multi_device: needs/forces a multi-device host")
    config.addinivalue_line(
        "markers", "timeout(seconds): per-test wall-clock bound, enforced "
        "by pytest-timeout (the CI distributed lane); inert without the "
        "plugin")
